"""L2 correctness: transpose-convention wrappers and the fused block step
against composed references, plus shape/dtype checks on the lowered HLO."""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.kernels import ref


def dd(n, seed):
    rng = np.random.default_rng(seed)
    a = rng.uniform(-1.0, 1.0, (n, n))
    np.fill_diagonal(a, np.abs(a).sum(axis=1) + 1.0)
    return jnp.asarray(a)


def rand(n, m, seed):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.uniform(-1.0, 1.0, (n, m)))


# ---------- transpose convention ----------


def test_getrf_t_is_transposed_getrf():
    a = dd(12, 0)
    (out_t,) = model.getrf_t(a.T)
    np.testing.assert_allclose(out_t.T, ref.getrf_ref(a), atol=1e-12)


def test_trsm_lower_t_convention():
    lu = ref.getrf_ref(dd(10, 1))
    b = rand(10, 10, 2)
    (out_t,) = model.trsm_lower_t(lu.T, b.T)
    np.testing.assert_allclose(out_t.T, ref.trsm_lower_ref(lu, b), atol=1e-12)


def test_trsm_upper_t_convention():
    lu = ref.getrf_ref(dd(10, 3))
    b = rand(10, 10, 4)
    (out_t,) = model.trsm_upper_t(lu.T, b.T)
    np.testing.assert_allclose(out_t.T, ref.trsm_upper_right_ref(lu, b), atol=1e-12)


def test_gemm_t_convention():
    c, a, b = rand(8, 8, 5), rand(8, 8, 6), rand(8, 8, 7)
    (out_t,) = model.gemm_t(c.T, a.T, b.T)
    np.testing.assert_allclose(out_t.T, ref.gemm_update_ref(c, a, b), atol=1e-12)


def test_col_major_buffer_semantics():
    """The exact contract the rust runtime relies on: feeding a col-major
    buffer as a row-major literal equals feeding the transpose."""
    a = dd(6, 8)
    col_major_flat = np.asarray(a).flatten(order="F")
    as_row_major = jnp.asarray(col_major_flat.reshape(6, 6))  # == a.T
    np.testing.assert_allclose(as_row_major, a.T)
    (out_t,) = model.getrf_t(as_row_major)
    back = np.asarray(out_t).flatten(order="C").reshape(6, 6, order="F")
    np.testing.assert_allclose(back, ref.getrf_ref(a), atol=1e-12)


# ---------- fused block step ----------


@pytest.mark.parametrize("n", [4, 8, 16])
def test_block_step_matches_composed_refs(n):
    d, a, b, c = dd(n, 10), rand(n, n, 11), rand(n, n, 12), rand(n, n, 13)
    lu_r, a_r, b_r, c_r = ref.block_step_ref(d, a, b, c)
    lu_t, a_t, b_t, c_t = model.block_step_t(d.T, a.T, b.T, c.T)
    np.testing.assert_allclose(lu_t.T, lu_r, atol=1e-11)
    np.testing.assert_allclose(a_t.T, a_r, atol=1e-11)
    np.testing.assert_allclose(b_t.T, b_r, atol=1e-11)
    np.testing.assert_allclose(c_t.T, c_r, atol=1e-11)


def test_block_step_equals_full_lu_of_supertile():
    """Eliminating the top-left half of a 2n×2n dense matrix via the fused
    step must equal the leading steps of a full LU."""
    n = 6
    m = dd(2 * n, 20)
    lu_full = ref.getrf_ref(m)
    d, a = m[:n, :n], m[n:, :n]
    b, c = m[:n, n:], m[n:, n:]
    lu, a2, b2, c2 = ref.block_step_ref(d, a, b, c)
    c2 = ref.getrf_ref(c2)
    np.testing.assert_allclose(lu_full[:n, :n], lu, atol=1e-9)
    np.testing.assert_allclose(lu_full[n:, :n], a2, atol=1e-9)
    np.testing.assert_allclose(lu_full[:n, n:], b2, atol=1e-9)
    np.testing.assert_allclose(lu_full[n:, n:], c2, atol=1e-9)
