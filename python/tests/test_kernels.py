"""L1 correctness: Pallas kernels vs the pure-jnp oracle (ref.py), with
hypothesis sweeping shapes and seeds — the core build-time correctness
signal for the dense path."""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import lu_kernels as lk
from compile.kernels import ref


def diag_dominant(n, seed, dtype=jnp.float64):
    rng = np.random.default_rng(seed)
    a = rng.uniform(-1.0, 1.0, (n, n))
    np.fill_diagonal(a, np.abs(a).sum(axis=1) + 1.0)
    return jnp.asarray(a, dtype=dtype)


def rand(n, m, seed, dtype=jnp.float64):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.uniform(-1.0, 1.0, (n, m)), dtype=dtype)


# ---------- oracle self-checks (ref.py against numpy linalg) ----------


@pytest.mark.parametrize("n", [1, 2, 5, 16, 33])
def test_ref_getrf_reconstructs(n):
    a = diag_dominant(n, seed=n)
    lu = ref.getrf_ref(a)
    l = jnp.tril(lu, -1) + jnp.eye(n)
    u = jnp.triu(lu)
    np.testing.assert_allclose(l @ u, a, rtol=0, atol=1e-10)


@pytest.mark.parametrize("n,k", [(4, 3), (8, 8), (16, 5)])
def test_ref_trsm_lower_solves(n, k):
    lu = ref.getrf_ref(diag_dominant(n, seed=7))
    l = jnp.tril(lu, -1) + jnp.eye(n)
    x = rand(n, k, seed=8)
    b = l @ x
    np.testing.assert_allclose(ref.trsm_lower_ref(lu, b), x, atol=1e-10)


@pytest.mark.parametrize("n,m", [(4, 3), (8, 8), (16, 5)])
def test_ref_trsm_upper_right_solves(n, m):
    lu = ref.getrf_ref(diag_dominant(n, seed=9))
    u = jnp.triu(lu)
    x = rand(m, n, seed=10)
    b = x @ u
    np.testing.assert_allclose(ref.trsm_upper_right_ref(lu, b), x, atol=1e-10)


def test_ref_gemm():
    c, a, b = rand(5, 6, 1), rand(5, 4, 2), rand(4, 6, 3)
    np.testing.assert_allclose(
        ref.gemm_update_ref(c, a, b), np.asarray(c) - np.asarray(a) @ np.asarray(b),
        atol=1e-12,
    )


# ---------- Pallas kernels vs oracle ----------


@pytest.mark.parametrize("n", [2, 4, 8, 16, 32, 64])
def test_pallas_getrf_matches_ref(n):
    a = diag_dominant(n, seed=100 + n)
    np.testing.assert_allclose(lk.getrf(a), ref.getrf_ref(a), atol=1e-11)


@pytest.mark.parametrize("n,k", [(4, 4), (8, 16), (32, 32), (64, 8)])
def test_pallas_trsm_lower_matches_ref(n, k):
    lu = ref.getrf_ref(diag_dominant(n, seed=200 + n))
    b = rand(n, k, seed=201 + k)
    np.testing.assert_allclose(lk.trsm_lower(lu, b), ref.trsm_lower_ref(lu, b), atol=1e-11)


@pytest.mark.parametrize("n,m", [(4, 4), (8, 16), (32, 32), (64, 8)])
def test_pallas_trsm_upper_matches_ref(n, m):
    lu = ref.getrf_ref(diag_dominant(n, seed=300 + n))
    b = rand(m, n, seed=301 + m)
    np.testing.assert_allclose(
        lk.trsm_upper_right(lu, b), ref.trsm_upper_right_ref(lu, b), atol=1e-11
    )


@pytest.mark.parametrize("m,k,n", [(4, 4, 4), (8, 4, 16), (32, 32, 32)])
def test_pallas_gemm_matches_ref(m, k, n):
    c, a, b = rand(m, n, 1), rand(m, k, 2), rand(k, n, 3)
    np.testing.assert_allclose(
        lk.gemm_update(c, a, b), ref.gemm_update_ref(c, a, b), atol=1e-12
    )


# ---------- hypothesis sweeps: shapes, dtypes, value ranges ----------


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(min_value=1, max_value=48),
    seed=st.integers(min_value=0, max_value=2**31),
)
def test_hyp_getrf_reconstructs(n, seed):
    a = diag_dominant(n, seed=seed)
    lu = lk.getrf(a)
    l = jnp.tril(lu, -1) + jnp.eye(n)
    u = jnp.triu(lu)
    np.testing.assert_allclose(l @ u, a, atol=1e-9)


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(min_value=1, max_value=32),
    k=st.integers(min_value=1, max_value=32),
    seed=st.integers(min_value=0, max_value=2**31),
)
def test_hyp_trsm_round_trip(n, k, seed):
    lu = lk.getrf(diag_dominant(n, seed=seed))
    x = rand(n, k, seed=seed ^ 0xFFFF)
    l = jnp.tril(lu, -1) + jnp.eye(n)
    b = l @ x
    np.testing.assert_allclose(lk.trsm_lower(lu, b), x, atol=1e-9)


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(min_value=1, max_value=24),
    k=st.integers(min_value=1, max_value=24),
    n=st.integers(min_value=1, max_value=24),
    seed=st.integers(min_value=0, max_value=2**31),
)
def test_hyp_gemm_matches_numpy(m, k, n, seed):
    rng = np.random.default_rng(seed)
    c = rng.standard_normal((m, n))
    a = rng.standard_normal((m, k))
    b = rng.standard_normal((k, n))
    got = lk.gemm_update(jnp.asarray(c), jnp.asarray(a), jnp.asarray(b))
    np.testing.assert_allclose(got, c - a @ b, atol=1e-10)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**31))
def test_hyp_getrf_f32_also_works(seed):
    a = diag_dominant(16, seed=seed, dtype=jnp.float32)
    lu = lk.getrf(a)
    l = jnp.tril(lu, -1) + jnp.eye(16, dtype=jnp.float32)
    u = jnp.triu(lu)
    np.testing.assert_allclose(l @ u, a, atol=1e-3)


# ---------- VMEM / MXU estimators ----------


def test_vmem_footprint_within_budget():
    # 256x256 f64, 3 operands = 1.5 MiB << 16 MiB VMEM
    assert lk.vmem_footprint_bytes(256) == 3 * 256 * 256 * 8
    assert lk.vmem_footprint_bytes(256) < 16 * 2**20


def test_mxu_utilization_saturates_at_128():
    assert lk.mxu_utilization_estimate(128) == 1.0
    assert lk.mxu_utilization_estimate(256) == 1.0
    assert lk.mxu_utilization_estimate(64) == pytest.approx(0.25)
