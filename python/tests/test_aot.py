"""AOT pipeline: lowering produces parseable HLO text with the right
entry signature for every op and tile size."""

import jax

jax.config.update("jax_enable_x64", True)

import pytest

from compile import aot, model


@pytest.mark.parametrize("size", [32, 64])
def test_all_entries_lower_to_hlo_text(size):
    for name, (fn, shapes) in aot.entries_for(size).items():
        text = aot.to_hlo_text(aot.lower_entry(fn, shapes))
        assert text.startswith("HloModule"), name
        assert "ENTRY" in text, name
        # f64 operands present
        assert f"f64[{size},{size}]" in text, name


def test_manifest_written(tmp_path):
    import subprocess
    import sys

    r = subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out-dir", str(tmp_path), "--sizes", "32"],
        cwd=str(aot.pathlib.Path(__file__).resolve().parents[1]),
        capture_output=True,
        text=True,
    )
    assert r.returncode == 0, r.stderr
    files = {p.name for p in tmp_path.iterdir()}
    for stem in ["getrf", "trsm_l", "trsm_u", "gemm", "block_step"]:
        assert f"{stem}_32.hlo.txt" in files
    assert "manifest.txt" in files


def test_hlo_text_has_no_64bit_id_issue_markers():
    """Smoke: text round-trips through the local XLA parser (the same
    parser class the rust xla_extension embeds)."""
    text = aot.to_hlo_text(aot.lower_entry(model.gemm_t, [(32, 32)] * 3))
    # stablehlo→xla conversion flattens pallas interpret mode: no custom-calls
    assert "custom-call" not in text.lower()
