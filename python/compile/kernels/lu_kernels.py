"""L1: Pallas block kernels for the dense path of the blocked sparse LU.

Hardware adaptation (DESIGN.md §3): the paper's dense path is cuBLAS on
A100 (threadblocks over shared memory). Rethought for the TPU/Pallas
model:

* a block op works on one tile that fits **VMEM** — the BlockSpecs below
  map the whole operand into VMEM in one shot for tiles ≤ 256×256 f64
  (512 KiB/operand, comfortably inside the ~16 MiB/core budget with
  double-buffering headroom);
* the Schur update (`gemm_kernel`) is a single `jnp.dot` inside the
  kernel, which Mosaic lowers onto the **MXU** systolic array — the analog
  of tensor-core WMMA tiles;
* GETRF/TRSM are sequential eliminations (latency-bound on any target);
  they stay in-VMEM `fori_loop`s over vector ops, the same structure the
  paper's single-SM dense getrf kernels have.

All kernels are lowered with `interpret=True`: the CPU PJRT plugin cannot
execute Mosaic custom-calls, and interpret mode lowers to plain HLO that
both CPU-jax (pytest) and the rust PJRT client execute identically.
Real-TPU performance is *estimated* from VMEM footprint + MXU utilization
in DESIGN.md §7 / EXPERIMENTS.md §Perf.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _getrf_kernel(a_ref, o_ref):
    a = a_ref[...]
    n = a.shape[0]

    def body(k, a):
        idx = jnp.arange(n)
        below = idx > k
        piv = a[k, k]
        lcol = jnp.where(below, a[:, k] / piv, a[:, k])
        a = a.at[:, k].set(lcol)
        l_masked = jnp.where(below, lcol, 0.0)
        u_masked = jnp.where(idx > k, a[k, :], 0.0)
        return a - jnp.outer(l_masked, u_masked)

    o_ref[...] = jax.lax.fori_loop(0, n, body, a)


def _trsm_lower_kernel(lu_ref, b_ref, o_ref):
    lu = lu_ref[...]
    m = lu.shape[0]

    def body(k, x):
        idx = jnp.arange(m)
        lcol = jnp.where(idx > k, lu[:, k], 0.0)
        return x - jnp.outer(lcol, x[k, :])

    o_ref[...] = jax.lax.fori_loop(0, m, body, b_ref[...])


def _trsm_upper_right_kernel(lu_ref, b_ref, o_ref):
    lu = lu_ref[...]
    k = lu.shape[0]

    def body(c, x):
        idx = jnp.arange(k)
        ucol = jnp.where(idx < c, lu[:, c], 0.0)
        xc = (x[:, c] - x @ ucol) / lu[c, c]
        return x.at[:, c].set(xc)

    o_ref[...] = jax.lax.fori_loop(0, k, body, b_ref[...])


def _gemm_kernel(c_ref, a_ref, b_ref, o_ref):
    # One MXU-shaped contraction; fp64 on CPU-interpret, bf16xbf16->f32
    # accumulate on a real TPU lowering.
    o_ref[...] = c_ref[...] - jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=c_ref.dtype
    )


def _call(kernel, out_shape, *args):
    return pl.pallas_call(
        kernel,
        out_shape=out_shape,
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(*args)


@functools.partial(jax.jit, static_argnames=())
def getrf(a):
    """{L\\U} of a square tile (no pivoting)."""
    return _call(_getrf_kernel, jax.ShapeDtypeStruct(a.shape, a.dtype), a)


@jax.jit
def trsm_lower(lu, b):
    """L^-1 B with unit-lower L from a factored tile."""
    return _call(_trsm_lower_kernel, jax.ShapeDtypeStruct(b.shape, b.dtype), lu, b)


@jax.jit
def trsm_upper_right(lu, b):
    """B U^-1 with upper U from a factored tile."""
    return _call(_trsm_upper_right_kernel, jax.ShapeDtypeStruct(b.shape, b.dtype), lu, b)


@jax.jit
def gemm_update(c, a, b):
    """C - A @ B."""
    return _call(_gemm_kernel, jax.ShapeDtypeStruct(c.shape, c.dtype), c, a, b)


def vmem_footprint_bytes(tile: int, dtype_bytes: int = 8, operands: int = 3) -> int:
    """Estimated VMEM residency of one kernel invocation (DESIGN.md §7)."""
    return operands * tile * tile * dtype_bytes


def mxu_utilization_estimate(tile: int) -> float:
    """Fraction of MXU peak the GEMM tile can sustain: the 128x128 systolic
    array is fully fed for tile >= 128; smaller tiles waste lanes."""
    return min(1.0, (tile / 128.0) ** 2) if tile < 128 else 1.0
