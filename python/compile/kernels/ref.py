"""Pure-jnp reference oracles for the Pallas block kernels.

These are the ground truth the L1 kernels are pytest-verified against
(`python/tests/test_kernels.py`), and they match the rust dense kernels in
`rust/src/numeric/dense.rs` operation-for-operation.

All matrices are row-major jax arrays here; the AOT wrappers in `model.py`
handle the transpose convention for the rust (column-major) caller.
"""

import jax
import jax.numpy as jnp


def getrf_ref(a: jax.Array) -> jax.Array:
    """No-pivot LU: returns {L\\U} packed (unit diagonal of L implicit)."""
    n = a.shape[0]

    def body(k, a):
        idx = jnp.arange(n)
        below = idx > k
        piv = a[k, k]
        lcol = jnp.where(below, a[:, k] / piv, a[:, k])
        a = a.at[:, k].set(lcol)
        l_masked = jnp.where(below, lcol, 0.0)
        u_masked = jnp.where(idx > k, a[k, :], 0.0)
        return a - jnp.outer(l_masked, u_masked)

    return jax.lax.fori_loop(0, n, body, a)


def trsm_lower_ref(lu: jax.Array, b: jax.Array) -> jax.Array:
    """X = L^-1 B with unit-lower L stored in {L\\U} `lu`."""
    m = lu.shape[0]

    def body(k, x):
        idx = jnp.arange(m)
        lcol = jnp.where(idx > k, lu[:, k], 0.0)
        return x - jnp.outer(lcol, x[k, :])

    return jax.lax.fori_loop(0, m, body, b)


def trsm_upper_right_ref(lu: jax.Array, b: jax.Array) -> jax.Array:
    """X = B U^-1 with upper U stored in {L\\U} `lu` (right-side solve)."""
    k = lu.shape[0]

    def body(c, x):
        idx = jnp.arange(k)
        ucol = jnp.where(idx < c, lu[:, c], 0.0)
        xc = (x[:, c] - x @ ucol) / lu[c, c]
        return x.at[:, c].set(xc)

    return jax.lax.fori_loop(0, k, body, b)


def gemm_update_ref(c: jax.Array, a: jax.Array, b: jax.Array) -> jax.Array:
    """C - A @ B (the Schur update)."""
    return c - a @ b


def block_step_ref(d, a, b, c):
    """One fused right-looking elimination step on a 2x2 dense block view:

    D -> {L\\U}, A -> A U^-1, B -> L^-1 B, C -> C - A' B'.
    """
    lu = getrf_ref(d)
    a2 = trsm_upper_right_ref(lu, a)
    b2 = trsm_lower_ref(lu, b)
    c2 = gemm_update_ref(c, a2, b2)
    return lu, a2, b2, c2
