"""L2: the jax compute graph around the L1 Pallas kernels.

Two responsibilities:

1. **Transpose convention** — the rust coordinator stores blocks
   column-major; jax literals built from those buffers read as the
   transposed matrix. Every exported entry point therefore takes and
   returns transposed operands, with the transposes folded into the XLA
   graph (they are layout ops, fused away by the compiler):
   `getrf_t(Aᵀ) = (LU(A))ᵀ`, `gemm_t(Cᵀ,Aᵀ,Bᵀ) = (C - A·B)ᵀ = Cᵀ - Bᵀ·Aᵀ`.

2. **Fusion** — `block_step_t` is the fused right-looking elimination
   step over a dense 2×2 super-tile (GETRF → both TRSMs → GEMM in one
   XLA program), used by the perf pass to amortize launch overhead when a
   whole trailing region goes dense.

Python runs only at build time: `aot.py` lowers these functions to HLO
text once; the rust runtime replays them forever after.
"""

import jax.numpy as jnp

from .kernels import lu_kernels as lk


def getrf_t(a_t):
    """Transposed-I/O wrapper of the L1 GETRF kernel. Returns a 1-tuple
    (the AOT bridge lowers with return_tuple=True)."""
    return (lk.getrf(a_t.T).T,)


def trsm_lower_t(lu_t, b_t):
    """Bᵀ ← (L⁻¹B)ᵀ."""
    return (lk.trsm_lower(lu_t.T, b_t.T).T,)


def trsm_upper_t(lu_t, b_t):
    """Bᵀ ← (B U⁻¹)ᵀ."""
    return (lk.trsm_upper_right(lu_t.T, b_t.T).T,)


def gemm_t(c_t, a_t, b_t):
    """Cᵀ ← (C − A·B)ᵀ — note transposition swaps the product order, so
    this stays a single MXU contraction with no data movement."""
    return (c_t - jnp.dot(b_t, a_t, preferred_element_type=c_t.dtype),)


def block_step_t(d_t, a_t, b_t, c_t):
    """Fused elimination step on a dense 2×2 super-tile (transposed I/O):

    D→{L\\U},  A→A·U⁻¹ (L-panel),  B→L⁻¹·B (U-panel),  C→C−A'B'.
    """
    d, a, b, c = d_t.T, a_t.T, b_t.T, c_t.T
    lu = lk.getrf(d)
    a2 = lk.trsm_upper_right(lu, a)
    b2 = lk.trsm_lower(lu, b)
    c2 = lk.gemm_update(c, a2, b2)
    return (lu.T, a2.T, b2.T, c2.T)
