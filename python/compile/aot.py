"""AOT bridge: lower the L2 graphs (with their L1 Pallas kernels) to HLO
text artifacts for the rust PJRT runtime.

HLO *text* is the interchange format, NOT `.serialize()`: jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids, which the image's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md and
aot_recipe).

Usage:  python -m compile.aot --out-dir ../artifacts [--sizes 32,64,...]
Emits:  {getrf,trsm_l,trsm_u,gemm}_{size}.hlo.txt  + block_step_{size}.hlo.txt
        + manifest.txt
"""

import argparse
import pathlib

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402
from jax._src.lib import xla_client as xc  # noqa: E402

from . import model  # noqa: E402

DEFAULT_SIZES = (32, 64, 128, 256)
DTYPE = jnp.float64


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_entry(fn, arg_shapes):
    specs = [jax.ShapeDtypeStruct(s, DTYPE) for s in arg_shapes]
    return jax.jit(fn).lower(*specs)


def entries_for(size: int):
    n = (size, size)
    return {
        f"getrf_{size}": (model.getrf_t, [n]),
        f"trsm_l_{size}": (model.trsm_lower_t, [n, n]),
        f"trsm_u_{size}": (model.trsm_upper_t, [n, n]),
        f"gemm_{size}": (model.gemm_t, [n, n, n]),
        f"block_step_{size}": (model.block_step_t, [n, n, n, n]),
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--sizes",
        default=",".join(str(s) for s in DEFAULT_SIZES),
        help="comma-separated tile sizes",
    )
    args = ap.parse_args()
    out = pathlib.Path(args.out_dir)
    out.mkdir(parents=True, exist_ok=True)
    sizes = [int(s) for s in args.sizes.split(",") if s]

    manifest = []
    for size in sizes:
        for name, (fn, shapes) in entries_for(size).items():
            text = to_hlo_text(lower_entry(fn, shapes))
            path = out / f"{name}.hlo.txt"
            path.write_text(text)
            manifest.append(f"{name}.hlo.txt {len(text)}")
            print(f"wrote {path} ({len(text)} chars)")
    (out / "manifest.txt").write_text("\n".join(manifest) + "\n")
    print(f"{len(manifest)} artifacts -> {out}")


if __name__ == "__main__":
    main()
