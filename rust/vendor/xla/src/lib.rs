//! API-compatible **stub** of the `xla` PJRT bindings.
//!
//! The real crate links `xla_extension` (a multi-GB native bundle) which is
//! not available offline. This stub keeps `sparselu::runtime` compiling
//! with the identical call surface; every entry point reports a clean
//! "PJRT unavailable" error at runtime, so artifact-gated integration
//! tests skip and `PjrtDense::load` fails with a useful message instead of
//! a link error. Swap the `xla` path dependency in `rust/Cargo.toml` to
//! the real bindings to enable the PJRT dense backend.

use std::fmt;

/// Error type mirroring the real crate's (std-error) surface.
#[derive(Debug)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable() -> Error {
    Error(
        "PJRT runtime unavailable: the `xla` dependency is the offline stub \
         (point rust/Cargo.toml at the real xla bindings to enable it)"
            .into(),
    )
}

/// PJRT client handle (stub — `cpu()` always errors).
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<Self> {
        Err(unavailable())
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable())
    }
}

/// Parsed HLO module (stub).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<Self> {
        Err(unavailable())
    }
}

/// XLA computation wrapper (stub).
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Compiled executable (stub — `execute` always errors).
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable())
    }
}

/// Device buffer handle (stub).
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable())
    }
}

/// Host literal (stub).
pub struct Literal;

impl Literal {
    pub fn vec1(_data: &[f64]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Err(unavailable())
    }

    pub fn to_tuple1(self) -> Result<Literal> {
        Err(unavailable())
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(unavailable())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_reports_unavailable() {
        let err = PjRtClient::cpu().err().expect("stub must error");
        assert!(err.to_string().contains("PJRT runtime unavailable"));
    }
}
