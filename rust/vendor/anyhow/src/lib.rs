//! Minimal offline stand-in for the `anyhow` crate.
//!
//! The container building this repository has no crates.io access, so the
//! subset of `anyhow` the codebase uses is implemented here: [`Error`],
//! [`Result`], the [`Context`] extension trait, and the [`anyhow!`] /
//! [`bail!`] macros. Semantics match the real crate for this subset
//! (context prefixes the message; `{:#}` prints the full chain), so the
//! real dependency can be swapped back in without source changes.

use std::error::Error as StdError;
use std::fmt;

/// A dynamically-typed error with a context chain.
pub struct Error {
    msg: String,
    source: Option<Box<dyn StdError + Send + Sync + 'static>>,
}

/// `Result<T, anyhow::Error>` alias.
pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Build an error from a displayable message.
    pub fn msg<M: fmt::Display>(m: M) -> Self {
        Error { msg: m.to_string(), source: None }
    }

    /// Wrap with an additional layer of context.
    pub fn context<C: fmt::Display>(self, c: C) -> Self {
        Error { msg: format!("{c}: {}", self.msg), source: self.source }
    }

    /// The root cause, if this error wraps a std error.
    pub fn source(&self) -> Option<&(dyn StdError + 'static)> {
        self.source.as_deref().map(|e| e as &(dyn StdError + 'static))
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // `{}` and `{:#}` both print the accumulated chain — the message
        // already carries every context layer.
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// NOTE: like the real anyhow, `Error` deliberately does NOT implement
// `std::error::Error` — that would conflict with this blanket conversion.
impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        Error { msg: e.to_string(), source: Some(Box::new(e)) }
    }
}

/// Extension trait adding `.context(...)` / `.with_context(...)` to
/// `Result` and `Option`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| e.into().context(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a message or format string.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Early-return with an [`Error`] built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn context_prefixes_message() {
        let r: Result<()> = Err(io_err()).context("reading config");
        let e = r.unwrap_err();
        assert!(e.to_string().starts_with("reading config: "));
        assert!(e.source().is_some());
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("missing").unwrap_err();
        assert_eq!(e.to_string(), "missing");
    }

    #[test]
    fn macros_build_errors() {
        let e = anyhow!("plain");
        assert_eq!(e.to_string(), "plain");
        let x = 3;
        let e = anyhow!("value {x} bad", );
        assert_eq!(e.to_string(), "value 3 bad");
        let e = anyhow!("{} and {}", 1, 2);
        assert_eq!(e.to_string(), "1 and 2");
        fn f() -> Result<()> {
            bail!("nope {}", 7)
        }
        assert_eq!(f().unwrap_err().to_string(), "nope 7");
    }

    #[test]
    fn question_mark_converts() {
        fn f() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert!(f().is_err());
    }

    #[test]
    fn context_on_anyhow_result_chains() {
        let r: Result<()> = Err(Error::msg("inner"));
        let e = r.context("outer").unwrap_err();
        assert_eq!(e.to_string(), "outer: inner");
    }
}
