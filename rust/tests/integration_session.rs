//! Integration: the session subsystem's numeric-only re-factorization
//! must be indistinguishable from a cold `Solver::factorize` — property
//! tests across seeded random matrices (the proptest crate is unavailable
//! offline; failures print the seed). Generators and shrinking helpers
//! are shared with `differential.rs` through `tests/common/`.

mod common;

use common::{perturbed, random_matrix};
use sparselu::session::{FactorPlan, PlanCache, SolverSession};
use sparselu::solver::{SolveOptions, Solver};
use sparselu::sparse::{gen, residual};
use sparselu::util::Prng;
use std::sync::Arc;

const SEEDS: u64 = 16;

#[test]
fn prop_refactorize_matches_cold_factorize_bitwise() {
    for seed in 0..SEEDS {
        let a = random_matrix(seed);
        let n = a.n_rows();
        let workers = 1 + (seed % 4) as u32;
        let opts = SolveOptions::ours(workers);

        // session: plan from the original pattern, refactorize with the
        // values of a *different* matrix instance (same pattern)
        let a2 = perturbed(&a, seed ^ 0xFACE);
        let plan = Arc::new(FactorPlan::build(&a, &opts).unwrap());
        let mut session = SolverSession::from_plan(plan);
        session
            .refactorize_matrix(&a2)
            .unwrap_or_else(|e| panic!("seed {seed}: {e}"));

        // cold path on the same values
        let mut solver = Solver::new(opts);
        let cold = solver.factorize(&a2).unwrap_or_else(|e| panic!("seed {seed}: {e}"));

        let mut rng = Prng::new(seed ^ 0xB0);
        let b: Vec<f64> = (0..n).map(|_| rng.signed_unit() * 3.0).collect();
        let x_session = session.solve(&b);
        let x_cold = cold.solve(&b);
        assert_eq!(
            x_session, x_cold,
            "seed {seed}: session refactorize must be bit-identical to cold factorize"
        );
        let r = residual(&a2, &x_session, &b);
        assert!(r < 1e-8, "seed {seed}: residual {r}");
    }
}

#[test]
fn prop_refactorize_residual_equivalent_across_steps() {
    // many Newton-style steps through one session stay well-conditioned
    for seed in 0..6 {
        let a = random_matrix(seed);
        let n = a.n_rows();
        let plan = Arc::new(FactorPlan::build(&a, &SolveOptions::ours(2)).unwrap());
        let mut session = SolverSession::from_plan(plan);
        for step in 0..5u64 {
            let astep = perturbed(&a, seed * 31 + step);
            session.refactorize_matrix(&astep).unwrap();
            let b: Vec<f64> = (0..n).map(|i| ((i + step as usize) % 9) as f64 - 4.0).collect();
            let x = session.solve(&b);
            let r = residual(&astep, &x, &b);
            assert!(r < 1e-8, "seed {seed} step {step}: residual {r}");
        }
        assert_eq!(session.refactor_count(), 5);
    }
}

#[test]
fn prop_solve_many_matches_repeated_single_solves() {
    for seed in 0..SEEDS {
        let a = random_matrix(seed);
        let n = a.n_rows();
        let plan = Arc::new(FactorPlan::build(&a, &SolveOptions::ours(1)).unwrap());
        let mut session = SolverSession::from_plan(plan);
        session.refactorize_matrix(&a).unwrap();
        let mut rng = Prng::new(seed ^ 0x51);
        let nrhs = 1 + rng.below(6);
        let bs: Vec<Vec<f64>> = (0..nrhs)
            .map(|_| (0..n).map(|_| rng.signed_unit() * 5.0).collect())
            .collect();
        let batched = session.solve_many(&bs);
        assert_eq!(batched.len(), nrhs);
        for (s, (b, x)) in bs.iter().zip(&batched).enumerate() {
            assert_eq!(
                x,
                &session.solve(b),
                "seed {seed} rhs {s}: batched solve must equal single solve"
            );
            let r = residual(&a, x, b);
            assert!(r < 1e-8, "seed {seed} rhs {s}: residual {r}");
        }
    }
}

#[test]
fn plan_cache_serves_newton_sweep_with_one_build() {
    let a = random_matrix(3);
    let opts = SolveOptions::ours(2);
    let mut cache = PlanCache::new(4);
    let mut plans = Vec::new();
    for step in 0..10u64 {
        let astep = perturbed(&a, step);
        plans.push(cache.get_or_build(&astep, &opts).unwrap());
    }
    assert_eq!(cache.misses(), 1, "one structure analysis for the whole sweep");
    assert_eq!(cache.hits(), 9);
    for p in &plans[1..] {
        assert!(Arc::ptr_eq(&plans[0], p));
    }
    // and the shared plan actually factorizes the perturbed steps
    let mut session = SolverSession::from_plan(plans[0].clone());
    let astep = perturbed(&a, 7);
    session.refactorize_matrix(&astep).unwrap();
    let b = vec![1.0; a.n_rows()];
    let x = session.solve(&b);
    assert!(residual(&astep, &x, &b) < 1e-8);
}

#[test]
fn fingerprint_distinguishes_patterns_across_generators() {
    let mats = [
        gen::grid2d_laplacian(10, 10),
        gen::grid2d_laplacian(10, 11),
        gen::tridiagonal(100),
        gen::circuit_bbd(gen::CircuitParams { n: 100, ..Default::default() }),
    ];
    let fps: Vec<u64> = mats.iter().map(|m| m.pattern_fingerprint()).collect();
    for i in 0..fps.len() {
        for j in (i + 1)..fps.len() {
            assert_ne!(fps[i], fps[j], "matrices {i} and {j} collide");
        }
    }
    // fingerprints are stable across clones and value changes
    let p = perturbed(&mats[3], 5);
    assert_eq!(p.pattern_fingerprint(), mats[3].pattern_fingerprint());
}
