//! Kernel differential rig: proves the tiled fast path bit-identical to
//! the scalar oracle.
//!
//! # The ordering contract
//!
//! The tiled kernels in `numeric::tiled` are pure *loop-order and data
//! re-layouts* of the scalar reference kernels in `numeric::dense`: for
//! every output element they execute the exact same sequence of IEEE-754
//! operations (same multiplies, same adds, same order of accumulation)
//! as the scalar kernel does for that element. Register blocking changes
//! *which elements* are in flight together, never the per-element
//! reduction order. Because IEEE-754 arithmetic is deterministic, that
//! makes `Tiled` and `Scalar` outputs equal not just approximately but
//! **bit for bit** — so this suite compares with `to_bits()`, and any
//! regression that perturbs accumulation order (e.g. a horizontal-sum
//! "optimization") fails loudly instead of slipping under an epsilon.
//!
//! The sweep covers square / tall / wide / 1×1 shapes, dense and sparse
//! fills, and the empty pattern (density 0.0), in both f64 and f32, plus
//! a whole-factorization differential through the public `Solver` API.
//! Shared shape/density suites live in `tests/common/blocks.rs`.

mod common;

use common::blocks;
use sparselu::numeric::{dense, tiled, KernelImpl};
use sparselu::solver::{SolveOptions, Solver};
use sparselu::util::Prng;

/// Bitwise comparison with a diagnostic that names the kernel, shape,
/// density, and first mismatching flat index.
fn assert_bits(kernel: &str, shape: &str, density: f64, scalar: &[f64], tiled: &[f64]) {
    if let Some(i) = blocks::bits_equal(scalar, tiled) {
        panic!(
            "{kernel} {shape} density {density}: tiled diverges from scalar at \
             flat index {i} (scalar {:e} vs tiled {:e}) — the order-preservation \
             contract is broken",
            scalar[i], tiled[i]
        );
    }
}

/// A diagonally-dominant block factored in place by the *scalar* oracle —
/// both TRSM paths are handed the same LU input, so any divergence is
/// theirs alone.
fn factored_block(n: usize, seed: u64) -> Vec<f64> {
    let mut lu = blocks::dd_block(n, 1.0, seed);
    dense::getrf_in_place(&mut lu, n).expect("diagonally dominant blocks factor");
    lu
}

#[test]
fn getrf_tiled_matches_scalar_bitwise() {
    for (case, &n) in blocks::GETRF_SIZES.iter().enumerate() {
        for &d in blocks::DENSITIES {
            let a = blocks::dd_block(n, d, 0xD1F + case as u64);
            let mut s = a.clone();
            let mut t = a;
            dense::getrf_in_place(&mut s, n).expect("scalar getrf on dd block");
            tiled::getrf_in_place(&mut t, n).expect("tiled getrf on dd block");
            assert_bits("getrf", &format!("{n}x{n}"), d, &s, &t);
        }
    }
}

#[test]
fn trsm_lower_tiled_matches_scalar_bitwise() {
    for (case, &(m, k)) in blocks::PANEL_SHAPES.iter().enumerate() {
        let lu = factored_block(m, 0x10_0 + case as u64);
        for &d in blocks::DENSITIES {
            let b = blocks::panel(m, k, d, 0x20_0 + case as u64);
            let mut s = b.clone();
            let mut t = b;
            dense::trsm_lower_unit(&lu, m, &mut s, k);
            tiled::trsm_lower_unit(&lu, m, &mut t, k);
            assert_bits("trsm_lower_unit", &format!("{m}x{k}"), d, &s, &t);
        }
    }
}

#[test]
fn trsm_upper_tiled_matches_scalar_bitwise() {
    for (case, &(m, k)) in blocks::PANEL_SHAPES.iter().enumerate() {
        let lu = factored_block(k, 0x30_0 + case as u64);
        for &d in blocks::DENSITIES {
            let b = blocks::panel(m, k, d, 0x40_0 + case as u64);
            let mut s = b.clone();
            let mut t = b;
            dense::trsm_upper_right(&lu, k, &mut s, m);
            tiled::trsm_upper_right(&lu, k, &mut t, m);
            assert_bits("trsm_upper_right", &format!("{m}x{k}"), d, &s, &t);
        }
    }
}

#[test]
fn gemm_tiled_matches_scalar_bitwise() {
    for (case, &(m, k, n)) in blocks::GEMM_SHAPES.iter().enumerate() {
        for &d in blocks::DENSITIES {
            let a = blocks::panel(m, k, d, 0x50_0 + case as u64);
            let b = blocks::panel(k, n, d, 0x60_0 + case as u64);
            let c = blocks::panel(m, n, 1.0, 0x70_0 + case as u64);
            let mut s = c.clone();
            let mut t = c;
            dense::gemm_update(&mut s, &a, &b, m, k, n);
            tiled::gemm_update(&mut t, &a, &b, m, k, n);
            assert_bits("gemm_update", &format!("{m}x{k}x{n}"), d, &s, &t);
        }
    }
}

/// Empty-pattern inputs: an all-zero GEMM update must leave C untouched
/// (bitwise, including signed zeros) on both paths, and an all-zero TRSM
/// panel must stay all zero.
#[test]
fn empty_pattern_blocks_are_fixed_points() {
    let (m, k, n) = (17, 9, 23);
    let a = blocks::panel(m, k, 0.0, 1);
    let b = blocks::panel(k, n, 0.0, 2);
    let c = blocks::panel(m, n, 1.0, 3);
    let mut s = c.clone();
    let mut t = c.clone();
    dense::gemm_update(&mut s, &a, &b, m, k, n);
    tiled::gemm_update(&mut t, &a, &b, m, k, n);
    assert_bits("gemm_update", "empty A,B", 0.0, &c, &s);
    assert_bits("gemm_update", "empty A,B", 0.0, &c, &t);

    let lu = factored_block(m, 4);
    let mut zs = vec![0.0; m * k];
    let mut zt = vec![0.0; m * k];
    dense::trsm_lower_unit(&lu, m, &mut zs, k);
    tiled::trsm_lower_unit(&lu, m, &mut zt, k);
    assert!(zs.iter().all(|v| *v == 0.0), "scalar trsm invents values from a zero panel");
    assert_bits("trsm_lower_unit", "empty panel", 0.0, &zs, &zt);
}

/// The contract is generic over the element type: instantiate the same
/// differential at f32 (the mixed-precision replay path's storage type).
#[test]
fn f32_instantiation_matches_bitwise() {
    for &n in &[1usize, 7, 32, 48] {
        let a64 = blocks::dd_block(n, 0.5, 0xF32 + n as u64);
        let a32: Vec<f32> = a64.iter().map(|v| *v as f32).collect();
        let mut s = a32.clone();
        let mut t = a32;
        dense::getrf_in_place(&mut s, n).expect("scalar f32 getrf on dd block");
        tiled::getrf_in_place(&mut t, n).expect("tiled f32 getrf on dd block");
        for (i, (x, y)) in s.iter().zip(&t).enumerate() {
            assert_eq!(
                x.to_bits(),
                y.to_bits(),
                "f32 getrf {n}x{n}: tiled diverges from scalar at flat index {i}"
            );
        }

        let (m, k) = (n, 2 * n + 1);
        let b64 = blocks::panel(m, k, 0.5, 0xF33 + n as u64);
        let b32: Vec<f32> = b64.iter().map(|v| *v as f32).collect();
        let lu = s; // scalar-factored f32 LU feeds both TRSM paths
        let mut ps = b32.clone();
        let mut pt = b32;
        dense::trsm_lower_unit(&lu, m, &mut ps, k);
        tiled::trsm_lower_unit(&lu, m, &mut pt, k);
        for (i, (x, y)) in ps.iter().zip(&pt).enumerate() {
            assert_eq!(
                x.to_bits(),
                y.to_bits(),
                "f32 trsm_lower_unit {m}x{k}: tiled diverges at flat index {i}"
            );
        }
    }
}

/// Property-style sweep: many seed-derived random shapes and densities,
/// shrunk only in the sense that the failure message pins the seed.
#[test]
fn random_shapes_match_bitwise() {
    for seed in 0..40u64 {
        let (m, k, n, d) = blocks::random_gemm_case(seed, 40);
        let a = blocks::panel(m, k, d, seed ^ 0xA);
        let b = blocks::panel(k, n, d, seed ^ 0xB);
        let c = blocks::panel(m, n, 1.0, seed ^ 0xC);
        let mut s = c.clone();
        let mut t = c;
        dense::gemm_update(&mut s, &a, &b, m, k, n);
        tiled::gemm_update(&mut t, &a, &b, m, k, n);
        assert_bits("gemm_update", &format!("seed {seed}: {m}x{k}x{n}"), d, &s, &t);

        let (gn, gd) = blocks::random_getrf_case(seed, 48);
        let g = blocks::dd_block(gn, gd, seed ^ 0xD);
        let mut gs = g.clone();
        let mut gt = g;
        dense::getrf_in_place(&mut gs, gn).expect("scalar getrf on dd block");
        tiled::getrf_in_place(&mut gt, gn).expect("tiled getrf on dd block");
        assert_bits("getrf", &format!("seed {seed}: {gn}x{gn}"), gd, &gs, &gt);
    }
}

/// Whole-pipeline differential: a full factorization + solve through the
/// public `Solver` API under `KernelImpl::Scalar` vs `KernelImpl::Tiled`
/// must produce bit-identical solutions — the per-kernel contract has to
/// survive composition across the blocked elimination too.
#[test]
fn whole_factorization_is_bit_identical_across_impls() {
    for seed in [11u64, 47, 101] {
        let a = common::random_matrix(seed);
        let n = a.n_rows();
        let mut rng = Prng::new(seed ^ 0xB17);
        let b: Vec<f64> = (0..n).map(|_| rng.signed_unit()).collect();

        let solve_with = |imp: KernelImpl| {
            let mut opts = SolveOptions::ours(1);
            opts.kernels.imp = imp;
            let mut solver = Solver::new(opts);
            let f = solver.factorize(&a).expect("suite matrix factors");
            f.solve(&b)
        };
        let xs = solve_with(KernelImpl::Scalar);
        let xt = solve_with(KernelImpl::Tiled);
        assert_bits("solver", &format!("seed {seed}: n {n}"), 1.0, &xs, &xt);
    }
}
