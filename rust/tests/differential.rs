//! Differential harness for incremental re-factorization: across seeded
//! random (matrix, change-set) pairs, `refactorize_partial(cs)` must be
//! **bit-identical** to a full `refactorize` of the updated values —
//! covering empty, single-entry, single-block, scattered multi-level and
//! full-matrix change sets. On failure the harness shrinks the case
//! (matrix size by bisection, then the change set by delta debugging)
//! and panics with a minimal reproducer.

mod common;

use common::shrink;
use sparselu::session::{ChangeSet, FactorPlan, SolverSession};
use sparselu::solver::{BlockingPolicy, SolveOptions, Solver};
use sparselu::sparse::{gen, residual, Csc};
use sparselu::util::Prng;
use std::sync::Arc;

const CASES: u64 = 64;

/// Deterministic replacement value for A-nonzero `k` — a pure function of
/// `(seed, k, old)` so a shrunken change set reproduces the same values.
fn new_value(seed: u64, k: usize, old: f64) -> f64 {
    let h = Prng::new(seed ^ (k as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15)).f64();
    old * (1.0 + 0.04 * (2.0 * h - 1.0)) + 1e-3 * (2.0 * h - 1.0)
}

/// The change-set value indices for one case, by kind (`case % 5`):
/// 0 = empty, 1 = single entry, 2 = confined to one block, 3 = scattered
/// multi-level subset (~10% of nnz), 4 = full matrix.
fn change_indices(seed: u64, a: &Csc, kind: u64) -> Vec<usize> {
    let nnz = a.nnz();
    let mut rng = Prng::new(seed ^ 0xC0FF_EE00);
    match kind {
        0 => Vec::new(),
        1 => vec![rng.below(nnz)],
        2 => {
            // all entries landing in the block of one randomly-chosen
            // entry (the external mirror of the plan's scatter map)
            let opts = SolveOptions::ours(1 + (seed % 4) as u32);
            let plan = FactorPlan::build(a, &opts).unwrap();
            let coords = common::value_coords(a);
            let target = common::block_of_entry(&plan, coords[rng.below(nnz)]);
            (0..nnz)
                .filter(|&k| common::block_of_entry(&plan, coords[k]) == target)
                .collect()
        }
        3 => {
            let m = (1 + nnz / 10).min(nnz);
            rng.sample_indices(nnz, m)
        }
        _ => (0..nnz).collect(),
    }
}

/// Matrix with `a`'s pattern and the given values.
fn with_values(a: &Csc, values: &[f64]) -> Csc {
    Csc::from_parts_unchecked(
        a.n_rows(),
        a.n_cols(),
        a.col_ptr.clone(),
        a.row_idx.clone(),
        values.to_vec(),
    )
}

/// One differential case. `indices` out of range for the (possibly
/// shrunken) matrix are ignored. Returns `Err(reason)` on any mismatch.
fn check_case(seed: u64, n: usize, indices: &[usize]) -> Result<(), String> {
    let a = common::random_matrix_sized(seed, n);
    let nnz = a.nnz();
    let workers = 1 + (seed % 4) as u32;
    let opts = SolveOptions::ours(workers);
    let plan = Arc::new(FactorPlan::build(&a, &opts).unwrap());

    let mut partial = SolverSession::from_plan(plan.clone());
    partial
        .refactorize(&a.values)
        .map_err(|e| format!("base refactorize: {e}"))?;

    let mut cs = ChangeSet::new();
    let mut new_values = a.values.clone();
    for &k in indices {
        if k >= nnz {
            continue; // index from a pre-shrink matrix size
        }
        let v = new_value(seed, k, a.values[k]);
        new_values[k] = v;
        cs.push(k, v);
    }

    let rep = partial
        .refactorize_partial(&cs)
        .map_err(|e| format!("partial refactorize: {e}"))?;
    let total = plan.dag.tasks.len();
    if rep.tasks_executed + rep.tasks_skipped != total {
        return Err(format!(
            "task accounting broken: executed {} + skipped {} != {total}",
            rep.tasks_executed, rep.tasks_skipped
        ));
    }
    if cs.is_empty() && (rep.tasks_executed != 0 || rep.blocks_affected != 0) {
        return Err(format!(
            "empty change set executed {} tasks over {} blocks",
            rep.tasks_executed, rep.blocks_affected
        ));
    }

    let mut full = SolverSession::from_plan(plan.clone());
    full.refactorize(&new_values)
        .map_err(|e| format!("full refactorize: {e}"))?;

    for id in 0..plan.structure.blocks.len() {
        let vp = partial.numeric().block_values(id as u32);
        let vf = full.numeric().block_values(id as u32);
        if vp != vf {
            return Err(format!("factor block {id} diverges (partial vs full)"));
        }
    }

    let b: Vec<f64> = (0..n).map(|i| ((i * 7) % 13) as f64 - 6.0).collect();
    let xp = partial.solve(&b);
    if xp != full.solve(&b) {
        return Err("solve vectors diverge (partial vs full)".into());
    }
    let r = residual(&with_values(&a, &new_values), &xp, &b);
    if r > 1e-6 {
        return Err(format!("residual {r:.3e} after partial refactorize"));
    }
    Ok(())
}

#[test]
fn prop_partial_refactorize_bitwise_equals_full() {
    for case in 0..CASES {
        let mut rng = Prng::new(case.wrapping_mul(0x5DEE_CE66).wrapping_add(11));
        let n = 20 + rng.below(160);
        let a = common::random_matrix_sized(case, n);
        let kind = case % 5;
        let indices = change_indices(case, &a, kind);
        if let Err(msg) = check_case(case, n, &indices) {
            // shrink the matrix size first (bisection), re-deriving the
            // change set at each candidate size...
            let n_min = shrink::minimize_scalar(8, n, |nn| {
                let aa = common::random_matrix_sized(case, nn);
                check_case(case, nn, &change_indices(case, &aa, kind)).is_err()
            });
            let a_min = common::random_matrix_sized(case, n_min);
            let idx_min = change_indices(case, &a_min, kind);
            let (n_rep, idx_base) = if check_case(case, n_min, &idx_min).is_err() {
                (n_min, idx_min)
            } else {
                (n, indices) // non-monotone bisection: keep the original
            };
            // ...then delta-debug the change set down to a minimal core
            let minimal = shrink::minimize_subset(&idx_base, |sub| {
                check_case(case, n_rep, sub).is_err()
            });
            panic!(
                "differential failure (case {case}, kind {kind}): {msg}\n\
                 minimal reproducer: seed={case}, n={n_rep}, workers={}, \
                 change indices={minimal:?}",
                1 + (case % 4)
            );
        }
    }
}

/// Acceptance criterion: a change set confined to one leaf block of a
/// ≥16-block matrix executes strictly fewer tasks than the full DAG and
/// still produces factors bit-identical to a **cold** factorization of
/// the updated matrix.
#[test]
fn leaf_block_change_prunes_tasks_and_matches_cold_factorize() {
    let a = gen::grid2d_laplacian(20, 20); // n = 400
    let opts = SolveOptions {
        blocking: BlockingPolicy::Regular(25), // 16 blocks of 25
        ..SolveOptions::ours(1)
    };
    let plan = Arc::new(FactorPlan::build(&a, &opts).unwrap());
    let nb = plan.structure.nb();
    assert!(nb >= 16, "need a >=16-block grid, got {nb}");

    let mut session = SolverSession::from_plan(plan.clone());
    session.refactorize(&a.values).unwrap();

    // a diagonal A-entry whose permuted row lands in the trailing
    // diagonal block — the leaf/sink of the block dependency DAG
    let p = plan.permutation().as_slice();
    let positions = plan.structure.blocking.positions();
    let last_lo = positions[nb - 1];
    let r = (0..a.n_rows())
        .find(|&i| p[i] >= last_lo && a.value_index(i, i).is_some())
        .expect("diagonal entry in the trailing block");
    let k = a.value_index(r, r).unwrap();
    let bumped = a.values[k] * 1.5;

    let rep = session
        .refactorize_partial(&ChangeSet::from_value_indices([(k, bumped)]))
        .unwrap();
    assert_eq!(rep.blocks_dirty, 1);
    assert_eq!(rep.blocks_affected, 1, "trailing diagonal block is a DAG sink");
    assert!(
        rep.tasks_executed < plan.dag.tasks.len(),
        "pruned run must execute strictly fewer tasks ({} vs {})",
        rep.tasks_executed,
        plan.dag.tasks.len()
    );
    assert!(rep.tasks_skipped > 0);

    // bit-identical to a cold factorization of the updated matrix
    let mut updated = a.clone();
    updated.values[k] = bumped;
    let mut solver = Solver::new(opts);
    let cold = solver.factorize(&updated).unwrap();
    for id in 0..plan.structure.blocks.len() {
        assert_eq!(
            session.numeric().block_values(id as u32),
            cold.factors().numeric.block_values(id as u32),
            "block {id} differs from cold factorization"
        );
    }
    let b: Vec<f64> = (0..400).map(|i| (i % 9) as f64 - 4.0).collect();
    assert_eq!(session.solve(&b), cold.solve(&b));
}

/// A change in the *first* block must invalidate downstream blocks (the
/// opposite extreme of the leaf-block case) and still match bitwise.
#[test]
fn root_block_change_cascades_and_matches_full() {
    let a = gen::grid2d_laplacian(16, 16); // n = 256
    let opts = SolveOptions {
        blocking: BlockingPolicy::Regular(16),
        ..SolveOptions::ours(2)
    };
    let plan = Arc::new(FactorPlan::build(&a, &opts).unwrap());
    let p = plan.permutation().as_slice();
    let positions = plan.structure.blocking.positions();
    let first_hi = positions[1];
    let r = (0..a.n_rows())
        .find(|&i| p[i] < first_hi && a.value_index(i, i).is_some())
        .expect("diagonal entry in the leading block");
    let k = a.value_index(r, r).unwrap();

    let mut session = SolverSession::from_plan(plan.clone());
    session.refactorize(&a.values).unwrap();
    let mut new_values = a.values.clone();
    new_values[k] *= 1.25;
    let rep = session
        .refactorize_partial(&ChangeSet::from_value_indices([(k, new_values[k])]))
        .unwrap();
    assert_eq!(rep.blocks_dirty, 1);
    assert!(
        rep.blocks_affected > 1,
        "a leading-block change must cascade (affected {})",
        rep.blocks_affected
    );

    let mut full = SolverSession::from_plan(plan.clone());
    full.refactorize(&new_values).unwrap();
    for id in 0..plan.structure.blocks.len() {
        assert_eq!(
            session.numeric().block_values(id as u32),
            full.numeric().block_values(id as u32),
            "block {id}"
        );
    }
}

/// A sequence of partial refactorizations (accumulating changes) stays
/// bit-identical to full refactorizations of the running values.
#[test]
fn accumulated_partial_steps_track_full_refactorize() {
    let a = common::random_matrix_sized(77, 90);
    let opts = SolveOptions::ours(2);
    let plan = Arc::new(FactorPlan::build(&a, &opts).unwrap());
    let mut inc = SolverSession::from_plan(plan.clone());
    inc.refactorize(&a.values).unwrap();
    let mut values = a.values.clone();
    let mut rng = Prng::new(0xACC);
    for step in 0..6 {
        let mut cs = ChangeSet::new();
        for _ in 0..(1 + rng.below(4)) {
            let k = rng.below(values.len());
            values[k] *= 1.0 + 0.03 * rng.signed_unit();
            cs.push(k, values[k]);
        }
        inc.refactorize_partial(&cs).unwrap();
        let mut full = SolverSession::from_plan(plan.clone());
        full.refactorize(&values).unwrap();
        for id in 0..plan.structure.blocks.len() {
            assert_eq!(
                inc.numeric().block_values(id as u32),
                full.numeric().block_values(id as u32),
                "step {step}, block {id}"
            );
        }
    }
    assert_eq!(inc.refactor_count(), 7);
}

// ---- transpose solves: differential check against a dense oracle ----

#[test]
fn solve_transpose_matches_dense_oracle() {
    let cases: Vec<Csc> = vec![
        gen::grid2d_laplacian(5, 5),
        gen::tridiagonal(30),
        gen::directed_graph(40, 3, 5),
        gen::circuit_bbd(gen::CircuitParams { n: 60, ..Default::default() }),
        common::random_matrix_sized(9, 35),
    ];
    for (ci, a) in cases.iter().enumerate() {
        let n = a.n_rows();
        let mut rng = Prng::new(0x7A + ci as u64);
        let b: Vec<f64> = (0..n).map(|_| rng.signed_unit() * 2.0).collect();
        let want = common::dense_solve_transpose(a, &b);

        // one-shot path: Factorization::solve_transpose → trisolve_t
        let mut solver = Solver::new(SolveOptions::ours(1));
        let f = solver.factorize(a).unwrap();
        let got = f.solve_transpose(&b);
        for (i, (g, w)) in got.iter().zip(&want).enumerate() {
            assert!(
                (g - w).abs() < 1e-7 * w.abs().max(1.0),
                "case {ci}, x[{i}]: blocked {g} vs dense {w}"
            );
        }

        // session path: SolverSession::solve_transpose over the same factors
        let plan = Arc::new(FactorPlan::build(a, &SolveOptions::ours(2)).unwrap());
        let mut s = SolverSession::from_plan(plan);
        s.refactorize(&a.values).unwrap();
        let got2 = s.solve_transpose(&b);
        for (i, (g, w)) in got2.iter().zip(&want).enumerate() {
            assert!(
                (g - w).abs() < 1e-7 * w.abs().max(1.0),
                "case {ci} (session), x[{i}]: blocked {g} vs dense {w}"
            );
        }
    }
}

#[test]
fn solve_transpose_after_partial_refactorize_matches_dense_oracle() {
    let a = common::random_matrix_sized(21, 50);
    let plan = Arc::new(FactorPlan::build(&a, &SolveOptions::ours(1)).unwrap());
    let mut s = SolverSession::from_plan(plan);
    s.refactorize(&a.values).unwrap();
    let k = a.value_index(10, 10).expect("diagonal entry");
    let mut new_values = a.values.clone();
    new_values[k] *= 1.75;
    s.refactorize_partial(&ChangeSet::from_value_indices([(k, new_values[k])]))
        .unwrap();
    let updated = with_values(&a, &new_values);
    let b: Vec<f64> = (0..50).map(|i| ((i * 3) % 7) as f64 - 3.0).collect();
    let want = common::dense_solve_transpose(&updated, &b);
    let got = s.solve_transpose(&b);
    for (i, (g, w)) in got.iter().zip(&want).enumerate() {
        assert!(
            (g - w).abs() < 1e-7 * w.abs().max(1.0),
            "x[{i}]: blocked {g} vs dense {w}"
        );
    }
}

// ---- the shrinker itself ----

#[test]
fn shrinker_isolates_minimal_failing_pair() {
    let items: Vec<usize> = (0..40).collect();
    let minimal = shrink::minimize_subset(&items, |s| s.contains(&7) && s.contains(&23));
    assert_eq!(minimal, vec![7, 23]);
}

#[test]
fn shrinker_returns_empty_when_items_are_irrelevant() {
    let items: Vec<usize> = (0..10).collect();
    let minimal = shrink::minimize_subset(&items, |_| true);
    assert!(minimal.is_empty());
}

#[test]
fn shrinker_keeps_single_culprit() {
    let items: Vec<u32> = (0..33).collect();
    let minimal = shrink::minimize_subset(&items, |s| s.contains(&31));
    assert_eq!(minimal, vec![31]);
}

#[test]
fn scalar_shrinker_bisects_to_threshold() {
    assert_eq!(shrink::minimize_scalar(0, 100, |x| x >= 37), 37);
    assert_eq!(shrink::minimize_scalar(5, 5, |_| true), 5);
}

// ---- determinism under work stealing ----

/// Stealing moves tasks between workers but never reorders the per-block
/// update chains, so every executor size — and every repetition, with
/// whatever steal schedule the OS produces — must reproduce
/// `factorize_sequential` bit for bit. Runs each seeded matrix on 1-, 2-
/// and 8-worker executors, several epochs each, under both the
/// persistent work-stealing scheduler and the spawn-per-call baseline.
#[test]
fn determinism_under_stealing_matches_sequential_bitwise() {
    use sparselu::coordinator::Scheduler;
    use sparselu::numeric::factor::{factorize_sequential, CpuDense};

    for seed in [3u64, 11, 27] {
        let a = common::random_matrix_sized(seed, 140);
        for workers in [1u32, 2, 8] {
            let opts = SolveOptions::ours(workers);
            let plan = Arc::new(FactorPlan::build(&a, &opts).unwrap());
            let seq =
                factorize_sequential(plan.structure.clone(), &opts.kernels, &CpuDense).unwrap();
            let mut session = SolverSession::from_plan(plan.clone());
            for sched in [Scheduler::Persistent, Scheduler::SpawnPerCall] {
                session.set_scheduler(sched);
                for round in 0..3 {
                    session.refactorize(&a.values).unwrap();
                    for id in 0..plan.structure.blocks.len() {
                        assert_eq!(
                            session.numeric().block_values(id as u32),
                            seq.numeric.block_values(id as u32),
                            "block {id} differs from sequential \
                             (seed={seed}, workers={workers}, {sched:?}, round={round})"
                        );
                    }
                }
            }
        }
    }
}
