//! Property-based tests over randomized inputs (seeded xoshiro PRNG —
//! the proptest crate is unavailable offline, so properties are checked
//! across a seed sweep; failures print the seed for reproduction).

mod common;

use common::blocks;
use sparselu::blocking::{irregular_blocking, DiagFeature, IrregularParams};
use sparselu::numeric::{dense, tiled};
use sparselu::ordering::Permutation;
use sparselu::solver::{SolveOptions, Solver};
use sparselu::sparse::{gen, residual, Coo, Csc};
use sparselu::symbolic;
use sparselu::util::Prng;

const SEEDS: u64 = 24;

/// Random diagonally-dominant sparse matrix with random size/density.
fn random_matrix(seed: u64) -> Csc {
    let mut rng = Prng::new(seed);
    let n = 20 + rng.below(280);
    let per_row = 1 + rng.below(5);
    let mut coo = Coo::with_capacity(n, n, n * (per_row + 1));
    for i in 0..n {
        for _ in 0..per_row {
            let j = rng.below(n);
            if j != i {
                coo.push(i, j, rng.signed_unit());
            }
        }
    }
    // diagonal dominance
    let m = coo.to_csc();
    let mut row_abs = vec![0.0; n];
    for j in 0..n {
        for (i, v) in m.col(j) {
            if i != j {
                row_abs[i] += v.abs();
            }
        }
    }
    let mut out = Coo::with_capacity(n, n, m.nnz() + n);
    for j in 0..n {
        for (i, v) in m.col(j) {
            if i != j {
                out.push(i, j, v);
            }
        }
    }
    for i in 0..n {
        out.push(i, i, row_abs[i] + 1.0);
    }
    out.to_csc()
}

#[test]
fn prop_factorize_solve_small_residual() {
    for seed in 0..SEEDS {
        let a = random_matrix(seed);
        let n = a.n_rows();
        let workers = 1 + (seed % 4) as u32;
        let mut solver = Solver::new(SolveOptions::ours(workers));
        let f = solver.factorize(&a).unwrap_or_else(|e| panic!("seed {seed}: {e}"));
        let mut rng = Prng::new(seed ^ 0xB);
        let b: Vec<f64> = (0..n).map(|_| rng.signed_unit() * 3.0).collect();
        let x = f.solve(&b);
        let r = residual(&a, &x, &b);
        assert!(r < 1e-8, "seed {seed}: residual {r}");
    }
}

#[test]
fn prop_lu_product_reconstructs_permuted_a() {
    // check L·U == P·A·Pᵀ entry-wise via the factored CSC
    for seed in 0..8 {
        let a = random_matrix(seed);
        let n = a.n_rows();
        let mut solver = Solver::new(SolveOptions::ours(1));
        let f = solver.factorize(&a).unwrap();
        let pa = a.permute_sym(f.permutation().as_slice());
        let lu = f.factors().to_csc();
        // multiply L*U densely (matrices are small)
        let mut dense = vec![vec![0.0; n]; n];
        for j in 0..n {
            for (i, v) in lu.col(j) {
                dense[i][j] = v;
            }
        }
        for i in 0..n {
            for j in 0..n {
                let kmax = i.min(j);
                let mut s = 0.0;
                for k in 0..=kmax {
                    let l = if i == k { 1.0 } else { dense[i][k] };
                    let u = dense[k][j];
                    if i >= k {
                        s += l * u;
                    }
                }
                let want = pa.get(i, j);
                assert!(
                    (s - want).abs() < 1e-8 * want.abs().max(1.0),
                    "seed {seed} ({i},{j}): {s} vs {want}"
                );
            }
        }
    }
}

#[test]
fn prop_transpose_involution() {
    for seed in 0..SEEDS {
        let a = random_matrix(seed);
        assert_eq!(a.transpose().transpose(), a, "seed {seed}");
    }
}

#[test]
fn prop_permutation_roundtrip() {
    for seed in 0..SEEDS {
        let mut rng = Prng::new(seed);
        let n = 5 + rng.below(200);
        let mut order: Vec<usize> = (0..n).collect();
        rng.shuffle(&mut order);
        let p = Permutation::from_order(&order);
        assert!(p.is_valid());
        let v: Vec<usize> = (0..n).collect();
        let w = p.permute_vec(&v);
        let back = p.inverse().permute_vec(&w);
        assert_eq!(v, back, "seed {seed}");
    }
}

#[test]
fn prop_symmetric_permutation_preserves_values_multiset() {
    for seed in 0..SEEDS {
        let a = random_matrix(seed);
        let n = a.n_cols();
        let mut rng = Prng::new(seed ^ 0x5);
        let mut order: Vec<usize> = (0..n).collect();
        rng.shuffle(&mut order);
        let p = Permutation::from_order(&order);
        let b = a.permute_sym(p.as_slice());
        assert_eq!(a.nnz(), b.nnz(), "seed {seed}");
        let mut va: Vec<u64> = a.values.iter().map(|v| v.to_bits()).collect();
        let mut vb: Vec<u64> = b.values.iter().map(|v| v.to_bits()).collect();
        va.sort_unstable();
        vb.sort_unstable();
        assert_eq!(va, vb, "seed {seed}");
    }
}

#[test]
fn prop_diag_feature_matches_bruteforce() {
    for seed in 0..SEEDS {
        let a = random_matrix(seed).plus_transpose_pattern();
        let f = DiagFeature::from_csc(&a);
        let n = a.n_cols();
        // brute-force at 5 probe points
        let mut rng = Prng::new(seed ^ 0x77);
        for _ in 0..5 {
            let k = 1 + rng.below(n);
            let mut cnt = 0u64;
            for j in 0..k {
                for &i in a.col_rows(j) {
                    if i < k {
                        cnt += 1;
                    }
                }
            }
            assert_eq!(f.blockptr[k], cnt, "seed {seed} k={k}");
        }
    }
}

#[test]
fn prop_irregular_blocking_partitions() {
    for seed in 0..SEEDS {
        let a = random_matrix(seed);
        let sym = symbolic::analyze(&a);
        let ldu = sym.ldu_pattern(&a).unwrap();
        let curve = DiagFeature::from_csc(&ldu).curve();
        let b = irregular_blocking(&curve, &IrregularParams::default());
        let pos = b.positions();
        assert_eq!(pos[0], 0, "seed {seed}");
        assert_eq!(*pos.last().unwrap(), a.n_cols(), "seed {seed}");
        assert!(pos.windows(2).all(|w| w[0] < w[1]), "seed {seed}");
        // block_of consistent with positions
        let mut rng = Prng::new(seed ^ 0x9);
        for _ in 0..10 {
            let i = rng.below(a.n_cols());
            let k = b.block_of(i);
            assert!(pos[k] <= i && i < pos[k + 1], "seed {seed} i={i}");
        }
    }
}

#[test]
fn prop_symbolic_fill_monotone_under_extra_entries() {
    // adding entries never reduces fill
    for seed in 0..12 {
        let a = random_matrix(seed);
        let base = symbolic::analyze(&a).nnz_ldu();
        // add a few extra entries
        let n = a.n_cols();
        let mut rng = Prng::new(seed ^ 0x3);
        let mut coo = Coo::with_capacity(n, n, a.nnz() + 10);
        for j in 0..n {
            for (i, v) in a.col(j) {
                coo.push(i, j, v);
            }
        }
        for _ in 0..10 {
            let i = rng.below(n);
            let j = rng.below(n);
            if i != j && a.get(i, j) == 0.0 {
                coo.push(i, j, 0.01);
            }
        }
        let denser = coo.to_csc();
        let more = symbolic::analyze(&denser).nnz_ldu();
        assert!(more >= base, "seed {seed}: {more} < {base}");
    }
}

#[test]
fn prop_coo_duplicate_sum() {
    for seed in 0..SEEDS {
        let mut rng = Prng::new(seed);
        let n = 5 + rng.below(40);
        let mut coo = Coo::new(n, n);
        let mut dense = vec![0.0f64; n * n];
        for _ in 0..200 {
            let i = rng.below(n);
            let j = rng.below(n);
            let v = rng.signed_unit();
            coo.push(i, j, v);
            dense[j * n + i] += v;
        }
        let m = coo.to_csc();
        for j in 0..n {
            for i in 0..n {
                let want = dense[j * n + i];
                let got = m.get(i, j);
                assert!((got - want).abs() < 1e-12, "seed {seed} ({i},{j})");
            }
        }
    }
}

#[test]
fn prop_tiled_kernels_bitwise_match_scalar() {
    // The deep shape/density sweep lives in tests/kernel_differential.rs;
    // this property re-draws fresh random cases every seed so the bitwise
    // contract is also exercised from the proptest harness's seed space.
    for seed in 0..SEEDS {
        let (m, k, n, d) = blocks::random_gemm_case(seed ^ 0x6EE, 32);
        let a = blocks::panel(m, k, d, seed ^ 0x1);
        let b = blocks::panel(k, n, d, seed ^ 0x2);
        let c = blocks::panel(m, n, 1.0, seed ^ 0x3);
        let mut s = c.clone();
        let mut t = c;
        dense::gemm_update(&mut s, &a, &b, m, k, n);
        tiled::gemm_update(&mut t, &a, &b, m, k, n);
        assert!(
            blocks::bits_equal(&s, &t).is_none(),
            "seed {seed}: tiled gemm {m}x{k}x{n} density {d} diverges from scalar"
        );

        let (gn, gd) = blocks::random_getrf_case(seed ^ 0x7EE, 40);
        let g = blocks::dd_block(gn, gd, seed ^ 0x4);
        let mut gs = g.clone();
        let mut gt = g;
        dense::getrf_in_place(&mut gs, gn).unwrap_or_else(|e| panic!("seed {seed}: {e}"));
        tiled::getrf_in_place(&mut gt, gn).unwrap_or_else(|e| panic!("seed {seed}: {e}"));
        assert!(
            blocks::bits_equal(&gs, &gt).is_none(),
            "seed {seed}: tiled getrf {gn}x{gn} density {gd} diverges from scalar"
        );
    }
}

#[test]
fn prop_mindegree_no_worse_than_natural_on_grids() {
    for seed in 0..6 {
        let mut rng = Prng::new(seed);
        let nx = 6 + rng.below(10);
        let ny = 6 + rng.below(10);
        let a = gen::grid2d_laplacian(nx, ny);
        let nat = symbolic::analyze(&a).nnz_ldu();
        let p = sparselu::ordering::order(&a, sparselu::ordering::OrderingMethod::MinDegree);
        let md = symbolic::analyze(&a.permute_sym(p.as_slice())).nnz_ldu();
        assert!(md <= nat, "grid {nx}x{ny}: md {md} nat {nat}");
    }
}
