//! Chaos suite: drives the serving stack through seeded fault storms
//! (`sparselu::fault`) and asserts the containment contract end to end —
//! every injected fault surfaces as exactly one typed per-request error
//! or one counted transparent rescue, pools and executors stay reusable
//! afterwards, a quarantined tenant revives in the background, and
//! post-recovery traffic is bit-identical to a fault-free oracle.
//!
//! Fault state is process-global, so every test that executes factor
//! tasks holds `FAULT_LOCK`: an armed plan in one test must neither
//! inject into a neighbor nor have its one-shot sequence numbers stolen
//! by a neighbor's task executions.

mod common;

use sparselu::fault::{self, FaultGuard, FaultPlan};
use sparselu::numeric::FactorError;
use sparselu::serve::{
    persist, Batcher, Request, Router, RouterConfig, ServeError, SessionPool, TenantHealth,
    TenantId,
};
use sparselu::session::{ChangeSet, FactorPlan, PlanCache, SolverSession};
use sparselu::solver::SolveOptions;
use sparselu::sparse::{gen, Csc};
use sparselu::util::Prng;
use std::path::PathBuf;
use std::sync::{Arc, Mutex, MutexGuard};
use std::time::{Duration, Instant};

static FAULT_LOCK: Mutex<()> = Mutex::new(());

/// Serialize fault-global tests; a panicking neighbor must not poison us.
fn lock() -> MutexGuard<'static, ()> {
    FAULT_LOCK.lock().unwrap_or_else(|p| p.into_inner())
}

fn plan_for(a: &Csc) -> Arc<FactorPlan> {
    Arc::new(FactorPlan::build(a, &SolveOptions::ours(1)).unwrap())
}

fn tmp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("sparselu-chaos-{tag}-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn health_of(router: &Router, id: TenantId) -> TenantHealth {
    router.health().into_iter().find(|h| h.tenant == id).expect("tenant has a live shard")
}

/// Submit, retrying briefly while the tenant's quarantine lifts.
fn submit_retry(router: &Router, id: TenantId, mk: impl Fn() -> Request) {
    for _ in 0..5000 {
        match router.submit(id, mk()) {
            Ok(()) => return,
            Err(ServeError::TenantQuarantined { .. }) => {
                std::thread::sleep(Duration::from_millis(1));
            }
            Err(e) => panic!("submit failed: {e}"),
        }
    }
    panic!("tenant {id:?} stayed quarantined");
}

// ---------------------------------------------------------------------
// exact accounting: one injection, one typed error
// ---------------------------------------------------------------------

#[test]
fn each_injected_fault_surfaces_as_exactly_one_typed_error() {
    let _l = lock();
    let a = gen::grid2d_laplacian(9, 9);
    let plan = plan_for(&a);
    let pool = SessionPool::new(plan, 2);
    let rhs: Vec<f64> = (0..a.n_rows()).map(|i| (i % 5) as f64 - 2.0).collect();

    // clean probe: the DAG's task count (to place a mid-run panic) and
    // the oracle solution every post-fault serve must bit-match
    let (tasks, want_x) = {
        let mut session = pool.checkout();
        let rep = session.refactorize(&a.values).unwrap();
        (rep.tasks_executed, session.solve(&rhs))
    };
    assert!(tasks >= 2, "matrix too small to host a mid-run fault");

    type Check = fn(&ServeError) -> bool;
    let scenarios: Vec<(FaultPlan, Check, &str)> = vec![
        (
            FaultPlan::seeded(1).panic_at_task(tasks as u64 - 1),
            |e| matches!(e, ServeError::Factor(FactorError::TaskPanic)),
            "kernel panic",
        ),
        (
            FaultPlan::seeded(2).nan_at_kernel(0),
            |e| matches!(e, ServeError::Factor(FactorError::NonFinite { .. })),
            "nan poisoning",
        ),
        (
            FaultPlan::seeded(3).zero_pivot_at_getrf(0),
            |e| matches!(e, ServeError::Factor(FactorError::Kernel(_))),
            "forced zero pivot",
        ),
    ];
    for (fp, check, label) in scenarios {
        let mut batcher = Batcher::new(8);
        batcher.submit(Request::Refactorize { values: a.values.clone() }).unwrap();
        let outcomes = {
            let _g = FaultGuard::new(fp);
            let mut session = pool.checkout();
            let out = batcher.drain(&mut session);
            assert_eq!(
                fault::counters().erroring(),
                1,
                "{label}: exactly one erroring injection fired"
            );
            out
        };
        assert_eq!(outcomes.len(), 1);
        let err = outcomes[0].as_ref().unwrap_err();
        assert!(check(err), "{label}: unexpected error {err:?}");
        assert_eq!(batcher.degraded_runs(), 0, "{label}: a full refactorize is never rescued");

        // containment: the same pool serves the very next request, and
        // the answer bit-matches the fault-free oracle
        let mut batcher = Batcher::new(8);
        batcher.submit(Request::Refactorize { values: a.values.clone() }).unwrap();
        batcher.submit(Request::Solve { rhs: rhs.clone() }).unwrap();
        let mut session = pool.checkout();
        let outcomes = batcher.drain(&mut session);
        assert!(
            outcomes.iter().all(|o| o.is_ok()),
            "{label}: pool unusable after the fault: {outcomes:?}"
        );
        assert_eq!(
            outcomes[1].as_ref().unwrap().solution.as_ref().unwrap(),
            &want_x,
            "{label}: post-fault serve diverges from the fault-free oracle"
        );
    }
    assert_eq!(pool.stats().in_use, 0, "every session checked back in");
    assert!(!fault::enabled(), "guards disarmed injection on drop");
}

#[test]
fn stalls_delay_but_never_error_and_factors_stay_bit_identical() {
    let _l = lock();
    let a = gen::grid2d_laplacian(7, 7);
    let plan = plan_for(&a);
    let mut oracle = SolverSession::from_plan(plan.clone());
    oracle.refactorize(&a.values).unwrap();

    let mut session = SolverSession::from_plan(plan.clone());
    let _g = FaultGuard::new(FaultPlan::seeded(9).stall_at_task(0).stall_rate(0.25, 50));
    session.refactorize(&a.values).unwrap();
    let c = fault::counters();
    assert!(c.stalls >= 1, "the one-shot stall alone guarantees a firing");
    assert_eq!(c.erroring(), 0, "stalls only delay");
    for id in 0..plan.structure.blocks.len() {
        assert_eq!(
            session.numeric().block_values(id as u32),
            oracle.numeric().block_values(id as u32),
            "block {id}: stalls changed numeric results"
        );
    }
}

// ---------------------------------------------------------------------
// degradation ladder: faulted partials retried full, once, counted
// ---------------------------------------------------------------------

#[test]
fn faulted_partial_refactorize_is_rescued_as_full_and_counted_degraded() {
    let _l = lock();
    let a = gen::grid2d_laplacian(8, 8);
    let plan = plan_for(&a);
    let k = a.value_index(20, 20).unwrap();
    let stamped = {
        let mut v = a.values.clone();
        v[k] *= 1.5;
        v
    };
    let rhs: Vec<f64> = (0..a.n_rows()).map(|i| (i % 7) as f64 - 3.0).collect();
    // oracle: the stamped matrix factored fresh through the full path —
    // the rescue's whole-matrix rescatter must land exactly here
    let mut oracle = SolverSession::from_plan(plan.clone());
    oracle.refactorize(&stamped).unwrap();
    let want = oracle.solve(&rhs);

    let faults = [
        (FaultPlan::seeded(11).panic_at_task(0), "panic in partial replay"),
        (FaultPlan::seeded(12).nan_at_kernel(0), "nan in partial replay"),
    ];
    for (fp, label) in faults {
        let mut session = SolverSession::from_plan(plan.clone());
        session.refactorize(&a.values).unwrap();
        // threshold 1.0 forces the partial route, where the ladder lives
        let mut batcher = Batcher::new(8).with_partial_threshold(1.0);
        batcher
            .submit(Request::Stamp { changes: ChangeSet::from_value_indices([(k, stamped[k])]) })
            .unwrap();
        let outcomes = {
            let _g = FaultGuard::new(fp);
            let out = batcher.drain(&mut session);
            assert_eq!(fault::counters().erroring(), 1, "{label}: one injection fired");
            out
        };
        let rep = match &outcomes[0] {
            Ok(rep) => rep,
            Err(e) => panic!("{label}: rescue failed instead of absorbing the fault: {e}"),
        };
        assert!(rep.degraded, "{label}: rescue must be visible on the report");
        assert!(!rep.went_partial, "{label}: the rescued execution ran the full path");
        assert_eq!(batcher.degraded_runs(), 1, "{label}: one rescue per injected fault");
        assert_eq!(session.solve(&rhs), want, "{label}: rescued factors diverge from oracle");
    }
}

// ---------------------------------------------------------------------
// the tentpole scenario: combined storm against a 4-tenant router
// ---------------------------------------------------------------------

#[test]
fn router_serves_through_combined_storm_quarantines_and_recovers_bit_identical() {
    let _l = lock();
    let mats = [
        gen::grid2d_laplacian(8, 8),
        gen::grid2d_laplacian(8, 9),
        gen::grid2d_laplacian(9, 9),
        gen::grid2d_laplacian(9, 10),
    ];
    let router = Router::new(
        SolveOptions::ours(1),
        RouterConfig {
            max_shards: 4,
            plan_cache_capacity: 8,
            shard_queue: 16,
            ..RouterConfig::default()
        },
    );
    let ids: Vec<TenantId> = mats.iter().map(|a| router.admit(a).unwrap()).collect();
    let rhs: Vec<Vec<f64>> = mats
        .iter()
        .map(|a| (0..a.n_rows()).map(|i| (i % 7) as f64 - 3.0).collect())
        .collect();

    // phase 0 — clean baseline: per-tenant DAG task counts (to aim the
    // one-shot triggers) and the solutions recovery must reproduce
    let mut tasks = Vec::new();
    let mut baseline = Vec::new();
    for ((a, id), r) in mats.iter().zip(&ids).zip(&rhs) {
        router.submit(*id, Request::Refactorize { values: a.values.clone() }).unwrap();
        router.submit(*id, Request::Solve { rhs: r.clone() }).unwrap();
        let out = router.drain_tenant(*id).unwrap();
        tasks.push(out[0].as_ref().unwrap().tasks_executed);
        baseline.push(out[1].as_ref().unwrap().solution.clone().unwrap());
    }

    // phase 1 — combined storm, aimed deterministically: the stall and
    // the panic land in tenant 0's refactorize (the panic on its last
    // task, so it executes tasks[0]-1 kernels first), the NaN on tenant
    // 1's last kernel dispatch, and tenant 0's plan file is corrupted on
    // save. Drains run sequentially on this thread, so the global
    // sequence numbers are exact.
    let panic_seq = tasks[0] as u64 - 1;
    let nan_seq = (tasks[0] - 1 + tasks[1] - 1) as u64;
    let dir = tmp_dir("storm");
    {
        let _g = FaultGuard::new(
            FaultPlan::seeded(0xC4A05)
                .stall_at_task(0)
                .panic_at_task(panic_seq)
                .nan_at_kernel(nan_seq)
                .corrupt_persist_at(0),
        );
        // the crash-safe save itself succeeds; the checksummed loader is
        // what rejects the corrupt bytes — the process never dies
        let path = persist::save_plan_to_dir(&router.plan_of(ids[0]).unwrap(), &dir).unwrap();
        assert!(persist::load_plan(&path).is_err(), "corrupt plan must not load");

        for (a, id) in mats.iter().zip(&ids) {
            router.submit(*id, Request::Refactorize { values: a.values.clone() }).unwrap();
        }
        let mut fault_errors = 0u64;
        for (i, id) in ids.iter().enumerate() {
            let out = router.drain_tenant(*id).unwrap();
            assert_eq!(out.len(), 1);
            match (i, out[0].as_ref()) {
                (0, Err(ServeError::Factor(FactorError::TaskPanic))) => fault_errors += 1,
                (1, Err(ServeError::Factor(FactorError::NonFinite { .. }))) => fault_errors += 1,
                (_, Ok(_)) if i >= 2 => {} // unfaulted tenants keep serving
                (_, other) => panic!("tenant {i}: unexpected outcome {other:?}"),
            }
        }
        let c = fault::counters();
        assert_eq!((c.panics, c.nans, c.persist), (1, 1, 1));
        assert!(c.stalls >= 1);
        assert_eq!(c.erroring(), fault_errors, "every erroring injection surfaced exactly once");
    }
    std::fs::remove_dir_all(&dir).ok();

    // the non-finite factors quarantined tenant 1 — and only tenant 1 —
    // and the background rebuild lifts it
    assert_eq!(health_of(&router, ids[1]).quarantines, 1);
    for &i in &[0usize, 2, 3] {
        let h = health_of(&router, ids[i]);
        assert_eq!((h.quarantines, h.quarantined), (0, false), "quarantine leaked to tenant {i}");
    }
    let deadline = Instant::now() + Duration::from_secs(10);
    while health_of(&router, ids[1]).quarantined {
        assert!(Instant::now() < deadline, "quarantine never lifted");
        std::thread::sleep(Duration::from_millis(1));
    }
    assert_eq!(health_of(&router, ids[1]).quarantine_revivals, 1);

    // phase 2 — recovery: identical traffic, bitwise-identical answers
    for (i, ((a, id), r)) in mats.iter().zip(&ids).zip(&rhs).enumerate() {
        submit_retry(&router, *id, || Request::Refactorize { values: a.values.clone() });
        submit_retry(&router, *id, || Request::Solve { rhs: r.clone() });
        let out = router.drain_tenant(*id).unwrap();
        for o in &out {
            assert!(o.is_ok(), "tenant {i}: post-recovery request failed: {o:?}");
        }
        let x = out[1].as_ref().unwrap().solution.as_ref().unwrap();
        assert_eq!(x.len(), baseline[i].len());
        for (got, want) in x.iter().zip(&baseline[i]) {
            assert_eq!(
                got.to_bits(),
                want.to_bits(),
                "tenant {i}: post-recovery solution is not bit-identical"
            );
        }
    }
    for h in router.health() {
        assert_eq!(h.sessions_in_use, 0, "tenant {:?} leaked a session", h.tenant);
    }
}

// ---------------------------------------------------------------------
// persist corruption: skipped at warm-up, never fatal
// ---------------------------------------------------------------------

#[test]
fn corrupt_persisted_plan_is_skipped_at_warmup_not_fatal() {
    let _l = lock();
    let a = gen::grid2d_laplacian(7, 7);
    let b = gen::grid2d_laplacian(7, 8);
    let dir = tmp_dir("warm");
    {
        let _g = FaultGuard::new(FaultPlan::seeded(5).corrupt_persist_at(0).truncate_persist());
        persist::save_plan_to_dir(&plan_for(&a), &dir).unwrap();
        assert_eq!(fault::counters().persist, 1);
    }
    persist::save_plan_to_dir(&plan_for(&b), &dir).unwrap(); // clean

    let mut cache = PlanCache::new(4);
    let warm = cache.warm_from_dir(&dir).unwrap();
    assert_eq!(warm.loaded, 1, "the clean plan warms");
    assert_eq!(warm.skipped.len(), 1, "the truncated plan is skipped, not fatal");

    // the crash-safe save never leaves temp droppings behind
    let leftovers = std::fs::read_dir(&dir)
        .unwrap()
        .filter_map(|e| e.ok())
        .filter(|e| e.path().extension().and_then(|x| x.to_str()) == Some("tmp"))
        .count();
    assert_eq!(leftovers, 0);
    std::fs::remove_dir_all(&dir).ok();
}

// ---------------------------------------------------------------------
// request lifetimes: deadlines and bounded checkouts
// ---------------------------------------------------------------------

#[test]
fn deadlines_and_checkout_timeouts_fail_cleanly() {
    let _l = lock();
    let a = gen::grid2d_laplacian(7, 7);
    let pool = SessionPool::new(plan_for(&a), 1);

    // exhausted pool: a bounded checkout gives up instead of blocking
    let held = pool.checkout();
    assert!(pool.checkout_timeout(Duration::from_millis(5)).is_none());
    drop(held);
    let mut session = pool.checkout_timeout(Duration::from_millis(5)).expect("pool is free");
    session.refactorize(&a.values).unwrap();

    // an expired deadline fails before execution; a live one never blocks
    let rhs = vec![1.0; a.n_rows()];
    let mut batcher = Batcher::new(8);
    batcher.submit_with_deadline(Request::Solve { rhs: rhs.clone() }, Instant::now()).unwrap();
    batcher
        .submit_with_deadline(Request::Solve { rhs }, Instant::now() + Duration::from_secs(60))
        .unwrap();
    std::thread::sleep(Duration::from_millis(2));
    let outcomes = batcher.drain(&mut session);
    assert!(matches!(outcomes[0], Err(ServeError::DeadlineExceeded { .. })));
    assert!(outcomes[1].is_ok(), "a live deadline never blocks execution");
}

// ---------------------------------------------------------------------
// property tests: random plans x random scripts (proptest crate is
// unavailable offline; same hand-rolled style as tests/proptests.rs)
// ---------------------------------------------------------------------

#[test]
fn proptest_random_one_shot_plans_keep_exact_fault_accounting() {
    let _l = lock();
    for iter in 0..6u64 {
        let mut rng = Prng::new(0xBA1A_5EED ^ iter);
        let a = common::random_matrix_sized(0xFACE + iter, 30 + rng.below(30));
        let plan = plan_for(&a);
        let mut session = SolverSession::from_plan(plan.clone());
        session.refactorize(&a.values).unwrap();
        let mut batcher = Batcher::new(4).with_partial_threshold(1.0);

        // one random erroring one-shot (two erroring faults colliding in
        // one run would merge into a single surfaced error, so exactness
        // demands a single trigger), plus harmless random stalls
        let seq = rng.below(40) as u64;
        let fp = match rng.below(3) {
            0 => FaultPlan::seeded(iter).panic_at_task(seq),
            1 => FaultPlan::seeded(iter).nan_at_kernel(seq),
            _ => FaultPlan::seeded(iter).zero_pivot_at_getrf(seq),
        };
        let fp = if rng.below(2) == 0 { fp.stall_rate(0.05, 20) } else { fp };

        let mut surfaced = 0u64;
        {
            let _g = FaultGuard::new(fp);
            for step in 0..12u64 {
                let req = match rng.below(4) {
                    0 => Request::Refactorize {
                        values: common::perturbed(&a, iter * 100 + step).values,
                    },
                    1 => {
                        let d = rng.below(a.n_rows());
                        let k = a.value_index(d, d).expect("full diagonal");
                        Request::Stamp {
                            changes: ChangeSet::from_value_indices([(
                                k,
                                a.values[k] * (1.0 + 0.1 * rng.f64()),
                            )]),
                        }
                    }
                    _ => Request::Solve {
                        rhs: (0..a.n_rows()).map(|_| rng.signed_unit()).collect(),
                    },
                };
                batcher.submit(req).unwrap();
                let mut out = batcher.drain(&mut session);
                assert_eq!(out.len(), 1);
                match out.pop().unwrap() {
                    Ok(_) => {}
                    Err(ServeError::Factor(_)) => surfaced += 1,
                    // collateral of a failed refactorize, not an injection
                    Err(ServeError::NotFactored) => {}
                    Err(e) => panic!("iter {iter} step {step}: unexpected error {e}"),
                }
            }
            assert_eq!(
                fault::counters().erroring(),
                surfaced + batcher.degraded_runs(),
                "iter {iter}: injected must balance surfaced + rescued exactly"
            );
        }

        // reusability: a clean round bit-matches a fresh session
        session.refactorize(&a.values).unwrap();
        let rhs: Vec<f64> = (0..a.n_rows()).map(|i| (i % 3) as f64 - 1.0).collect();
        let mut oracle = SolverSession::from_plan(plan.clone());
        oracle.refactorize(&a.values).unwrap();
        assert_eq!(session.solve(&rhs), oracle.solve(&rhs), "iter {iter}: chaos state leaked");
    }
    assert!(!fault::enabled());
}

#[test]
fn proptest_rate_based_storm_has_no_deadlock_and_recovers() {
    let _l = lock();
    let mats = [gen::grid2d_laplacian(7, 7), gen::grid2d_laplacian(7, 8)];
    let router = Router::new(
        SolveOptions::ours(2),
        RouterConfig {
            max_shards: 2,
            plan_cache_capacity: 4,
            shard_queue: 8,
            checkout_timeout: Some(Duration::from_millis(200)),
            ..RouterConfig::default()
        },
    );
    let ids: Vec<TenantId> = mats.iter().map(|a| router.admit(a).unwrap()).collect();

    {
        let _g = FaultGuard::new(
            FaultPlan::seeded(0x57A6)
                .panic_rate(0.02)
                .nan_rate(0.02)
                .zero_pivot_rate(0.01)
                .stall_rate(0.05, 30),
        );
        // both tenants hammer the router concurrently under the storm;
        // completion of this scope IS the no-deadlock/no-escaped-panic
        // assertion — quarantines, rejections and typed errors are all
        // legal, hangs and unwinds into this thread are not
        std::thread::scope(|scope| {
            for (t, (a, id)) in mats.iter().zip(&ids).enumerate() {
                let router = &router;
                scope.spawn(move || {
                    let mut rng = Prng::new(0xD15EA5E ^ t as u64);
                    for _round in 0..8 {
                        let mut reqs = vec![Request::Refactorize { values: a.values.clone() }];
                        for _ in 0..rng.below(3) {
                            reqs.push(Request::Solve {
                                rhs: (0..a.n_rows()).map(|_| rng.signed_unit()).collect(),
                            });
                        }
                        for req in reqs {
                            match router.submit(*id, req) {
                                Ok(())
                                | Err(ServeError::TenantQuarantined { .. })
                                | Err(ServeError::ShardFull { .. }) => {}
                                Err(e) => panic!("tenant {t}: unexpected admit error {e}"),
                            }
                        }
                        let _ = router.drain_tenant(*id).unwrap();
                    }
                });
            }
        });
    }

    // recovery: any storm quarantine lifts, then a clean round fully
    // succeeds on both tenants and no session stays checked out
    let deadline = Instant::now() + Duration::from_secs(10);
    while router.health().iter().any(|h| h.quarantined) {
        assert!(Instant::now() < deadline, "quarantine never lifted after the storm");
        std::thread::sleep(Duration::from_millis(1));
    }
    for (a, id) in mats.iter().zip(&ids) {
        submit_retry(&router, *id, || Request::Refactorize { values: a.values.clone() });
        submit_retry(&router, *id, || Request::Solve { rhs: vec![1.0; a.n_rows()] });
        let out = router.drain_tenant(*id).unwrap();
        assert_eq!(out.len(), 2);
        assert!(out.iter().all(|o| o.is_ok()), "post-storm round failed: {out:?}");
    }
    for h in router.health() {
        assert_eq!(h.sessions_in_use, 0, "tenant {:?} leaked a session", h.tenant);
    }
}
