//! Integration tests of the observability spine: golden exposition
//! format conformance, concurrent scrape-under-load (monotone counters,
//! no torn histograms), priority shedding order, autoscaled serving
//! bit-matching an unscaled oracle, and the acceptance scrape — a
//! 4-tenant run whose `/metrics` endpoint exposes the full series set.

use sparselu::obs::{self, Autoscaler, MetricsServer, Registry, SloPolicy};
use sparselu::serve::{
    loadgen, MultiTenantConfig, Priority, Request, Router, RouterConfig, ScenarioMix, ServeError,
};
use sparselu::session::{ChangeSet, FactorPlan, SolverSession};
use sparselu::solver::SolveOptions;
use sparselu::sparse::{gen, Csc};
use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

// ---------------------------------------------------------------------
// exposition format
// ---------------------------------------------------------------------

/// Golden line-by-line render: HELP/TYPE ordering, label escaping,
/// cumulative `le` buckets with `_sum`/`_count`, family sort order.
#[test]
fn golden_exposition_format() {
    let r = Registry::new();
    r.gauge("demo_depth", "Current queue depth.", &[]).set(2.5);
    // hairy label value: backslash, quote and newline all need escapes
    r.counter("demo_requests_total", "Requests, by tenant.", &[("tenant", "a\"b\\c\nd")]).add(3);
    let h = r.histogram("demo_wait_seconds", "Queue wait.", &[("tenant", "t1")], &[0.25, 1.0]);
    h.observe(0.25); // le="0.25" is inclusive
    h.observe(0.5);
    h.observe(4.0); // +Inf bucket
    let text = r.render();
    let expected = concat!(
        "# HELP demo_depth Current queue depth.\n",
        "# TYPE demo_depth gauge\n",
        "demo_depth 2.5\n",
        "# HELP demo_requests_total Requests, by tenant.\n",
        "# TYPE demo_requests_total counter\n",
        r#"demo_requests_total{tenant="a\"b\\c\nd"} 3"#,
        "\n",
        "# HELP demo_wait_seconds Queue wait.\n",
        "# TYPE demo_wait_seconds histogram\n",
        "demo_wait_seconds_bucket{tenant=\"t1\",le=\"0.25\"} 1\n",
        "demo_wait_seconds_bucket{tenant=\"t1\",le=\"1\"} 2\n",
        "demo_wait_seconds_bucket{tenant=\"t1\",le=\"+Inf\"} 3\n",
        "demo_wait_seconds_sum{tenant=\"t1\"} 4.75\n",
        "demo_wait_seconds_count{tenant=\"t1\"} 3\n",
    );
    assert_eq!(text, expected);
    let summary = obs::validate(&text).expect("golden text validates");
    assert_eq!(summary.families, 3);
    assert_eq!(summary.samples, 7);
    assert_eq!(summary.series.len(), 3);
}

/// 8 writer threads hammer counters and a histogram while a scraper
/// loops over HTTP: every scrape must validate (cumulative buckets,
/// `_count` == `+Inf` — i.e. no torn histogram reads) and every
/// counter series must be monotone across scrapes.
#[test]
fn concurrent_scrapes_are_valid_and_monotone() {
    let registry = Arc::new(Registry::new());
    let server = MetricsServer::serve("127.0.0.1:0", registry.clone()).unwrap();
    let addr = server.local_addr();
    let stop = Arc::new(AtomicBool::new(false));
    std::thread::scope(|scope| {
        for w in 0..8 {
            let registry = registry.clone();
            let stop = stop.clone();
            scope.spawn(move || {
                let label = format!("w{w}");
                let c = registry.counter(
                    "stress_ops_total",
                    "Writer operations.",
                    &[("writer", label.as_str())],
                );
                let h = registry.histogram(
                    "stress_wait_seconds",
                    "Synthetic wait.",
                    &[("writer", label.as_str())],
                    &obs::LATENCY_BUCKETS,
                );
                let mut i = 0u64;
                while !stop.load(Ordering::Relaxed) {
                    c.inc();
                    h.observe((i % 100) as f64 * 1e-4);
                    i += 1;
                }
            });
        }
        let mut prev: HashMap<String, u64> = HashMap::new();
        for _ in 0..20 {
            let text = obs::scrape(addr, "/metrics").unwrap();
            obs::validate(&text).unwrap_or_else(|e| panic!("scrape invalid: {e}\n--\n{text}"));
            for line in text.lines().filter(|l| l.starts_with("stress_ops_total{")) {
                let (series, value) = line.rsplit_once(' ').unwrap();
                let value: u64 = value.parse().unwrap();
                if let Some(&was) = prev.get(series) {
                    assert!(value >= was, "counter went backwards: {series} {was} -> {value}");
                }
                prev.insert(series.to_string(), value);
            }
        }
        assert_eq!(prev.len(), 8, "every writer's series appeared");
        assert!(prev.values().all(|&v| v > 0));
        stop.store(true, Ordering::Relaxed);
    });
}

// ---------------------------------------------------------------------
// priority shedding
// ---------------------------------------------------------------------

/// With shedding on, low-priority admission stops at the watermark while
/// high-priority traffic still fills the queue to true capacity.
#[test]
fn shedding_rejects_low_priority_before_high() {
    let a = gen::grid2d_laplacian(7, 7);
    let router = Router::new(
        SolveOptions::ours(1),
        RouterConfig {
            shard_queue: 6,
            registry: Some(Arc::new(Registry::new())),
            ..RouterConfig::default()
        },
    );
    let t = router.admit(&a).unwrap();
    router.scale_tenant(t, 1, 6, 3).unwrap();
    let rhs = vec![1.0; a.n_rows()];
    for _ in 0..3 {
        router.submit_with_priority(t, Request::Solve { rhs: rhs.clone() }, Priority::Low).unwrap();
    }
    assert!(
        matches!(
            router.submit_with_priority(t, Request::Solve { rhs: rhs.clone() }, Priority::Low),
            Err(ServeError::ShardFull { .. })
        ),
        "low is shed at the watermark"
    );
    for _ in 0..3 {
        router.submit(t, Request::Solve { rhs: rhs.clone() }).unwrap();
    }
    assert!(
        matches!(
            router.submit(t, Request::Solve { rhs }),
            Err(ServeError::ShardFull { .. })
        ),
        "high is only rejected at true capacity"
    );
    let health = &router.health()[0];
    assert_eq!(health.queue_depth, 6);
    assert_eq!(health.low_priority_limit, 3);
}

// ---------------------------------------------------------------------
// autoscaled serving vs unscaled oracle
// ---------------------------------------------------------------------

enum Step {
    Full(Vec<f64>),
    Stamp(ChangeSet),
    Solve(Vec<f64>),
}

fn script_for(a: &Csc, seed: u64, len: usize) -> Vec<Step> {
    let mut rng = sparselu::util::Prng::new(seed);
    let n = a.n_rows();
    let mut steps = vec![Step::Full(a.values.clone())];
    for _ in 1..len {
        steps.push(match rng.below(10) {
            0..=1 => Step::Full(
                a.values.iter().map(|v| v * (1.0 + 0.02 * rng.signed_unit())).collect(),
            ),
            2..=5 => {
                let d = rng.below(n);
                let k = a.value_index(d, d).expect("full diagonal");
                let nv = a.values[k] * (1.0 + 0.03 * (0.5 + 0.5 * rng.f64()));
                Step::Stamp(ChangeSet::from_value_indices([(k, nv)]))
            }
            _ => Step::Solve((0..n).map(|_| rng.signed_unit()).collect()),
        });
    }
    steps
}

fn oracle_solutions(plan: &Arc<FactorPlan>, steps: &[Step]) -> Vec<Vec<f64>> {
    let mut session = SolverSession::from_plan(plan.clone());
    let mut solutions = Vec::new();
    for step in steps {
        match step {
            Step::Full(values) => {
                session.refactorize(values).unwrap();
            }
            Step::Stamp(cs) => {
                session.refactorize_partial(cs).unwrap();
            }
            Step::Solve(rhs) => solutions.push(session.solve(rhs)),
        }
    }
    solutions
}

fn step_request(step: &Step) -> Request {
    match step {
        Step::Full(values) => Request::Refactorize { values: values.clone() },
        Step::Stamp(cs) => Request::Stamp { changes: cs.clone() },
        Step::Solve(rhs) => Request::Solve { rhs: rhs.clone() },
    }
}

/// The acceptance bar for the control loop: while the autoscaler
/// resizes pools and queue bounds live (ticking between bursts), every
/// admitted request's result must be bit-identical to a single-session
/// replay with no scaling at all — shedding and resizing are
/// admission-side only and never change execution.
#[test]
fn autoscaled_serving_is_bit_identical_to_the_unscaled_oracle() {
    let a = gen::grid2d_laplacian(10, 10);
    let registry = Arc::new(Registry::new());
    let router = Arc::new(Router::new(
        SolveOptions::ours(1),
        RouterConfig {
            shard_queue: 8,
            registry: Some(registry.clone()),
            ..RouterConfig::default()
        },
    ));
    let tenant = router.admit(&a).unwrap();
    let policy = SloPolicy {
        // pin the SLO far out so the trace (queue depth) drives scaling
        // deterministically regardless of machine speed
        p99_queue_wait_slo_s: 10.0,
        min_sessions: 1,
        max_sessions: 4,
        min_queue: 4,
        max_queue: 32,
        ..SloPolicy::default()
    };
    let scaler = Autoscaler::new(router.clone(), policy);

    let steps = script_for(&a, 77, 40);
    let expected = oracle_solutions(&router.plan_of(tenant).unwrap(), &steps);

    let mut solutions: Vec<Vec<f64>> = Vec::new();
    let mut collect = |outcomes: Vec<Result<sparselu::serve::ServeReport, ServeError>>| {
        for outcome in outcomes {
            if let Some(x) = outcome.expect("scripted request failed").solution {
                solutions.push(x);
            }
        }
    };
    for chunk in steps.chunks(5) {
        for step in chunk {
            // closed loop: if a (possibly shrunken) queue is full, drain
            // and retry — nothing is ever dropped
            loop {
                match router.submit(tenant, step_request(step)) {
                    Ok(()) => break,
                    Err(ServeError::ShardFull { .. }) => {
                        collect(router.drain_tenant(tenant).unwrap())
                    }
                    Err(e) => panic!("unexpected submit failure: {e}"),
                }
            }
        }
        scaler.tick(); // the control loop runs mid-load, resizing live
        collect(router.drain_tenant(tenant).unwrap());
    }
    assert_eq!(solutions, expected, "autoscaled serving changed admitted results");
    assert!(
        registry.counter("sparselu_autoscale_ticks_total", "", &[]).get() >= 8,
        "the controller actually ran during the load"
    );
    let health = &router.health()[0];
    assert!(health.sessions_target <= policy.max_sessions);
    assert!(health.queue_capacity >= policy.min_queue && health.queue_capacity <= policy.max_queue);
}

// ---------------------------------------------------------------------
// acceptance scrape: 4-tenant run, >= 20 distinct series
// ---------------------------------------------------------------------

#[test]
fn four_tenant_run_exposes_the_full_series_set() {
    let registry = Arc::new(Registry::new());
    let mats: Vec<(String, Csc)> = vec![
        (
            "bbd-300".into(),
            gen::circuit_bbd(gen::CircuitParams { n: 300, ..Default::default() }),
        ),
        ("grid-9x9".into(), gen::grid2d_laplacian(9, 9)),
        ("fem-200".into(), gen::banded_fem(200, &[1, 2, 3, 20, 21], 0.85, 0xFE3)),
        ("grid-8x10".into(), gen::grid2d_laplacian(8, 10)),
    ];
    let cfg = MultiTenantConfig {
        clients: 4,
        requests_per_client: 12,
        burst: 3,
        mix: ScenarioMix::default(),
        seed: 0xC0FFEE,
        router: RouterConfig {
            sessions_per_shard: 1,
            registry: Some(registry.clone()),
            ..RouterConfig::default()
        },
        autoscale: Some(SloPolicy { p99_queue_wait_slo_s: 10.0, ..SloPolicy::default() }),
    };
    // 2-worker plans: the shared work-stealing executor is live, so its
    // steal/park counters are registered and mirrored
    let report = loadgen::run_multi(&mats, &SolveOptions::ours(2), &cfg);
    assert_eq!(report.tenants, 4);
    assert!(report.total_requests >= 4 * 12);

    let server = MetricsServer::serve("127.0.0.1:0", registry.clone()).unwrap();
    let text = obs::scrape(server.local_addr(), "/metrics").unwrap();
    let summary =
        obs::validate(&text).unwrap_or_else(|e| panic!("exposition invalid: {e}\n--\n{text}"));
    assert!(
        summary.series.len() >= 20,
        "expected >= 20 distinct series, got {}:\n{}",
        summary.series.len(),
        summary.series.join("\n")
    );
    assert_eq!(registry.label_values("tenant").len(), 4, "one label value per tenant");
    for needle in [
        "sparselu_tenant_queue_wait_seconds_bucket{",
        "sparselu_tenant_exec_seconds_bucket{",
        "sparselu_tenant_batch_size_bucket{",
        "sparselu_tenant_submitted_total{",
        "sparselu_pool_checkout_wait_seconds_bucket{",
        "sparselu_pool_sessions_target{",
        "sparselu_plan_cache_misses_total",
        "sparselu_router_shards_live",
        "sparselu_executor_steals_total{workers=\"2\"}",
        "sparselu_executor_parks_total{workers=\"2\"}",
        "sparselu_executor_workers{workers=\"2\"} 2",
        "sparselu_autoscale_ticks_total",
    ] {
        assert!(text.contains(needle), "missing {needle} in scrape:\n{text}");
    }
    // per-tenant histograms saw real traffic
    let completed: u64 = text
        .lines()
        .filter(|l| l.starts_with("sparselu_tenant_completed_total{"))
        .map(|l| l.rsplit(' ').next().unwrap().parse::<u64>().unwrap())
        .sum();
    assert!(completed as usize >= 4 * 12, "completed counters cover the whole load");
}
