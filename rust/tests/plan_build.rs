//! Differential tests for executor-parallel plan construction: for every
//! suite matrix and worker count, [`FactorPlan::build_on`] must produce a
//! plan **bit-identical** to the sequential [`FactorPlan::build`] — same
//! permutation, symbolic fill, blocking, per-block storage, task DAG and
//! scatter map — and the two plans must re-factorize to bitwise-equal
//! factors. Plus the structurally-singular regression: a matrix with a
//! structurally empty diagonal entry must surface
//! [`FactorError::StructurallySingular`] as a clean `Err` on every
//! serving path (direct build, plan cache, router admission), never a
//! panic.

use sparselu::coordinator::Executor;
use sparselu::numeric::FactorError;
use sparselu::serve::{Router, RouterConfig, ServeError};
use sparselu::session::{FactorPlan, PlanCache, SolverSession};
use sparselu::solver::SolveOptions;
use sparselu::sparse::{gen, Coo, Csc};
use std::sync::Arc;

fn suite() -> Vec<(&'static str, Csc)> {
    vec![
        ("grid2d-16x16", gen::grid2d_laplacian(16, 16)),
        (
            "circuit-bbd-600",
            gen::circuit_bbd(gen::CircuitParams { n: 600, ..Default::default() }),
        ),
        ("tridiagonal-300", gen::tridiagonal(300)),
        ("arrow-up-200", gen::arrow_up(200)),
        ("banded-fem-300", gen::banded_fem(300, &[1, 7, 19], 0.6, 7)),
    ]
}

/// Field-by-field structural equality of two plans built from the same
/// (matrix, options) pair.
fn assert_plans_identical(seq: &FactorPlan, par: &FactorPlan, tag: &str) {
    assert_eq!(seq.permutation().as_slice(), par.permutation().as_slice(), "{tag}: perm");
    assert_eq!(
        seq.inverse_permutation().as_slice(),
        par.inverse_permutation().as_slice(),
        "{tag}: iperm"
    );
    assert_eq!(seq.fingerprint(), par.fingerprint(), "{tag}: fingerprint");
    assert_eq!(seq.report.nnz_ldu, par.report.nnz_ldu, "{tag}: nnz_ldu");
    assert_eq!(
        seq.structure.blocking.positions(),
        par.structure.blocking.positions(),
        "{tag}: blocking positions"
    );
    assert_eq!(seq.structure.blocks.len(), par.structure.blocks.len(), "{tag}: block count");
    for (id, (sb, pb)) in seq.structure.blocks.iter().zip(&par.structure.blocks).enumerate() {
        assert_eq!((sb.bi, sb.bj), (pb.bi, pb.bj), "{tag}: block {id} coords");
        assert_eq!((sb.n_rows, sb.n_cols), (pb.n_rows, pb.n_cols), "{tag}: block {id} dims");
        assert_eq!(sb.col_ptr, pb.col_ptr, "{tag}: block {id} col_ptr");
        assert_eq!(sb.row_idx, pb.row_idx, "{tag}: block {id} row_idx");
        assert_eq!(sb.values, pb.values, "{tag}: block {id} values");
    }
    assert_eq!(seq.structure.by_col, par.structure.by_col, "{tag}: by_col");
    assert_eq!(seq.structure.by_row, par.structure.by_row, "{tag}: by_row");
    assert_eq!(seq.dag.tasks.len(), par.dag.tasks.len(), "{tag}: task count");
    for (i, (st, pt)) in seq.dag.tasks.iter().zip(&par.dag.tasks).enumerate() {
        assert_eq!(st.op, pt.op, "{tag}: task {i} op");
        assert_eq!(st.owner, pt.owner, "{tag}: task {i} owner");
        assert_eq!(st.deps, pt.deps, "{tag}: task {i} deps");
        assert_eq!(st.out, pt.out, "{tag}: task {i} out-edges");
        assert_eq!(st.level, pt.level, "{tag}: task {i} level");
        assert_eq!(st.cost.to_bits(), pt.cost.to_bits(), "{tag}: task {i} cost");
        assert_eq!(st.flops.to_bits(), pt.flops.to_bits(), "{tag}: task {i} flops");
    }
    assert_eq!(seq.scatter_maps().0, par.scatter_maps().0, "{tag}: scatter blocks");
    assert_eq!(seq.scatter_maps().1, par.scatter_maps().1, "{tag}: scatter offsets");
}

#[test]
fn parallel_build_is_bit_identical_to_sequential() {
    for (name, a) in &suite() {
        for workers in [1u32, 2, 8] {
            let tag = format!("{name} w={workers}");
            let opts = SolveOptions::ours(workers);
            let seq = FactorPlan::build(a, &opts).unwrap();
            let exec = Executor::shared(workers);
            let par = FactorPlan::build_on(a, &opts, &exec).unwrap();
            assert_plans_identical(&seq, &par, &tag);

            // and the two plans drive bitwise-identical numerics
            let mut s1 = SolverSession::from_plan(Arc::new(seq));
            let mut s2 = SolverSession::from_plan(Arc::new(par));
            s1.refactorize(&a.values).unwrap();
            s2.refactorize(&a.values).unwrap();
            for id in 0..s1.plan().structure.blocks.len() {
                assert_eq!(
                    s1.numeric().block_values(id as u32),
                    s2.numeric().block_values(id as u32),
                    "{tag}: factor block {id} diverges"
                );
            }
            let b: Vec<f64> = (0..a.n_rows()).map(|i| ((i * 5) % 9) as f64 - 4.0).collect();
            assert_eq!(s1.solve(&b), s2.solve(&b), "{tag}: solve diverges");
        }
    }
}

/// `n`×`n` pattern with a structural zero at diagonal `row` (plus some
/// off-diagonal coupling so the matrix is not block-trivial).
fn singular_matrix(n: usize, row: usize) -> Csc {
    let mut coo = Coo::new(n, n);
    for i in 0..n {
        if i != row {
            coo.push(i, i, 4.0);
        }
    }
    coo.push(0, row, 1.0);
    coo.push(row, (row + 1) % n, 1.0);
    coo.to_csc()
}

#[test]
fn structurally_singular_errors_on_every_serving_path() {
    let a = singular_matrix(6, 3);
    let opts = SolveOptions::ours(2);

    // direct build, sequential and parallel
    let err = FactorPlan::build(&a, &opts).unwrap_err();
    assert_eq!(err, FactorError::StructurallySingular { row: 3 });
    let exec = Executor::shared(2);
    let err = FactorPlan::build_on(&a, &opts, &exec).unwrap_err();
    assert_eq!(err, FactorError::StructurallySingular { row: 3 });

    // plan cache: the error propagates and nothing is cached
    let mut cache = PlanCache::new(4);
    let err = cache.get_or_build(&a, &opts).unwrap_err();
    assert_eq!(err, FactorError::StructurallySingular { row: 3 });
    assert_eq!(cache.len(), 0);

    // router admission: a per-request error, and the router survives to
    // serve a well-posed pattern afterwards
    let router = Router::new(opts, RouterConfig::default());
    match router.admit(&a) {
        Err(ServeError::Factor(FactorError::StructurallySingular { row })) => {
            assert_eq!(row, 3);
        }
        other => panic!("expected StructurallySingular from admit, got {other:?}"),
    }
    let good = gen::grid2d_laplacian(8, 8);
    let tenant = router.admit(&good).unwrap();
    assert!(router.drain_tenant(tenant).unwrap().is_empty());
}
