//! Cross-module integration: ordering → symbolic → blocking → partition
//! consistency on every generator archetype.

use sparselu::blocking::{
    irregular_blocking, regular_blocking, BlockedMatrix, DiagFeature, IrregularParams,
};
use sparselu::ordering::{order, OrderingMethod};
use sparselu::sparse::{gen, Csc};
use sparselu::symbolic;

fn archetypes() -> Vec<(&'static str, Csc)> {
    vec![
        ("grid2d", gen::grid2d_laplacian(20, 20)),
        ("grid3d", gen::grid3d_laplacian(7, 7, 7)),
        ("bbd", gen::circuit_bbd(gen::CircuitParams { n: 500, ..Default::default() })),
        ("graph", gen::directed_graph(400, 4, 11)),
        ("fem", gen::banded_fem(400, &[1, 2, 17], 0.9, 5)),
        ("em", gen::electromagnetics_like(400, 10, 2, 6)),
        ("tridiag", gen::tridiagonal(400)),
        ("uniform", gen::uniform_random(300, 0.03, 7)),
        ("local_dense", gen::local_dense_blocks(400, &[(100, 60)], 2, 8)),
        ("dense_rows", gen::dense_rows_cols(400, &[200], 2, 9)),
        ("arrow_up", gen::arrow_up(200)),
        ("arrow_down", gen::arrow_down(200)),
    ]
}

#[test]
fn symbolic_pattern_contains_a_for_all_archetypes() {
    for (name, a) in archetypes() {
        let perm = order(&a, OrderingMethod::MinDegree);
        let pa = a.permute_sym(perm.as_slice());
        let sym = symbolic::analyze(&pa);
        let ldu = sym.ldu_pattern(&pa).unwrap(); // errors (OutOfPattern) if A ⊄ pattern
        assert!(ldu.nnz() >= pa.nnz(), "{name}");
        assert!(ldu.has_full_diagonal(), "{name}");
        // reported nnz consistent
        assert_eq!(ldu.nnz(), sym.nnz_ldu(), "{name}");
    }
}

#[test]
fn diag_feature_total_matches_nnz_on_filled_patterns() {
    for (name, a) in archetypes() {
        let sym = symbolic::analyze(&a);
        let ldu = sym.ldu_pattern(&a).unwrap();
        let f = DiagFeature::from_csc(&ldu);
        assert_eq!(f.total() as usize, ldu.nnz(), "{name}");
        let curve = f.curve();
        assert!(curve.pct.windows(2).all(|w| w[0] <= w[1]), "{name}: curve not monotone");
    }
}

#[test]
fn blocked_partition_reassembles_for_both_policies() {
    for (name, a) in archetypes() {
        let sym = symbolic::analyze(&a);
        let ldu = sym.ldu_pattern(&a).unwrap();
        let n = ldu.n_cols();
        let curve = DiagFeature::from_csc(&ldu).curve();
        for (policy, blocking) in [
            ("regular", regular_blocking(n, (n / 7).max(1))),
            ("irregular", irregular_blocking(&curve, &IrregularParams::default())),
        ] {
            let bm = BlockedMatrix::build(&ldu, blocking);
            assert_eq!(bm.to_csc(), ldu, "{name}/{policy}: partition lost entries");
            // every diagonal block present (full diagonal pattern)
            for k in 0..bm.nb() {
                assert!(bm.block_id(k, k).is_some(), "{name}/{policy}: diag block {k} missing");
            }
        }
    }
}

#[test]
fn orderings_are_permutations_and_reduce_or_keep_fill() {
    for (name, a) in archetypes() {
        let natural = symbolic::analyze(&a).nnz_ldu();
        let perm = order(&a, OrderingMethod::MinDegree);
        assert!(perm.is_valid(), "{name}");
        let md = symbolic::analyze(&a.permute_sym(perm.as_slice())).nnz_ldu();
        // min-degree should never be catastrophically worse than natural
        assert!(
            (md as f64) < 1.6 * natural as f64 + 100.0,
            "{name}: md fill {md} vs natural {natural}"
        );
    }
}

#[test]
fn feature_curve_classifies_the_fig7_archetypes() {
    // linear
    let lin = DiagFeature::from_csc(&gen::tridiagonal(2000)).curve();
    // quadratic (uniform)
    let sym = gen::uniform_random(800, 0.02, 3).plus_transpose_pattern();
    let uni = DiagFeature::from_csc(&sym).curve();
    assert!(lin.quadratic_score().abs() < 0.02);
    assert!(uni.quadratic_score() < -0.05);
    assert!(uni.quadratic_score() < lin.quadratic_score());
}

#[test]
fn irregular_blocking_tracks_density_transitions() {
    // matrix with one dense region: blocks inside the region must be finer
    // than the widest block outside it
    let a = gen::local_dense_blocks(2000, &[(1200, 400)], 2, 21);
    let sym = symbolic::analyze(&a);
    let ldu = sym.ldu_pattern(&a).unwrap();
    let curve = DiagFeature::from_csc(&ldu).curve();
    let b = irregular_blocking(&curve, &IrregularParams::default());
    let mut inside = Vec::new();
    let mut outside = Vec::new();
    for k in 0..b.num_blocks() {
        let mid = (b.positions()[k] + b.positions()[k + 1]) / 2;
        if (1200..1600).contains(&mid) {
            inside.push(b.block_size(k) as f64);
        } else if mid < 1000 {
            outside.push(b.block_size(k) as f64);
        }
    }
    let max_inside = inside.iter().cloned().fold(0.0, f64::max);
    let max_outside = outside.iter().cloned().fold(0.0, f64::max);
    assert!(
        max_inside <= max_outside,
        "dense region blocks ({max_inside}) should be no coarser than sparse ({max_outside})"
    );
}
