//! Integration tests of the serving layer: the ≥8-thread pool + cache
//! stress test (every concurrent result must bit-match a single-threaded
//! oracle), the persist round trip (a plan loaded from disk must
//! reproduce bit-identical factors, full and partial), and the
//! multi-tenant router (concurrent tenants bit-match per-pattern
//! oracles; shard eviction/revival and `ShardFull` backpressure behave).

mod common;

use common::perturbed;
use sparselu::serve::{
    persist, Batcher, Request, Router, RouterConfig, ServeError, SessionPool, TenantId,
};
use sparselu::session::{ChangeSet, FactorPlan, PlanCache, SolverSession};
use sparselu::solver::SolveOptions;
use sparselu::sparse::{gen, Csc};
use std::path::PathBuf;
use std::sync::{Arc, Mutex};

fn tmp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("sparselu-serve-{tag}-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// Precomputed single-threaded ground truth for one value scenario.
struct Oracle {
    values: Vec<f64>,
    blocks: Vec<Vec<f64>>,
    rhs: Vec<f64>,
    x: Vec<f64>,
}

#[test]
fn pool_and_cache_stress_bitwise_matches_single_thread_oracle() {
    const THREADS: usize = 8;
    const ITERS: usize = 6;
    const SCENARIOS: usize = 5;

    let a = gen::circuit_bbd(gen::CircuitParams { n: 260, ..Default::default() });
    let opts = SolveOptions::ours(2);
    let plan = Arc::new(FactorPlan::build(&a, &opts).unwrap());

    // ground truth, computed serially: the bitwise factors and one solve
    // per scenario
    let oracles: Vec<Oracle> = (0..SCENARIOS)
        .map(|s| {
            let values = perturbed(&a, 1000 + s as u64).values;
            let mut session = SolverSession::from_plan(plan.clone());
            session.refactorize(&values).unwrap();
            let blocks = (0..plan.structure.blocks.len())
                .map(|id| session.numeric().block_values(id as u32))
                .collect();
            let rhs: Vec<f64> =
                (0..a.n_rows()).map(|i| ((i * 7 + s) % 11) as f64 - 5.0).collect();
            let x = session.solve(&rhs);
            Oracle { values, blocks, rhs, x }
        })
        .collect();

    // fewer sessions than threads → checkouts contend and block, and
    // every thread inherits sessions in arbitrary prior states
    let pool = SessionPool::new(plan.clone(), 3);
    let cache = Mutex::new(PlanCache::new(4));
    cache.lock().unwrap().insert(plan.clone());

    std::thread::scope(|scope| {
        for t in 0..THREADS {
            let (pool, cache, plan, a, opts, oracles) =
                (&pool, &cache, &plan, &a, &opts, &oracles);
            scope.spawn(move || {
                for i in 0..ITERS {
                    let oracle = &oracles[(t * 13 + i * 7) % SCENARIOS];
                    // hammer the shared cache: every lookup must hit and
                    // hand back the one shared plan
                    let cached = cache.lock().unwrap().get_or_build(a, opts).unwrap();
                    assert!(Arc::ptr_eq(&cached, plan), "cache served a different plan");

                    let mut session = pool.checkout();
                    if session.is_factored() && (t + i) % 2 == 0 {
                        // incremental route from whatever state the pool
                        // handed us to the scenario's values
                        let cs = ChangeSet::from_values_diff(
                            session.current_values(),
                            &oracle.values,
                        );
                        session.refactorize_partial(&cs).unwrap();
                    } else {
                        session.refactorize(&oracle.values).unwrap();
                    }
                    for (id, want) in oracle.blocks.iter().enumerate() {
                        assert_eq!(
                            &session.numeric().block_values(id as u32),
                            want,
                            "thread {t} iter {i}: block {id} diverged from the oracle"
                        );
                    }
                    assert_eq!(
                        session.solve(&oracle.rhs),
                        oracle.x,
                        "thread {t} iter {i}: solve diverged from the oracle"
                    );
                }
            });
        }
    });

    let stats = pool.stats();
    assert!(stats.created <= 3, "pool must not grow past its cap");
    assert_eq!(stats.checkouts, THREADS * ITERS);
    assert_eq!(stats.in_use, 0, "every guard checked its session back in");
    let cache = cache.into_inner().unwrap();
    assert_eq!(cache.misses(), 0, "the warmed cache never rebuilt a plan");
    assert_eq!(cache.hits(), THREADS * ITERS);
}

#[test]
fn persisted_plan_reproduces_bitwise_identical_factors() {
    let a = gen::circuit_bbd(gen::CircuitParams { n: 220, ..Default::default() });
    let opts = SolveOptions::ours(1);
    let plan = Arc::new(FactorPlan::build(&a, &opts).unwrap());
    let dir = tmp_dir("roundtrip");
    let path = persist::save_plan_to_dir(&plan, &dir).unwrap();
    let loaded = persist::load_plan(&path).unwrap();

    let values = perturbed(&a, 7).values;
    let mut original = SolverSession::from_plan(plan.clone());
    let mut warmed = SolverSession::from_plan(loaded.clone());
    original.refactorize(&values).unwrap();
    warmed.refactorize(&values).unwrap();
    for id in 0..plan.structure.blocks.len() {
        assert_eq!(
            original.numeric().block_values(id as u32),
            warmed.numeric().block_values(id as u32),
            "full refactorize: block {id} differs through the loaded plan"
        );
    }
    let b: Vec<f64> = (0..a.n_rows()).map(|i| ((i * 3) % 13) as f64 - 6.0).collect();
    assert_eq!(original.solve(&b), warmed.solve(&b));

    // the loaded plan's rebuilt reachability index prunes identically
    let k = a.value_index(50, 50).unwrap();
    let cs = ChangeSet::from_value_indices([(k, values[k] * 1.5)]);
    let r1 = original.refactorize_partial(&cs).unwrap();
    let r2 = warmed.refactorize_partial(&cs).unwrap();
    assert_eq!(r1.tasks_executed, r2.tasks_executed);
    assert_eq!(r1.blocks_affected, r2.blocks_affected);
    for id in 0..plan.structure.blocks.len() {
        assert_eq!(
            original.numeric().block_values(id as u32),
            warmed.numeric().block_values(id as u32),
            "partial refactorize: block {id} differs through the loaded plan"
        );
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn batched_serving_through_the_pool_matches_a_direct_session() {
    let a = gen::grid2d_laplacian(9, 9);
    let plan = Arc::new(FactorPlan::build(&a, &SolveOptions::ours(1)).unwrap());
    let pool = SessionPool::new(plan.clone(), 2);

    let k = a.value_index(40, 40).unwrap();
    let rhs: Vec<Vec<f64>> = (0..4)
        .map(|t| (0..a.n_rows()).map(|i| ((i + t) % 7) as f64 - 3.0).collect())
        .collect();
    let mut batcher = Batcher::new(16);
    batcher.submit(Request::Refactorize { values: a.values.clone() }).unwrap();
    batcher
        .submit(Request::Stamp {
            changes: ChangeSet::from_value_indices([(k, a.values[k] * 3.0)]),
        })
        .unwrap();
    for r in &rhs {
        batcher.submit(Request::Solve { rhs: r.clone() }).unwrap();
    }

    let mut session = pool.checkout();
    let outcomes = batcher.drain(&mut session);
    assert_eq!(outcomes.len(), 6);
    let reports: Vec<_> = outcomes.into_iter().map(|o| o.unwrap()).collect();

    // reference: the same work done directly, full refactorizes only
    // (the stamp route — partial or full — must not change results)
    let mut reference = SolverSession::from_plan(plan.clone());
    let mut values = a.values.clone();
    reference.refactorize(&values).unwrap();
    values[k] *= 3.0;
    reference.refactorize(&values).unwrap();
    for (report, r) in reports[2..].iter().zip(&rhs) {
        assert_eq!(report.batch_size, 4, "the four solves coalesced into one sweep");
        assert_eq!(report.solution.as_ref().unwrap(), &reference.solve(r));
        assert!(report.queue_seconds >= 0.0);
    }
}

// ---------------------------------------------------------------------
// multi-tenant router
// ---------------------------------------------------------------------

/// One deterministic request in a tenant's traffic script.
enum Step {
    Full(Vec<f64>),
    Stamp(ChangeSet),
    Solve(Vec<f64>),
}

/// Deterministic interleaved full/stamp/solve script for one matrix.
/// Always starts with a full refactorize so the shard's factors are
/// seeded; stamps hit random diagonal entries (always in-pattern for the
/// generator matrices).
fn script_for(a: &Csc, seed: u64, len: usize) -> Vec<Step> {
    let mut rng = sparselu::util::Prng::new(seed);
    let n = a.n_rows();
    let mut steps = vec![Step::Full(a.values.clone())];
    for _ in 1..len {
        steps.push(match rng.below(10) {
            0..=1 => Step::Full(
                a.values.iter().map(|v| v * (1.0 + 0.02 * rng.signed_unit())).collect(),
            ),
            2..=5 => {
                let d = rng.below(n);
                let k = a.value_index(d, d).expect("full diagonal");
                let nv = a.values[k] * (1.0 + 0.03 * (0.5 + 0.5 * rng.f64()));
                Step::Stamp(ChangeSet::from_value_indices([(k, nv)]))
            }
            _ => Step::Solve((0..n).map(|_| rng.signed_unit()).collect()),
        });
    }
    steps
}

/// Single-threaded oracle: replay a script directly on a session over
/// `plan`, returning the solution of every solve step in order.
fn oracle_solutions(plan: &Arc<FactorPlan>, steps: &[Step]) -> Vec<Vec<f64>> {
    let mut session = SolverSession::from_plan(plan.clone());
    let mut solutions = Vec::new();
    for step in steps {
        match step {
            Step::Full(values) => {
                session.refactorize(values).unwrap();
            }
            Step::Stamp(cs) => {
                session.refactorize_partial(cs).unwrap();
            }
            Step::Solve(rhs) => solutions.push(session.solve(rhs)),
        }
    }
    solutions
}

fn step_request(step: &Step) -> Request {
    match step {
        Step::Full(values) => Request::Refactorize { values: values.clone() },
        Step::Stamp(cs) => Request::Stamp { changes: cs.clone() },
        Step::Solve(rhs) => Request::Solve { rhs: rhs.clone() },
    }
}

fn router_stress_with_workers(workers: u32) {
    const STEPS: usize = 28;
    const BURST: usize = 3;

    // four tenants with four distinct sparsity patterns
    let mats: Vec<(Csc, u64)> = vec![
        (gen::circuit_bbd(gen::CircuitParams { n: 240, ..Default::default() }), 11),
        (gen::grid2d_laplacian(11, 11), 22),
        (gen::banded_fem(200, &[1, 2, 3, 20, 21], 0.85, 0xFE3), 33),
        (gen::grid2d_laplacian(9, 13), 44),
    ];
    let opts = SolveOptions::ours(workers);
    let router = Router::new(
        opts.clone(),
        RouterConfig { max_shards: 4, plan_cache_capacity: 8, ..RouterConfig::default() },
    );
    let ids: Vec<TenantId> = mats.iter().map(|(a, _)| router.admit(a).unwrap()).collect();
    assert_eq!(router.stats().shards_live, 4);

    // oracles replay each script single-threaded against the *routed*
    // plan, so factor bit-patterns are directly comparable
    let scripts: Vec<Vec<Step>> =
        mats.iter().map(|(a, seed)| script_for(a, *seed, STEPS)).collect();
    let expected: Vec<Vec<Vec<f64>>> = scripts
        .iter()
        .zip(&ids)
        .map(|(steps, id)| oracle_solutions(&router.plan_of(*id).unwrap(), steps))
        .collect();

    // one client thread per tenant, all hammering the router at once:
    // tenants interleave arbitrarily on the wall clock, but each
    // tenant's own stream keeps submission order
    std::thread::scope(|scope| {
        for ((steps, id), expected) in scripts.iter().zip(&ids).zip(&expected) {
            let router = &router;
            scope.spawn(move || {
                let mut solutions: Vec<Vec<f64>> = Vec::new();
                for chunk in steps.chunks(BURST) {
                    for step in chunk {
                        router.submit(*id, step_request(step)).unwrap();
                    }
                    for outcome in router.drain_tenant(*id).unwrap() {
                        let report = outcome.expect("scripted request failed");
                        if let Some(x) = report.solution {
                            solutions.push(x);
                        }
                    }
                }
                assert_eq!(
                    &solutions, expected,
                    "tenant {id:?}: routed solutions diverge from the oracle"
                );
            });
        }
    });

    // every request completed, nothing rejected, no tenant starved
    for (id, steps) in ids.iter().zip(&scripts) {
        let stats = router.tenant_stats(*id).unwrap();
        assert_eq!(stats.submitted, steps.len());
        assert_eq!(stats.completed, steps.len());
        assert_eq!(stats.errored, 0);
        assert_eq!(stats.rejected, 0);
        assert!(stats.tasks_executed > 0);
    }
    assert_eq!(router.stats().evictions, 0, "no eviction under a fitting working set");
}

#[test]
fn router_stress_every_tenant_bitwise_matches_its_oracle() {
    router_stress_with_workers(1);
}

/// The same 4-tenant stress, but with 2-worker plans: every tenant's
/// sessions (and the single-threaded oracles) now execute on the ONE
/// process-wide shared work-stealing executor, so concurrent shard
/// drains multiplex jobs over shared worker threads — and must still
/// bit-match their per-pattern oracles.
#[test]
fn router_stress_bitwise_matches_over_shared_executor() {
    router_stress_with_workers(2);
}

#[test]
fn drain_all_groups_outcomes_per_tenant() {
    let mats =
        [gen::grid2d_laplacian(8, 8), gen::grid2d_laplacian(8, 9), gen::grid2d_laplacian(9, 9)];
    let opts = SolveOptions::ours(1);
    let router = Router::new(opts, RouterConfig::default());
    let ids: Vec<TenantId> = mats.iter().map(|a| router.admit(a).unwrap()).collect();
    let rhs: Vec<Vec<f64>> =
        mats.iter().map(|a| (0..a.n_rows()).map(|i| (i % 5) as f64 - 2.0).collect()).collect();
    for ((a, id), r) in mats.iter().zip(&ids).zip(&rhs) {
        router.submit(*id, Request::Refactorize { values: a.values.clone() }).unwrap();
        router.submit(*id, Request::Solve { rhs: r.clone() }).unwrap();
        router.submit(*id, Request::Solve { rhs: r.clone() }).unwrap();
    }
    let drained = router.drain_all(3);
    assert_eq!(drained.len(), 3, "one outcome group per tenant with queued work");
    for ((a, id), r) in mats.iter().zip(&ids).zip(&rhs) {
        let (_, outcomes) = drained
            .iter()
            .find(|(tenant, _)| tenant == id)
            .expect("every tenant drained");
        assert_eq!(outcomes.len(), 3);
        // reference solve through a fresh session over the same plan
        let mut reference = SolverSession::from_plan(router.plan_of(*id).unwrap());
        reference.refactorize(&a.values).unwrap();
        let want = reference.solve(r);
        for outcome in &outcomes[1..] {
            let report = outcome.as_ref().expect("solve failed");
            assert_eq!(report.solution.as_ref().unwrap(), &want);
            assert_eq!(report.batch_size, 2, "the two solves coalesced");
        }
        assert_eq!(router.queued(*id).unwrap(), 0, "queues fully drained");
    }
    // a second sweep with nothing queued drains nothing
    assert!(router.drain_all(2).is_empty());
}

#[test]
fn shard_full_backpressure_is_scoped_to_one_tenant() {
    let a = gen::grid2d_laplacian(7, 7);
    let b = gen::grid2d_laplacian(7, 8);
    let opts = SolveOptions::ours(1);
    let router = Router::new(
        opts,
        RouterConfig { shard_queue: 2, ..RouterConfig::default() },
    );
    let ta = router.admit(&a).unwrap();
    let tb = router.admit(&b).unwrap();
    router.submit(ta, Request::Refactorize { values: a.values.clone() }).unwrap();
    router.submit(ta, Request::Solve { rhs: vec![1.0; a.n_rows()] }).unwrap();
    // tenant a's queue is full: its client gets ShardFull with its key…
    match router.submit(ta, Request::Solve { rhs: vec![1.0; a.n_rows()] }) {
        Err(ServeError::ShardFull { tenant, capacity }) => {
            assert_eq!(tenant, ta.0);
            assert_eq!(capacity, 2);
        }
        other => panic!("expected ShardFull, got {other:?}"),
    }
    // …while tenant b admits traffic unimpeded
    router.submit(tb, Request::Refactorize { values: b.values.clone() }).unwrap();
    router.submit(tb, Request::Solve { rhs: vec![1.0; b.n_rows()] }).unwrap();
    // draining tenant a reopens its queue
    let outcomes = router.drain_tenant(ta).unwrap();
    assert_eq!(outcomes.len(), 2);
    assert!(outcomes.iter().all(|o| o.is_ok()));
    router.submit(ta, Request::Solve { rhs: vec![1.0; a.n_rows()] }).unwrap();
    assert_eq!(router.tenant_stats(ta).unwrap().rejected, 1);
    assert_eq!(router.tenant_stats(tb).unwrap().rejected, 0);
}

#[test]
fn evicted_tenant_revives_and_serves_bit_identical_results() {
    let a = gen::grid2d_laplacian(8, 8);
    let b = gen::grid2d_laplacian(8, 9);
    let opts = SolveOptions::ours(1);
    // one shard slot: admitting either pattern evicts the other; the
    // plan cache (capacity 4) keeps both plans alive across evictions
    let router = Router::new(
        opts,
        RouterConfig { max_shards: 1, plan_cache_capacity: 4, ..RouterConfig::default() },
    );
    let rhs: Vec<f64> = (0..a.n_rows()).map(|i| (i % 7) as f64 - 3.0).collect();

    let ta = router.admit(&a).unwrap();
    let plan_a = router.plan_of(ta).unwrap();
    router.submit(ta, Request::Refactorize { values: a.values.clone() }).unwrap();
    router.submit(ta, Request::Solve { rhs: rhs.clone() }).unwrap();
    let first = router.drain_tenant(ta).unwrap();
    let x_first = first[1].as_ref().unwrap().solution.clone().unwrap();

    // B takes the only slot (A idle → evicted); serve B to completion
    let tb = router.admit(&b).unwrap();
    assert!(matches!(
        router.submit(ta, Request::Solve { rhs: rhs.clone() }),
        Err(ServeError::UnknownTenant { .. })
    ), "evicted tenant is gone until re-admitted");
    router.submit(tb, Request::Refactorize { values: b.values.clone() }).unwrap();
    router.submit(tb, Request::Solve { rhs: vec![1.0; b.n_rows()] }).unwrap();
    assert!(router.drain_tenant(tb).unwrap().iter().all(|o| o.is_ok()));

    // revive A: same tenant id, same cached plan, fresh session state
    let ta2 = router.admit(&a).unwrap();
    assert_eq!(ta, ta2);
    assert!(Arc::ptr_eq(&plan_a, &router.plan_of(ta2).unwrap()), "revival hit the plan cache");
    // the revived shard's session has no factors yet: a premature solve
    // is a clean per-request error…
    router.submit(ta2, Request::Solve { rhs: rhs.clone() }).unwrap();
    let premature = router.drain_tenant(ta2).unwrap();
    assert!(matches!(premature.as_slice(), [Err(ServeError::NotFactored)]));
    // …and after re-seeding, results bit-match the pre-eviction serve
    router.submit(ta2, Request::Refactorize { values: a.values.clone() }).unwrap();
    router.submit(ta2, Request::Solve { rhs: rhs.clone() }).unwrap();
    let revived = router.drain_tenant(ta2).unwrap();
    let x_revived = revived[1].as_ref().unwrap().solution.clone().unwrap();
    assert_eq!(x_revived, x_first, "revived tenant diverges from its pre-eviction results");

    let stats = router.stats();
    assert_eq!(stats.evictions, 2, "A evicted for B, then B evicted for A's revival");
    assert_eq!(stats.revivals, 1);
    assert_eq!(stats.spin_ups, 3);
    assert_eq!(stats.cache_misses, 2, "both plans built exactly once");
}
