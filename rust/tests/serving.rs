//! Integration tests of the serving layer: the ≥8-thread pool + cache
//! stress test (every concurrent result must bit-match a single-threaded
//! oracle) and the persist round trip (a plan loaded from disk must
//! reproduce bit-identical factors, full and partial).

mod common;

use common::perturbed;
use sparselu::serve::{persist, Batcher, Request, SessionPool};
use sparselu::session::{ChangeSet, FactorPlan, PlanCache, SolverSession};
use sparselu::solver::SolveOptions;
use sparselu::sparse::gen;
use std::path::PathBuf;
use std::sync::{Arc, Mutex};

fn tmp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("sparselu-serve-{tag}-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// Precomputed single-threaded ground truth for one value scenario.
struct Oracle {
    values: Vec<f64>,
    blocks: Vec<Vec<f64>>,
    rhs: Vec<f64>,
    x: Vec<f64>,
}

#[test]
fn pool_and_cache_stress_bitwise_matches_single_thread_oracle() {
    const THREADS: usize = 8;
    const ITERS: usize = 6;
    const SCENARIOS: usize = 5;

    let a = gen::circuit_bbd(gen::CircuitParams { n: 260, ..Default::default() });
    let opts = SolveOptions::ours(2);
    let plan = Arc::new(FactorPlan::build(&a, &opts));

    // ground truth, computed serially: the bitwise factors and one solve
    // per scenario
    let oracles: Vec<Oracle> = (0..SCENARIOS)
        .map(|s| {
            let values = perturbed(&a, 1000 + s as u64).values;
            let mut session = SolverSession::from_plan(plan.clone());
            session.refactorize(&values).unwrap();
            let blocks = (0..plan.structure.blocks.len())
                .map(|id| session.numeric().block_values(id as u32))
                .collect();
            let rhs: Vec<f64> =
                (0..a.n_rows()).map(|i| ((i * 7 + s) % 11) as f64 - 5.0).collect();
            let x = session.solve(&rhs);
            Oracle { values, blocks, rhs, x }
        })
        .collect();

    // fewer sessions than threads → checkouts contend and block, and
    // every thread inherits sessions in arbitrary prior states
    let pool = SessionPool::new(plan.clone(), 3);
    let cache = Mutex::new(PlanCache::new(4));
    cache.lock().unwrap().insert(plan.clone());

    std::thread::scope(|scope| {
        for t in 0..THREADS {
            let (pool, cache, plan, a, opts, oracles) =
                (&pool, &cache, &plan, &a, &opts, &oracles);
            scope.spawn(move || {
                for i in 0..ITERS {
                    let oracle = &oracles[(t * 13 + i * 7) % SCENARIOS];
                    // hammer the shared cache: every lookup must hit and
                    // hand back the one shared plan
                    let cached = cache.lock().unwrap().get_or_build(a, opts);
                    assert!(Arc::ptr_eq(&cached, plan), "cache served a different plan");

                    let mut session = pool.checkout();
                    if session.is_factored() && (t + i) % 2 == 0 {
                        // incremental route from whatever state the pool
                        // handed us to the scenario's values
                        let cs = ChangeSet::from_values_diff(
                            session.current_values(),
                            &oracle.values,
                        );
                        session.refactorize_partial(&cs).unwrap();
                    } else {
                        session.refactorize(&oracle.values).unwrap();
                    }
                    for (id, want) in oracle.blocks.iter().enumerate() {
                        assert_eq!(
                            &session.numeric().block_values(id as u32),
                            want,
                            "thread {t} iter {i}: block {id} diverged from the oracle"
                        );
                    }
                    assert_eq!(
                        session.solve(&oracle.rhs),
                        oracle.x,
                        "thread {t} iter {i}: solve diverged from the oracle"
                    );
                }
            });
        }
    });

    let stats = pool.stats();
    assert!(stats.created <= 3, "pool must not grow past its cap");
    assert_eq!(stats.checkouts, THREADS * ITERS);
    assert_eq!(stats.in_use, 0, "every guard checked its session back in");
    let cache = cache.into_inner().unwrap();
    assert_eq!(cache.misses(), 0, "the warmed cache never rebuilt a plan");
    assert_eq!(cache.hits(), THREADS * ITERS);
}

#[test]
fn persisted_plan_reproduces_bitwise_identical_factors() {
    let a = gen::circuit_bbd(gen::CircuitParams { n: 220, ..Default::default() });
    let opts = SolveOptions::ours(1);
    let plan = Arc::new(FactorPlan::build(&a, &opts));
    let dir = tmp_dir("roundtrip");
    let path = persist::save_plan_to_dir(&plan, &dir).unwrap();
    let loaded = persist::load_plan(&path).unwrap();

    let values = perturbed(&a, 7).values;
    let mut original = SolverSession::from_plan(plan.clone());
    let mut warmed = SolverSession::from_plan(loaded.clone());
    original.refactorize(&values).unwrap();
    warmed.refactorize(&values).unwrap();
    for id in 0..plan.structure.blocks.len() {
        assert_eq!(
            original.numeric().block_values(id as u32),
            warmed.numeric().block_values(id as u32),
            "full refactorize: block {id} differs through the loaded plan"
        );
    }
    let b: Vec<f64> = (0..a.n_rows()).map(|i| ((i * 3) % 13) as f64 - 6.0).collect();
    assert_eq!(original.solve(&b), warmed.solve(&b));

    // the loaded plan's rebuilt reachability index prunes identically
    let k = a.value_index(50, 50).unwrap();
    let cs = ChangeSet::from_value_indices([(k, values[k] * 1.5)]);
    let r1 = original.refactorize_partial(&cs).unwrap();
    let r2 = warmed.refactorize_partial(&cs).unwrap();
    assert_eq!(r1.tasks_executed, r2.tasks_executed);
    assert_eq!(r1.blocks_affected, r2.blocks_affected);
    for id in 0..plan.structure.blocks.len() {
        assert_eq!(
            original.numeric().block_values(id as u32),
            warmed.numeric().block_values(id as u32),
            "partial refactorize: block {id} differs through the loaded plan"
        );
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn batched_serving_through_the_pool_matches_a_direct_session() {
    let a = gen::grid2d_laplacian(9, 9);
    let plan = Arc::new(FactorPlan::build(&a, &SolveOptions::ours(1)));
    let pool = SessionPool::new(plan.clone(), 2);

    let k = a.value_index(40, 40).unwrap();
    let rhs: Vec<Vec<f64>> = (0..4)
        .map(|t| (0..a.n_rows()).map(|i| ((i + t) % 7) as f64 - 3.0).collect())
        .collect();
    let mut batcher = Batcher::new(16);
    batcher.submit(Request::Refactorize { values: a.values.clone() }).unwrap();
    batcher
        .submit(Request::Stamp {
            changes: ChangeSet::from_value_indices([(k, a.values[k] * 3.0)]),
        })
        .unwrap();
    for r in &rhs {
        batcher.submit(Request::Solve { rhs: r.clone() }).unwrap();
    }

    let mut session = pool.checkout();
    let outcomes = batcher.drain(&mut session);
    assert_eq!(outcomes.len(), 6);
    let reports: Vec<_> = outcomes.into_iter().map(|o| o.unwrap()).collect();

    // reference: the same work done directly, full refactorizes only
    // (the stamp route — partial or full — must not change results)
    let mut reference = SolverSession::from_plan(plan.clone());
    let mut values = a.values.clone();
    reference.refactorize(&values).unwrap();
    values[k] *= 3.0;
    reference.refactorize(&values).unwrap();
    for (report, r) in reports[2..].iter().zip(&rhs) {
        assert_eq!(report.batch_size, 4, "the four solves coalesced into one sweep");
        assert_eq!(report.solution.as_ref().unwrap(), &reference.solve(r));
        assert!(report.queue_seconds >= 0.0);
    }
}
