//! Integration: the PJRT runtime executes the AOT artifacts and agrees
//! with the pure-rust dense kernels — the full L1→L2→AOT→L3 bridge.
//!
//! Requires `make artifacts` (skipped with a clear message otherwise).

use sparselu::numeric::dense;
use sparselu::numeric::factor::{CpuDense, DenseBackend};
use sparselu::runtime::PjrtDense;
use sparselu::util::Prng;

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.txt").exists().then_some(dir)
}

fn load() -> Option<PjrtDense> {
    let dir = artifacts_dir()?;
    Some(PjrtDense::load(dir).expect("artifacts present but failed to load"))
}

fn random_dd(n: usize, seed: u64) -> Vec<f64> {
    let mut rng = Prng::new(seed);
    let mut a = vec![0.0; n * n];
    for j in 0..n {
        for i in 0..n {
            if i != j {
                a[j * n + i] = rng.signed_unit();
            }
        }
    }
    for i in 0..n {
        let row: f64 = (0..n).filter(|&j| j != i).map(|j| a[j * n + i].abs()).sum();
        a[i * n + i] = row + 1.0;
    }
    a
}

fn rand_mat(m: usize, n: usize, seed: u64) -> Vec<f64> {
    let mut rng = Prng::new(seed);
    (0..m * n).map(|_| rng.signed_unit()).collect()
}

fn close(a: &[f64], b: &[f64], tol: f64, what: &str) {
    assert_eq!(a.len(), b.len());
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert!(
            (x - y).abs() < tol * y.abs().max(1.0),
            "{what}: mismatch at {i}: {x} vs {y}"
        );
    }
}

#[test]
fn pjrt_getrf_matches_cpu_exact_tile() {
    let Some(pjrt) = load() else {
        eprintln!("SKIP: run `make artifacts` first");
        return;
    };
    for &n in &[32usize, 64] {
        let a0 = random_dd(n, 42 + n as u64);
        let mut a_cpu = a0.clone();
        let mut a_pjrt = a0.clone();
        CpuDense.getrf(&mut a_cpu, n).unwrap();
        pjrt.getrf(&mut a_pjrt, n).unwrap();
        close(&a_pjrt, &a_cpu, 1e-10, "getrf");
    }
}

#[test]
fn pjrt_getrf_matches_cpu_padded() {
    let Some(pjrt) = load() else {
        eprintln!("SKIP: run `make artifacts` first");
        return;
    };
    // 5 pads to 32; 50 pads to 64; 100 pads to 128
    for &n in &[5usize, 50, 100] {
        let a0 = random_dd(n, 7 + n as u64);
        let mut a_cpu = a0.clone();
        let mut a_pjrt = a0.clone();
        CpuDense.getrf(&mut a_cpu, n).unwrap();
        pjrt.getrf(&mut a_pjrt, n).unwrap();
        close(&a_pjrt, &a_cpu, 1e-9, "getrf padded");
    }
}

#[test]
fn pjrt_trsms_match_cpu() {
    let Some(pjrt) = load() else {
        eprintln!("SKIP: run `make artifacts` first");
        return;
    };
    let (m, k) = (40usize, 23usize);
    let mut lu = random_dd(m, 3);
    dense::getrf_in_place(&mut lu, m).unwrap();
    let b0 = rand_mat(m, k, 5);
    let mut b_cpu = b0.clone();
    let mut b_pjrt = b0.clone();
    CpuDense.trsm_lower(&lu, m, &mut b_cpu, k);
    pjrt.trsm_lower(&lu, m, &mut b_pjrt, k);
    close(&b_pjrt, &b_cpu, 1e-9, "trsm_lower");

    let mut lu_k = random_dd(k, 6);
    dense::getrf_in_place(&mut lu_k, k).unwrap();
    let c0 = rand_mat(m, k, 8);
    let mut c_cpu = c0.clone();
    let mut c_pjrt = c0.clone();
    CpuDense.trsm_upper(&lu_k, k, &mut c_cpu, m);
    pjrt.trsm_upper(&lu_k, k, &mut c_pjrt, m);
    close(&c_pjrt, &c_cpu, 1e-9, "trsm_upper");
}

#[test]
fn pjrt_gemm_matches_cpu() {
    let Some(pjrt) = load() else {
        eprintln!("SKIP: run `make artifacts` first");
        return;
    };
    let (m, k, n) = (33usize, 47usize, 29usize);
    let a = rand_mat(m, k, 1);
    let b = rand_mat(k, n, 2);
    let c0 = rand_mat(m, n, 3);
    let mut c_cpu = c0.clone();
    let mut c_pjrt = c0.clone();
    CpuDense.gemm(&mut c_cpu, &a, &b, m, k, n);
    pjrt.gemm(&mut c_pjrt, &a, &b, m, k, n);
    close(&c_pjrt, &c_cpu, 1e-10, "gemm");
    assert!(pjrt.executions() >= 1);
}

#[test]
fn pjrt_backend_drives_full_factorization() {
    use sparselu::solver::{BlockingPolicy, SolveOptions, Solver};
    use sparselu::sparse::{gen, residual};

    let Some(pjrt) = load() else {
        eprintln!("SKIP: run `make artifacts` first");
        return;
    };
    let a = gen::electromagnetics_like(240, 10, 2, 17);
    let opts = SolveOptions {
        blocking: BlockingPolicy::Regular(48),
        kernels: sparselu::numeric::KernelPolicy {
            dense_threshold: 0.10, // push plenty of ops through PJRT
            ..Default::default()
        },
        ..SolveOptions::ours(2)
    };
    let mut solver = Solver::with_backend(opts, &pjrt);
    let f = solver.factorize(&a).unwrap();
    let b: Vec<f64> = (0..240).map(|i| (i % 9) as f64 - 4.0).collect();
    let x = f.solve(&b);
    let r = residual(&a, &x, &b);
    assert!(r < 1e-8, "residual {r}");
    assert!(pjrt.executions() > 0, "dense path never dispatched to PJRT");
}
