//! End-to-end solver integration: every preset × archetype × worker count
//! must produce a small residual; parallel must equal sequential bitwise;
//! failure modes surface as errors, not wrong answers.

use sparselu::ordering::OrderingMethod;
use sparselu::solver::{BlockingPolicy, SolveOptions, Solver};
use sparselu::sparse::{gen, residual, Csc};
use sparselu::util::Prng;

fn solve_residual(a: &Csc, opts: SolveOptions) -> f64 {
    let mut solver = Solver::new(opts);
    let f = solver.factorize(a).expect("factorize");
    let n = a.n_rows();
    let mut rng = Prng::new(0xD0);
    let b: Vec<f64> = (0..n).map(|_| rng.signed_unit() * 5.0).collect();
    let x = f.solve(&b);
    residual(a, &x, &b)
}

#[test]
fn presets_solve_bbd() {
    let a = gen::circuit_bbd(gen::CircuitParams { n: 600, ..Default::default() });
    for opts in [
        SolveOptions::ours(1),
        SolveOptions::pangulu(1),
        SolveOptions::superlu_like(1),
    ] {
        let r = solve_residual(&a, opts);
        assert!(r < 1e-9, "residual {r}");
    }
}

#[test]
fn worker_counts_all_solve() {
    let a = gen::electromagnetics_like(500, 12, 2, 3);
    for w in [1, 2, 3, 4, 8] {
        let r = solve_residual(&a, SolveOptions::ours(w));
        assert!(r < 1e-9, "workers {w}: residual {r}");
    }
}

#[test]
fn parallel_equals_sequential_bitwise() {
    // same DAG order ⇒ identical floating-point results
    let a = gen::directed_graph(300, 4, 77);
    let solve = |w: u32| -> Vec<f64> {
        let mut solver = Solver::new(SolveOptions::ours(w));
        let f = solver.factorize(&a).unwrap();
        let b: Vec<f64> = (0..300).map(|i| (i % 11) as f64).collect();
        f.solve(&b)
    };
    let x1 = solve(1);
    let x4 = solve(4);
    assert_eq!(x1, x4, "parallel execution changed the numerics");
}

#[test]
fn unsymmetric_pattern_with_rcm_and_natural() {
    let a = gen::directed_graph(250, 3, 5);
    for ord in [OrderingMethod::Natural, OrderingMethod::Rcm] {
        let opts = SolveOptions { ordering: ord, ..SolveOptions::ours(2) };
        let r = solve_residual(&a, opts);
        assert!(r < 1e-9, "{ord:?}: {r}");
    }
}

#[test]
fn tiny_matrices_no_panic() {
    for n in [1usize, 2, 3, 5, 8] {
        let a = gen::tridiagonal(n);
        let r = solve_residual(&a, SolveOptions::ours(2));
        assert!(r < 1e-12, "n={n}: {r}");
    }
}

#[test]
fn explicit_tiny_block_size() {
    let a = gen::grid2d_laplacian(9, 9);
    let opts = SolveOptions {
        blocking: BlockingPolicy::Regular(3),
        ..SolveOptions::ours(2)
    };
    let r = solve_residual(&a, opts);
    assert!(r < 1e-10);
}

#[test]
fn numerically_singular_matrix_errors() {
    // full pattern but rank-deficient values
    let mut coo = sparselu::sparse::Coo::new(3, 3);
    for i in 0..3 {
        for j in 0..3 {
            coo.push(i, j, 1.0);
        }
    }
    let a = coo.to_csc();
    let mut solver = Solver::new(SolveOptions::ours(1));
    assert!(solver.factorize(&a).is_err());
}

#[test]
fn solve_matches_known_solution() {
    // construct b = A*x_true, recover x_true
    let a = gen::banded_fem(200, &[1, 2, 9], 0.9, 13);
    let mut rng = Prng::new(4);
    let x_true: Vec<f64> = (0..200).map(|_| rng.signed_unit()).collect();
    let b = a.mul_vec(&x_true);
    let mut solver = Solver::new(SolveOptions::ours(2));
    let f = solver.factorize(&a).unwrap();
    let x = f.solve(&b);
    for (got, want) in x.iter().zip(&x_true) {
        assert!((got - want).abs() < 1e-8, "{got} vs {want}");
    }
}

#[test]
fn matrix_market_round_trip_through_solver() {
    let a = gen::grid2d_laplacian(12, 12);
    let dir = std::env::temp_dir().join("sparselu_it");
    std::fs::create_dir_all(&dir).unwrap();
    let p = dir.join("grid.mtx");
    sparselu::sparse::io::write_matrix_market(&a, &p).unwrap();
    let back = sparselu::sparse::io::read_matrix_market(&p).unwrap();
    assert_eq!(a, back);
    let r = solve_residual(&back, SolveOptions::ours(1));
    assert!(r < 1e-10);
}

#[test]
fn report_fields_are_consistent() {
    let a = gen::circuit_bbd(gen::CircuitParams { n: 500, ..Default::default() });
    let mut solver = Solver::new(SolveOptions::ours(4));
    let f = solver.factorize(&a).unwrap();
    let r = &f.report;
    assert_eq!(r.n, 500);
    assert_eq!(r.block_sizes.len(), r.num_blocks);
    assert_eq!(r.block_sizes.iter().sum::<usize>(), 500);
    assert!(r.nonempty_blocks >= r.num_blocks); // at least the diagonal
    assert!(r.tasks >= r.nonempty_blocks);
    assert_eq!(r.measured_busy.len(), 4);
    assert!(r.modeled_makespan > 0.0);
    assert!(r.balance.per_block_nnz.len() == r.nonempty_blocks);
}

#[test]
fn repeated_factorization_is_deterministic() {
    let a = gen::circuit_bbd(gen::CircuitParams { n: 400, ..Default::default() });
    let run = || {
        let mut solver = Solver::new(SolveOptions::ours(4));
        let f = solver.factorize(&a).unwrap();
        let b = vec![1.0; 400];
        f.solve(&b)
    };
    assert_eq!(run(), run());
}
