//! Integration tests for task-level tracing (`sparselu::obs::trace`):
//!
//! * the Chrome-trace export parses and is schema-valid (every event
//!   carries `ph`/`pid`/`tid`, complete events have non-negative
//!   durations and monotone per-lane timestamps, and the traced run's
//!   task events are all present);
//! * tracing is **observation only**: with tracing on, every DAG task is
//!   recorded exactly once at any worker count and the factors stay
//!   bit-identical to a tracing-off session on the same plan;
//! * ring overflow drops the oldest events and surfaces the loss in
//!   `dropped_events` instead of reallocating or erroring.
//!
//! The tracing switch is process-global, so the tests that toggle it
//! serialize on one mutex (the test harness runs tests in parallel
//! threads within this binary).

use sparselu::obs::trace;
use sparselu::session::{FactorPlan, SolverSession};
use sparselu::solver::SolveOptions;
use sparselu::sparse::gen;
use std::collections::HashMap;
use std::sync::{Arc, Mutex, MutexGuard};
use std::time::Instant;

static ENABLE_LOCK: Mutex<()> = Mutex::new(());

/// Serialize tests that toggle the global tracing switch; a panicked
/// holder must not cascade into unrelated failures.
fn lock() -> MutexGuard<'static, ()> {
    ENABLE_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

#[test]
fn chrome_trace_export_parses_and_is_schema_valid() {
    let _g = lock();
    let a = gen::grid2d_laplacian(16, 16);
    let opts = SolveOptions::ours(3);
    let plan = Arc::new(FactorPlan::build(&a, &opts).unwrap());

    // drop recordings left by sibling tests: the inline 1-worker path
    // records its run span (timestamped at run *start*) after its tasks,
    // so a stale lane would trip the per-lane monotonicity check below
    trace::clear();
    trace::set_enabled(true);
    let mut session = SolverSession::from_plan(plan.clone());
    let tid = trace::next_trace_id();
    session.set_trace_id(tid);
    session.refactorize(&a.values).unwrap();
    let snap = trace::snapshot();
    trace::set_enabled(false);

    let text = trace::chrome_trace_of(&snap);
    let doc = trace::parse_json(&text).expect("export parses");
    assert!(doc.get("displayTimeUnit").is_some());
    let dropped = doc
        .get("otherData")
        .and_then(|o| o.get("dropped_events"))
        .and_then(|d| d.as_f64())
        .expect("dropped_events reported");
    assert!(dropped >= 0.0);

    let evs = doc.get("traceEvents").unwrap().as_arr().unwrap();
    assert!(!evs.is_empty());
    let mut last_ts: HashMap<i64, f64> = HashMap::new();
    let mut our_tasks = 0usize;
    let mut our_runs = 0usize;
    for e in evs {
        let ph = e.get("ph").and_then(|p| p.as_str()).expect("every event has ph");
        assert_eq!(e.get("pid").and_then(|p| p.as_f64()), Some(1.0));
        let lane = e.get("tid").and_then(|t| t.as_f64()).expect("every event has tid") as i64;
        match ph {
            "X" => {
                let ts = e.get("ts").and_then(|t| t.as_f64()).expect("complete event has ts");
                let dur = e.get("dur").and_then(|d| d.as_f64()).expect("complete event has dur");
                assert!(dur >= 0.0);
                // each lane is one thread's ring: chronological order
                if let Some(prev) = last_ts.insert(lane, ts) {
                    assert!(ts >= prev, "lane {lane} timestamps not monotone");
                }
                let args = e.get("args").expect("slice has args");
                let of_run = args.get("trace").and_then(|t| t.as_f64()) == Some(tid as f64);
                match e.get("cat").and_then(|c| c.as_str()) {
                    Some("task") if of_run => our_tasks += 1,
                    Some("run") if of_run => our_runs += 1,
                    Some("task") | Some("run") => {}
                    other => panic!("unexpected slice category {other:?}"),
                }
            }
            "M" => {
                let name = e.get("args").and_then(|a| a.get("name")).and_then(|n| n.as_str());
                assert!(name.is_some(), "metadata event names its process/thread");
            }
            "s" | "f" => {
                assert!(e.get("id").and_then(|i| i.as_f64()).is_some(), "flow event has id");
            }
            other => panic!("unexpected event phase {other:?}"),
        }
    }
    assert_eq!(our_tasks, plan.dag.tasks.len(), "every DAG task exported exactly once");
    assert_eq!(our_runs, 1, "one run span for one refactorize");
}

#[test]
fn tracing_records_every_task_and_never_changes_the_factors() {
    let _g = lock();
    let a = gen::circuit_bbd(gen::CircuitParams { n: 300, ..Default::default() });
    for workers in [1u32, 2, 8] {
        let opts = SolveOptions::ours(workers);
        let plan = Arc::new(FactorPlan::build(&a, &opts).unwrap());
        let nblocks = plan.structure.blocks.len();

        // oracle: same plan, tracing off
        trace::set_enabled(false);
        let mut off = SolverSession::from_plan(plan.clone());
        off.refactorize(&a.values).unwrap();
        let oracle: Vec<Vec<f64>> =
            (0..nblocks).map(|id| off.numeric().block_values(id as u32)).collect();

        trace::set_enabled(true);
        let mut on = SolverSession::from_plan(plan.clone());
        let tid = trace::next_trace_id();
        on.set_trace_id(tid);
        on.refactorize(&a.values).unwrap();
        let snap = trace::snapshot();
        trace::set_enabled(false);

        let events: Vec<trace::TraceEvent> = snap
            .all_events()
            .into_iter()
            .filter(|e| e.trace_id == tid)
            .collect();
        let tasks: Vec<&trace::TraceEvent> =
            events.iter().filter(|e| e.kind == trace::EventKind::Task).collect();
        assert_eq!(
            tasks.len(),
            plan.dag.tasks.len(),
            "every task recorded exactly once (workers={workers})"
        );
        let mut seen = vec![false; plan.dag.tasks.len()];
        for e in &tasks {
            assert!(!seen[e.task as usize], "task {} recorded twice", e.task);
            seen[e.task as usize] = true;
            assert!(e.worker < workers, "worker id in range");
            assert!(e.end_ns >= e.start_ns);
            if workers == 1 {
                assert_eq!(e.stolen_from, -1, "inline path never steals");
            }
        }
        let runs = events.iter().filter(|e| e.kind == trace::EventKind::Run).count();
        assert_eq!(runs, 1, "one run span per refactorize (workers={workers})");

        // observation only: bit-identical factors with tracing on
        for (id, oracle_block) in oracle.iter().enumerate() {
            assert_eq!(
                &on.numeric().block_values(id as u32),
                oracle_block,
                "block {id} differs with tracing on (workers={workers})"
            );
        }
    }
}

#[test]
fn ring_overflow_drops_oldest_and_is_counted() {
    let _g = lock();
    // record_task writes to this thread's private lane unconditionally
    // (the on/off gate lives at run submission), so the test owns every
    // event it finds under its marker run id
    let marker = 0x00DE_AD00_u64;
    let total = trace::RING_CAPACITY + 123;
    let t = Instant::now();
    for i in 0..total {
        trace::record_task(trace::TaskSpan {
            run_id: marker,
            trace_id: 0,
            task: i as u32,
            op: "ssssm",
            target: (1, 2),
            level: 0,
            worker: 0,
            stolen_from: -1,
            start: t,
            end: t,
        });
    }
    let snap = trace::snapshot();
    let lane = snap
        .lanes
        .iter()
        .find(|l| l.events.iter().any(|e| e.run_id == marker))
        .expect("this thread's lane was registered");
    let ours: Vec<u32> =
        lane.events.iter().filter(|e| e.run_id == marker).map(|e| e.task).collect();
    // the ring retained exactly its capacity: the newest window, in order
    assert_eq!(ours.len(), trace::RING_CAPACITY);
    assert_eq!(ours[0] as usize, total - trace::RING_CAPACITY);
    assert_eq!(*ours.last().unwrap() as usize, total - 1);
    let expected: Vec<u32> = ((total - trace::RING_CAPACITY) as u32..total as u32).collect();
    assert_eq!(ours, expected, "oldest dropped, newest retained in order");
    assert!(snap.dropped_events >= 123, "overflow surfaced as dropped_events");
}
