//! Shared property-test harness for the integration suites (the proptest
//! crate is unavailable offline): seeded random matrix generators, a
//! dense reference solver, and proptest-style shrinking helpers that
//! bisect a failing case down to a minimal reproducer before reporting.
#![allow(dead_code)]

use sparselu::session::FactorPlan;
use sparselu::sparse::{Coo, Csc};
use sparselu::util::Prng;

pub mod blocks;

/// Random diagonally-dominant sparse matrix with seed-derived size.
pub fn random_matrix(seed: u64) -> Csc {
    let mut rng = Prng::new(seed);
    let n = 20 + rng.below(230);
    random_matrix_with(&mut rng, n)
}

/// Like [`random_matrix`] but with the size forced to `n` — the knob the
/// shrinker turns. Consumes the same leading PRNG draw so the value
/// stream beyond the size choice matches [`random_matrix`].
pub fn random_matrix_sized(seed: u64, n: usize) -> Csc {
    let mut rng = Prng::new(seed);
    let _ = rng.below(230); // keep the stream aligned with random_matrix
    random_matrix_with(&mut rng, n)
}

fn random_matrix_with(rng: &mut Prng, n: usize) -> Csc {
    let per_row = 1 + rng.below(5);
    let mut coo = Coo::with_capacity(n, n, n * (per_row + 1));
    for i in 0..n {
        for _ in 0..per_row {
            let j = rng.below(n);
            if j != i {
                coo.push(i, j, rng.signed_unit());
            }
        }
    }
    let m = coo.to_csc();
    let mut row_abs = vec![0.0; n];
    for j in 0..n {
        for (i, v) in m.col(j) {
            if i != j {
                row_abs[i] += v.abs();
            }
        }
    }
    let mut out = Coo::with_capacity(n, n, m.nnz() + n);
    for j in 0..n {
        for (i, v) in m.col(j) {
            if i != j {
                out.push(i, j, v);
            }
        }
    }
    for i in 0..n {
        out.push(i, i, row_abs[i] + 1.0);
    }
    out.to_csc()
}

/// Same pattern as `a`, values perturbed deterministically.
pub fn perturbed(a: &Csc, seed: u64) -> Csc {
    let mut rng = Prng::new(seed);
    let values: Vec<f64> = a
        .values
        .iter()
        .map(|v| v * (1.0 + 0.05 * rng.signed_unit()))
        .collect();
    Csc::from_parts_unchecked(
        a.n_rows(),
        a.n_cols(),
        a.col_ptr.clone(),
        a.row_idx.clone(),
        values,
    )
}

/// `(row, col)` coordinate of every CSC value index of `a`, in order.
pub fn value_coords(a: &Csc) -> Vec<(usize, usize)> {
    let mut coords = Vec::with_capacity(a.nnz());
    for j in 0..a.n_cols() {
        for &i in a.col_rows(j) {
            coords.push((i, j));
        }
    }
    coords
}

/// Grid block coordinates the A-entry at `(i, j)` lands in under `plan`'s
/// permutation and blocking (the external mirror of the plan's scatter
/// map, for choosing block-confined change sets in tests).
pub fn block_of_entry(plan: &FactorPlan, (i, j): (usize, usize)) -> (usize, usize) {
    let p = plan.permutation().as_slice();
    let positions = plan.structure.blocking.positions();
    (block_index_of(positions, p[i]), block_index_of(positions, p[j]))
}

fn block_index_of(positions: &[usize], r: usize) -> usize {
    positions.partition_point(|&p| p <= r) - 1
}

/// Solve `Aᵀ x = b` by dense Gaussian elimination with partial pivoting —
/// the oracle the blocked transpose solves are differenced against.
pub fn dense_solve_transpose(a: &Csc, b: &[f64]) -> Vec<f64> {
    let n = a.n_rows();
    assert_eq!(n, a.n_cols());
    assert_eq!(b.len(), n);
    let mut m = a.transpose().to_dense();
    let mut x = b.to_vec();
    for c in 0..n {
        // partial pivoting
        let piv = (c..n)
            .max_by(|&r1, &r2| m[r1][c].abs().partial_cmp(&m[r2][c].abs()).unwrap())
            .unwrap();
        m.swap(c, piv);
        x.swap(c, piv);
        assert!(m[c][c] != 0.0, "dense oracle: singular matrix");
        let prow: Vec<f64> = m[c][c..n].to_vec();
        let xc = x[c];
        for r in c + 1..n {
            let f = m[r][c] / prow[0];
            if f == 0.0 {
                continue;
            }
            for (t, cc) in (c..n).enumerate() {
                m[r][cc] -= f * prow[t];
            }
            x[r] -= f * xc;
        }
    }
    for c in (0..n).rev() {
        let mut acc = x[c];
        for cc in c + 1..n {
            acc -= m[c][cc] * x[cc];
        }
        x[c] = acc / m[c][c];
    }
    x
}

/// Proptest-style shrinking: reduce a failing case before reporting it.
pub mod shrink {
    /// Delta-debugging (ddmin) subset minimization: repeatedly drop
    /// chunks of `items` while `fails` keeps returning `true`, ending at
    /// a locally-minimal failing subset (order preserved).
    ///
    /// `fails(&[])` is probed last; if even the empty set fails, the
    /// empty set is returned (the items were irrelevant to the failure).
    pub fn minimize_subset<T: Clone>(
        items: &[T],
        mut fails: impl FnMut(&[T]) -> bool,
    ) -> Vec<T> {
        let mut cur = items.to_vec();
        let mut granularity = 2usize;
        while cur.len() >= 2 {
            let chunk = (cur.len() + granularity - 1) / granularity;
            let mut reduced: Option<Vec<T>> = None;
            let mut start = 0;
            while start < cur.len() {
                let end = (start + chunk).min(cur.len());
                let cand: Vec<T> = cur[..start]
                    .iter()
                    .chain(cur[end..].iter())
                    .cloned()
                    .collect();
                if fails(&cand) {
                    reduced = Some(cand);
                    break;
                }
                start = end;
            }
            match reduced {
                Some(cand) => {
                    cur = cand;
                    granularity = granularity.saturating_sub(1).max(2);
                }
                None if granularity >= cur.len() => break,
                None => granularity = (granularity * 2).min(cur.len()),
            }
        }
        if cur.len() == 1 && fails(&[]) {
            cur.clear();
        }
        cur
    }

    /// Smallest scalar in `[lo, hi]` for which `fails` holds, by
    /// bisection. Assumes `fails(hi)`; best-effort if non-monotone.
    pub fn minimize_scalar(lo: usize, hi: usize, mut fails: impl FnMut(usize) -> bool) -> usize {
        let (mut lo, mut hi) = (lo, hi);
        while lo < hi {
            let mid = lo + (hi - lo) / 2;
            if fails(mid) {
                hi = mid;
            } else {
                lo = mid + 1;
            }
        }
        hi
    }
}
