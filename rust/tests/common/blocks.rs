//! Block-shape/density generators shared by the kernel differential rig
//! (`tests/kernel_differential.rs`), the kernel micro-bench
//! (`benches/kernels.rs`), and the property suite (`tests/proptests.rs`).
//!
//! The dense buffers come from the crate's seeded generators
//! ([`gen::dense_dd_density`] / [`gen::dense_uniform_density`]) so every
//! consumer draws from the same distribution; this module adds the shape
//! suites (square / tall / wide / 1×1 / empty-pattern) and seeded random
//! shape drawing for the property tests.
#![allow(dead_code)]

use sparselu::sparse::gen;
use sparselu::util::Prng;

/// GETRF sizes: 1×1 degenerate, sub-register-tile, exact register-tile
/// multiples, off-multiples that exercise the tail paths, and
/// dense-region sizes.
pub const GETRF_SIZES: &[usize] = &[1, 2, 3, 5, 8, 13, 16, 31, 32, 33, 64, 96];

/// Panel shapes `(rows, cols)` for the TRSM kernels: square, tall, wide,
/// single-row/column degenerates.
pub const PANEL_SHAPES: &[(usize, usize)] =
    &[(1, 1), (1, 7), (7, 1), (8, 8), (5, 13), (13, 5), (32, 32), (48, 9), (9, 48), (64, 64)];

/// GEMM shapes `(m, k, n)`: square, tall, wide, rank-1 (`k = 1`), thin
/// inner dimension, and register-tile off-multiples.
pub const GEMM_SHAPES: &[(usize, usize, usize)] = &[
    (1, 1, 1),
    (8, 8, 8),
    (7, 3, 5),
    (33, 17, 9),
    (64, 1, 64),
    (64, 64, 64),
    (96, 32, 96),
    (13, 64, 13),
];

/// Fill densities the rig sweeps: empty pattern (all structural zeros),
/// sparse fill, the dense-kernel selection threshold region, full.
pub const DENSITIES: &[f64] = &[0.0, 0.25, 0.5, 1.0];

/// Diagonally-dominant `n×n` block at the given off-diagonal density
/// (nonsingular at every density — the diagonal always dominates).
pub fn dd_block(n: usize, density: f64, seed: u64) -> Vec<f64> {
    gen::dense_dd_density(n, density, seed)
}

/// `m×n` panel at the given density (`0.0` gives the all-zero
/// empty-pattern panel).
pub fn panel(m: usize, n: usize, density: f64, seed: u64) -> Vec<f64> {
    gen::dense_uniform_density(m, n, density, seed)
}

/// Achieved nonzero fraction of a buffer.
pub fn density_of(buf: &[f64]) -> f64 {
    gen::buffer_density(buf)
}

/// Seed-derived random GEMM shape + density for property tests: each
/// dimension in `1..=max_dim`, density drawn from [`DENSITIES`].
pub fn random_gemm_case(seed: u64, max_dim: usize) -> (usize, usize, usize, f64) {
    let mut rng = Prng::new(seed);
    let m = 1 + rng.below(max_dim);
    let k = 1 + rng.below(max_dim);
    let n = 1 + rng.below(max_dim);
    let d = DENSITIES[rng.below(DENSITIES.len())];
    (m, k, n, d)
}

/// Seed-derived random square size + density for GETRF property tests.
pub fn random_getrf_case(seed: u64, max_dim: usize) -> (usize, f64) {
    let mut rng = Prng::new(seed);
    // never 0-density off-diagonals alone decide singularity — dd_block
    // keeps the diagonal dominant at every density
    (1 + rng.below(max_dim), DENSITIES[rng.below(DENSITIES.len())])
}

/// Bitwise equality of two f64 buffers — the differential rig's
/// comparator (exact equality of bit patterns, not approximate closeness).
pub fn bits_equal(a: &[f64], b: &[f64]) -> Option<usize> {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).position(|(x, y)| x.to_bits() != y.to_bits())
}
