//! Minimal bench harness (criterion is not vendored offline): warmup +
//! N timed repetitions, reporting min/median/mean.

use std::time::Instant;

pub struct BenchResult {
    pub name: String,
    pub median: f64,
    pub min: f64,
    pub mean: f64,
    pub reps: usize,
}

/// Time `f` with one warmup and up to `reps` repetitions (capped at
/// ~2s total), reporting seconds.
pub fn bench<T>(name: &str, reps: usize, mut f: impl FnMut() -> T) -> BenchResult {
    // warmup
    let t0 = Instant::now();
    std::hint::black_box(f());
    let warm = t0.elapsed().as_secs_f64();
    let budget = 2.0f64;
    let reps = reps.min(((budget / warm.max(1e-9)) as usize).max(1));
    let mut times = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t = Instant::now();
        std::hint::black_box(f());
        times.push(t.elapsed().as_secs_f64());
    }
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let median = times[times.len() / 2];
    let min = times[0];
    let mean = times.iter().sum::<f64>() / times.len() as f64;
    let r = BenchResult { name: name.to_string(), median, min, mean, reps };
    println!(
        "{:44} median {:>10.6}s  min {:>10.6}s  mean {:>10.6}s  ({} reps)",
        r.name, r.median, r.min, r.mean, r.reps
    );
    r
}

pub fn section(title: &str) {
    println!("\n=== {title} ===");
}
