//! Serving-layer throughput bench: the closed-loop load generator
//! (`serve::loadgen`) drives a shared-plan `SessionPool` with 8 client
//! threads over two matrices and two scenario mixes, then a
//! multi-tenant scenario routes the same client count over three
//! distinct sparsity patterns through `serve::Router`, reporting
//! throughput and p50/p99 latency per scenario and per tenant.
//!
//! Emits `BENCH_serve.json` in the working directory (uploaded by CI
//! next to `BENCH_refactor.json`).
//!
//! ```text
//! cargo bench --bench serve
//! ```

use sparselu::serve::loadgen::{self, LoadgenConfig, MultiTenantConfig};
use sparselu::serve::{RouterConfig, ScenarioMix};
use sparselu::session::FactorPlan;
use sparselu::solver::SolveOptions;
use sparselu::sparse::gen;
use std::io::Write;
use std::sync::Arc;

fn main() {
    let suite = [
        (
            "ASIC-like-bbd",
            gen::circuit_bbd(gen::CircuitParams {
                n: 1500,
                border_frac: 0.05,
                border_density: 0.35,
                interior_deg: 2,
                seed: 0x680F,
            }),
            // SPICE-shaped traffic: stamps dominate
            ScenarioMix { full: 1, stamp: 6, solve: 3 },
        ),
        (
            "ecology-like-grid2d",
            gen::grid2d_laplacian(38, 38),
            // solver-service-shaped traffic: solves dominate
            ScenarioMix { full: 2, stamp: 2, solve: 6 },
        ),
    ];
    let opts = SolveOptions::ours(1);
    let mut objects = Vec::new();

    for (name, a, mix) in &suite {
        println!("\n=== {name} (n={}, nnz={}) ===", a.n_rows(), a.nnz());
        let plan = Arc::new(FactorPlan::build(a, &opts).unwrap());
        let cfg = LoadgenConfig {
            clients: 8,
            requests_per_client: 24,
            pool_sessions: 4,
            mix: *mix,
            seed: 0xBE7C,
        };
        let report = loadgen::run(a, plan, &cfg);
        println!(
            "{} requests in {:.3}s -> {:.1} req/s  (sessions created: {}, \
             tasks {} executed / {} skipped)",
            report.total_requests,
            report.wall_seconds,
            report.throughput_rps,
            report.sessions_created,
            report.tasks_executed,
            report.tasks_skipped,
        );
        for (scenario, s) in &report.per_scenario {
            if s.count == 0 {
                continue;
            }
            println!(
                "  {scenario:6} x{:<4} p50 {:>9.6}s  p99 {:>9.6}s  max {:>9.6}s",
                s.count, s.p50_s, s.p99_s, s.max_s
            );
        }
        objects.push(report.to_json(name, a.n_rows(), a.nnz()).trim_end().to_string());
    }

    // multi-tenant scenario: 8 clients spread over 3 distinct patterns,
    // routed by fingerprint through serve::Router to concurrent shards
    let tenants = vec![
        (
            "ASIC-like-bbd".to_string(),
            gen::circuit_bbd(gen::CircuitParams { n: 900, ..Default::default() }),
        ),
        ("ecology-like-grid2d".to_string(), gen::grid2d_laplacian(30, 30)),
        ("fem-like-banded".to_string(), gen::banded_fem(800, &[1, 2, 3, 40, 41], 0.85, 0xFE3)),
    ];
    println!("\n=== multi-tenant ({} patterns) ===", tenants.len());
    let mcfg = MultiTenantConfig {
        clients: 8,
        requests_per_client: 24,
        burst: 4,
        mix: ScenarioMix::default(),
        seed: 0xBE7C,
        router: RouterConfig::default(),
    };
    let multi = loadgen::run_multi(&tenants, &opts, &mcfg);
    println!(
        "{} requests in {:.3}s -> {:.1} req/s across {} tenants",
        multi.total_requests, multi.wall_seconds, multi.throughput_rps, multi.tenants
    );
    for t in &multi.per_tenant {
        println!(
            "  {:20} x{:<4} {:.1} req/s  p50 {:>9.6}s  p99 {:>9.6}s",
            t.name, t.completed, t.throughput_rps, t.latency.p50_s, t.latency.p99_s
        );
    }
    objects.push(multi.to_json().trim_end().to_string());

    let json = format!(
        "{{\n\"bench\": \"serve-suite\",\n\"results\": [\n{}\n]\n}}\n",
        objects.join(",\n")
    );
    let path = "BENCH_serve.json";
    let mut f = std::fs::File::create(path).expect("create BENCH_serve.json");
    f.write_all(json.as_bytes()).expect("write BENCH_serve.json");
    println!("\nwrote {path}");
}
