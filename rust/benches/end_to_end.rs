//! End-to-end factorization benches: one row per paper table, at Small
//! scale for quick iteration (the full tables come from `repro bench`).

mod common;

use common::{bench, section};
use sparselu::bench_harness::{paper_suite, SuiteScale};
use sparselu::solver::{SolveOptions, Solver};

fn main() {
    section("numeric factorization per suite matrix (Small scale, 1 worker)");
    for m in paper_suite(SuiteScale::Small) {
        for (tag, opts) in [
            ("ours", SolveOptions::ours(1)),
            ("pangulu", SolveOptions::pangulu(1)),
            ("superlu", SolveOptions::superlu_like(1)),
        ] {
            bench(&format!("{:-18} {tag}", m.name), 5, || {
                let mut solver = Solver::new(opts.clone());
                solver.factorize(&m.matrix).unwrap().report.numeric_seconds
            });
        }
    }

    section("4-worker scaling on the BBD matrix (Table 5 shape)");
    let suite = paper_suite(SuiteScale::Small);
    let asic = suite.iter().find(|m| m.name == "ASIC_680k").unwrap();
    for w in [1u32, 2, 4] {
        bench(&format!("ASIC_680k ours, {w} workers"), 5, || {
            let mut solver = Solver::new(SolveOptions::ours(w));
            solver.factorize(&asic.matrix).unwrap().report.numeric_seconds
        });
    }
}
