//! Preprocessing benchmarks: Algorithm 2 (feature extraction), Algorithm 3
//! (irregular blocking), partitioning and DAG build — the §5.4 costs.

mod common;

use common::{bench, section};
use sparselu::blocking::{
    irregular_blocking, regular_blocking, BlockedMatrix, DiagFeature, IrregularParams,
};
use sparselu::coordinator::{Placement, TaskDag};
use sparselu::gpu_model::CostModel;
use sparselu::numeric::KernelPolicy;
use sparselu::sparse::gen;
use sparselu::symbolic;

fn main() {
    let a = gen::circuit_bbd(gen::CircuitParams { n: 6800, ..Default::default() });
    let sym = symbolic::analyze(&a);
    let ldu = sym.ldu_pattern(&a).unwrap();
    let n = ldu.n_cols();
    println!("matrix: BBD n={n} nnz(L+U)={}", ldu.nnz());

    section("Algorithm 2: diagonal block pointer");
    bench("DiagFeature::from_csc", 100, || DiagFeature::from_csc(&ldu));
    let feature = DiagFeature::from_csc(&ldu);
    bench("curve + 1000-point sampling", 500, || feature.curve().sample(1000));

    section("Algorithm 3 vs regular blocking");
    let curve = feature.curve();
    bench("irregular_blocking (Alg. 3)", 1000, || {
        irregular_blocking(&curve, &IrregularParams::default())
    });
    bench("regular_blocking", 1000, || regular_blocking(n, 283));

    section("partition + DAG build (the preprocessing the paper prices)");
    let irr = irregular_blocking(&curve, &IrregularParams::default());
    let reg = regular_blocking(n, 283);
    bench("BlockedMatrix::build (irregular)", 20, || {
        BlockedMatrix::build(&ldu, irr.clone())
    });
    bench("BlockedMatrix::build (regular)", 20, || {
        BlockedMatrix::build(&ldu, reg.clone())
    });
    let bm_irr = BlockedMatrix::build(&ldu, irr);
    let bm_reg = BlockedMatrix::build(&ldu, reg);
    let model = CostModel::a100();
    let policy = KernelPolicy::default();
    bench("TaskDag::build (irregular)", 20, || {
        TaskDag::build(&bm_irr, &policy, Placement::square(4), &model)
    });
    bench("TaskDag::build (regular)", 20, || {
        TaskDag::build(&bm_reg, &policy, Placement::square(4), &model)
    });
    let dag_irr = TaskDag::build(&bm_irr, &policy, Placement::square(4), &model);
    let dag_reg = TaskDag::build(&bm_reg, &policy, Placement::square(4), &model);
    println!(
        "\nirregular: {} blocks, {} tasks | regular: {} blocks, {} tasks",
        bm_irr.nb(),
        dag_irr.tasks.len(),
        bm_reg.nb(),
        dag_reg.tasks.len()
    );

    section("discrete-event simulation");
    bench("simulate 4 devices (irregular DAG)", 50, || {
        sparselu::coordinator::simulate(&dag_irr, 4, &model)
    });
}
