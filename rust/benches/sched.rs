//! Scheduler bench: the refactorize-storm scenario comparing the
//! spawn-per-call baseline against the persistent work-stealing executor
//! on many tiny full + partial replays — exactly the session/serve
//! steady state the executor exists to make cheap.
//!
//! Emits `BENCH_sched.json` in the working directory (also reachable as
//! `repro sched-bench`).
//!
//! ```text
//! cargo bench --bench sched
//! ```

use std::io::Write;

fn main() {
    let report = sparselu::bench_harness::sched::run(40, &[1, 2, 4]);
    report.print();
    let json = report.to_json();
    let path = "BENCH_sched.json";
    let mut f = std::fs::File::create(path).expect("create BENCH_sched.json");
    f.write_all(json.as_bytes()).expect("write BENCH_sched.json");
    println!("\nwrote {path}");
}
