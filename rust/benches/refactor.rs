//! Cold-vs-warm re-factorization bench: prices exactly what the session
//! subsystem amortizes, on paper-style generator matrices.
//!
//! * **cold** — full `Solver::factorize` (ordering + symbolic + blocking
//!   + DAG + numeric) per call;
//! * **plan** — one `FactorPlan::build` (the structure-only work);
//! * **warm** — `SolverSession::refactorize` per call (numeric only; the
//!   plan is constructed exactly once, before the timed region);
//! * **partial** — `SolverSession::refactorize_partial` with a one-entry
//!   change set confined to the trailing diagonal block (the incremental
//!   path: dirty-block closure + pruned DAG subset);
//! * **cache_hit** — `PlanCache::get_or_build` on a warm cache.
//!
//! Emits `BENCH_refactor.json` in the working directory.
//!
//! ```text
//! cargo bench --bench refactor
//! ```

mod common;

use common::{bench, section};
use sparselu::session::{ChangeSet, FactorPlan, PlanCache, SolverSession};
use sparselu::solver::{SolveOptions, Solver};
use sparselu::sparse::gen;
use std::io::Write;
use std::sync::Arc;

fn main() {
    let suite = [
        (
            "ASIC-like-bbd",
            gen::circuit_bbd(gen::CircuitParams {
                n: 3000,
                border_frac: 0.05,
                border_density: 0.35,
                interior_deg: 2,
                seed: 0x680F,
            }),
        ),
        ("ecology-like-grid2d", gen::grid2d_laplacian(45, 45)),
        ("dielFilter-like-em", gen::electromagnetics_like(2200, 24, 2, 0xD1E1)),
    ];
    let opts = SolveOptions::ours(1);
    let mut rows = Vec::new();

    for (name, a) in &suite {
        section(name);
        let cold = bench(&format!("{name} cold factorize"), 8, || {
            let mut solver = Solver::new(opts.clone());
            solver.factorize(a).expect("cold factorize").report.numeric_seconds
        });

        let plan_build = bench(&format!("{name} FactorPlan::build"), 8, || {
            FactorPlan::build(a, &opts).unwrap().report.nnz_ldu
        });

        // the plan for the warm path is constructed exactly ONCE, here,
        // outside the timed region — refactorize cannot rebuild it (the
        // session API has no path that does structure work)
        let plan = Arc::new(FactorPlan::build(a, &opts).unwrap());
        let mut session = SolverSession::from_plan(plan.clone());
        let warm = bench(&format!("{name} warm refactorize"), 16, || {
            session.refactorize(&a.values).expect("refactorize").numeric_seconds
        });
        assert!(
            Arc::strong_count(&plan) >= 2,
            "the single pre-built plan is the one the session used"
        );

        let refactors = session.refactor_count();

        // incremental: a one-entry change set whose permuted coordinate
        // lands in the trailing diagonal block (the DAG sink), so the
        // pruned subset is as small as it gets
        let p = plan.permutation().as_slice();
        let positions = plan.structure.blocking.positions();
        let last_lo = positions[plan.structure.nb() - 1];
        let r = (0..a.n_rows())
            .find(|&i| p[i] >= last_lo && a.value_index(i, i).is_some())
            .expect("diagonal entry in the trailing block");
        let k = a.value_index(r, r).unwrap();
        let base_v = a.values[k];
        let mut executed = 0usize;
        let mut skipped = 0usize;
        let mut flip = 1.0f64;
        let partial = bench(&format!("{name} partial refactorize (1 entry)"), 16, || {
            flip = -flip; // alternate so every call is a real change
            let cs = ChangeSet::from_value_indices([(k, base_v * (1.5 + 0.1 * flip))]);
            let rep = session.refactorize_partial(&cs).expect("partial refactorize");
            executed = rep.tasks_executed;
            skipped = rep.tasks_skipped;
            executed
        });
        println!(
            "  -> partial refactorize executed {executed} of {} tasks \
             ({skipped} skipped by reachability pruning)",
            executed + skipped
        );

        let mut cache = PlanCache::new(4);
        let _ = cache.get_or_build(a, &opts).unwrap(); // warm the cache (1 miss)
        let cache_hit = bench(&format!("{name} PlanCache hit"), 32, || {
            cache.get_or_build(a, &opts).unwrap().report.nnz_ldu
        });
        assert_eq!(cache.misses(), 1, "warm cache must never rebuild the plan");

        let saving = cold.median - warm.median;
        println!(
            "  -> preprocessing saved per warm call: {saving:.6}s \
             ({:.1}x cold/warm, {} refactorizations through one plan)",
            cold.median / warm.median.max(1e-12),
            refactors,
        );
        rows.push(format!(
            concat!(
                "    {{\"matrix\": \"{}\", \"n\": {}, \"nnz\": {}, ",
                "\"cold_median_s\": {:.9}, \"plan_build_median_s\": {:.9}, ",
                "\"warm_median_s\": {:.9}, \"partial_median_s\": {:.9}, ",
                "\"partial_tasks_executed\": {}, \"partial_tasks_skipped\": {}, ",
                "\"warm_over_partial\": {:.3}, \"cache_hit_median_s\": {:.9}, ",
                "\"preprocess_saving_s\": {:.9}, \"cold_over_warm\": {:.3}, ",
                "\"plan_builds_in_warm_path\": 1, \"warm_refactorizations\": {}}}"
            ),
            name,
            a.n_rows(),
            a.nnz(),
            cold.median,
            plan_build.median,
            warm.median,
            partial.median,
            executed,
            skipped,
            warm.median / partial.median.max(1e-12),
            cache_hit.median,
            saving,
            cold.median / warm.median.max(1e-12),
            refactors,
        ));
    }

    let json = format!(
        "{{\n  \"bench\": \"refactor\",\n  \"results\": [\n{}\n  ]\n}}\n",
        rows.join(",\n")
    );
    let path = "BENCH_refactor.json";
    let mut f = std::fs::File::create(path).expect("create BENCH_refactor.json");
    f.write_all(json.as_bytes()).expect("write BENCH_refactor.json");
    println!("\nwrote {path}");
}
