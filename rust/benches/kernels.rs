//! Micro-benchmarks of the four block kernels (sparse + dense paths) —
//! the inputs to the perf pass (EXPERIMENTS.md §Perf L3).

mod common;
// Shared shape/density generators — same module the kernel differential
// rig (tests/kernel_differential.rs) and proptests draw inputs from.
#[path = "../tests/common/blocks.rs"]
mod blocks;

use common::{bench, section};
use sparselu::blocking::{regular_blocking, BlockedMatrix};
use sparselu::numeric::kernels::{self, Workspace};
use sparselu::numeric::{dense, tiled};
use sparselu::sparse::gen;
use sparselu::symbolic;
use sparselu::util::Prng;

fn main() {
    section("sparse kernels on BBD blocks (block size 256)");
    let a = gen::circuit_bbd(gen::CircuitParams { n: 2048, ..Default::default() });
    let sym = symbolic::analyze(&a);
    let ldu = sym.ldu_pattern(&a).unwrap();
    let bm = BlockedMatrix::build(&ldu, regular_blocking(2048, 256));
    let nb = bm.nb();
    let mut ws = Workspace::with_capacity(512);

    // representative blocks: last diagonal (dense-ish) + mid panels
    let diag_id = bm.block_id(nb - 1, nb - 1).unwrap();
    let diag = bm.block(diag_id);
    println!(
        "diag block ({},{}) nnz={} density={:.3}",
        nb - 1,
        nb - 1,
        diag.nnz(),
        diag.density()
    );
    bench("sparse GETRF (dense-ish diag block)", 50, || {
        let mut vals = diag.values.clone();
        kernels::getrf(diag, &mut vals, &mut ws).unwrap()
    });

    let first_diag = bm.block(bm.block_id(0, 0).unwrap());
    bench("sparse GETRF (sparse diag block)", 200, || {
        let mut vals = first_diag.values.clone();
        kernels::getrf(first_diag, &mut vals, &mut ws).unwrap()
    });

    // factor the first diagonal block once for panel benches
    let mut diag_fact = first_diag.values.clone();
    kernels::getrf(first_diag, &mut diag_fact, &mut ws).unwrap();
    if let Some(uid) = bm.by_row[0].iter().copied().find(|&id| bm.block(id).bj > 0) {
        let upat = bm.block(uid);
        bench("sparse GESSM (U panel)", 200, || {
            let mut v = upat.values.clone();
            kernels::gessm(upat, &mut v, first_diag, &diag_fact, &mut ws)
        });
    }
    if let Some(lid) = bm.by_col[0].iter().copied().find(|&id| bm.block(id).bi > 0) {
        let lpat = bm.block(lid);
        bench("sparse TSTRF (L panel)", 200, || {
            let mut v = lpat.values.clone();
            kernels::tstrf(lpat, &mut v, first_diag, &diag_fact, &mut ws)
        });
        // SSSSM with the densest available target
        let tgt_bi = bm.block(lid).bi as usize;
        if let Some(uid) = bm.by_row[0].iter().copied().find(|&id| bm.block(id).bj > 0) {
            let tgt_bj = bm.block(uid).bj as usize;
            if let Some(cid) = bm.block_id(tgt_bi, tgt_bj) {
                let (cpat, apat, bpat) = (bm.block(cid), bm.block(lid), bm.block(uid));
                let flops = kernels::flops::ssssm(apat, bpat, cpat);
                let r = bench("sparse SSSSM (Schur update)", 400, || {
                    let mut v = cpat.values.clone();
                    kernels::ssssm(cpat, &mut v, apat, &apat.values, bpat, &bpat.values, &mut ws)
                });
                println!("  SSSSM ~{:.0} Mflop/s (sparse)", flops / r.median / 1e6);
            }
        }
    }

    section("dense kernels: scalar oracle vs tiled fast path");
    // The dense kernels are skip-free (no value-dependent branches), so
    // timing at density 0.5 vs 1.0 should be indistinguishable — running
    // both makes that visible in the output.
    for n in [64usize, 128, 256] {
        for &d in &[0.5, 1.0] {
            let a = blocks::dd_block(n, d, n as u64);
            let r = bench(&format!("scalar GETRF {n}x{n} d={d}"), 100, || {
                let mut m = a.clone();
                dense::getrf_in_place(&mut m, n).unwrap()
            });
            let flops = kernels::flops::getrf_dense(n);
            println!("  ~{:.0} Mflop/s", flops / r.median / 1e6);
            let rt = bench(&format!("tiled  GETRF {n}x{n} d={d}"), 100, || {
                let mut m = a.clone();
                tiled::getrf_in_place(&mut m, n).unwrap()
            });
            println!("  ~{:.0} Mflop/s ({:.2}x)", flops / rt.median / 1e6, r.median / rt.median);

            let b = blocks::panel(n, n, d, n as u64 + 1);
            let c = blocks::panel(n, n, 1.0, n as u64 + 2);
            let r = bench(&format!("scalar GEMM  {n}x{n} d={d}"), 100, || {
                let mut m = c.clone();
                dense::gemm_update(&mut m, &a, &b, n, n, n)
            });
            let flops = kernels::flops::ssssm_dense(n, n, n);
            println!("  ~{:.0} Mflop/s", flops / r.median / 1e6);
            let rt = bench(&format!("tiled  GEMM  {n}x{n} d={d}"), 100, || {
                let mut m = c.clone();
                tiled::gemm_update(&mut m, &a, &b, n, n, n)
            });
            println!("  ~{:.0} Mflop/s ({:.2}x)", flops / rt.median / 1e6, r.median / rt.median);
        }
    }

    // PJRT artifact path (L1 Pallas kernels through the xla runtime) —
    // measures the dispatch + execution overhead vs the pure-rust path.
    let art_dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if art_dir.join("manifest.txt").exists() {
        use sparselu::numeric::factor::DenseBackend;
        use sparselu::runtime::PjrtDense;
        section("PJRT artifact kernels (AOT Pallas via xla crate)");
        let pjrt = PjrtDense::load(&art_dir).expect("load artifacts");
        for n in [64usize, 128, 256] {
            let mut rng = Prng::new(n as u64);
            let mut a: Vec<f64> = (0..n * n).map(|_| rng.signed_unit()).collect();
            for i in 0..n {
                a[i * n + i] = n as f64;
            }
            let b: Vec<f64> = (0..n * n).map(|_| rng.signed_unit()).collect();
            let c: Vec<f64> = (0..n * n).map(|_| rng.signed_unit()).collect();
            let r = bench(&format!("PJRT GEMM   {n}x{n}"), 50, || {
                let mut m = c.clone();
                pjrt.gemm(&mut m, &a, &b, n, n, n)
            });
            let flops = 2.0 * (n as f64).powi(3);
            println!("  ~{:.0} Mflop/s (incl. dispatch)", flops / r.median / 1e6);
            let r = bench(&format!("PJRT GETRF  {n}x{n}"), 50, || {
                let mut m = a.clone();
                pjrt.getrf(&mut m, n).unwrap()
            });
            let flops = 2.0 / 3.0 * (n as f64).powi(3);
            println!("  ~{:.0} Mflop/s (incl. dispatch)", flops / r.median / 1e6);
        }
    } else {
        println!("\n(PJRT bench skipped: run `make artifacts`)");
    }
}
