//! # sparselu — structure-aware irregular blocking for sparse LU factorization
//!
//! Reproduction of *"A Structure-Aware Irregular Blocking Method for Sparse
//! LU Factorization"* (Hu, Xiong, Huang, Wu, Jiang — CS.DC 2025).
//!
//! The crate implements the full solver stack the paper builds on:
//!
//! * [`sparse`] — CSC/CSR/COO formats, MatrixMarket IO and synthetic matrix
//!   generators matching the SuiteSparse kinds of the paper's Table 3.
//! * [`ordering`] — fill-reducing orderings (minimum degree, RCM).
//! * [`symbolic`] — elimination tree and symbolic factorization (L+U fill
//!   pattern, flop counts).
//! * [`blocking`] — the paper's contribution: the diagonal block-based
//!   feature (Algorithm 2), the structure-aware irregular blocking method
//!   (Algorithm 3), plus the regular-blocking and PanguLU-selection-tree
//!   baselines, and the blocked-matrix builder with its dependency DAG.
//! * [`numeric`] — right-looking blocked LU numeric factorization with
//!   sparse kernels (GETRF/GESSM/TSTRF/SSSSM) and a dense kernel path that
//!   dispatches to AOT-compiled XLA/PJRT artifacts.
//! * [`coordinator`] — dependency-DAG scheduler, the persistent
//!   work-stealing executor ([`coordinator::Executor`]: per-worker
//!   deques, targeted wakeups, parking, reusable per-run
//!   [`coordinator::RunState`] — shared process-wide per worker count),
//!   2D block-cyclic placement, load-balance metrics, and the
//!   spawn-per-call baseline scheduler kept for `repro sched-bench`.
//! * [`gpu_model`] — A100 roofline cost model used to report modeled GPU
//!   times alongside measured CPU wall-clock.
//! * [`runtime`] — PJRT CPU client wrapper loading `artifacts/*.hlo.txt`.
//! * [`solver`] — the high-level one-shot [`solver::Solver`] API.
//! * [`session`] — plan-cached re-factorization: an immutable
//!   [`session::FactorPlan`] (ordering + symbolic + blocking + DAG +
//!   placement, built once per sparsity pattern), a
//!   [`session::SolverSession`] whose `refactorize` re-runs only the
//!   numeric phase over preallocated storage, a
//!   [`session::PlanCache`] (LRU on
//!   [`sparse::Csc::pattern_fingerprint`]) for serving workloads, and
//!   **incremental** re-factorization
//!   ([`session::SolverSession::refactorize_partial`] +
//!   [`session::ChangeSet`]): when only a few A-values change, only the
//!   DAG tasks reachable from the dirty blocks re-execute.
//! * [`serve`] — the multi-client serving layer over `session`:
//!   [`serve::SessionPool`] (N sessions sharing one plan,
//!   checkout/checkin, lazy growth), [`serve::Batcher`] (bounded queue
//!   coalescing solves into multi-RHS sweeps, coalescing consecutive
//!   stamps into one merged change set, and routing stamps partial vs
//!   full via [`session::SolverSession::estimate_partial`]),
//!   [`serve::Router`] (**multi-matrix tenancy**: requests routed by
//!   pattern fingerprint to per-pattern shards — shared plan + pool +
//!   batcher — that drain concurrently on a worker pool, with
//!   `ShardFull` admission control and `PlanCache`-LRU-driven shard
//!   eviction/revival), [`serve::persist`] (versioned checksummed plan
//!   files + [`session::PlanCache::warm_from_dir`] for one-disk-read
//!   cold starts), and [`serve::loadgen`] (the closed-loop single-pool
//!   and multi-tenant throughput / tail-latency benches behind `repro
//!   serve-bench`).
//! * [`obs`] — the observability spine: dependency-free metric
//!   [`obs::Registry`] (atomic counters/gauges, fixed-bucket latency
//!   histograms), Prometheus text exposition 0.0.4
//!   ([`obs::Registry::render`] + strict [`obs::validate`] parser), a
//!   minimal `GET /metrics` scrape endpoint ([`obs::MetricsServer`]),
//!   and the SLO-driven [`obs::Autoscaler`] that resizes per-tenant
//!   session pools / queue bounds and sheds
//!   [`serve::Priority::Low`] traffic under saturation.
//! * [`bench_harness`] — regenerates every table and figure of the paper.
//!
//! `ARCHITECTURE.md` at the repository root walks the whole pipeline —
//! CSC input → ordering → symbolic → structure-aware blocking → DAG
//! scheduling → numeric kernels, and the session/serve layers on top —
//! with a module map and a data-flow diagram of the serving router.
//!
//! ## Quickstart
//!
//! One-shot solve:
//!
//! ```no_run
//! use sparselu::solver::{Solver, SolveOptions, BlockingPolicy};
//! use sparselu::sparse::gen;
//!
//! let a = gen::grid2d_laplacian(64, 64); // ecology1-like 2D problem
//! let opts = SolveOptions { blocking: BlockingPolicy::Irregular, ..Default::default() };
//! let mut solver = Solver::new(opts);
//! let fact = solver.factorize(&a).unwrap();
//! let b = vec![1.0; a.n_rows()];
//! let x = fact.solve(&b);
//! let r = sparselu::sparse::residual(&a, &x, &b);
//! assert!(r < 1e-8);
//! ```
//!
//! ## Session workflow (repeated solves, fixed sparsity)
//!
//! Circuit simulation, Newton iterations and timestepping re-factorize
//! the *same pattern* with *new values* thousands of times. Build the
//! plan once and pay only the numeric phase per step:
//!
//! ```no_run
//! use sparselu::session::{FactorPlan, PlanCache, SolverSession};
//! use sparselu::solver::SolveOptions;
//! use sparselu::sparse::gen;
//! use std::sync::Arc;
//!
//! let a = gen::circuit_bbd(gen::CircuitParams::default());
//! let opts = SolveOptions::ours(4);
//!
//! // one plan per sparsity pattern (or let a PlanCache manage them)
//! let mut cache = PlanCache::new(8);
//! let plan: Arc<FactorPlan> = cache.get_or_build(&a, &opts).unwrap();
//!
//! let mut session = SolverSession::from_plan(plan);
//! for _newton_step in 0..100 {
//!     let values = a.values.clone(); // updated conductances, same pattern
//!     session.refactorize(&values).unwrap(); // numeric-only, no allocation
//!     let rhs: Vec<Vec<f64>> = vec![vec![1.0; a.n_rows()]; 4];
//!     let xs = session.solve_many(&rhs); // batched multi-RHS solve
//!     assert_eq!(xs.len(), 4);
//! }
//! ```
//!
//! ## Incremental re-factorization (sparse value updates)
//!
//! When a step changes only a handful of entries — a SPICE device stamp:
//! one nonlinear transistor re-linearized between Newton iterations
//! touches the 2 diagonal conductance entries of its terminal nodes —
//! even the numeric-only full `refactorize` is overkill. A
//! [`session::ChangeSet`] names the changed entries; the session maps
//! them to their destination blocks through the plan's scatter map,
//! closes the dirty set over the plan's precomputed block dependency
//! edges, and re-runs **only** the reachable DAG tasks against the
//! preserved factors of every other block. The result is bit-identical
//! to a full re-factorization of the updated matrix:
//!
//! ```no_run
//! use sparselu::session::{ChangeSet, FactorPlan, SolverSession};
//! use sparselu::solver::SolveOptions;
//! use sparselu::sparse::gen;
//! use std::sync::Arc;
//!
//! let a = gen::circuit_bbd(gen::CircuitParams::default());
//! let plan = Arc::new(FactorPlan::build(&a, &SolveOptions::ours(4)).unwrap());
//! let mut session = SolverSession::from_plan(plan);
//! session.refactorize(&a.values).unwrap(); // full pass seeds the factors
//!
//! // device stamp: the transistor between nodes 3 and 7 re-linearized —
//! // its two diagonal conductance entries change, nothing else
//! let (g3, g7) = (1.2e-3, 0.8e-3);
//! let stamp = ChangeSet::from_coords(&a, &[(3, 3, g3), (7, 7, g7)]).unwrap();
//! let report = session.refactorize_partial(&stamp).unwrap();
//! // typically: 2 dirty blocks, a small affected closure, most tasks skipped
//! assert!(report.tasks_executed + report.tasks_skipped == session.plan().dag.tasks.len());
//! let x = session.solve(&vec![1.0; a.n_rows()]);
//! assert_eq!(x.len(), a.n_rows());
//! ```

pub mod sparse;
pub mod ordering;
pub mod symbolic;
pub mod blocking;
pub mod numeric;
pub mod coordinator;
pub mod fault;
pub mod gpu_model;
pub mod obs;
pub mod runtime;
pub mod serve;
pub mod session;
pub mod solver;
pub mod bench_harness;
pub mod util;
