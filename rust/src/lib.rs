//! # sparselu — structure-aware irregular blocking for sparse LU factorization
//!
//! Reproduction of *"A Structure-Aware Irregular Blocking Method for Sparse
//! LU Factorization"* (Hu, Xiong, Huang, Wu, Jiang — CS.DC 2025).
//!
//! The crate implements the full solver stack the paper builds on:
//!
//! * [`sparse`] — CSC/CSR/COO formats, MatrixMarket IO and synthetic matrix
//!   generators matching the SuiteSparse kinds of the paper's Table 3.
//! * [`ordering`] — fill-reducing orderings (minimum degree, RCM).
//! * [`symbolic`] — elimination tree and symbolic factorization (L+U fill
//!   pattern, flop counts).
//! * [`blocking`] — the paper's contribution: the diagonal block-based
//!   feature (Algorithm 2), the structure-aware irregular blocking method
//!   (Algorithm 3), plus the regular-blocking and PanguLU-selection-tree
//!   baselines, and the blocked-matrix builder with its dependency DAG.
//! * [`numeric`] — right-looking blocked LU numeric factorization with
//!   sparse kernels (GETRF/GESSM/TSTRF/SSSSM) and a dense kernel path that
//!   dispatches to AOT-compiled XLA/PJRT artifacts.
//! * [`coordinator`] — dependency-DAG scheduler, multi-worker execution
//!   (simulated multi-GPU), 2D block-cyclic placement, load-balance metrics.
//! * [`gpu_model`] — A100 roofline cost model used to report modeled GPU
//!   times alongside measured CPU wall-clock.
//! * [`runtime`] — PJRT CPU client wrapper loading `artifacts/*.hlo.txt`.
//! * [`solver`] — the high-level one-shot [`solver::Solver`] API.
//! * [`session`] — plan-cached re-factorization: an immutable
//!   [`session::FactorPlan`] (ordering + symbolic + blocking + DAG +
//!   placement, built once per sparsity pattern), a
//!   [`session::SolverSession`] whose `refactorize` re-runs only the
//!   numeric phase over preallocated storage, and a
//!   [`session::PlanCache`] (LRU on
//!   [`sparse::Csc::pattern_fingerprint`]) for serving workloads.
//! * [`bench_harness`] — regenerates every table and figure of the paper.
//!
//! ## Quickstart
//!
//! One-shot solve:
//!
//! ```no_run
//! use sparselu::solver::{Solver, SolveOptions, BlockingPolicy};
//! use sparselu::sparse::gen;
//!
//! let a = gen::grid2d_laplacian(64, 64); // ecology1-like 2D problem
//! let opts = SolveOptions { blocking: BlockingPolicy::Irregular, ..Default::default() };
//! let mut solver = Solver::new(opts);
//! let fact = solver.factorize(&a).unwrap();
//! let b = vec![1.0; a.n_rows()];
//! let x = fact.solve(&b);
//! let r = sparselu::sparse::residual(&a, &x, &b);
//! assert!(r < 1e-8);
//! ```
//!
//! ## Session workflow (repeated solves, fixed sparsity)
//!
//! Circuit simulation, Newton iterations and timestepping re-factorize
//! the *same pattern* with *new values* thousands of times. Build the
//! plan once and pay only the numeric phase per step:
//!
//! ```no_run
//! use sparselu::session::{FactorPlan, PlanCache, SolverSession};
//! use sparselu::solver::SolveOptions;
//! use sparselu::sparse::gen;
//! use std::sync::Arc;
//!
//! let a = gen::circuit_bbd(gen::CircuitParams::default());
//! let opts = SolveOptions::ours(4);
//!
//! // one plan per sparsity pattern (or let a PlanCache manage them)
//! let mut cache = PlanCache::new(8);
//! let plan: Arc<FactorPlan> = cache.get_or_build(&a, &opts);
//!
//! let mut session = SolverSession::from_plan(plan);
//! for _newton_step in 0..100 {
//!     let values = a.values.clone(); // updated conductances, same pattern
//!     session.refactorize(&values).unwrap(); // numeric-only, no allocation
//!     let rhs: Vec<Vec<f64>> = vec![vec![1.0; a.n_rows()]; 4];
//!     let xs = session.solve_many(&rhs); // batched multi-RHS solve
//!     assert_eq!(xs.len(), 4);
//! }
//! ```

pub mod sparse;
pub mod ordering;
pub mod symbolic;
pub mod blocking;
pub mod numeric;
pub mod coordinator;
pub mod gpu_model;
pub mod runtime;
pub mod session;
pub mod solver;
pub mod bench_harness;
pub mod util;
