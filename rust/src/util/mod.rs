//! Small shared utilities: a deterministic PRNG (no external crates are
//! available offline), timers, and summary statistics.

pub mod prng;
pub mod stats;
pub mod timer;

pub use prng::Prng;
pub use stats::Summary;
pub use timer::Stopwatch;
