//! Wall-clock stopwatch used for phase timing and benches.

use std::time::Instant;

/// Simple stopwatch accumulating named laps.
#[derive(Debug)]
pub struct Stopwatch {
    start: Instant,
    laps: Vec<(String, f64)>,
    last: Instant,
}

impl Default for Stopwatch {
    fn default() -> Self {
        Self::new()
    }
}

impl Stopwatch {
    pub fn new() -> Self {
        let now = Instant::now();
        Self { start: now, laps: Vec::new(), last: now }
    }

    /// Record a lap since the previous lap (or construction) under `name`.
    pub fn lap(&mut self, name: &str) -> f64 {
        let now = Instant::now();
        let dt = now.duration_since(self.last).as_secs_f64();
        self.laps.push((name.to_string(), dt));
        self.last = now;
        dt
    }

    /// Total elapsed seconds since construction.
    pub fn total(&self) -> f64 {
        self.last.duration_since(self.start).as_secs_f64()
    }

    /// All recorded laps.
    pub fn laps(&self) -> &[(String, f64)] {
        &self.laps
    }

    /// Seconds recorded for a named lap, if present.
    pub fn get(&self, name: &str) -> Option<f64> {
        self.laps.iter().find(|(n, _)| n == name).map(|(_, t)| *t)
    }
}

/// Time a closure, returning (result, seconds).
pub fn timed<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let r = f();
    (r, t0.elapsed().as_secs_f64())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn laps_accumulate_in_order() {
        let mut sw = Stopwatch::new();
        sw.lap("a");
        sw.lap("b");
        assert_eq!(sw.laps().len(), 2);
        assert_eq!(sw.laps()[0].0, "a");
        assert!(sw.get("b").is_some());
        assert!(sw.get("c").is_none());
    }

    #[test]
    fn timed_returns_result() {
        let (v, t) = timed(|| 21 * 2);
        assert_eq!(v, 42);
        assert!(t >= 0.0);
    }
}
