//! Summary statistics used by load-balance metrics and the bench harness.

/// Summary of a sample: count, min, max, mean, standard deviation,
/// coefficient of variation and imbalance factor (max/mean).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Summary {
    pub count: usize,
    pub min: f64,
    pub max: f64,
    pub mean: f64,
    pub stddev: f64,
}

impl Summary {
    /// Compute over a sample; returns an all-zero summary for empty input.
    pub fn of(xs: &[f64]) -> Self {
        if xs.is_empty() {
            return Self { count: 0, min: 0.0, max: 0.0, mean: 0.0, stddev: 0.0 };
        }
        let n = xs.len() as f64;
        let mean = xs.iter().sum::<f64>() / n;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n;
        Self {
            count: xs.len(),
            min: xs.iter().cloned().fold(f64::INFINITY, f64::min),
            max: xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max),
            mean,
            stddev: var.sqrt(),
        }
    }

    /// Coefficient of variation (stddev / mean); 0 when mean is 0.
    pub fn cv(&self) -> f64 {
        if self.mean == 0.0 { 0.0 } else { self.stddev / self.mean }
    }

    /// Imbalance factor max/mean — the classic parallel-load metric.
    /// 1.0 is perfectly balanced.
    pub fn imbalance(&self) -> f64 {
        if self.mean == 0.0 { 1.0 } else { self.max / self.mean }
    }
}

/// Geometric mean of strictly positive values (paper reports GEOMEAN rows).
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let s: f64 = xs.iter().map(|x| x.ln()).sum();
    (s / xs.len() as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_of_constant() {
        let s = Summary::of(&[3.0, 3.0, 3.0]);
        assert_eq!(s.mean, 3.0);
        assert_eq!(s.stddev, 0.0);
        assert_eq!(s.imbalance(), 1.0);
        assert_eq!(s.cv(), 0.0);
    }

    #[test]
    fn summary_of_empty() {
        let s = Summary::of(&[]);
        assert_eq!(s.count, 0);
        assert_eq!(s.mean, 0.0);
    }

    #[test]
    fn summary_min_max() {
        let s = Summary::of(&[1.0, 5.0, 3.0]);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert!((s.mean - 3.0).abs() < 1e-12);
    }

    #[test]
    fn imbalance_detects_skew() {
        let balanced = Summary::of(&[10.0, 10.0, 10.0, 10.0]);
        let skewed = Summary::of(&[1.0, 1.0, 1.0, 37.0]);
        assert!(skewed.imbalance() > 3.0 * balanced.imbalance());
    }

    #[test]
    fn geomean_matches_hand_computation() {
        assert!((geomean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
        assert!((geomean(&[2.0, 2.0, 2.0]) - 2.0).abs() < 1e-12);
        assert_eq!(geomean(&[]), 0.0);
    }
}
