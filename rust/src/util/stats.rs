//! Summary statistics used by load-balance metrics and the bench harness.

/// Summary of a sample: count, min, max, mean, standard deviation,
/// coefficient of variation and imbalance factor (max/mean).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Summary {
    pub count: usize,
    pub min: f64,
    pub max: f64,
    pub mean: f64,
    pub stddev: f64,
}

impl Summary {
    /// Compute over a sample; returns an all-zero summary for empty input.
    pub fn of(xs: &[f64]) -> Self {
        if xs.is_empty() {
            return Self { count: 0, min: 0.0, max: 0.0, mean: 0.0, stddev: 0.0 };
        }
        let n = xs.len() as f64;
        let mean = xs.iter().sum::<f64>() / n;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n;
        Self {
            count: xs.len(),
            min: xs.iter().cloned().fold(f64::INFINITY, f64::min),
            max: xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max),
            mean,
            stddev: var.sqrt(),
        }
    }

    /// Coefficient of variation (stddev / mean); 0 when mean is 0.
    pub fn cv(&self) -> f64 {
        if self.mean == 0.0 { 0.0 } else { self.stddev / self.mean }
    }

    /// Imbalance factor max/mean — the classic parallel-load metric.
    /// 1.0 is perfectly balanced.
    pub fn imbalance(&self) -> f64 {
        if self.mean == 0.0 { 1.0 } else { self.max / self.mean }
    }

    /// Exact nearest-rank quantile of an **ascending-sorted** sample.
    ///
    /// `q` is clamped to `[0, 1]`; an empty sample yields 0.0. This is
    /// the one definition of p50/p99 shared by the load generator, the
    /// bench harness and the autoscaler, so reported latencies are
    /// comparable across all three.
    pub fn quantile(sorted: &[f64], q: f64) -> f64 {
        if sorted.is_empty() {
            return 0.0;
        }
        let q = q.clamp(0.0, 1.0);
        let rank = ((q * sorted.len() as f64).ceil() as usize).max(1);
        sorted[rank.min(sorted.len()) - 1]
    }
}

/// Quantile estimate from a fixed-bucket histogram, Prometheus
/// `histogram_quantile` style: find the bucket holding the nearest-rank
/// observation and interpolate linearly inside it.
///
/// `bounds` are the ascending finite upper bounds; `counts` are the
/// **per-bucket** (non-cumulative) observation counts and must have
/// `bounds.len() + 1` entries, the last being the implicit `+Inf`
/// bucket. Observations landing in the `+Inf` bucket are reported as the
/// largest finite bound (the histogram cannot resolve beyond it). An
/// empty histogram yields 0.0.
pub fn histogram_quantile(bounds: &[f64], counts: &[u64], q: f64) -> f64 {
    assert_eq!(counts.len(), bounds.len() + 1, "counts must include the +Inf bucket");
    let total: u64 = counts.iter().sum();
    if total == 0 {
        return 0.0;
    }
    let q = q.clamp(0.0, 1.0);
    let rank = ((q * total as f64).ceil() as u64).max(1);
    let mut cum = 0u64;
    for (i, &c) in counts.iter().enumerate() {
        let prev = cum;
        cum += c;
        if cum >= rank {
            if i == bounds.len() {
                // +Inf bucket: unresolvable past the last finite bound.
                return bounds.last().copied().unwrap_or(0.0);
            }
            let lower = if i == 0 { 0.0 } else { bounds[i - 1] };
            let upper = bounds[i];
            let within = (rank - prev) as f64 / c.max(1) as f64;
            return lower + (upper - lower) * within;
        }
    }
    bounds.last().copied().unwrap_or(0.0)
}

/// Geometric mean of strictly positive values (paper reports GEOMEAN rows).
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let s: f64 = xs.iter().map(|x| x.ln()).sum();
    (s / xs.len() as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_of_constant() {
        let s = Summary::of(&[3.0, 3.0, 3.0]);
        assert_eq!(s.mean, 3.0);
        assert_eq!(s.stddev, 0.0);
        assert_eq!(s.imbalance(), 1.0);
        assert_eq!(s.cv(), 0.0);
    }

    #[test]
    fn summary_of_empty() {
        let s = Summary::of(&[]);
        assert_eq!(s.count, 0);
        assert_eq!(s.mean, 0.0);
    }

    #[test]
    fn summary_min_max() {
        let s = Summary::of(&[1.0, 5.0, 3.0]);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert!((s.mean - 3.0).abs() < 1e-12);
    }

    #[test]
    fn imbalance_detects_skew() {
        let balanced = Summary::of(&[10.0, 10.0, 10.0, 10.0]);
        let skewed = Summary::of(&[1.0, 1.0, 1.0, 37.0]);
        assert!(skewed.imbalance() > 3.0 * balanced.imbalance());
    }

    #[test]
    fn geomean_matches_hand_computation() {
        assert!((geomean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
        assert!((geomean(&[2.0, 2.0, 2.0]) - 2.0).abs() < 1e-12);
        assert_eq!(geomean(&[]), 0.0);
    }

    #[test]
    fn quantile_nearest_rank() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(Summary::quantile(&xs, 0.50), 50.0);
        assert_eq!(Summary::quantile(&xs, 0.99), 99.0);
        assert_eq!(Summary::quantile(&xs, 1.0), 100.0);
        assert_eq!(Summary::quantile(&xs, 0.0), 1.0);
        assert_eq!(Summary::quantile(&[], 0.5), 0.0);
        assert_eq!(Summary::quantile(&[7.0], 0.99), 7.0);
    }

    #[test]
    fn histogram_quantile_interpolates_within_bucket() {
        let bounds = [1.0, 2.0, 4.0];
        // 10 obs in (1,2], none elsewhere: p50 is the 5th of 10 → halfway.
        let counts = [0, 10, 0, 0];
        let p50 = histogram_quantile(&bounds, &counts, 0.5);
        assert!((p50 - 1.5).abs() < 1e-12, "got {p50}");
        // all mass past the last bound reports the last finite bound
        assert_eq!(histogram_quantile(&bounds, &[0, 0, 0, 5], 0.5), 4.0);
        // empty histogram
        assert_eq!(histogram_quantile(&bounds, &[0, 0, 0, 0], 0.99), 0.0);
    }

    #[test]
    fn histogram_quantile_spans_buckets() {
        let bounds = [1.0, 2.0];
        // 5 in (0,1], 5 in (1,2]: p99 → rank 10 → top of second bucket.
        let v = histogram_quantile(&bounds, &[5, 5, 0], 0.99);
        assert!((v - 2.0).abs() < 1e-12, "got {v}");
        // p50 → rank 5 → top of first bucket
        let v = histogram_quantile(&bounds, &[5, 5, 0], 0.5);
        assert!((v - 1.0).abs() < 1e-12, "got {v}");
    }
}
