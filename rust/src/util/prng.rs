//! Deterministic xoshiro256** PRNG.
//!
//! The reproduction must be bit-reproducible across runs (benches regenerate
//! paper tables from synthetic matrices), so all randomness flows through
//! this seeded generator rather than OS entropy.

/// xoshiro256** 1.0 — public-domain algorithm by Blackman & Vigna.
#[derive(Clone, Debug)]
pub struct Prng {
    s: [u64; 4],
}

impl Prng {
    /// Create a generator from a seed; any seed (including 0) is valid.
    pub fn new(seed: u64) -> Self {
        // SplitMix64 to spread the seed over the state.
        let mut x = seed.wrapping_add(0x9E3779B97F4A7C15);
        let mut next = || {
            x = x.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Self { s: [next(), next(), next(), next()] }
    }

    /// Next raw 64-bit output.
    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in `[0, n)`. `n` must be nonzero.
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire-style rejection-free reduction is fine for benchmark use.
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform in `[lo, hi)`.
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Signed value uniform in `[-1, 1)` excluding tiny magnitudes, handy
    /// for well-conditioned test values.
    pub fn signed_unit(&mut self) -> f64 {
        let v = self.f64() * 2.0 - 1.0;
        if v.abs() < 0.05 {
            v + 0.1 * v.signum().max(0.0).mul_add(2.0, -1.0)
        } else {
            v
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Choose `k` distinct indices from `[0, n)` (k <= n), sorted.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        // Floyd's algorithm.
        let mut chosen = std::collections::BTreeSet::new();
        for j in n - k..n {
            let t = self.below(j + 1);
            if !chosen.insert(t) {
                chosen.insert(j);
            }
        }
        chosen.into_iter().collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_same_seed() {
        let mut a = Prng::new(42);
        let mut b = Prng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Prng::new(1);
        let mut b = Prng::new(2);
        let same = (0..32).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Prng::new(7);
        for _ in 0..1000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn below_bounds() {
        let mut r = Prng::new(9);
        for n in [1usize, 2, 3, 10, 1000] {
            for _ in 0..200 {
                assert!(r.below(n) < n);
            }
        }
    }

    #[test]
    fn below_covers_small_range() {
        let mut r = Prng::new(11);
        let mut seen = [false; 5];
        for _ in 0..500 {
            seen[r.below(5)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn sample_indices_distinct_sorted() {
        let mut r = Prng::new(3);
        let s = r.sample_indices(100, 20);
        assert_eq!(s.len(), 20);
        assert!(s.windows(2).all(|w| w[0] < w[1]));
        assert!(s.iter().all(|&i| i < 100));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Prng::new(5);
        let mut xs: Vec<usize> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
