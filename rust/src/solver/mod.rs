//! High-level solver API: reorder → symbolic → block → schedule → numeric
//! → solve, with the paper's three configurations as presets:
//!
//! * [`SolveOptions::ours`] — **irregular blocking** (Algorithm 3) +
//!   sparse kernels (the paper's contribution);
//! * [`SolveOptions::pangulu`] — regular blocking via the selection tree +
//!   sparse kernels (the PanguLU baseline);
//! * [`SolveOptions::superlu_like`] — regular blocking + dense kernels
//!   everywhere (the SuperLU_DIST-style supernodal/BLAS baseline).

//! Since the session subsystem landed, `Solver` is a thin wrapper: a
//! one-shot `factorize` builds a [`crate::session::FactorPlan`] and runs
//! one numeric pass over it. Workloads that re-factorize a fixed
//! pattern should hold the plan plus a
//! [`crate::session::SolverSession`] directly (see the
//! [`crate::session`] docs).

use crate::blocking::{BalanceReport, IrregularParams};
use crate::coordinator::{self, Executor, RunState};
use crate::gpu_model::CostModel;
use crate::numeric::factor::{CpuDense, DenseBackend, FactorError, Factors, NumericMatrix};
use crate::numeric::KernelPolicy;
use crate::ordering::{OrderingMethod, Permutation};
use crate::session::FactorPlan;
use crate::sparse::Csc;
use crate::util::timer::timed;
use std::sync::Arc;

/// How to partition the matrix into 2D blocks.
#[derive(Clone, Debug, PartialEq)]
pub enum BlockingPolicy {
    /// Fixed regular block size.
    Regular(usize),
    /// Regular, size picked by PanguLU's selection tree (scaled menu).
    PanguSelect,
    /// The paper's structure-aware irregular blocking.
    Irregular,
}

/// Full solver configuration.
#[derive(Clone, Debug)]
pub struct SolveOptions {
    pub ordering: OrderingMethod,
    pub blocking: BlockingPolicy,
    pub kernels: KernelPolicy,
    pub irregular: IrregularParams,
    /// Worker count (simulated GPUs).
    pub workers: u32,
    /// Device cost model for the modeled numbers.
    pub model: CostModel,
}

impl Default for SolveOptions {
    fn default() -> Self {
        Self {
            ordering: OrderingMethod::MinDegree,
            blocking: BlockingPolicy::Irregular,
            kernels: KernelPolicy::default(),
            irregular: IrregularParams::default(),
            workers: 1,
            model: CostModel::a100(),
        }
    }
}

impl SolveOptions {
    /// The paper's system: irregular blocking + sparse kernels.
    pub fn ours(workers: u32) -> Self {
        Self { workers, ..Default::default() }
    }

    /// PanguLU baseline: selection-tree regular blocking + sparse kernels.
    pub fn pangulu(workers: u32) -> Self {
        Self { blocking: BlockingPolicy::PanguSelect, workers, ..Default::default() }
    }

    /// PanguLU with an explicit block size (the Fig 4/10/12 sweeps).
    pub fn pangulu_with_size(workers: u32, size: usize) -> Self {
        Self { blocking: BlockingPolicy::Regular(size), workers, ..Default::default() }
    }

    /// SuperLU_DIST-like baseline: dense (BLAS-style) kernels everywhere.
    pub fn superlu_like(workers: u32) -> Self {
        Self {
            blocking: BlockingPolicy::PanguSelect,
            kernels: KernelPolicy { force_dense: true, ..Default::default() },
            workers,
            ..Default::default()
        }
    }
}

/// Per-phase timing and structural report (Fig 1 / Table 3 / §5.4 data).
#[derive(Clone, Debug)]
pub struct SolveReport {
    pub n: usize,
    pub nnz_a: usize,
    pub nnz_ldu: usize,
    pub flops: f64,
    pub reorder_seconds: f64,
    pub symbolic_seconds: f64,
    /// Blocking + partitioning + DAG construction (the paper's §5.4
    /// "preprocessing cost" of the numeric phase).
    pub preprocess_seconds: f64,
    pub numeric_seconds: f64,
    pub num_blocks: usize,
    pub block_sizes: Vec<usize>,
    pub nonempty_blocks: usize,
    pub tasks: usize,
    pub dag_levels: u32,
    /// Modeled single-device total cost (Σ task costs).
    pub modeled_total_cost: f64,
    /// Modeled makespan on `workers` devices.
    pub modeled_makespan: f64,
    /// Modeled per-worker utilization.
    pub modeled_utilization: Vec<f64>,
    /// Measured per-worker busy seconds.
    pub measured_busy: Vec<f64>,
    /// Block-level nnz balance.
    pub balance: BalanceReport,
}

impl SolveReport {
    /// Fig 1 quantity: numeric share of end-to-end time.
    pub fn numeric_share(&self) -> f64 {
        let total = self.reorder_seconds
            + self.symbolic_seconds
            + self.preprocess_seconds
            + self.numeric_seconds;
        if total == 0.0 { 0.0 } else { self.numeric_seconds / total }
    }
}

/// A completed factorization: factors + permutation + report.
pub struct Factorization {
    factors: Factors,
    perm: Permutation,
    pub report: SolveReport,
}

impl Factorization {
    /// Solve `A x = b` (applies the fill-reducing permutation around the
    /// blocked triangular solves).
    pub fn solve(&self, b: &[f64]) -> Vec<f64> {
        let pb = self.perm.permute_vec(b);
        let px = self.factors.solve(&pb);
        self.perm.inverse().permute_vec(&px)
    }

    /// Solve the transpose system `Aᵀ x = b` with the same factors
    /// (adjoint/sensitivity solves; `PAPᵀ = LU ⇒ Aᵀ = Pᵀ(LU)ᵀP`).
    pub fn solve_transpose(&self, b: &[f64]) -> Vec<f64> {
        let pb = self.perm.permute_vec(b);
        let px = self.factors.solve_transpose(&pb);
        self.perm.inverse().permute_vec(&px)
    }

    /// Solve with iterative refinement: after the direct solve, apply up
    /// to `max_iters` residual-correction steps (`x += A⁻¹(b − Ax)`),
    /// stopping early once the residual stops improving. Recovers digits
    /// lost to accumulated rounding on ill-scaled systems.
    pub fn solve_refined(&self, a: &Csc, b: &[f64], max_iters: usize) -> Vec<f64> {
        let mut x = self.solve(b);
        let mut best_res = crate::sparse::residual(a, &x, b);
        for _ in 0..max_iters {
            if best_res == 0.0 {
                break;
            }
            let ax = a.mul_vec(&x);
            let r: Vec<f64> = b.iter().zip(&ax).map(|(bi, axi)| bi - axi).collect();
            let dx = self.solve(&r);
            let cand: Vec<f64> = x.iter().zip(&dx).map(|(xi, di)| xi + di).collect();
            let res = crate::sparse::residual(a, &cand, b);
            if res < best_res {
                x = cand;
                best_res = res;
            } else {
                break;
            }
        }
        x
    }

    /// Solve for several right-hand sides (factor once, solve many) —
    /// batched through [`crate::numeric::trisolve::solve_multi`], so the
    /// factor blocks are traversed once for all RHS. Results are
    /// identical to repeated [`Self::solve`] calls.
    pub fn solve_many(&self, bs: &[Vec<f64>]) -> Vec<Vec<f64>> {
        let pbs: Vec<Vec<f64>> = bs.iter().map(|b| self.perm.permute_vec(b)).collect();
        let pxs = crate::numeric::trisolve::solve_multi(&self.factors.numeric, &pbs);
        let inv = self.perm.inverse();
        pxs.iter().map(|px| inv.permute_vec(px)).collect()
    }

    pub fn factors(&self) -> &Factors {
        &self.factors
    }

    pub fn permutation(&self) -> &Permutation {
        &self.perm
    }
}

/// The solver: configuration + dense backend + a handle on the shared
/// persistent executor (repeated `factorize` calls reuse one worker pool
/// and one set of scheduling counters instead of spawning threads per
/// call).
pub struct Solver<'b> {
    opts: SolveOptions,
    backend: &'b (dyn DenseBackend + Sync),
    exec: Arc<Executor>,
    run_state: RunState,
}

impl Solver<'static> {
    /// Solver with the pure-rust dense backend.
    pub fn new(opts: SolveOptions) -> Self {
        static CPU: CpuDense = CpuDense;
        Self::with_backend(opts, &CPU)
    }
}

impl<'b> Solver<'b> {
    /// Solver with a custom dense backend (e.g. [`crate::runtime::PjrtDense`]).
    pub fn with_backend(opts: SolveOptions, backend: &'b (dyn DenseBackend + Sync)) -> Self {
        let exec = Executor::shared(opts.workers);
        Solver { opts, backend, exec, run_state: RunState::new() }
    }

    pub fn options(&self) -> &SolveOptions {
        &self.opts
    }

    /// Run the full pipeline on `a`: build a fresh [`FactorPlan`]
    /// (ordering → symbolic → blocking → DAG), then one numeric pass
    /// over it.
    ///
    /// The one-shot path seeds the numeric storage directly from the
    /// plan's blocked pattern (whose values *are* `a`'s, scattered during
    /// symbolic assembly) instead of going through the session's
    /// zero-and-scatter — identical results, no redundant O(nnz) passes.
    /// Repeated solves on a fixed pattern should hold a
    /// [`crate::session::SolverSession`] instead.
    pub fn factorize(&mut self, a: &Csc) -> Result<Factorization, FactorError> {
        assert_eq!(a.n_rows(), a.n_cols(), "square systems only");
        let plan = Arc::new(FactorPlan::build_for_oneshot(a, &self.opts, Some(&self.exec))?);
        let nm = NumericMatrix::from_blocked(plan.structure.clone());
        let (run, numeric_seconds) = timed(|| {
            coordinator::run_dag(
                &nm,
                &plan.dag,
                &self.opts.kernels,
                self.backend,
                &self.exec,
                &mut self.run_state,
            )
        });
        let run = run?;
        let report = report_from_plan(&plan, numeric_seconds, &run.busy);
        let factors = Factors {
            numeric: nm,
            sparse_ops: run.total_tasks,
            dense_ops: 0,
        };
        Ok(Factorization { factors, perm: plan.permutation().clone(), report })
    }
}

/// Assemble the legacy per-solve report from plan products plus the
/// numeric pass measurements.
fn report_from_plan(plan: &FactorPlan, numeric_seconds: f64, busy: &[f64]) -> SolveReport {
    let bm = &plan.structure;
    let dag = &plan.dag;
    let r = &plan.report;
    SolveReport {
        n: r.n,
        nnz_a: r.nnz_a,
        nnz_ldu: r.nnz_ldu,
        flops: r.flops,
        reorder_seconds: r.reorder_seconds,
        symbolic_seconds: r.symbolic_seconds,
        preprocess_seconds: r.preprocess_seconds,
        numeric_seconds,
        num_blocks: bm.nb(),
        block_sizes: bm.blocking.sizes(),
        nonempty_blocks: bm.num_nonempty(),
        tasks: dag.tasks.len(),
        dag_levels: dag.num_levels,
        modeled_total_cost: dag.total_cost(),
        modeled_makespan: plan.sim.makespan,
        modeled_utilization: plan.sim.utilization.clone(),
        measured_busy: busy.to_vec(),
        balance: plan.balance.clone(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::{gen, residual};

    fn end_to_end(a: &Csc, opts: SolveOptions, tol: f64) -> SolveReport {
        let mut s = Solver::new(opts);
        let f = s.factorize(a).unwrap();
        let n = a.n_cols();
        let b: Vec<f64> = (0..n).map(|i| ((i % 13) as f64) - 6.0).collect();
        let x = f.solve(&b);
        let r = residual(a, &x, &b);
        assert!(r < tol, "residual {r}");
        f.report
    }

    #[test]
    fn ours_solves_grid() {
        let a = gen::grid2d_laplacian(12, 12);
        let rep = end_to_end(&a, SolveOptions::ours(1), 1e-9);
        assert_eq!(rep.n, 144);
        assert!(rep.nnz_ldu >= rep.nnz_a);
        assert!(rep.flops > 0.0);
        assert!(rep.num_blocks >= 1);
    }

    #[test]
    fn pangulu_solves_bbd() {
        let a = gen::circuit_bbd(gen::CircuitParams { n: 400, ..Default::default() });
        end_to_end(&a, SolveOptions::pangulu(1), 1e-9);
    }

    #[test]
    fn superlu_like_solves() {
        let a = gen::banded_fem(150, &[1, 9], 0.9, 4);
        end_to_end(&a, SolveOptions::superlu_like(1), 1e-9);
    }

    #[test]
    fn parallel_workers_solve() {
        let a = gen::electromagnetics_like(300, 8, 2, 6);
        let rep = end_to_end(&a, SolveOptions::ours(4), 1e-9);
        assert_eq!(rep.measured_busy.len(), 4);
        assert_eq!(rep.modeled_utilization.len(), 4);
    }

    #[test]
    fn all_orderings_work() {
        let a = gen::grid2d_laplacian(10, 10);
        for ord in [OrderingMethod::Natural, OrderingMethod::Rcm, OrderingMethod::MinDegree] {
            let opts = SolveOptions { ordering: ord, ..SolveOptions::ours(1) };
            end_to_end(&a, opts, 1e-9);
        }
    }

    #[test]
    fn min_degree_reduces_fill_vs_natural() {
        let a = gen::grid2d_laplacian(14, 14);
        let md = end_to_end(
            &a,
            SolveOptions { ordering: OrderingMethod::MinDegree, ..SolveOptions::ours(1) },
            1e-9,
        );
        let nat = end_to_end(
            &a,
            SolveOptions { ordering: OrderingMethod::Natural, ..SolveOptions::ours(1) },
            1e-9,
        );
        assert!(md.nnz_ldu < nat.nnz_ldu);
    }

    #[test]
    fn explicit_block_size_respected() {
        let a = gen::grid2d_laplacian(10, 10);
        let rep = end_to_end(&a, SolveOptions::pangulu_with_size(1, 25), 1e-9);
        assert_eq!(rep.num_blocks, 4);
        assert!(rep.block_sizes.iter().all(|&s| s == 25));
    }

    #[test]
    fn transpose_solve_through_solver() {
        let a = gen::directed_graph(180, 3, 6);
        let mut s = Solver::new(SolveOptions::ours(2));
        let f = s.factorize(&a).unwrap();
        let mut rng = crate::util::Prng::new(12);
        let x_true: Vec<f64> = (0..180).map(|_| rng.signed_unit()).collect();
        let b = a.transpose().mul_vec(&x_true);
        let x = f.solve_transpose(&b);
        for (got, want) in x.iter().zip(&x_true) {
            assert!((got - want).abs() < 1e-8);
        }
    }

    #[test]
    fn refined_solve_never_worse() {
        let a = gen::circuit_bbd(gen::CircuitParams { n: 300, ..Default::default() });
        let mut s = Solver::new(SolveOptions::ours(1));
        let f = s.factorize(&a).unwrap();
        let b: Vec<f64> = (0..300).map(|i| ((i * 13) % 7) as f64 - 3.0).collect();
        let plain = crate::sparse::residual(&a, &f.solve(&b), &b);
        let refined = crate::sparse::residual(&a, &f.solve_refined(&a, &b, 3), &b);
        assert!(refined <= plain * 1.0000001, "refined {refined} vs plain {plain}");
        assert!(refined < 1e-12);
    }

    #[test]
    fn solve_many_matches_individual() {
        let a = gen::grid2d_laplacian(8, 8);
        let mut s = Solver::new(SolveOptions::ours(1));
        let f = s.factorize(&a).unwrap();
        let bs: Vec<Vec<f64>> = (0..3)
            .map(|k| (0..64).map(|i| ((i + k) % 5) as f64).collect())
            .collect();
        let many = f.solve_many(&bs);
        for (b, x) in bs.iter().zip(&many) {
            assert_eq!(x, &f.solve(b));
        }
    }

    #[test]
    fn report_phases_positive() {
        let a = gen::directed_graph(200, 4, 8);
        let rep = end_to_end(&a, SolveOptions::ours(2), 1e-9);
        assert!(rep.numeric_seconds > 0.0);
        assert!(rep.numeric_share() > 0.0 && rep.numeric_share() <= 1.0);
        assert!(rep.modeled_makespan > 0.0);
        assert!(rep.modeled_total_cost >= rep.modeled_makespan / 2.0);
    }
}
