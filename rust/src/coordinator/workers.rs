//! DAG execution entry points: the persistent work-stealing path
//! ([`run_dag`]/[`run_dag_subset`], thin wrappers over
//! [`Executor::run`](super::executor::Executor::run)) and the
//! spawn-per-call baseline ([`run_dag_spawn`]/[`run_dag_subset_spawn`] —
//! `P` fresh threads plus one global ready-queue lock per call, kept as
//! the measured reference for `repro sched-bench` and as a second
//! scheduler for differential testing). Python is nowhere near this path
//! — dense ops go to the [`crate::numeric::factor::DenseBackend`] (pure
//! rust or PJRT artifacts).

use super::dag::TaskDag;
use super::executor::{is_active, Executor, RunState};
use super::placement::Placement;
use crate::blocking::partition::BlockedMatrix;
use crate::gpu_model::CostModel;
use crate::numeric::factor::{DenseBackend, FactorError, Factors, NumericMatrix};
use crate::numeric::kernels::Workspace;
use crate::numeric::KernelPolicy;
use std::sync::atomic::{AtomicU32, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Instant;

/// Execution report of a parallel factorization.
#[derive(Clone, Debug)]
pub struct RunReport {
    /// Measured wall-clock seconds of the numeric phase.
    pub wall_seconds: f64,
    /// Measured busy seconds per worker.
    pub busy: Vec<f64>,
    /// Tasks executed per worker.
    pub tasks_done: Vec<usize>,
    /// Total tasks.
    pub total_tasks: usize,
    /// Number of workers.
    pub workers: u32,
}

impl RunReport {
    /// max/mean measured busy-time imbalance.
    pub fn imbalance(&self) -> f64 {
        crate::util::Summary::of(&self.busy).imbalance()
    }
}

/// Factorize `bm` following the DAG on the process-wide shared
/// [`Executor`] for `num_workers`.
///
/// Returns the factors plus the measured run report.
pub fn factorize_parallel(
    bm: Arc<BlockedMatrix>,
    dag: &TaskDag,
    policy: &KernelPolicy,
    backend: &(dyn DenseBackend + Sync),
    num_workers: u32,
) -> Result<(Factors, RunReport), FactorError> {
    let nm = NumericMatrix::from_blocked(bm);
    let exec = Executor::shared(num_workers);
    let mut state = RunState::new();
    let report = run_dag(&nm, dag, policy, backend, &exec, &mut state)?;
    let n = report.total_tasks;
    Ok((Factors { numeric: nm, sparse_ops: n, dense_ops: 0 }, report))
}

/// Execute the task DAG over an **existing** [`NumericMatrix`] on the
/// persistent work-stealing `exec` pool — the re-entrant core of
/// [`factorize_parallel`].
///
/// This is the numeric-only path [`crate::session::SolverSession`] re-runs
/// on every re-factorization: the blocked structure, the DAG, the
/// per-block value storage **and** the scheduling counters (`state`) are
/// all preallocated by the plan/session; a steady-state replay allocates
/// nothing but one small job header.
pub fn run_dag(
    nm: &NumericMatrix,
    dag: &TaskDag,
    policy: &KernelPolicy,
    backend: &(dyn DenseBackend + Sync),
    exec: &Executor,
    state: &mut RunState,
) -> Result<RunReport, FactorError> {
    exec.run(nm, dag, None, policy, backend, state)
}

/// Execute only the tasks with `in_subset[t] == true` on `exec`, with the
/// DAG's cross-task dependencies intact *within* the subset.
///
/// Dependency edges arriving from tasks **outside** the subset are treated
/// as already satisfied: the caller guarantees those tasks' output blocks
/// hold their final factored values from a previous run. This is the
/// incremental re-factorization contract
/// ([`crate::session::SolverSession::refactorize_partial`]): the subset is
/// the set of tasks writing blocks forward-reachable from the dirty
/// blocks, which is closed under "reads a recomputed block", so every
/// out-of-subset dependency's output is unchanged by construction.
///
/// An all-`false` mask is valid and returns immediately with zero tasks
/// executed.
pub fn run_dag_subset(
    nm: &NumericMatrix,
    dag: &TaskDag,
    in_subset: &[bool],
    policy: &KernelPolicy,
    backend: &(dyn DenseBackend + Sync),
    exec: &Executor,
    state: &mut RunState,
) -> Result<RunReport, FactorError> {
    exec.run(nm, dag, Some(in_subset), policy, backend, state)
}

/// As [`run_dag`], but on the spawn-per-call baseline scheduler: `P`
/// fresh OS threads, one global ready-queue `Mutex` + `notify_all`
/// broadcast, counters reallocated per call. This is the pre-executor
/// behavior, kept so `repro sched-bench` can price exactly what the
/// persistent pool saves — and so the differential harness can assert
/// both schedulers produce bit-identical factors.
pub fn run_dag_spawn(
    nm: &NumericMatrix,
    dag: &TaskDag,
    policy: &KernelPolicy,
    backend: &(dyn DenseBackend + Sync),
    num_workers: u32,
) -> Result<RunReport, FactorError> {
    run_dag_spawn_inner(nm, dag, None, policy, backend, num_workers)
}

/// Subset form of [`run_dag_spawn`] (same contract as
/// [`run_dag_subset`]).
pub fn run_dag_subset_spawn(
    nm: &NumericMatrix,
    dag: &TaskDag,
    in_subset: &[bool],
    policy: &KernelPolicy,
    backend: &(dyn DenseBackend + Sync),
    num_workers: u32,
) -> Result<RunReport, FactorError> {
    assert_eq!(in_subset.len(), dag.tasks.len(), "subset mask must cover every DAG task");
    run_dag_spawn_inner(nm, dag, Some(in_subset), policy, backend, num_workers)
}

struct Queues {
    ready: Mutex<Vec<std::collections::VecDeque<u32>>>,
    cv: Condvar,
    done: AtomicUsize,
    total: usize,
    failed: Mutex<Option<FactorError>>,
}

fn run_dag_spawn_inner(
    nm: &NumericMatrix,
    dag: &TaskDag,
    subset: Option<&[bool]>,
    policy: &KernelPolicy,
    backend: &(dyn DenseBackend + Sync),
    num_workers: u32,
) -> Result<RunReport, FactorError> {
    let p = num_workers as usize;

    // Dependency counters restricted to the active tasks: on the full
    // path these are the DAG's stored in-degrees; on the subset path each
    // active task counts only its in-subset predecessors.
    let (deps, n): (Vec<AtomicU32>, usize) = match subset {
        None => (
            dag.tasks.iter().map(|t| AtomicU32::new(t.deps)).collect(),
            dag.tasks.len(),
        ),
        Some(mask) => {
            let mut counts = vec![0u32; dag.tasks.len()];
            let mut total = 0usize;
            for (t, task) in dag.tasks.iter().enumerate() {
                if !mask[t] {
                    continue;
                }
                total += 1;
                for &o in &task.out {
                    if mask[o as usize] {
                        counts[o as usize] += 1;
                    }
                }
            }
            (counts.into_iter().map(AtomicU32::new).collect(), total)
        }
    };
    let mut initial: Vec<std::collections::VecDeque<u32>> =
        vec![std::collections::VecDeque::new(); p];
    for (t, task) in dag.tasks.iter().enumerate() {
        if is_active(subset, t) && deps[t].load(Ordering::Relaxed) == 0 {
            initial[task.owner as usize % p].push_back(t as u32);
        }
    }
    let q = Queues {
        ready: Mutex::new(initial),
        cv: Condvar::new(),
        done: AtomicUsize::new(0),
        total: n,
        failed: Mutex::new(None),
    };

    let t0 = Instant::now();
    let (busy, tasks_done) = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..p)
            .map(|w| {
                let nm = &nm;
                let dag = &dag;
                let q = &q;
                let deps = &deps;
                scope.spawn(move || {
                    let mut ws = Workspace::with_capacity(nm.max_dim);
                    let mut my_busy = 0.0f64;
                    let mut my_done = 0usize;
                    // dependent-release scratch, reused across tasks
                    let mut to_push: Vec<(usize, u32)> = Vec::new();
                    loop {
                        // fetch next task for this worker
                        let task_id = {
                            let mut ready = q.ready.lock().unwrap();
                            loop {
                                if q.done.load(Ordering::SeqCst) >= q.total
                                    || q.failed.lock().unwrap().is_some()
                                {
                                    break None;
                                }
                                if let Some(t) = ready[w].pop_front() {
                                    break Some(t);
                                }
                                ready = q.cv.wait(ready).unwrap();
                            }
                        };
                        let Some(t) = task_id else { break };
                        let task = &dag.tasks[t as usize];
                        let start = Instant::now();
                        let res = nm.execute(task.op, policy, backend, &mut ws);
                        my_busy += start.elapsed().as_secs_f64();
                        my_done += 1;
                        if let Err(e) = res {
                            *q.failed.lock().unwrap() = Some(e);
                            q.cv.notify_all();
                            break;
                        }
                        // release dependents (inactive tasks have no
                        // counter to decrement and must never enqueue)
                        to_push.clear();
                        for &o in &task.out {
                            if is_active(subset, o as usize)
                                && deps[o as usize].fetch_sub(1, Ordering::AcqRel) == 1
                            {
                                to_push.push((dag.tasks[o as usize].owner as usize % p, o));
                            }
                        }
                        let finished = q.done.fetch_add(1, Ordering::SeqCst) + 1;
                        if !to_push.is_empty() || finished >= q.total {
                            let mut ready = q.ready.lock().unwrap();
                            for &(ow, o) in to_push.iter() {
                                ready[ow].push_back(o);
                            }
                            drop(ready);
                            q.cv.notify_all();
                        }
                    }
                    (my_busy, my_done)
                })
            })
            .collect();
        let mut busy = Vec::with_capacity(p);
        let mut tasks_done = Vec::with_capacity(p);
        for handle in handles {
            let (b, d) = handle.join().expect("spawned DAG worker panicked");
            busy.push(b);
            tasks_done.push(d);
        }
        (busy, tasks_done)
    });
    let wall = t0.elapsed().as_secs_f64();

    if let Some(e) = q.failed.lock().unwrap().take() {
        return Err(e);
    }
    assert_eq!(q.done.load(Ordering::SeqCst), n, "not all tasks executed");

    Ok(RunReport {
        wall_seconds: wall,
        busy,
        tasks_done,
        total_tasks: n,
        workers: num_workers,
    })
}

/// Convenience: build DAG + run in one call (measured path).
pub fn factorize_with_workers(
    bm: Arc<BlockedMatrix>,
    policy: &KernelPolicy,
    backend: &(dyn DenseBackend + Sync),
    num_workers: u32,
    model: &CostModel,
) -> Result<(Factors, RunReport, TaskDag), FactorError> {
    let placement = Placement::square(num_workers);
    let dag = TaskDag::build(&bm, policy, placement, model);
    let (f, r) = factorize_parallel(bm, &dag, policy, backend, num_workers)?;
    Ok((f, r, dag))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blocking::{regular_blocking, BlockedMatrix};
    use crate::numeric::factor::{factorize_sequential, CpuDense};
    use crate::sparse::{gen, residual};
    use crate::symbolic;

    fn parallel_check(a: &crate::sparse::Csc, bs: usize, p: u32) {
        let sym = symbolic::analyze(a);
        let ldu = sym.ldu_pattern(a).unwrap();
        let bm = Arc::new(BlockedMatrix::build(&ldu, regular_blocking(a.n_cols(), bs)));
        let policy = KernelPolicy::default();
        let model = CostModel::a100();
        let (f, report, _) =
            factorize_with_workers(bm.clone(), &policy, &CpuDense, p, &model).unwrap();
        assert_eq!(report.tasks_done.iter().sum::<usize>(), report.total_tasks);
        let n = a.n_cols();
        let b: Vec<f64> = (0..n).map(|i| ((i * 7) % 11) as f64 - 5.0).collect();
        let x = f.solve(&b);
        let r = residual(a, &x, &b);
        assert!(r < 1e-9, "residual {r} with {p} workers");

        // parallel result must equal sequential bit-for-bit? Not exactly —
        // update order is fixed by chaining, so yes: same order, same fp.
        let fs = factorize_sequential(bm, &policy, &CpuDense).unwrap();
        for (idx, _) in fs.numeric.structure.blocks.iter().enumerate() {
            let vs = fs.numeric.block_values(idx as u32);
            let vp = f.numeric.block_values(idx as u32);
            assert_eq!(vs, vp, "block {idx} differs between sequential and parallel");
        }
    }

    #[test]
    fn one_worker_matches_sequential() {
        parallel_check(&gen::grid2d_laplacian(8, 8), 12, 1);
    }

    #[test]
    fn two_workers_correct() {
        parallel_check(&gen::directed_graph(150, 4, 21), 25, 2);
    }

    #[test]
    fn four_workers_correct() {
        let a = gen::circuit_bbd(gen::CircuitParams { n: 300, ..Default::default() });
        parallel_check(&a, 40, 4);
    }

    #[test]
    fn four_workers_on_bbd_irregular_blocking() {
        let a = gen::circuit_bbd(gen::CircuitParams { n: 500, ..Default::default() });
        let sym = symbolic::analyze(&a);
        let ldu = sym.ldu_pattern(&a).unwrap();
        let curve = crate::blocking::DiagFeature::from_csc(&ldu).curve();
        let blocking = crate::blocking::irregular_blocking(
            &curve,
            &crate::blocking::IrregularParams::default(),
        );
        let bm = Arc::new(BlockedMatrix::build(&ldu, blocking));
        let model = CostModel::a100();
        let (f, report, _) = factorize_with_workers(
            bm,
            &KernelPolicy::default(),
            &CpuDense,
            4,
            &model,
        )
        .unwrap();
        assert_eq!(report.workers, 4);
        let b: Vec<f64> = (0..500).map(|i| (i % 3) as f64).collect();
        let x = f.solve(&b);
        assert!(residual(&a, &x, &b) < 1e-9);
    }

    #[test]
    fn subset_full_mask_matches_run_dag() {
        let a = gen::grid2d_laplacian(8, 8);
        let sym = symbolic::analyze(&a);
        let ldu = sym.ldu_pattern(&a).unwrap();
        let bm = Arc::new(BlockedMatrix::build(&ldu, regular_blocking(64, 12)));
        let policy = KernelPolicy::default();
        let dag = TaskDag::build(&bm, &policy, Placement::square(2), &CostModel::a100());
        let exec = Executor::shared(2);
        let mut state = RunState::new();
        let nm_full = NumericMatrix::from_blocked(bm.clone());
        run_dag(&nm_full, &dag, &policy, &CpuDense, &exec, &mut state).unwrap();
        let nm_sub = NumericMatrix::from_blocked(bm.clone());
        let mask = vec![true; dag.tasks.len()];
        let rep =
            run_dag_subset(&nm_sub, &dag, &mask, &policy, &CpuDense, &exec, &mut state).unwrap();
        assert_eq!(rep.total_tasks, dag.tasks.len());
        assert_eq!(rep.tasks_done.iter().sum::<usize>(), dag.tasks.len());
        for id in 0..bm.blocks.len() {
            assert_eq!(
                nm_full.block_values(id as u32),
                nm_sub.block_values(id as u32),
                "block {id} differs between full-mask subset run and run_dag"
            );
        }
    }

    #[test]
    fn subset_empty_mask_is_noop() {
        let a = gen::tridiagonal(60);
        let sym = symbolic::analyze(&a);
        let ldu = sym.ldu_pattern(&a).unwrap();
        let bm = Arc::new(BlockedMatrix::build(&ldu, regular_blocking(60, 10)));
        let policy = KernelPolicy::default();
        let dag = TaskDag::build(&bm, &policy, Placement::square(2), &CostModel::a100());
        let nm = NumericMatrix::from_blocked(bm.clone());
        let before: Vec<Vec<f64>> =
            (0..bm.blocks.len()).map(|id| nm.block_values(id as u32)).collect();
        let mask = vec![false; dag.tasks.len()];
        let exec = Executor::shared(2);
        let mut state = RunState::new();
        let rep = run_dag_subset(&nm, &dag, &mask, &policy, &CpuDense, &exec, &mut state).unwrap();
        assert_eq!(rep.total_tasks, 0);
        assert_eq!(rep.tasks_done.iter().sum::<usize>(), 0);
        for (id, b) in before.iter().enumerate() {
            assert_eq!(&nm.block_values(id as u32), b, "block {id} was touched");
        }
    }

    #[test]
    fn spawn_baseline_matches_executor_bitwise() {
        let a = gen::circuit_bbd(gen::CircuitParams { n: 250, ..Default::default() });
        let sym = symbolic::analyze(&a);
        let ldu = sym.ldu_pattern(&a).unwrap();
        let bm = Arc::new(BlockedMatrix::build(&ldu, regular_blocking(a.n_cols(), 30)));
        let policy = KernelPolicy::default();
        let dag = TaskDag::build(&bm, &policy, Placement::square(3), &CostModel::a100());
        let nm_spawn = NumericMatrix::from_blocked(bm.clone());
        run_dag_spawn(&nm_spawn, &dag, &policy, &CpuDense, 3).unwrap();
        let nm_exec = NumericMatrix::from_blocked(bm.clone());
        let exec = Executor::shared(3);
        let mut state = RunState::new();
        run_dag(&nm_exec, &dag, &policy, &CpuDense, &exec, &mut state).unwrap();
        for id in 0..bm.blocks.len() {
            assert_eq!(
                nm_spawn.block_values(id as u32),
                nm_exec.block_values(id as u32),
                "block {id} differs between spawn baseline and executor"
            );
        }
    }

    #[test]
    fn zero_pivot_propagates_as_error() {
        // singular matrix: duplicate rows
        let mut coo = crate::sparse::Coo::new(4, 4);
        for j in 0..4 {
            coo.push(0, j, 1.0);
            coo.push(1, j, 1.0);
        }
        coo.push(2, 2, 1.0);
        coo.push(3, 3, 1.0);
        coo.push(2, 0, 0.5);
        coo.push(3, 1, 0.5);
        let a = coo.to_csc();
        let sym = symbolic::analyze(&a);
        let ldu = sym.ldu_pattern(&a).unwrap();
        let bm = Arc::new(BlockedMatrix::build(&ldu, regular_blocking(4, 2)));
        let model = CostModel::a100();
        let r = factorize_with_workers(bm, &KernelPolicy::default(), &CpuDense, 2, &model);
        assert!(r.is_err());
    }
}
