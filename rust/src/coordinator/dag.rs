//! Task-DAG construction for right-looking blocked LU.
//!
//! Tasks are the four block ops of Algorithm 1. Dependencies:
//!
//! * every block (i,j) receives its Schur updates SSSSM(i,j,k) in
//!   ascending `k`, **chained** (serialized per target — this both encodes
//!   the accumulation order and excludes write races);
//! * the *finalize* op of a block (GETRF for diagonal, GESSM/TSTRF for
//!   panels) runs after its last update;
//! * GESSM(k,j) and TSTRF(i,k) additionally wait on GETRF(k);
//! * SSSSM(i,j,k) additionally waits on TSTRF(i,k) and GESSM(k,j).
//!
//! Level = longest-path depth — the dependency-tree levels of the paper's
//! Fig 5 (for a dense block grid, level(block) recovers `min(i,j)`-style
//! wavefronts; sparsity shortens the chains, adding parallelism).

use crate::blocking::partition::BlockedMatrix;
use crate::gpu_model::{self, CostModel, OpClass};
use crate::numeric::factor::BlockOp;
use crate::numeric::kernels::flops;
use crate::numeric::{KernelKind, KernelPolicy};
use crate::util::Summary;

use super::placement::Placement;

/// One schedulable task.
#[derive(Clone, Debug)]
pub struct Task {
    pub op: BlockOp,
    /// Worker that executes this task (owner of the target block).
    pub owner: u32,
    /// Number of prerequisite tasks.
    pub deps: u32,
    /// Tasks unlocked by this one.
    pub out: Vec<u32>,
    /// Modeled device seconds (A100 cost model).
    pub cost: f64,
    /// Flop count of the op (sparse-pattern flops).
    pub flops: f64,
    /// Bytes produced at the target block (transfer pricing).
    pub out_bytes: f64,
    /// Longest-path depth.
    pub level: u32,
}

/// The full DAG plus summary data.
pub struct TaskDag {
    pub tasks: Vec<Task>,
    pub num_levels: u32,
    pub total_flops: f64,
    /// Critical-path modeled time (infinite workers).
    pub critical_path: f64,
}

impl TaskDag {
    /// Build the DAG for `bm` under a kernel policy, placement and cost
    /// model.
    pub fn build(
        bm: &BlockedMatrix,
        policy: &KernelPolicy,
        placement: Placement,
        model: &CostModel,
    ) -> Self {
        let nb = bm.nb();
        // finalize-task id of each nonempty block, indexed by block idx
        let nblocks = bm.blocks.len();
        let mut tasks: Vec<Task> = Vec::with_capacity(nblocks * 2);
        let mut finalize_id = vec![u32::MAX; nblocks];

        // 1. create finalize tasks
        for (idx, b) in bm.blocks.iter().enumerate() {
            let (i, j) = (b.bi as usize, b.bj as usize);
            let op = if i == j {
                BlockOp::Getrf { k: i }
            } else if i < j {
                BlockOp::Gessm { k: i, j }
            } else {
                BlockOp::Tstrf { i, k: j }
            };
            let (class, flops, work) = op_cost(bm, op, policy);
            let bytes_touched = gpu_model::sparse_bytes(b.nnz(), b.nnz());
            // factor-type ops have a serial column dependency chain the
            // length of the diagonal block's width; GESSM's target
            // columns are mutually independent (only each column's
            // substitution is chained), so it pipelines ~2× better
            let diag_w = bm
                .block_id(i.min(j), i.min(j))
                .map(|id| bm.block(id).n_cols as usize)
                .unwrap_or(0);
            let serial_cols = if i < j { diag_w / 2 } else { diag_w };
            let cost = model.op_time_full(class, flops, bytes_touched, work, serial_cols);
            finalize_id[idx] = tasks.len() as u32;
            tasks.push(Task {
                op,
                owner: placement.owner(i, j),
                deps: 0,
                out: Vec::new(),
                cost,
                flops,
                out_bytes: b.nnz() as f64 * 12.0,
                level: 0,
            });
        }

        // finalize id by grid position
        let fid = |bm: &BlockedMatrix, i: usize, j: usize| -> Option<u32> {
            bm.block_id(i, j).map(|bidx| finalize_id[bidx as usize])
        };

        // 2. create SSSSM chains per block + cross edges
        for (idx, b) in bm.blocks.iter().enumerate() {
            let (i, j) = (b.bi as usize, b.bj as usize);
            let kmax = i.min(j);
            // ks = {k < kmax : (i,k) and (k,j) nonempty}
            let row_cols: Vec<usize> = bm.by_row[i]
                .iter()
                .map(|&id| bm.block(id).bj as usize)
                .take_while(|&c| c < kmax)
                .collect();
            let col_rows: Vec<usize> = bm.by_col[j]
                .iter()
                .map(|&id| bm.block(id).bi as usize)
                .take_while(|&r| r < kmax)
                .collect();
            let mut ks = Vec::new();
            let (mut a, mut c) = (0usize, 0usize);
            while a < row_cols.len() && c < col_rows.len() {
                match row_cols[a].cmp(&col_rows[c]) {
                    std::cmp::Ordering::Less => a += 1,
                    std::cmp::Ordering::Greater => c += 1,
                    std::cmp::Ordering::Equal => {
                        ks.push(row_cols[a]);
                        a += 1;
                        c += 1;
                    }
                }
            }

            let my_finalize = finalize_id[idx];
            let owner = tasks[my_finalize as usize].owner;
            let mut prev: Option<u32> = None;
            for &k in &ks {
                let op = BlockOp::Ssssm { i, j, k };
                let (class, flops, work) = op_cost(bm, op, policy);
                let src_nnz = bm.block(bm.block_id(i, k).unwrap()).nnz()
                    + bm.block(bm.block_id(k, j).unwrap()).nnz();
                let bytes = gpu_model::sparse_bytes(src_nnz, b.nnz());
                let tid = tasks.len() as u32;
                tasks.push(Task {
                    op,
                    owner,
                    deps: 0,
                    out: Vec::new(),
                    cost: model.op_time_full(class, flops, bytes, work, 0),
                    flops,
                    out_bytes: b.nnz() as f64 * 12.0,
                    level: 0,
                });
                // deps: TSTRF(i,k), GESSM(k,j), prev update
                let t1 = fid(bm, i, k).expect("L source finalize");
                let t2 = fid(bm, k, j).expect("U source finalize");
                add_edge(&mut tasks, t1, tid);
                add_edge(&mut tasks, t2, tid);
                if let Some(p) = prev {
                    add_edge(&mut tasks, p, tid);
                }
                prev = Some(tid);
            }
            // finalize waits on the last update
            if let Some(p) = prev {
                add_edge(&mut tasks, p, my_finalize);
            }
            // panel finalizes wait on GETRF of their step
            match tasks[my_finalize as usize].op {
                BlockOp::Gessm { k, .. } | BlockOp::Tstrf { k, .. } => {
                    let g = fid(bm, k, k).expect("diagonal block must exist");
                    add_edge(&mut tasks, g, my_finalize);
                }
                _ => {}
            }
        }

        // 3. levels via Kahn topological sweep
        let n = tasks.len();
        let mut indeg: Vec<u32> = tasks.iter().map(|t| t.deps).collect();
        let mut queue: Vec<u32> = (0..n as u32).filter(|&t| indeg[t as usize] == 0).collect();
        let mut head = 0;
        let mut num_levels = 0u32;
        let mut finish = vec![0.0f64; n]; // critical-path finish times
        let mut processed = 0usize;
        while head < queue.len() {
            let t = queue[head] as usize;
            head += 1;
            processed += 1;
            let lvl = tasks[t].level;
            num_levels = num_levels.max(lvl + 1);
            finish[t] += tasks[t].cost;
            let ft = finish[t];
            let outs = std::mem::take(&mut tasks[t].out);
            for &o in &outs {
                let oi = o as usize;
                tasks[oi].level = tasks[oi].level.max(lvl + 1);
                finish[oi] = finish[oi].max(ft);
                indeg[oi] -= 1;
                if indeg[oi] == 0 {
                    queue.push(o);
                }
            }
            tasks[t].out = outs;
        }
        assert_eq!(processed, n, "task DAG has a cycle");
        let critical_path = finish.iter().cloned().fold(0.0, f64::max);
        let total_flops = tasks.iter().map(|t| t.flops).sum();
        let _ = nb;
        Self { tasks, num_levels, total_flops, critical_path }
    }

    /// Total modeled device-seconds (sum over tasks).
    pub fn total_cost(&self) -> f64 {
        self.tasks.iter().map(|t| t.cost).sum()
    }

    /// Per-level summed cost — the paper's Fig 5 "last level dominates"
    /// diagnostic, priced in modeled seconds.
    pub fn level_costs(&self) -> Vec<f64> {
        let mut out = vec![0.0; self.num_levels as usize];
        for t in &self.tasks {
            out[t.level as usize] += t.cost;
        }
        out
    }

    /// Summary of per-task cost within each level (within-level balance).
    pub fn level_summaries(&self) -> Vec<Summary> {
        let mut per: Vec<Vec<f64>> = vec![Vec::new(); self.num_levels as usize];
        for t in &self.tasks {
            per[t.level as usize].push(t.cost);
        }
        per.iter().map(|v| Summary::of(v)).collect()
    }
}

fn add_edge(tasks: &mut [Task], from: u32, to: u32) {
    tasks[from as usize].out.push(to);
    tasks[to as usize].deps += 1;
}

/// (op class, flop count, utilization work) for pricing one op under the
/// kernel policy. Dense kernels' utilization work is the dense tile cell
/// count (they stream the padded tile regardless of sparsity); sparse
/// kernels' is the nonzeros they touch.
fn op_cost(bm: &BlockedMatrix, op: BlockOp, policy: &KernelPolicy) -> (OpClass, f64, f64) {
    match op {
        BlockOp::Getrf { k } => {
            let b = bm.block(bm.block_id(k, k).unwrap());
            match policy.choose(b.density()) {
                KernelKind::Sparse => (OpClass::SparseFactor, flops::getrf(b), b.nnz() as f64),
                KernelKind::Dense => {
                    let n = b.n_cols as f64;
                    (OpClass::Dense, flops::getrf_dense(b.n_cols as usize), n * n)
                }
            }
        }
        BlockOp::Gessm { k, j } => {
            let d = bm.block(bm.block_id(k, k).unwrap());
            let t = bm.block(bm.block_id(k, j).unwrap());
            match policy.choose(d.density().max(t.density())) {
                KernelKind::Sparse => (
                    OpClass::SparseFactor,
                    flops::gessm(t, d),
                    (d.nnz() + t.nnz()) as f64,
                ),
                KernelKind::Dense => {
                    let (m, n) = (d.n_rows as f64, t.n_cols as f64);
                    (
                        OpClass::Dense,
                        flops::gessm_dense(d.n_rows as usize, t.n_cols as usize),
                        m * n,
                    )
                }
            }
        }
        BlockOp::Tstrf { i, k } => {
            let d = bm.block(bm.block_id(k, k).unwrap());
            let t = bm.block(bm.block_id(i, k).unwrap());
            match policy.choose(d.density().max(t.density())) {
                KernelKind::Sparse => (
                    OpClass::SparseFactor,
                    flops::tstrf(t, d),
                    (d.nnz() + t.nnz()) as f64,
                ),
                KernelKind::Dense => {
                    let (m, n) = (t.n_rows as f64, d.n_cols as f64);
                    (
                        OpClass::Dense,
                        flops::tstrf_dense(t.n_rows as usize, d.n_cols as usize),
                        m * n,
                    )
                }
            }
        }
        BlockOp::Ssssm { i, j, k } => {
            let a = bm.block(bm.block_id(i, k).unwrap());
            let b = bm.block(bm.block_id(k, j).unwrap());
            // no target block -> the op is a structural no-op
            let Some(cid) = bm.block_id(i, j) else {
                return (OpClass::SparseUpdate, 0.0, 0.0);
            };
            let c = bm.block(cid);
            match policy.choose(a.density().max(b.density()).max(c.density())) {
                KernelKind::Sparse => (
                    OpClass::SparseUpdate,
                    flops::ssssm(a, b, c),
                    (a.nnz() + b.nnz()) as f64,
                ),
                KernelKind::Dense => {
                    let (m, n) = (a.n_rows as f64, b.n_cols as f64);
                    (
                        OpClass::Dense,
                        flops::ssssm_dense(a.n_rows as usize, a.n_cols as usize, b.n_cols as usize),
                        m * n,
                    )
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blocking::{regular_blocking, BlockedMatrix};
    use crate::sparse::gen;
    use crate::symbolic;

    fn dag_for(a: &crate::sparse::Csc, bs: usize, p: u32) -> (TaskDag, BlockedMatrix) {
        let sym = symbolic::analyze(a);
        let ldu = sym.ldu_pattern(a).unwrap();
        let bm = BlockedMatrix::build(&ldu, regular_blocking(a.n_cols(), bs));
        let dag = TaskDag::build(
            &bm,
            &KernelPolicy::default(),
            Placement::square(p),
            &CostModel::a100(),
        );
        (dag, bm)
    }

    #[test]
    fn dag_is_acyclic_and_complete() {
        let a = gen::grid2d_laplacian(10, 10);
        let (dag, bm) = dag_for(&a, 20, 1);
        // one finalize per nonempty block
        let finalizes = dag
            .tasks
            .iter()
            .filter(|t| !matches!(t.op, BlockOp::Ssssm { .. }))
            .count();
        assert_eq!(finalizes, bm.num_nonempty());
        // dep counts consistent with out edges
        let mut indeg = vec![0u32; dag.tasks.len()];
        for t in &dag.tasks {
            for &o in &t.out {
                indeg[o as usize] += 1;
            }
        }
        for (t, task) in dag.tasks.iter().enumerate() {
            assert_eq!(indeg[t], task.deps, "task {t} {:?}", task.op);
        }
    }

    #[test]
    fn getrf_of_step0_has_no_deps() {
        let a = gen::grid2d_laplacian(8, 8);
        let (dag, _) = dag_for(&a, 16, 1);
        let g0 = dag
            .tasks
            .iter()
            .find(|t| matches!(t.op, BlockOp::Getrf { k: 0 }))
            .unwrap();
        assert_eq!(g0.deps, 0);
        assert_eq!(g0.level, 0);
    }

    #[test]
    fn updates_chained_in_k_order() {
        // dense-ish small matrix: block (2,2) gets updates from k=0 and 1
        let a = gen::uniform_random(60, 0.2, 1);
        let (dag, _) = dag_for(&a, 20, 1);
        let u0 = dag
            .tasks
            .iter()
            .position(|t| matches!(t.op, BlockOp::Ssssm { i: 2, j: 2, k: 0 }));
        let u1 = dag
            .tasks
            .iter()
            .position(|t| matches!(t.op, BlockOp::Ssssm { i: 2, j: 2, k: 1 }));
        let (u0, u1) = (u0.expect("update k=0"), u1.expect("update k=1"));
        assert!(
            dag.tasks[u0].out.contains(&(u1 as u32)),
            "k=0 update must chain into k=1 update"
        );
        // GETRF(2) waits on the last update
        let g2 = dag
            .tasks
            .iter()
            .position(|t| matches!(t.op, BlockOp::Getrf { k: 2 }))
            .unwrap();
        assert!(dag.tasks[u1].out.contains(&(g2 as u32)));
    }

    #[test]
    fn tridiagonal_dag_is_mostly_parallel_free() {
        // tridiagonal with 1 off-diag block coupling: level count ~ 2 per
        // step (chain), total tasks small
        let a = gen::tridiagonal(100);
        let (dag, bm) = dag_for(&a, 10, 1);
        assert_eq!(dag.tasks.len(), bm.num_nonempty() + count_ssssm(&dag));
        assert!(dag.critical_path > 0.0);
        assert!(dag.total_cost() >= dag.critical_path);
    }

    fn count_ssssm(dag: &TaskDag) -> usize {
        dag.tasks
            .iter()
            .filter(|t| matches!(t.op, BlockOp::Ssssm { .. }))
            .count()
    }

    #[test]
    fn owners_match_placement() {
        let a = gen::uniform_random(80, 0.1, 2);
        let (dag, _) = dag_for(&a, 16, 4);
        let p = Placement::square(4);
        for t in &dag.tasks {
            let (i, j) = t.op.target();
            assert_eq!(t.owner, p.owner(i, j));
        }
    }

    #[test]
    fn level_costs_sum_to_total() {
        let a = gen::circuit_bbd(gen::CircuitParams { n: 300, ..Default::default() });
        let (dag, _) = dag_for(&a, 50, 1);
        let s: f64 = dag.level_costs().iter().sum();
        assert!((s - dag.total_cost()).abs() < 1e-9 * dag.total_cost());
        assert_eq!(dag.level_summaries().len(), dag.num_levels as usize);
    }
}
