//! 2D block-cyclic placement of blocks onto workers — PanguLU's process
//! grid (`P = Pr × Pc`, block (i,j) owned by `(i mod Pr, j mod Pc)`).

/// A `Pr × Pc` worker grid.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Placement {
    pub pr: u32,
    pub pc: u32,
}

impl Placement {
    /// Near-square grid for `p` workers (1→1×1, 2→1×2, 4→2×2, 6→2×3, …).
    pub fn square(p: u32) -> Self {
        assert!(p > 0);
        let mut pr = (p as f64).sqrt() as u32;
        while p % pr != 0 {
            pr -= 1;
        }
        Self { pr, pc: p / pr }
    }

    pub fn num_workers(&self) -> u32 {
        self.pr * self.pc
    }

    /// Owner of block (i, j).
    pub fn owner(&self, bi: usize, bj: usize) -> u32 {
        (bi as u32 % self.pr) * self.pc + (bj as u32 % self.pc)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn square_grids() {
        assert_eq!(Placement::square(1), Placement { pr: 1, pc: 1 });
        assert_eq!(Placement::square(2), Placement { pr: 1, pc: 2 });
        assert_eq!(Placement::square(4), Placement { pr: 2, pc: 2 });
        assert_eq!(Placement::square(6), Placement { pr: 2, pc: 3 });
        assert_eq!(Placement::square(7), Placement { pr: 1, pc: 7 });
    }

    #[test]
    fn owner_in_range_and_cyclic() {
        let p = Placement::square(4);
        for i in 0..10 {
            for j in 0..10 {
                let o = p.owner(i, j);
                assert!(o < 4);
                assert_eq!(o, p.owner(i + 2, j + 2), "cyclic with period 2");
            }
        }
        // all workers used
        let mut seen = [false; 4];
        for i in 0..2 {
            for j in 0..2 {
                seen[p.owner(i, j) as usize] = true;
            }
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn single_worker_owns_everything() {
        let p = Placement::square(1);
        assert_eq!(p.owner(3, 5), 0);
    }
}
