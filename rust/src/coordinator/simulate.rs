//! Discrete-event simulation of the task DAG on `P` modeled devices —
//! produces the *modeled A100* numbers reported next to measured
//! CPU wall-clock in the paper-table reproductions.
//!
//! List scheduling, owner-computes: each task runs on the owner of its
//! output block; a worker executes its ready tasks in ready-time order.
//! Cross-worker data dependencies pay the link transfer cost of the
//! producer's output block.

use super::dag::TaskDag;
use crate::gpu_model::CostModel;

/// Simulation result.
#[derive(Clone, Debug)]
pub struct SimReport {
    /// Modeled end-to-end seconds.
    pub makespan: f64,
    /// Busy seconds per worker.
    pub busy: Vec<f64>,
    /// Seconds spent on modeled transfers per worker.
    pub transfer: Vec<f64>,
    /// Worker utilization (busy / makespan).
    pub utilization: Vec<f64>,
}

impl SimReport {
    /// max/mean busy-time imbalance (1.0 = perfect).
    pub fn imbalance(&self) -> f64 {
        crate::util::Summary::of(&self.busy).imbalance()
    }
}

/// Simulate `dag` on `num_workers` devices, each sustaining
/// `model.concurrent_kernels` overlapping kernels (stream slots).
pub fn simulate(dag: &TaskDag, num_workers: u32, model: &CostModel) -> SimReport {
    let n = dag.tasks.len();
    let p = num_workers as usize;
    let slots_per = model.concurrent_kernels.max(1) as usize;
    let mut indeg: Vec<u32> = dag.tasks.iter().map(|t| t.deps).collect();
    let mut ready_time = vec![0.0f64; n];
    // per-worker ready lists; each device has `slots_per` stream slots
    let mut ready: Vec<Vec<u32>> = vec![Vec::new(); p];
    let mut slot_time = vec![vec![0.0f64; slots_per]; p];
    let mut busy = vec![0.0f64; p];
    let mut transfer = vec![0.0f64; p];
    let mut remaining = n;

    for (t, task) in dag.tasks.iter().enumerate() {
        if task.deps == 0 {
            ready[task.owner as usize].push(t as u32);
        }
    }

    let mut makespan = 0.0f64;
    while remaining > 0 {
        // pick the (worker, task, slot) combination that starts earliest
        let mut best: Option<(f64, usize, usize, usize)> = None; // (start, worker, pos, slot)
        for w in 0..p {
            if ready[w].is_empty() {
                continue;
            }
            // earliest-free stream slot of this device
            let (slot, &st) = slot_time[w]
                .iter()
                .enumerate()
                .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap();
            for (pos, &t) in ready[w].iter().enumerate() {
                let start = st.max(ready_time[t as usize]);
                match best {
                    Some((bs, _, _, _)) if bs <= start => {}
                    _ => best = Some((start, w, pos, slot)),
                }
            }
        }
        let (start, w, pos, slot) = best.expect("deadlock: no ready task but work remains");
        let t = ready[w].swap_remove(pos) as usize;
        let task = &dag.tasks[t];
        let finish = start + task.cost;
        slot_time[w][slot] = finish;
        busy[w] += task.cost;
        makespan = makespan.max(finish);
        remaining -= 1;
        for &o in &task.out {
            let oi = o as usize;
            let consumer = &dag.tasks[oi];
            let mut avail = finish;
            if consumer.owner != task.owner {
                let tt = model.transfer_time(task.out_bytes);
                avail += tt;
                transfer[consumer.owner as usize] += tt;
            }
            ready_time[oi] = ready_time[oi].max(avail);
            indeg[oi] -= 1;
            if indeg[oi] == 0 {
                ready[consumer.owner as usize].push(o);
            }
        }
    }

    // utilization normalized by stream capacity (1.0 = all slots busy
    // for the whole makespan)
    let utilization = busy
        .iter()
        .map(|&b| {
            if makespan > 0.0 {
                b / (makespan * slots_per as f64)
            } else {
                0.0
            }
        })
        .collect();
    SimReport { makespan, busy, transfer, utilization }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blocking::{regular_blocking, BlockedMatrix};
    use crate::coordinator::placement::Placement;
    use crate::coordinator::TaskDag;
    use crate::numeric::KernelPolicy;
    use crate::sparse::gen;
    use crate::symbolic;

    fn sim(a: &crate::sparse::Csc, bs: usize, p: u32) -> SimReport {
        let sym = symbolic::analyze(a);
        let ldu = sym.ldu_pattern(a).unwrap();
        let bm = BlockedMatrix::build(&ldu, regular_blocking(a.n_cols(), bs));
        let model = CostModel::a100();
        let dag = TaskDag::build(&bm, &KernelPolicy::default(), Placement::square(p), &model);
        simulate(&dag, p, &model)
    }

    #[test]
    fn makespan_bounded_by_total_and_critical_path() {
        let a = gen::uniform_random(120, 0.08, 3);
        let sym = symbolic::analyze(&a);
        let ldu = sym.ldu_pattern(&a).unwrap();
        let bm = BlockedMatrix::build(&ldu, regular_blocking(120, 24));
        let model = CostModel::a100();
        let dag = TaskDag::build(&bm, &KernelPolicy::default(), Placement::square(4), &model);
        let r = simulate(&dag, 4, &model);
        assert!(r.makespan <= dag.total_cost() + 1e-12 + r.transfer.iter().sum::<f64>());
        assert!(r.makespan >= dag.critical_path - 1e-12);
        // capacity bound: 4 devices × concurrent_kernels slots
        let cap = 4.0 * model.concurrent_kernels as f64;
        assert!(r.makespan >= dag.total_cost() / cap - 1e-12);
    }

    #[test]
    fn single_worker_serial_model_matches_total_cost() {
        // with stream concurrency 1, one device runs tasks back-to-back
        let a = gen::grid2d_laplacian(8, 8);
        let sym = symbolic::analyze(&a);
        let ldu = sym.ldu_pattern(&a).unwrap();
        let bm = BlockedMatrix::build(&ldu, regular_blocking(64, 16));
        let model = CostModel { concurrent_kernels: 1, ..CostModel::a100() };
        let dag = TaskDag::build(&bm, &KernelPolicy::default(), Placement::square(1), &model);
        let r = simulate(&dag, 1, &model);
        assert!((r.makespan - dag.total_cost()).abs() < 1e-12 * dag.total_cost().max(1.0));
        assert_eq!(r.busy.len(), 1);
        assert!((r.utilization[0] - 1.0).abs() < 1e-9);
    }

    #[test]
    fn stream_concurrency_shortens_makespan() {
        let a = gen::uniform_random(150, 0.06, 5);
        let sym = symbolic::analyze(&a);
        let ldu = sym.ldu_pattern(&a).unwrap();
        let bm = BlockedMatrix::build(&ldu, regular_blocking(150, 25));
        let serial = CostModel { concurrent_kernels: 1, ..CostModel::a100() };
        let streams = CostModel::a100();
        let dag = TaskDag::build(&bm, &KernelPolicy::default(), Placement::square(1), &streams);
        let r1 = simulate(&dag, 1, &serial);
        let r8 = simulate(&dag, 1, &streams);
        assert!(r8.makespan < r1.makespan, "{} vs {}", r8.makespan, r1.makespan);
    }

    #[test]
    fn modeled_block_size_curve_is_u_shaped() {
        // the paper's Fig 4: too-fine blocks pay launch overhead, too-
        // coarse blocks pay the serial column chain; the optimum is
        // interior. Check the modeled makespan across a size sweep.
        let a = gen::electromagnetics_like(2600, 12, 2, 0x0F5E);
        let sym = symbolic::analyze(&a);
        let ldu = sym.ldu_pattern(&a).unwrap();
        let model = CostModel::a100();
        let mut times = Vec::new();
        for bs in [32usize, 108, 432, 2600] {
            let bm = BlockedMatrix::build(&ldu, regular_blocking(2600, bs));
            let dag =
                TaskDag::build(&bm, &KernelPolicy::default(), Placement::square(1), &model);
            times.push(simulate(&dag, 1, &model).makespan);
        }
        let interior_min = times[1].min(times[2]);
        assert!(
            interior_min < times[0] && interior_min < times[3],
            "expected U-shape, got {times:?}"
        );
    }

    #[test]
    fn more_workers_do_not_regress_materially() {
        // with 8-stream overlap a single device already exploits most
        // task parallelism at this size; 4 devices add transfer cost, so
        // allow parity but not a material regression
        let a = gen::circuit_bbd(gen::CircuitParams { n: 500, ..Default::default() });
        let r1 = sim(&a, 50, 1);
        let r4 = sim(&a, 50, 4);
        assert!(
            r4.makespan < 1.5 * r1.makespan,
            "4 workers {} vs 1 worker {}",
            r4.makespan,
            r1.makespan
        );
    }

    #[test]
    fn multi_device_distributes_work_and_wins_when_throughput_bound() {
        // throttle streams to 1 so the workload is throughput-bound, then
        // multiple devices must win and all of them must do work
        let a = gen::circuit_bbd(gen::CircuitParams {
            n: 4000,
            border_frac: 0.04,
            border_density: 0.3,
            interior_deg: 2,
            seed: 8,
        });
        let sym = symbolic::analyze(&a);
        let ldu = sym.ldu_pattern(&a).unwrap();
        let bm = BlockedMatrix::build(&ldu, regular_blocking(4000, 160));
        let model = CostModel { concurrent_kernels: 1, ..CostModel::a100() };
        let dag1 = TaskDag::build(&bm, &KernelPolicy::default(), Placement::square(1), &model);
        let dag4 = TaskDag::build(&bm, &KernelPolicy::default(), Placement::square(4), &model);
        let r1 = simulate(&dag1, 1, &model);
        let r4 = simulate(&dag4, 4, &model);
        assert!(
            r4.makespan < r1.makespan,
            "4 devices {} vs 1 device {}",
            r4.makespan,
            r1.makespan
        );
        assert!(r4.busy.iter().all(|&b| b > 0.0), "idle device: {:?}", r4.busy);
    }

    #[test]
    fn imbalance_at_least_one() {
        let a = gen::uniform_random(150, 0.05, 9);
        let r = sim(&a, 30, 4);
        assert!(r.imbalance() >= 1.0 - 1e-12);
    }
}
