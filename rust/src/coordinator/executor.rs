//! [`Executor`] — the persistent work-stealing task runtime behind every
//! DAG execution.
//!
//! The paper's speedups come from balancing block work across the levels
//! of the dependency tree; the pre-executor scheduler threw much of that
//! away at runtime: every re-factorization spawned `P` fresh OS threads,
//! every task pop and dependent release took one global
//! `Mutex<Vec<VecDeque>>` plus a `Condvar::notify_all` broadcast, and the
//! dependency counters were reallocated per run. That overhead dominates
//! exactly the small pruned replays the session/serve stack exists to
//! make cheap. The task-parallel factorization literature (2D
//! partitioned-block task parallelism, asynchronous fan-both solvers)
//! gets its wins from a *persistent* task runtime instead — which is what
//! this module provides:
//!
//! * **One pool, created once.** [`Executor::new`] spawns `P` worker
//!   threads that live until the executor drops; [`Executor::shared`]
//!   hands out one process-wide pool per worker count, so every
//!   [`crate::session::SolverSession`], [`crate::solver::Solver`] and
//!   [`crate::serve`] shard with the same `workers` setting shares the
//!   same threads instead of spawning their own per call.
//! * **Per-worker deques + stealing.** Owner-computes: a task is pushed
//!   to the deque of its target block's owner (`owner % P`), who pops
//!   from the front; an idle worker steals from the *tail* of the other
//!   deques. No global ready-queue lock — contention is per-deque and
//!   only materializes when a steal actually happens.
//! * **Targeted wakeups + parking.** Pushing work wakes at most one
//!   parked worker *per pushed task* (the deque's owner first, thieves
//!   for the rest) instead of `notify_all`-broadcasting to all `P`; a
//!   fully idle pool is parked on per-worker condvars and costs nothing.
//! * **Allocation-free steady state.** All per-run mutable scheduling
//!   state — dependency counters, subset-restricted counts, per-worker
//!   busy/task tallies, seed scratch — lives in a reusable [`RunState`]
//!   owned by the caller (preallocated per session) and is reset in
//!   place each run instead of rebuilt; the only per-run allocation is
//!   one small job header.
//!
//! ## Determinism under stealing
//!
//! Work stealing changes *which thread* runs a task and *when*, never
//! *what* it computes: the DAG chains the SSSSM updates of each target
//! block in ascending `k` (see [`crate::coordinator::dag`]), so the
//! floating-point accumulation order per block is fixed by dependency
//! edges alone. Any legal schedule — sequential, spawn-per-call,
//! work-stealing, any worker count — produces bit-identical factors. The
//! differential harness (`rust/tests/differential.rs`) asserts exactly
//! that across matrices, worker counts and repeated runs.
//!
//! ## Error containment
//!
//! A failing task (zero pivot) cancels its job: the failing worker flags
//! the job, [`Executor::run`] purges the job's queued entries and waits
//! out in-flight claims before returning the error. Nothing poisons the
//! pool — the same executor immediately serves the next run (tested in
//! this module and in the serving stress tests).

use super::dag::TaskDag;
use super::workers::RunReport;
use crate::numeric::factor::{BlockOp, DenseBackend, FactorError, NumericMatrix};
use crate::numeric::kernels::Workspace;
use crate::numeric::KernelPolicy;
use crate::obs::trace;
use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock, Weak};
use std::time::Instant;

/// Which scheduler a DAG run executes on.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Scheduler {
    /// The persistent work-stealing pool ([`Executor`]) — the default.
    Persistent,
    /// The spawn-per-call baseline
    /// ([`crate::coordinator::run_dag_spawn`]): `P` fresh threads and a
    /// global ready-queue lock per call. Kept as the measured baseline
    /// for `repro sched-bench` and as a differential-testing axis.
    SpawnPerCall,
}

/// Is task `t` active under the (optional) subset mask? (Shared with the
/// spawn-per-call baseline in `coordinator::workers`, so the two
/// schedulers cannot silently diverge on subset semantics.)
pub(super) fn is_active(subset: Option<&[bool]>, t: usize) -> bool {
    match subset {
        None => true,
        Some(mask) => mask[t],
    }
}

/// Reusable per-run scheduling state: dependency counters, per-worker
/// tallies and seed scratch, preallocated once (per
/// [`crate::session::SolverSession`], or lazily for one-shot callers) and
/// reset in place at the start of every run — a DAG replay allocates
/// nothing here in steady state.
///
/// The counters are atomics so executor workers can decrement them
/// concurrently through a shared reference while the owning caller keeps
/// the `&mut` it will use to reset them for the next epoch.
pub struct RunState {
    /// Per-task remaining-dependency counters (subset-restricted on the
    /// incremental path).
    deps: Vec<AtomicU32>,
    /// Per-worker busy seconds, stored as `f64::to_bits` — each slot has
    /// a single writer (its worker), so plain load/store pairs suffice.
    busy_bits: Vec<AtomicU64>,
    /// Per-worker executed-task tallies (single writer each).
    tally: Vec<AtomicUsize>,
    /// Initially-ready tasks grouped by owning worker — the seed push
    /// buffers, reused across runs (and reused as the work stack by the
    /// single-worker inline path).
    seeds: Vec<Vec<u32>>,
}

impl RunState {
    /// Empty state; sized lazily by the first run.
    pub fn new() -> Self {
        Self { deps: Vec::new(), busy_bits: Vec::new(), tally: Vec::new(), seeds: Vec::new() }
    }

    /// State preallocated for a DAG of `ntasks` tasks on `workers`
    /// workers (what a session builds at construction time).
    pub fn sized(ntasks: usize, workers: u32) -> Self {
        let mut state = Self::new();
        state.reserve(ntasks, workers as usize);
        state
    }

    fn reserve(&mut self, ntasks: usize, p: usize) {
        if self.deps.len() != ntasks {
            self.deps.clear();
            self.deps.resize_with(ntasks, || AtomicU32::new(0));
        }
        if self.busy_bits.len() != p {
            self.busy_bits.clear();
            self.busy_bits.resize_with(p, || AtomicU64::new(0));
            self.tally.clear();
            self.tally.resize_with(p, || AtomicUsize::new(0));
        }
        if self.seeds.len() != p {
            self.seeds.resize_with(p, Vec::new);
        }
    }

    /// Reset for a new epoch: refill the dependency counters (restricted
    /// to `subset` when given), zero the tallies, and group the
    /// initially-ready tasks by owner. Returns the number of active
    /// tasks. In-place only — no allocation once the buffers have grown
    /// to the plan's size.
    fn prepare(&mut self, dag: &TaskDag, subset: Option<&[bool]>, p: usize) -> usize {
        self.reserve(dag.tasks.len(), p);
        for b in &mut self.busy_bits {
            *b.get_mut() = 0;
        }
        for t in &mut self.tally {
            *t.get_mut() = 0;
        }
        for s in &mut self.seeds {
            s.clear();
        }
        let total = match subset {
            None => {
                for (t, task) in dag.tasks.iter().enumerate() {
                    *self.deps[t].get_mut() = task.deps;
                }
                dag.tasks.len()
            }
            Some(mask) => {
                // each active task counts only its in-subset
                // predecessors; out-of-subset dependencies are treated as
                // already satisfied (the incremental contract)
                for d in &mut self.deps {
                    *d.get_mut() = 0;
                }
                let mut total = 0usize;
                for (t, task) in dag.tasks.iter().enumerate() {
                    if !mask[t] {
                        continue;
                    }
                    total += 1;
                    for &o in &task.out {
                        if mask[o as usize] {
                            *self.deps[o as usize].get_mut() += 1;
                        }
                    }
                }
                total
            }
        };
        for (t, task) in dag.tasks.iter().enumerate() {
            if is_active(subset, t) && self.deps[t].load(Ordering::Relaxed) == 0 {
                self.seeds[task.owner as usize % p].push(t as u32);
            }
        }
        total
    }
}

impl Default for RunState {
    fn default() -> Self {
        Self::new()
    }
}

/// High bit of [`Job::claims`]: the job is cancelled, no new task of it
/// may begin executing.
const CANCEL: u64 = 1 << 63;

struct JobStatus {
    done: bool,
    failed: Option<FactorError>,
}

/// What a job's queue entries execute: a DAG run (the numeric path) or a
/// flat index-parallel loop (the plan-construction path). Both carry
/// lifetime-erased borrows of the submitting call's data — the claim
/// protocol on [`Job`] keeps every dereference inside the submitter's
/// blocking window.
enum Work {
    /// One DAG run over a blocked numeric matrix.
    Dag {
        nm: *const NumericMatrix,
        dag: *const TaskDag,
        policy: *const KernelPolicy,
        backend: *const (dyn DenseBackend + Sync),
        subset: Option<*const [bool]>,
        state: *const RunState,
    },
    /// `f(t)` for every task index `t` — no dependencies, no numeric
    /// state; the closure owns all effects (writing disjoint output
    /// slots, see [`Executor::for_each`]).
    Each { f: *const (dyn Fn(usize) + Sync) },
}

/// One in-flight job: lifetime-erased borrows of the caller's data plus
/// the job-scoped completion/cancellation protocol. Queue entries hold an
/// `Arc<Job>`, so a stale entry left behind by a failed run keeps only
/// this small header alive — never the borrowed data.
struct Job {
    work: Work,
    total: usize,
    /// `(run_id, trace_id)` stamped at submission by
    /// [`trace::begin_run`] — `(0, 0)` when tracing was off, which is
    /// also the per-task recording gate (a plain field read, no atomic).
    trace: (u64, u64),
    /// Tasks executed successfully.
    done: AtomicUsize,
    /// Claim word: [`CANCEL`] bit + count of workers currently executing
    /// a task of this job (i.e. currently allowed to dereference the raw
    /// pointers in [`Work`]).
    claims: AtomicU64,
    status: Mutex<JobStatus>,
    cv: Condvar,
}

// SAFETY: the raw pointers in `Work` borrow data owned by the
// `Executor::run` / `Executor::for_each` call that created the job.
// Neither returns until either every task has executed (all queue entries
// consumed) or the job has been cancelled and every in-flight claim
// released — and a worker only dereferences the pointers inside a
// `begin()`/`end()` claim window, which `begin()` refuses to open once
// the cancel bit is set. All mutable state behind the `Dag` pointers is
// atomics (`RunState`) or internally locked (`NumericMatrix` block
// RwLocks); an `Each` closure is `Sync` and manages its own disjointness.
unsafe impl Send for Job {}
unsafe impl Sync for Job {}

impl Job {
    /// Open a claim window; fails iff the job is cancelled.
    fn begin(&self) -> bool {
        self.claims
            .fetch_update(Ordering::Acquire, Ordering::Relaxed, |c| {
                if c & CANCEL != 0 {
                    None
                } else {
                    Some(c + 1)
                }
            })
            .is_ok()
    }

    /// Close a claim window; wakes the cancelling caller when the last
    /// in-flight claim of a cancelled job drains.
    fn end(&self) {
        let prev = self.claims.fetch_sub(1, Ordering::AcqRel);
        if prev & CANCEL != 0 && prev & !CANCEL == 1 {
            let _guard = self.status.lock().unwrap();
            self.cv.notify_all();
        }
    }

    /// A task of this job failed: poison further claims first, then
    /// signal the submitter. Queued siblings are purged by the waiting
    /// submitter; in-flight ones drain through the claim count.
    fn fail(&self, e: FactorError) {
        self.claims.fetch_or(CANCEL, Ordering::AcqRel);
        let mut st = self.status.lock().unwrap();
        if st.failed.is_none() {
            st.failed = Some(e);
        }
        st.done = true;
        self.cv.notify_all();
    }

    /// A task of this job succeeded; signals the submitter when it was
    /// the last one.
    fn complete_one(&self) {
        let finished = self.done.fetch_add(1, Ordering::SeqCst) + 1;
        if finished >= self.total {
            let mut st = self.status.lock().unwrap();
            st.done = true;
            self.cv.notify_all();
        }
    }
}

/// One queued unit of work: which job, which task.
type Entry = (Arc<Job>, u32);

struct Parker {
    /// "You have been woken" flag, protected by the mutex the condvar
    /// waits on — closes the notify-before-wait race.
    flag: Mutex<bool>,
    cv: Condvar,
}

struct Shared {
    /// Per-worker ready deques: owner pushes/pops at the front-end pair
    /// (`push_back`/`pop_front`), thieves take from the tail
    /// (`pop_back`).
    queues: Vec<Mutex<VecDeque<Entry>>>,
    parkers: Vec<Parker>,
    /// Workers currently idle (registered before their final rescan, so
    /// a submitter racing that rescan still finds them here).
    idle: Mutex<Vec<usize>>,
    /// `idle.len()`, maintained under the `idle` lock — the lock-free
    /// fast path of [`Shared::unpark_for`], so a saturated pool's task
    /// completions never touch the idle mutex at all.
    idle_count: AtomicUsize,
    shutdown: AtomicBool,
    steals: AtomicU64,
    wakeups: AtomicU64,
    parks: AtomicU64,
    runs: AtomicU64,
}

impl Shared {
    /// Wake up to `count` parked workers — one per task just pushed —
    /// preferring `preferred` (the owner of the deque pushed to) first;
    /// the others come and steal from its tail, so a fan of independent
    /// tasks concentrated in one owner's deque still spreads across the
    /// pool.
    ///
    /// Lock-free when nobody is parked (the saturated steady state):
    /// the SeqCst `idle_count` read is sound against a concurrently
    /// registering worker because registration (SeqCst RMW) precedes the
    /// worker's rescan, and our queue push precedes this read — if the
    /// worker's rescan ran before our push (so it missed the task), the
    /// mutex ordering makes its registration happen-before this read, so
    /// we see the count and wake it; otherwise its rescan sees the task.
    /// Either way pushed work is never stranded.
    fn unpark_for(&self, preferred: usize, count: usize) {
        if count == 0 || self.idle_count.load(Ordering::SeqCst) == 0 {
            return;
        }
        let mut idle = self.idle.lock().unwrap();
        for _ in 0..count {
            let target = match idle.iter().position(|&w| w == preferred) {
                Some(pos) => idle.swap_remove(pos),
                None => match idle.pop() {
                    Some(w) => w,
                    None => break,
                },
            };
            self.idle_count.fetch_sub(1, Ordering::SeqCst);
            self.wakeups.fetch_add(1, Ordering::Relaxed);
            // idle → parker-flag nesting is the fixed lock order; workers
            // never take them in reverse while holding the flag
            let mut flag = self.parkers[target].flag.lock().unwrap();
            *flag = true;
            self.parkers[target].cv.notify_one();
        }
    }
}

/// Scheduler-health snapshot of one [`Executor`]: monotone counters
/// plus the pool's instantaneous shape. Taken lock-free by
/// [`Executor::stats`] (a handful of `Relaxed`/`SeqCst` atomic loads),
/// so it is cheap enough for a metrics refresher to call on every
/// scrape and for `repro sched-bench` to delta around each storm.
#[derive(Clone, Copy, Debug, Default)]
pub struct ExecutorStats {
    /// Jobs submitted: DAG runs plus data-parallel [`Executor::for_each`]
    /// jobs (plan-construction passes).
    pub runs: u64,
    /// Tasks taken from another worker's deque tail.
    pub steals: u64,
    /// Targeted unpark signals delivered to a parked worker.
    pub wakeups: u64,
    /// Times a worker parked (went fully idle).
    pub parks: u64,
    /// Worker threads in the pool (0 threads are spawned for a 1-worker
    /// executor — runs execute inline — but `workers` still reads 1).
    pub workers: u32,
    /// Workers idle right now (registered in the idle set, parked or
    /// about to park). `workers - idle_workers` is the busy gauge.
    pub idle_workers: usize,
}

/// Persistent worker pool executing task DAGs. See the [module
/// docs](self) for the design; [`Executor::run`] is the single entry
/// point ([`crate::coordinator::run_dag`] and
/// [`crate::coordinator::run_dag_subset`] are thin wrappers over it).
pub struct Executor {
    shared: Arc<Shared>,
    handles: Vec<std::thread::JoinHandle<()>>,
    workers: u32,
}

impl Executor {
    /// Pool with `workers` persistent threads, created once and reused by
    /// every run submitted to it.
    ///
    /// A 1-worker executor spawns no thread at all: its runs execute
    /// inline on the calling thread (scheduling a 1-thread team through
    /// queues would only add overhead, and running inline lets many
    /// callers — e.g. concurrent serve-shard drains — each contribute
    /// their own CPU, exactly like the spawn-per-call scheduler did).
    pub fn new(workers: u32) -> Self {
        assert!(workers >= 1, "Executor needs at least one worker");
        let p = workers as usize;
        let shared = Arc::new(Shared {
            queues: (0..p).map(|_| Mutex::new(VecDeque::new())).collect(),
            parkers: (0..p)
                .map(|_| Parker { flag: Mutex::new(false), cv: Condvar::new() })
                .collect(),
            idle: Mutex::new(Vec::with_capacity(p)),
            idle_count: AtomicUsize::new(0),
            shutdown: AtomicBool::new(false),
            steals: AtomicU64::new(0),
            wakeups: AtomicU64::new(0),
            parks: AtomicU64::new(0),
            runs: AtomicU64::new(0),
        });
        let handles = if p == 1 {
            Vec::new()
        } else {
            (0..p)
                .map(|w| {
                    let shared = shared.clone();
                    std::thread::Builder::new()
                        .name(format!("lu-exec-{w}"))
                        .spawn(move || worker_loop(&shared, w))
                        .expect("spawn executor worker thread")
                })
                .collect()
        };
        Self { shared, handles, workers }
    }

    /// The process-wide shared pool for `workers` — every session, solver
    /// and serve shard built with the same worker count reuses one pool
    /// (kept alive by its users; rebuilt on demand once all drop it).
    pub fn shared(workers: u32) -> Arc<Executor> {
        static REGISTRY: OnceLock<Mutex<HashMap<u32, Weak<Executor>>>> = OnceLock::new();
        let registry = REGISTRY.get_or_init(|| Mutex::new(HashMap::new()));
        let mut map = registry.lock().unwrap();
        if let Some(existing) = map.get(&workers).and_then(Weak::upgrade) {
            return existing;
        }
        let exec = Arc::new(Executor::new(workers));
        map.insert(workers, Arc::downgrade(&exec));
        exec
    }

    /// Worker count of the pool.
    pub fn workers(&self) -> u32 {
        self.workers
    }

    /// Lock-free scheduler-health snapshot: the monotone counters
    /// (subtract two snapshots for a per-interval reading) plus worker
    /// count and the idle-worker gauge. Safe to call from any thread at
    /// any rate — it takes no locks and never perturbs the pool.
    pub fn stats(&self) -> ExecutorStats {
        ExecutorStats {
            runs: self.shared.runs.load(Ordering::Relaxed),
            steals: self.shared.steals.load(Ordering::Relaxed),
            wakeups: self.shared.wakeups.load(Ordering::Relaxed),
            parks: self.shared.parks.load(Ordering::Relaxed),
            workers: self.workers,
            idle_workers: self.shared.idle_count.load(Ordering::SeqCst),
        }
    }

    /// Execute a task DAG (or the `subset`-masked part of it, with
    /// out-of-subset dependencies treated as satisfied) over `nm`,
    /// blocking until every active task ran or one failed. Concurrent
    /// `run` calls from different threads multiplex over the same worker
    /// pool.
    ///
    /// `state` carries the reusable per-run counters; callers re-running
    /// the same DAG (sessions) should keep one `RunState` alive across
    /// calls so the run allocates nothing.
    pub fn run(
        &self,
        nm: &NumericMatrix,
        dag: &TaskDag,
        subset: Option<&[bool]>,
        policy: &KernelPolicy,
        backend: &(dyn DenseBackend + Sync),
        state: &mut RunState,
    ) -> Result<RunReport, FactorError> {
        if let Some(mask) = subset {
            assert_eq!(mask.len(), dag.tasks.len(), "subset mask must cover every DAG task");
        }
        let p = self.workers as usize;
        let total = state.prepare(dag, subset, p);
        self.shared.runs.fetch_add(1, Ordering::Relaxed);
        if total == 0 {
            return Ok(RunReport {
                wall_seconds: 0.0,
                busy: vec![0.0; p],
                tasks_done: vec![0; p],
                total_tasks: 0,
                workers: self.workers,
            });
        }
        // one AtomicBool load when tracing is off; a run id + the
        // submitting thread's trace id when it is on
        let trace_ids = trace::begin_run();
        if p == 1 {
            return self.run_inline(nm, dag, subset, policy, backend, state, trace_ids);
        }

        let t0 = Instant::now();
        let state_ref: &RunState = state;
        let job = Arc::new(Job {
            work: Work::Dag {
                nm: nm as *const NumericMatrix,
                dag: dag as *const TaskDag,
                policy: policy as *const KernelPolicy,
                backend: backend as *const (dyn DenseBackend + Sync),
                subset: subset.map(|s| s as *const [bool]),
                state: state_ref as *const RunState,
            },
            total,
            trace: trace_ids,
            done: AtomicUsize::new(0),
            claims: AtomicU64::new(0),
            status: Mutex::new(JobStatus { done: false, failed: None }),
            cv: Condvar::new(),
        });
        // seed the deques (one lock per owner), then wake one worker per
        // seeded task (owner first, thieves for the rest)
        for w in 0..p {
            if state_ref.seeds[w].is_empty() {
                continue;
            }
            {
                let mut q = self.shared.queues[w].lock().unwrap();
                for &t in &state_ref.seeds[w] {
                    q.push_back((job.clone(), t));
                }
            }
            self.shared.unpark_for(w, state_ref.seeds[w].len());
        }
        if let Some(e) = self.wait_job(&job) {
            return Err(e);
        }
        debug_assert_eq!(job.done.load(Ordering::SeqCst), total, "not all tasks executed");
        if trace_ids.0 != 0 {
            // run span on the submitting thread's lane: the flow-arrow
            // source every task event of this run links back to
            trace::record_run(trace_ids.0, trace_ids.1, total as u32, t0, Instant::now());
        }
        Ok(RunReport {
            wall_seconds: t0.elapsed().as_secs_f64(),
            busy: state_ref
                .busy_bits
                .iter()
                .map(|b| f64::from_bits(b.load(Ordering::Relaxed)))
                .collect(),
            tasks_done: state_ref.tally.iter().map(|c| c.load(Ordering::Relaxed)).collect(),
            total_tasks: total,
            workers: self.workers,
        })
    }

    /// The 1-worker path: topological execution on the calling thread,
    /// reusing `state.deps` as the ready-propagation counters and
    /// `state.seeds[0]` as the work stack. No queues, no locks, no
    /// wakeups — the cheapest possible replay of a tiny pruned DAG.
    #[allow(clippy::too_many_arguments)] // private tail of `run`
    fn run_inline(
        &self,
        nm: &NumericMatrix,
        dag: &TaskDag,
        subset: Option<&[bool]>,
        policy: &KernelPolicy,
        backend: &(dyn DenseBackend + Sync),
        state: &mut RunState,
        trace_ids: (u64, u64),
    ) -> Result<RunReport, FactorError> {
        let t0 = Instant::now();
        let mut ws = Workspace::with_capacity(nm.max_dim);
        let mut executed = 0usize;
        let mut busy = 0.0f64;
        while let Some(t) = state.seeds[0].pop() {
            let task = &dag.tasks[t as usize];
            let started = Instant::now();
            // same panic containment as the pool path: a buggy kernel
            // surfaces as `Err(TaskPanic)` at every worker count, never
            // as an unwind through the calling (e.g. serve drain) thread
            std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                // executor-job fault boundary: an injected stall sleeps
                // here and an injected panic unwinds into this catch —
                // identical containment to a real kernel bug
                crate::fault::on_task();
                nm.execute(task.op, policy, backend, &mut ws)
            }))
            .unwrap_or(Err(FactorError::TaskPanic))?;
            let ended = Instant::now();
            busy += (ended - started).as_secs_f64();
            executed += 1;
            if trace_ids.0 != 0 {
                trace::record_task(trace::TaskSpan {
                    run_id: trace_ids.0,
                    trace_id: trace_ids.1,
                    task: t,
                    op: op_name(task.op),
                    target: task.op.target(),
                    level: task.level,
                    worker: 0,
                    stolen_from: -1,
                    start: started,
                    end: ended,
                });
            }
            for &o in &task.out {
                let o_us = o as usize;
                if is_active(subset, o_us) {
                    let d = state.deps[o_us].get_mut();
                    *d -= 1;
                    if *d == 0 {
                        state.seeds[0].push(o);
                    }
                }
            }
        }
        if trace_ids.0 != 0 {
            trace::record_run(trace_ids.0, trace_ids.1, executed as u32, t0, Instant::now());
        }
        Ok(RunReport {
            wall_seconds: t0.elapsed().as_secs_f64(),
            busy: vec![busy],
            tasks_done: vec![executed],
            total_tasks: executed,
            workers: 1,
        })
    }

    /// Block until `job` completes or fails; on failure, cancel-and-drain
    /// before returning the error: no new claim can begin, queued entries
    /// of the job are purged, and in-flight executions are waited out —
    /// so the borrows in `job` are dead before this returns and the pool
    /// is immediately reusable for the next job.
    fn wait_job(&self, job: &Arc<Job>) -> Option<FactorError> {
        let failed = {
            let mut st = job.status.lock().unwrap();
            while !st.done {
                st = job.cv.wait(st).unwrap();
            }
            st.failed.take()
        };
        let e = failed?;
        job.claims.fetch_or(CANCEL, Ordering::AcqRel);
        self.purge(job);
        {
            let mut st = job.status.lock().unwrap();
            while job.claims.load(Ordering::Acquire) & !CANCEL != 0 {
                st = job.cv.wait(st).unwrap();
            }
        }
        // entries the last in-flight tasks released after the first
        // purge: cancelled, so pop-and-skip would also discard them, but
        // dropping them now frees the job header immediately
        self.purge(job);
        Some(e)
    }

    /// Run `f(i)` for every `i < n` across the pool, blocking until all
    /// invocations completed (or one panicked — surfaced as
    /// [`FactorError::TaskPanic`] after the cancel-and-drain protocol).
    ///
    /// This is the data-parallel counterpart of [`Executor::run`], used
    /// by plan construction ([`crate::session::FactorPlan`]): the indices
    /// carry no dependencies, so they are dealt round-robin across the
    /// worker deques up front and balanced by the normal stealing path.
    /// `f` must confine its effects to per-index state (disjoint output
    /// slots); data-level failures should be recorded in those slots and
    /// resolved by the caller, keeping job failure reserved for panics.
    ///
    /// On a 1-worker pool the loop runs inline on the calling thread with
    /// identical panic containment.
    pub fn for_each(&self, n: usize, f: &(dyn Fn(usize) + Sync)) -> Result<(), FactorError> {
        if n == 0 {
            return Ok(());
        }
        let p = self.workers as usize;
        if p == 1 {
            for i in 0..n {
                std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(i)))
                    .map_err(|_| FactorError::TaskPanic)?;
            }
            return Ok(());
        }
        self.shared.runs.fetch_add(1, Ordering::Relaxed);
        let job = Arc::new(Job {
            work: Work::Each { f: f as *const (dyn Fn(usize) + Sync) },
            total: n,
            trace: (0, 0),
            done: AtomicUsize::new(0),
            claims: AtomicU64::new(0),
            status: Mutex::new(JobStatus { done: false, failed: None }),
            cv: Condvar::new(),
        });
        for w in 0..p {
            let mut pushed = 0usize;
            {
                let mut q = self.shared.queues[w].lock().unwrap();
                let mut i = w;
                while i < n {
                    q.push_back((job.clone(), i as u32));
                    pushed += 1;
                    i += p;
                }
            }
            self.shared.unpark_for(w, pushed);
        }
        match self.wait_job(&job) {
            Some(e) => Err(e),
            None => Ok(()),
        }
    }

    /// Split `data` into at most `max_chunks` contiguous chunks and run
    /// `f(start_index, chunk)` for each across the pool. The chunks are
    /// disjoint `&mut` views, so each invocation owns its slice; chunk
    /// boundaries depend only on `(data.len(), max_chunks)`, never on
    /// scheduling — the foundation of the deterministic parallel
    /// plan-construction passes.
    pub fn for_each_slice_mut<T: Send>(
        &self,
        data: &mut [T],
        max_chunks: usize,
        f: &(dyn Fn(usize, &mut [T]) + Sync),
    ) -> Result<(), FactorError> {
        let len = data.len();
        if len == 0 {
            return Ok(());
        }
        let chunks = max_chunks.clamp(1, len);
        let base = len / chunks;
        let rem = len % chunks;
        let bounds: Vec<(usize, usize)> =
            (0..chunks).map(|c| (c * base + c.min(rem), base + usize::from(c < rem))).collect();
        let ptr = SendPtr(data.as_mut_ptr());
        self.for_each(chunks, &move |c| {
            let (start, size) = bounds[c];
            // SAFETY: chunk ranges are disjoint by construction and
            // `for_each` does not return until every chunk ran or the
            // job was cancelled and drained, so `data` outlives every
            // dereference and no two chunks alias.
            let chunk = unsafe { std::slice::from_raw_parts_mut(ptr.0.add(start), size) };
            f(start, chunk);
        })
    }

    /// Drop every queued entry of `job` from all deques.
    fn purge(&self, job: &Arc<Job>) {
        for q in &self.shared.queues {
            q.lock().unwrap().retain(|(j, _)| !Arc::ptr_eq(j, job));
        }
    }
}

/// A `*mut T` that crosses threads: used by
/// [`Executor::for_each_slice_mut`] to hand each chunk closure its own
/// disjoint window into one borrowed slice.
#[derive(Clone, Copy)]
struct SendPtr<T>(*mut T);
// SAFETY: only ever dereferenced through disjoint ranges (see
// `for_each_slice_mut`), so sharing the pointer across workers is no
// more than sharing `&mut [T]` split into non-overlapping chunks.
unsafe impl<T: Send> Send for SendPtr<T> {}
unsafe impl<T: Send> Sync for SendPtr<T> {}

/// Number of chunks a parallel plan-construction pass should split `len`
/// slots into: a few chunks per worker for stealing slack, 1 when no
/// multi-worker pool is available (the sequential path).
pub(crate) fn par_chunk_count(exec: Option<&Executor>, len: usize) -> usize {
    match exec {
        Some(e) if e.workers() > 1 => (e.workers() as usize * 4).clamp(1, len.max(1)),
        _ => 1,
    }
}

/// Run `f(start_index, chunk)` over disjoint contiguous chunks of `data`
/// — on `exec` when it has multiple workers, inline as one chunk
/// otherwise. The sequential path runs the *same* closure over the whole
/// slice, so parallel and sequential plan builds execute identical code
/// per slot and differ only in chunking; each slot's value is a pure
/// function of its index.
pub(crate) fn par_chunks<T: Send>(
    exec: Option<&Executor>,
    data: &mut [T],
    f: &(dyn Fn(usize, &mut [T]) + Sync),
) -> Result<(), FactorError> {
    match exec {
        Some(e) if e.workers() > 1 && data.len() > 1 => {
            e.for_each_slice_mut(data, par_chunk_count(exec, data.len()), f)
        }
        _ => {
            if !data.is_empty() {
                f(0, data);
            }
            Ok(())
        }
    }
}

impl Drop for Executor {
    fn drop(&mut self) {
        self.shared.shutdown.store(true, Ordering::Release);
        for parker in &self.shared.parkers {
            let mut flag = parker.flag.lock().unwrap();
            *flag = true;
            parker.cv.notify_all();
        }
        for handle in self.handles.drain(..) {
            let _ = handle.join();
        }
    }
}

fn worker_loop(shared: &Shared, w: usize) {
    let p = shared.queues.len();
    let mut ws = Workspace::default();
    // dependent-release scratch, reused across every task this worker
    // ever executes (the per-task `to_push: Vec` of the old scheduler)
    let mut to_push: Vec<(usize, u32)> = Vec::new();
    loop {
        if shared.shutdown.load(Ordering::Acquire) {
            return;
        }
        // 1) own deque (oldest first), else steal from another's tail
        if let Some(((job, t), from)) = rescan(shared, w, p) {
            execute_task(shared, w, p, &job, t, from, &mut ws, &mut to_push);
            continue;
        }
        // 2) go idle: register first, rescan second (a submitter that
        // pushed between our scans either sees us in the idle set and
        // wakes us, or pushed early enough for this rescan to find it),
        // park third
        {
            let mut idle = shared.idle.lock().unwrap();
            idle.push(w);
            shared.idle_count.fetch_add(1, Ordering::SeqCst);
        }
        if let Some(((job, t), from)) = rescan(shared, w, p) {
            deregister(shared, w);
            execute_task(shared, w, p, &job, t, from, &mut ws, &mut to_push);
            continue;
        }
        shared.parks.fetch_add(1, Ordering::Relaxed);
        {
            let mut flag = shared.parkers[w].flag.lock().unwrap();
            while !*flag && !shared.shutdown.load(Ordering::Acquire) {
                flag = shared.parkers[w].cv.wait(flag).unwrap();
            }
            *flag = false;
        }
        // a waker that popped us from the idle set already deregistered
        // us; on a stale-flag wake (the set bit outlived its work) we
        // must deregister ourselves, or duplicate registrations pile up.
        // The flag lock is released first — wakers take idle → flag, and
        // taking them in the opposite order here would deadlock.
        deregister(shared, w);
    }
}

/// Remove `w` from the idle set if a waker has not already done so.
fn deregister(shared: &Shared, w: usize) {
    let mut idle = shared.idle.lock().unwrap();
    if let Some(pos) = idle.iter().position(|&x| x == w) {
        idle.swap_remove(pos);
        shared.idle_count.fetch_sub(1, Ordering::SeqCst);
    }
}

/// One pass over every deque (own front, others' tails). Returns the
/// entry plus the deque it came from, so a stolen task can be
/// attributed to its victim in the trace.
fn rescan(shared: &Shared, w: usize, p: usize) -> Option<(Entry, usize)> {
    for i in 0..p {
        let v = (w + i) % p;
        let entry = if v == w {
            shared.queues[v].lock().unwrap().pop_front()
        } else {
            shared.queues[v].lock().unwrap().pop_back()
        };
        if let Some(entry) = entry {
            if v != w {
                shared.steals.fetch_add(1, Ordering::Relaxed);
            }
            return Some((entry, v));
        }
    }
    None
}

/// Trace label of a kernel op.
fn op_name(op: BlockOp) -> &'static str {
    match op {
        BlockOp::Getrf { .. } => "getrf",
        BlockOp::Gessm { .. } => "gessm",
        BlockOp::Tstrf { .. } => "tstrf",
        BlockOp::Ssssm { .. } => "ssssm",
    }
}

#[allow(clippy::too_many_arguments)] // private worker-loop tail
fn execute_task(
    shared: &Shared,
    w: usize,
    p: usize,
    job: &Arc<Job>,
    t: u32,
    from: usize,
    ws: &mut Workspace,
    to_push: &mut Vec<(usize, u32)>,
) {
    if !job.begin() {
        // stale entry of a cancelled (failed) run — skip it
        return;
    }
    match &job.work {
        Work::Dag { nm, dag, policy, backend, subset, state } => {
            // SAFETY: the claim window opened, so the owning
            // `Executor::run` call is still blocked in its wait loop and
            // every borrow behind these pointers is live (see the
            // Send/Sync rationale on `Job`).
            let nm = unsafe { &**nm };
            let dag = unsafe { &**dag };
            let policy = unsafe { &**policy };
            let backend = unsafe { &**backend };
            let state = unsafe { &**state };
            let subset = subset.map(|s| unsafe { &*s });

            let task = &dag.tasks[t as usize];
            let started = Instant::now();
            // a panicking kernel must not kill a pool worker: the thread
            // is never respawned and the submitting `run` would hang
            // forever waiting for a completion signal that cannot come.
            // Catch the unwind, scrap the (possibly inconsistent)
            // workspace, and route the failure through the normal
            // cancel-and-drain error path instead.
            let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                // executor-job fault boundary (see `run_inline` twin)
                crate::fault::on_task();
                nm.execute(task.op, policy, backend, ws)
            }))
            .unwrap_or_else(|_| {
                *ws = Workspace::default();
                Err(FactorError::TaskPanic)
            });
            let ended = Instant::now();
            let elapsed = (ended - started).as_secs_f64();
            if job.trace.0 != 0 {
                // one ring write, only for jobs submitted with tracing
                // on; the untraced hot path pays a plain field read
                trace::record_task(trace::TaskSpan {
                    run_id: job.trace.0,
                    trace_id: job.trace.1,
                    task: t,
                    op: op_name(task.op),
                    target: task.op.target(),
                    level: task.level,
                    worker: w as u32,
                    stolen_from: if from == w { -1 } else { from as i32 },
                    start: started,
                    end: ended,
                });
            }
            // single-writer slots (only worker `w` touches index `w`), so
            // a load/store pair is enough — no CAS, no per-worker
            // Mutex<f64>
            let busy = f64::from_bits(state.busy_bits[w].load(Ordering::Relaxed)) + elapsed;
            state.busy_bits[w].store(busy.to_bits(), Ordering::Relaxed);
            state.tally[w].fetch_add(1, Ordering::Relaxed);

            match res {
                Err(e) => job.fail(e),
                Ok(()) => {
                    // release dependents: batch pushes per owner deque so
                    // each target lock is taken once, then wake at most
                    // one worker per deque pushed to
                    to_push.clear();
                    for &o in &task.out {
                        let o_us = o as usize;
                        if is_active(subset, o_us)
                            && state.deps[o_us].fetch_sub(1, Ordering::AcqRel) == 1
                        {
                            to_push.push((dag.tasks[o_us].owner as usize % p, o));
                        }
                    }
                    if !to_push.is_empty() {
                        to_push.sort_unstable_by_key(|&(owner, _)| owner);
                        let mut i = 0;
                        while i < to_push.len() {
                            let owner = to_push[i].0;
                            let mut end = i;
                            {
                                let mut q = shared.queues[owner].lock().unwrap();
                                while end < to_push.len() && to_push[end].0 == owner {
                                    q.push_back((job.clone(), to_push[end].1));
                                    end += 1;
                                }
                            }
                            // one wakeup per pushed task, minus the one
                            // we keep for ourselves when pushing to our
                            // own deque (we pop it next iteration)
                            let pushed = end - i;
                            let helpers = if owner == w { pushed - 1 } else { pushed };
                            shared.unpark_for(owner, helpers);
                            i = end;
                        }
                    }
                    job.complete_one();
                }
            }
        }
        Work::Each { f } => {
            // SAFETY: same claim-window argument as above, for the
            // `Executor::for_each` submitter.
            let func = unsafe { &**f };
            let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                func(t as usize);
            }))
            .map_err(|_| FactorError::TaskPanic);
            match res {
                Err(e) => job.fail(e),
                Ok(()) => job.complete_one(),
            }
        }
    }
    job.end();
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blocking::{regular_blocking, BlockedMatrix};
    use crate::coordinator::Placement;
    use crate::gpu_model::CostModel;
    use crate::numeric::factor::{factorize_sequential, CpuDense};
    use crate::sparse::gen;
    use crate::symbolic;

    fn blocked(a: &crate::sparse::Csc, bs: usize) -> Arc<BlockedMatrix> {
        let sym = symbolic::analyze(a);
        let ldu = sym.ldu_pattern(a).unwrap();
        Arc::new(BlockedMatrix::build(&ldu, regular_blocking(a.n_cols(), bs)))
    }

    fn singular_blocked() -> Arc<BlockedMatrix> {
        // band + long-range couplings, with rows 30 and 31 made
        // bit-identical: elimination stays exact on the duplicated pair,
        // so the pivot at column 31 is exactly zero — deep enough in the
        // DAG that unrelated tasks are still queued and in flight when
        // the GETRF fails (the cancel-and-drain path, possibly on a
        // stolen task)
        let n = 60;
        let mut coo = crate::sparse::Coo::new(n, n);
        for i in 0..n {
            if i == 30 || i == 31 {
                continue;
            }
            coo.push(i, i, 4.0);
            if i + 1 < n {
                coo.push(i, i + 1, -1.0);
            }
            if i >= 1 {
                coo.push(i, i - 1, -1.0);
            }
            if i + 12 < n {
                coo.push(i, i + 12, -0.5);
            }
            if i >= 12 {
                coo.push(i, i - 12, -0.5);
            }
        }
        for r in [30, 31] {
            coo.push(r, 18, -0.5);
            coo.push(r, 30, 2.0);
            coo.push(r, 31, 2.0);
            coo.push(r, 43, -0.5);
        }
        blocked(&coo.to_csc(), 10)
    }

    #[test]
    fn pool_matches_sequential_across_worker_counts() {
        let a = gen::circuit_bbd(gen::CircuitParams { n: 300, ..Default::default() });
        let bm = blocked(&a, 40);
        let policy = KernelPolicy::default();
        let seq = factorize_sequential(bm.clone(), &policy, &CpuDense).unwrap();
        for workers in [1u32, 2, 4, 8] {
            let exec = Executor::new(workers);
            let dag = TaskDag::build(&bm, &policy, Placement::square(workers), &CostModel::a100());
            let mut state = RunState::new();
            // several epochs through the same pool + state
            for round in 0..3 {
                let nm = NumericMatrix::from_blocked(bm.clone());
                let rep = exec.run(&nm, &dag, None, &policy, &CpuDense, &mut state).unwrap();
                assert_eq!(rep.total_tasks, dag.tasks.len());
                assert_eq!(rep.tasks_done.iter().sum::<usize>(), dag.tasks.len());
                assert_eq!(rep.workers, workers);
                for id in 0..bm.blocks.len() {
                    assert_eq!(
                        nm.block_values(id as u32),
                        seq.numeric.block_values(id as u32),
                        "block {id} differs (workers={workers}, round={round})"
                    );
                }
            }
            assert_eq!(exec.stats().runs, 3);
        }
    }

    #[test]
    fn error_during_run_drains_cleanly_and_pool_is_reusable() {
        let bad = singular_blocked();
        let policy = KernelPolicy::default();
        let exec = Executor::new(4);
        let bad_dag = TaskDag::build(&bad, &policy, Placement::square(4), &CostModel::a100());
        let mut state = RunState::new();
        // repeated failing runs: each must return Err without hanging or
        // poisoning the pool, wherever the failing GETRF lands (own pop
        // or steal)
        for _ in 0..8 {
            let nm = NumericMatrix::from_blocked(bad.clone());
            let res = exec.run(&nm, &bad_dag, None, &policy, &CpuDense, &mut state);
            assert!(res.is_err(), "singular matrix must fail");
        }
        // the same pool and the same RunState immediately serve a good
        // run, bit-identical to the sequential oracle
        let a = gen::grid2d_laplacian(10, 10);
        let bm = blocked(&a, 20);
        let dag = TaskDag::build(&bm, &policy, Placement::square(4), &CostModel::a100());
        let seq = factorize_sequential(bm.clone(), &policy, &CpuDense).unwrap();
        let nm = NumericMatrix::from_blocked(bm.clone());
        let rep = exec.run(&nm, &dag, None, &policy, &CpuDense, &mut state).unwrap();
        assert_eq!(rep.total_tasks, dag.tasks.len());
        for id in 0..bm.blocks.len() {
            assert_eq!(
                nm.block_values(id as u32),
                seq.numeric.block_values(id as u32),
                "block {id} differs after an Err run"
            );
        }
    }

    #[test]
    fn concurrent_runs_share_one_pool() {
        // four threads each re-factorize their own matrix on ONE shared
        // 2-worker pool; every result must bit-match its oracle
        let exec = Arc::new(Executor::new(2));
        let policy = KernelPolicy::default();
        let mats = [
            gen::grid2d_laplacian(8, 8),
            gen::grid2d_laplacian(9, 9),
            gen::circuit_bbd(gen::CircuitParams { n: 200, ..Default::default() }),
            gen::tridiagonal(80),
        ];
        std::thread::scope(|scope| {
            for a in &mats {
                let exec = exec.clone();
                let policy = &policy;
                scope.spawn(move || {
                    let bm = blocked(a, 16);
                    let dag = TaskDag::build(&bm, policy, Placement::square(2), &CostModel::a100());
                    let seq = factorize_sequential(bm.clone(), policy, &CpuDense).unwrap();
                    let mut state = RunState::new();
                    for _ in 0..4 {
                        let nm = NumericMatrix::from_blocked(bm.clone());
                        exec.run(&nm, &dag, None, policy, &CpuDense, &mut state).unwrap();
                        for id in 0..bm.blocks.len() {
                            assert_eq!(
                                nm.block_values(id as u32),
                                seq.numeric.block_values(id as u32),
                                "block {id} differs under pool sharing"
                            );
                        }
                    }
                });
            }
        });
        assert_eq!(exec.stats().runs, 16);
    }

    #[test]
    fn shared_registry_hands_out_one_pool_per_worker_count() {
        let a = Executor::shared(3);
        let b = Executor::shared(3);
        assert!(Arc::ptr_eq(&a, &b), "same worker count shares one pool");
        let c = Executor::shared(5);
        assert!(!Arc::ptr_eq(&a, &c));
        assert_eq!(c.workers(), 5);
    }

    #[test]
    fn empty_subset_is_a_free_noop() {
        let a = gen::tridiagonal(40);
        let bm = blocked(&a, 10);
        let policy = KernelPolicy::default();
        let dag = TaskDag::build(&bm, &policy, Placement::square(2), &CostModel::a100());
        let exec = Executor::new(2);
        let nm = NumericMatrix::from_blocked(bm.clone());
        let mask = vec![false; dag.tasks.len()];
        let mut state = RunState::new();
        let rep = exec.run(&nm, &dag, Some(&mask), &policy, &CpuDense, &mut state).unwrap();
        assert_eq!(rep.total_tasks, 0);
        assert_eq!(rep.tasks_done.iter().sum::<usize>(), 0);
    }

    #[test]
    fn idle_pool_parks_its_workers() {
        let exec = Executor::new(4);
        // give the freshly spawned workers a moment to find nothing and
        // park; an idle pool must converge to "everyone parked"
        for _ in 0..200 {
            if exec.stats().parks >= 3 {
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
        assert!(exec.stats().parks >= 3, "idle workers should park");
    }

    #[test]
    fn for_each_fills_every_slot_at_any_worker_count() {
        for workers in [1u32, 2, 4, 8] {
            let exec = Executor::new(workers);
            let n = 1000usize;
            let slots: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(usize::MAX)).collect();
            exec.for_each(n, &|i| {
                slots[i].store(i * i, Ordering::Relaxed);
            })
            .unwrap();
            for (i, s) in slots.iter().enumerate() {
                assert_eq!(s.load(Ordering::Relaxed), i * i, "slot {i} (workers={workers})");
            }
            // empty jobs are free no-ops
            exec.for_each(0, &|_| panic!("must not run")).unwrap();
        }
    }

    #[test]
    fn for_each_slice_mut_chunks_are_disjoint_and_deterministic() {
        for workers in [1u32, 2, 4] {
            let exec = Executor::new(workers);
            let mut data = vec![0u64; 257];
            exec.for_each_slice_mut(&mut data, 7, &|start, chunk| {
                for (off, v) in chunk.iter_mut().enumerate() {
                    *v = (start + off) as u64 + 1;
                }
            })
            .unwrap();
            for (i, &v) in data.iter().enumerate() {
                assert_eq!(v, i as u64 + 1, "index {i} (workers={workers})");
            }
        }
    }

    #[test]
    fn for_each_panic_surfaces_as_task_panic_and_pool_survives() {
        for workers in [1u32, 4] {
            let exec = Executor::new(workers);
            let res = exec.for_each(64, &|i| {
                if i == 37 {
                    panic!("injected");
                }
            });
            assert_eq!(res, Err(FactorError::TaskPanic), "workers={workers}");
            // the same pool immediately serves the next job
            let count = AtomicUsize::new(0);
            exec.for_each(64, &|_| {
                count.fetch_add(1, Ordering::Relaxed);
            })
            .unwrap();
            assert_eq!(count.load(Ordering::Relaxed), 64);
        }
    }

    #[test]
    fn stats_snapshot_reports_pool_shape() {
        let exec = Executor::new(4);
        assert_eq!(exec.stats().workers, 4);
        // wait for the idle gauge to converge to "everyone idle"
        for _ in 0..500 {
            if exec.stats().idle_workers == 4 {
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
        assert_eq!(exec.stats().idle_workers, 4, "idle pool: all workers in the idle set");
        // the 1-worker inline executor has no threads and so no idlers
        let inline = Executor::new(1);
        let st = inline.stats();
        assert_eq!(st.workers, 1);
        assert_eq!(st.idle_workers, 0);
    }
}
