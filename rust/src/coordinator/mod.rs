//! The L3 coordinator: task-DAG construction over the blocked matrix,
//! block-cyclic placement across workers (simulated GPUs), a threaded
//! owner-computes executor, a discrete-event simulator pricing the same
//! DAG on the A100 cost model, and load-balance metrics.
//!
//! The paper's parallel setting (PanguLU on 1–4 A100s) maps as:
//!
//! * GPU `g` ⇒ worker thread `g` (owner-computes: every op runs on the
//!   owner of its output block);
//! * PanguLU's 2D block-cyclic process grid ⇒ [`placement::Placement`];
//! * CUDA streams/events ⇒ the dependency-counting ready queues;
//! * measured GPU time ⇒ both measured CPU wall-clock **and** the modeled
//!   A100 makespan from [`simulate::simulate`] (same DAG, same placement).
//!
//! Execution happens on the persistent work-stealing
//! [`executor::Executor`]: per-worker ready deques (owner-computes push,
//! idle workers steal from the tail), targeted single-worker wakeups, a
//! parking protocol so an idle pool costs nothing, and a reusable
//! [`executor::RunState`] so steady-state replays allocate nothing. Two
//! entry points matter downstream: [`run_dag`] executes a whole task DAG
//! (the full re-factorization path of
//! [`crate::session::SolverSession::refactorize`]) and [`run_dag_subset`]
//! executes a masked task subset with out-of-subset dependencies treated
//! as already satisfied (the pruned incremental path of
//! [`crate::session::SolverSession::refactorize_partial`]). The
//! pre-executor spawn-per-call scheduler survives as
//! [`run_dag_spawn`]/[`run_dag_subset_spawn`] — the measured baseline of
//! `repro sched-bench`. `ARCHITECTURE.md` at the repository root places
//! this module in the full pipeline and diagrams the executor.

pub mod dag;
pub mod executor;
pub mod metrics;
pub mod placement;
pub mod simulate;
pub mod workers;

pub use dag::{Task, TaskDag};
pub(crate) use executor::par_chunks;
pub use executor::{Executor, ExecutorStats, RunState, Scheduler};
pub use metrics::LoadReport;
pub use placement::Placement;
pub use simulate::{simulate, SimReport};
pub use workers::{
    factorize_parallel, run_dag, run_dag_spawn, run_dag_subset, run_dag_subset_spawn, RunReport,
};
