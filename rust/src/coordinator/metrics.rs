//! Load-balance metrics combining the measured run report with the
//! modeled simulation — the quantities behind the paper's §5.3 claim that
//! irregular blocking's benefit "is very obvious" in parallel computing.

use super::simulate::SimReport;
use super::workers::RunReport;
use crate::util::Summary;

/// Joint load report for one factorization run.
#[derive(Clone, Debug)]
pub struct LoadReport {
    /// Measured wall seconds.
    pub wall_seconds: f64,
    /// Measured per-worker busy seconds.
    pub measured_busy: Vec<f64>,
    /// Measured imbalance (max/mean busy).
    pub measured_imbalance: f64,
    /// Modeled makespan seconds (A100 cost model).
    pub modeled_makespan: f64,
    /// Modeled imbalance.
    pub modeled_imbalance: f64,
    /// Modeled utilizations.
    pub modeled_utilization: Vec<f64>,
}

impl LoadReport {
    pub fn new(run: &RunReport, sim: &SimReport) -> Self {
        Self {
            wall_seconds: run.wall_seconds,
            measured_busy: run.busy.clone(),
            measured_imbalance: Summary::of(&run.busy).imbalance(),
            modeled_makespan: sim.makespan,
            modeled_imbalance: sim.imbalance(),
            modeled_utilization: sim.utilization.clone(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn combines_measured_and_modeled() {
        let run = RunReport {
            wall_seconds: 2.0,
            busy: vec![1.0, 1.5],
            tasks_done: vec![10, 12],
            total_tasks: 22,
            workers: 2,
        };
        let sim = SimReport {
            makespan: 0.5,
            busy: vec![0.2, 0.4],
            transfer: vec![0.0, 0.01],
            utilization: vec![0.4, 0.8],
        };
        let l = LoadReport::new(&run, &sim);
        assert_eq!(l.wall_seconds, 2.0);
        assert!((l.measured_imbalance - 1.5 / 1.25).abs() < 1e-12);
        assert_eq!(l.modeled_makespan, 0.5);
        assert!((l.modeled_imbalance - 0.4 / 0.3).abs() < 1e-12);
    }
}
