//! Task-level tracing: see every block, every level, every request.
//!
//! The metrics spine ([`crate::obs::metrics`]) exports aggregates —
//! counters and latency histograms — but no *causality*: when a serve
//! p99 spikes, nothing says which request, which DAG level or which
//! straggler block was responsible. This module records one event per
//! executed DAG task (task id, kernel kind, target block, level, worker,
//! stolen-from worker, monotonic start/end) plus one span per DAG run,
//! and turns the recording into three artifacts:
//!
//! 1. **Chrome-trace/Perfetto JSON** ([`chrome_trace_json`], served on
//!    `GET /trace` and written by `repro trace --out`): one lane per
//!    recording thread (pool workers are the `lu-exec-{w}` lanes), flow
//!    arrows from each run span to its tasks.
//! 2. **Critical-path analysis** ([`analyze_run`]): the longest
//!    dependency chain through the *measured* task durations vs the
//!    achieved makespan — scheduling efficiency and top-k stragglers.
//! 3. **Per-level balance** ([`level_balance`]): nonzeros and measured
//!    seconds per target block per DAG level, with max/mean imbalance
//!    within each level and across levels — the measurement behind the
//!    paper's claim that irregular blocking "adequately balances the
//!    nonzeros of blocks both within the same level and across levels".
//!
//! ## Cost model
//!
//! Tracing is always compiled and **cheap when off**: the only cost on
//! the trace-off path is one `Relaxed` load of an `AtomicBool` per DAG
//! run submission (per-task recording is gated on the run id stamped
//! into the job header, a plain field read). When on, an event is one
//! write into a per-thread single-writer ring buffer — no lock, no
//! allocation, no syscall; overflow overwrites the oldest events and is
//! surfaced as [`TraceSnapshot::dropped_events`], never as a
//! reallocation.
//!
//! Recording never changes *what* is computed: the executor's schedule
//! is untouched and factors stay bit-identical with tracing on or off
//! (asserted by `rust/tests/tracing.rs`).
//!
//! ## Correlation
//!
//! A `trace_id` spans the serve stack: the [`crate::serve::Batcher`]
//! allocates one per drained batch ([`next_trace_id`]), installs it on
//! the session, and stamps it on every [`crate::serve::ServeReport`];
//! the session publishes it thread-locally ([`set_current_trace_id`])
//! so the executor can stamp it into every task event of the runs that
//! batch triggered. Logs, metrics and trace events of one request
//! therefore share an id.

use crate::blocking::BlockedMatrix;
use crate::coordinator::TaskDag;
use std::cell::{Cell, RefCell, UnsafeCell};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

/// Events each per-thread ring holds before overwriting the oldest.
/// Power of two so the ring index is a mask, not a division.
pub const RING_CAPACITY: usize = 1 << 13;

/// Global on/off switch. A static (not part of the collector) so the
/// trace-off check never touches the `OnceLock`.
static ENABLED: AtomicBool = AtomicBool::new(false);

/// Is tracing on? One `Relaxed` atomic load — the entire cost of the
/// trace-off path.
#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Turn tracing on or off. Runs already in flight keep recording (their
/// job headers carry a run id); new runs observe the switch at submit.
pub fn set_enabled(on: bool) {
    ENABLED.store(on, Ordering::Relaxed);
}

/// What one [`TraceEvent`] describes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EventKind {
    /// One executed DAG task (a kernel invocation).
    Task,
    /// One whole DAG run, submit to completion, on the submitting
    /// thread's lane (`task` holds the active task count).
    Run,
}

/// One recorded event. `Copy` and fixed-size so ring slots never
/// allocate; timestamps are nanoseconds since the collector's epoch.
#[derive(Clone, Copy, Debug)]
pub struct TraceEvent {
    /// Task span or run span.
    pub kind: EventKind,
    /// Run this event belongs to (unique per traced DAG run, never 0).
    pub run_id: u64,
    /// Request-correlation id threaded from the serve stack (0 when the
    /// run was not triggered by a traced request).
    pub trace_id: u64,
    /// DAG task index (for [`EventKind::Run`]: active task count).
    pub task: u32,
    /// Kernel kind: `"getrf"`, `"gessm"`, `"tstrf"`, `"ssssm"` (for
    /// [`EventKind::Run`]: `"run"`).
    pub op: &'static str,
    /// Target block row of the op.
    pub bi: u32,
    /// Target block column of the op.
    pub bj: u32,
    /// DAG level (longest-path depth) of the task.
    pub level: u32,
    /// Worker that executed the task (0 on the inline 1-worker path).
    pub worker: u32,
    /// Deque the entry was stolen from, or -1 when the worker popped its
    /// own deque (and for run spans).
    pub stolen_from: i32,
    /// Start, nanoseconds since the collector epoch.
    pub start_ns: u64,
    /// End, nanoseconds since the collector epoch.
    pub end_ns: u64,
}

impl TraceEvent {
    const ZERO: TraceEvent = TraceEvent {
        kind: EventKind::Task,
        run_id: 0,
        trace_id: 0,
        task: 0,
        op: "",
        bi: 0,
        bj: 0,
        level: 0,
        worker: 0,
        stolen_from: -1,
        start_ns: 0,
        end_ns: 0,
    };

    /// Event duration in seconds.
    pub fn seconds(&self) -> f64 {
        self.end_ns.saturating_sub(self.start_ns) as f64 * 1e-9
    }
}

/// Fixed-capacity single-writer ring. The owning thread is the only
/// writer; `head` counts events ever written and is published with
/// `Release` so a reader's `Acquire` load sees fully written slots for
/// everything strictly before it.
struct Ring {
    slots: Box<[UnsafeCell<TraceEvent>]>,
    head: AtomicU64,
}

// SAFETY: one designated writer thread mutates the slots; readers copy
// slot windows and then discard any prefix the re-read head proves may
// have been overwritten during the copy (see `Ring::read`).
unsafe impl Sync for Ring {}

impl Ring {
    fn with_capacity(cap: usize) -> Self {
        assert!(cap.is_power_of_two(), "ring capacity must be a power of two");
        let slots: Vec<UnsafeCell<TraceEvent>> =
            (0..cap).map(|_| UnsafeCell::new(TraceEvent::ZERO)).collect();
        Self { slots: slots.into_boxed_slice(), head: AtomicU64::new(0) }
    }

    /// Append one event, overwriting the oldest when full. Writer-side
    /// only: one slot write + one `Release` store, no allocation ever.
    fn push(&self, ev: TraceEvent) {
        let h = self.head.load(Ordering::Relaxed);
        let idx = (h as usize) & (self.slots.len() - 1);
        // SAFETY: single writer (this ring is reached through a
        // thread-local handle), so no concurrent `push` exists; readers
        // tolerate the overwrite via the head re-read in `read`.
        unsafe {
            *self.slots[idx].get() = ev;
        }
        self.head.store(h + 1, Ordering::Release);
    }

    /// Copy out the currently retained window, oldest first, plus the
    /// count of events dropped by overwriting. Safe against a concurrent
    /// writer: the window is copied, then `head` is re-read and any
    /// prefix the writer may have overwritten meanwhile is discarded.
    fn read(&self) -> (Vec<TraceEvent>, u64) {
        let cap = self.slots.len() as u64;
        let head0 = self.head.load(Ordering::Acquire);
        let avail = head0.min(cap);
        let start = head0 - avail;
        let mut out = Vec::with_capacity(avail as usize);
        for seq in start..head0 {
            let idx = (seq as usize) & (self.slots.len() - 1);
            // SAFETY: the slot may be concurrently overwritten; the copy
            // is a plain memcpy of POD and the re-read below discards
            // every slot the writer could have touched.
            out.push(unsafe { *self.slots[idx].get() });
        }
        let head1 = self.head.load(Ordering::Acquire);
        let valid_from = head1.saturating_sub(cap);
        let skip = (valid_from.saturating_sub(start) as usize).min(out.len());
        out.drain(..skip);
        (out, head1.saturating_sub(cap))
    }

    fn clear(&self) {
        self.head.store(0, Ordering::Release);
    }
}

/// One recording lane: a ring plus the owning thread's name.
struct Lane {
    name: String,
    ring: Ring,
}

struct Collector {
    /// Common time base for every lane's timestamps.
    epoch: Instant,
    /// Lane registry; index = lane id. Locked only on first use per
    /// thread and at snapshot time, never on the event hot path.
    lanes: Mutex<Vec<Arc<Lane>>>,
    next_run: AtomicU64,
    next_trace: AtomicU64,
}

fn collector() -> &'static Collector {
    static COLLECTOR: OnceLock<Collector> = OnceLock::new();
    COLLECTOR.get_or_init(|| Collector {
        epoch: Instant::now(),
        lanes: Mutex::new(Vec::new()),
        next_run: AtomicU64::new(0),
        next_trace: AtomicU64::new(0),
    })
}

thread_local! {
    /// This thread's lane (id + ring handle), registered on first event.
    static LANE: RefCell<Option<(u32, Arc<Lane>)>> = const { RefCell::new(None) };
    /// Request-correlation id the next submitted run inherits.
    static CURRENT_TRACE: Cell<u64> = const { Cell::new(0) };
}

/// Run `f` on this thread's ring, registering a lane on first use.
fn with_ring(f: impl FnOnce(u32, &Ring)) {
    LANE.with(|slot| {
        let mut slot = slot.borrow_mut();
        if slot.is_none() {
            let c = collector();
            let mut lanes = c.lanes.lock().unwrap();
            let id = lanes.len() as u32;
            let name = std::thread::current()
                .name()
                .map(str::to_string)
                .unwrap_or_else(|| format!("thread-{id}"));
            let lane = Arc::new(Lane { name, ring: Ring::with_capacity(RING_CAPACITY) });
            lanes.push(lane.clone());
            *slot = Some((id, lane));
        }
        let (id, lane) = slot.as_ref().unwrap();
        f(*id, &lane.ring);
    });
}

fn rel_ns(t: Instant) -> u64 {
    t.saturating_duration_since(collector().epoch).as_nanos() as u64
}

/// Fresh request-correlation id (monotone, never 0).
pub fn next_trace_id() -> u64 {
    collector().next_trace.fetch_add(1, Ordering::Relaxed) + 1
}

/// Publish the trace id the next DAG run submitted from this thread
/// should carry (what [`crate::session::SolverSession`] installs before
/// executing its DAG).
pub fn set_current_trace_id(id: u64) {
    CURRENT_TRACE.with(|c| c.set(id));
}

/// The trace id currently published on this thread (0 when none).
pub fn current_trace_id() -> u64 {
    CURRENT_TRACE.with(|c| c.get())
}

/// Called by the executor at run submission: when tracing is on, mint a
/// run id and capture the submitting thread's trace id; when off, return
/// `(0, 0)` — the per-task recording sites gate on `run_id != 0`.
pub fn begin_run() -> (u64, u64) {
    if !enabled() {
        return (0, 0);
    }
    (collector().next_run.fetch_add(1, Ordering::Relaxed) + 1, current_trace_id())
}

/// One executed task, as reported by the executor.
pub struct TaskSpan {
    /// Run id minted by [`begin_run`].
    pub run_id: u64,
    /// Trace id captured by [`begin_run`].
    pub trace_id: u64,
    /// DAG task index.
    pub task: u32,
    /// Kernel kind name.
    pub op: &'static str,
    /// Target block coordinates.
    pub target: (usize, usize),
    /// DAG level of the task.
    pub level: u32,
    /// Executing worker.
    pub worker: u32,
    /// Deque the entry came from when stolen, -1 otherwise.
    pub stolen_from: i32,
    /// Kernel start.
    pub start: Instant,
    /// Kernel end.
    pub end: Instant,
}

/// Record one executed task on the calling thread's lane.
pub fn record_task(span: TaskSpan) {
    let ev = TraceEvent {
        kind: EventKind::Task,
        run_id: span.run_id,
        trace_id: span.trace_id,
        task: span.task,
        op: span.op,
        bi: span.target.0 as u32,
        bj: span.target.1 as u32,
        level: span.level,
        worker: span.worker,
        stolen_from: span.stolen_from,
        start_ns: rel_ns(span.start),
        end_ns: rel_ns(span.end),
    };
    with_ring(|_, ring| ring.push(ev));
}

/// Record a whole DAG run span on the calling (submitting) thread's
/// lane — the source anchor of the request→tasks flow arrows.
pub fn record_run(run_id: u64, trace_id: u64, tasks: u32, start: Instant, end: Instant) {
    let ev = TraceEvent {
        kind: EventKind::Run,
        run_id,
        trace_id,
        task: tasks,
        op: "run",
        bi: 0,
        bj: 0,
        level: 0,
        worker: 0,
        stolen_from: -1,
        start_ns: rel_ns(start),
        end_ns: rel_ns(end),
    };
    with_ring(|_, ring| ring.push(ev));
}

/// Reset every lane's ring (bench/test scenario isolation). Call only
/// while no traced run is in flight — a concurrent writer would race the
/// reset benignly (its events land at the ring start) but the snapshot
/// would mix epochs.
pub fn clear() {
    let lanes = collector().lanes.lock().unwrap();
    for lane in lanes.iter() {
        lane.ring.clear();
    }
}

/// One lane's retained events, oldest first.
pub struct LaneSnapshot {
    /// Lane id (Chrome-trace `tid`).
    pub lane: u32,
    /// Owning thread's name at registration.
    pub name: String,
    /// Retained events in recording order (chronological per lane).
    pub events: Vec<TraceEvent>,
}

/// Point-in-time copy of every lane.
pub struct TraceSnapshot {
    /// All lanes, by lane id.
    pub lanes: Vec<LaneSnapshot>,
    /// Events lost to ring overwrites across all lanes since the last
    /// [`clear`].
    pub dropped_events: u64,
}

impl TraceSnapshot {
    /// All retained events across lanes, in lane order.
    pub fn all_events(&self) -> Vec<TraceEvent> {
        self.lanes.iter().flat_map(|l| l.events.iter().copied()).collect()
    }
}

/// Copy out every lane's retained events. Cheap relative to a run (one
/// lock + memcpy per lane) and safe while recording continues.
pub fn snapshot() -> TraceSnapshot {
    let lanes = collector().lanes.lock().unwrap();
    let mut out = Vec::with_capacity(lanes.len());
    let mut dropped = 0u64;
    for (id, lane) in lanes.iter().enumerate() {
        let (events, lost) = lane.ring.read();
        dropped += lost;
        out.push(LaneSnapshot { lane: id as u32, name: lane.name.clone(), events });
    }
    TraceSnapshot { lanes: out, dropped_events: dropped }
}

// --------------------------------------------------------------------
// Chrome-trace / Perfetto export
// --------------------------------------------------------------------

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

fn us(ns: u64) -> f64 {
    ns as f64 / 1000.0
}

/// Render `snap` in Chrome-trace JSON (the `traceEvents` array format
/// Perfetto and `chrome://tracing` load): one `tid` lane per recording
/// thread, `"X"` complete events for tasks and run spans, `"s"`/`"f"`
/// flow arrows linking each run span to its tasks, and thread-name
/// metadata so pool workers show up as `lu-exec-{w}`.
pub fn chrome_trace_of(snap: &TraceSnapshot) -> String {
    let mut evs: Vec<String> = Vec::new();
    evs.push(
        "{\"name\":\"process_name\",\"ph\":\"M\",\"pid\":1,\"tid\":0,\
         \"args\":{\"name\":\"sparselu\"}}"
            .to_string(),
    );
    for lane in &snap.lanes {
        evs.push(format!(
            "{{\"name\":\"thread_name\",\"ph\":\"M\",\"pid\":1,\"tid\":{},\
             \"args\":{{\"name\":\"{}\"}}}}",
            lane.lane,
            json_escape(&lane.name)
        ));
        for e in &lane.events {
            let dur = us(e.end_ns.saturating_sub(e.start_ns));
            match e.kind {
                EventKind::Run => {
                    evs.push(format!(
                        "{{\"name\":\"run #{}\",\"cat\":\"run\",\"ph\":\"X\",\
                         \"ts\":{:.3},\"dur\":{:.3},\"pid\":1,\"tid\":{},\
                         \"args\":{{\"run\":{},\"trace\":{},\"tasks\":{}}}}}",
                        e.run_id,
                        us(e.start_ns),
                        dur,
                        lane.lane,
                        e.run_id,
                        e.trace_id,
                        e.task
                    ));
                    // flow source: arrows fan out from the run span to
                    // every task event carrying the same run id
                    evs.push(format!(
                        "{{\"name\":\"run\",\"cat\":\"flow\",\"ph\":\"s\",\"id\":{},\
                         \"ts\":{:.3},\"pid\":1,\"tid\":{}}}",
                        e.run_id,
                        us(e.start_ns),
                        lane.lane
                    ));
                }
                EventKind::Task => {
                    evs.push(format!(
                        "{{\"name\":\"{}({},{})\",\"cat\":\"task\",\"ph\":\"X\",\
                         \"ts\":{:.3},\"dur\":{:.3},\"pid\":1,\"tid\":{},\
                         \"args\":{{\"task\":{},\"level\":{},\"run\":{},\"trace\":{},\
                         \"worker\":{},\"stolen_from\":{}}}}}",
                        e.op,
                        e.bi,
                        e.bj,
                        us(e.start_ns),
                        dur,
                        lane.lane,
                        e.task,
                        e.level,
                        e.run_id,
                        e.trace_id,
                        e.worker,
                        e.stolen_from
                    ));
                    evs.push(format!(
                        "{{\"name\":\"run\",\"cat\":\"flow\",\"ph\":\"f\",\"bp\":\"e\",\
                         \"id\":{},\"ts\":{:.3},\"pid\":1,\"tid\":{}}}",
                        e.run_id,
                        us(e.start_ns),
                        lane.lane
                    ));
                }
            }
        }
    }
    format!(
        "{{\"displayTimeUnit\":\"ms\",\"otherData\":{{\"dropped_events\":{}}},\
         \"traceEvents\":[\n{}\n]}}\n",
        snap.dropped_events,
        evs.join(",\n")
    )
}

/// [`chrome_trace_of`] over a fresh [`snapshot`] — what `GET /trace`
/// and `repro trace` serve.
pub fn chrome_trace_json() -> String {
    chrome_trace_of(&snapshot())
}

// --------------------------------------------------------------------
// Critical-path analysis
// --------------------------------------------------------------------

/// One of the top-k longest-running tasks of a run.
#[derive(Clone, Debug)]
pub struct Straggler {
    /// DAG task index.
    pub task: u32,
    /// Kernel kind.
    pub op: &'static str,
    /// Target block coordinates.
    pub target: (u32, u32),
    /// DAG level.
    pub level: u32,
    /// Executing worker.
    pub worker: u32,
    /// Measured seconds.
    pub seconds: f64,
}

/// Measured schedule quality of one recorded DAG run.
#[derive(Clone, Debug)]
pub struct RunAnalysis {
    /// The analyzed run.
    pub run_id: u64,
    /// Its request-correlation id.
    pub trace_id: u64,
    /// Task events found for the run.
    pub tasks: usize,
    /// Achieved makespan: last task end minus first task start.
    pub makespan_seconds: f64,
    /// Longest dependency chain through the *measured* durations — the
    /// floor any schedule of this run's timings could reach. (Distinct
    /// from [`TaskDag::critical_path`], which prices the modeled costs.)
    pub critical_path_seconds: f64,
    /// Sum of all measured task durations (total work).
    pub total_task_seconds: f64,
    /// `critical_path / makespan` — 1.0 means the schedule was as tight
    /// as the critical chain allows, lower means workers idled.
    pub scheduling_efficiency: f64,
    /// Longest-running tasks, descending.
    pub stragglers: Vec<Straggler>,
}

/// Walk the recorded timings of run `run_id` against the DAG's edges:
/// longest measured dependency chain, achieved makespan, scheduling
/// efficiency and the `top_k` stragglers. Returns `None` when the run
/// has no task events in `events`.
pub fn analyze_run(
    dag: &TaskDag,
    events: &[TraceEvent],
    run_id: u64,
    top_k: usize,
) -> Option<RunAnalysis> {
    let tasks: Vec<&TraceEvent> = events
        .iter()
        .filter(|e| e.kind == EventKind::Task && e.run_id == run_id)
        .collect();
    if tasks.is_empty() {
        return None;
    }
    let trace_id = tasks[0].trace_id;
    let min_start = tasks.iter().map(|e| e.start_ns).min().unwrap();
    let max_end = tasks.iter().map(|e| e.end_ns).max().unwrap();
    let makespan = (max_end - min_start) as f64 * 1e-9;

    let n = dag.tasks.len();
    let mut dur = vec![0.0f64; n];
    let mut present = vec![false; n];
    let mut total = 0.0f64;
    for e in &tasks {
        let t = e.task as usize;
        if t < n {
            dur[t] = e.seconds();
            present[t] = true;
            total += dur[t];
        }
    }
    // finish[t] = dur[t] + max over present predecessors finish[p];
    // every DAG edge goes to a strictly deeper level, so processing
    // tasks by ascending level is a topological order
    let mut order: Vec<u32> = (0..n as u32).filter(|&t| present[t as usize]).collect();
    order.sort_by_key(|&t| dag.tasks[t as usize].level);
    let mut finish = dur.clone();
    let mut critical = 0.0f64;
    for &t in &order {
        let ft = finish[t as usize];
        critical = critical.max(ft);
        for &o in &dag.tasks[t as usize].out {
            let o = o as usize;
            if present[o] && ft + dur[o] > finish[o] {
                finish[o] = ft + dur[o];
            }
        }
    }

    let mut ranked: Vec<&TraceEvent> = tasks.clone();
    ranked.sort_by(|a, b| b.seconds().total_cmp(&a.seconds()));
    let stragglers = ranked
        .iter()
        .take(top_k)
        .map(|e| Straggler {
            task: e.task,
            op: e.op,
            target: (e.bi, e.bj),
            level: e.level,
            worker: e.worker,
            seconds: e.seconds(),
        })
        .collect();

    Some(RunAnalysis {
        run_id,
        trace_id,
        tasks: tasks.len(),
        makespan_seconds: makespan,
        critical_path_seconds: critical,
        total_task_seconds: total,
        scheduling_efficiency: if makespan > 0.0 { critical / makespan } else { 1.0 },
        stragglers,
    })
}

// --------------------------------------------------------------------
// Per-level balance
// --------------------------------------------------------------------

/// Nonzero and measured-time balance of one DAG level: per *target
/// block* within the level, max/mean is the imbalance factor (1.0 =
/// perfectly balanced).
#[derive(Clone, Debug)]
pub struct LevelBalance {
    /// DAG level.
    pub level: u32,
    /// Task events recorded at this level.
    pub tasks: usize,
    /// Distinct target blocks at this level.
    pub blocks: usize,
    /// Largest target-block nonzero count.
    pub nnz_max: u64,
    /// Mean target-block nonzero count.
    pub nnz_mean: f64,
    /// Total nonzeros across the level's target blocks.
    pub nnz_total: u64,
    /// Largest per-block measured seconds (tasks summed per block).
    pub seconds_max: f64,
    /// Mean per-block measured seconds.
    pub seconds_mean: f64,
    /// Total measured seconds of the level.
    pub seconds_total: f64,
    /// `nnz_max / nnz_mean` within the level.
    pub nnz_imbalance: f64,
    /// `seconds_max / seconds_mean` within the level.
    pub time_imbalance: f64,
}

/// Group run `run_id`'s task events by DAG level and measure the paper's
/// balance claim: per level, the nonzeros of the distinct target blocks
/// and the measured seconds aggregated per target block, each with its
/// max/mean imbalance. Levels are returned ascending.
pub fn level_balance(bm: &BlockedMatrix, events: &[TraceEvent], run_id: u64) -> Vec<LevelBalance> {
    use std::collections::BTreeMap;
    // level -> target block (bi,bj) -> (nnz, seconds)
    let mut levels: BTreeMap<u32, BTreeMap<(u32, u32), (u64, f64, usize)>> = BTreeMap::new();
    for e in events {
        if e.kind != EventKind::Task || e.run_id != run_id {
            continue;
        }
        let nnz = bm
            .block_id(e.bi as usize, e.bj as usize)
            .map(|id| bm.block(id).nnz() as u64)
            .unwrap_or(0);
        let slot = levels
            .entry(e.level)
            .or_default()
            .entry((e.bi, e.bj))
            .or_insert((nnz, 0.0, 0));
        slot.1 += e.seconds();
        slot.2 += 1;
    }
    levels
        .into_iter()
        .map(|(level, blocks)| {
            let nblocks = blocks.len();
            let tasks: usize = blocks.values().map(|&(_, _, t)| t).sum();
            let nnz_total: u64 = blocks.values().map(|&(z, _, _)| z).sum();
            let nnz_max: u64 = blocks.values().map(|&(z, _, _)| z).max().unwrap_or(0);
            let seconds_total: f64 = blocks.values().map(|&(_, s, _)| s).sum();
            let seconds_max: f64 = blocks.values().map(|&(_, s, _)| s).fold(0.0f64, f64::max);
            let nnz_mean = nnz_total as f64 / nblocks.max(1) as f64;
            let seconds_mean = seconds_total / nblocks.max(1) as f64;
            LevelBalance {
                level,
                tasks,
                blocks: nblocks,
                nnz_max,
                nnz_mean,
                nnz_total,
                seconds_max,
                seconds_mean,
                seconds_total,
                nnz_imbalance: ratio(nnz_max as f64, nnz_mean),
                time_imbalance: ratio(seconds_max, seconds_mean),
            }
        })
        .collect()
}

fn ratio(max: f64, mean: f64) -> f64 {
    if mean > 0.0 {
        max / mean
    } else {
        1.0
    }
}

/// Across-level imbalance `(nnz, seconds)`: max/mean over the per-level
/// totals of `levels` — the complement of the within-level factors.
pub fn imbalance_across(levels: &[LevelBalance]) -> (f64, f64) {
    if levels.is_empty() {
        return (1.0, 1.0);
    }
    let n = levels.len() as f64;
    let nnz_mean = levels.iter().map(|l| l.nnz_total as f64).sum::<f64>() / n;
    let nnz_max = levels.iter().map(|l| l.nnz_total as f64).fold(0.0f64, f64::max);
    let sec_mean = levels.iter().map(|l| l.seconds_total).sum::<f64>() / n;
    let sec_max = levels.iter().map(|l| l.seconds_total).fold(0.0f64, f64::max);
    (ratio(nnz_max, nnz_mean), ratio(sec_max, sec_mean))
}

// --------------------------------------------------------------------
// Minimal JSON reader (the crate writes JSON by hand and has no serde;
// the golden trace tests and `repro metrics-dump --trace-summary` need
// to read it back)
// --------------------------------------------------------------------

/// A parsed JSON value (objects keep insertion order).
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    /// `null`
    Null,
    /// `true` / `false`
    Bool(bool),
    /// Any number (always read as `f64`).
    Num(f64),
    /// String.
    Str(String),
    /// Array.
    Arr(Vec<Json>),
    /// Object, as ordered key/value pairs.
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Object member by key.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(pairs) => pairs.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// Numeric value.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(v) => Some(*v),
            _ => None,
        }
    }

    /// String value.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Array elements.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }
}

/// Strict recursive-descent JSON parser: one value, trailing whitespace
/// only. Errors carry a byte offset.
pub fn parse_json(s: &str) -> Result<Json, String> {
    let mut p = JsonParser { bytes: s.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(format!("trailing data at byte {}", p.pos));
    }
    Ok(v)
}

struct JsonParser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl JsonParser<'_> {
    fn skip_ws(&mut self) {
        while let Some(&b) = self.bytes.get(self.pos) {
            if b == b' ' || b == b'\t' || b == b'\n' || b == b'\r' {
                self.pos += 1;
            } else {
                break;
            }
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> Result<(), String> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(format!("expected {:?} at byte {}", b as char, self.pos))
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.pos))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(b'-') | Some(b'0'..=b'9') => self.number(),
            _ => Err(format!("unexpected byte {}", self.pos)),
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.skip_ws();
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.pos)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            out.push((key, self.value()?));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.pos)),
            }
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".to_string()),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    let esc = self.peek().ok_or("unterminated escape")?;
                    self.pos += 1;
                    match esc {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'b' => out.push('\u{0008}'),
                        b'f' => out.push('\u{000c}'),
                        b'u' => {
                            let code = self.hex4()?;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        _ => return Err(format!("bad escape at byte {}", self.pos)),
                    }
                }
                Some(_) => {
                    // consume one UTF-8 scalar (input is a &str, so
                    // boundaries are valid)
                    let start = self.pos;
                    let mut end = start + 1;
                    while end < self.bytes.len() && (self.bytes[end] & 0xC0) == 0x80 {
                        end += 1;
                    }
                    out.push_str(std::str::from_utf8(&self.bytes[start..end]).unwrap());
                    self.pos = end;
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, String> {
        if self.pos + 4 > self.bytes.len() {
            return Err("truncated \\u escape".to_string());
        }
        let s = std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])
            .map_err(|_| "bad \\u escape".to_string())?;
        let code = u32::from_str_radix(s, 16).map_err(|_| "bad \\u escape".to_string())?;
        self.pos += 4;
        Ok(code)
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9') | Some(b'.') | Some(b'e') | Some(b'E'))
            || (self.pos > start && matches!(self.peek(), Some(b'+') | Some(b'-')))
        {
            // '+'/'-' only directly after an exponent marker
            if matches!(self.peek(), Some(b'+') | Some(b'-'))
                && !matches!(self.bytes.get(self.pos - 1), Some(b'e') | Some(b'E'))
            {
                break;
            }
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| format!("bad number {text:?} at byte {start}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(task: u32, level: u32, start_ns: u64, end_ns: u64) -> TraceEvent {
        TraceEvent {
            kind: EventKind::Task,
            run_id: 77,
            trace_id: 5,
            task,
            op: "ssssm",
            bi: task,
            bj: task,
            level,
            worker: 0,
            stolen_from: -1,
            start_ns,
            end_ns,
        }
    }

    #[test]
    fn ring_overflow_drops_oldest_counts_and_never_reallocates() {
        let ring = Ring::with_capacity(8);
        let base = ring.slots.as_ptr();
        for i in 0..20u64 {
            ring.push(ev(i as u32, 0, i * 10, i * 10 + 5));
        }
        // no reallocation on the hot path: the slot storage is the same
        assert!(std::ptr::eq(base, ring.slots.as_ptr()));
        let (events, dropped) = ring.read();
        assert_eq!(events.len(), 8, "ring retains exactly its capacity");
        assert_eq!(dropped, 12, "12 of 20 events were overwritten");
        // the retained window is the newest 8, oldest first
        let tasks: Vec<u32> = events.iter().map(|e| e.task).collect();
        assert_eq!(tasks, (12..20).collect::<Vec<u32>>());
    }

    #[test]
    fn ring_read_before_wrap_returns_everything() {
        let ring = Ring::with_capacity(8);
        for i in 0..5u64 {
            ring.push(ev(i as u32, 0, i, i + 1));
        }
        let (events, dropped) = ring.read();
        assert_eq!(events.len(), 5);
        assert_eq!(dropped, 0);
        ring.clear();
        let (events, dropped) = ring.read();
        assert!(events.is_empty());
        assert_eq!(dropped, 0);
    }

    #[test]
    fn critical_path_on_a_hand_built_dag() {
        use crate::coordinator::Task;
        use crate::numeric::factor::BlockOp;
        // diamond: 0 -> {1, 2} -> 3; durations 10, 30, 20, 40 (ns)
        let mk = |out: Vec<u32>, level: u32| Task {
            op: BlockOp::Getrf { k: 0 },
            owner: 0,
            deps: 0,
            out,
            cost: 0.0,
            flops: 0.0,
            out_bytes: 0.0,
            level,
        };
        let dag = TaskDag {
            tasks: vec![
                mk(vec![1, 2], 0),
                mk(vec![3], 1),
                mk(vec![3], 1),
                mk(vec![], 2),
            ],
            num_levels: 3,
            total_flops: 0.0,
            critical_path: 0.0,
        };
        // schedule: 0 on [0,10], 1 on [10,40], 2 on [10,30], 3 on [40,80]
        let events = vec![
            ev(0, 0, 0, 10),
            ev(1, 1, 10, 40),
            ev(2, 1, 10, 30),
            ev(3, 2, 40, 80),
        ];
        let a = analyze_run(&dag, &events, 77, 2).unwrap();
        assert_eq!(a.tasks, 4);
        // longest chain 0 -> 1 -> 3 = 10 + 30 + 40 = 80 ns
        assert!((a.critical_path_seconds - 80e-9).abs() < 1e-15);
        assert!((a.makespan_seconds - 80e-9).abs() < 1e-15);
        assert!((a.total_task_seconds - 100e-9).abs() < 1e-15);
        assert!((a.scheduling_efficiency - 1.0).abs() < 1e-9);
        assert!(a.critical_path_seconds <= a.makespan_seconds + 1e-15);
        // stragglers descend: task 3 (40ns) then task 1 (30ns)
        assert_eq!(a.stragglers.len(), 2);
        assert_eq!(a.stragglers[0].task, 3);
        assert_eq!(a.stragglers[1].task, 1);
        // unknown run id -> no analysis
        assert!(analyze_run(&dag, &events, 999, 2).is_none());
    }

    #[test]
    fn json_parser_roundtrips_the_shapes_we_emit() {
        let v = parse_json(
            "{\"a\": [1, 2.5, -3e-2], \"s\": \"x\\\"y\\u0041\", \
             \"t\": true, \"n\": null}",
        )
        .unwrap();
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[0].as_f64(), Some(1.0));
        assert_eq!(arr[2].as_f64(), Some(-0.03));
        assert_eq!(v.get("s").unwrap().as_str(), Some("x\"yA"));
        assert_eq!(v.get("t"), Some(&Json::Bool(true)));
        assert_eq!(v.get("n"), Some(&Json::Null));
        assert!(parse_json("{\"unterminated\": ").is_err());
        assert!(parse_json("[1,]").is_err());
        assert!(parse_json("{} trailing").is_err());
    }

    #[test]
    fn chrome_export_of_a_synthetic_snapshot_parses_and_is_monotone() {
        let mut run = ev(3, 0, 0, 100);
        run.kind = EventKind::Run;
        let snap = TraceSnapshot {
            lanes: vec![
                LaneSnapshot { lane: 0, name: "main".into(), events: vec![run] },
                LaneSnapshot {
                    lane: 1,
                    name: "lu-exec-1".into(),
                    events: vec![ev(0, 0, 0, 40), ev(1, 1, 40, 90)],
                },
            ],
            dropped_events: 0,
        };
        let text = chrome_trace_of(&snap);
        let v = parse_json(&text).unwrap();
        let evs = v.get("traceEvents").unwrap().as_arr().unwrap();
        // per tid, "X" events must be monotone in ts
        let mut last_ts: std::collections::HashMap<i64, f64> = std::collections::HashMap::new();
        let mut slices = 0;
        for e in evs {
            if e.get("ph").unwrap().as_str() != Some("X") {
                continue;
            }
            slices += 1;
            let tid = e.get("tid").unwrap().as_f64().unwrap() as i64;
            let ts = e.get("ts").unwrap().as_f64().unwrap();
            let dur = e.get("dur").unwrap().as_f64().unwrap();
            assert!(dur >= 0.0);
            if let Some(prev) = last_ts.insert(tid, ts) {
                assert!(ts >= prev, "lane {tid} not monotone");
            }
        }
        assert_eq!(slices, 3);
    }

    #[test]
    fn trace_ids_are_unique_and_thread_local_id_roundtrips() {
        let a = next_trace_id();
        let b = next_trace_id();
        assert!(b > a);
        set_current_trace_id(a);
        assert_eq!(current_trace_id(), a);
        set_current_trace_id(0);
        assert_eq!(current_trace_id(), 0);
    }
}
