//! Prometheus text exposition format 0.0.4: renderer and validator.
//!
//! [`render`] turns a registry snapshot into the `# HELP` / `# TYPE` /
//! sample-line text a Prometheus server scrapes; [`validate`] is a
//! strict parser of that format used three ways: by the golden
//! format-conformance test, by `repro metrics-dump --check`, and by CI
//! against the `BENCH_metrics.txt` artifact. Having the validator in
//! the tree (instead of trusting the renderer) means a rendering
//! regression fails a test with the offending line, not a scrape in
//! production.

use super::metrics::{FamilySnapshot, MetricKind, SampleValue};
use std::collections::{HashMap, HashSet};
use std::fmt::Write as _;

/// Escape a `# HELP` text: `\` and newline.
fn escape_help(s: &str) -> String {
    s.replace('\\', "\\\\").replace('\n', "\\n")
}

/// Escape a label value: `\`, `"` and newline.
fn escape_label_value(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"").replace('\n', "\\n")
}

/// Format a sample value / bucket bound the way Prometheus expects:
/// `+Inf`, `-Inf`, `NaN`, else shortest `f64` display.
fn fmt_value(v: f64) -> String {
    if v.is_nan() {
        "NaN".to_string()
    } else if v == f64::INFINITY {
        "+Inf".to_string()
    } else if v == f64::NEG_INFINITY {
        "-Inf".to_string()
    } else {
        format!("{v}")
    }
}

/// Render `{k="v",...}`; `extra` appends a final pair (used for `le`).
fn fmt_labels(labels: &[(String, String)], extra: Option<(&str, &str)>) -> String {
    let mut parts: Vec<String> =
        labels.iter().map(|(k, v)| format!("{k}=\"{}\"", escape_label_value(v))).collect();
    if let Some((k, v)) = extra {
        parts.push(format!("{k}=\"{}\"", escape_label_value(v)));
    }
    if parts.is_empty() { String::new() } else { format!("{{{}}}", parts.join(",")) }
}

/// Render families (as produced by
/// [`crate::obs::Registry::snapshot`]) to exposition text.
pub fn render(families: &[FamilySnapshot]) -> String {
    let mut out = String::new();
    for fam in families {
        if fam.series.is_empty() {
            continue;
        }
        let _ = writeln!(out, "# HELP {} {}", fam.name, escape_help(&fam.help));
        let _ = writeln!(out, "# TYPE {} {}", fam.name, fam.kind.as_str());
        for s in &fam.series {
            match &s.value {
                SampleValue::Counter(v) => {
                    let _ = writeln!(out, "{}{} {v}", fam.name, fmt_labels(&s.labels, None));
                }
                SampleValue::Gauge(v) => {
                    let _ = writeln!(
                        out,
                        "{}{} {}",
                        fam.name,
                        fmt_labels(&s.labels, None),
                        fmt_value(*v)
                    );
                }
                SampleValue::Histogram(h) => {
                    let mut cum = 0u64;
                    for (i, bound) in h.bounds.iter().enumerate() {
                        cum += h.counts[i];
                        let _ = writeln!(
                            out,
                            "{}_bucket{} {cum}",
                            fam.name,
                            fmt_labels(&s.labels, Some(("le", &fmt_value(*bound))))
                        );
                    }
                    cum += h.counts[h.bounds.len()];
                    let _ = writeln!(
                        out,
                        "{}_bucket{} {cum}",
                        fam.name,
                        fmt_labels(&s.labels, Some(("le", "+Inf")))
                    );
                    let _ = writeln!(
                        out,
                        "{}_sum{} {}",
                        fam.name,
                        fmt_labels(&s.labels, None),
                        fmt_value(h.sum)
                    );
                    let _ =
                        writeln!(out, "{}_count{} {cum}", fam.name, fmt_labels(&s.labels, None));
                }
            }
        }
    }
    out
}

/// What [`validate`] found in a conforming exposition.
#[derive(Clone, Debug)]
pub struct ExpoSummary {
    /// Number of metric families (`# TYPE` lines).
    pub families: usize,
    /// Total sample lines.
    pub samples: usize,
    /// Distinct series identities: one per `(family, label set)` —
    /// histogram `_bucket`/`_sum`/`_count` lines collapse into one.
    pub series: Vec<String>,
}

/// A parsed sample line.
struct Sample {
    name: String,
    labels: Vec<(String, String)>,
    value: f64,
}

fn parse_value(s: &str) -> Result<f64, String> {
    match s {
        "+Inf" => Ok(f64::INFINITY),
        "-Inf" => Ok(f64::NEG_INFINITY),
        "NaN" => Ok(f64::NAN),
        _ => s.parse::<f64>().map_err(|_| format!("unparseable value {s:?}")),
    }
}

fn valid_name(s: &str, label: bool) -> bool {
    let mut chars = s.chars();
    match chars.next() {
        Some(c) if c.is_ascii_alphabetic() || c == '_' || (!label && c == ':') => {}
        _ => return false,
    }
    chars.all(|c| c.is_ascii_alphanumeric() || c == '_' || (!label && c == ':'))
}

/// Parse one sample line into name, labels and value.
fn parse_sample(line: &str) -> Result<Sample, String> {
    let err = |m: &str| format!("{m} in {line:?}");
    let (name_part, rest) = match line.find('{') {
        Some(b) => (&line[..b], &line[b..]),
        None => {
            let sp = line.find(' ').ok_or_else(|| err("missing value"))?;
            (&line[..sp], &line[sp..])
        }
    };
    if !valid_name(name_part, false) {
        return Err(err("invalid metric name"));
    }
    let mut labels = Vec::new();
    let value_str;
    if let Some(rest) = rest.strip_prefix('{') {
        // parse k="v" pairs, honoring escapes inside the quoted value
        let mut chars = rest.char_indices().peekable();
        let mut key_start = 0;
        loop {
            // key
            let eq = loop {
                match chars.next() {
                    Some((i, '=')) => break i,
                    Some((_, c)) if c.is_ascii_alphanumeric() || c == '_' => {}
                    Some((i, '}')) if i == key_start => {
                        // empty label set `{}` — only legal as the whole set
                        if !labels.is_empty() {
                            return Err(err("trailing comma before }"));
                        }
                        break usize::MAX;
                    }
                    _ => return Err(err("malformed label name")),
                }
            };
            if eq == usize::MAX {
                let after = &rest[key_start + 1..];
                value_str = after.strip_prefix(' ').ok_or_else(|| err("missing value"))?;
                break;
            }
            let key = &rest[key_start..eq];
            if !valid_name(key, true) {
                return Err(err("invalid label name"));
            }
            match chars.next() {
                Some((_, '"')) => {}
                _ => return Err(err("label value not quoted")),
            }
            let mut val = String::new();
            loop {
                match chars.next() {
                    Some((_, '\\')) => match chars.next() {
                        Some((_, '\\')) => val.push('\\'),
                        Some((_, '"')) => val.push('"'),
                        Some((_, 'n')) => val.push('\n'),
                        _ => return Err(err("bad escape in label value")),
                    },
                    Some((_, '"')) => break,
                    Some((_, c)) => val.push(c),
                    None => return Err(err("unterminated label value")),
                }
            }
            labels.push((key.to_string(), val));
            match chars.next() {
                Some((i, ',')) => key_start = i + 1,
                Some((i, '}')) => {
                    let after = &rest[i + 1..];
                    value_str = after.strip_prefix(' ').ok_or_else(|| err("missing value"))?;
                    break;
                }
                _ => return Err(err("expected , or } after label")),
            }
        }
    } else {
        value_str = rest.strip_prefix(' ').ok_or_else(|| err("missing value"))?;
    }
    let value_str = value_str.trim_end();
    if value_str.contains(' ') {
        // a timestamp would appear here; we neither emit nor accept one
        return Err(err("unexpected timestamp or trailing tokens"));
    }
    let value = parse_value(value_str).map_err(|m| err(&m))?;
    Ok(Sample { name: name_part.to_string(), labels, value })
}

fn series_id(family: &str, labels: &[(String, String)]) -> String {
    let mut labels: Vec<&(String, String)> = labels.iter().collect();
    labels.sort();
    let parts: Vec<String> = labels.iter().map(|(k, v)| format!("{k}={v:?}")).collect();
    format!("{family}{{{}}}", parts.join(","))
}

/// Validate exposition text, enforcing what our renderer (and a scrape
/// consumer) rely on:
///
/// * every family has `# HELP` then `# TYPE` (in that order, once),
///   followed by that family's samples, contiguously;
/// * sample names match the family (histogram samples may append
///   `_bucket`/`_sum`/`_count`);
/// * label names and metric names are well-formed, label values
///   unescape cleanly, values parse as `f64`;
/// * no duplicate `(name, labels)` sample;
/// * per histogram series: `le` bounds ascending with `+Inf` last,
///   bucket counts cumulative (non-decreasing), `le="+Inf"` equals
///   `_count`, and `_sum`/`_count` both present;
/// * counter values are finite and non-negative.
pub fn validate(text: &str) -> Result<ExpoSummary, String> {
    let mut families = 0usize;
    let mut samples = 0usize;
    let mut seen_families: HashSet<String> = HashSet::new();
    let mut seen_samples: HashSet<String> = HashSet::new();
    let mut series: HashSet<String> = HashSet::new();

    // current family state
    let mut cur_name: Option<String> = None;
    let mut cur_kind: Option<MetricKind> = None;
    let mut cur_has_samples = false;
    let mut pending_help: Option<String> = None;
    // histogram bookkeeping for the *current* family:
    // series-id → (bounds-with-counts, sum?, count?)
    type HistState = (Vec<(f64, f64)>, Option<f64>, Option<f64>);
    let mut hist: HashMap<String, HistState> = HashMap::new();

    fn close_family(
        name: &Option<String>,
        kind: &Option<MetricKind>,
        has_samples: bool,
        hist: &mut HashMap<String, HistState>,
    ) -> Result<(), String> {
        if let Some(name) = name {
            if !has_samples {
                return Err(format!("family {name} has HELP/TYPE but no samples"));
            }
            if *kind == Some(MetricKind::Histogram) {
                for (id, (buckets, sum, count)) in hist.iter() {
                    if buckets.is_empty() {
                        return Err(format!("histogram series {id} has no buckets"));
                    }
                    let mut prev_bound = f64::NEG_INFINITY;
                    let mut prev_cum = -1.0;
                    for (bound, cum) in buckets {
                        if *bound <= prev_bound {
                            return Err(format!("histogram {id}: le bounds not ascending"));
                        }
                        if *cum < prev_cum {
                            return Err(format!("histogram {id}: bucket counts not cumulative"));
                        }
                        prev_bound = *bound;
                        prev_cum = *cum;
                    }
                    let (last_bound, last_cum) = buckets[buckets.len() - 1];
                    if last_bound != f64::INFINITY {
                        return Err(format!("histogram {id}: missing le=\"+Inf\" bucket"));
                    }
                    let sum = sum.ok_or_else(|| format!("histogram {id}: missing _sum"))?;
                    let count = count.ok_or_else(|| format!("histogram {id}: missing _count"))?;
                    if count != last_cum {
                        return Err(format!(
                            "histogram {id}: _count {count} != +Inf bucket {last_cum}"
                        ));
                    }
                    if !sum.is_finite() {
                        return Err(format!("histogram {id}: non-finite _sum"));
                    }
                }
            }
        }
        hist.clear();
        Ok(())
    }

    for raw in text.lines() {
        let line = raw.trim_end();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix("# HELP ") {
            if let Some(orphan) = pending_help.take() {
                return Err(format!("HELP for {orphan} not followed by its TYPE"));
            }
            close_family(&cur_name, &cur_kind, cur_has_samples, &mut hist)?;
            cur_name = None;
            cur_kind = None;
            cur_has_samples = false;
            let (name, _help) =
                rest.split_once(' ').map(|(n, h)| (n, h)).unwrap_or((rest, ""));
            if !valid_name(name, false) {
                return Err(format!("invalid family name in {line:?}"));
            }
            if !seen_families.insert(name.to_string()) {
                return Err(format!("family {name} declared twice"));
            }
            pending_help = Some(name.to_string());
        } else if let Some(rest) = line.strip_prefix("# TYPE ") {
            let (name, kind_str) =
                rest.split_once(' ').ok_or_else(|| format!("malformed TYPE line {line:?}"))?;
            match pending_help.take() {
                Some(h) if h == name => {}
                _ => return Err(format!("TYPE for {name} not directly preceded by its HELP")),
            }
            let kind = match kind_str {
                "counter" => MetricKind::Counter,
                "gauge" => MetricKind::Gauge,
                "histogram" => MetricKind::Histogram,
                other => return Err(format!("unknown metric type {other:?}")),
            };
            cur_name = Some(name.to_string());
            cur_kind = Some(kind);
            families += 1;
        } else if line.starts_with('#') {
            return Err(format!("unexpected comment line {line:?}"));
        } else {
            let fam = cur_name
                .as_deref()
                .ok_or_else(|| format!("sample before any HELP/TYPE: {line:?}"))?;
            let kind = cur_kind.unwrap();
            let s = parse_sample(line)?;
            samples += 1;
            let id = series_id(&s.name, &s.labels);
            if !seen_samples.insert(id) {
                return Err(format!("duplicate sample {line:?}"));
            }
            match kind {
                MetricKind::Counter | MetricKind::Gauge => {
                    if s.name != fam {
                        return Err(format!("sample {} under family {fam}", s.name));
                    }
                    if kind == MetricKind::Counter && !(s.value.is_finite() && s.value >= 0.0) {
                        return Err(format!("counter {fam} has invalid value {}", s.value));
                    }
                    series.insert(series_id(fam, &s.labels));
                }
                MetricKind::Histogram => {
                    let suffix = s
                        .name
                        .strip_prefix(fam)
                        .ok_or_else(|| format!("sample {} under family {fam}", s.name))?;
                    let mut base_labels = s.labels.clone();
                    match suffix {
                        "_bucket" => {
                            let pos = base_labels
                                .iter()
                                .position(|(k, _)| k == "le")
                                .ok_or_else(|| format!("_bucket without le: {line:?}"))?;
                            let (_, le) = base_labels.remove(pos);
                            let bound = parse_value(&le)
                                .map_err(|m| format!("{m} in le of {line:?}"))?;
                            let id = series_id(fam, &base_labels);
                            hist.entry(id.clone()).or_default().0.push((bound, s.value));
                            series.insert(id);
                        }
                        "_sum" => {
                            let id = series_id(fam, &base_labels);
                            let slot = hist.entry(id.clone()).or_default();
                            if slot.1.replace(s.value).is_some() {
                                return Err(format!("duplicate _sum for {id}"));
                            }
                            series.insert(id);
                        }
                        "_count" => {
                            let id = series_id(fam, &base_labels);
                            let slot = hist.entry(id.clone()).or_default();
                            if slot.2.replace(s.value).is_some() {
                                return Err(format!("duplicate _count for {id}"));
                            }
                            series.insert(id);
                        }
                        other => {
                            return Err(format!(
                                "histogram sample suffix {other:?} in {line:?}"
                            ))
                        }
                    }
                }
            }
            cur_has_samples = true;
        }
    }
    if pending_help.is_some() {
        return Err("HELP without a following TYPE at end of input".to_string());
    }
    close_family(&cur_name, &cur_kind, cur_has_samples, &mut hist)?;
    let mut series: Vec<String> = series.into_iter().collect();
    series.sort();
    Ok(ExpoSummary { families, samples, series })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::Registry;

    #[test]
    fn renders_and_validates_roundtrip() {
        let r = Registry::new();
        r.counter("t_ops_total", "operations", &[("tenant", "a")]).add(3);
        r.gauge("t_depth", "queue depth", &[]).set(2.0);
        let h = r.histogram("t_wait_seconds", "wait", &[("tenant", "a")], &[0.1, 1.0]);
        h.observe(0.05);
        h.observe(0.5);
        h.observe(5.0);
        let text = r.render();
        let summary = validate(&text).expect("rendered output must validate");
        assert_eq!(summary.families, 3);
        // 1 counter + 1 gauge + (3 buckets + sum + count)
        assert_eq!(summary.samples, 7);
        assert_eq!(summary.series.len(), 3);
    }

    #[test]
    fn label_escaping_roundtrips() {
        let r = Registry::new();
        let hairy = "a\\b\"c\nd";
        r.counter("t_total", "t", &[("name", hairy)]).inc();
        let text = r.render();
        assert!(text.contains(r#"name="a\\b\"c\nd""#), "escaped form present: {text}");
        let sample = text.lines().find(|l| !l.starts_with('#')).unwrap();
        let parsed = parse_sample(sample).unwrap();
        assert_eq!(parsed.labels[0].1, hairy, "unescape restores the original");
        validate(&text).unwrap();
    }

    #[test]
    fn validator_rejects_malformed_input() {
        // TYPE before HELP
        let bad = "# TYPE t_x counter\n# HELP t_x x\nt_x 1\n";
        assert!(validate(bad).is_err());
        // non-cumulative buckets
        let bad = "# HELP t_h h\n# TYPE t_h histogram\n\
                   t_h_bucket{le=\"1\"} 5\nt_h_bucket{le=\"+Inf\"} 3\n\
                   t_h_sum 1\nt_h_count 3\n";
        assert!(validate(bad).unwrap_err().contains("cumulative"));
        // count mismatch
        let bad = "# HELP t_h h\n# TYPE t_h histogram\n\
                   t_h_bucket{le=\"1\"} 2\nt_h_bucket{le=\"+Inf\"} 3\n\
                   t_h_sum 1\nt_h_count 4\n";
        assert!(validate(bad).unwrap_err().contains("_count"));
        // duplicate series
        let bad = "# HELP t_x x\n# TYPE t_x counter\nt_x 1\nt_x 2\n";
        assert!(validate(bad).unwrap_err().contains("duplicate"));
        // sample under wrong family
        let bad = "# HELP t_x x\n# TYPE t_x counter\nt_y 1\n";
        assert!(validate(bad).is_err());
        // negative counter
        let bad = "# HELP t_x x\n# TYPE t_x counter\nt_x -1\n";
        assert!(validate(bad).is_err());
        // missing +Inf
        let bad = "# HELP t_h h\n# TYPE t_h histogram\n\
                   t_h_bucket{le=\"1\"} 2\nt_h_sum 1\nt_h_count 2\n";
        assert!(validate(bad).unwrap_err().contains("+Inf"));
    }

    #[test]
    fn empty_families_are_not_rendered() {
        let r = Registry::new();
        let text = r.render();
        assert!(text.is_empty());
        let s = validate(&text).unwrap();
        assert_eq!(s.families, 0);
    }
}
