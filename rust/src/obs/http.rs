//! Minimal blocking HTTP scrape endpoint on `std::net::TcpListener`.
//!
//! Serves three routes — `GET /metrics` (exposition text 0.0.4),
//! `GET /trace` (Chrome-trace JSON of the current
//! [`crate::obs::trace`] recording) and `GET /healthz` — one
//! connection at a time on a background
//! thread. Scrapes are rare (seconds apart) and small (tens of KB), so
//! a single-threaded accept loop with short socket timeouts under a
//! hard per-connection deadline (`CONNECTION_DEADLINE`) is the
//! whole server; there is deliberately no HTTP library, keep-alive,
//! TLS or routing table. [`scrape`] is the matching one-call client
//! used by `repro metrics-dump --addr`, the serve-bench self-scrape
//! and the integration tests.

use super::Registry;
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Content-Type for exposition format 0.0.4.
pub const CONTENT_TYPE: &str = "text/plain; version=0.0.4; charset=utf-8";

/// Handle to a running scrape endpoint; shuts the server down on drop.
pub struct MetricsServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    thread: Option<std::thread::JoinHandle<()>>,
}

impl MetricsServer {
    /// Bind `addr` (e.g. `"127.0.0.1:9464"`, port 0 for ephemeral) and
    /// serve `registry` until the handle is dropped.
    pub fn serve(addr: &str, registry: Arc<Registry>) -> std::io::Result<MetricsServer> {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let thread = {
            let stop = stop.clone();
            std::thread::Builder::new()
                .name("metrics-http".to_string())
                .spawn(move || {
                    for conn in listener.incoming() {
                        if stop.load(Ordering::Acquire) {
                            break;
                        }
                        if let Ok(stream) = conn {
                            // a broken scraper must not kill the server
                            let _ = handle_connection(stream, &registry);
                        }
                    }
                })
                .expect("spawn metrics-http thread")
        };
        Ok(MetricsServer { addr: local, stop, thread: Some(thread) })
    }

    /// The bound address (resolves port 0 to the real ephemeral port).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }
}

impl Drop for MetricsServer {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Release);
        // unblock the accept loop with a throwaway connection
        let _ = TcpStream::connect(self.addr);
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

/// Hard wall-clock budget for one whole connection (request read +
/// response write). The per-syscall socket timeouts bound each
/// *individual* read or write, but a slow-loris client trickling one
/// byte per interval resets them every time — and the accept loop is
/// single-threaded, so one such client would wedge every scrape after
/// it. Every syscall timeout below is re-armed with the *remaining*
/// budget instead, so a stalled or trickling peer costs at most this
/// long before the connection is dropped.
const CONNECTION_DEADLINE: Duration = Duration::from_secs(5);

/// What is left of the connection budget, as an `Err(TimedOut)` once
/// it is exhausted (socket timeouts reject zero durations, so an empty
/// budget must become an error rather than `Some(0)`).
fn remaining(deadline: Instant) -> std::io::Result<Duration> {
    deadline
        .checked_duration_since(Instant::now())
        .filter(|d| !d.is_zero())
        .ok_or_else(|| {
            std::io::Error::new(std::io::ErrorKind::TimedOut, "connection deadline exceeded")
        })
}

fn handle_connection(mut stream: TcpStream, registry: &Registry) -> std::io::Result<()> {
    let deadline = Instant::now() + CONNECTION_DEADLINE;
    // read until end of request head; cap at 8 KB (we ignore bodies)
    let mut head = Vec::new();
    let mut buf = [0u8; 1024];
    loop {
        // re-arm with the remaining budget: a trickling client runs
        // the budget down instead of resetting a fixed timeout
        stream.set_read_timeout(Some(remaining(deadline)?.min(Duration::from_secs(2))))?;
        let n = stream.read(&mut buf)?;
        if n == 0 {
            break;
        }
        head.extend_from_slice(&buf[..n]);
        if head.windows(4).any(|w| w == b"\r\n\r\n") || head.len() > 8192 {
            break;
        }
    }
    let head = String::from_utf8_lossy(&head);
    let request_line = head.lines().next().unwrap_or("");
    let mut parts = request_line.split_whitespace();
    let (method, path) = (parts.next().unwrap_or(""), parts.next().unwrap_or(""));
    let (status, content_type, body) = if method != "GET" {
        ("405 Method Not Allowed", "text/plain", "method not allowed\n".to_string())
    } else {
        match path {
            "/metrics" => ("200 OK", CONTENT_TYPE, registry.render()),
            // whatever the process-wide trace collector currently holds
            // (empty traceEvents when tracing was never enabled)
            "/trace" => ("200 OK", "application/json", crate::obs::trace::chrome_trace_json()),
            "/healthz" => ("200 OK", "text/plain", "ok\n".to_string()),
            _ => ("404 Not Found", "text/plain", "not found\n".to_string()),
        }
    };
    let header = format!(
        "HTTP/1.1 {status}\r\nContent-Type: {content_type}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
        body.len()
    );
    // chunked writes under the same budget, so a client that stops
    // reading mid-response cannot hold the handler past the deadline
    let mut out = Vec::with_capacity(header.len() + body.len());
    out.extend_from_slice(header.as_bytes());
    out.extend_from_slice(body.as_bytes());
    let mut sent = 0;
    while sent < out.len() {
        stream.set_write_timeout(Some(remaining(deadline)?))?;
        let n = stream.write(&out[sent..])?;
        if n == 0 {
            return Err(std::io::Error::new(
                std::io::ErrorKind::WriteZero,
                "client stopped reading the response",
            ));
        }
        sent += n;
    }
    stream.flush()
}

/// One-shot HTTP GET against a metrics endpoint; returns the response
/// body, or an error carrying the status line for non-200 responses.
pub fn scrape(addr: impl ToSocketAddrs, path: &str) -> std::io::Result<String> {
    let mut stream = TcpStream::connect(addr)?;
    stream.set_read_timeout(Some(Duration::from_secs(5)))?;
    let request = format!("GET {path} HTTP/1.1\r\nHost: metrics\r\nConnection: close\r\n\r\n");
    stream.write_all(request.as_bytes())?;
    let mut response = String::new();
    stream.read_to_string(&mut response)?;
    let (head, body) = response
        .split_once("\r\n\r\n")
        .ok_or_else(|| std::io::Error::new(std::io::ErrorKind::InvalidData, "no header/body split"))?;
    let status_line = head.lines().next().unwrap_or("");
    if !status_line.contains(" 200 ") {
        return Err(std::io::Error::new(
            std::io::ErrorKind::Other,
            format!("scrape failed: {status_line}"),
        ));
    }
    Ok(body.to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serves_metrics_and_healthz() {
        let registry = Arc::new(Registry::new());
        registry.counter("t_total", "t", &[]).add(7);
        let server = MetricsServer::serve("127.0.0.1:0", registry.clone()).unwrap();
        let addr = server.local_addr();

        let body = scrape(addr, "/metrics").unwrap();
        assert!(body.contains("t_total 7"), "body: {body}");
        crate::obs::expo::validate(&body).unwrap();

        assert_eq!(scrape(addr, "/healthz").unwrap(), "ok\n");
        assert!(scrape(addr, "/nope").is_err(), "404 surfaces as Err");

        // /trace always serves valid Chrome-trace JSON (possibly with
        // zero task events when tracing is off)
        let trace_body = scrape(addr, "/trace").unwrap();
        let parsed = crate::obs::trace::parse_json(&trace_body).unwrap();
        assert!(parsed.get("traceEvents").is_some());

        // live updates are visible on the next scrape
        registry.counter("t_total", "t", &[]).inc();
        let body = scrape(addr, "/metrics").unwrap();
        assert!(body.contains("t_total 8"), "body: {body}");
        drop(server); // shuts down cleanly without hanging the test
    }
}
