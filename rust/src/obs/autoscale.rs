//! SLO-driven pool autoscaler and priority load shedding.
//!
//! Closes the observability loop: the same per-tenant series the
//! metrics endpoint exports (queue depth, queue-wait histograms) feed a
//! small control loop that retargets each shard's serving capacity
//! through [`Router::scale_tenant`] — the autoscaler's only write path,
//! so everything it does is also reachable by an external operator
//! reading `/metrics` and calling the same API.
//!
//! The loop is deliberately simple and deterministic:
//!
//! * **Signal.** Each [`Autoscaler::tick`] reads [`Router::health`] and
//!   computes the *interval* p99 queue wait per tenant by deltaing the
//!   cumulative [`HistogramSnapshot`] against the previous tick's.
//! * **Pressure.** A tenant is *pressured* when its queue depth crosses
//!   `queue_high_fraction` of capacity or the interval p99 exceeds
//!   [`SloPolicy::p99_queue_wait_slo_s`]; it is *idle* when depth is at
//!   or below `queue_low_fraction` of capacity and p99 is within SLO.
//! * **Actuation.** Pressured tenants gain one pool session (up to
//!   `max_sessions`), a doubled queue bound (up to `max_queue`), and
//!   shedding turns on: [`Priority::Low`] requests are rejected once
//!   the queue passes `shed_fraction` of its bound, keeping headroom
//!   for high-priority traffic. Idle tenants give back one session and
//!   half the queue; anything in between keeps its sessions but has
//!   shedding turned off.
//!
//! Shedding is admission-only (see [`Priority`]): it changes which
//! requests get in, never how admitted requests execute, so results for
//! admitted work stay bit-identical to an unscaled run.
//!
//! [`Priority`]: crate::serve::Priority
//! [`Priority::Low`]: crate::serve::Priority::Low

use super::{Counter, Gauge, HistogramSnapshot};
use crate::serve::{Router, TenantId};
use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Targets and bounds for the control loop. The defaults suit the
/// serve-bench's in-process latencies; a real deployment would widen
/// the SLO.
#[derive(Clone, Copy, Debug)]
pub struct SloPolicy {
    /// Interval p99 queue-wait target in seconds; above it a tenant is
    /// pressured even with a shallow queue.
    pub p99_queue_wait_slo_s: f64,
    /// Fraction of queue capacity at which depth alone signals
    /// pressure.
    pub queue_high_fraction: f64,
    /// Fraction of queue capacity at or below which (SLO permitting) a
    /// tenant is idle and may shrink.
    pub queue_low_fraction: f64,
    /// Session-pool bounds the controller never leaves.
    pub min_sessions: usize,
    pub max_sessions: usize,
    /// Queue-bound limits for grow (double) / shrink (halve) steps.
    pub min_queue: usize,
    pub max_queue: usize,
    /// While shedding, [`Priority::Low`] admission stops at this
    /// fraction of the queue bound.
    ///
    /// [`Priority::Low`]: crate::serve::Priority::Low
    pub shed_fraction: f64,
}

impl Default for SloPolicy {
    fn default() -> Self {
        SloPolicy {
            p99_queue_wait_slo_s: 0.05,
            queue_high_fraction: 0.5,
            queue_low_fraction: 0.05,
            min_sessions: 1,
            max_sessions: 8,
            min_queue: 64,
            max_queue: 256,
            shed_fraction: 0.5,
        }
    }
}

/// What one tick decided for one tenant (returned for logging/tests;
/// the actuation already happened).
#[derive(Clone, Copy, Debug)]
pub struct ScaleDecision {
    pub tenant: TenantId,
    /// Queue depth observed this tick.
    pub queue_depth: usize,
    /// Interval p99 queue wait in seconds (0.0 when nothing completed
    /// since the last tick).
    pub p99_queue_wait_s: f64,
    pub sessions_from: usize,
    pub sessions_to: usize,
    pub queue_from: usize,
    pub queue_to: usize,
    /// Whether low-priority shedding is on after this tick.
    pub shedding: bool,
}

/// The control loop. Drive it synchronously with [`Autoscaler::tick`]
/// (deterministic, used by the tests) or hand it a thread with
/// [`Autoscaler::spawn`].
pub struct Autoscaler {
    router: Arc<Router>,
    policy: SloPolicy,
    /// Previous tick's cumulative queue-wait snapshot per tenant key,
    /// for interval quantiles.
    prev: Mutex<HashMap<u64, HistogramSnapshot>>,
    ticks: Counter,
    resizes_up: Counter,
    resizes_down: Counter,
    shedding_tenants: Gauge,
}

impl Autoscaler {
    /// Build a controller over `router`, publishing its own activity
    /// (`sparselu_autoscale_*`) to the router's registry.
    pub fn new(router: Arc<Router>, policy: SloPolicy) -> Autoscaler {
        assert!(policy.min_sessions >= 1, "a shard needs at least one session");
        assert!(policy.min_sessions <= policy.max_sessions, "session bounds inverted");
        assert!(policy.min_queue >= 1 && policy.min_queue <= policy.max_queue, "queue bounds");
        assert!(policy.shed_fraction > 0.0 && policy.shed_fraction <= 1.0, "shed fraction");
        let r = router.registry();
        let ticks =
            r.counter("sparselu_autoscale_ticks_total", "Autoscaler control-loop ticks.", &[]);
        let resizes = |direction: &str| {
            r.counter(
                "sparselu_autoscale_resizes_total",
                "Session-pool resizes applied by the autoscaler.",
                &[("direction", direction)],
            )
        };
        let shedding_tenants = r.gauge(
            "sparselu_autoscale_shedding_tenants",
            "Tenants currently under low-priority load shedding.",
            &[],
        );
        Autoscaler {
            router,
            policy,
            prev: Mutex::new(HashMap::new()),
            ticks,
            resizes_up: resizes("up"),
            resizes_down: resizes("down"),
            shedding_tenants,
        }
    }

    pub fn policy(&self) -> &SloPolicy {
        &self.policy
    }

    /// One synchronous control-loop iteration: read health, decide, and
    /// actuate via [`Router::scale_tenant`]. Deterministic given the
    /// observed health, so tests can script it.
    pub fn tick(&self) -> Vec<ScaleDecision> {
        self.ticks.inc();
        let health = self.router.health();
        let mut prev = self.prev.lock().unwrap();
        let mut decisions = Vec::with_capacity(health.len());
        let mut shedding_now = 0u64;
        for h in &health {
            let interval = match prev.get(&h.tenant.0) {
                Some(p) => h.queue_wait.delta(p),
                None => h.queue_wait.clone(),
            };
            prev.insert(h.tenant.0, h.queue_wait.clone());
            let p99 = if interval.count() > 0 { interval.quantile(0.99) } else { 0.0 };

            let pol = &self.policy;
            let high = ((h.queue_capacity as f64) * pol.queue_high_fraction).ceil() as usize;
            let low = ((h.queue_capacity as f64) * pol.queue_low_fraction).floor() as usize;
            let pressured = h.queue_depth >= high.max(1) || p99 > pol.p99_queue_wait_slo_s;
            let idle = h.queue_depth <= low && p99 <= pol.p99_queue_wait_slo_s;

            let (sessions_to, queue_to, shedding) = if pressured {
                (
                    (h.sessions_target + 1).min(pol.max_sessions),
                    h.queue_capacity.saturating_mul(2).clamp(pol.min_queue, pol.max_queue),
                    true,
                )
            } else if idle {
                (
                    h.sessions_target.saturating_sub(1).max(pol.min_sessions),
                    (h.queue_capacity / 2).clamp(pol.min_queue, pol.max_queue),
                    false,
                )
            } else {
                // in the comfort band: hold capacity, stop shedding
                (h.sessions_target, h.queue_capacity, false)
            };
            let low_limit = if shedding {
                (((queue_to as f64) * pol.shed_fraction).floor() as usize).max(1)
            } else {
                queue_to
            };

            let was_shedding = h.low_priority_limit < h.queue_capacity;
            let changed = sessions_to != h.sessions_target
                || queue_to != h.queue_capacity
                || shedding != was_shedding;
            // A tenant evicted between health() and here is simply gone;
            // its decision still records what we intended.
            if changed
                && self.router.scale_tenant(h.tenant, sessions_to, queue_to, low_limit).is_ok()
            {
                if sessions_to > h.sessions_target {
                    self.resizes_up.inc();
                } else if sessions_to < h.sessions_target {
                    self.resizes_down.inc();
                }
            }
            if shedding {
                shedding_now += 1;
            }
            decisions.push(ScaleDecision {
                tenant: h.tenant,
                queue_depth: h.queue_depth,
                p99_queue_wait_s: p99,
                sessions_from: h.sessions_target,
                sessions_to,
                queue_from: h.queue_capacity,
                queue_to,
                shedding,
            });
        }
        // forget evicted tenants so a revival starts a fresh interval
        prev.retain(|key, _| health.iter().any(|h| h.tenant.0 == *key));
        self.shedding_tenants.set(shedding_now as f64);
        decisions
    }

    /// Run the loop on a background thread every `interval` until the
    /// returned handle is stopped or dropped.
    pub fn spawn(self: Arc<Self>, interval: Duration) -> AutoscaleHandle {
        let stop = Arc::new(AtomicBool::new(false));
        let thread = {
            let stop = stop.clone();
            std::thread::Builder::new()
                .name("autoscaler".to_string())
                .spawn(move || {
                    while !stop.load(Ordering::Acquire) {
                        std::thread::park_timeout(interval);
                        if stop.load(Ordering::Acquire) {
                            break;
                        }
                        let _ = self.tick();
                    }
                })
                .expect("spawn autoscaler thread")
        };
        AutoscaleHandle { stop, thread: Some(thread) }
    }
}

impl std::fmt::Debug for Autoscaler {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Autoscaler").field("policy", &self.policy).finish_non_exhaustive()
    }
}

/// Joins the background control loop on stop/drop.
#[derive(Debug)]
pub struct AutoscaleHandle {
    stop: Arc<AtomicBool>,
    thread: Option<std::thread::JoinHandle<()>>,
}

impl AutoscaleHandle {
    /// Stop the loop and wait for the thread to exit.
    pub fn stop(mut self) {
        self.shutdown();
    }

    fn shutdown(&mut self) {
        self.stop.store(true, Ordering::Release);
        if let Some(t) = self.thread.take() {
            t.thread().unpark();
            let _ = t.join();
        }
    }
}

impl Drop for AutoscaleHandle {
    fn drop(&mut self) {
        self.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::Registry;
    use crate::serve::{Request, RouterConfig};
    use crate::solver::SolveOptions;
    use crate::sparse::gen;

    fn scaled_router(shard_queue: usize) -> (Arc<Router>, TenantId) {
        let router = Arc::new(Router::new(
            SolveOptions::ours(1),
            RouterConfig {
                max_shards: 2,
                plan_cache_capacity: 4,
                shard_queue,
                registry: Some(Arc::new(Registry::new())),
                ..RouterConfig::default()
            },
        ));
        let tenant = router.admit(&gen::grid2d_laplacian(6, 6)).unwrap();
        (router, tenant)
    }

    #[test]
    fn grows_under_pressure_and_shrinks_idle_within_bounds() {
        let (router, tenant) = scaled_router(4);
        let policy = SloPolicy {
            // depth alone drives this test; a wall-clock p99 would be
            // timing-dependent
            p99_queue_wait_slo_s: 10.0,
            min_sessions: 1,
            max_sessions: 3,
            min_queue: 4,
            max_queue: 16,
            ..SloPolicy::default()
        };
        let scaler = Autoscaler::new(router.clone(), policy);

        // fill the queue: depth 4 of 4 is past the high watermark
        let rhs = vec![1.0; 36];
        for _ in 0..4 {
            router.submit(tenant, Request::Solve { rhs: rhs.clone() }).unwrap();
        }
        let first = scaler.tick();
        assert_eq!(first.len(), 1);
        assert!(first[0].shedding, "pressure turns shedding on");
        assert_eq!(first[0].sessions_from, 1);
        assert_eq!(first[0].sessions_to, 2);
        assert_eq!(first[0].queue_to, 8, "queue doubles under pressure");
        for _ in 0..10 {
            // keep the growing queue full so the pressure persists
            while router.submit(tenant, Request::Solve { rhs: rhs.clone() }).is_ok() {}
            scaler.tick(); // converges, never exceeds the caps
        }
        let h = &router.health()[0];
        assert_eq!(h.sessions_target, 3, "capped at max_sessions");
        assert_eq!(h.queue_capacity, 16, "capped at max_queue");
        assert!(h.low_priority_limit < h.queue_capacity, "still shedding");

        // drain everything; the queue goes quiet and the pool deflates
        router.drain_tenant(tenant).unwrap();
        for _ in 0..10 {
            scaler.tick();
        }
        let h = &router.health()[0];
        assert_eq!(h.sessions_target, 1, "idle deflates to min_sessions");
        assert_eq!(h.queue_capacity, 4, "queue halves back to min_queue");
        assert_eq!(h.low_priority_limit, h.queue_capacity, "shedding off");
        assert!(
            router.registry().counter("sparselu_autoscale_resizes_total", "", &[("direction", "down")]).get()
                >= 2
        );
    }

    #[test]
    fn comfort_band_holds_capacity_but_stops_shedding() {
        let (router, tenant) = scaled_router(16);
        let policy = SloPolicy {
            min_sessions: 1,
            max_sessions: 4,
            min_queue: 16,
            max_queue: 64,
            ..SloPolicy::default()
        };
        let scaler = Autoscaler::new(router.clone(), policy);

        // depth 4 of 16: above the low watermark (0), below high (8)
        let rhs = vec![1.0; 36];
        for _ in 0..4 {
            router.submit(tenant, Request::Solve { rhs: rhs.clone() }).unwrap();
        }
        // force shedding on first, as if pressure had just passed
        router.scale_tenant(tenant, 2, 16, 8).unwrap();
        let decisions = scaler.tick();
        assert!(!decisions[0].shedding);
        assert_eq!(decisions[0].sessions_to, 2, "comfort band holds sessions");
        let h = &router.health()[0];
        assert_eq!(h.low_priority_limit, h.queue_capacity, "shedding turned off");
        assert_eq!(h.sessions_target, 2);
        assert_eq!(h.queue_capacity, 16);
    }

    #[test]
    fn background_loop_spawns_and_stops_cleanly() {
        let (router, _tenant) = scaled_router(8);
        let scaler = Arc::new(Autoscaler::new(router.clone(), SloPolicy::default()));
        let handle = scaler.clone().spawn(Duration::from_millis(1));
        // let it take at least one lap, then shut down deterministically
        while router.registry().counter("sparselu_autoscale_ticks_total", "", &[]).get() == 0 {
            std::thread::yield_now();
            scaler.tick(); // count a synchronous lap too; either unblocks us
        }
        handle.stop();
    }
}
