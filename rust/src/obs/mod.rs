//! Observability: metrics spine, exposition endpoint, and the
//! SLO-driven autoscaler.
//!
//! Dependency-free by design — the whole stack is `std` atomics, a
//! `TcpListener`, and string formatting:
//!
//! * [`metrics`] — atomic [`Counter`]s/[`Gauge`]s and fixed-bucket
//!   latency [`Histogram`]s behind a process-wide [`Registry`]
//!   ([`Registry::global`]), with get-or-create labeled series.
//! * [`expo`] — Prometheus text exposition format 0.0.4: a renderer
//!   ([`Registry::render`]) and a strict [`validate`] parser used by
//!   tests and `repro metrics-dump --check`.
//! * [`http`] — a minimal blocking scrape endpoint
//!   ([`MetricsServer`]: `GET /metrics` + `/healthz`) and the matching
//!   one-call [`scrape`] client.
//! * [`autoscale`] — the control loop that closes the observability
//!   spine back onto the serve layer: per-tenant queue depth and p99
//!   queue wait against an [`SloPolicy`], actuated through
//!   [`Router::scale_tenant`] (pool resize, queue rebound, priority
//!   load shedding).
//! * [`trace`] — task-level tracing of the persistent executor:
//!   per-thread lock-free ring buffers, `trace_id` request correlation,
//!   Chrome-trace/Perfetto export (`GET /trace`, `repro trace`),
//!   measured critical-path analysis and the per-level balance report
//!   behind `repro trace-bench`. Always compiled; the trace-off cost is
//!   one atomic load per DAG run.
//!
//! Metric naming follows Prometheus conventions: `sparselu_` prefix,
//! `_total` counters, `_seconds` histograms, tenants labeled
//! `tenant="<016x pattern key>"`. ARCHITECTURE.md's "Observability"
//! section has the full series table.
//!
//! [`Router::scale_tenant`]: crate::serve::Router::scale_tenant

pub mod autoscale;
pub mod expo;
pub mod http;
pub mod metrics;
pub mod trace;

pub use autoscale::{AutoscaleHandle, Autoscaler, ScaleDecision, SloPolicy};
pub use expo::{validate, ExpoSummary};
pub use http::{scrape, MetricsServer, CONTENT_TYPE};
pub use metrics::{
    Counter, FamilySnapshot, Gauge, Histogram, HistogramSnapshot, MetricKind, Registry,
    SampleValue, SeriesSnapshot, BATCH_BUCKETS, BUILD_BUCKETS, LATENCY_BUCKETS,
};

use crate::coordinator::Executor;
use std::sync::{Arc, Weak};

/// Publish an executor's scheduler counters to `registry` as
/// `sparselu_executor_*` series labeled by pool size.
///
/// The executor's own counters stay plain atomics on its hot paths; a
/// keyed snapshot refresher mirrors them into the registry right before
/// each scrape ([`Counter::mirror`], so stale refreshes never move a
/// series backwards). Holding only a [`Weak`] keeps this registration
/// from pinning the pool alive; re-registering the same pool size
/// (e.g. a later router reviving the shared executor) replaces the
/// refresher instead of stacking duplicates.
pub fn register_executor(registry: &Arc<Registry>, executor: &Arc<Executor>) {
    let workers = executor.workers();
    let label = workers.to_string();
    let labels: &[(&str, &str)] = &[("workers", label.as_str())];
    let runs = registry.counter("sparselu_executor_runs_total", "DAG runs submitted.", labels);
    let steals = registry.counter(
        "sparselu_executor_steals_total",
        "Tasks taken from another worker's deque tail.",
        labels,
    );
    let wakeups = registry.counter(
        "sparselu_executor_wakeups_total",
        "Targeted unpark signals delivered to parked workers.",
        labels,
    );
    let parks = registry.counter(
        "sparselu_executor_parks_total",
        "Times a worker went fully idle.",
        labels,
    );
    let gauge_workers =
        registry.gauge("sparselu_executor_workers", "Worker threads in the pool.", labels);
    let parked = registry.gauge(
        "sparselu_executor_parked",
        "Workers idle right now (parked or about to park).",
        labels,
    );
    gauge_workers.set(workers as f64);
    let weak: Weak<Executor> = Arc::downgrade(executor);
    registry.register_refresher(&format!("executor-{workers}"), move || {
        if let Some(exec) = weak.upgrade() {
            let stats = exec.stats();
            runs.mirror(stats.runs);
            steals.mirror(stats.steals);
            wakeups.mirror(stats.wakeups);
            parks.mirror(stats.parks);
            parked.set(stats.idle_workers as f64);
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn executor_series_mirror_scheduler_stats() {
        let registry = Arc::new(Registry::new());
        let executor = Executor::shared(2);
        register_executor(&registry, &executor);
        // re-registering the same pool replaces, not duplicates
        register_executor(&registry, &executor);

        let text = registry.render();
        expo::validate(&text).unwrap();
        assert!(text.contains("sparselu_executor_workers{workers=\"2\"} 2"), "text: {text}");
        let runs_line_count = text
            .lines()
            .filter(|l| l.starts_with("sparselu_executor_runs_total{"))
            .count();
        assert_eq!(runs_line_count, 1, "one series per pool size");

        // the refresher mirrored live scheduler state at render time;
        // the shared pool may have run more since (tests share it), so
        // only the monotone lower bound is race-free to assert
        let mirrored = registry
            .counter("sparselu_executor_runs_total", "", &[("workers", "2")])
            .get();
        assert!(mirrored <= executor.stats().runs);
    }
}
