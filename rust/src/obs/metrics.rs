//! Dependency-free metrics core: atomic counters, gauges and
//! fixed-bucket histograms behind a process-wide [`Registry`].
//!
//! Handles ([`Counter`], [`Gauge`], [`Histogram`]) are cheap `Arc`
//! clones around atomics — instrumented code holds a handle and updates
//! it lock-free on the hot path; the registry only takes its lock on
//! registration (get-or-create) and at scrape time. All values are
//! updated with `Relaxed` atomics: scrapes observe each series at some
//! point in its monotone history (no torn reads, counters never go
//! backwards), which is exactly the Prometheus contract — cross-series
//! consistency within one scrape is not promised and not needed.

use std::collections::HashSet;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

/// Monotone counter. `f64`-free: rendered as an integer.
#[derive(Clone)]
pub struct Counter {
    cell: Arc<AtomicU64>,
}

impl Counter {
    fn new() -> Self {
        Self { cell: Arc::new(AtomicU64::new(0)) }
    }

    /// Add one.
    pub fn inc(&self) {
        self.add(1);
    }

    /// Add `n`.
    pub fn add(&self, n: u64) {
        self.cell.fetch_add(n, Ordering::Relaxed);
    }

    /// Current value.
    pub fn get(&self) -> u64 {
        self.cell.load(Ordering::Relaxed)
    }

    /// Mirror an externally maintained monotone total (e.g. an
    /// [`crate::coordinator::ExecutorStats`] counter). `fetch_max` keeps
    /// the series monotone even if several mirrors race.
    pub fn mirror(&self, total: u64) {
        self.cell.fetch_max(total, Ordering::Relaxed);
    }
}

/// Instantaneous value; an `f64` stored as bits in an `AtomicU64`.
#[derive(Clone)]
pub struct Gauge {
    bits: Arc<AtomicU64>,
}

impl Gauge {
    fn new() -> Self {
        Self { bits: Arc::new(AtomicU64::new(0f64.to_bits())) }
    }

    /// Set to `v`.
    pub fn set(&self, v: f64) {
        self.bits.store(v.to_bits(), Ordering::Relaxed);
    }

    /// Current value.
    pub fn get(&self) -> f64 {
        f64::from_bits(self.bits.load(Ordering::Relaxed))
    }

    /// Add `d` (CAS loop; gauges are updated rarely).
    pub fn add(&self, d: f64) {
        let mut cur = self.bits.load(Ordering::Relaxed);
        loop {
            let next = (f64::from_bits(cur) + d).to_bits();
            match self.bits.compare_exchange_weak(cur, next, Ordering::Relaxed, Ordering::Relaxed)
            {
                Ok(_) => return,
                Err(seen) => cur = seen,
            }
        }
    }
}

/// Latency buckets (seconds) shared by all timing histograms: 50µs–5s,
/// roughly ×2–×2.5 per step, matching Prometheus client defaults.
pub const LATENCY_BUCKETS: [f64; 16] = [
    5e-5, 1e-4, 2.5e-4, 5e-4, 1e-3, 2.5e-3, 5e-3, 1e-2, 2.5e-2, 5e-2, 0.1, 0.25, 0.5, 1.0, 2.5,
    5.0,
];

/// Batch-size buckets (requests per drained batch).
pub const BATCH_BUCKETS: [f64; 7] = [1.0, 2.0, 4.0, 8.0, 16.0, 32.0, 64.0];

/// Plan-build time buckets (seconds): symbolic + blocking can take a
/// while on big patterns, so the range extends past the latency set.
pub const BUILD_BUCKETS: [f64; 10] = [1e-3, 5e-3, 1e-2, 5e-2, 0.1, 0.5, 1.0, 5.0, 10.0, 60.0];

struct HistogramCore {
    bounds: Vec<f64>,
    /// `bounds.len() + 1` per-bucket counts; the last is the implicit
    /// `+Inf` bucket. `_count` is derived as the sum at snapshot time so
    /// bucket/count consistency holds by construction under concurrency.
    buckets: Vec<AtomicU64>,
    sum_bits: AtomicU64,
}

/// Fixed-bucket histogram with Prometheus `le` (≤) bucket semantics.
#[derive(Clone)]
pub struct Histogram {
    core: Arc<HistogramCore>,
}

impl Histogram {
    fn new(bounds: &[f64]) -> Self {
        assert!(!bounds.is_empty(), "histogram needs at least one finite bucket bound");
        assert!(
            bounds.windows(2).all(|w| w[0] < w[1]) && bounds.iter().all(|b| b.is_finite()),
            "histogram bounds must be finite and strictly ascending"
        );
        Self {
            core: Arc::new(HistogramCore {
                bounds: bounds.to_vec(),
                buckets: (0..=bounds.len()).map(|_| AtomicU64::new(0)).collect(),
                sum_bits: AtomicU64::new(0f64.to_bits()),
            }),
        }
    }

    /// Record one observation.
    pub fn observe(&self, v: f64) {
        // first bucket whose upper bound is >= v (le semantics); past
        // the last finite bound lands in the +Inf bucket.
        let i = self.core.bounds.partition_point(|&b| b < v);
        self.core.buckets[i].fetch_add(1, Ordering::Relaxed);
        let mut cur = self.core.sum_bits.load(Ordering::Relaxed);
        loop {
            let next = (f64::from_bits(cur) + v).to_bits();
            match self.core.sum_bits.compare_exchange_weak(
                cur,
                next,
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => return,
                Err(seen) => cur = seen,
            }
        }
    }

    /// Record a duration in seconds.
    pub fn observe_duration(&self, d: std::time::Duration) {
        self.observe(d.as_secs_f64());
    }

    /// Point-in-time copy of bounds, per-bucket counts and sum.
    pub fn snapshot(&self) -> HistogramSnapshot {
        HistogramSnapshot {
            bounds: self.core.bounds.clone(),
            counts: self.core.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect(),
            sum: f64::from_bits(self.core.sum_bits.load(Ordering::Relaxed)),
        }
    }
}

/// Owned copy of a histogram's state, used for rendering and for the
/// autoscaler's between-ticks interval deltas.
#[derive(Clone, Debug)]
pub struct HistogramSnapshot {
    /// Ascending finite upper bounds.
    pub bounds: Vec<f64>,
    /// Per-bucket (non-cumulative) counts, `bounds.len() + 1` entries;
    /// the last is the `+Inf` bucket.
    pub counts: Vec<u64>,
    /// Sum of all observations.
    pub sum: f64,
}

impl HistogramSnapshot {
    /// Total observation count.
    pub fn count(&self) -> u64 {
        self.counts.iter().sum()
    }

    /// Interpolated quantile over this snapshot
    /// (see [`crate::util::stats::histogram_quantile`]).
    pub fn quantile(&self, q: f64) -> f64 {
        crate::util::stats::histogram_quantile(&self.bounds, &self.counts, q)
    }

    /// Observations recorded since `earlier` (same bounds required).
    /// Saturating per bucket, so a racy pair of snapshots can never
    /// produce negative interval counts.
    pub fn delta(&self, earlier: &HistogramSnapshot) -> HistogramSnapshot {
        assert_eq!(self.bounds, earlier.bounds, "snapshots from different histograms");
        HistogramSnapshot {
            bounds: self.bounds.clone(),
            counts: self
                .counts
                .iter()
                .zip(&earlier.counts)
                .map(|(now, was)| now.saturating_sub(*was))
                .collect(),
            sum: (self.sum - earlier.sum).max(0.0),
        }
    }
}

/// Metric family kind, as rendered in `# TYPE`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MetricKind {
    /// Monotone counter (`_total` naming convention).
    Counter,
    /// Instantaneous value.
    Gauge,
    /// Fixed-bucket histogram (`_bucket`/`_sum`/`_count` samples).
    Histogram,
}

impl MetricKind {
    /// Lowercase name used in the `# TYPE` line.
    pub fn as_str(&self) -> &'static str {
        match self {
            MetricKind::Counter => "counter",
            MetricKind::Gauge => "gauge",
            MetricKind::Histogram => "histogram",
        }
    }
}

enum Metric {
    Counter(Counter),
    Gauge(Gauge),
    Histogram(Histogram),
}

struct Series {
    /// Sorted by key at registration, so label order never splits series.
    labels: Vec<(String, String)>,
    metric: Metric,
}

struct Family {
    name: String,
    help: String,
    kind: MetricKind,
    /// Bucket bounds shared by every series of a histogram family.
    bounds: Vec<f64>,
    series: Vec<Series>,
}

/// One sample value in a registry snapshot.
#[derive(Clone, Debug)]
pub enum SampleValue {
    /// Counter total.
    Counter(u64),
    /// Gauge value.
    Gauge(f64),
    /// Histogram state.
    Histogram(HistogramSnapshot),
}

/// One labeled series in a registry snapshot.
#[derive(Clone, Debug)]
pub struct SeriesSnapshot {
    /// Label pairs, sorted by key.
    pub labels: Vec<(String, String)>,
    /// The series' value at snapshot time.
    pub value: SampleValue,
}

/// One metric family in a registry snapshot.
#[derive(Clone, Debug)]
pub struct FamilySnapshot {
    /// Family name (`[a-zA-Z_:][a-zA-Z0-9_:]*`).
    pub name: String,
    /// `# HELP` text.
    pub help: String,
    /// `# TYPE`.
    pub kind: MetricKind,
    /// All series, sorted by labels for deterministic rendering.
    pub series: Vec<SeriesSnapshot>,
}

/// Process-wide metric registry: get-or-create handles by
/// `(name, labels)`, snapshot for rendering, and *refreshers* — named
/// callbacks run before each snapshot to mirror externally maintained
/// counters (e.g. executor steal/park totals) into registry series.
pub struct Registry {
    families: Mutex<Vec<Family>>,
    refreshers: Mutex<Vec<(String, Box<dyn Fn() + Send + Sync>)>>,
}

impl std::fmt::Debug for Registry {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let fams = self.families.lock().unwrap();
        let series: usize = fams.iter().map(|fam| fam.series.len()).sum();
        f.debug_struct("Registry")
            .field("families", &fams.len())
            .field("series", &series)
            .finish()
    }
}

impl Default for Registry {
    fn default() -> Self {
        Self::new()
    }
}

fn valid_metric_name(name: &str) -> bool {
    let mut chars = name.chars();
    match chars.next() {
        Some(c) if c.is_ascii_alphabetic() || c == '_' || c == ':' => {}
        _ => return false,
    }
    chars.all(|c| c.is_ascii_alphanumeric() || c == '_' || c == ':')
}

fn valid_label_name(name: &str) -> bool {
    let mut chars = name.chars();
    match chars.next() {
        Some(c) if c.is_ascii_alphabetic() || c == '_' => {}
        _ => return false,
    }
    chars.all(|c| c.is_ascii_alphanumeric() || c == '_')
}

impl Registry {
    /// Fresh empty registry (tests and scoped benches use their own;
    /// production wiring defaults to [`Registry::global`]).
    pub fn new() -> Self {
        Self { families: Mutex::new(Vec::new()), refreshers: Mutex::new(Vec::new()) }
    }

    /// The process-wide registry.
    pub fn global() -> Arc<Registry> {
        static GLOBAL: OnceLock<Arc<Registry>> = OnceLock::new();
        GLOBAL.get_or_init(|| Arc::new(Registry::new())).clone()
    }

    /// Get or create the counter `name{labels}`. Panics if `name` is
    /// already registered with a different kind — that is a programming
    /// error, not a runtime condition.
    pub fn counter(&self, name: &str, help: &str, labels: &[(&str, &str)]) -> Counter {
        match self.series(name, help, MetricKind::Counter, labels, &[]) {
            Metric::Counter(c) => c,
            _ => unreachable!(),
        }
    }

    /// Get or create the gauge `name{labels}`.
    pub fn gauge(&self, name: &str, help: &str, labels: &[(&str, &str)]) -> Gauge {
        match self.series(name, help, MetricKind::Gauge, labels, &[]) {
            Metric::Gauge(g) => g,
            _ => unreachable!(),
        }
    }

    /// Get or create the histogram `name{labels}` with the family's
    /// bucket `bounds` (every series of one family shares them).
    pub fn histogram(
        &self,
        name: &str,
        help: &str,
        labels: &[(&str, &str)],
        bounds: &[f64],
    ) -> Histogram {
        match self.series(name, help, MetricKind::Histogram, labels, bounds) {
            Metric::Histogram(h) => h,
            _ => unreachable!(),
        }
    }

    fn series(
        &self,
        name: &str,
        help: &str,
        kind: MetricKind,
        labels: &[(&str, &str)],
        bounds: &[f64],
    ) -> Metric {
        assert!(valid_metric_name(name), "invalid metric name {name:?}");
        for (k, _) in labels {
            assert!(valid_label_name(k), "invalid label name {k:?} on {name}");
        }
        let mut labels: Vec<(String, String)> =
            labels.iter().map(|(k, v)| (k.to_string(), v.to_string())).collect();
        labels.sort();
        let mut fams = self.families.lock().unwrap();
        let fam = match fams.iter_mut().find(|f| f.name == name) {
            Some(f) => {
                assert!(
                    f.kind == kind,
                    "metric {name} registered as {:?}, requested as {kind:?}",
                    f.kind
                );
                assert!(
                    kind != MetricKind::Histogram || f.bounds == bounds,
                    "histogram {name} registered with different bucket bounds"
                );
                f
            }
            None => {
                fams.push(Family {
                    name: name.to_string(),
                    help: help.to_string(),
                    kind,
                    bounds: bounds.to_vec(),
                    series: Vec::new(),
                });
                fams.last_mut().unwrap()
            }
        };
        if let Some(s) = fam.series.iter().find(|s| s.labels == labels) {
            return match &s.metric {
                Metric::Counter(c) => Metric::Counter(c.clone()),
                Metric::Gauge(g) => Metric::Gauge(g.clone()),
                Metric::Histogram(h) => Metric::Histogram(h.clone()),
            };
        }
        let metric = match kind {
            MetricKind::Counter => Metric::Counter(Counter::new()),
            MetricKind::Gauge => Metric::Gauge(Gauge::new()),
            MetricKind::Histogram => Metric::Histogram(Histogram::new(bounds)),
        };
        let handle = match &metric {
            Metric::Counter(c) => Metric::Counter(c.clone()),
            Metric::Gauge(g) => Metric::Gauge(g.clone()),
            Metric::Histogram(h) => Metric::Histogram(h.clone()),
        };
        fam.series.push(Series { labels, metric });
        handle
    }

    /// Register (or replace, by `key`) a callback run before every
    /// snapshot/render. Keyed so repeated wiring of the same source
    /// (e.g. one router per test over the global registry) does not
    /// accumulate duplicate callbacks.
    pub fn register_refresher(&self, key: &str, f: impl Fn() + Send + Sync + 'static) {
        let mut rs = self.refreshers.lock().unwrap();
        if let Some(slot) = rs.iter_mut().find(|(k, _)| k == key) {
            slot.1 = Box::new(f);
        } else {
            rs.push((key.to_string(), Box::new(f)));
        }
    }

    /// Run refreshers, then copy out every family sorted by name (and
    /// every series sorted by labels) for deterministic rendering.
    pub fn snapshot(&self) -> Vec<FamilySnapshot> {
        {
            // refreshers run *before* the families lock is taken: they
            // are allowed to call get-or-create on this registry.
            let rs = self.refreshers.lock().unwrap();
            for (_, f) in rs.iter() {
                f();
            }
        }
        let fams = self.families.lock().unwrap();
        let mut out: Vec<FamilySnapshot> = fams
            .iter()
            .map(|f| {
                let mut series: Vec<SeriesSnapshot> = f
                    .series
                    .iter()
                    .map(|s| SeriesSnapshot {
                        labels: s.labels.clone(),
                        value: match &s.metric {
                            Metric::Counter(c) => SampleValue::Counter(c.get()),
                            Metric::Gauge(g) => SampleValue::Gauge(g.get()),
                            Metric::Histogram(h) => SampleValue::Histogram(h.snapshot()),
                        },
                    })
                    .collect();
                series.sort_by(|a, b| a.labels.cmp(&b.labels));
                FamilySnapshot {
                    name: f.name.clone(),
                    help: f.help.clone(),
                    kind: f.kind,
                    series,
                }
            })
            .collect();
        out.sort_by(|a, b| a.name.cmp(&b.name));
        out
    }

    /// Render the full registry in Prometheus text exposition format
    /// 0.0.4 (see [`crate::obs::expo::render`]).
    pub fn render(&self) -> String {
        crate::obs::expo::render(&self.snapshot())
    }

    /// Number of distinct `(name, labels)` series currently registered.
    pub fn series_count(&self) -> usize {
        let fams = self.families.lock().unwrap();
        fams.iter().map(|f| f.series.len()).sum()
    }

    /// Distinct label values seen for `label` across all families —
    /// used by tests to e.g. enumerate tenants.
    pub fn label_values(&self, label: &str) -> Vec<String> {
        let fams = self.families.lock().unwrap();
        let mut seen = HashSet::new();
        for f in fams.iter() {
            for s in &f.series {
                if let Some((_, v)) = s.labels.iter().find(|(k, _)| k == label) {
                    seen.insert(v.clone());
                }
            }
        }
        let mut out: Vec<String> = seen.into_iter().collect();
        out.sort();
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_and_gauge_roundtrip() {
        let r = Registry::new();
        let c = r.counter("t_ops_total", "ops", &[("k", "v")]);
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);
        // get-or-create returns the same underlying cell
        let c2 = r.counter("t_ops_total", "ops", &[("k", "v")]);
        c2.inc();
        assert_eq!(c.get(), 6);
        // a different label set is a different series
        let c3 = r.counter("t_ops_total", "ops", &[("k", "w")]);
        assert_eq!(c3.get(), 0);
        assert_eq!(r.series_count(), 2);

        let g = r.gauge("t_depth", "depth", &[]);
        g.set(3.5);
        g.add(-1.0);
        assert!((g.get() - 2.5).abs() < 1e-12);
    }

    #[test]
    fn label_order_does_not_split_series() {
        let r = Registry::new();
        let a = r.counter("t_total", "t", &[("a", "1"), ("b", "2")]);
        let b = r.counter("t_total", "t", &[("b", "2"), ("a", "1")]);
        a.inc();
        assert_eq!(b.get(), 1);
        assert_eq!(r.series_count(), 1);
    }

    #[test]
    #[should_panic(expected = "registered as")]
    fn kind_mismatch_panics() {
        let r = Registry::new();
        r.counter("t_x", "x", &[]);
        r.gauge("t_x", "x", &[]);
    }

    #[test]
    fn histogram_bucket_edges() {
        let r = Registry::new();
        let h = r.histogram("t_lat_seconds", "lat", &[], &[1.0, 2.0, 4.0]);
        h.observe(0.5); // (0,1]
        h.observe(1.0); // le="1" includes the bound itself
        h.observe(1.5); // (1,2]
        h.observe(4.0); // le="4"
        h.observe(9.0); // +Inf
        let s = h.snapshot();
        assert_eq!(s.counts, vec![2, 1, 1, 1]);
        assert_eq!(s.count(), 5);
        assert!((s.sum - 16.0).abs() < 1e-12);
    }

    #[test]
    fn snapshot_delta_is_interval() {
        let r = Registry::new();
        let h = r.histogram("t_lat_seconds", "lat", &[], &[1.0, 2.0]);
        h.observe(0.5);
        let early = h.snapshot();
        h.observe(0.5);
        h.observe(1.5);
        let d = h.snapshot().delta(&early);
        assert_eq!(d.counts, vec![1, 1, 0]);
        assert_eq!(d.count(), 2);
        assert!((d.sum - 2.0).abs() < 1e-12);
    }

    #[test]
    fn counter_mirror_is_monotone() {
        let r = Registry::new();
        let c = r.counter("t_total", "t", &[]);
        c.mirror(10);
        c.mirror(7); // stale mirror cannot move the series backwards
        assert_eq!(c.get(), 10);
        c.mirror(12);
        assert_eq!(c.get(), 12);
    }

    #[test]
    fn refresher_runs_at_snapshot_and_replaces_by_key() {
        let r = Arc::new(Registry::new());
        let c = r.counter("t_total", "t", &[]);
        let src = Arc::new(AtomicU64::new(3));
        {
            let (c, src) = (c.clone(), src.clone());
            r.register_refresher("mirror", move || c.mirror(src.load(Ordering::Relaxed)));
        }
        r.snapshot();
        assert_eq!(c.get(), 3);
        src.store(8, Ordering::Relaxed);
        // re-registering under the same key replaces, not appends
        {
            let (c, src) = (c.clone(), src.clone());
            r.register_refresher("mirror", move || c.mirror(src.load(Ordering::Relaxed)));
        }
        r.snapshot();
        assert_eq!(c.get(), 8);
    }
}
