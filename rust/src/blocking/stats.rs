//! Balance audits over a blocked matrix (the paper's Fig 5 / §3.2
//! motivation and the §4.1 claim: irregular blocking "adequately balances
//! the nonzeros of blocks both within the same level and across levels in
//! the dependency tree").
//!
//! The dependency level of block (i, j) in right-looking blocked LU is
//! `min(i, j)`: the block becomes computable at elimination step
//! `min(i, j)` (Fig 5(b) groups blocks exactly this way).

use super::partition::BlockedMatrix;
use crate::util::Summary;

/// Balance report for a blocked matrix.
#[derive(Clone, Debug)]
pub struct BalanceReport {
    /// nnz of every non-empty block.
    pub per_block_nnz: Vec<f64>,
    /// Total nnz per dependency level (level = min(bi, bj)).
    pub per_level_nnz: Vec<f64>,
    /// Within-level coefficient of variation, averaged over levels with
    /// ≥ 2 blocks (weighted by block count).
    pub within_level_cv: f64,
    /// Summary over blocks.
    pub block_summary: Summary,
    /// Summary over levels.
    pub level_summary: Summary,
}

impl BalanceReport {
    pub fn of(bm: &BlockedMatrix) -> Self {
        let nb = bm.nb();
        let per_block_nnz: Vec<f64> = bm.blocks.iter().map(|b| b.nnz() as f64).collect();
        let mut level_sets: Vec<Vec<f64>> = vec![Vec::new(); nb];
        for b in &bm.blocks {
            let level = b.bi.min(b.bj) as usize;
            level_sets[level].push(b.nnz() as f64);
        }
        let per_level_nnz: Vec<f64> = level_sets
            .iter()
            .map(|s| s.iter().sum::<f64>())
            .collect();
        let mut weighted_cv = 0.0;
        let mut weight = 0.0;
        for s in &level_sets {
            if s.len() >= 2 {
                let cv = Summary::of(s).cv();
                weighted_cv += cv * s.len() as f64;
                weight += s.len() as f64;
            }
        }
        let within_level_cv = if weight > 0.0 { weighted_cv / weight } else { 0.0 };
        Self {
            block_summary: Summary::of(&per_block_nnz),
            level_summary: Summary::of(&per_level_nnz),
            per_block_nnz,
            per_level_nnz,
            within_level_cv,
        }
    }

    /// The Fig 5 pathology metric: share of all nonzeros sitting in the
    /// *last* dependency level (the bottom-right corner block region).
    pub fn last_level_share(&self) -> f64 {
        let total: f64 = self.per_level_nnz.iter().sum();
        if total == 0.0 {
            return 0.0;
        }
        self.per_level_nnz.last().copied().unwrap_or(0.0) / total
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blocking::{irregular_blocking, regular_blocking, BlockedMatrix, DiagFeature, IrregularParams};
    use crate::sparse::gen;
    use crate::symbolic;

    fn ldu_of(a: &crate::sparse::Csc) -> crate::sparse::Csc {
        symbolic::analyze(a).ldu_pattern(a).unwrap()
    }

    #[test]
    fn report_totals_match_matrix() {
        let a = gen::grid2d_laplacian(12, 12);
        let ldu = ldu_of(&a);
        let bm = BlockedMatrix::build(&ldu, regular_blocking(144, 24));
        let r = BalanceReport::of(&bm);
        let total: f64 = r.per_block_nnz.iter().sum();
        assert_eq!(total as usize, ldu.nnz());
        let level_total: f64 = r.per_level_nnz.iter().sum();
        assert_eq!(level_total as usize, ldu.nnz());
    }

    #[test]
    fn regular_blocking_on_bbd_is_imbalanced() {
        // §3.2: regular blocking on a BBD matrix piles nonzeros into the
        // last level; irregular blocking reduces both block-level CV and
        // last-level share.
        let a = gen::circuit_bbd(gen::CircuitParams {
            n: 2500,
            border_frac: 0.08,
            border_density: 0.4,
            interior_deg: 2,
            seed: 3,
        });
        let ldu = ldu_of(&a);
        let curve = DiagFeature::from_csc(&ldu).curve();
        let irr = irregular_blocking(&curve, &IrregularParams::default());
        let reg = regular_blocking(2500, 2500 / irr.num_blocks().max(1));
        let r_irr = BalanceReport::of(&BlockedMatrix::build(&ldu, irr));
        let r_reg = BalanceReport::of(&BlockedMatrix::build(&ldu, reg));
        assert!(
            r_irr.block_summary.cv() < r_reg.block_summary.cv(),
            "irregular block cv {} vs regular {}",
            r_irr.block_summary.cv(),
            r_reg.block_summary.cv()
        );
    }

    #[test]
    fn last_level_share_in_unit_range() {
        let a = gen::uniform_random(500, 0.02, 1);
        let ldu = ldu_of(&a);
        let bm = BlockedMatrix::build(&ldu, regular_blocking(500, 100));
        let r = BalanceReport::of(&bm);
        let s = r.last_level_share();
        assert!((0.0..=1.0).contains(&s));
    }
}
