//! The diagonal block-based feature (paper §4.2, Algorithm 2).
//!
//! From a CSC matrix with symmetric pattern, compute `blockptr` where
//! `blockptr[i+1]` = number of nonzeros in the leading `(i+1)×(i+1)`
//! submatrix `[0..=i, 0..=i]`. Normalizing index and value yields the
//! *percentage-of-nonzeros-along-the-diagonal* curve whose global shape
//! (linear vs quadratic) and local jumps/inflections expose the matrix's
//! two-dimensional nonzero distribution (Figs 7–8).

use crate::sparse::Csc;

/// The diagonal block-based pointer of Algorithm 2.
#[derive(Clone, Debug, PartialEq)]
pub struct DiagFeature {
    /// `blockptr[k]` = nnz of leading `k×k` submatrix; length `n+1`.
    pub blockptr: Vec<u64>,
    /// Matrix dimension.
    pub n: usize,
}

impl DiagFeature {
    /// Algorithm 2, verbatim: one pass over the CSC arrays counting, for
    /// each column `i`, the strictly-below-diagonal entries grouped by row
    /// (`num[index] += 1` for `index > i`); by pattern symmetry each such
    /// entry mirrors one above the diagonal in row `index`, so expanding
    /// the leading submatrix from `k` to `k+1` adds `2·num[k] + 1` entries
    /// (the `+1` is the structurally-full diagonal).
    ///
    /// The input must have a symmetric *pattern* (the post-symbolic L+U
    /// pattern always does); values are irrelevant.
    pub fn from_csc(m: &Csc) -> Self {
        let n = m.n_cols();
        assert_eq!(m.n_rows(), n);
        let mut num = vec![0u64; n];
        for i in 0..n {
            for &index in m.col_rows(i) {
                if index > i {
                    num[index] += 1;
                }
            }
        }
        let mut blockptr = vec![0u64; n + 1];
        for i in 0..n {
            let add = 2 * num[i] + 1;
            blockptr[i + 1] = blockptr[i] + add;
        }
        Self { blockptr, n }
    }

    /// Total nonzeros according to the pointer (== nnz for symmetric
    /// pattern with full diagonal).
    pub fn total(&self) -> u64 {
        *self.blockptr.last().unwrap()
    }

    /// Normalize into the percentage curve (x = i/n, y = blockptr[i]/total).
    pub fn curve(&self) -> FeatureCurve {
        let total = self.total().max(1) as f64;
        FeatureCurve {
            pct: self.blockptr.iter().map(|&v| v as f64 / total).collect(),
            n: self.n,
        }
    }
}

/// Normalized percentage-of-nonzeros curve; `pct[k]` = fraction of all
/// nonzeros inside the leading `k×k` submatrix, `pct[n] == 1`.
#[derive(Clone, Debug, PartialEq)]
pub struct FeatureCurve {
    pub pct: Vec<f64>,
    pub n: usize,
}

impl FeatureCurve {
    /// Uniformly sample `points+1` values (including both endpoints) —
    /// the paper samples 1000 points before running Algorithm 3.
    pub fn sample(&self, points: usize) -> Vec<f64> {
        assert!(points >= 1);
        (0..=points)
            .map(|s| {
                let idx = (s as u128 * self.n as u128 / points as u128) as usize;
                self.pct[idx]
            })
            .collect()
    }

    /// Quadratic-shape score: mean of `pct(x) - x` over the curve.
    /// ~0 for linear matrices (uniform along the diagonal, Fig 7a);
    /// strongly negative for right-bottom-heavy/quadratic matrices
    /// (Fig 7b, Fig 11 left).
    pub fn quadratic_score(&self) -> f64 {
        let n = self.n.max(1) as f64;
        let s: f64 = self
            .pct
            .iter()
            .enumerate()
            .map(|(i, &p)| p - i as f64 / n)
            .sum();
        s / (self.n + 1) as f64
    }

    /// Largest single-step jump in the curve — dense rows/columns produce
    /// visible discontinuities (Fig 8b,d).
    pub fn max_jump(&self) -> f64 {
        self.pct
            .windows(2)
            .map(|w| w[1] - w[0])
            .fold(0.0f64, f64::max)
    }

    /// Write the sampled curve as `x,y` CSV rows (figure regeneration).
    pub fn to_csv(&self, points: usize) -> String {
        let ys = self.sample(points);
        let mut out = String::from("x,pct\n");
        for (s, y) in ys.iter().enumerate() {
            out.push_str(&format!("{},{}\n", s as f64 / points as f64, y));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::gen;
    use crate::symbolic;

    /// Brute-force reference: count nnz of leading k×k submatrices.
    fn brute_blockptr(m: &Csc) -> Vec<u64> {
        let n = m.n_cols();
        let mut out = vec![0u64; n + 1];
        for k in 1..=n {
            let mut cnt = 0u64;
            for j in 0..k {
                for (i, _) in m.col(j) {
                    if i < k {
                        cnt += 1;
                    }
                }
            }
            out[k] = cnt;
        }
        out
    }

    #[test]
    fn algorithm2_matches_brute_force_on_tridiagonal() {
        let m = gen::tridiagonal(30);
        let f = DiagFeature::from_csc(&m);
        assert_eq!(f.blockptr, brute_blockptr(&m));
        assert_eq!(f.total(), m.nnz() as u64);
    }

    #[test]
    fn algorithm2_matches_brute_force_on_filled_pattern() {
        let a = gen::directed_graph(50, 3, 7);
        let sym = symbolic::analyze(&a);
        let ldu = sym.ldu_pattern(&a).unwrap();
        let f = DiagFeature::from_csc(&ldu);
        assert_eq!(f.blockptr, brute_blockptr(&ldu));
    }

    #[test]
    fn linear_matrix_has_linear_curve() {
        // Fig 7(a): tridiagonal ⇒ pct grows linearly.
        let m = gen::tridiagonal(1000);
        let c = DiagFeature::from_csc(&m).curve();
        assert!(c.quadratic_score().abs() < 0.01, "score {}", c.quadratic_score());
        // midpoint ≈ 0.5
        assert!((c.pct[500] - 0.5).abs() < 0.01);
    }

    #[test]
    fn uniform_matrix_has_quadratic_curve() {
        // Fig 7(b): uniform 2D distribution ⇒ pct(k) ≈ (k/n)².
        let m = gen::uniform_random(400, 0.05, 3).plus_transpose_pattern();
        let c = DiagFeature::from_csc(&m).curve();
        // midpoint ≈ 0.25, well below linear
        assert!(c.pct[200] < 0.35, "midpoint {}", c.pct[200]);
        assert!(c.quadratic_score() < -0.05, "score {}", c.quadratic_score());
    }

    #[test]
    fn dense_rows_make_jumps() {
        // Fig 8(b,d): dense rows/cols ⇒ jump discontinuities.
        let plain = gen::tridiagonal(500);
        let spiky = gen::dense_rows_cols(500, &[250], 2, 9).plus_transpose_pattern();
        let cj = DiagFeature::from_csc(&spiky).curve().max_jump();
        let pj = DiagFeature::from_csc(&plain).curve().max_jump();
        assert!(cj > 10.0 * pj, "spiky jump {cj} vs plain {pj}");
    }

    #[test]
    fn sampling_includes_endpoints() {
        let m = gen::tridiagonal(997); // non-divisible by sample count
        let c = DiagFeature::from_csc(&m).curve();
        let s = c.sample(100);
        assert_eq!(s.len(), 101);
        assert_eq!(s[0], 0.0);
        assert!((s[100] - 1.0).abs() < 1e-12);
        assert!(s.windows(2).all(|w| w[0] <= w[1]), "monotone");
    }

    #[test]
    fn curve_is_monotone_and_normalized() {
        let m = gen::grid2d_laplacian(20, 20);
        let c = DiagFeature::from_csc(&m).curve();
        assert_eq!(c.pct[0], 0.0);
        assert!((c.pct[400] - 1.0).abs() < 1e-12);
        assert!(c.pct.windows(2).all(|w| w[0] <= w[1]));
    }

    #[test]
    fn csv_output_has_header_and_rows() {
        let m = gen::tridiagonal(50);
        let csv = DiagFeature::from_csc(&m).curve().to_csv(10);
        let lines: Vec<&str> = csv.trim().lines().collect();
        assert_eq!(lines[0], "x,pct");
        assert_eq!(lines.len(), 12);
    }
}
