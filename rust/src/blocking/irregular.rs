//! The structure-aware irregular blocking method (paper §4.3, Algorithm 3).
//!
//! Given the sampled percentage-of-nonzeros curve, walk the sample points
//! and compare the percentage gain over a `step`-wide window against the
//! *linear* gain (`step / sample_points` — the gain a uniformly-distributed
//! matrix would show, §4.3):
//!
//! * gain ≥ threshold ⇒ the window is **dense**: mark a fine-grained
//!   boundary at the window end (paper's `P₁` case);
//! * gain < threshold ⇒ **sparse**: skip, but after `max_num` consecutive
//!   skips force a boundary anyway to bound block size (`Pₘ` case).
//!
//! The paper fixes `step = 2`, `max_num = 3`, `sample_points = 1000`
//! (determined empirically; §4.3). We keep those defaults and additionally
//! clamp emitted positions to be strictly increasing (sampling-grid
//! collisions can otherwise duplicate a position on small matrices).

use super::{feature::FeatureCurve, Blocking};

/// Tunables of Algorithm 3.
#[derive(Clone, Copy, Debug)]
pub struct IrregularParams {
    /// Number of uniform samples of the percentage curve (paper: 1000).
    pub sample_points: usize,
    /// Look-ahead window in samples (paper: 2).
    pub step: usize,
    /// Max consecutive skips before a forced boundary (paper: 3).
    pub max_num: usize,
    /// Density threshold on the percentage difference; `None` uses the
    /// paper's linear difference `step / sample_points`.
    pub threshold: Option<f64>,
    /// Lower bound on emitted block size (in rows). `0` (the default)
    /// auto-scales: the paper's constants assume 10⁵–10⁶-order matrices
    /// where the 1000-point grid is ~700 rows wide; on the scaled-down
    /// reproduction matrices the grid is shrunk so the *ratio* between
    /// irregular and regular (selection-tree) block sizes matches the
    /// paper's observation (§5.2: dense-region blocks a bit finer than
    /// PanguLU's pick, sparse-region blocks 2–4× coarser).
    pub min_block: usize,
}

impl Default for IrregularParams {
    fn default() -> Self {
        Self { sample_points: 1000, step: 2, max_num: 3, threshold: None, min_block: 0 }
    }
}

impl IrregularParams {
    /// Effective threshold (paper: the linear difference).
    pub fn effective_threshold(&self) -> f64 {
        self.threshold
            .unwrap_or(self.step as f64 / self.sample_points as f64)
    }

    /// Resolved minimum block size for an `n×n` matrix.
    pub fn min_block_for(&self, n: usize) -> usize {
        if self.min_block > 0 {
            self.min_block
        } else {
            // auto: grid of ~192 samples ⇒ dense blocks ≈ n/96 ≈ half the
            // PanguLU menu anchor (n/24 middle option), forced sparse
            // blocks ≈ (max_num+1)·step·grid ≈ n/12 ≈ 2–4× the anchor.
            (n / 192).max(8)
        }
    }

    /// Shrink `sample_points` for small matrices so the sampling grid is
    /// not finer than the resolved minimum block size.
    pub fn clamped_for(&self, n: usize) -> Self {
        let min_block = self.min_block_for(n);
        let max_samples = (n / min_block).max(4);
        Self {
            sample_points: self.sample_points.min(max_samples),
            min_block,
            ..*self
        }
    }
}

/// Algorithm 3: produce irregular blocking positions for an `n×n` matrix
/// from its feature curve.
pub fn irregular_blocking(curve: &FeatureCurve, params: &IrregularParams) -> Blocking {
    let n = curve.n;
    let p = params.clamped_for(n);
    let sp = p.sample_points;
    let pct = curve.sample(sp); // pct[0..=sp]
    let threshold = p.effective_threshold();

    let mut positions: Vec<usize> = vec![0];
    let mut l = 0usize; // skip counter
    let mut i = 0usize;
    while i + p.step <= sp {
        let diff = pct[i + p.step] - pct[i];
        let here = ((i + p.step) as u128 * n as u128 / sp as u128) as usize;
        if diff >= threshold {
            // dense region ⇒ fine-grained boundary (P₁)
            push_position(&mut positions, here, n, p.min_block);
            l = 0;
            i += p.step;
        } else if l >= p.max_num {
            // too many skips ⇒ forced boundary (Pₘ) to avoid huge blocks
            push_position(&mut positions, here, n, p.min_block);
            l = 0;
            i += p.step;
        } else {
            l += 1;
            i += p.step;
        }
    }
    if *positions.last().unwrap() != n {
        // merge a too-small tail into the previous block
        if n - positions.last().unwrap() < p.min_block && positions.len() > 1 {
            *positions.last_mut().unwrap() = n;
        } else {
            positions.push(n);
        }
    }
    Blocking::new(n, positions)
}

fn push_position(positions: &mut Vec<usize>, pos: usize, n: usize, min_block: usize) {
    let last = *positions.last().unwrap();
    if pos > last && pos < n && pos - last >= min_block {
        positions.push(pos);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blocking::feature::DiagFeature;
    use crate::sparse::gen;
    use crate::symbolic;
    use crate::util::Summary;

    fn curve_of(a: &crate::sparse::Csc) -> FeatureCurve {
        let sym = symbolic::analyze(a);
        let ldu = sym.ldu_pattern(a).unwrap();
        DiagFeature::from_csc(&ldu).curve()
    }

    #[test]
    fn linear_matrix_gets_near_uniform_blocks() {
        // Tridiagonal: perfectly linear curve ⇒ every window's diff equals
        // the threshold ⇒ all dense-path boundaries at uniform spacing.
        let a = gen::tridiagonal(4000);
        let b = irregular_blocking(&curve_of(&a), &IrregularParams::default());
        let sizes: Vec<f64> = b.sizes().iter().map(|&s| s as f64).collect();
        let s = Summary::of(&sizes);
        assert!(s.cv() < 0.5, "cv {} sizes {:?}", s.cv(), &b.sizes()[..8.min(b.num_blocks())]);
    }

    #[test]
    fn bbd_matrix_gets_fine_blocks_in_dense_region() {
        // ASIC-like: dense border at the bottom-right ⇒ fine blocks there,
        // coarse blocks in the sparse interior.
        let a = gen::circuit_bbd(gen::CircuitParams {
            n: 3000,
            border_frac: 0.1,
            border_density: 0.4,
            interior_deg: 2,
            seed: 1,
        });
        let b = irregular_blocking(&curve_of(&a), &IrregularParams::default());
        assert!(b.num_blocks() >= 3, "got {} blocks", b.num_blocks());
        // average block size in the last 10% (dense border) vs the rest
        let border_start = 2700;
        let mut dense_sizes = Vec::new();
        let mut sparse_sizes = Vec::new();
        for k in 0..b.num_blocks() {
            let mid = (b.positions()[k] + b.positions()[k + 1]) / 2;
            if mid >= border_start {
                dense_sizes.push(b.block_size(k) as f64);
            } else {
                sparse_sizes.push(b.block_size(k) as f64);
            }
        }
        if !dense_sizes.is_empty() && !sparse_sizes.is_empty() {
            let d = Summary::of(&dense_sizes).mean;
            let s = Summary::of(&sparse_sizes).mean;
            assert!(d < s, "dense mean {d} should be finer than sparse mean {s}");
        }
    }

    #[test]
    fn balances_nnz_across_diagonal_blocks_vs_regular() {
        // The headline property: irregular blocking lowers the imbalance of
        // per-block-column nnz versus regular blocking on a BBD matrix.
        let a = gen::circuit_bbd(gen::CircuitParams {
            n: 3000,
            border_frac: 0.08,
            border_density: 0.4,
            interior_deg: 2,
            seed: 2,
        });
        let sym = symbolic::analyze(&a);
        let ldu = sym.ldu_pattern(&a).unwrap();
        let curve = DiagFeature::from_csc(&ldu).curve();
        let irr = irregular_blocking(&curve, &IrregularParams::default());
        let reg = crate::blocking::regular_blocking(3000, 3000 / irr.num_blocks().max(1));

        let nnz_per_diag_block = |b: &Blocking| -> Vec<f64> {
            (0..b.num_blocks())
                .map(|k| {
                    let (lo, hi) = (b.positions()[k], b.positions()[k + 1]);
                    let mut cnt = 0usize;
                    for j in lo..hi {
                        for &i in ldu.col_rows(j) {
                            if i >= lo && i < hi {
                                cnt += 1;
                            }
                        }
                    }
                    cnt as f64
                })
                .collect()
        };
        let irr_imb = Summary::of(&nnz_per_diag_block(&irr)).cv();
        let reg_imb = Summary::of(&nnz_per_diag_block(&reg)).cv();
        assert!(
            irr_imb < reg_imb,
            "irregular cv {irr_imb} should beat regular cv {reg_imb}"
        );
    }

    #[test]
    fn positions_strictly_increasing_and_cover() {
        for seed in 0..5 {
            let a = gen::directed_graph(1500, 3, seed);
            let b = irregular_blocking(&curve_of(&a), &IrregularParams::default());
            let p = b.positions();
            assert_eq!(p[0], 0);
            assert_eq!(*p.last().unwrap(), 1500);
            assert!(p.windows(2).all(|w| w[0] < w[1]));
        }
    }

    #[test]
    fn min_block_respected() {
        let a = gen::uniform_random(600, 0.05, 3);
        let params = IrregularParams { min_block: 32, ..Default::default() };
        let b = irregular_blocking(&curve_of(&a), &params);
        assert!(b.sizes().iter().all(|&s| s >= 32), "{:?}", b.sizes());
    }

    #[test]
    fn forced_boundary_bounds_block_size() {
        // On an ultra-sparse linear matrix the skip counter must still
        // force boundaries: no block should exceed
        // (max_num + 1) * step * (n / sample_points) by much.
        let a = gen::tridiagonal(8000);
        let p = IrregularParams::default().clamped_for(8000);
        let b = irregular_blocking(&curve_of(&a), &IrregularParams::default());
        let grid = 8000 / p.sample_points;
        let cap = (p.max_num + 2) * p.step * grid + p.min_block;
        assert!(
            b.sizes().iter().all(|&s| s <= cap),
            "max size {} cap {cap}",
            b.sizes().iter().max().unwrap()
        );
    }

    #[test]
    fn tiny_matrix_does_not_panic() {
        let a = gen::tridiagonal(16);
        let b = irregular_blocking(&curve_of(&a), &IrregularParams::default());
        assert_eq!(*b.positions().last().unwrap(), 16);
    }
}
