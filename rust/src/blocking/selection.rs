//! PanguLU's block-size selection tree.
//!
//! PanguLU picks a regular block size from a small option set
//! ({200, 300, 500, 1000, 2000, 5000} in the paper, §5.2) by walking a
//! decision tree over the matrix order and the number of nonzeros after
//! symbolic factorization. The paper's Fig 4 shows this frequently picks a
//! suboptimal size — which is exactly what our reproduction of Fig 4/10/12
//! demonstrates. The thresholds below follow PanguLU's published heuristic
//! shape (order-dominated, density-adjusted).

/// The block-size options of the paper (§5.2).
pub const PANGU_SIZES: &[usize] = &[200, 300, 500, 1000, 2000, 5000];

/// Select a regular block size from matrix order `n` and post-symbolic
/// nonzero count `nnz_ldu`, PanguLU-style.
///
/// The tree first buckets by matrix order, then nudges one step up when the
/// factor density (nnz per row) is high — larger blocks keep dense rows in
/// fewer kernels — and one step down when extremely sparse.
pub fn select_block_size(n: usize, nnz_ldu: usize) -> usize {
    select_from(n, nnz_ldu, PANGU_SIZES)
}

/// Same tree over an arbitrary (sorted ascending) option set; the
/// reproduction scales the option set down alongside the matrices.
pub fn select_from(n: usize, nnz_ldu: usize, options: &[usize]) -> usize {
    assert!(!options.is_empty());
    let nnz_per_row = nnz_ldu as f64 / n.max(1) as f64;
    // order bucket: index grows with matrix order
    let mut idx = match n {
        0..=50_000 => 0,
        50_001..=200_000 => 1,
        200_001..=500_000 => 2,
        500_001..=1_000_000 => 3,
        1_000_001..=2_000_000 => 4,
        _ => 5,
    };
    // density adjustment
    if nnz_per_row > 200.0 {
        idx += 1;
    } else if nnz_per_row < 10.0 && idx > 0 {
        idx -= 1;
    }
    options[idx.min(options.len() - 1)]
}

/// Scaled option set for matrices of order `n`: keeps the same 6-way menu
/// shape as PanguLU but proportional to the (smaller) reproduction sizes.
/// For paper-scale n (≥ 3·10⁵) this returns [`PANGU_SIZES`] itself.
pub fn scaled_options(n: usize) -> Vec<usize> {
    if n >= 300_000 {
        return PANGU_SIZES.to_vec();
    }
    // keep the ratios of the paper's menu: 200:300:500:1000:2000:5000,
    // anchored so the middle option ~ n/24 (PanguLU's 500–1000 for ~10⁵–10⁶)
    let anchor = (n / 24).max(8);
    let ratios = [0.4, 0.6, 1.0, 2.0, 4.0, 10.0];
    ratios
        .iter()
        .map(|r| ((anchor as f64 * r) as usize).max(4))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_scale_orders_pick_paper_sizes() {
        // language: n = 3.99e5, nnz(L+U) = 3.88e8
        let s = select_block_size(399_000, 388_000_000);
        assert!(PANGU_SIZES.contains(&s));
        assert!(s >= 500, "large dense factor should use bigger blocks, got {s}");
        // ecology1: n = 1e6, nnz(L+U) = 7.2e7 (very sparse: 72/row)
        let s2 = select_block_size(1_000_000, 72_000_000);
        assert!(PANGU_SIZES.contains(&s2));
    }

    #[test]
    fn small_orders_pick_small_sizes() {
        let s = select_block_size(10_000, 200_000);
        assert!(s <= 300, "got {s}");
    }

    #[test]
    fn density_bumps_selection_up() {
        let sparse = select_block_size(100_000, 500_000);
        let dense = select_block_size(100_000, 100_000_000);
        assert!(dense >= sparse);
    }

    #[test]
    fn scaled_options_preserve_menu_shape() {
        let o = scaled_options(12_000);
        assert_eq!(o.len(), 6);
        assert!(o.windows(2).all(|w| w[0] < w[1]), "{o:?}");
        assert!(o[0] >= 4);
        let p = scaled_options(500_000);
        assert_eq!(p, PANGU_SIZES);
    }

    #[test]
    fn select_from_never_out_of_bounds() {
        let o = [8usize, 16, 32];
        for n in [10, 1_000, 100_000, 3_000_000] {
            for nnz in [n, n * 100, n * 1000] {
                let s = select_from(n, nnz, &o);
                assert!(o.contains(&s));
            }
        }
    }
}
