//! Blocking — the paper's contribution.
//!
//! * [`feature`] — the **diagonal block-based feature** (Algorithm 2):
//!   a pointer array whose entry `i+1` is the number of nonzeros in the
//!   leading `(i+1)×(i+1)` submatrix, normalized into a percentage curve.
//! * [`irregular`] — the **structure-aware irregular blocking method**
//!   (Algorithm 3): fine-grained boundaries in dense regions, coarse in
//!   sparse regions, driven by the feature curve.
//! * [`regular`] — regular fixed-size 2D blocking (the PanguLU baseline).
//! * [`selection`] — PanguLU's selection tree picking a regular block size
//!   from matrix order and post-symbolic nnz.
//! * [`partition`] — materializes a blocking into a [`partition::BlockedMatrix`]:
//!   per-block local CSC patterns + values over the filled L+U pattern.
//! * [`stats`] — per-block / per-level nonzero balance audits (Fig 5).
//!
//! Everything here depends **only on the sparsity pattern** (the filled
//! L+U pattern from [`crate::symbolic`]), never on values — which is
//! what lets [`crate::session::FactorPlan`] freeze a blocking once per
//! pattern and re-use it across millions of numeric re-factorizations.
//! See `ARCHITECTURE.md` at the repository root for where blocking sits
//! in the pipeline.

pub mod feature;
pub mod irregular;
pub mod partition;
pub mod regular;
pub mod selection;
pub mod stats;

pub use feature::{DiagFeature, FeatureCurve};
pub use irregular::{irregular_blocking, IrregularParams};
pub use partition::{Block, BlockedMatrix};
pub use regular::regular_blocking;
pub use selection::select_block_size;
pub use stats::BalanceReport;

/// A blocking of an `n×n` matrix: strictly increasing boundary positions
/// `P_0 = 0 < P_1 < … < P_p = n` (the paper's `ptr` array).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Blocking {
    n: usize,
    positions: Vec<usize>,
}

impl Blocking {
    /// Build from boundary positions; validates monotonicity and coverage.
    pub fn new(n: usize, positions: Vec<usize>) -> Self {
        assert!(!positions.is_empty(), "empty blocking");
        assert_eq!(positions[0], 0, "blocking must start at 0");
        assert_eq!(*positions.last().unwrap(), n, "blocking must end at n");
        assert!(
            positions.windows(2).all(|w| w[0] < w[1]),
            "blocking positions must be strictly increasing"
        );
        Self { n, positions }
    }

    /// Matrix dimension.
    pub fn n(&self) -> usize {
        self.n
    }

    /// Number of block rows/columns.
    pub fn num_blocks(&self) -> usize {
        self.positions.len() - 1
    }

    /// Boundary positions `P_0..=P_p`.
    pub fn positions(&self) -> &[usize] {
        &self.positions
    }

    /// Size of block `k`.
    pub fn block_size(&self, k: usize) -> usize {
        self.positions[k + 1] - self.positions[k]
    }

    /// All block sizes.
    pub fn sizes(&self) -> Vec<usize> {
        (0..self.num_blocks()).map(|k| self.block_size(k)).collect()
    }

    /// Block index containing row/col `i` (binary search).
    pub fn block_of(&self, i: usize) -> usize {
        debug_assert!(i < self.n);
        match self.positions.binary_search(&i) {
            Ok(k) if k == self.positions.len() - 1 => k - 1,
            Ok(k) => k,
            Err(k) => k - 1,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn block_of_finds_containing_block() {
        let b = Blocking::new(10, vec![0, 3, 7, 10]);
        assert_eq!(b.num_blocks(), 3);
        assert_eq!(b.block_of(0), 0);
        assert_eq!(b.block_of(2), 0);
        assert_eq!(b.block_of(3), 1);
        assert_eq!(b.block_of(6), 1);
        assert_eq!(b.block_of(7), 2);
        assert_eq!(b.block_of(9), 2);
        assert_eq!(b.sizes(), vec![3, 4, 3]);
    }

    #[test]
    #[should_panic]
    fn rejects_nonmonotonic() {
        Blocking::new(10, vec![0, 5, 5, 10]);
    }

    #[test]
    #[should_panic]
    fn rejects_wrong_end() {
        Blocking::new(10, vec![0, 5]);
    }
}
