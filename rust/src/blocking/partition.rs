//! Materialize a [`Blocking`] over the filled L+U pattern into per-block
//! local CSC storage — the data structure the numeric factorization
//! engine operates on (PanguLU's "blocked sparse storage").

use super::Blocking;
use crate::coordinator::{par_chunks, Executor};
use crate::numeric::factor::FactorError;
use crate::sparse::Csc;
use std::collections::HashMap;

/// One non-empty block: a local-indexed CSC sub-matrix.
#[derive(Clone, Debug)]
pub struct Block {
    /// Block row / block column coordinates.
    pub bi: u32,
    pub bj: u32,
    /// Local dimensions.
    pub n_rows: u32,
    pub n_cols: u32,
    /// Local CSC pattern (u32 indices: blocks are ≤ a few thousand wide).
    pub col_ptr: Vec<u32>,
    pub row_idx: Vec<u32>,
    /// Values in pattern order. Fill positions start at 0.
    pub values: Vec<f64>,
    /// For **diagonal** blocks: per-column offset (within the column
    /// slice) of the diagonal entry — precomputed so the factor kernels
    /// skip a binary search per AXPY (perf opt-2). Empty for off-diagonal
    /// blocks.
    pub diag_pos: Vec<u32>,
}

impl Block {
    pub fn nnz(&self) -> usize {
        self.row_idx.len()
    }

    pub fn density(&self) -> f64 {
        let cells = self.n_rows as f64 * self.n_cols as f64;
        if cells == 0.0 { 0.0 } else { self.nnz() as f64 / cells }
    }

    /// Local row indices of local column `c`.
    pub fn col_rows(&self, c: usize) -> &[u32] {
        &self.row_idx[self.col_ptr[c] as usize..self.col_ptr[c + 1] as usize]
    }

    /// Values of local column `c`.
    pub fn col_values(&self, c: usize) -> &[f64] {
        &self.values[self.col_ptr[c] as usize..self.col_ptr[c + 1] as usize]
    }

    /// Value at local (r, c); 0.0 if not stored.
    pub fn get(&self, r: usize, c: usize) -> f64 {
        match self.col_rows(c).binary_search(&(r as u32)) {
            Ok(k) => self.values[self.col_ptr[c] as usize + k],
            Err(_) => 0.0,
        }
    }

    /// Densify into a column-major `n_rows × n_cols` buffer.
    pub fn to_dense_col_major(&self) -> Vec<f64> {
        let (nr, nc) = (self.n_rows as usize, self.n_cols as usize);
        let mut d = vec![0.0; nr * nc];
        for c in 0..nc {
            for k in self.col_ptr[c] as usize..self.col_ptr[c + 1] as usize {
                d[c * nr + self.row_idx[k] as usize] = self.values[k];
            }
        }
        d
    }

    /// Scatter a column-major dense buffer back into the stored pattern.
    /// Entries outside the pattern must be (numerically) zero — they are
    /// fill the symbolic phase already accounted for; a debug assertion
    /// guards against symbolic/numeric divergence.
    pub fn from_dense_col_major(&mut self, d: &[f64]) {
        let nr = self.n_rows as usize;
        for c in 0..self.n_cols as usize {
            for k in self.col_ptr[c] as usize..self.col_ptr[c + 1] as usize {
                self.values[k] = d[c * nr + self.row_idx[k] as usize];
            }
        }
        #[cfg(debug_assertions)]
        {
            let mut inside = vec![false; d.len()];
            for c in 0..self.n_cols as usize {
                for k in self.col_ptr[c] as usize..self.col_ptr[c + 1] as usize {
                    inside[c * nr + self.row_idx[k] as usize] = true;
                }
            }
            for (p, &v) in d.iter().enumerate() {
                debug_assert!(
                    inside[p] || v.abs() < 1e-9,
                    "dense kernel produced value {v} outside symbolic pattern"
                );
            }
        }
    }
}

/// A blocked sparse matrix: the set of non-empty blocks over a blocking
/// grid, with row/column adjacency for the factorization loops.
#[derive(Clone, Debug)]
pub struct BlockedMatrix {
    pub blocking: Blocking,
    pub blocks: Vec<Block>,
    index: HashMap<(u32, u32), u32>,
    /// For each block column `bj`: ids of non-empty blocks sorted by `bi`.
    pub by_col: Vec<Vec<u32>>,
    /// For each block row `bi`: ids of non-empty blocks sorted by `bj`.
    pub by_row: Vec<Vec<u32>>,
}

/// Per-block-row accumulator for one block-column stripe.
struct Builder {
    counts: Vec<u32>,
    rows: Vec<u32>,
    vals: Vec<f64>,
}

/// Assemble every non-empty block of block-column stripe `bj`, in
/// ascending `bi` order — the exact per-stripe body of the sequential
/// partition pass, factored out so stripes can run concurrently (they
/// touch disjoint columns of `ldu` and write disjoint outputs).
fn build_stripe(
    ldu: &Csc,
    positions: &[usize],
    row_block: &[u32],
    bj: usize,
) -> Result<Vec<Block>, FactorError> {
    let nb = positions.len() - 1;
    let (lo, hi) = (positions[bj], positions[bj + 1]);
    let width = hi - lo;
    let mut builders: Vec<Option<Builder>> = (0..nb).map(|_| None).collect();
    let mut touched: Vec<usize> = Vec::new();
    // gather entries of this stripe into per-block-row builders
    for (c_local, j) in (lo..hi).enumerate() {
        for (i, v) in ldu.col(j) {
            let bi = row_block[i] as usize;
            let b = builders[bi].get_or_insert_with(|| {
                touched.push(bi);
                Builder { counts: vec![0u32; width], rows: Vec::new(), vals: Vec::new() }
            });
            b.counts[c_local] += 1;
            b.rows.push((i - positions[bi]) as u32);
            b.vals.push(v);
        }
    }
    // entries arrive per global column (columns are the outer loop), so
    // per builder they are already grouped by ascending column
    touched.sort_unstable();
    let mut out = Vec::with_capacity(touched.len());
    for &bi in &touched {
        let b = builders[bi].take().unwrap();
        let mut col_ptr = vec![0u32; width + 1];
        for c in 0..width {
            col_ptr[c + 1] = col_ptr[c] + b.counts[c];
        }
        // precompute diagonal offsets for diagonal blocks
        let diag_pos = if bi == bj {
            let mut dp = Vec::with_capacity(width);
            for c in 0..width {
                let rows = &b.rows[col_ptr[c] as usize..col_ptr[c + 1] as usize];
                match rows.binary_search(&(c as u32)) {
                    Ok(k) => dp.push(k as u32),
                    // `lo + c` is the row index in the pattern handed to
                    // the partitioner (post-permutation when called from
                    // a plan build; FactorPlan's own diagonal scan
                    // reports the pre-permutation index first)
                    Err(_) => return Err(FactorError::StructurallySingular { row: lo + c }),
                }
            }
            dp
        } else {
            Vec::new()
        };
        out.push(Block {
            bi: bi as u32,
            bj: bj as u32,
            n_rows: (positions[bi + 1] - positions[bi]) as u32,
            n_cols: width as u32,
            col_ptr,
            row_idx: b.rows,
            values: b.vals,
            diag_pos,
        });
    }
    Ok(out)
}

impl BlockedMatrix {
    /// Partition `ldu` (the filled L+U pattern with values) by `blocking`.
    ///
    /// Sequential, panicking wrapper over [`Self::try_build_on`] for
    /// callers that know their pattern has a full structural diagonal
    /// (every in-repo generator guarantees one). Serving paths go through
    /// `try_build_on` instead so a tenant-supplied singular pattern comes
    /// back as an `Err`.
    pub fn build(ldu: &Csc, blocking: Blocking) -> Self {
        match Self::try_build_on(ldu, blocking, None) {
            Ok(bm) => bm,
            Err(FactorError::StructurallySingular { row }) => {
                panic!("diagonal entry missing in diagonal block (row {row})")
            }
            Err(e) => panic!("blocked partition failed: {e}"),
        }
    }

    /// Partition `ldu` by `blocking`, assembling the block-column stripes
    /// on `exec` when one is given (each stripe is independent once the
    /// block boundaries are fixed — Kim et al.'s 2D partitioned-block
    /// observation). The resulting block order, ids and adjacency are
    /// bit-identical to the sequential pass at every worker count:
    /// stripes write disjoint slots that are stitched in `bj` order.
    ///
    /// Returns [`FactorError::StructurallySingular`] (first affected
    /// column in `ldu` row numbering) when a diagonal block is missing a
    /// diagonal entry, instead of panicking the calling thread.
    pub fn try_build_on(
        ldu: &Csc,
        blocking: Blocking,
        exec: Option<&Executor>,
    ) -> Result<Self, FactorError> {
        let n = ldu.n_cols();
        assert_eq!(blocking.n(), n);
        let nb = blocking.num_blocks();
        let positions = blocking.positions().to_vec();

        // row → block-row map, computed once (a binary search per entry
        // dominated this pass before — perf opt-3)
        let mut row_block = vec![0u32; n];
        for bi in 0..nb {
            for r in positions[bi]..positions[bi + 1] {
                row_block[r] = bi as u32;
            }
        }

        let mut stripes: Vec<Result<Vec<Block>, FactorError>> =
            (0..nb).map(|_| Ok(Vec::new())).collect();
        par_chunks(exec, &mut stripes, &|start, out| {
            for (off, slot) in out.iter_mut().enumerate() {
                *slot = build_stripe(ldu, &positions, &row_block, start + off);
            }
        })?;
        let mut blocks: Vec<Block> = Vec::new();
        for stripe in stripes {
            // first error in bj order wins — deterministic across
            // worker counts (every stripe ran to completion regardless)
            blocks.extend(stripe?);
        }

        let mut index = HashMap::with_capacity(blocks.len());
        let mut by_col: Vec<Vec<u32>> = vec![Vec::new(); nb];
        let mut by_row: Vec<Vec<u32>> = vec![Vec::new(); nb];
        for (id, b) in blocks.iter().enumerate() {
            index.insert((b.bi, b.bj), id as u32);
            by_col[b.bj as usize].push(id as u32);
            by_row[b.bi as usize].push(id as u32);
        }
        for v in &mut by_col {
            v.sort_unstable_by_key(|&id| blocks[id as usize].bi);
        }
        for v in &mut by_row {
            v.sort_unstable_by_key(|&id| blocks[id as usize].bj);
        }
        Ok(Self { blocking, blocks, index, by_col, by_row })
    }

    pub fn nb(&self) -> usize {
        self.blocking.num_blocks()
    }

    pub fn num_nonempty(&self) -> usize {
        self.blocks.len()
    }

    /// Block id at grid position, if non-empty.
    pub fn block_id(&self, bi: usize, bj: usize) -> Option<u32> {
        self.index.get(&(bi as u32, bj as u32)).copied()
    }

    pub fn block(&self, id: u32) -> &Block {
        &self.blocks[id as usize]
    }

    pub fn block_mut(&mut self, id: u32) -> &mut Block {
        &mut self.blocks[id as usize]
    }

    /// Total stored nonzeros across blocks (== nnz of the LDU pattern).
    pub fn nnz(&self) -> usize {
        self.blocks.iter().map(|b| b.nnz()).sum()
    }

    /// Reassemble the global CSC (tests / verification).
    pub fn to_csc(&self) -> Csc {
        let n = self.blocking.n();
        let positions = self.blocking.positions();
        let mut coo = crate::sparse::Coo::with_capacity(n, n, self.nnz());
        for b in &self.blocks {
            let (rlo, clo) = (positions[b.bi as usize], positions[b.bj as usize]);
            for c in 0..b.n_cols as usize {
                for k in b.col_ptr[c] as usize..b.col_ptr[c + 1] as usize {
                    coo.push(rlo + b.row_idx[k] as usize, clo + c, b.values[k]);
                }
            }
        }
        coo.to_csc()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blocking::regular_blocking;
    use crate::sparse::gen;
    use crate::symbolic;

    fn blocked(n_grid: usize, bs: usize) -> (Csc, BlockedMatrix) {
        let a = gen::grid2d_laplacian(n_grid, n_grid);
        let sym = symbolic::analyze(&a);
        let ldu = sym.ldu_pattern(&a).unwrap();
        let bm = BlockedMatrix::build(&ldu, regular_blocking(a.n_cols(), bs));
        (ldu, bm)
    }

    #[test]
    fn round_trip_preserves_matrix() {
        let (ldu, bm) = blocked(8, 10);
        assert_eq!(bm.to_csc(), ldu);
        assert_eq!(bm.nnz(), ldu.nnz());
    }

    #[test]
    fn blocks_have_correct_dims() {
        let (_, bm) = blocked(8, 10); // n=64, blocks 10,10,10,10,10,10,4
        assert_eq!(bm.nb(), 7);
        for b in &bm.blocks {
            let er = bm.blocking.block_size(b.bi as usize);
            let ec = bm.blocking.block_size(b.bj as usize);
            assert_eq!(b.n_rows as usize, er);
            assert_eq!(b.n_cols as usize, ec);
            // all local indices in range, sorted per column
            for c in 0..b.n_cols as usize {
                let rows = b.col_rows(c);
                assert!(rows.windows(2).all(|w| w[0] < w[1]));
                assert!(rows.iter().all(|&r| r < b.n_rows));
            }
        }
    }

    #[test]
    fn adjacency_lists_consistent() {
        let (_, bm) = blocked(10, 16);
        for (bj, ids) in bm.by_col.iter().enumerate() {
            let bis: Vec<u32> = ids.iter().map(|&id| bm.block(id).bi).collect();
            assert!(bis.windows(2).all(|w| w[0] < w[1]), "col {bj} not sorted");
            for &id in ids {
                assert_eq!(bm.block(id).bj as usize, bj);
            }
        }
        for (bi, ids) in bm.by_row.iter().enumerate() {
            for &id in ids {
                assert_eq!(bm.block(id).bi as usize, bi);
            }
        }
    }

    #[test]
    fn block_id_lookup() {
        let (_, bm) = blocked(6, 12);
        for (id, b) in bm.blocks.iter().enumerate() {
            assert_eq!(bm.block_id(b.bi as usize, b.bj as usize), Some(id as u32));
        }
        // grid laplacian blocked by 12 on n=36: far corner block (0, nb-1)
        // may be empty before fill... after fill with natural order it is
        // often nonempty; just check lookup of a definitely-empty pair on
        // a tridiagonal instead.
        let t = gen::tridiagonal(40);
        let sym = symbolic::analyze(&t);
        let ldu = sym.ldu_pattern(&t).unwrap();
        let bm2 = BlockedMatrix::build(&ldu, regular_blocking(40, 10));
        assert_eq!(bm2.block_id(0, 3), None, "tridiagonal corner must be empty");
    }

    #[test]
    fn dense_round_trip() {
        let (_, mut bm) = blocked(6, 9);
        let id = bm.block_id(0, 0).unwrap();
        let before = bm.block(id).values.clone();
        let dense = bm.block(id).to_dense_col_major();
        bm.block_mut(id).from_dense_col_major(&dense);
        assert_eq!(bm.block(id).values, before);
    }

    #[test]
    fn diag_pos_points_at_diagonal_entries() {
        let (_, bm) = blocked(8, 10);
        for b in &bm.blocks {
            if b.bi == b.bj {
                assert_eq!(b.diag_pos.len(), b.n_cols as usize);
                for c in 0..b.n_cols as usize {
                    let rows = b.col_rows(c);
                    assert_eq!(rows[b.diag_pos[c] as usize] as usize, c, "block {}", b.bi);
                }
            } else {
                assert!(b.diag_pos.is_empty());
            }
        }
    }

    #[test]
    fn empty_blocks_not_stored() {
        let t = gen::tridiagonal(100);
        let sym = symbolic::analyze(&t);
        let ldu = sym.ldu_pattern(&t).unwrap();
        let bm = BlockedMatrix::build(&ldu, regular_blocking(100, 10));
        // tridiagonal: only diagonal + sub/super-diagonal block couples
        assert!(bm.num_nonempty() <= 10 + 9 + 9);
        assert!(bm.num_nonempty() >= 10);
    }

    #[test]
    fn parallel_partition_is_bit_identical_to_sequential() {
        let a = gen::circuit_bbd(gen::CircuitParams { n: 600, ..Default::default() });
        let sym = symbolic::analyze(&a);
        let ldu = sym.ldu_pattern(&a).unwrap();
        let seq = BlockedMatrix::build(&ldu, regular_blocking(a.n_cols(), 48));
        for workers in [2u32, 8] {
            let exec = crate::coordinator::Executor::shared(workers);
            let par =
                BlockedMatrix::try_build_on(&ldu, regular_blocking(a.n_cols(), 48), Some(&exec))
                    .unwrap();
            assert_eq!(par.blocks.len(), seq.blocks.len(), "workers={workers}");
            for (id, (p, s)) in par.blocks.iter().zip(&seq.blocks).enumerate() {
                assert_eq!((p.bi, p.bj), (s.bi, s.bj), "block {id} coords (workers={workers})");
                assert_eq!(p.col_ptr, s.col_ptr, "block {id} col_ptr");
                assert_eq!(p.row_idx, s.row_idx, "block {id} row_idx");
                assert_eq!(p.values, s.values, "block {id} values");
                assert_eq!(p.diag_pos, s.diag_pos, "block {id} diag_pos");
            }
            assert_eq!(par.by_col, seq.by_col);
            assert_eq!(par.by_row, seq.by_row);
        }
    }

    #[test]
    fn structurally_singular_pattern_returns_err_not_panic() {
        // column 2 is populated but has no diagonal entry
        let mut coo = crate::sparse::Coo::new(5, 5);
        for i in 0..5 {
            if i != 2 {
                coo.push(i, i, 4.0);
            }
        }
        coo.push(0, 2, 1.0);
        coo.push(2, 3, 1.0);
        let c = coo.to_csc();
        let err = BlockedMatrix::try_build_on(&c, regular_blocking(5, 5), None).unwrap_err();
        assert_eq!(err, FactorError::StructurallySingular { row: 2 });
    }

    #[test]
    fn irregular_blocking_partition_works() {
        let a = gen::circuit_bbd(gen::CircuitParams { n: 800, ..Default::default() });
        let sym = symbolic::analyze(&a);
        let ldu = sym.ldu_pattern(&a).unwrap();
        let curve = crate::blocking::DiagFeature::from_csc(&ldu).curve();
        let blocking =
            crate::blocking::irregular_blocking(&curve, &crate::blocking::IrregularParams::default());
        let bm = BlockedMatrix::build(&ldu, blocking);
        assert_eq!(bm.to_csc(), ldu);
    }
}
