//! Regular fixed-size 2D blocking — the PanguLU baseline the paper
//! compares against.

use super::Blocking;

/// Partition `0..n` into blocks of size `block_size` (last block may be
/// smaller), exactly as PanguLU's regular 2D block-cyclic layout does.
pub fn regular_blocking(n: usize, block_size: usize) -> Blocking {
    assert!(block_size > 0, "block size must be positive");
    assert!(n > 0, "empty matrix");
    let mut positions = Vec::with_capacity(n / block_size + 2);
    let mut p = 0;
    while p < n {
        positions.push(p);
        p += block_size;
    }
    positions.push(n);
    Blocking::new(n, positions)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_division() {
        let b = regular_blocking(100, 25);
        assert_eq!(b.positions(), &[0, 25, 50, 75, 100]);
        assert_eq!(b.sizes(), vec![25, 25, 25, 25]);
    }

    #[test]
    fn ragged_tail() {
        let b = regular_blocking(10, 4);
        assert_eq!(b.positions(), &[0, 4, 8, 10]);
        assert_eq!(b.sizes(), vec![4, 4, 2]);
    }

    #[test]
    fn block_larger_than_matrix() {
        let b = regular_blocking(7, 100);
        assert_eq!(b.positions(), &[0, 7]);
        assert_eq!(b.num_blocks(), 1);
    }

    #[test]
    fn size_one_blocks() {
        let b = regular_blocking(3, 1);
        assert_eq!(b.num_blocks(), 3);
    }
}
