//! A100 roofline cost model.
//!
//! The paper's testbed is 1–4 NVIDIA A100-80G GPUs (HBM 1555 GB/s,
//! Table 2). This reproduction executes on CPU threads, so for the
//! paper-shaped tables we additionally report **modeled GPU time**: each
//! block operation is priced as
//!
//! ```text
//! t(op) = max(flops / (peak · eff_op), bytes / bw) + launch_overhead
//! ```
//!
//! and the multi-GPU makespan is obtained by discrete-event simulation of
//! the task DAG over the block-cyclic placement
//! ([`crate::coordinator::simulate`]). Efficiencies are *relative*
//! calibrations (sparse kernels are memory-bound and irregular; dense
//! kernels approach the roofline); the tables compare solvers under the
//! same model, so only the ratios matter — mirroring how the paper's
//! speedups abstract over absolute kernel quality.

/// Operation classes with distinct achievable efficiency.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OpClass {
    /// Sparse GETRF/GESSM/TSTRF — latency-bound sequential dependence.
    SparseFactor,
    /// Sparse SSSSM — streaming AXPYs, memory-bound.
    SparseUpdate,
    /// Dense kernels (cuBLAS / MXU artifact).
    Dense,
}

/// Roofline parameters of one device.
#[derive(Clone, Copy, Debug)]
pub struct CostModel {
    /// Peak FP64 throughput, flop/s.
    pub peak_flops: f64,
    /// HBM bandwidth, bytes/s.
    pub mem_bw: f64,
    /// Kernel launch + scheduling overhead per block op, seconds.
    pub launch_overhead: f64,
    /// Achievable fraction of peak for sparse factor kernels.
    pub eff_sparse_factor: f64,
    /// Achievable fraction of peak for sparse update kernels.
    pub eff_sparse_update: f64,
    /// Achievable fraction of peak for dense kernels.
    pub eff_dense: f64,
    /// Inter-GPU transfer bandwidth (NVLink-ish), bytes/s — charged when a
    /// task consumes a block owned by another worker.
    pub link_bw: f64,
    /// Per-transfer latency, seconds.
    pub link_latency: f64,
    /// Serial latency per eliminated column in factor-type kernels
    /// (GETRF/TRSM column loops are dependency chains on the device; this
    /// is what makes *giant* sparse blocks slow on GPUs and why blocked
    /// factorization exists at all).
    pub col_latency: f64,
    /// Quadratic serial coefficient: wider blocks have longer per-column
    /// trailing updates on the chain (sync spans more warps), so the
    /// chain cost grows super-linearly with block width — this produces
    /// the block-size U-shape of the paper's Fig 4.
    pub col_latency_quad: f64,
    /// Work (nonzeros touched) at which a kernel reaches half of its
    /// class efficiency — small blocks underutilize the device (the
    /// paper's §5.2: "large blocks contain relatively more nonzero
    /// elements, which can improve the utilization rate of the GPU").
    pub sat_half_work: f64,
    /// Concurrent kernels one device sustains (streams/occupancy) —
    /// independent block ops overlap on a real GPU; this is why *tiny*
    /// blocks underutilize only through launch overhead, not serially.
    pub concurrent_kernels: u32,
}

impl CostModel {
    /// NVIDIA A100-80G, FP64 (Table 2 of the paper).
    pub fn a100() -> Self {
        Self {
            peak_flops: 9.7e12,       // FP64 non-tensor
            mem_bw: 1.555e12,         // 1555 GB/s
            launch_overhead: 6e-6,    // ~6 µs per kernel
            eff_sparse_factor: 0.010, // irregular, latency-bound
            eff_sparse_update: 0.035, // streaming sparse AXPY
            eff_dense: 0.55,          // cuBLAS-level
            link_bw: 300e9,           // NVLink3 per direction
            link_latency: 8e-6,
            col_latency: 1.2e-6,      // dependent-column step latency
            col_latency_quad: 5e-10,  // super-linear width penalty
            sat_half_work: 24_000.0,  // half-saturation work (values)
            concurrent_kernels: 8,    // stream-level overlap per device
        }
    }

    /// Device-utilization factor of an op touching `work` values:
    /// Michaelis–Menten saturation toward 1.0.
    pub fn saturation(&self, work: f64) -> f64 {
        work / (work + self.sat_half_work)
    }

    /// Full op pricing: roofline with utilization saturation for the
    /// compute term plus the serial column chain (0 for update ops).
    pub fn op_time_full(
        &self,
        class: OpClass,
        flops: f64,
        bytes: f64,
        work: f64,
        serial_cols: usize,
    ) -> f64 {
        let eff = match class {
            OpClass::SparseFactor => self.eff_sparse_factor,
            OpClass::SparseUpdate => self.eff_sparse_update,
            OpClass::Dense => self.eff_dense,
        } * self.saturation(work);
        let compute = flops / (self.peak_flops * eff.max(1e-6));
        let memory = bytes / self.mem_bw;
        let s = serial_cols as f64;
        compute.max(memory)
            + self.launch_overhead
            + s * self.col_latency
            + s * s * self.col_latency_quad
    }

    /// Back-compat wrapper: no saturation, with serial chain.
    pub fn op_time_serial(
        &self,
        class: OpClass,
        flops: f64,
        bytes: f64,
        serial_cols: usize,
    ) -> f64 {
        let s = serial_cols as f64;
        self.op_time(class, flops, bytes) + s * self.col_latency + s * s * self.col_latency_quad
    }

    /// Time for one block op given its flop and byte counts.
    pub fn op_time(&self, class: OpClass, flops: f64, bytes: f64) -> f64 {
        let eff = match class {
            OpClass::SparseFactor => self.eff_sparse_factor,
            OpClass::SparseUpdate => self.eff_sparse_update,
            OpClass::Dense => self.eff_dense,
        };
        let compute = flops / (self.peak_flops * eff);
        let memory = bytes / self.mem_bw;
        compute.max(memory) + self.launch_overhead
    }

    /// Time to move `bytes` between two devices.
    pub fn transfer_time(&self, bytes: f64) -> f64 {
        bytes / self.link_bw + self.link_latency
    }
}

/// Approximate bytes touched by a sparse block op over patterns with the
/// given nnz counts (index + value traffic, read + write).
pub fn sparse_bytes(nnz_read: usize, nnz_written: usize) -> f64 {
    // 8B value + 4B index per entry; written entries also read
    (nnz_read as f64) * 12.0 + (nnz_written as f64) * 24.0
}

/// Bytes for a dense op on an `m×n` block.
pub fn dense_bytes(m: usize, n: usize) -> f64 {
    (m * n) as f64 * 8.0 * 2.0
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dense_big_gemm_is_compute_bound() {
        let m = CostModel::a100();
        // 2048³ GEMM: 2·2048³ flops vs 3·2048²·8 bytes
        let flops = 2.0 * 2048f64.powi(3);
        let bytes = 3.0 * 2048f64 * 2048.0 * 8.0;
        let t = m.op_time(OpClass::Dense, flops, bytes);
        let compute = flops / (m.peak_flops * m.eff_dense);
        assert!((t - (compute + m.launch_overhead)).abs() < 1e-9);
    }

    #[test]
    fn sparse_small_op_is_overhead_bound() {
        let m = CostModel::a100();
        let t = m.op_time(OpClass::SparseUpdate, 100.0, 1000.0);
        assert!(t < 2.0 * m.launch_overhead);
        assert!(t >= m.launch_overhead);
    }

    #[test]
    fn sparse_update_faster_than_factor_at_same_size() {
        let m = CostModel::a100();
        let f = m.op_time(OpClass::SparseFactor, 1e9, 1e6);
        let u = m.op_time(OpClass::SparseUpdate, 1e9, 1e6);
        assert!(u < f);
    }

    #[test]
    fn transfer_scales_with_bytes() {
        let m = CostModel::a100();
        let t1 = m.transfer_time(1e6);
        let t2 = m.transfer_time(1e9);
        assert!(t2 > 100.0 * t1);
    }

    #[test]
    fn byte_helpers_positive() {
        assert!(sparse_bytes(100, 50) > 0.0);
        assert!(dense_bytes(64, 64) == 64.0 * 64.0 * 16.0);
    }

    #[test]
    fn saturation_monotone_and_bounded() {
        let m = CostModel::a100();
        assert!(m.saturation(0.0) == 0.0);
        assert!(m.saturation(m.sat_half_work) == 0.5);
        assert!(m.saturation(1e12) > 0.999);
        let mut prev = 0.0;
        for w in [10.0, 100.0, 1e4, 1e6, 1e8] {
            let s = m.saturation(w);
            assert!(s > prev);
            prev = s;
        }
    }

    #[test]
    fn small_blocks_pay_underutilization() {
        // same flops, small-work op must be slower than big-work op
        let m = CostModel::a100();
        let small = m.op_time_full(OpClass::SparseUpdate, 1e8, 1e4, 1_000.0, 0);
        let big = m.op_time_full(OpClass::SparseUpdate, 1e8, 1e4, 1_000_000.0, 0);
        assert!(small > 5.0 * big, "small {small} vs big {big}");
    }

    #[test]
    fn serial_chain_grows_superlinearly() {
        let m = CostModel::a100();
        let t1 = m.op_time_full(OpClass::SparseFactor, 0.0, 0.0, 1e9, 100);
        let t10 = m.op_time_full(OpClass::SparseFactor, 0.0, 0.0, 1e9, 1000);
        // 10x the columns must cost more than 10x the serial time
        assert!(
            (t10 - m.launch_overhead) > 10.0 * (t1 - m.launch_overhead),
            "t1 {t1} t10 {t10}"
        );
    }
}
