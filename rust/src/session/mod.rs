//! Plan-cached re-factorization sessions — factor the *pattern* once,
//! factor the *values* millions of times.
//!
//! Everything the paper contributes (the diagonal-block feature, the
//! irregular blocking, the task DAG over the blocks) depends only on the
//! sparsity pattern. The dominant real workload for sparse LU — SPICE
//! Newton iterations, transient timesteps, parameter sweeps — re-factors
//! the **same pattern** with **new values** over and over. This module
//! splits the pipeline accordingly:
//!
//! * [`FactorPlan`] — immutable, `Arc`-shareable product of the
//!   structure-only phases: ordering + symbolic pattern + blocking +
//!   task DAG + placement + a precomputed value scatter map.
//! * [`SolverSession`] — binds a plan to preallocated blocked storage;
//!   [`SolverSession::refactorize`] scatters new values and re-runs the
//!   DAG with no symbolic work and no per-call block allocation, and
//!   [`SolverSession::solve_many`] batches multi-RHS triangular solves.
//! * [`PlanCache`] — LRU over [`crate::sparse::Csc::pattern_fingerprint`]
//!   so serving paths get plan reuse without bookkeeping.
//!
//! ```no_run
//! use sparselu::session::{FactorPlan, SolverSession};
//! use sparselu::solver::SolveOptions;
//! use sparselu::sparse::gen;
//! use std::sync::Arc;
//!
//! let a = gen::circuit_bbd(gen::CircuitParams::default());
//! let plan = Arc::new(FactorPlan::build(&a, &SolveOptions::ours(4)));
//! let mut session = SolverSession::from_plan(plan);
//! for _newton_step in 0..1000 {
//!     // update conductances, same pattern
//!     let values = a.values.clone();
//!     session.refactorize(&values).unwrap();
//!     let b = vec![1.0; a.n_rows()];
//!     let x = session.solve(&b);
//!     assert_eq!(x.len(), a.n_rows());
//! }
//! ```

pub mod cache;
pub mod plan;
#[allow(clippy::module_inception)]
pub mod session;

pub use cache::PlanCache;
pub use plan::{FactorPlan, PlanReport};
pub use session::{RefactorReport, SolverSession};
