//! Plan-cached re-factorization sessions — factor the *pattern* once,
//! factor the *values* millions of times.
//!
//! Everything the paper contributes (the diagonal-block feature, the
//! irregular blocking, the task DAG over the blocks) depends only on the
//! sparsity pattern. The dominant real workload for sparse LU — SPICE
//! Newton iterations, transient timesteps, parameter sweeps — re-factors
//! the **same pattern** with **new values** over and over. This module
//! splits the pipeline accordingly:
//!
//! * [`FactorPlan`] — immutable, `Arc`-shareable product of the
//!   structure-only phases: ordering + symbolic pattern + blocking +
//!   task DAG + placement + a precomputed value scatter map.
//! * [`SolverSession`] — binds a plan to preallocated blocked storage;
//!   [`SolverSession::refactorize`] scatters new values and re-runs the
//!   DAG with no symbolic work and no per-call block allocation, and
//!   [`SolverSession::solve_many`] batches multi-RHS triangular solves.
//! * [`PlanCache`] — LRU over [`crate::sparse::Csc::pattern_fingerprint`]
//!   so serving paths get plan reuse without bookkeeping.
//! * [`ChangeSet`] + [`SolverSession::refactorize_partial`] —
//!   **incremental** re-factorization: when only a few A-values change
//!   (a SPICE device stamp, one nonlinear element between Newton steps),
//!   the changed entries map to *dirty* blocks through the plan's
//!   scatter map, the dirty set is closed over the plan's precomputed
//!   block dependency edges, and only the DAG tasks writing affected
//!   blocks re-execute — bit-identical to a full `refactorize`, at a
//!   fraction of the task count.
//! * [`SolverSession::estimate_partial`] — the allocation-free forecast
//!   of that pruning (dirty blocks, closure size, tasks that would run),
//!   so schedulers can pick partial vs full per request before
//!   executing anything.
//!
//! The [`crate::serve`] layer builds the multi-client serving story on
//! top of these pieces: warm a [`PlanCache`] from persisted plan files
//! ([`PlanCache::warm_from_dir`]), share the plan across a
//! [`crate::serve::SessionPool`], batch each client's requests through
//! a [`crate::serve::Batcher`], and serve many *patterns* at once by
//! routing requests to per-pattern shards through a
//! [`crate::serve::Router`] keyed by this cache.
//!
//! ```no_run
//! use sparselu::session::{ChangeSet, FactorPlan, SolverSession};
//! use sparselu::solver::SolveOptions;
//! use sparselu::sparse::gen;
//! use std::sync::Arc;
//!
//! let a = gen::circuit_bbd(gen::CircuitParams::default());
//! let plan = Arc::new(FactorPlan::build(&a, &SolveOptions::ours(4)).unwrap());
//! let mut session = SolverSession::from_plan(plan);
//! session.refactorize(&a.values).unwrap(); // full pass seeds the factors
//! for _newton_step in 0..1000 {
//!     // one device re-stamped: two conductance entries change
//!     let g = 1.0e-3;
//!     let cs = ChangeSet::from_coords(&a, &[(0, 0, g), (1, 1, g)]).unwrap();
//!     let report = session.refactorize_partial(&cs).unwrap();
//!     assert_eq!(
//!         report.tasks_executed + report.tasks_skipped,
//!         session.plan().dag.tasks.len(),
//!     );
//!     let b = vec![1.0; a.n_rows()];
//!     let x = session.solve(&b);
//!     assert_eq!(x.len(), a.n_rows());
//! }
//! ```

pub mod cache;
pub mod changeset;
pub mod plan;
#[allow(clippy::module_inception)]
pub mod session;

pub use cache::{PlanCache, SharedPlanCache};
pub use changeset::ChangeSet;
pub use plan::{FactorPlan, PlanReport};
pub use session::{
    PartialEstimate, RefactorReport, RefineError, RefinedSolve, SolverSession, REFINE_MAX_ITERS,
    REFINE_TARGET,
};
