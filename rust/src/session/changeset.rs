//! [`ChangeSet`] — a sparse description of *which* values of `A` changed
//! between two re-factorizations of the same pattern.
//!
//! Incremental re-factorization ([`crate::session::SolverSession::refactorize_partial`])
//! starts from exactly this information: each changed A-nonzero lands in
//! one block of the plan's blocked L+U structure (through the plan's
//! scatter map), those blocks form the *dirty* seed set, and only the DAG
//! tasks writing blocks forward-reachable from the seeds re-execute.
//!
//! Entries are addressed by **CSC value index** of the original `A` —
//! the position in [`crate::sparse::Csc::values`] — which is stable for a
//! fixed sparsity pattern. Coordinate-based construction
//! ([`ChangeSet::from_coords`], the SPICE "device stamp" shape) and
//! whole-matrix diffing ([`ChangeSet::from_matrix_diff`]) are provided on
//! top of that.

use crate::numeric::factor::FactorError;
use crate::sparse::Csc;

/// A set of `(value index, new value)` updates to the nonzeros of `A`.
///
/// Duplicate indices are allowed; the last update for an index wins
/// (updates are applied in order).
#[derive(Clone, Debug, Default)]
pub struct ChangeSet {
    updates: Vec<(usize, f64)>,
}

impl ChangeSet {
    /// Empty change set (a no-op `refactorize_partial`).
    pub fn new() -> Self {
        Self { updates: Vec::new() }
    }

    /// Number of recorded updates.
    pub fn len(&self) -> usize {
        self.updates.len()
    }

    pub fn is_empty(&self) -> bool {
        self.updates.is_empty()
    }

    /// Record a new value for the A-nonzero at CSC value index `k`.
    pub fn push(&mut self, k: usize, new_value: f64) {
        self.updates.push((k, new_value));
    }

    /// Build from `(value index, new value)` pairs.
    pub fn from_value_indices(updates: impl IntoIterator<Item = (usize, f64)>) -> Self {
        Self { updates: updates.into_iter().collect() }
    }

    /// Device-stamp style construction: updates addressed by `(row, col,
    /// new value)` coordinate, resolved against `a`'s pattern via
    /// [`Csc::value_index`].
    ///
    /// A coordinate outside the sparsity pattern returns
    /// [`FactorError::OutOfPattern`] — such a stamp would change the
    /// *structure*, which needs a fresh [`crate::session::FactorPlan`],
    /// not a change set. Serving paths forward the error to the client
    /// instead of aborting the process.
    pub fn from_coords(a: &Csc, stamps: &[(usize, usize, f64)]) -> Result<Self, FactorError> {
        let updates = stamps
            .iter()
            .map(|&(i, j, v)| match a.value_index(i, j) {
                Some(k) => Ok((k, v)),
                None => Err(FactorError::OutOfPattern { row: i, col: j }),
            })
            .collect::<Result<Vec<_>, _>>()?;
        Ok(Self { updates })
    }

    /// Diff two same-pattern matrices ([`Csc::value_diff`]): every entry
    /// whose value changed becomes one update.
    pub fn from_matrix_diff(old: &Csc, new: &Csc) -> Self {
        Self { updates: old.value_diff(new) }
    }

    /// Diff two value vectors of the same planned pattern (e.g. the
    /// session's [`crate::session::SolverSession::current_values`] against
    /// the next Newton step's values).
    pub fn from_values_diff(old: &[f64], new: &[f64]) -> Self {
        Self { updates: crate::sparse::csc::values_diff(old, new) }
    }

    /// The recorded `(value index, new value)` updates, in push order.
    pub fn updates(&self) -> &[(usize, f64)] {
        &self.updates
    }

    /// Append every update of `other` after this set's own.
    ///
    /// Because later updates win per index, `a.extend_from(&b)` is
    /// equivalent to applying `a` then `b` in sequence — which is what
    /// makes **change-set batching across timesteps** sound: a run of
    /// consecutive device stamps coalesced into one merged set produces
    /// factors bit-identical to stamping each set one at a time, while
    /// paying a single dirty-block closure and one pruned DAG replay
    /// (see [`crate::serve::Batcher`]).
    ///
    /// ```
    /// use sparselu::session::ChangeSet;
    /// let mut a = ChangeSet::from_value_indices([(3, 1.0), (5, 2.0)]);
    /// let b = ChangeSet::from_value_indices([(5, 9.0)]);
    /// a.extend_from(&b);
    /// assert_eq!(a.updates(), &[(3, 1.0), (5, 2.0), (5, 9.0)]); // 5 → 9.0 wins
    /// ```
    pub fn extend_from(&mut self, other: &ChangeSet) {
        self.updates.extend_from_slice(&other.updates);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::gen;

    #[test]
    fn from_coords_resolves_value_indices() {
        let a = gen::tridiagonal(6);
        let cs = ChangeSet::from_coords(&a, &[(0, 0, 5.0), (2, 1, -1.0)]).unwrap();
        assert_eq!(cs.len(), 2);
        let (k0, v0) = cs.updates()[0];
        assert_eq!(k0, a.value_index(0, 0).unwrap());
        assert_eq!(v0, 5.0);
        let (k1, _) = cs.updates()[1];
        assert_eq!(k1, a.value_index(2, 1).unwrap());
    }

    #[test]
    fn from_coords_rejects_structural_stamp_with_error() {
        // a stamp outside the pattern must come back as a clean error the
        // serving layer can forward — never a process abort
        let a = gen::tridiagonal(6);
        let err = ChangeSet::from_coords(&a, &[(0, 0, 1.0), (0, 5, 1.0)]).unwrap_err();
        match err {
            FactorError::OutOfPattern { row, col } => assert_eq!((row, col), (0, 5)),
            other => panic!("expected OutOfPattern, got {other:?}"),
        }
        // out-of-range coordinates are rejected the same way
        assert!(matches!(
            ChangeSet::from_coords(&a, &[(9, 0, 1.0)]),
            Err(FactorError::OutOfPattern { row: 9, col: 0 })
        ));
    }

    #[test]
    fn from_values_diff_finds_changes() {
        let a = gen::tridiagonal(5);
        let mut new = a.values.clone();
        new[3] += 1.0;
        new[7] -= 2.0;
        let cs = ChangeSet::from_values_diff(&a.values, &new);
        assert_eq!(cs.updates(), &[(3, new[3]), (7, new[7])]);
        assert!(ChangeSet::from_values_diff(&a.values, &a.values).is_empty());
    }

    #[test]
    fn extend_from_preserves_sequential_semantics() {
        let mut a = ChangeSet::from_value_indices([(0, 1.0), (2, 2.0)]);
        let b = ChangeSet::from_value_indices([(2, 7.0), (4, 3.0)]);
        a.extend_from(&b);
        assert_eq!(a.len(), 4);
        // applying the merged set in order leaves index 2 at b's value
        let mut values = vec![0.0; 5];
        for &(k, v) in a.updates() {
            values[k] = v;
        }
        assert_eq!(values, vec![1.0, 0.0, 7.0, 0.0, 3.0]);
    }

    #[test]
    fn push_and_default_are_consistent() {
        let mut cs = ChangeSet::default();
        assert!(cs.is_empty());
        cs.push(4, 2.5);
        assert_eq!(cs.len(), 1);
        assert_eq!(cs.updates(), &[(4, 2.5)]);
    }
}
