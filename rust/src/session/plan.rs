//! The [`FactorPlan`]: every product of the structure-only pipeline
//! (ordering, symbolic factorization, blocking, task DAG, placement,
//! value scatter map) frozen into one immutable, shareable object.
//!
//! A plan depends **only on the sparsity pattern** of `A` (plus the solve
//! options) — never on its values. Building one runs the expensive
//! analysis the paper prices in §5.4 exactly once; afterwards any number
//! of numeric-only re-factorizations replay the plan's DAG over new
//! values at zero symbolic cost.

use crate::blocking::{
    self, irregular_blocking, regular_blocking, BalanceReport, BlockedMatrix, Blocking,
    DiagFeature,
};
use crate::coordinator::{par_chunks, simulate, Executor, Placement, SimReport, TaskDag};
use crate::numeric::factor::{BlockOp, FactorError, NumericMatrix};
use crate::numeric::Precision;
use crate::ordering::{order, Permutation};
use crate::solver::{BlockingPolicy, SolveOptions};
use crate::sparse::Csc;
use crate::symbolic;
use crate::util::Stopwatch;
use std::sync::Arc;

/// Structure-phase statistics and timings of one plan build.
#[derive(Clone, Debug)]
pub struct PlanReport {
    pub n: usize,
    pub nnz_a: usize,
    pub nnz_ldu: usize,
    pub flops: f64,
    pub reorder_seconds: f64,
    pub symbolic_seconds: f64,
    /// Blocking + partitioning + placement + DAG construction — the same
    /// lap the pre-session `Solver::factorize` reported, so the §5.4
    /// preprocessing-cost tables stay comparable across versions.
    pub preprocess_seconds: f64,
    /// Session-only extras a one-shot solve never paid before: scatter-map
    /// construction + cost-model simulation. Kept out of
    /// `preprocess_seconds` to avoid skewing the paper-reproduction
    /// metrics.
    pub plan_extra_seconds: f64,
}

impl PlanReport {
    /// Total structure-only seconds a plan-cache hit saves.
    pub fn total_seconds(&self) -> f64 {
        self.reorder_seconds
            + self.symbolic_seconds
            + self.preprocess_seconds
            + self.plan_extra_seconds
    }
}

/// Immutable preprocessing product for one sparsity pattern.
///
/// Shareable via `Arc`: many [`crate::session::SolverSession`]s (e.g. one
/// per concurrent request on a serving path) can factorize different
/// value sets against the same plan simultaneously.
pub struct FactorPlan {
    opts: SolveOptions,
    perm: Permutation,
    /// Precomputed `perm.inverse()` — solves apply it on every call, so
    /// the session hot path must not re-derive it per solve.
    iperm: Permutation,
    fingerprint: u64,
    /// Blocked L+U fill pattern (block values hold the *first* matrix's
    /// numbers — sessions treat them purely as pattern + storage layout).
    pub structure: Arc<BlockedMatrix>,
    /// Task DAG over `structure` under the plan's kernel policy/placement.
    pub dag: TaskDag,
    /// Block-level nnz balance of the blocking.
    pub balance: BalanceReport,
    /// Modeled multi-device schedule of `dag` (A100 cost model).
    pub sim: SimReport,
    /// For A-nonzero `k` (CSC order): destination block id and offset
    /// within that block's value array after permutation.
    scatter_block: Vec<u32>,
    scatter_off: Vec<u32>,
    /// Reachability index for incremental re-factorization (`None` for
    /// one-shot plans, which never re-factorize partially).
    reach: Option<ReachIndex>,
    /// Build-time stats and timings.
    pub report: PlanReport,
}

/// Precomputed per-plan structures for incremental re-factorization:
/// which DAG tasks write each block, which blocks are read downstream of
/// each block, and which A-nonzeros scatter into each block. Built once
/// per plan so the warm `refactorize_partial` path only walks
/// preallocated adjacency — the dirty-closure BFS allocates nothing.
pub(crate) struct ReachIndex {
    /// Block idx → ids of DAG tasks whose target is that block.
    tasks_by_target: Vec<Vec<u32>>,
    /// Block idx → downstream block idxs (deduped union of task
    /// source-block → target-block edges). A value change in block `b`
    /// can only alter factor values in blocks forward-reachable from `b`
    /// over these edges.
    block_out: Vec<Vec<u32>>,
    /// CSR grouping of the scatter map by destination block:
    /// `scatter_a[scatter_ptr[b]..scatter_ptr[b+1]]` are the A-nonzero
    /// indices landing in block `b` — the inverse of `scatter_block`,
    /// used to re-initialize exactly the affected blocks.
    scatter_ptr: Vec<u32>,
    scatter_a: Vec<u32>,
}

impl ReachIndex {
    /// Build the index, resolving each task's target/source blocks on
    /// `exec` when one is given. The per-task lookups are pure functions
    /// of the (immutable) DAG and blocked structure, so they run
    /// chunk-parallel into per-task slots; the grouping passes that
    /// follow are cheap sequential reductions in task order — the result
    /// is bit-identical at every worker count. The only possible `Err`
    /// is [`FactorError::TaskPanic`] out of the pool.
    fn build_on(
        bm: &BlockedMatrix,
        dag: &TaskDag,
        scatter_block: &[u32],
        exec: Option<&Executor>,
    ) -> Result<Self, FactorError> {
        let nblocks = bm.blocks.len();
        // per task: target block + up to two source blocks (block-
        // granular read → write edges of the op)
        let mut touches: Vec<(u32, Option<u32>, Option<u32>)> =
            vec![(0, None, None); dag.tasks.len()];
        par_chunks(exec, &mut touches, &|start, chunk| {
            for (off, slot) in chunk.iter_mut().enumerate() {
                let task = &dag.tasks[start + off];
                let (ti, tj) = task.op.target();
                let tgt = bm.block_id(ti, tj).expect("task target block exists");
                let edge = |bi: usize, bj: usize| {
                    let s = bm.block_id(bi, bj).expect("task source block exists");
                    (s != tgt).then_some(s)
                };
                let (s1, s2) = match task.op {
                    BlockOp::Getrf { .. } => (None, None),
                    BlockOp::Gessm { k, .. } | BlockOp::Tstrf { k, .. } => (edge(k, k), None),
                    BlockOp::Ssssm { i, j, k } => (edge(i, k), edge(k, j)),
                };
                *slot = (tgt, s1, s2);
            }
        })?;
        let mut tasks_by_target: Vec<Vec<u32>> = vec![Vec::new(); nblocks];
        let mut block_out: Vec<Vec<u32>> = vec![Vec::new(); nblocks];
        for (tid, &(tgt, s1, s2)) in touches.iter().enumerate() {
            tasks_by_target[tgt as usize].push(tid as u32);
            for s in [s1, s2].into_iter().flatten() {
                block_out[s as usize].push(tgt);
            }
        }
        par_chunks(exec, &mut block_out, &|_, chunk| {
            for outs in chunk.iter_mut() {
                outs.sort_unstable();
                outs.dedup();
            }
        })?;
        // group the scatter map by destination block (counting sort)
        let mut scatter_ptr = vec![0u32; nblocks + 1];
        for &b in scatter_block {
            scatter_ptr[b as usize + 1] += 1;
        }
        for b in 0..nblocks {
            scatter_ptr[b + 1] += scatter_ptr[b];
        }
        let mut next = scatter_ptr.clone();
        let mut scatter_a = vec![0u32; scatter_block.len()];
        for (k, &b) in scatter_block.iter().enumerate() {
            let p = next[b as usize] as usize;
            next[b as usize] += 1;
            scatter_a[p] = k as u32;
        }
        Ok(Self { tasks_by_target, block_out, scatter_ptr, scatter_a })
    }

    /// DAG task ids writing block `b`.
    pub(crate) fn tasks_of(&self, b: u32) -> &[u32] {
        &self.tasks_by_target[b as usize]
    }

    /// Blocks that read block `b` (direct downstream neighbors).
    pub(crate) fn downstream(&self, b: u32) -> &[u32] {
        &self.block_out[b as usize]
    }

    /// A-nonzero indices scattering into block `b`.
    pub(crate) fn a_indices_of(&self, b: u32) -> &[u32] {
        let (lo, hi) = (self.scatter_ptr[b as usize], self.scatter_ptr[b as usize + 1]);
        &self.scatter_a[lo as usize..hi as usize]
    }
}

/// Raw parts of a persisted session plan — everything a plan cannot (or
/// should not) cheaply reconstruct at load time. Produced by the binary
/// reader in [`crate::serve::persist`], consumed by
/// [`FactorPlan::from_parts`].
pub(crate) struct PlanParts {
    pub opts: SolveOptions,
    pub perm: Permutation,
    pub fingerprint: u64,
    /// The filled L+U pattern. Values are ignored (loaded plans carry
    /// zeros in their blocked structure); sessions scatter real values
    /// on every refactorize anyway.
    pub ldu: Csc,
    pub blocking: Blocking,
    pub scatter_block: Vec<u32>,
    pub scatter_off: Vec<u32>,
    pub flops: f64,
}

impl FactorPlan {
    /// Run the structure-only pipeline on `a` under `opts`, including
    /// the value scatter map that powers re-factorization.
    ///
    /// Returns [`FactorError::StructurallySingular`] when `a`'s pattern
    /// lacks a diagonal entry — client input a serving path must reject,
    /// not panic on. [`Self::build_on`] is the same pipeline with its
    /// parallelizable passes run on an [`Executor`].
    pub fn build(a: &Csc, opts: &SolveOptions) -> Result<Self, FactorError> {
        Self::build_inner(a, opts, true, None)
    }

    /// As [`Self::build`], running the parallelizable passes (symbolic
    /// reach sets, per-stripe block assembly, scatter-map and
    /// reachability-index construction) on `exec`. The result is
    /// bit-identical to the sequential [`Self::build`] — same ordering,
    /// same block boundaries, same task DAG, same scatter map — at every
    /// worker count; only the build latency changes.
    pub fn build_on(a: &Csc, opts: &SolveOptions, exec: &Executor) -> Result<Self, FactorError> {
        Self::build_inner(a, opts, true, Some(exec))
    }

    /// Plan without the scatter map — for the one-shot
    /// [`crate::solver::Solver::factorize`] path, which seeds numeric
    /// storage directly from the blocked pattern and never re-scatters.
    /// Such a plan cannot back a session (`scatter_values` rejects it).
    pub(crate) fn build_for_oneshot(
        a: &Csc,
        opts: &SolveOptions,
        exec: Option<&Executor>,
    ) -> Result<Self, FactorError> {
        Self::build_inner(a, opts, false, exec)
    }

    fn build_inner(
        a: &Csc,
        opts: &SolveOptions,
        with_scatter: bool,
        exec: Option<&Executor>,
    ) -> Result<Self, FactorError> {
        assert_eq!(a.n_rows(), a.n_cols(), "square systems only");
        // reject structurally singular patterns up front: LU without
        // numerical pivoting needs every diagonal entry structurally
        // present. Scanning `a` itself (rather than letting the
        // partitioner trip over the permuted pattern) reports the
        // client's own row index — a symmetric permutation maps
        // diagonals to diagonals, so this scan catches exactly the
        // patterns the downstream diagonal checks would.
        for j in 0..a.n_cols() {
            if a.col_rows(j).binary_search(&j).is_err() {
                return Err(FactorError::StructurallySingular { row: j });
            }
        }
        let mut sw = Stopwatch::new();

        // phase 1: reorder (sequential — the ordering heuristics are
        // inherently order-dependent and cheap relative to the rest)
        let perm = order(a, opts.ordering);
        let pa = a.permute_sym(perm.as_slice());
        let reorder_seconds = sw.lap("reorder");

        // phase 2: symbolic — cannot fail on its own input: the pattern
        // was analyzed from `pa` itself, so pattern(pa) ⊆ symbolic
        // pattern by construction (the Err arm of `ldu_pattern` exists
        // for mismatched-matrix callers)
        let sym = symbolic::analyze_on(&pa, exec)?;
        let ldu = sym
            .ldu_pattern(&pa)
            .expect("pattern(A) is contained in its own symbolic pattern");
        let symbolic_seconds = sw.lap("symbolic");

        // phase 3a: blocking + DAG (the §5.4 preprocessing lap, same
        // boundary as the pre-session Solver so tables stay comparable)
        let blocking = blocking_for(opts, &ldu);
        let structure = Arc::new(BlockedMatrix::try_build_on(&ldu, blocking, exec)?);
        let balance = BalanceReport::of(&structure);
        let placement = Placement::square(opts.workers);
        let dag = TaskDag::build(&structure, &opts.kernels, placement, &opts.model);
        let preprocess_seconds = sw.lap("preprocess");

        // session-only extras: modeled schedule + value scatter map +
        // incremental-refactorization reachability index
        let sim = simulate(&dag, opts.workers, &opts.model);
        let (scatter_block, scatter_off) = if with_scatter {
            build_scatter_on(a, &perm, &structure, exec)?
        } else {
            (Vec::new(), Vec::new())
        };
        let reach = if with_scatter {
            Some(ReachIndex::build_on(&structure, &dag, &scatter_block, exec)?)
        } else {
            None
        };
        let plan_extra_seconds = sw.lap("plan_extra");

        let report = PlanReport {
            n: a.n_cols(),
            nnz_a: a.nnz(),
            nnz_ldu: ldu.nnz(),
            flops: sym.flops(),
            reorder_seconds,
            symbolic_seconds,
            preprocess_seconds,
            plan_extra_seconds,
        };
        Ok(Self {
            opts: opts.clone(),
            iperm: perm.inverse(),
            perm,
            // one-shot plans skip the O(nnz) hash too: nothing ever
            // compares their fingerprint
            fingerprint: if with_scatter { a.pattern_fingerprint() } else { 0 },
            structure,
            dag,
            balance,
            sim,
            scatter_block,
            scatter_off,
            reach,
            report,
        })
    }

    /// Reassemble a session plan from persisted parts (the serde hook of
    /// [`crate::serve::persist`]). The blocked structure, task DAG,
    /// modeled schedule and reachability index are rebuilt — cheap and
    /// deterministic given the persisted pattern + blocking — while the
    /// expensive structure phases (ordering, symbolic analysis) are
    /// **not** re-run. A loaded plan's report shows zero
    /// reorder/symbolic seconds; preprocess/plan_extra record the
    /// rebuild cost paid at load.
    ///
    /// Scatter maps are bounds-checked against the rebuilt structure, so
    /// a checksum-valid but internally inconsistent file comes back as
    /// `Err` instead of panicking later inside the reachability index or
    /// a block rescatter.
    pub(crate) fn from_parts(parts: PlanParts) -> Result<Self, String> {
        let PlanParts {
            opts,
            perm,
            fingerprint,
            ldu,
            blocking,
            scatter_block,
            scatter_off,
            flops,
        } = parts;
        let mut sw = Stopwatch::new();
        let nnz_ldu = ldu.nnz();
        let structure = Arc::new(
            BlockedMatrix::try_build_on(&ldu, blocking, None)
                .map_err(|e| format!("persisted pattern rejected: {e}"))?,
        );
        let nblocks = structure.blocks.len() as u32;
        for (&b, &off) in scatter_block.iter().zip(&scatter_off) {
            if b >= nblocks {
                return Err(format!("scatter block id {b} out of range ({nblocks} blocks)"));
            }
            let block_nnz = structure.blocks[b as usize].nnz();
            if off as usize >= block_nnz {
                return Err(format!(
                    "scatter offset {off} out of range for block {b} (nnz {block_nnz})"
                ));
            }
        }
        let balance = BalanceReport::of(&structure);
        let placement = Placement::square(opts.workers);
        let dag = TaskDag::build(&structure, &opts.kernels, placement, &opts.model);
        let preprocess_seconds = sw.lap("preprocess");
        let sim = simulate(&dag, opts.workers, &opts.model);
        let reach = Some(
            ReachIndex::build_on(&structure, &dag, &scatter_block, None)
                .map_err(|e| e.to_string())?,
        );
        let plan_extra_seconds = sw.lap("plan_extra");
        let report = PlanReport {
            n: perm.len(),
            nnz_a: scatter_block.len(),
            nnz_ldu,
            flops,
            reorder_seconds: 0.0,
            symbolic_seconds: 0.0,
            preprocess_seconds,
            plan_extra_seconds,
        };
        Ok(Self {
            opts,
            iperm: perm.inverse(),
            perm,
            fingerprint,
            structure,
            dag,
            balance,
            sim,
            scatter_block,
            scatter_off,
            reach,
            report,
        })
    }

    /// The precomputed `(block, offset)` scatter maps: for A-nonzero `k`
    /// (CSC order), the destination block id and offset within that
    /// block's value array. Used by the persistence layer and by the
    /// differential tests asserting parallel ≡ sequential builds.
    pub fn scatter_maps(&self) -> (&[u32], &[u32]) {
        (&self.scatter_block, &self.scatter_off)
    }

    /// Options the plan was built under.
    pub fn options(&self) -> &SolveOptions {
        &self.opts
    }

    /// Fill-reducing permutation (old → new).
    pub fn permutation(&self) -> &Permutation {
        &self.perm
    }

    /// Inverse of [`Self::permutation`] (new → old), precomputed.
    pub fn inverse_permutation(&self) -> &Permutation {
        &self.iperm
    }

    /// Pattern fingerprint of the analyzed matrix.
    pub fn fingerprint(&self) -> u64 {
        self.fingerprint
    }

    pub fn n(&self) -> usize {
        self.report.n
    }

    /// Nonzero count a value vector must match.
    pub fn nnz_a(&self) -> usize {
        self.report.nnz_a
    }

    /// Does `a` have the pattern this plan was built for?
    pub fn matches(&self, a: &Csc) -> bool {
        a.n_rows() == self.report.n
            && a.n_cols() == self.report.n
            && a.nnz() == self.report.nnz_a
            && a.pattern_fingerprint() == self.fingerprint
    }

    /// Scatter a fresh value vector (CSC order of the original `A`) into
    /// preallocated blocked storage: zero fill, then one store per
    /// nonzero through the precomputed map. No allocation, no symbolic
    /// work, no index search.
    ///
    /// Precision-aware: when `nm` has been demoted to
    /// [`Precision::Mixed`], values are rounded to `f32` and scattered
    /// into the single-precision shadow storage instead — that is the
    /// storage the next factorization pass reads and overwrites.
    pub fn scatter_values(&self, values: &[f64], nm: &mut NumericMatrix) {
        assert_eq!(
            values.len(),
            self.scatter_block.len(),
            "value vector length must equal nnz(A) of the planned pattern \
             (a plan built for one-shot use has no scatter map)"
        );
        nm.zero_values();
        match nm.precision {
            Precision::Full => {
                for ((&b, &off), &v) in
                    self.scatter_block.iter().zip(&self.scatter_off).zip(values)
                {
                    nm.values_mut(b)[off as usize] = v;
                }
            }
            Precision::Mixed => {
                for ((&b, &off), &v) in
                    self.scatter_block.iter().zip(&self.scatter_off).zip(values)
                {
                    nm.values32_mut(b)[off as usize] = v as f32;
                }
            }
        }
    }

    /// Destination block of A-nonzero `k` under the scatter map.
    pub(crate) fn scatter_block_of(&self, k: usize) -> u32 {
        self.scatter_block[k]
    }

    /// Reachability index for incremental re-factorization.
    pub(crate) fn reach(&self) -> &ReachIndex {
        self.reach.as_ref().expect(
            "incremental re-factorization needs a session plan \
             (one-shot plans carry no reachability index)",
        )
    }

    /// Re-initialize exactly one block of `nm` to its pre-factorization
    /// state: zero the stored pattern, then scatter the block's share of
    /// `values` (the full A value vector, CSC order) back in. This is the
    /// block-granular counterpart of [`Self::scatter_values`], used to
    /// reset only the blocks an incremental re-factorization re-executes.
    pub(crate) fn rescatter_block(&self, b: u32, values: &[f64], nm: &mut NumericMatrix) {
        let reach = self.reach();
        nm.zero_block(b);
        match nm.precision {
            Precision::Full => {
                let vals = nm.values_mut(b);
                for &k in reach.a_indices_of(b) {
                    vals[self.scatter_off[k as usize] as usize] = values[k as usize];
                }
            }
            Precision::Mixed => {
                let vals = nm.values32_mut(b);
                for &k in reach.a_indices_of(b) {
                    vals[self.scatter_off[k as usize] as usize] = values[k as usize] as f32;
                }
            }
        }
    }

    /// Original-matrix coordinates of every A-nonzero, in the CSC order
    /// of the value vectors clients hand to
    /// [`crate::session::SolverSession::refactorize`]: entry `k` of the
    /// result is the `(row, col)` of value `k` in the **unpermuted** `A`.
    ///
    /// Recovered purely from the scatter map and the blocked structure —
    /// the plan never stores `A` itself. Used by iterative refinement to
    /// compute f64 residuals `b − A·x` from the session's retained value
    /// vector without the client re-supplying the pattern. O(nnz·log w)
    /// with `w` the block width; call once and cache.
    pub fn value_coords(&self) -> Vec<(u32, u32)> {
        let positions = self.structure.blocking.positions();
        let inv = self.iperm.as_slice();
        let mut out = Vec::with_capacity(self.scatter_block.len());
        for (&b, &off) in self.scatter_block.iter().zip(&self.scatter_off) {
            let blk = self.structure.block(b);
            let off = off as usize;
            // local column: last col whose slice starts at or before `off`
            let c = blk.col_ptr.partition_point(|&p| p as usize <= off) - 1;
            let r = blk.row_idx[off] as usize;
            // permuted coordinates, then back through new → old
            let rp = positions[blk.bi as usize] + r;
            let cp = positions[blk.bj as usize] + c;
            out.push((inv[rp] as u32, inv[cp] as u32));
        }
        out
    }
}

/// Resolve the blocking policy against the filled pattern (previously a
/// private `Solver` method; plans are now the only place blockings are
/// chosen).
pub(crate) fn blocking_for(opts: &SolveOptions, ldu: &Csc) -> Blocking {
    let n = ldu.n_cols();
    match &opts.blocking {
        BlockingPolicy::Regular(size) => regular_blocking(n, (*size).min(n)),
        BlockingPolicy::PanguSelect => {
            let options = blocking::selection::scaled_options(n);
            let size = blocking::selection::select_from(n, ldu.nnz(), &options);
            regular_blocking(n, size.min(n))
        }
        BlockingPolicy::Irregular => {
            let curve = DiagFeature::from_csc(ldu).curve();
            irregular_blocking(&curve, &opts.irregular)
        }
    }
}

/// Map every A-nonzero to its (block, value-offset) destination once; the
/// numeric path then re-scatters values with plain stores.
///
/// The per-entry lookups (permutation, block-id hash probe, binary search
/// in the block column) run chunk-parallel on `exec` when one is given:
/// entry `k`'s destination is a pure function of `k`, the matrix and the
/// immutable blocked structure, so each chunk fills its own disjoint
/// window of the output and the map is bit-identical at every worker
/// count. The cheap entry enumeration stays sequential. The only
/// possible `Err` is [`FactorError::TaskPanic`] out of the pool.
fn build_scatter_on(
    a: &Csc,
    perm: &Permutation,
    bm: &BlockedMatrix,
    exec: Option<&Executor>,
) -> Result<(Vec<u32>, Vec<u32>), FactorError> {
    let n = a.n_cols();
    let positions = bm.blocking.positions();
    let nb = bm.nb();
    // row → block-row map (same trick as BlockedMatrix::try_build_on)
    let mut row_block = vec![0u32; n];
    for bi in 0..nb {
        for r in positions[bi]..positions[bi + 1] {
            row_block[r] = bi as u32;
        }
    }
    let p = perm.as_slice();
    // enumerate (row, col) of every nonzero in CSC order — O(nnz), cheap
    let mut entries: Vec<(u32, u32)> = Vec::with_capacity(a.nnz());
    for j in 0..n {
        for &i in a.col_rows(j) {
            entries.push((i as u32, j as u32));
        }
    }
    let mut out: Vec<(u32, u32)> = vec![(0, 0); entries.len()];
    par_chunks(exec, &mut out, &|start, chunk| {
        for (off, slot) in chunk.iter_mut().enumerate() {
            let (i, j) = entries[start + off];
            let (i, j) = (i as usize, j as usize);
            let pj = p[j];
            let bj = row_block[pj] as usize;
            let c_local = pj - positions[bj];
            let pi = p[i];
            let bi = row_block[pi] as usize;
            let id = bm
                .block_id(bi, bj)
                .expect("A entry must fall inside the symbolic L+U pattern");
            let blk = bm.block(id);
            let r_local = (pi - positions[bi]) as u32;
            let t = blk
                .col_rows(c_local)
                .binary_search(&r_local)
                .expect("A entry missing from block pattern");
            *slot = (id, blk.col_ptr[c_local] + t as u32);
        }
    })?;
    let scatter_block = out.iter().map(|&(b, _)| b).collect();
    let scatter_off = out.iter().map(|&(_, o)| o).collect();
    Ok((scatter_block, scatter_off))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::gen;

    #[test]
    fn plan_matches_only_same_pattern() {
        let a = gen::grid2d_laplacian(8, 8);
        let plan = FactorPlan::build(&a, &SolveOptions::ours(1)).unwrap();
        assert!(plan.matches(&a));
        assert_eq!(plan.n(), 64);
        assert_eq!(plan.nnz_a(), a.nnz());
        // same pattern, new values — still matches
        let mut b = a.clone();
        for v in &mut b.values {
            *v += 0.25;
        }
        assert!(plan.matches(&b));
        // different pattern — rejected
        let c = gen::grid2d_laplacian(8, 9);
        assert!(!plan.matches(&c));
    }

    #[test]
    fn scatter_reproduces_blocked_values() {
        // scattering A's own values must reproduce exactly the blocked
        // values the partitioner stored at build time
        let a = gen::circuit_bbd(gen::CircuitParams { n: 300, ..Default::default() });
        let plan = FactorPlan::build(&a, &SolveOptions::ours(1)).unwrap();
        let mut nm = NumericMatrix::from_blocked(plan.structure.clone());
        // wreck the storage first so the test can't pass vacuously
        for i in 0..plan.structure.blocks.len() {
            nm.values_mut(i as u32).fill(f64::NAN);
        }
        plan.scatter_values(&a.values, &mut nm);
        for (idx, blk) in plan.structure.blocks.iter().enumerate() {
            let got = nm.block_values(idx as u32);
            assert_eq!(got, blk.values, "block {idx} values diverge");
        }
    }

    #[test]
    fn reach_index_partitions_scatter_and_targets() {
        let a = gen::circuit_bbd(gen::CircuitParams { n: 250, ..Default::default() });
        let plan = FactorPlan::build(&a, &SolveOptions::ours(1)).unwrap();
        let reach = plan.reach();
        let nblocks = plan.structure.blocks.len();
        // every A-nonzero appears in exactly one block's scatter group,
        // and the group agrees with the forward scatter map
        let mut seen = vec![false; a.nnz()];
        for b in 0..nblocks {
            for &k in reach.a_indices_of(b as u32) {
                assert!(!seen[k as usize], "A index {k} grouped twice");
                seen[k as usize] = true;
                assert_eq!(plan.scatter_block_of(k as usize), b as u32);
            }
        }
        assert!(seen.iter().all(|&s| s), "every A index grouped");
        // every DAG task appears under exactly one target block
        let mut task_seen = vec![false; plan.dag.tasks.len()];
        for b in 0..nblocks {
            for &t in reach.tasks_of(b as u32) {
                assert!(!task_seen[t as usize], "task {t} targeted twice");
                task_seen[t as usize] = true;
                let (ti, tj) = plan.dag.tasks[t as usize].op.target();
                assert_eq!(plan.structure.block_id(ti, tj), Some(b as u32));
            }
        }
        assert!(task_seen.iter().all(|&s| s), "every task has a target block");
    }

    #[test]
    fn last_diagonal_block_has_no_downstream() {
        let a = gen::grid2d_laplacian(9, 9);
        let plan = FactorPlan::build(&a, &SolveOptions::ours(1)).unwrap();
        let nb = plan.structure.nb();
        let last = plan.structure.block_id(nb - 1, nb - 1).unwrap();
        assert!(
            plan.reach().downstream(last).is_empty(),
            "the trailing diagonal block is the DAG sink"
        );
    }

    #[test]
    fn rescatter_blocks_reproduces_full_scatter() {
        let a = gen::circuit_bbd(gen::CircuitParams { n: 200, ..Default::default() });
        let plan = FactorPlan::build(&a, &SolveOptions::ours(1)).unwrap();
        let mut full = NumericMatrix::from_blocked_zeroed(plan.structure.clone());
        plan.scatter_values(&a.values, &mut full);
        let mut blockwise = NumericMatrix::from_blocked_zeroed(plan.structure.clone());
        for v in 0..plan.structure.blocks.len() {
            blockwise.values_mut(v as u32).fill(f64::NAN); // wreck first
        }
        for b in 0..plan.structure.blocks.len() {
            plan.rescatter_block(b as u32, &a.values, &mut blockwise);
        }
        for id in 0..plan.structure.blocks.len() {
            assert_eq!(
                full.block_values(id as u32),
                blockwise.block_values(id as u32),
                "block {id}"
            );
        }
    }

    #[test]
    fn plan_report_totals() {
        let a = gen::grid2d_laplacian(10, 10);
        let plan = FactorPlan::build(&a, &SolveOptions::ours(2)).unwrap();
        let r = &plan.report;
        assert!(r.total_seconds() >= r.preprocess_seconds);
        assert_eq!(r.nnz_a, a.nnz());
        assert!(r.nnz_ldu >= r.nnz_a);
        assert!(r.flops > 0.0);
        assert!(!plan.dag.tasks.is_empty());
        assert_eq!(plan.sim.utilization.len(), 2);
    }

    #[test]
    fn parallel_build_matches_sequential_scatter_and_reach() {
        // the differential harness (tests/plan_build.rs) compares the
        // public surface; the scatter map and reachability index are
        // private, so their bitwise equality is asserted here
        let a = gen::circuit_bbd(gen::CircuitParams { n: 400, ..Default::default() });
        let opts = SolveOptions::ours(4);
        let seq = FactorPlan::build(&a, &opts).unwrap();
        for workers in [2u32, 8] {
            let exec = crate::coordinator::Executor::shared(workers);
            let par = FactorPlan::build_on(&a, &opts, &exec).unwrap();
            assert_eq!(par.scatter_maps().0, seq.scatter_maps().0, "workers={workers}");
            assert_eq!(par.scatter_maps().1, seq.scatter_maps().1, "workers={workers}");
            let (sr, pr) = (seq.reach(), par.reach());
            assert_eq!(pr.tasks_by_target, sr.tasks_by_target, "workers={workers}");
            assert_eq!(pr.block_out, sr.block_out, "workers={workers}");
            assert_eq!(pr.scatter_ptr, sr.scatter_ptr, "workers={workers}");
            assert_eq!(pr.scatter_a, sr.scatter_a, "workers={workers}");
        }
    }

    #[test]
    fn value_coords_recover_the_original_matrix() {
        // SpMV assembled purely from (coords, values) must equal the
        // sparse product — i.e. the coordinates recovered from the
        // scatter map round-trip through permutation and blocking
        let a = gen::circuit_bbd(gen::CircuitParams { n: 260, ..Default::default() });
        let plan = FactorPlan::build(&a, &SolveOptions::ours(1)).unwrap();
        let coords = plan.value_coords();
        assert_eq!(coords.len(), a.nnz());
        let n = a.n_cols();
        let x: Vec<f64> = (0..n).map(|i| 0.5 + (i % 7) as f64).collect();
        let mut y = vec![0.0; n];
        for (&(r, c), &v) in coords.iter().zip(&a.values) {
            y[r as usize] += v * x[c as usize];
        }
        let want = a.mul_vec(&x);
        for i in 0..n {
            assert!(
                (y[i] - want[i]).abs() <= 1e-12 * want[i].abs().max(1.0),
                "row {i}: {} vs {}",
                y[i],
                want[i]
            );
        }
    }

    #[test]
    fn mixed_scatter_targets_f32_storage() {
        use crate::numeric::Precision;
        let a = gen::grid2d_laplacian(9, 9);
        let plan = FactorPlan::build(&a, &SolveOptions::ours(1)).unwrap();
        let mut nm = NumericMatrix::from_blocked_zeroed(plan.structure.clone());
        nm.set_precision(Precision::Mixed);
        plan.scatter_values(&a.values, &mut nm);
        // the f32 shadow holds the demoted values at the same offsets the
        // f64 path would use
        let mut full = NumericMatrix::from_blocked_zeroed(plan.structure.clone());
        full.set_precision(Precision::Full);
        plan.scatter_values(&a.values, &mut full);
        for id in 0..plan.structure.blocks.len() {
            let lo = crate::numeric::factor::read_vals(&nm.values32()[id]);
            let hi = full.block_values(id as u32);
            assert_eq!(lo.len(), hi.len(), "block {id}");
            for (g, w) in lo.iter().zip(hi.iter()) {
                assert_eq!(*g, *w as f32, "block {id}");
            }
        }
        // block-granular rescatter produces the same f32 storage
        let mut bw = NumericMatrix::from_blocked_zeroed(plan.structure.clone());
        bw.set_precision(Precision::Mixed);
        for b in 0..plan.structure.blocks.len() {
            plan.rescatter_block(b as u32, &a.values, &mut bw);
        }
        for id in 0..plan.structure.blocks.len() {
            assert_eq!(
                *crate::numeric::factor::read_vals(&bw.values32()[id]),
                *crate::numeric::factor::read_vals(&nm.values32()[id]),
                "block {id}"
            );
        }
    }

    #[test]
    fn structurally_singular_input_is_an_error_not_a_panic() {
        // column 2 is populated but has no diagonal entry
        let mut coo = crate::sparse::Coo::new(5, 5);
        for i in 0..5 {
            if i != 2 {
                coo.push(i, i, 4.0);
            }
        }
        coo.push(0, 2, 1.0);
        coo.push(2, 3, 1.0);
        let a = coo.to_csc();
        let err = FactorPlan::build(&a, &SolveOptions::ours(1)).unwrap_err();
        assert_eq!(err, FactorError::StructurallySingular { row: 2 });
        // the parallel path reports the identical error
        let exec = crate::coordinator::Executor::shared(2);
        let err = FactorPlan::build_on(&a, &SolveOptions::ours(2), &exec).unwrap_err();
        assert_eq!(err, FactorError::StructurallySingular { row: 2 });
    }
}
