//! The [`FactorPlan`]: every product of the structure-only pipeline
//! (ordering, symbolic factorization, blocking, task DAG, placement,
//! value scatter map) frozen into one immutable, shareable object.
//!
//! A plan depends **only on the sparsity pattern** of `A` (plus the solve
//! options) — never on its values. Building one runs the expensive
//! analysis the paper prices in §5.4 exactly once; afterwards any number
//! of numeric-only re-factorizations replay the plan's DAG over new
//! values at zero symbolic cost.

use crate::blocking::{
    self, irregular_blocking, regular_blocking, BalanceReport, BlockedMatrix, Blocking,
    DiagFeature,
};
use crate::coordinator::{simulate, Placement, SimReport, TaskDag};
use crate::numeric::factor::NumericMatrix;
use crate::ordering::{order, Permutation};
use crate::solver::{BlockingPolicy, SolveOptions};
use crate::sparse::Csc;
use crate::symbolic;
use crate::util::Stopwatch;
use std::sync::Arc;

/// Structure-phase statistics and timings of one plan build.
#[derive(Clone, Debug)]
pub struct PlanReport {
    pub n: usize,
    pub nnz_a: usize,
    pub nnz_ldu: usize,
    pub flops: f64,
    pub reorder_seconds: f64,
    pub symbolic_seconds: f64,
    /// Blocking + partitioning + placement + DAG construction — the same
    /// lap the pre-session `Solver::factorize` reported, so the §5.4
    /// preprocessing-cost tables stay comparable across versions.
    pub preprocess_seconds: f64,
    /// Session-only extras a one-shot solve never paid before: scatter-map
    /// construction + cost-model simulation. Kept out of
    /// `preprocess_seconds` to avoid skewing the paper-reproduction
    /// metrics.
    pub plan_extra_seconds: f64,
}

impl PlanReport {
    /// Total structure-only seconds a plan-cache hit saves.
    pub fn total_seconds(&self) -> f64 {
        self.reorder_seconds
            + self.symbolic_seconds
            + self.preprocess_seconds
            + self.plan_extra_seconds
    }
}

/// Immutable preprocessing product for one sparsity pattern.
///
/// Shareable via `Arc`: many [`crate::session::SolverSession`]s (e.g. one
/// per concurrent request on a serving path) can factorize different
/// value sets against the same plan simultaneously.
pub struct FactorPlan {
    opts: SolveOptions,
    perm: Permutation,
    /// Precomputed `perm.inverse()` — solves apply it on every call, so
    /// the session hot path must not re-derive it per solve.
    iperm: Permutation,
    fingerprint: u64,
    /// Blocked L+U fill pattern (block values hold the *first* matrix's
    /// numbers — sessions treat them purely as pattern + storage layout).
    pub structure: Arc<BlockedMatrix>,
    /// Task DAG over `structure` under the plan's kernel policy/placement.
    pub dag: TaskDag,
    /// Block-level nnz balance of the blocking.
    pub balance: BalanceReport,
    /// Modeled multi-device schedule of `dag` (A100 cost model).
    pub sim: SimReport,
    /// For A-nonzero `k` (CSC order): destination block id and offset
    /// within that block's value array after permutation.
    scatter_block: Vec<u32>,
    scatter_off: Vec<u32>,
    /// Build-time stats and timings.
    pub report: PlanReport,
}

impl FactorPlan {
    /// Run the structure-only pipeline on `a` under `opts`, including
    /// the value scatter map that powers re-factorization.
    pub fn build(a: &Csc, opts: &SolveOptions) -> Self {
        Self::build_inner(a, opts, true)
    }

    /// Plan without the scatter map — for the one-shot
    /// [`crate::solver::Solver::factorize`] path, which seeds numeric
    /// storage directly from the blocked pattern and never re-scatters.
    /// Such a plan cannot back a session (`scatter_values` rejects it).
    pub(crate) fn build_for_oneshot(a: &Csc, opts: &SolveOptions) -> Self {
        Self::build_inner(a, opts, false)
    }

    fn build_inner(a: &Csc, opts: &SolveOptions, with_scatter: bool) -> Self {
        assert_eq!(a.n_rows(), a.n_cols(), "square systems only");
        let mut sw = Stopwatch::new();

        // phase 1: reorder
        let perm = order(a, opts.ordering);
        let pa = a.permute_sym(perm.as_slice());
        let reorder_seconds = sw.lap("reorder");

        // phase 2: symbolic
        let sym = symbolic::analyze(&pa);
        let ldu = sym.ldu_pattern(&pa);
        let symbolic_seconds = sw.lap("symbolic");

        // phase 3a: blocking + DAG (the §5.4 preprocessing lap, same
        // boundary as the pre-session Solver so tables stay comparable)
        let blocking = blocking_for(opts, &ldu);
        let structure = Arc::new(BlockedMatrix::build(&ldu, blocking));
        let balance = BalanceReport::of(&structure);
        let placement = Placement::square(opts.workers);
        let dag = TaskDag::build(&structure, &opts.kernels, placement, &opts.model);
        let preprocess_seconds = sw.lap("preprocess");

        // session-only extras: modeled schedule + value scatter map
        let sim = simulate(&dag, opts.workers, &opts.model);
        let (scatter_block, scatter_off) = if with_scatter {
            build_scatter(a, &perm, &structure)
        } else {
            (Vec::new(), Vec::new())
        };
        let plan_extra_seconds = sw.lap("plan_extra");

        let report = PlanReport {
            n: a.n_cols(),
            nnz_a: a.nnz(),
            nnz_ldu: ldu.nnz(),
            flops: sym.flops(),
            reorder_seconds,
            symbolic_seconds,
            preprocess_seconds,
            plan_extra_seconds,
        };
        Self {
            opts: opts.clone(),
            iperm: perm.inverse(),
            perm,
            // one-shot plans skip the O(nnz) hash too: nothing ever
            // compares their fingerprint
            fingerprint: if with_scatter { a.pattern_fingerprint() } else { 0 },
            structure,
            dag,
            balance,
            sim,
            scatter_block,
            scatter_off,
            report,
        }
    }

    /// Options the plan was built under.
    pub fn options(&self) -> &SolveOptions {
        &self.opts
    }

    /// Fill-reducing permutation (old → new).
    pub fn permutation(&self) -> &Permutation {
        &self.perm
    }

    /// Inverse of [`Self::permutation`] (new → old), precomputed.
    pub fn inverse_permutation(&self) -> &Permutation {
        &self.iperm
    }

    /// Pattern fingerprint of the analyzed matrix.
    pub fn fingerprint(&self) -> u64 {
        self.fingerprint
    }

    pub fn n(&self) -> usize {
        self.report.n
    }

    /// Nonzero count a value vector must match.
    pub fn nnz_a(&self) -> usize {
        self.report.nnz_a
    }

    /// Does `a` have the pattern this plan was built for?
    pub fn matches(&self, a: &Csc) -> bool {
        a.n_rows() == self.report.n
            && a.n_cols() == self.report.n
            && a.nnz() == self.report.nnz_a
            && a.pattern_fingerprint() == self.fingerprint
    }

    /// Scatter a fresh value vector (CSC order of the original `A`) into
    /// preallocated blocked storage: zero fill, then one store per
    /// nonzero through the precomputed map. No allocation, no symbolic
    /// work, no index search.
    pub fn scatter_values(&self, values: &[f64], nm: &mut NumericMatrix) {
        assert_eq!(
            values.len(),
            self.scatter_block.len(),
            "value vector length must equal nnz(A) of the planned pattern \
             (a plan built for one-shot use has no scatter map)"
        );
        nm.zero_values();
        for ((&b, &off), &v) in self.scatter_block.iter().zip(&self.scatter_off).zip(values) {
            nm.values_mut(b)[off as usize] = v;
        }
    }
}

/// Resolve the blocking policy against the filled pattern (previously a
/// private `Solver` method; plans are now the only place blockings are
/// chosen).
pub(crate) fn blocking_for(opts: &SolveOptions, ldu: &Csc) -> Blocking {
    let n = ldu.n_cols();
    match &opts.blocking {
        BlockingPolicy::Regular(size) => regular_blocking(n, (*size).min(n)),
        BlockingPolicy::PanguSelect => {
            let options = blocking::selection::scaled_options(n);
            let size = blocking::selection::select_from(n, ldu.nnz(), &options);
            regular_blocking(n, size.min(n))
        }
        BlockingPolicy::Irregular => {
            let curve = DiagFeature::from_csc(ldu).curve();
            irregular_blocking(&curve, &opts.irregular)
        }
    }
}

/// Map every A-nonzero to its (block, value-offset) destination once; the
/// numeric path then re-scatters values with plain stores.
fn build_scatter(a: &Csc, perm: &Permutation, bm: &BlockedMatrix) -> (Vec<u32>, Vec<u32>) {
    let n = a.n_cols();
    let positions = bm.blocking.positions();
    let nb = bm.nb();
    // row → block-row map (same trick as BlockedMatrix::build)
    let mut row_block = vec![0u32; n];
    for bi in 0..nb {
        for r in positions[bi]..positions[bi + 1] {
            row_block[r] = bi as u32;
        }
    }
    let p = perm.as_slice();
    let mut scatter_block = Vec::with_capacity(a.nnz());
    let mut scatter_off = Vec::with_capacity(a.nnz());
    for j in 0..n {
        let pj = p[j];
        let bj = row_block[pj] as usize;
        let c_local = pj - positions[bj];
        for &i in a.col_rows(j) {
            let pi = p[i];
            let bi = row_block[pi] as usize;
            let id = bm
                .block_id(bi, bj)
                .expect("A entry must fall inside the symbolic L+U pattern");
            let blk = bm.block(id);
            let r_local = (pi - positions[bi]) as u32;
            let t = blk
                .col_rows(c_local)
                .binary_search(&r_local)
                .expect("A entry missing from block pattern");
            scatter_block.push(id);
            scatter_off.push(blk.col_ptr[c_local] + t as u32);
        }
    }
    (scatter_block, scatter_off)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::gen;

    #[test]
    fn plan_matches_only_same_pattern() {
        let a = gen::grid2d_laplacian(8, 8);
        let plan = FactorPlan::build(&a, &SolveOptions::ours(1));
        assert!(plan.matches(&a));
        assert_eq!(plan.n(), 64);
        assert_eq!(plan.nnz_a(), a.nnz());
        // same pattern, new values — still matches
        let mut b = a.clone();
        for v in &mut b.values {
            *v += 0.25;
        }
        assert!(plan.matches(&b));
        // different pattern — rejected
        let c = gen::grid2d_laplacian(8, 9);
        assert!(!plan.matches(&c));
    }

    #[test]
    fn scatter_reproduces_blocked_values() {
        // scattering A's own values must reproduce exactly the blocked
        // values the partitioner stored at build time
        let a = gen::circuit_bbd(gen::CircuitParams { n: 300, ..Default::default() });
        let plan = FactorPlan::build(&a, &SolveOptions::ours(1));
        let mut nm = NumericMatrix::from_blocked(plan.structure.clone());
        // wreck the storage first so the test can't pass vacuously
        for i in 0..plan.structure.blocks.len() {
            nm.values_mut(i as u32).fill(f64::NAN);
        }
        plan.scatter_values(&a.values, &mut nm);
        for (idx, blk) in plan.structure.blocks.iter().enumerate() {
            let got = nm.block_values(idx as u32);
            assert_eq!(got, blk.values, "block {idx} values diverge");
        }
    }

    #[test]
    fn plan_report_totals() {
        let a = gen::grid2d_laplacian(10, 10);
        let plan = FactorPlan::build(&a, &SolveOptions::ours(2));
        let r = &plan.report;
        assert!(r.total_seconds() >= r.preprocess_seconds);
        assert_eq!(r.nnz_a, a.nnz());
        assert!(r.nnz_ldu >= r.nnz_a);
        assert!(r.flops > 0.0);
        assert!(!plan.dag.tasks.is_empty());
        assert_eq!(plan.sim.utilization.len(), 2);
    }
}
