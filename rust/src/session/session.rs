//! [`SolverSession`] — the compute-many half of the split pipeline.
//!
//! A session binds one [`FactorPlan`] to preallocated numeric storage and
//! a dense backend. `refactorize` scatters a new value vector through the
//! plan's precomputed map and re-runs the plan's task DAG: **no ordering,
//! no symbolic factorization, no blocking, no DAG construction and no
//! per-call block allocation** happen on this path — exactly the repeated
//! Newton-step / transient-timestep workload of SPICE-style circuit
//! simulation the paper targets.

use super::plan::FactorPlan;
use crate::coordinator::{self, RunReport};
use crate::numeric::factor::{CpuDense, DenseBackend, FactorError, Factors, NumericMatrix};
use crate::numeric::{trisolve, trisolve_t};
use crate::sparse::Csc;
use crate::util::timer::timed;
use std::sync::Arc;

/// Timing report of one numeric-only re-factorization.
#[derive(Clone, Debug)]
pub struct RefactorReport {
    /// Scatter (value placement) seconds.
    pub scatter_seconds: f64,
    /// DAG execution seconds.
    pub numeric_seconds: f64,
    /// Per-worker execution report.
    pub run: RunReport,
}

/// A re-usable factorization session over a fixed sparsity pattern.
pub struct SolverSession<'b> {
    plan: Arc<FactorPlan>,
    numeric: NumericMatrix,
    backend: &'b (dyn DenseBackend + Sync),
    refactor_count: usize,
    factored: bool,
}

impl SolverSession<'static> {
    /// Session over `plan` with the pure-rust dense backend.
    pub fn from_plan(plan: Arc<FactorPlan>) -> Self {
        static CPU: CpuDense = CpuDense;
        Self::with_backend(plan, &CPU)
    }
}

impl<'b> SolverSession<'b> {
    /// Session over `plan` with a custom dense backend (e.g.
    /// [`crate::runtime::PjrtDense`]). Allocates the blocked value
    /// storage **once**; every later call reuses it.
    pub fn with_backend(plan: Arc<FactorPlan>, backend: &'b (dyn DenseBackend + Sync)) -> Self {
        // zero-filled storage: the first refactorize overwrites every
        // value, so copying the plan's stale block values would be waste
        let numeric = NumericMatrix::from_blocked_zeroed(plan.structure.clone());
        Self { plan, numeric, backend, refactor_count: 0, factored: false }
    }

    pub fn plan(&self) -> &Arc<FactorPlan> {
        &self.plan
    }

    /// Number of completed re-factorizations.
    pub fn refactor_count(&self) -> usize {
        self.refactor_count
    }

    /// Has a successful (re-)factorization produced usable factors?
    pub fn is_factored(&self) -> bool {
        self.factored
    }

    /// Numeric-only re-factorization: scatter `values` (the nonzeros of
    /// `A` in its original CSC order) into the preallocated blocked
    /// storage and re-run the plan's task DAG.
    ///
    /// Results are bit-identical to a cold `Solver::factorize` of the
    /// same matrix: the scatter reproduces the partitioner's initial
    /// state exactly and the DAG serializes updates per target block in
    /// the same order.
    pub fn refactorize(&mut self, values: &[f64]) -> Result<RefactorReport, FactorError> {
        self.factored = false;
        let (_, scatter_seconds) = timed(|| self.plan.scatter_values(values, &mut self.numeric));
        let opts = self.plan.options();
        let (run, numeric_seconds) = timed(|| {
            coordinator::run_dag(
                &self.numeric,
                &self.plan.dag,
                &opts.kernels,
                self.backend,
                opts.workers,
            )
        });
        let run = run?;
        self.factored = true;
        self.refactor_count += 1;
        Ok(RefactorReport { scatter_seconds, numeric_seconds, run })
    }

    /// As [`Self::refactorize`] but takes the whole matrix and checks its
    /// pattern against the plan first.
    pub fn refactorize_matrix(&mut self, a: &Csc) -> Result<RefactorReport, FactorError> {
        assert!(
            self.plan.matches(a),
            "matrix pattern does not match the session's FactorPlan \
             (fingerprint {:#018x})",
            self.plan.fingerprint()
        );
        self.refactorize(&a.values)
    }

    /// Solve `A x = b` with the current factors (permutation applied
    /// around the blocked triangular solves).
    pub fn solve(&self, b: &[f64]) -> Vec<f64> {
        assert!(self.factored, "solve before a successful refactorize");
        let pb = self.plan.permutation().permute_vec(b);
        let px = trisolve::solve(&self.numeric, &pb);
        self.plan.inverse_permutation().permute_vec(&px)
    }

    /// Solve `Aᵀ x = b` with the same factors.
    pub fn solve_transpose(&self, b: &[f64]) -> Vec<f64> {
        assert!(self.factored, "solve before a successful refactorize");
        let pb = self.plan.permutation().permute_vec(b);
        let px = trisolve_t::solve_transpose(&self.numeric, &pb);
        self.plan.inverse_permutation().permute_vec(&px)
    }

    /// Solve `A X = B` for many right-hand sides in one batched blocked
    /// sweep ([`trisolve::solve_multi`]) — factor once, solve many,
    /// traverse the factor blocks once.
    pub fn solve_many(&self, bs: &[Vec<f64>]) -> Vec<Vec<f64>> {
        assert!(self.factored, "solve before a successful refactorize");
        let perm = self.plan.permutation();
        let pbs: Vec<Vec<f64>> = bs.iter().map(|b| perm.permute_vec(b)).collect();
        let pxs = trisolve::solve_multi(&self.numeric, &pbs);
        let inv = self.plan.inverse_permutation();
        pxs.iter().map(|px| inv.permute_vec(px)).collect()
    }

    /// Consume the session, yielding the factors (for interop with the
    /// one-shot [`crate::solver::Factorization`] API).
    pub fn into_factors(self) -> Factors {
        assert!(self.factored, "into_factors before a successful refactorize");
        let tasks = self.plan.dag.tasks.len();
        Factors { numeric: self.numeric, sparse_ops: tasks, dense_ops: 0 }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solver::SolveOptions;
    use crate::sparse::{gen, residual};

    fn session_for(a: &Csc, opts: SolveOptions) -> SolverSession<'static> {
        SolverSession::from_plan(Arc::new(FactorPlan::build(a, &opts)))
    }

    #[test]
    fn refactorize_then_solve() {
        let a = gen::grid2d_laplacian(9, 9);
        let mut s = session_for(&a, SolveOptions::ours(1));
        assert!(!s.is_factored());
        s.refactorize_matrix(&a).unwrap();
        assert!(s.is_factored());
        assert_eq!(s.refactor_count(), 1);
        let b: Vec<f64> = (0..81).map(|i| (i % 7) as f64 - 3.0).collect();
        let x = s.solve(&b);
        assert!(residual(&a, &x, &b) < 1e-9);
    }

    #[test]
    fn repeated_refactorize_is_deterministic() {
        let a = gen::circuit_bbd(gen::CircuitParams { n: 250, ..Default::default() });
        let mut s = session_for(&a, SolveOptions::ours(2));
        let b: Vec<f64> = (0..250).map(|i| ((i * 3) % 11) as f64 - 5.0).collect();
        s.refactorize(&a.values).unwrap();
        let x1 = s.solve(&b);
        s.refactorize(&a.values).unwrap();
        let x2 = s.solve(&b);
        assert_eq!(x1, x2, "same values must reproduce bit-identical solves");
        assert_eq!(s.refactor_count(), 2);
    }

    #[test]
    fn transpose_solve_through_session() {
        let a = gen::directed_graph(120, 3, 9);
        let mut s = session_for(&a, SolveOptions::ours(1));
        s.refactorize_matrix(&a).unwrap();
        let mut rng = crate::util::Prng::new(4);
        let x_true: Vec<f64> = (0..120).map(|_| rng.signed_unit()).collect();
        let b = a.transpose().mul_vec(&x_true);
        let x = s.solve_transpose(&b);
        for (got, want) in x.iter().zip(&x_true) {
            assert!((got - want).abs() < 1e-8);
        }
    }

    #[test]
    #[should_panic(expected = "does not match")]
    fn pattern_mismatch_panics() {
        let a = gen::grid2d_laplacian(6, 6);
        let other = gen::grid2d_laplacian(6, 7);
        let mut s = session_for(&a, SolveOptions::ours(1));
        let _ = s.refactorize_matrix(&other);
    }
}
