//! [`SolverSession`] — the compute-many half of the split pipeline.
//!
//! A session binds one [`FactorPlan`] to preallocated numeric storage and
//! a dense backend. `refactorize` scatters a new value vector through the
//! plan's precomputed map and re-runs the plan's task DAG: **no ordering,
//! no symbolic factorization, no blocking, no DAG construction and no
//! per-call block allocation** happen on this path — exactly the repeated
//! Newton-step / transient-timestep workload of SPICE-style circuit
//! simulation the paper targets.
//!
//! DAG runs execute on the persistent work-stealing
//! [`crate::coordinator::Executor`] (shared process-wide per worker
//! count) with a per-session reusable [`crate::coordinator::RunState`],
//! so a steady-state replay spawns no threads and allocates nothing —
//! the spawn-per-call baseline remains selectable via
//! [`SolverSession::set_scheduler`] for benchmarking.

use super::changeset::ChangeSet;
use super::plan::FactorPlan;
use crate::coordinator::{self, Executor, RunReport, RunState, Scheduler};
use crate::numeric::factor::{CpuDense, DenseBackend, FactorError, Factors, NumericMatrix};
use crate::numeric::{trisolve, trisolve_t, Precision};
use crate::sparse::Csc;
use crate::util::timer::timed;
use std::sync::{Arc, OnceLock};

/// Timing + pruning report of one (full or incremental) re-factorization.
#[derive(Clone, Debug)]
pub struct RefactorReport {
    /// Scatter (value placement / dirty-closure) seconds.
    pub scatter_seconds: f64,
    /// DAG execution seconds.
    pub numeric_seconds: f64,
    /// DAG tasks executed in this call (the whole DAG for a full
    /// `refactorize`; only the dirty-reachable subset for
    /// `refactorize_partial`).
    pub tasks_executed: usize,
    /// DAG tasks skipped because no dirty block reaches their target
    /// (always 0 for a full `refactorize`).
    pub tasks_skipped: usize,
    /// Blocks whose A-entries were touched by the change set (the seed
    /// set of the reachability closure).
    pub blocks_dirty: usize,
    /// Blocks re-initialized and recomputed (forward closure of the
    /// dirty set over the block dependency graph).
    pub blocks_affected: usize,
    /// Per-worker execution report.
    pub run: RunReport,
}

/// Pruning forecast of an incremental re-factorization: what
/// [`SolverSession::refactorize_partial`] *would* do for a change set,
/// computed without executing any task (only the session's preallocated
/// closure scratch is touched — values, factors and counters are not).
/// The serving batcher — and any external caller scheduling work — uses
/// this to choose partial vs full re-factorization per request.
#[derive(Clone, Copy, Debug)]
pub struct PartialEstimate {
    /// Blocks the change set's entries land in (the closure seeds).
    pub blocks_dirty: usize,
    /// Blocks in the forward closure (would be re-initialized and
    /// recomputed).
    pub blocks_affected: usize,
    /// DAG tasks the partial pass would execute.
    pub tasks_to_run: usize,
    /// Total DAG tasks (a full refactorize executes all of them).
    pub tasks_total: usize,
    /// Modeled device-seconds of the task subset (same cost model as
    /// `plan.dag`; compare against `plan.dag.total_cost()`).
    pub modeled_cost: f64,
}

impl PartialEstimate {
    /// Fraction of the DAG the partial pass would re-execute
    /// (0.0 = free no-op, 1.0 = no cheaper than a full refactorize).
    pub fn run_fraction(&self) -> f64 {
        if self.tasks_total == 0 {
            0.0
        } else {
            self.tasks_to_run as f64 / self.tasks_total as f64
        }
    }
}

/// Relative-residual target of [`SolverSession::solve_refined`]: mixed
/// precision is only worth shipping if refinement recovers full f64
/// accuracy, so the default target sits at the level a plain f64 solve
/// reaches on well-conditioned systems.
pub const REFINE_TARGET: f64 = 1e-12;

/// Iteration cap of [`SolverSession::solve_refined`]. Well-conditioned
/// systems converge in 2–4 corrections; a system still above target
/// after this many is not contracting (κ(A)·ε₃₂ ≳ 1) and full precision
/// is the right tool.
pub const REFINE_MAX_ITERS: usize = 25;

/// Mixed-precision iterative refinement failed to reach
/// [`REFINE_TARGET`] — the typed signal serving paths forward to clients
/// so they can retry the request at [`Precision::Full`].
#[derive(Clone, Debug, PartialEq)]
pub enum RefineError {
    /// The residual stopped contracting (stalled or grew, went
    /// non-finite, or the iteration cap was reached) — the classic
    /// symptom of κ(A)·ε₃₂ ≳ 1, where single-precision factors carry no
    /// usable correction information.
    Diverged {
        /// Correction solves applied before giving up.
        iterations: usize,
        /// Last relative residual `‖b − Ax‖∞ / ‖b‖∞` observed.
        residual: f64,
    },
}

impl std::fmt::Display for RefineError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RefineError::Diverged { iterations, residual } => write!(
                f,
                "mixed-precision refinement diverged after {iterations} iteration(s) \
                 (relative residual {residual:.3e}); the system is too ill-conditioned \
                 for f32 factors — use Precision::Full"
            ),
        }
    }
}

impl std::error::Error for RefineError {}

/// A converged [`SolverSession::solve_refined`] result.
#[derive(Clone, Debug)]
pub struct RefinedSolve {
    /// The solution, refined to full f64 accuracy.
    pub x: Vec<f64>,
    /// Correction solves applied (0 = the initial mixed solve already
    /// met the target).
    pub iterations: usize,
    /// Final relative residual `‖b − Ax‖∞ / ‖b‖∞`.
    pub residual: f64,
}

/// A re-usable factorization session over a fixed sparsity pattern.
pub struct SolverSession<'b> {
    plan: Arc<FactorPlan>,
    numeric: NumericMatrix,
    backend: &'b (dyn DenseBackend + Sync),
    /// Persistent work-stealing pool the DAG runs execute on — the
    /// process-wide shared pool for the plan's worker count, so every
    /// session (and serve shard) with the same setting reuses one set of
    /// threads instead of spawning per call.
    exec: Arc<Executor>,
    /// Reusable per-run scheduling state (dependency counters, tallies),
    /// preallocated to the plan's DAG so replays allocate nothing.
    run_state: RunState,
    /// Persistent executor by default; the spawn-per-call baseline is
    /// selectable for benchmarking/differential testing.
    sched: Scheduler,
    refactor_count: usize,
    factored: bool,
    /// A-values (CSC order) the current factors were computed from — the
    /// baseline `refactorize_partial` applies change sets against.
    current_values: Vec<f64>,
    // --- preallocated scratch for the incremental warm path ---
    /// Per-block "in the affected closure" flag.
    affected: Vec<bool>,
    /// Per-task "re-execute" mask handed to `run_dag_subset`.
    in_subset: Vec<bool>,
    /// BFS queue over block ids; after the closure completes it holds
    /// exactly the affected blocks.
    queue: Vec<u32>,
    /// Request-correlation id the next DAG runs are stamped with when
    /// tracing is on (see [`crate::obs::trace`]); 0 = uncorrelated.
    trace_id: u64,
    /// Original-matrix coordinates of every A-nonzero (CSC order),
    /// recovered from the plan's scatter map on first use — the f64
    /// residual SpMV of [`Self::solve_refined`] runs over these plus
    /// `current_values`, so refinement needs no client-side copy of `A`.
    coords: OnceLock<Vec<(u32, u32)>>,
}

impl SolverSession<'static> {
    /// Session over `plan` with the pure-rust dense backend.
    pub fn from_plan(plan: Arc<FactorPlan>) -> Self {
        static CPU: CpuDense = CpuDense;
        Self::with_backend(plan, &CPU)
    }
}

impl<'b> SolverSession<'b> {
    /// Session over `plan` with a custom dense backend (e.g.
    /// [`crate::runtime::PjrtDense`]). Allocates the blocked value
    /// storage **once**; every later call reuses it.
    pub fn with_backend(plan: Arc<FactorPlan>, backend: &'b (dyn DenseBackend + Sync)) -> Self {
        // zero-filled storage: the first refactorize overwrites every
        // value, so copying the plan's stale block values would be waste
        let numeric = NumericMatrix::from_blocked_zeroed(plan.structure.clone());
        let nnz_a = plan.nnz_a();
        let nblocks = plan.structure.blocks.len();
        let ntasks = plan.dag.tasks.len();
        let workers = plan.options().workers;
        Self {
            exec: Executor::shared(workers),
            run_state: RunState::sized(ntasks, workers),
            sched: Scheduler::Persistent,
            plan,
            numeric,
            backend,
            refactor_count: 0,
            factored: false,
            current_values: vec![0.0; nnz_a],
            affected: vec![false; nblocks],
            in_subset: vec![false; ntasks],
            queue: Vec::with_capacity(nblocks),
            trace_id: 0,
            coords: OnceLock::new(),
        }
    }

    /// Switch the session's factorization precision. [`Precision::Mixed`]
    /// allocates the f32 shadow storage on first use and routes every
    /// subsequent `refactorize`/`refactorize_partial` through the
    /// single-precision kernels — roughly half the value-memory traffic
    /// on the bandwidth-bound replay path. Full f64 accuracy is then
    /// recovered per solve by [`Self::solve_refined`].
    ///
    /// Changing precision invalidates the current factors: a full
    /// `refactorize` must run before the next solve.
    pub fn set_precision(&mut self, p: Precision) {
        if self.numeric.precision != p {
            self.factored = false;
        }
        self.numeric.set_precision(p);
    }

    /// The precision re-factorizations currently run at.
    pub fn precision(&self) -> Precision {
        self.numeric.precision
    }

    /// Set the [`crate::obs::trace`] correlation id the session's next
    /// DAG runs carry (the serving [`crate::serve::Batcher`] installs
    /// one per drained batch). The id is published thread-locally right
    /// before each run, so the executor stamps it into every task event
    /// — events, logs and the [`crate::serve::ServeReport`] of one
    /// request then share an id. A no-op while tracing is off.
    pub fn set_trace_id(&mut self, id: u64) {
        self.trace_id = id;
    }

    /// The correlation id currently installed on the session.
    pub fn trace_id(&self) -> u64 {
        self.trace_id
    }

    /// Publish this session's trace id on the calling thread for the
    /// DAG run about to be submitted. Gated on the enable flag so the
    /// tracing-off hot path pays one atomic load, no TLS write.
    fn publish_trace_id(&self) {
        if crate::obs::trace::enabled() {
            crate::obs::trace::set_current_trace_id(self.trace_id);
        }
    }

    pub fn plan(&self) -> &Arc<FactorPlan> {
        &self.plan
    }

    /// The persistent executor this session's DAG runs execute on
    /// (shared process-wide among sessions with the same worker count).
    pub fn executor(&self) -> &Arc<Executor> {
        &self.exec
    }

    /// Switch between the persistent work-stealing executor (the
    /// default) and the spawn-per-call baseline scheduler. Factors are
    /// bit-identical either way — only scheduling overhead differs; the
    /// toggle exists for `repro sched-bench` and differential tests.
    pub fn set_scheduler(&mut self, sched: Scheduler) {
        self.sched = sched;
    }

    /// The scheduler re-factorizations currently run on.
    pub fn scheduler(&self) -> Scheduler {
        self.sched
    }

    /// The blocked numeric storage holding the current factors.
    pub fn numeric(&self) -> &NumericMatrix {
        &self.numeric
    }

    /// A-values (CSC order) of the matrix the current factors correspond
    /// to — diff the next step's values against this to build a
    /// [`ChangeSet`] ([`ChangeSet::from_values_diff`]).
    pub fn current_values(&self) -> &[f64] {
        &self.current_values
    }

    /// Number of completed re-factorizations.
    pub fn refactor_count(&self) -> usize {
        self.refactor_count
    }

    /// Has a successful (re-)factorization produced usable factors?
    pub fn is_factored(&self) -> bool {
        self.factored
    }

    /// Numeric-only re-factorization: scatter `values` (the nonzeros of
    /// `A` in its original CSC order) into the preallocated blocked
    /// storage and re-run the plan's task DAG.
    ///
    /// Results are bit-identical to a cold `Solver::factorize` of the
    /// same matrix: the scatter reproduces the partitioner's initial
    /// state exactly and the DAG serializes updates per target block in
    /// the same order.
    pub fn refactorize(&mut self, values: &[f64]) -> Result<RefactorReport, FactorError> {
        self.factored = false;
        let (_, scatter_seconds) = timed(|| self.plan.scatter_values(values, &mut self.numeric));
        self.current_values.copy_from_slice(values);
        let opts = self.plan.options();
        self.publish_trace_id();
        let (run, numeric_seconds) = timed(|| match self.sched {
            Scheduler::Persistent => coordinator::run_dag(
                &self.numeric,
                &self.plan.dag,
                &opts.kernels,
                self.backend,
                &self.exec,
                &mut self.run_state,
            ),
            Scheduler::SpawnPerCall => coordinator::run_dag_spawn(
                &self.numeric,
                &self.plan.dag,
                &opts.kernels,
                self.backend,
                opts.workers,
            ),
        });
        let run = run?;
        // post-factor non-finite scan: a NaN/Inf factor (overflow,
        // poisoned input, injected fault) must not be marked usable —
        // a later solve would return garbage without any error
        if let Some(block) = self.numeric.scan_non_finite() {
            return Err(FactorError::NonFinite { block });
        }
        self.factored = true;
        self.refactor_count += 1;
        let nblocks = self.plan.structure.blocks.len();
        Ok(RefactorReport {
            scatter_seconds,
            numeric_seconds,
            tasks_executed: run.total_tasks,
            tasks_skipped: 0,
            blocks_dirty: nblocks,
            blocks_affected: nblocks,
            run,
        })
    }

    /// Incremental re-factorization: re-run **only** the DAG tasks whose
    /// target block is forward-reachable from the blocks the change set
    /// touches, against the preserved factors of every other block.
    ///
    /// The change set's updates are applied to the session's current A
    /// values; each updated nonzero marks its destination block *dirty*
    /// (via the plan's scatter map), the dirty set is closed under the
    /// plan's precomputed block dependency edges, the affected blocks are
    /// reset to their freshly-scattered state, and
    /// [`coordinator::run_dag_subset`] replays exactly the tasks writing
    /// them. Unaffected blocks keep their factored values — which are
    /// bit-identical to what a full re-factorization of the updated
    /// matrix would recompute for them, because no value they depend on
    /// changed and every kernel is deterministic. The result is therefore
    /// **bit-identical to a full [`Self::refactorize`]** of the updated
    /// values, for any change set (empty, full, or anything between).
    ///
    /// Requires a prior successful (full) `refactorize` — the preserved
    /// blocks must hold valid factors — and a session plan (one built by
    /// [`FactorPlan::build`], not the one-shot constructor).
    ///
    /// # Example: a SPICE device stamp
    ///
    /// One transistor between nodes 40/41 re-linearizes between Newton
    /// iterations, so exactly two conductance entries of `A` change:
    ///
    /// ```
    /// use sparselu::session::{ChangeSet, FactorPlan, SolverSession};
    /// use sparselu::solver::SolveOptions;
    /// use sparselu::sparse::gen;
    /// use std::sync::Arc;
    ///
    /// let a = gen::circuit_bbd(gen::CircuitParams { n: 300, ..Default::default() });
    /// let plan = Arc::new(FactorPlan::build(&a, &SolveOptions::ours(2)).unwrap());
    /// let mut session = SolverSession::from_plan(plan);
    /// session.refactorize(&a.values)?;                    // full pass seeds factors
    ///
    /// let (g0, g1) = (1.2e-3, 0.8e-3);
    /// let stamp = ChangeSet::from_coords(&a, &[(40, 40, g0), (41, 41, g1)])?;
    /// let rep = session.refactorize_partial(&stamp)?;     // pruned, bit-identical
    /// assert!(rep.blocks_dirty <= 2, "two entries seed at most two dirty blocks");
    /// assert_eq!(
    ///     rep.tasks_executed + rep.tasks_skipped,
    ///     session.plan().dag.tasks.len(),
    /// );
    /// # Ok::<(), sparselu::numeric::factor::FactorError>(())
    /// ```
    ///
    /// `from_coords` returns [`FactorError::OutOfPattern`] (instead of
    /// panicking) when a stamp lies outside the sparsity pattern —
    /// serving paths forward the error to the client.
    pub fn refactorize_partial(&mut self, cs: &ChangeSet) -> Result<RefactorReport, FactorError> {
        assert!(
            self.factored,
            "refactorize_partial needs a successful full refactorize first \
             (there are no preserved factors to reuse)"
        );
        let plan = self.plan.clone();
        let reach = plan.reach();
        self.factored = false;

        let SolverSession { current_values, affected, in_subset, queue, numeric, .. } =
            &mut *self;
        let ((blocks_dirty, blocks_affected), scatter_seconds) = timed(|| {
            affected.fill(false);
            in_subset.fill(false);
            queue.clear();
            // seed: destination blocks of the changed A entries; updates
            // that bit-equal the current value are no-ops and dirty
            // nothing (a converged loop re-stamping identical values
            // must not trigger recomputation)
            for &(k, v) in cs.updates() {
                assert!(
                    k < current_values.len(),
                    "change-set value index {k} out of range (nnz = {})",
                    current_values.len()
                );
                if v.to_bits() == current_values[k].to_bits() {
                    continue;
                }
                current_values[k] = v;
                let b = plan.scatter_block_of(k);
                if !affected[b as usize] {
                    affected[b as usize] = true;
                    queue.push(b);
                }
            }
            let blocks_dirty = queue.len();
            // forward closure over the block dependency graph
            let mut head = 0;
            while head < queue.len() {
                let b = queue[head];
                head += 1;
                for &down in reach.downstream(b) {
                    if !affected[down as usize] {
                        affected[down as usize] = true;
                        queue.push(down);
                    }
                }
            }
            // reset affected blocks to their pre-factorization state and
            // collect the task subset that rebuilds them
            for &b in queue.iter() {
                plan.rescatter_block(b, current_values, numeric);
                for &t in reach.tasks_of(b) {
                    in_subset[t as usize] = true;
                }
            }
            (blocks_dirty, queue.len())
        });

        let opts = plan.options();
        let total = plan.dag.tasks.len();
        if blocks_affected == 0 {
            // no dirty blocks (empty or all-identical change set): the
            // preserved factors already are the answer — skip the worker
            // spawn entirely so a converged Newton loop's no-op steps
            // stay free
            self.factored = true;
            self.refactor_count += 1;
            let p = opts.workers as usize;
            return Ok(RefactorReport {
                scatter_seconds,
                numeric_seconds: 0.0,
                tasks_executed: 0,
                tasks_skipped: total,
                blocks_dirty: 0,
                blocks_affected: 0,
                run: RunReport {
                    wall_seconds: 0.0,
                    busy: vec![0.0; p],
                    tasks_done: vec![0; p],
                    total_tasks: 0,
                    workers: opts.workers,
                },
            });
        }
        self.publish_trace_id();
        let (run, numeric_seconds) = timed(|| match self.sched {
            Scheduler::Persistent => coordinator::run_dag_subset(
                &self.numeric,
                &plan.dag,
                &self.in_subset,
                &opts.kernels,
                self.backend,
                &self.exec,
                &mut self.run_state,
            ),
            Scheduler::SpawnPerCall => coordinator::run_dag_subset_spawn(
                &self.numeric,
                &plan.dag,
                &self.in_subset,
                &opts.kernels,
                self.backend,
                opts.workers,
            ),
        });
        let run = run?;
        // same post-factor non-finite gate as the full path: preserved
        // factors from earlier runs are scanned too, so a poisoned block
        // outside the dirty closure still fails the step
        if let Some(block) = self.numeric.scan_non_finite() {
            return Err(FactorError::NonFinite { block });
        }
        self.factored = true;
        self.refactor_count += 1;
        let executed = run.total_tasks;
        Ok(RefactorReport {
            scatter_seconds,
            numeric_seconds,
            tasks_executed: executed,
            tasks_skipped: total - executed,
            blocks_dirty,
            blocks_affected,
            run,
        })
    }

    /// Forecast what [`Self::refactorize_partial`] would do for `cs`:
    /// the same dirty-seed + forward-closure walk as the real path,
    /// reusing the session's preallocated closure scratch (hence
    /// `&mut self`) so the serving hot path allocates nothing. **No
    /// task executes and no semantic state changes** — current values,
    /// factors and counters are untouched. Updates that bit-equal the
    /// current value are no-ops here exactly as they are on the real
    /// path, so the forecast's counts match the report the eventual
    /// `refactorize_partial(cs)` call would return.
    pub fn estimate_partial(&mut self, cs: &ChangeSet) -> PartialEstimate {
        let plan = self.plan.clone();
        let reach = plan.reach();
        let SolverSession { current_values, affected, queue, .. } = &mut *self;
        affected.fill(false);
        queue.clear();
        for &(k, v) in cs.updates() {
            assert!(
                k < current_values.len(),
                "change-set value index {k} out of range (nnz = {})",
                current_values.len()
            );
            if v.to_bits() == current_values[k].to_bits() {
                continue;
            }
            let b = plan.scatter_block_of(k);
            if !affected[b as usize] {
                affected[b as usize] = true;
                queue.push(b);
            }
        }
        let blocks_dirty = queue.len();
        let mut head = 0;
        while head < queue.len() {
            let b = queue[head];
            head += 1;
            for &down in reach.downstream(b) {
                if !affected[down as usize] {
                    affected[down as usize] = true;
                    queue.push(down);
                }
            }
        }
        let mut tasks_to_run = 0usize;
        let mut modeled_cost = 0.0f64;
        for &b in queue.iter() {
            for &t in reach.tasks_of(b) {
                tasks_to_run += 1;
                modeled_cost += plan.dag.tasks[t as usize].cost;
            }
        }
        PartialEstimate {
            blocks_dirty,
            blocks_affected: queue.len(),
            tasks_to_run,
            tasks_total: plan.dag.tasks.len(),
            modeled_cost,
        }
    }

    /// As [`Self::refactorize_partial`] but takes the whole updated
    /// matrix: diffs its values against the session's current values and
    /// applies the resulting change set. The pattern must match the plan.
    pub fn refactorize_partial_matrix(&mut self, a: &Csc) -> Result<RefactorReport, FactorError> {
        assert!(
            self.plan.matches(a),
            "matrix pattern does not match the session's FactorPlan \
             (fingerprint {:#018x})",
            self.plan.fingerprint()
        );
        let cs = ChangeSet::from_values_diff(&self.current_values, &a.values);
        self.refactorize_partial(&cs)
    }

    /// As [`Self::refactorize`] but takes the whole matrix and checks its
    /// pattern against the plan first.
    pub fn refactorize_matrix(&mut self, a: &Csc) -> Result<RefactorReport, FactorError> {
        assert!(
            self.plan.matches(a),
            "matrix pattern does not match the session's FactorPlan \
             (fingerprint {:#018x})",
            self.plan.fingerprint()
        );
        self.refactorize(&a.values)
    }

    /// Solve `A x = b` with the current factors (permutation applied
    /// around the blocked triangular solves). Full-precision sessions
    /// only; under [`Precision::Mixed`] use [`Self::solve_refined`].
    pub fn solve(&self, b: &[f64]) -> Vec<f64> {
        assert!(self.factored, "solve before a successful refactorize");
        self.assert_full_precision("solve");
        let pb = self.plan.permutation().permute_vec(b);
        let px = trisolve::solve(&self.numeric, &pb);
        self.plan.inverse_permutation().permute_vec(&px)
    }

    /// Solve `Aᵀ x = b` with the same factors. Full-precision sessions
    /// only — transpose solves have no mixed-precision refinement path
    /// (the residual replay would need `Aᵀ` coordinates; a documented
    /// limitation, not an oversight).
    pub fn solve_transpose(&self, b: &[f64]) -> Vec<f64> {
        assert!(self.factored, "solve before a successful refactorize");
        self.assert_full_precision("solve_transpose");
        let pb = self.plan.permutation().permute_vec(b);
        let px = trisolve_t::solve_transpose(&self.numeric, &pb);
        self.plan.inverse_permutation().permute_vec(&px)
    }

    /// Solve `A X = B` for many right-hand sides in one batched blocked
    /// sweep ([`trisolve::solve_multi`]) — factor once, solve many,
    /// traverse the factor blocks once. Full-precision sessions only.
    pub fn solve_many(&self, bs: &[Vec<f64>]) -> Vec<Vec<f64>> {
        assert!(self.factored, "solve before a successful refactorize");
        self.assert_full_precision("solve_many");
        let perm = self.plan.permutation();
        let pbs: Vec<Vec<f64>> = bs.iter().map(|b| perm.permute_vec(b)).collect();
        let pxs = trisolve::solve_multi(&self.numeric, &pbs);
        let inv = self.plan.inverse_permutation();
        pxs.iter().map(|px| inv.permute_vec(px)).collect()
    }

    fn assert_full_precision(&self, what: &str) {
        assert_eq!(
            self.numeric.precision,
            Precision::Full,
            "{what} reads f64 factors, but this session factorizes at \
             Precision::Mixed — use solve_refined (or set_precision(Full) \
             and refactorize)"
        );
    }

    /// One mixed solve in original-matrix ordering: permute, run the
    /// f32-factor triangular solves in f64 arithmetic, permute back.
    fn solve_mixed_once(&self, b: &[f64]) -> Vec<f64> {
        let pb = self.plan.permutation().permute_vec(b);
        let px = trisolve::solve_mixed(&self.numeric, &pb);
        self.plan.inverse_permutation().permute_vec(&px)
    }

    /// Solve `A x = b` against **single-precision factors**, recovering
    /// full f64 accuracy by iterative refinement: repeat
    /// `x ← x + LU₃₂⁻¹ (b − A x)` with the residual computed in f64 from
    /// the session's retained A-values, until the relative residual
    /// `‖b − Ax‖∞ / ‖b‖∞` drops to [`REFINE_TARGET`].
    ///
    /// Requires a [`Precision::Mixed`] session with current factors. The
    /// factorization itself ran at half the memory traffic; each
    /// correction costs one f64 SpMV plus one triangular replay. On
    /// well-conditioned systems this converges in 2–4 iterations; when
    /// κ(A)·ε₃₂ ≳ 1 the iteration cannot contract and the typed
    /// [`RefineError::Diverged`] is returned (callers fall back to
    /// [`Precision::Full`]).
    pub fn solve_refined(&self, b: &[f64]) -> Result<RefinedSolve, RefineError> {
        assert!(self.factored, "solve before a successful refactorize");
        assert_eq!(
            self.numeric.precision,
            Precision::Mixed,
            "solve_refined needs a Precision::Mixed session \
             (a Full session's plain solve is already exact)"
        );
        let n = self.plan.n();
        assert_eq!(b.len(), n);
        let coords = self.coords.get_or_init(|| self.plan.value_coords());
        let bnorm = b.iter().fold(0.0f64, |m, &v| m.max(v.abs())).max(f64::MIN_POSITIVE);
        let mut x = self.solve_mixed_once(b);
        let mut r = vec![0.0f64; n];
        let mut prev = f64::INFINITY;
        for it in 0..=REFINE_MAX_ITERS {
            // f64 residual r = b − A·x over the retained A values
            r.copy_from_slice(b);
            for (&(i, j), &v) in coords.iter().zip(&self.current_values) {
                r[i as usize] -= v * x[j as usize];
            }
            let res = r.iter().fold(0.0f64, |m, &v| m.max(v.abs())) / bnorm;
            if !res.is_finite() {
                return Err(RefineError::Diverged { iterations: it, residual: res });
            }
            if res <= REFINE_TARGET {
                return Ok(RefinedSolve { x, iterations: it, residual: res });
            }
            // a healthy refinement contracts by ~κ(A)·ε₃₂ per step —
            // anything not beating 0.9 is stalled and will never reach
            // the target, so give up early rather than burn the cap
            if res > prev * 0.9 || it == REFINE_MAX_ITERS {
                return Err(RefineError::Diverged { iterations: it, residual: res });
            }
            prev = res;
            let d = self.solve_mixed_once(&r);
            for (xi, di) in x.iter_mut().zip(&d) {
                *xi += di;
            }
        }
        unreachable!("loop exits via return")
    }

    /// Consume the session, yielding the factors (for interop with the
    /// one-shot [`crate::solver::Factorization`] API).
    pub fn into_factors(self) -> Factors {
        assert!(self.factored, "into_factors before a successful refactorize");
        self.assert_full_precision("into_factors");
        let tasks = self.plan.dag.tasks.len();
        Factors { numeric: self.numeric, sparse_ops: tasks, dense_ops: 0 }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solver::SolveOptions;
    use crate::sparse::{gen, residual};

    fn session_for(a: &Csc, opts: SolveOptions) -> SolverSession<'static> {
        SolverSession::from_plan(Arc::new(FactorPlan::build(a, &opts).unwrap()))
    }

    #[test]
    fn refactorize_then_solve() {
        let a = gen::grid2d_laplacian(9, 9);
        let mut s = session_for(&a, SolveOptions::ours(1));
        assert!(!s.is_factored());
        s.refactorize_matrix(&a).unwrap();
        assert!(s.is_factored());
        assert_eq!(s.refactor_count(), 1);
        let b: Vec<f64> = (0..81).map(|i| (i % 7) as f64 - 3.0).collect();
        let x = s.solve(&b);
        assert!(residual(&a, &x, &b) < 1e-9);
    }

    #[test]
    fn repeated_refactorize_is_deterministic() {
        let a = gen::circuit_bbd(gen::CircuitParams { n: 250, ..Default::default() });
        let mut s = session_for(&a, SolveOptions::ours(2));
        let b: Vec<f64> = (0..250).map(|i| ((i * 3) % 11) as f64 - 5.0).collect();
        s.refactorize(&a.values).unwrap();
        let x1 = s.solve(&b);
        s.refactorize(&a.values).unwrap();
        let x2 = s.solve(&b);
        assert_eq!(x1, x2, "same values must reproduce bit-identical solves");
        assert_eq!(s.refactor_count(), 2);
    }

    #[test]
    fn transpose_solve_through_session() {
        let a = gen::directed_graph(120, 3, 9);
        let mut s = session_for(&a, SolveOptions::ours(1));
        s.refactorize_matrix(&a).unwrap();
        let mut rng = crate::util::Prng::new(4);
        let x_true: Vec<f64> = (0..120).map(|_| rng.signed_unit()).collect();
        let b = a.transpose().mul_vec(&x_true);
        let x = s.solve_transpose(&b);
        for (got, want) in x.iter().zip(&x_true) {
            assert!((got - want).abs() < 1e-8);
        }
    }

    #[test]
    #[should_panic(expected = "does not match")]
    fn pattern_mismatch_panics() {
        let a = gen::grid2d_laplacian(6, 6);
        let other = gen::grid2d_laplacian(6, 7);
        let mut s = session_for(&a, SolveOptions::ours(1));
        let _ = s.refactorize_matrix(&other);
    }

    #[test]
    fn empty_change_set_executes_nothing_and_preserves_factors() {
        let a = gen::grid2d_laplacian(8, 8);
        let mut s = session_for(&a, SolveOptions::ours(1));
        s.refactorize(&a.values).unwrap();
        let before: Vec<Vec<f64>> = (0..s.plan().structure.blocks.len())
            .map(|id| s.numeric().block_values(id as u32))
            .collect();
        let rep = s.refactorize_partial(&ChangeSet::new()).unwrap();
        assert_eq!(rep.tasks_executed, 0);
        assert_eq!(rep.tasks_skipped, s.plan().dag.tasks.len());
        assert_eq!(rep.blocks_dirty, 0);
        assert_eq!(rep.blocks_affected, 0);
        for (id, b) in before.iter().enumerate() {
            assert_eq!(&s.numeric().block_values(id as u32), b, "block {id}");
        }
        assert!(s.is_factored());
        assert_eq!(s.refactor_count(), 2);
    }

    #[test]
    fn identical_restamp_is_a_free_noop() {
        // a converged loop re-stamping the same values must dirty nothing
        let a = gen::grid2d_laplacian(8, 8);
        let mut s = session_for(&a, SolveOptions::ours(1));
        s.refactorize(&a.values).unwrap();
        let k = a.value_index(30, 30).unwrap();
        let rep = s
            .refactorize_partial(&ChangeSet::from_value_indices([(k, a.values[k])]))
            .unwrap();
        assert_eq!(rep.blocks_dirty, 0);
        assert_eq!(rep.blocks_affected, 0);
        assert_eq!(rep.tasks_executed, 0);
        assert!(s.is_factored());
    }

    #[test]
    fn full_change_set_matches_full_refactorize_bitwise() {
        let a = gen::circuit_bbd(gen::CircuitParams { n: 200, ..Default::default() });
        let plan = Arc::new(FactorPlan::build(&a, &SolveOptions::ours(2)).unwrap());
        let mut partial = SolverSession::from_plan(plan.clone());
        partial.refactorize(&a.values).unwrap();
        let new_values: Vec<f64> = a.values.iter().map(|v| v * 1.125).collect();
        let cs = ChangeSet::from_values_diff(&a.values, &new_values);
        let rep = partial.refactorize_partial(&cs).unwrap();
        assert_eq!(rep.tasks_executed + rep.tasks_skipped, plan.dag.tasks.len());

        let mut full = SolverSession::from_plan(plan.clone());
        full.refactorize(&new_values).unwrap();
        for id in 0..plan.structure.blocks.len() {
            assert_eq!(
                partial.numeric().block_values(id as u32),
                full.numeric().block_values(id as u32),
                "block {id} diverges"
            );
        }
        assert_eq!(partial.current_values(), &new_values[..]);
    }

    #[test]
    fn single_entry_change_prunes_and_matches() {
        let a = gen::grid2d_laplacian(10, 10);
        let plan = Arc::new(FactorPlan::build(&a, &SolveOptions::ours(1)).unwrap());
        let mut partial = SolverSession::from_plan(plan.clone());
        partial.refactorize(&a.values).unwrap();
        // bump one diagonal entry
        let k = a.value_index(57, 57).unwrap();
        let mut new_values = a.values.clone();
        new_values[k] *= 2.0;
        let rep = partial
            .refactorize_partial(&ChangeSet::from_value_indices([(k, new_values[k])]))
            .unwrap();
        assert_eq!(rep.blocks_dirty, 1);
        assert!(rep.blocks_affected >= 1);
        assert!(rep.tasks_executed >= 1);

        let mut full = SolverSession::from_plan(plan.clone());
        full.refactorize(&new_values).unwrap();
        for id in 0..plan.structure.blocks.len() {
            assert_eq!(
                partial.numeric().block_values(id as u32),
                full.numeric().block_values(id as u32),
                "block {id} diverges"
            );
        }
        let b: Vec<f64> = (0..100).map(|i| (i % 7) as f64 - 3.0).collect();
        assert_eq!(partial.solve(&b), full.solve(&b));
    }

    #[test]
    fn estimate_partial_forecasts_the_real_partial_pass() {
        let a = gen::grid2d_laplacian(10, 10);
        let mut s = session_for(&a, SolveOptions::ours(1));
        s.refactorize(&a.values).unwrap();
        let k = a.value_index(57, 57).unwrap();
        let cs = ChangeSet::from_value_indices([(k, a.values[k] * 2.0)]);
        let before = s.current_values().to_vec();
        let est = s.estimate_partial(&cs);
        assert_eq!(s.current_values(), &before[..], "estimate must not mutate the session");
        assert!(s.is_factored(), "estimate must not invalidate the factors");
        assert!(est.modeled_cost > 0.0);
        assert!(est.run_fraction() > 0.0 && est.run_fraction() <= 1.0);
        let rep = s.refactorize_partial(&cs).unwrap();
        assert_eq!(est.blocks_dirty, rep.blocks_dirty);
        assert_eq!(est.blocks_affected, rep.blocks_affected);
        assert_eq!(est.tasks_to_run, rep.tasks_executed);
        assert_eq!(est.tasks_total, rep.tasks_executed + rep.tasks_skipped);
        // an all-identical re-stamp forecasts a free no-op
        let same = s.current_values()[k];
        let noop = s.estimate_partial(&ChangeSet::from_value_indices([(k, same)]));
        assert_eq!(noop.tasks_to_run, 0);
        assert_eq!(noop.blocks_affected, 0);
        assert_eq!(noop.run_fraction(), 0.0);
    }

    #[test]
    fn partial_matrix_diffs_against_current_values() {
        let a = gen::directed_graph(100, 3, 11);
        let mut s = session_for(&a, SolveOptions::ours(1));
        s.refactorize_matrix(&a).unwrap();
        let mut a2 = a.clone();
        let k = a2.value_index(40, 40).unwrap();
        a2.values[k] += 3.5;
        let rep = s.refactorize_partial_matrix(&a2).unwrap();
        assert_eq!(rep.blocks_dirty, 1);
        let b: Vec<f64> = (0..100).map(|i| ((i * 5) % 9) as f64 - 4.0).collect();
        let x = s.solve(&b);
        assert!(residual(&a2, &x, &b) < 1e-8);
    }

    #[test]
    #[should_panic(expected = "needs a successful full refactorize")]
    fn partial_before_full_panics() {
        let a = gen::grid2d_laplacian(6, 6);
        let mut s = session_for(&a, SolveOptions::ours(1));
        let _ = s.refactorize_partial(&ChangeSet::new());
    }

    #[test]
    fn mixed_precision_refinement_reaches_full_accuracy() {
        let a = gen::grid2d_laplacian(12, 12);
        let n = a.n_cols();
        let mut s = session_for(&a, SolveOptions::ours(1));
        s.set_precision(Precision::Mixed);
        assert_eq!(s.precision(), Precision::Mixed);
        assert!(!s.is_factored(), "precision switch invalidates factors");
        s.refactorize(&a.values).unwrap();
        let b: Vec<f64> = (0..n).map(|i| ((i * 5) % 13) as f64 - 6.0).collect();
        let refined = s.solve_refined(&b).unwrap();
        assert!(refined.iterations <= super::REFINE_MAX_ITERS);
        assert!(refined.residual <= super::REFINE_TARGET);
        // verify independently against the sparse matrix itself
        let r = residual(&a, &refined.x, &b);
        let bnorm = b.iter().fold(0.0f64, |m, &v| m.max(v.abs()));
        assert!(r / bnorm <= 1e-12, "independent residual {r:e}");
        // refinement must actually be doing work: the raw mixed solve
        // alone is nowhere near f64 accuracy on a 144-dof laplacian
        assert!(refined.iterations >= 1, "f32 factors cannot hit 1e-12 unrefined");
    }

    #[test]
    fn mixed_refinement_after_partial_refactorize() {
        let a = gen::circuit_bbd(gen::CircuitParams { n: 220, ..Default::default() });
        let mut s = session_for(&a, SolveOptions::ours(2));
        s.set_precision(Precision::Mixed);
        s.refactorize(&a.values).unwrap();
        let k = a.value_index(40, 40).unwrap();
        let cs = ChangeSet::from_value_indices([(k, a.values[k] * 1.5)]);
        s.refactorize_partial(&cs).unwrap();
        let b: Vec<f64> = (0..220).map(|i| (i % 9) as f64 - 4.0).collect();
        let refined = s.solve_refined(&b).unwrap();
        // residual against the *updated* matrix
        let mut a2 = a.clone();
        a2.values[k] *= 1.5;
        let bnorm = b.iter().fold(0.0f64, |m, &v| m.max(v.abs()));
        assert!(residual(&a2, &refined.x, &b) / bnorm <= 1e-12);
    }

    #[test]
    fn refinement_reports_divergence_on_ill_conditioned_system() {
        // Upper bidiagonal with unit diagonal and -2.1 superdiagonal:
        // κ∞(A) grows like 2.1^n (~4e9 at n=30), so κ·ε₃₂ ≫ 1 and f32
        // factors carry no contraction — yet every pivot is exactly 1.0
        // in both precisions (the elimination graph is acyclic, so the
        // diagonal is never updated), making the failure mode *purely*
        // a refinement divergence, never a ZeroPivot.
        let n = 30;
        let mut coo = crate::sparse::Coo::new(n, n);
        for i in 0..n {
            coo.push(i, i, 1.0);
            if i + 1 < n {
                coo.push(i, i + 1, -2.1);
            }
        }
        let a = coo.to_csc();
        let mut s = session_for(&a, SolveOptions::ours(1));
        s.set_precision(Precision::Mixed);
        s.refactorize(&a.values).unwrap();
        let b = vec![1.0; n];
        match s.solve_refined(&b) {
            Err(super::RefineError::Diverged { iterations, residual }) => {
                assert!(iterations <= super::REFINE_MAX_ITERS);
                assert!(
                    !(residual <= super::REFINE_TARGET),
                    "divergence must report an above-target residual, got {residual:e}"
                );
            }
            Ok(r) => panic!(
                "κ ~ 4e9 system must not refine to 1e-12 on f32 factors \
                 (converged in {} iterations at {:e})",
                r.iterations, r.residual
            ),
        }
        // the same system at full precision still solves usefully —
        // κ·ε₆₄ ≈ 1e-6, so expect a small-but-not-tiny relative residual
        let mut full = session_for(&a, SolveOptions::ours(1));
        full.refactorize(&a.values).unwrap();
        let x = full.solve(&b);
        assert!(residual(&a, &x, &b) < 1e-3);
    }

    #[test]
    #[should_panic(expected = "solve_refined needs a Precision::Mixed session")]
    fn solve_refined_rejects_full_precision_sessions() {
        let a = gen::grid2d_laplacian(6, 6);
        let mut s = session_for(&a, SolveOptions::ours(1));
        s.refactorize(&a.values).unwrap();
        let _ = s.solve_refined(&vec![1.0; 36]);
    }

    #[test]
    #[should_panic(expected = "Precision::Mixed")]
    fn plain_solve_rejects_mixed_sessions() {
        let a = gen::grid2d_laplacian(6, 6);
        let mut s = session_for(&a, SolveOptions::ours(1));
        s.set_precision(Precision::Mixed);
        s.refactorize(&a.values).unwrap();
        let _ = s.solve(&vec![1.0; 36]);
    }
}
