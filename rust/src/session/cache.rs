//! [`PlanCache`] — LRU cache of [`FactorPlan`]s keyed by pattern
//! fingerprint + solve-options signature.
//!
//! Serving workloads see a small working set of sparsity patterns (one
//! per netlist / mesh / model under simulation) hit by a huge stream of
//! numeric re-factorizations. The cache makes plan reuse automatic: the
//! first request for a pattern pays the full structure analysis, every
//! later request gets the shared `Arc<FactorPlan>` back in O(capacity).
//!
//! [`SharedPlanCache`] wraps the LRU in a mutex **without** holding it
//! across plan construction: concurrent requests for the same unseen
//! fingerprint are deduplicated onto a single build (one leader builds,
//! followers block on a condvar and receive the same `Arc`), while
//! requests for other patterns proceed unhindered.

use super::plan::FactorPlan;
use crate::coordinator::Executor;
use crate::numeric::factor::FactorError;
use crate::solver::{BlockingPolicy, SolveOptions};
use crate::sparse::Csc;
use std::collections::HashMap;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};

/// Least-recently-used plan cache.
pub struct PlanCache {
    capacity: usize,
    /// LRU order: index 0 = least recent, last = most recent. Linear
    /// scans are fine at the capacities that make sense here (a handful
    /// to a few hundred patterns).
    entries: Vec<(u64, Arc<FactorPlan>)>,
    hits: usize,
    misses: usize,
}

impl PlanCache {
    /// Cache holding up to `capacity` plans.
    pub fn new(capacity: usize) -> Self {
        assert!(capacity > 0, "PlanCache needs capacity >= 1");
        Self { capacity, entries: Vec::new(), hits: 0, misses: 0 }
    }

    /// The cache key for a matrix under given options: pattern
    /// fingerprint mixed with an options signature, so the same pattern
    /// under different blocking/kernel/worker settings gets distinct
    /// plans.
    pub fn key_for(a: &Csc, opts: &SolveOptions) -> u64 {
        splitmix(a.pattern_fingerprint() ^ options_signature(opts))
    }

    /// Hit-only half of [`Self::get_or_build`]: return the cached plan
    /// for `(a, opts)` if present and verified against `a` (shape + nnz
    /// + fingerprint, so a hash collision can never hand back a plan for
    /// a different pattern), refreshing its recency. A collision evicts
    /// the impostor and reports a miss; no miss counter is touched — the
    /// caller decides whether a build follows.
    pub fn lookup(&mut self, a: &Csc, opts: &SolveOptions) -> Option<Arc<FactorPlan>> {
        let fp = a.pattern_fingerprint();
        let key = splitmix(fp ^ options_signature(opts));
        let pos = self.entries.iter().position(|(k, _)| *k == key)?;
        let p = &self.entries[pos].1;
        if p.fingerprint() == fp
            && p.n() == a.n_rows()
            && p.n() == a.n_cols()
            && p.nnz_a() == a.nnz()
        {
            self.hits += 1;
            let entry = self.entries.remove(pos);
            let plan = entry.1.clone();
            self.entries.push(entry); // move to most-recent
            return Some(plan);
        }
        // fingerprint collision: evict the impostor and rebuild
        self.entries.remove(pos);
        None
    }

    /// Fetch the plan for `(a, opts)`, building sequentially and
    /// inserting it on miss. Structurally singular input surfaces as
    /// [`FactorError::StructurallySingular`]; nothing is cached on error.
    pub fn get_or_build(
        &mut self,
        a: &Csc,
        opts: &SolveOptions,
    ) -> Result<Arc<FactorPlan>, FactorError> {
        self.get_or_build_on(a, opts, None)
    }

    /// As [`Self::get_or_build`], running the build's parallelizable
    /// passes on `exec` when one is supplied.
    pub fn get_or_build_on(
        &mut self,
        a: &Csc,
        opts: &SolveOptions,
        exec: Option<&Executor>,
    ) -> Result<Arc<FactorPlan>, FactorError> {
        if let Some(plan) = self.lookup(a, opts) {
            return Ok(plan);
        }
        self.misses += 1;
        let built = match exec {
            Some(e) => FactorPlan::build_on(a, opts, e)?,
            None => FactorPlan::build(a, opts)?,
        };
        let plan = Arc::new(built);
        if self.entries.len() == self.capacity {
            self.entries.remove(0); // evict least-recent
        }
        self.entries.push((PlanCache::key_for(a, opts), plan.clone()));
        Ok(plan)
    }

    /// The cache key a (session) plan indexes under — the same key
    /// [`Self::get_or_build`] computes for the matrix/options pair the
    /// plan was built from.
    pub fn key_of_plan(plan: &FactorPlan) -> u64 {
        splitmix(plan.fingerprint() ^ options_signature(plan.options()))
    }

    /// Insert an already-built plan (e.g. one deserialized from disk by
    /// [`crate::serve::persist`]) under its own key, as most-recent. A
    /// plan already cached under the same key is replaced; the
    /// least-recent entry is evicted if the cache is full. Later
    /// `get_or_build` calls for the same pattern + options hit without
    /// rebuilding.
    pub fn insert(&mut self, plan: Arc<FactorPlan>) {
        let key = Self::key_of_plan(&plan);
        if let Some(pos) = self.entries.iter().position(|(k, _)| *k == key) {
            self.entries.remove(pos);
        } else if self.entries.len() == self.capacity {
            self.entries.remove(0); // evict least-recent
        }
        self.entries.push((key, plan));
    }

    /// Refresh `key` to most-recently-used without fetching the plan.
    /// Returns whether the key was present.
    ///
    /// The serving router calls this on every request routed to a
    /// tenant, so the cache's LRU order tracks *traffic* recency — the
    /// same order [`crate::serve::Router`] consults ([`Self::keys_lru`])
    /// when it must pick a shard to evict.
    pub fn touch(&mut self, key: u64) -> bool {
        if let Some(pos) = self.entries.iter().position(|(k, _)| *k == key) {
            let entry = self.entries.remove(pos);
            self.entries.push(entry);
            true
        } else {
            false
        }
    }

    /// Cached keys, least-recently-used first. A key absent from this
    /// list has been evicted (or was never cached) — a shard whose plan
    /// the cache already dropped is the most evictable of all.
    pub fn keys_lru(&self) -> Vec<u64> {
        self.entries.iter().map(|(k, _)| *k).collect()
    }

    /// Plans currently cached.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn hits(&self) -> usize {
        self.hits
    }

    pub fn misses(&self) -> usize {
        self.misses
    }

    /// Drop every cached plan (counters are kept).
    pub fn clear(&mut self) {
        self.entries.clear();
    }

    /// Test-only: insert `plan` under an arbitrary `key`, bypassing
    /// [`Self::key_for`] — forges the hash collision the verification
    /// path in [`Self::lookup`] exists to catch.
    #[cfg(test)]
    fn insert_forged(&mut self, key: u64, plan: Arc<FactorPlan>) {
        self.entries.push((key, plan));
    }
}

/// One in-flight plan build: the leader publishes into `result` and
/// wakes followers through `ready`.
struct BuildSlot {
    result: Mutex<Option<Result<Arc<FactorPlan>, FactorError>>>,
    ready: Condvar,
}

/// Thread-safe wrapper around [`PlanCache`] that deduplicates in-flight
/// builds.
///
/// The LRU mutex is held only for lookups and insertions — never across
/// plan construction. When several threads race on the same unseen
/// `(pattern, options)` key, exactly one (the leader) runs the build;
/// the rest block on the slot's condvar and receive the same
/// `Arc<FactorPlan>`. Distinct keys build concurrently. Failed builds
/// are handed to every waiter but never cached, so a transient racer
/// storm on a bad matrix costs one build, not one per racer.
pub struct SharedPlanCache {
    inner: Mutex<PlanCache>,
    inflight: Mutex<HashMap<u64, Arc<BuildSlot>>>,
}

impl SharedPlanCache {
    /// Shared cache holding up to `capacity` plans.
    pub fn new(capacity: usize) -> Self {
        Self {
            inner: Mutex::new(PlanCache::new(capacity)),
            inflight: Mutex::new(HashMap::new()),
        }
    }

    /// Direct access to the underlying LRU (counters, `touch`,
    /// `keys_lru`, warm inserts). Do not hold this guard across a build.
    pub fn lock(&self) -> MutexGuard<'_, PlanCache> {
        self.inner.lock().unwrap()
    }

    /// Fetch the plan for `(a, opts)`, building on miss — at most one
    /// build per key runs at a time; concurrent requesters share it.
    pub fn get_or_build(
        &self,
        a: &Csc,
        opts: &SolveOptions,
        exec: Option<&Executor>,
    ) -> Result<Arc<FactorPlan>, FactorError> {
        self.get_or_build_traced(a, opts, exec).map(|(plan, _)| plan)
    }

    /// As [`Self::get_or_build`], also reporting whether *this* call ran
    /// the build (`true`) or got the plan from the cache or a concurrent
    /// builder (`false`) — the router uses the flag to decide whether to
    /// record a build latency sample and persist the fresh plan.
    pub fn get_or_build_traced(
        &self,
        a: &Csc,
        opts: &SolveOptions,
        exec: Option<&Executor>,
    ) -> Result<(Arc<FactorPlan>, bool), FactorError> {
        let key = PlanCache::key_for(a, opts);
        if let Some(plan) = self.lock().lookup(a, opts) {
            return Ok((plan, false));
        }
        // miss: join an in-flight build for this key, or lead one
        let (slot, leader) = {
            let mut inflight = self.inflight.lock().unwrap();
            match inflight.get(&key) {
                Some(slot) => (slot.clone(), false),
                None => {
                    // a previous leader may have finished between our
                    // miss and this lock; its cache insert
                    // happens-before its slot removal, so a second
                    // lookup settles the race without a rebuild
                    if let Some(plan) = self.lock().lookup(a, opts) {
                        return Ok((plan, false));
                    }
                    let slot = Arc::new(BuildSlot {
                        result: Mutex::new(None),
                        ready: Condvar::new(),
                    });
                    inflight.insert(key, slot.clone());
                    (slot, true)
                }
            }
        };
        if !leader {
            let mut result = slot.result.lock().unwrap();
            while result.is_none() {
                result = slot.ready.wait(result).unwrap();
            }
            let shared = result.as_ref().expect("slot published").clone();
            if shared.is_ok() {
                self.lock().hits += 1;
            }
            return shared.map(|plan| (plan, false));
        }
        // leader: build outside every lock; a panicking build must still
        // release the followers, so it degrades to a TaskPanic error
        let built = catch_unwind(AssertUnwindSafe(|| match exec {
            Some(e) => FactorPlan::build_on(a, opts, e),
            None => FactorPlan::build(a, opts),
        }))
        .unwrap_or(Err(FactorError::TaskPanic))
        .map(Arc::new);
        {
            let mut cache = self.lock();
            cache.misses += 1;
            if let Ok(plan) = &built {
                cache.insert(plan.clone());
            }
        }
        *slot.result.lock().unwrap() = Some(built.clone());
        slot.ready.notify_all();
        self.inflight.lock().unwrap().remove(&key);
        built.map(|plan| (plan, true))
    }
}

/// Hash every option that influences a plan's structure or costs.
fn options_signature(opts: &SolveOptions) -> u64 {
    let mut h: u64 = 0x9e37_79b9_7f4a_7c15;
    let mut mix = |x: u64| h = splitmix(h ^ x);
    mix(opts.ordering as u64);
    match &opts.blocking {
        BlockingPolicy::Regular(s) => {
            mix(1);
            mix(*s as u64);
        }
        BlockingPolicy::PanguSelect => mix(2),
        BlockingPolicy::Irregular => mix(3),
    }
    mix(opts.kernels.dense_threshold.to_bits());
    mix(opts.kernels.force_dense as u64);
    mix(opts.kernels.use_runtime as u64);
    mix(opts.workers as u64);
    let ir = &opts.irregular;
    mix(ir.sample_points as u64);
    mix(ir.step as u64);
    mix(ir.max_num as u64);
    mix(ir.threshold.map_or(u64::MAX, f64::to_bits));
    mix(ir.min_block as u64);
    let m = &opts.model;
    for f in [
        m.peak_flops,
        m.mem_bw,
        m.launch_overhead,
        m.eff_sparse_factor,
        m.eff_sparse_update,
        m.eff_dense,
        m.link_bw,
        m.link_latency,
        m.col_latency,
        m.col_latency_quad,
        m.sat_half_work,
    ] {
        mix(f.to_bits());
    }
    mix(m.concurrent_kernels as u64);
    drop(mix);
    h
}

/// splitmix64 finalizer — cheap avalanche for the key mix.
fn splitmix(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9e37_79b9_7f4a_7c15);
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::gen;

    #[test]
    fn second_request_hits_and_shares_plan() {
        let a = gen::grid2d_laplacian(8, 8);
        let mut cache = PlanCache::new(4);
        let p1 = cache.get_or_build(&a, &SolveOptions::ours(1)).unwrap();
        let p2 = cache.get_or_build(&a, &SolveOptions::ours(1)).unwrap();
        assert!(Arc::ptr_eq(&p1, &p2), "hit must return the same plan");
        assert_eq!((cache.hits(), cache.misses()), (1, 1));
        assert_eq!(cache.len(), 1);
    }

    #[test]
    fn same_pattern_new_values_still_hits() {
        let a = gen::grid2d_laplacian(8, 8);
        let mut b = a.clone();
        for v in &mut b.values {
            *v *= 1.5;
        }
        let mut cache = PlanCache::new(4);
        let p1 = cache.get_or_build(&a, &SolveOptions::ours(1)).unwrap();
        let p2 = cache.get_or_build(&b, &SolveOptions::ours(1)).unwrap();
        assert!(Arc::ptr_eq(&p1, &p2));
        assert_eq!(cache.hits(), 1);
    }

    #[test]
    fn different_options_get_distinct_plans() {
        let a = gen::grid2d_laplacian(8, 8);
        let mut cache = PlanCache::new(4);
        let p1 = cache.get_or_build(&a, &SolveOptions::ours(1)).unwrap();
        let p2 = cache.get_or_build(&a, &SolveOptions::pangulu(1)).unwrap();
        let p3 = cache.get_or_build(&a, &SolveOptions::ours(2)).unwrap();
        assert!(!Arc::ptr_eq(&p1, &p2));
        assert!(!Arc::ptr_eq(&p1, &p3));
        assert_eq!(cache.misses(), 3);
        assert_eq!(cache.len(), 3);
    }

    #[test]
    fn forged_key_collision_rejected_and_rebuilt() {
        // a plan for pattern A sits in the slot pattern B's key hashes to
        // (as if splitmix collided); the verification pass must evict the
        // impostor and build a genuine plan for B instead of handing A's
        // plan back.
        let a = gen::grid2d_laplacian(6, 6);
        let b = gen::grid2d_laplacian(6, 7);
        let opts = SolveOptions::ours(1);
        let impostor = Arc::new(FactorPlan::build(&a, &opts).unwrap());
        let mut cache = PlanCache::new(4);
        cache.insert_forged(PlanCache::key_for(&b, &opts), impostor.clone());
        assert_eq!(cache.len(), 1);

        let got = cache.get_or_build(&b, &opts).unwrap();
        assert!(!Arc::ptr_eq(&got, &impostor), "collision must not serve the impostor");
        assert_eq!(got.fingerprint(), b.pattern_fingerprint());
        assert_eq!((cache.hits(), cache.misses()), (0, 1));
        assert_eq!(cache.len(), 1, "impostor evicted, genuine plan cached");

        // the genuine plan now hits normally
        let again = cache.get_or_build(&b, &opts).unwrap();
        assert!(Arc::ptr_eq(&got, &again));
        assert_eq!(cache.hits(), 1);
    }

    #[test]
    fn forged_collision_same_shape_and_nnz_still_rejected() {
        // same n, same nnz, different pattern: only the fingerprint check
        // can tell them apart on the verification path
        let mk = |shift: usize| {
            let mut coo = crate::sparse::Coo::new(6, 6);
            for i in 0..6 {
                coo.push(i, i, 4.0);
            }
            // one off-diagonal pair, placed differently per matrix
            coo.push(shift, 5 - shift, 1.0);
            coo.push(5 - shift, shift, 1.0);
            coo.to_csc()
        };
        let a = mk(0);
        let b = mk(1);
        assert_eq!(a.nnz(), b.nnz());
        assert_ne!(a.pattern_fingerprint(), b.pattern_fingerprint());
        let opts = SolveOptions::ours(1);
        let impostor = Arc::new(FactorPlan::build(&a, &opts).unwrap());
        let mut cache = PlanCache::new(2);
        cache.insert_forged(PlanCache::key_for(&b, &opts), impostor.clone());
        let got = cache.get_or_build(&b, &opts).unwrap();
        assert!(!Arc::ptr_eq(&got, &impostor));
        assert_eq!(got.fingerprint(), b.pattern_fingerprint());
        assert_eq!(cache.misses(), 1);
    }

    #[test]
    fn inserted_plan_hits_without_rebuilding() {
        let a = gen::grid2d_laplacian(8, 8);
        let opts = SolveOptions::ours(1);
        let plan = Arc::new(FactorPlan::build(&a, &opts).unwrap());
        let mut cache = PlanCache::new(2);
        cache.insert(plan.clone());
        assert_eq!(cache.len(), 1);
        let got = cache.get_or_build(&a, &opts).unwrap();
        assert!(Arc::ptr_eq(&got, &plan), "warm insert must serve the same plan");
        assert_eq!((cache.hits(), cache.misses()), (1, 0));
        // re-inserting under the same key replaces rather than grows
        cache.insert(plan.clone());
        assert_eq!(cache.len(), 1);
    }

    #[test]
    fn touch_refreshes_recency_and_keys_lru_reports_order() {
        let mats =
            [gen::grid2d_laplacian(6, 6), gen::grid2d_laplacian(6, 7), gen::grid2d_laplacian(7, 7)];
        let opts = SolveOptions::ours(1);
        let mut cache = PlanCache::new(3);
        let keys: Vec<u64> = mats
            .iter()
            .map(|a| {
                cache.get_or_build(a, &opts).unwrap();
                PlanCache::key_for(a, &opts)
            })
            .collect();
        assert_eq!(cache.keys_lru(), keys, "insertion order = recency order");
        // touching the least-recent key moves it to the back
        assert!(cache.touch(keys[0]));
        assert_eq!(cache.keys_lru(), vec![keys[1], keys[2], keys[0]]);
        assert!(!cache.touch(0xDEAD_BEEF), "unknown key untouched");
        // a touched entry survives the next eviction
        cache.get_or_build(&gen::grid2d_laplacian(7, 8), &opts).unwrap(); // evicts keys[1]
        assert!(cache.keys_lru().contains(&keys[0]));
        assert!(!cache.keys_lru().contains(&keys[1]));
    }

    #[test]
    fn lru_evicts_oldest() {
        let mats = [
            gen::grid2d_laplacian(6, 6),
            gen::grid2d_laplacian(6, 7),
            gen::grid2d_laplacian(7, 7),
        ];
        let opts = SolveOptions::ours(1);
        let mut cache = PlanCache::new(2);
        cache.get_or_build(&mats[0], &opts).unwrap();
        cache.get_or_build(&mats[1], &opts).unwrap();
        cache.get_or_build(&mats[0], &opts).unwrap(); // refresh 0 → 1 is now LRU
        cache.get_or_build(&mats[2], &opts).unwrap(); // evicts 1
        assert_eq!(cache.len(), 2);
        cache.get_or_build(&mats[0], &opts).unwrap(); // still cached
        assert_eq!(cache.hits(), 2);
        cache.get_or_build(&mats[1], &opts).unwrap(); // was evicted → miss
        assert_eq!(cache.misses(), 4);
    }

    #[test]
    fn concurrent_misses_share_one_build() {
        // N threads race on the same unseen fingerprint; exactly one
        // build runs and every racer gets the same Arc back
        let a = gen::grid2d_laplacian(12, 12);
        let opts = SolveOptions::ours(1);
        let cache = Arc::new(SharedPlanCache::new(4));
        let n_threads = 8;
        let barrier = Arc::new(std::sync::Barrier::new(n_threads));
        let plans: Vec<Arc<FactorPlan>> = std::thread::scope(|s| {
            let handles: Vec<_> = (0..n_threads)
                .map(|_| {
                    let (cache, barrier, a, opts) = (&cache, &barrier, &a, &opts);
                    s.spawn(move || {
                        barrier.wait();
                        cache.get_or_build(a, opts, None).unwrap()
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        for p in &plans[1..] {
            assert!(Arc::ptr_eq(&plans[0], p), "racers must share the leader's plan");
        }
        let inner = cache.lock();
        assert_eq!(inner.misses(), 1, "the storm costs exactly one build");
        assert_eq!(inner.hits() + 1, n_threads, "every follower counts as a hit");
        assert_eq!(inner.len(), 1);
    }

    #[test]
    fn shared_cache_singular_build_fails_every_racer_and_caches_nothing() {
        let mut coo = crate::sparse::Coo::new(4, 4);
        for i in 0..4 {
            if i != 1 {
                coo.push(i, i, 2.0);
            }
        }
        coo.push(0, 1, 1.0);
        let a = coo.to_csc();
        let cache = SharedPlanCache::new(4);
        let err = cache.get_or_build(&a, &SolveOptions::ours(1), None).unwrap_err();
        assert_eq!(err, FactorError::StructurallySingular { row: 1 });
        assert!(cache.lock().is_empty(), "failed builds are never cached");
    }
}
