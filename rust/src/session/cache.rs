//! [`PlanCache`] — LRU cache of [`FactorPlan`]s keyed by pattern
//! fingerprint + solve-options signature.
//!
//! Serving workloads see a small working set of sparsity patterns (one
//! per netlist / mesh / model under simulation) hit by a huge stream of
//! numeric re-factorizations. The cache makes plan reuse automatic: the
//! first request for a pattern pays the full structure analysis, every
//! later request gets the shared `Arc<FactorPlan>` back in O(capacity).

use super::plan::FactorPlan;
use crate::solver::{BlockingPolicy, SolveOptions};
use crate::sparse::Csc;
use std::sync::Arc;

/// Least-recently-used plan cache.
pub struct PlanCache {
    capacity: usize,
    /// LRU order: index 0 = least recent, last = most recent. Linear
    /// scans are fine at the capacities that make sense here (a handful
    /// to a few hundred patterns).
    entries: Vec<(u64, Arc<FactorPlan>)>,
    hits: usize,
    misses: usize,
}

impl PlanCache {
    /// Cache holding up to `capacity` plans.
    pub fn new(capacity: usize) -> Self {
        assert!(capacity > 0, "PlanCache needs capacity >= 1");
        Self { capacity, entries: Vec::new(), hits: 0, misses: 0 }
    }

    /// The cache key for a matrix under given options: pattern
    /// fingerprint mixed with an options signature, so the same pattern
    /// under different blocking/kernel/worker settings gets distinct
    /// plans.
    pub fn key_for(a: &Csc, opts: &SolveOptions) -> u64 {
        splitmix(a.pattern_fingerprint() ^ options_signature(opts))
    }

    /// Fetch the plan for `(a, opts)`, building and inserting it on miss.
    /// On hit the plan is additionally verified against `a` (shape + nnz
    /// + fingerprint) so a hash collision can never hand back a plan for
    /// a different pattern. The pattern is hashed once per call.
    pub fn get_or_build(&mut self, a: &Csc, opts: &SolveOptions) -> Arc<FactorPlan> {
        let fp = a.pattern_fingerprint();
        let key = splitmix(fp ^ options_signature(opts));
        if let Some(pos) = self.entries.iter().position(|(k, _)| *k == key) {
            let p = &self.entries[pos].1;
            if p.fingerprint() == fp
                && p.n() == a.n_rows()
                && p.n() == a.n_cols()
                && p.nnz_a() == a.nnz()
            {
                self.hits += 1;
                let entry = self.entries.remove(pos);
                let plan = entry.1.clone();
                self.entries.push(entry); // move to most-recent
                return plan;
            }
            // fingerprint collision: evict the impostor and rebuild
            self.entries.remove(pos);
        }
        self.misses += 1;
        let plan = Arc::new(FactorPlan::build(a, opts));
        if self.entries.len() == self.capacity {
            self.entries.remove(0); // evict least-recent
        }
        self.entries.push((key, plan.clone()));
        plan
    }

    /// The cache key a (session) plan indexes under — the same key
    /// [`Self::get_or_build`] computes for the matrix/options pair the
    /// plan was built from.
    pub fn key_of_plan(plan: &FactorPlan) -> u64 {
        splitmix(plan.fingerprint() ^ options_signature(plan.options()))
    }

    /// Insert an already-built plan (e.g. one deserialized from disk by
    /// [`crate::serve::persist`]) under its own key, as most-recent. A
    /// plan already cached under the same key is replaced; the
    /// least-recent entry is evicted if the cache is full. Later
    /// `get_or_build` calls for the same pattern + options hit without
    /// rebuilding.
    pub fn insert(&mut self, plan: Arc<FactorPlan>) {
        let key = Self::key_of_plan(&plan);
        if let Some(pos) = self.entries.iter().position(|(k, _)| *k == key) {
            self.entries.remove(pos);
        } else if self.entries.len() == self.capacity {
            self.entries.remove(0); // evict least-recent
        }
        self.entries.push((key, plan));
    }

    /// Refresh `key` to most-recently-used without fetching the plan.
    /// Returns whether the key was present.
    ///
    /// The serving router calls this on every request routed to a
    /// tenant, so the cache's LRU order tracks *traffic* recency — the
    /// same order [`crate::serve::Router`] consults ([`Self::keys_lru`])
    /// when it must pick a shard to evict.
    pub fn touch(&mut self, key: u64) -> bool {
        if let Some(pos) = self.entries.iter().position(|(k, _)| *k == key) {
            let entry = self.entries.remove(pos);
            self.entries.push(entry);
            true
        } else {
            false
        }
    }

    /// Cached keys, least-recently-used first. A key absent from this
    /// list has been evicted (or was never cached) — a shard whose plan
    /// the cache already dropped is the most evictable of all.
    pub fn keys_lru(&self) -> Vec<u64> {
        self.entries.iter().map(|(k, _)| *k).collect()
    }

    /// Plans currently cached.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn hits(&self) -> usize {
        self.hits
    }

    pub fn misses(&self) -> usize {
        self.misses
    }

    /// Drop every cached plan (counters are kept).
    pub fn clear(&mut self) {
        self.entries.clear();
    }

    /// Test-only: insert `plan` under an arbitrary `key`, bypassing
    /// [`Self::key_for`] — forges the hash collision the verification
    /// path in [`Self::get_or_build`] exists to catch.
    #[cfg(test)]
    fn insert_forged(&mut self, key: u64, plan: Arc<FactorPlan>) {
        self.entries.push((key, plan));
    }
}

/// Hash every option that influences a plan's structure or costs.
fn options_signature(opts: &SolveOptions) -> u64 {
    let mut h: u64 = 0x9e37_79b9_7f4a_7c15;
    let mut mix = |x: u64| h = splitmix(h ^ x);
    mix(opts.ordering as u64);
    match &opts.blocking {
        BlockingPolicy::Regular(s) => {
            mix(1);
            mix(*s as u64);
        }
        BlockingPolicy::PanguSelect => mix(2),
        BlockingPolicy::Irregular => mix(3),
    }
    mix(opts.kernels.dense_threshold.to_bits());
    mix(opts.kernels.force_dense as u64);
    mix(opts.kernels.use_runtime as u64);
    mix(opts.workers as u64);
    let ir = &opts.irregular;
    mix(ir.sample_points as u64);
    mix(ir.step as u64);
    mix(ir.max_num as u64);
    mix(ir.threshold.map_or(u64::MAX, f64::to_bits));
    mix(ir.min_block as u64);
    let m = &opts.model;
    for f in [
        m.peak_flops,
        m.mem_bw,
        m.launch_overhead,
        m.eff_sparse_factor,
        m.eff_sparse_update,
        m.eff_dense,
        m.link_bw,
        m.link_latency,
        m.col_latency,
        m.col_latency_quad,
        m.sat_half_work,
    ] {
        mix(f.to_bits());
    }
    mix(m.concurrent_kernels as u64);
    drop(mix);
    h
}

/// splitmix64 finalizer — cheap avalanche for the key mix.
fn splitmix(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9e37_79b9_7f4a_7c15);
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::gen;

    #[test]
    fn second_request_hits_and_shares_plan() {
        let a = gen::grid2d_laplacian(8, 8);
        let mut cache = PlanCache::new(4);
        let p1 = cache.get_or_build(&a, &SolveOptions::ours(1));
        let p2 = cache.get_or_build(&a, &SolveOptions::ours(1));
        assert!(Arc::ptr_eq(&p1, &p2), "hit must return the same plan");
        assert_eq!((cache.hits(), cache.misses()), (1, 1));
        assert_eq!(cache.len(), 1);
    }

    #[test]
    fn same_pattern_new_values_still_hits() {
        let a = gen::grid2d_laplacian(8, 8);
        let mut b = a.clone();
        for v in &mut b.values {
            *v *= 1.5;
        }
        let mut cache = PlanCache::new(4);
        let p1 = cache.get_or_build(&a, &SolveOptions::ours(1));
        let p2 = cache.get_or_build(&b, &SolveOptions::ours(1));
        assert!(Arc::ptr_eq(&p1, &p2));
        assert_eq!(cache.hits(), 1);
    }

    #[test]
    fn different_options_get_distinct_plans() {
        let a = gen::grid2d_laplacian(8, 8);
        let mut cache = PlanCache::new(4);
        let p1 = cache.get_or_build(&a, &SolveOptions::ours(1));
        let p2 = cache.get_or_build(&a, &SolveOptions::pangulu(1));
        let p3 = cache.get_or_build(&a, &SolveOptions::ours(2));
        assert!(!Arc::ptr_eq(&p1, &p2));
        assert!(!Arc::ptr_eq(&p1, &p3));
        assert_eq!(cache.misses(), 3);
        assert_eq!(cache.len(), 3);
    }

    #[test]
    fn forged_key_collision_rejected_and_rebuilt() {
        // a plan for pattern A sits in the slot pattern B's key hashes to
        // (as if splitmix collided); the verification pass must evict the
        // impostor and build a genuine plan for B instead of handing A's
        // plan back.
        let a = gen::grid2d_laplacian(6, 6);
        let b = gen::grid2d_laplacian(6, 7);
        let opts = SolveOptions::ours(1);
        let impostor = Arc::new(FactorPlan::build(&a, &opts));
        let mut cache = PlanCache::new(4);
        cache.insert_forged(PlanCache::key_for(&b, &opts), impostor.clone());
        assert_eq!(cache.len(), 1);

        let got = cache.get_or_build(&b, &opts);
        assert!(!Arc::ptr_eq(&got, &impostor), "collision must not serve the impostor");
        assert_eq!(got.fingerprint(), b.pattern_fingerprint());
        assert_eq!((cache.hits(), cache.misses()), (0, 1));
        assert_eq!(cache.len(), 1, "impostor evicted, genuine plan cached");

        // the genuine plan now hits normally
        let again = cache.get_or_build(&b, &opts);
        assert!(Arc::ptr_eq(&got, &again));
        assert_eq!(cache.hits(), 1);
    }

    #[test]
    fn forged_collision_same_shape_and_nnz_still_rejected() {
        // same n, same nnz, different pattern: only the fingerprint check
        // can tell them apart on the verification path
        let mk = |shift: usize| {
            let mut coo = crate::sparse::Coo::new(6, 6);
            for i in 0..6 {
                coo.push(i, i, 4.0);
            }
            // one off-diagonal pair, placed differently per matrix
            coo.push(shift, 5 - shift, 1.0);
            coo.push(5 - shift, shift, 1.0);
            coo.to_csc()
        };
        let a = mk(0);
        let b = mk(1);
        assert_eq!(a.nnz(), b.nnz());
        assert_ne!(a.pattern_fingerprint(), b.pattern_fingerprint());
        let opts = SolveOptions::ours(1);
        let impostor = Arc::new(FactorPlan::build(&a, &opts));
        let mut cache = PlanCache::new(2);
        cache.insert_forged(PlanCache::key_for(&b, &opts), impostor.clone());
        let got = cache.get_or_build(&b, &opts);
        assert!(!Arc::ptr_eq(&got, &impostor));
        assert_eq!(got.fingerprint(), b.pattern_fingerprint());
        assert_eq!(cache.misses(), 1);
    }

    #[test]
    fn inserted_plan_hits_without_rebuilding() {
        let a = gen::grid2d_laplacian(8, 8);
        let opts = SolveOptions::ours(1);
        let plan = Arc::new(FactorPlan::build(&a, &opts));
        let mut cache = PlanCache::new(2);
        cache.insert(plan.clone());
        assert_eq!(cache.len(), 1);
        let got = cache.get_or_build(&a, &opts);
        assert!(Arc::ptr_eq(&got, &plan), "warm insert must serve the same plan");
        assert_eq!((cache.hits(), cache.misses()), (1, 0));
        // re-inserting under the same key replaces rather than grows
        cache.insert(plan.clone());
        assert_eq!(cache.len(), 1);
    }

    #[test]
    fn touch_refreshes_recency_and_keys_lru_reports_order() {
        let mats =
            [gen::grid2d_laplacian(6, 6), gen::grid2d_laplacian(6, 7), gen::grid2d_laplacian(7, 7)];
        let opts = SolveOptions::ours(1);
        let mut cache = PlanCache::new(3);
        let keys: Vec<u64> = mats
            .iter()
            .map(|a| {
                cache.get_or_build(a, &opts);
                PlanCache::key_for(a, &opts)
            })
            .collect();
        assert_eq!(cache.keys_lru(), keys, "insertion order = recency order");
        // touching the least-recent key moves it to the back
        assert!(cache.touch(keys[0]));
        assert_eq!(cache.keys_lru(), vec![keys[1], keys[2], keys[0]]);
        assert!(!cache.touch(0xDEAD_BEEF), "unknown key untouched");
        // a touched entry survives the next eviction
        cache.get_or_build(&gen::grid2d_laplacian(7, 8), &opts); // evicts keys[1]
        assert!(cache.keys_lru().contains(&keys[0]));
        assert!(!cache.keys_lru().contains(&keys[1]));
    }

    #[test]
    fn lru_evicts_oldest() {
        let mats = [
            gen::grid2d_laplacian(6, 6),
            gen::grid2d_laplacian(6, 7),
            gen::grid2d_laplacian(7, 7),
        ];
        let opts = SolveOptions::ours(1);
        let mut cache = PlanCache::new(2);
        cache.get_or_build(&mats[0], &opts);
        cache.get_or_build(&mats[1], &opts);
        cache.get_or_build(&mats[0], &opts); // refresh 0 → 1 is now LRU
        cache.get_or_build(&mats[2], &opts); // evicts 1
        assert_eq!(cache.len(), 2);
        cache.get_or_build(&mats[0], &opts); // still cached
        assert_eq!(cache.hits(), 2);
        cache.get_or_build(&mats[1], &opts); // was evicted → miss
        assert_eq!(cache.misses(), 4);
    }
}
