//! Symbolic factorization (the paper's phase 2).
//!
//! Determines the nonzero structure of L and U before any floating-point
//! work. Because the reproduction follows the paper's assumption that the
//! post-symbolic matrix has a **symmetric pattern** (§4.2, citing PanguLU),
//! we compute the symbolic Cholesky pattern of `A + Aᵀ`: `pattern(L)` and
//! `pattern(U) = pattern(L)ᵀ`.
//!
//! Implementation: elimination tree (Liu) + up-looking row-pattern
//! traversal (Gilbert–Ng–Peyton), both O(nnz(L)).

pub mod etree;
pub mod fill;

pub use etree::{etree, postorder};
pub use fill::{analyze, analyze_on, Symbolic};

#[cfg(test)]
mod tests {
    use crate::sparse::gen;

    #[test]
    fn arrow_matrices_fill_extremes() {
        // Fig 2 of the paper: arrow-up ⇒ full fill; arrow-down ⇒ none.
        let n = 40;
        let up = super::analyze(&gen::arrow_up(n));
        let down = super::analyze(&gen::arrow_down(n));
        assert_eq!(up.nnz_ldu(), n * n, "arrow-up must fill completely");
        assert_eq!(down.nnz_ldu(), 3 * n - 2, "arrow-down must not fill");
    }
}
