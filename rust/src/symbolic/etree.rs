//! Elimination tree of a symmetric-pattern matrix (Liu's algorithm with
//! path compression) and its postorder.

/// Sentinel for "no parent" (tree root).
pub const NONE: usize = usize::MAX;

/// Elimination tree of the symmetric pattern `m` (use
/// `a.plus_transpose_pattern()` for unsymmetric A). `parent[j]` is the
/// etree parent of column j, or [`NONE`] for roots.
pub fn etree(m: &crate::sparse::Csc) -> Vec<usize> {
    let n = m.n_cols();
    let mut parent = vec![NONE; n];
    let mut ancestor = vec![NONE; n];
    for j in 0..n {
        for &i in m.col_rows(j) {
            if i >= j {
                continue; // lower part / diagonal: skip (we walk k < j)
            }
            // climb from i to the root of its current subtree, compressing
            let mut k = i;
            while ancestor[k] != NONE && ancestor[k] != j {
                let next = ancestor[k];
                ancestor[k] = j; // path compression
                k = next;
            }
            if ancestor[k] == NONE {
                ancestor[k] = j;
                parent[k] = j;
            }
        }
    }
    parent
}

/// Postorder of the forest given by `parent` (children visited in index
/// order). Returns `post` with `post[k]` = k-th node in postorder.
pub fn postorder(parent: &[usize]) -> Vec<usize> {
    let n = parent.len();
    // build child lists
    let mut head = vec![NONE; n];
    let mut next = vec![NONE; n];
    // iterate in reverse so child lists come out in ascending order
    for v in (0..n).rev() {
        let p = parent[v];
        if p != NONE {
            next[v] = head[p];
            head[p] = v;
        }
    }
    let mut post = Vec::with_capacity(n);
    let mut stack = Vec::new();
    for root in 0..n {
        if parent[root] != NONE {
            continue;
        }
        // iterative DFS producing postorder
        stack.push((root, false));
        while let Some((v, expanded)) = stack.pop() {
            if expanded {
                post.push(v);
                continue;
            }
            stack.push((v, true));
            let mut c = head[v];
            let mut kids = Vec::new();
            while c != NONE {
                kids.push(c);
                c = next[c];
            }
            // push in reverse so the smallest child is processed first
            for &k in kids.iter().rev() {
                stack.push((k, false));
            }
        }
    }
    post
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::{gen, Coo};

    #[test]
    fn tridiagonal_etree_is_a_path() {
        let m = gen::tridiagonal(6);
        let p = etree(&m);
        assert_eq!(p, vec![1, 2, 3, 4, 5, NONE]);
    }

    #[test]
    fn arrow_down_etree_is_a_star_path() {
        // all columns connect only to the last: parent[i] = n-1 directly?
        // For arrow-down, col j has entries {j, n-1}; etree parent of each
        // j < n-1 is n-1.
        let m = gen::arrow_down(5);
        let p = etree(&m);
        assert_eq!(p, vec![4, 4, 4, 4, NONE]);
    }

    #[test]
    fn disconnected_gives_forest() {
        let mut coo = Coo::new(4, 4);
        coo.push_sym(0, 1, 1.0);
        coo.push_sym(2, 3, 1.0);
        for i in 0..4 {
            coo.push(i, i, 2.0);
        }
        let p = etree(&coo.to_csc());
        assert_eq!(p, vec![1, NONE, 3, NONE]);
    }

    #[test]
    fn postorder_visits_children_before_parents() {
        let m = gen::grid2d_laplacian(5, 5).plus_transpose_pattern();
        let parent = etree(&m);
        let post = postorder(&parent);
        assert_eq!(post.len(), 25);
        let mut pos = vec![0usize; 25];
        for (k, &v) in post.iter().enumerate() {
            pos[v] = k;
        }
        for v in 0..25 {
            if parent[v] != NONE {
                assert!(pos[v] < pos[parent[v]], "child {v} after parent");
            }
        }
    }

    #[test]
    fn postorder_is_permutation() {
        let m = gen::directed_graph(60, 3, 1).plus_transpose_pattern();
        let parent = etree(&m);
        let mut post = postorder(&parent);
        post.sort_unstable();
        assert_eq!(post, (0..60).collect::<Vec<_>>());
    }
}
