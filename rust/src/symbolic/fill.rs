//! Fill-in computation: the full `L+U` pattern with fill, per-column counts
//! and the factorization flop count (the paper's Table 3 reports
//! `nnz(L+U)` and FLOPs for every benchmark matrix).

use super::etree::{self, NONE};
use crate::coordinator::{par_chunks, Executor};
use crate::numeric::factor::FactorError;
use crate::sparse::Csc;

/// Result of symbolic factorization on the symmetrized pattern.
#[derive(Clone, Debug)]
pub struct Symbolic {
    n: usize,
    /// Elimination tree parents.
    pub parent: Vec<usize>,
    /// Row patterns of L, excluding the diagonal: `row_pats[i]` lists the
    /// columns `k < i` with `L[i,k] ≠ 0`, sorted ascending.
    pub row_pats: Vec<Vec<usize>>,
    /// Per-column nonzero counts of L **including** the diagonal.
    pub col_counts: Vec<usize>,
}

impl Symbolic {
    pub fn n(&self) -> usize {
        self.n
    }

    /// nnz(L) including the unit diagonal.
    pub fn nnz_l(&self) -> usize {
        self.col_counts.iter().sum()
    }

    /// nnz(L+U) with the shared diagonal counted once (the paper's metric).
    pub fn nnz_ldu(&self) -> usize {
        2 * self.nnz_l() - self.n
    }

    /// Fill-ratio versus the original matrix.
    pub fn fill_ratio(&self, a: &Csc) -> f64 {
        self.nnz_ldu() as f64 / a.nnz() as f64
    }

    /// Exact flop count of no-pivot LU on the symmetric pattern:
    /// per pivot k with c_k below-diagonal entries in column k of L,
    /// `c_k` divisions + `2·c_k²` multiply-adds in the rank-1 update.
    pub fn flops(&self) -> f64 {
        self.col_counts
            .iter()
            .map(|&c| {
                let ck = (c - 1) as f64;
                ck + 2.0 * ck * ck
            })
            .sum()
    }

    /// Assemble the full `L+U` pattern as a CSC matrix with values taken
    /// from `a` (zero at fill positions). Column `j` holds the U-part rows
    /// `k < j`, the diagonal, and the L-part rows `i > j`, sorted.
    ///
    /// `a` must be the same (permuted) matrix that was analyzed: an entry
    /// of `a` falling outside the symbolic pattern returns
    /// [`FactorError::OutOfPattern`] (a serving path handed a mismatched
    /// matrix must get an error back, not abort the process).
    pub fn ldu_pattern(&self, a: &Csc) -> Result<Csc, FactorError> {
        let n = self.n;
        if a.n_cols() != n {
            return Err(FactorError::DimensionMismatch { got: a.n_cols(), want: n });
        }
        // counts: col j gets |row_pats[j]| U-entries + 1 diag + below-diag
        // L entries (row i > j has j in row_pats[i]).
        let mut cnt = vec![0usize; n + 1];
        for j in 0..n {
            cnt[j + 1] += self.row_pats[j].len() + 1;
        }
        for (i, pat) in self.row_pats.iter().enumerate() {
            debug_assert!(i < n);
            for &k in pat {
                cnt[k + 1] += 1;
            }
        }
        for j in 0..n {
            cnt[j + 1] += cnt[j];
        }
        let col_ptr = cnt;
        let nnz = col_ptr[n];
        let mut row_idx = vec![0usize; nnz];
        let mut next = col_ptr.clone();
        // U-part + diagonal first (rows < j then j, ascending because
        // row_pats are sorted), then L-part appended in ascending row order
        // by iterating i ascending.
        for j in 0..n {
            for &k in &self.row_pats[j] {
                // U entry U[k, j] — row k of column j
                let p = next[j];
                row_idx[p] = k;
                next[j] += 1;
            }
            let p = next[j];
            row_idx[p] = j; // diagonal
            next[j] += 1;
        }
        for i in 0..n {
            for &k in &self.row_pats[i] {
                // L entry L[i, k] — row i of column k; i ascending keeps order
                let p = next[k];
                row_idx[p] = i;
                next[k] += 1;
            }
        }
        // scatter A's values into the pattern (single allocation pass —
        // perf opt-4: the previous version built the CSC twice)
        let mut values = vec![0.0f64; nnz];
        for j in 0..n {
            let (base, end) = (col_ptr[j], col_ptr[j + 1]);
            let rows = &row_idx[base..end];
            for (i, v) in a.col(j) {
                match rows.binary_search(&i) {
                    Ok(k) => values[base + k] = v,
                    Err(_) => return Err(FactorError::OutOfPattern { row: i, col: j }),
                }
            }
        }
        let out = Csc::from_parts_unchecked(n, n, col_ptr, row_idx, values);
        debug_assert!(out.validate().is_ok(), "{:?}", out.validate());
        Ok(out)
    }
}

/// Run symbolic factorization on (the symmetrization of) `a`.
///
/// Computes the elimination tree of `pattern(A+Aᵀ)` and the row patterns of
/// the Cholesky factor L by the up-looking traversal: the pattern of row
/// `i` is the union of etree paths from each `k` (with `M[i,k] ≠ 0`,
/// `k < i`) up toward `i`.
pub fn analyze(a: &Csc) -> Symbolic {
    match analyze_on(a, None) {
        Ok(sym) => sym,
        Err(_) => unreachable!("sequential symbolic analysis cannot fail"),
    }
}

/// As [`analyze`] but the input is already a symmetric pattern.
pub fn analyze_symmetric(m: &Csc) -> Symbolic {
    match analyze_symmetric_on(m, None) {
        Ok(sym) => sym,
        Err(_) => unreachable!("sequential symbolic analysis cannot fail"),
    }
}

/// As [`analyze`], computing the per-row reach sets on `exec` when one is
/// given. The elimination tree is built sequentially (it is a cheap
/// O(nnz·α) pass and every row's traversal depends on it), then the rows'
/// etree climbs run independently — each row's pattern is a pure function
/// of the fixed tree and that row's adjacency (the GSoFa observation), so
/// the result is bit-identical at every worker count.
///
/// The only possible `Err` is [`FactorError::TaskPanic`] out of the pool;
/// the analysis itself cannot fail.
pub fn analyze_on(a: &Csc, exec: Option<&Executor>) -> Result<Symbolic, FactorError> {
    assert_eq!(a.n_rows(), a.n_cols(), "symbolic analysis needs square A");
    let m = a.plus_transpose_pattern();
    analyze_symmetric_on(&m, exec)
}

/// As [`analyze_symmetric`], with the per-row reach sets computed on
/// `exec` when one is given (see [`analyze_on`]).
pub fn analyze_symmetric_on(m: &Csc, exec: Option<&Executor>) -> Result<Symbolic, FactorError> {
    let n = m.n_cols();
    let parent = etree::etree(m);
    let mut row_pats: Vec<Vec<usize>> = vec![Vec::new(); n];
    par_chunks(exec, &mut row_pats, &|start, pats| {
        // per-chunk mark scratch: the sequential pass reused one `mark`
        // across rows purely as an optimization — per-row semantics are
        // identical since `mark[t] == i` is only ever tested against the
        // current row index
        let mut mark = vec![usize::MAX; n];
        for (off, pat) in pats.iter_mut().enumerate() {
            let i = start + off;
            mark[i] = i;
            // entries k < i of row i == entries k < i of column i
            // (symmetry)
            for &k in m.col_rows(i) {
                if k >= i {
                    break; // columns are sorted ascending
                }
                let mut t = k;
                while mark[t] != i {
                    mark[t] = i;
                    pat.push(t);
                    t = parent[t];
                    debug_assert_ne!(t, NONE, "etree path must reach row {i}");
                }
            }
            pat.sort_unstable();
        }
    })?;
    // column counts are a cheap sequential reduction over the row
    // patterns (the sequential pass incremented them inline; summing
    // afterwards counts exactly the same memberships)
    let mut col_counts = vec![1usize; n]; // diagonal
    for pat in &row_pats {
        for &k in pat {
            col_counts[k] += 1;
        }
    }
    Ok(Symbolic { n, parent, row_pats, col_counts })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::gen;

    /// Dense reference: simulate fill by dense elimination on the pattern.
    fn dense_fill_pattern(a: &Csc) -> Vec<Vec<bool>> {
        let n = a.n_cols();
        let m = a.plus_transpose_pattern();
        let mut p = vec![vec![false; n]; n];
        for j in 0..n {
            for (i, _) in m.col(j) {
                p[i][j] = true;
            }
        }
        for i in 0..n {
            p[i][i] = true;
        }
        for k in 0..n {
            for i in (k + 1)..n {
                if p[i][k] {
                    for j in (k + 1)..n {
                        if p[k][j] {
                            p[i][j] = true;
                        }
                    }
                }
            }
        }
        p
    }

    fn check_against_dense(a: &Csc) {
        let sym = analyze(a);
        let ldu = sym.ldu_pattern(a).unwrap();
        let dense = dense_fill_pattern(a);
        let n = a.n_cols();
        let mut nnz_dense = 0;
        for (i, row) in dense.iter().enumerate() {
            for (j, &set) in row.iter().enumerate() {
                if set {
                    nnz_dense += 1;
                    assert!(
                        ldu.col_rows(j).binary_search(&i).is_ok(),
                        "missing fill entry ({i},{j}) n={n}"
                    );
                }
            }
        }
        assert_eq!(ldu.nnz(), nnz_dense, "extra entries beyond dense fill");
        assert_eq!(sym.nnz_ldu(), nnz_dense);
    }

    #[test]
    fn matches_dense_fill_on_tridiagonal() {
        check_against_dense(&gen::tridiagonal(12));
    }

    #[test]
    fn matches_dense_fill_on_grid() {
        check_against_dense(&gen::grid2d_laplacian(5, 4));
    }

    #[test]
    fn matches_dense_fill_on_random_unsymmetric() {
        check_against_dense(&gen::directed_graph(40, 3, 17));
    }

    #[test]
    fn matches_dense_fill_on_arrow() {
        check_against_dense(&gen::arrow_up(15));
        check_against_dense(&gen::arrow_down(15));
    }

    #[test]
    fn matches_dense_fill_on_local_dense() {
        check_against_dense(&gen::local_dense_blocks(50, &[(10, 12)], 2, 5));
    }

    #[test]
    fn ldu_values_match_a() {
        let a = gen::grid2d_laplacian(4, 4);
        let sym = analyze(&a);
        let ldu = sym.ldu_pattern(&a).unwrap();
        for j in 0..16 {
            for (i, v) in a.col(j) {
                assert_eq!(ldu.get(i, j), v);
            }
        }
    }

    #[test]
    fn tridiagonal_has_no_fill() {
        let a = gen::tridiagonal(100);
        let sym = analyze(&a);
        assert_eq!(sym.nnz_ldu(), a.nnz());
        assert_eq!(sym.fill_ratio(&a), 1.0);
    }

    #[test]
    fn flops_of_dense_matrix() {
        // fully dense: c_k = n-1-k; flops = Σ c + 2c²  — compare with
        // direct summation.
        let a = gen::arrow_up(10); // fills to dense
        let sym = analyze(&a);
        let expected: f64 = (0..10)
            .map(|k| {
                let c = (10 - 1 - k) as f64;
                c + 2.0 * c * c
            })
            .sum();
        assert_eq!(sym.flops(), expected);
    }

    #[test]
    fn mismatched_matrix_returns_out_of_pattern_error() {
        // analyze a tridiagonal (no fill), then hand ldu_pattern a matrix
        // with an entry the symbolic pattern cannot contain — the serving
        // contract is a clean error, not a process abort
        let a = gen::tridiagonal(6);
        let sym = analyze(&a);
        let mut coo = crate::sparse::Coo::new(6, 6);
        for i in 0..6 {
            coo.push(i, i, 2.0);
        }
        coo.push(0, 5, 1.0); // far off-band, outside the tridiagonal fill
        let b = coo.to_csc();
        match sym.ldu_pattern(&b) {
            Err(FactorError::OutOfPattern { row: 0, col: 5 }) => {}
            other => panic!("expected OutOfPattern(0,5), got {other:?}"),
        }
        // a wrong-dimension matrix is an error too, not an abort
        let c = gen::tridiagonal(7);
        assert!(matches!(
            sym.ldu_pattern(&c),
            Err(FactorError::DimensionMismatch { got: 7, want: 6 })
        ));
    }

    #[test]
    fn parallel_analysis_is_bit_identical_to_sequential() {
        let mats = [
            gen::grid2d_laplacian(16, 16),
            gen::circuit_bbd(gen::CircuitParams { n: 500, ..Default::default() }),
            gen::directed_graph(200, 4, 7),
        ];
        for a in &mats {
            let seq = analyze(a);
            for workers in [2u32, 8] {
                let exec = crate::coordinator::Executor::shared(workers);
                let par = analyze_on(a, Some(&exec)).unwrap();
                assert_eq!(par.parent, seq.parent, "workers={workers}");
                assert_eq!(par.row_pats, seq.row_pats, "workers={workers}");
                assert_eq!(par.col_counts, seq.col_counts, "workers={workers}");
            }
        }
    }

    #[test]
    fn col_counts_sum_to_nnz_l() {
        let a = gen::grid2d_laplacian(6, 6);
        let sym = analyze(&a);
        let total: usize = sym.col_counts.iter().sum();
        assert_eq!(total, sym.nnz_l());
        // L below-diag entries + U above-diag + diag == nnz_ldu
        assert_eq!(2 * sym.nnz_l() - 36, sym.nnz_ldu());
    }
}
