//! Fig 10 / Fig 12 (PanguLU_Best block-size sweeps vs irregular blocking)
//! and the §5.4 preprocessing-cost comparison.

use super::{matrices, write_csv, SuiteScale, TablePrinter};
use crate::solver::{SolveOptions, Solver};
use crate::util::stats::geomean;
use std::path::Path;

/// Sweep all regular sizes; return ((size, time) best by measured,
/// (size, time) best by modeled makespan).
fn best_regular(
    matrix: &crate::sparse::Csc,
    workers: u32,
) -> anyhow::Result<((usize, f64), (usize, f64))> {
    let options = crate::blocking::selection::scaled_options(matrix.n_cols());
    let mut best_meas: Option<(usize, f64)> = None;
    let mut best_model: Option<(usize, f64)> = None;
    for &bs in &options {
        let mut solver = Solver::new(SolveOptions::pangulu_with_size(workers, bs));
        let f = solver
            .factorize(matrix)
            .map_err(|e| anyhow::anyhow!("block size {bs}: {e}"))?;
        let t = f.report.numeric_seconds;
        let m = f.report.modeled_makespan;
        if best_meas.map(|(_, bt)| t < bt).unwrap_or(true) {
            best_meas = Some((bs, t));
        }
        if best_model.map(|(_, bm)| m < bm).unwrap_or(true) {
            best_model = Some((bs, m));
        }
    }
    Ok((best_meas.unwrap(), best_model.unwrap()))
}

fn pangulu_best_sweep(
    out_dir: &Path,
    scale: SuiteScale,
    workers: u32,
    fig: &str,
    paper_avg: &str,
) -> anyhow::Result<()> {
    println!(
        "{} — PanguLU / PanguLU_Best / Ours on {} device(s) (paper avg PanguLU_Best speedup {})",
        fig.to_uppercase(),
        workers,
        paper_avg
    );
    let tp = TablePrinter::new(
        &[
            "Matrix", "PanguLU(s)", "Best(s)", "best size", "Ours(s)", "Best/PanguLU",
            "Ours/Best", "mOurs/mBest",
        ],
        &[18, 11, 10, 10, 10, 13, 10, 12],
    );
    let mut csv = String::from(
        "matrix,pangulu_s,best_s,best_size,ours_s,best_speedup,ours_vs_best,modeled_ours_vs_best\n",
    );
    let mut best_speedups = Vec::new();
    let mut ours_vs_best = Vec::new();
    let mut modeled_ours_vs_best = Vec::new();
    for m in matrices::paper_suite(scale) {
        let run = |opts: SolveOptions| -> anyhow::Result<(f64, f64)> {
            let mut solver = Solver::new(opts);
            let f = solver
                .factorize(&m.matrix)
                .map_err(|e| anyhow::anyhow!("{}: {e}", m.name))?;
            Ok((f.report.numeric_seconds, f.report.modeled_makespan))
        };
        let (pangulu, _) = run(SolveOptions::pangulu(workers))?;
        let ((bs, best), (_, best_modeled)) = best_regular(&m.matrix, workers)?;
        let (ours, ours_modeled) = run(SolveOptions::ours(workers))?;
        let sp_best = pangulu / best;
        let sp_ours = best / ours;
        let sp_ours_modeled = best_modeled / ours_modeled;
        best_speedups.push(sp_best);
        ours_vs_best.push(sp_ours);
        modeled_ours_vs_best.push(sp_ours_modeled);
        tp.row(&[
            m.name,
            &format!("{pangulu:.3}"),
            &format!("{best:.3}"),
            &bs.to_string(),
            &format!("{ours:.3}"),
            &format!("{sp_best:.2}x"),
            &format!("{sp_ours:.2}x"),
            &format!("{sp_ours_modeled:.2}x"),
        ]);
        csv.push_str(&format!(
            "{},{pangulu:.6},{best:.6},{bs},{ours:.6},{sp_best:.3},{sp_ours:.3},{sp_ours_modeled:.3}\n",
            m.name
        ));
    }
    println!(
        "AVG: PanguLU_Best over PanguLU {:.2}x (paper {paper_avg}); Ours over Best \
         {:.2}x measured / {:.2}x modeled-A100",
        geomean(&best_speedups),
        geomean(&ours_vs_best),
        geomean(&modeled_ours_vs_best)
    );
    csv.push_str(&format!(
        "GEOMEAN,,,,,{:.3},{:.3},{:.3}\n",
        geomean(&best_speedups),
        geomean(&ours_vs_best),
        geomean(&modeled_ours_vs_best)
    ));
    write_csv(out_dir, &format!("{fig}.csv"), &csv)
}

/// Fig 10: single device.
pub fn fig10_pangulu_best(out_dir: &Path, scale: SuiteScale, workers: u32) -> anyhow::Result<()> {
    pangulu_best_sweep(out_dir, scale, workers, "fig10", "1.19x")
}

/// Fig 12: four devices.
pub fn fig12_pangulu_best(out_dir: &Path, scale: SuiteScale, workers: u32) -> anyhow::Result<()> {
    pangulu_best_sweep(out_dir, scale, workers, "fig12", "1.17x")
}

/// Ablations over the design choices DESIGN.md calls out: the sparse/dense
/// kernel threshold, Algorithm 3's (step, max_num) constants, and the
/// process-grid shape. Not a paper figure — supporting evidence for the
/// defaults.
pub fn ablations(out_dir: &Path, scale: SuiteScale) -> anyhow::Result<()> {
    use crate::blocking::IrregularParams;
    use crate::coordinator::Placement;
    use crate::numeric::KernelPolicy;

    let suite = matrices::paper_suite(scale);
    let em = &suite.iter().find(|m| m.name == "dielFilterV3real").unwrap().matrix;
    let bbd = &suite.iter().find(|m| m.name == "ASIC_680k").unwrap().matrix;

    println!("Ablation 1 — sparse/dense kernel threshold (dielFilter analogue, ours, 1 worker)");
    let mut csv = String::from("ablation,param,numeric_s,modeled_s\n");
    let tp = TablePrinter::new(&["dense_threshold", "numeric(s)", "modeled(s)"], &[16, 11, 11]);
    for thr in [0.05, 0.15, 0.30, 0.60, 1.01] {
        let mut opts = SolveOptions::ours(1);
        opts.kernels = KernelPolicy { dense_threshold: thr, ..Default::default() };
        let mut solver = Solver::new(opts);
        let r = solver
            .factorize(em)
            .map_err(|e| anyhow::anyhow!("thr {thr}: {e}"))?
            .report;
        tp.row(&[
            &format!("{thr:.2}"),
            &format!("{:.3}", r.numeric_seconds),
            &format!("{:.4}", r.modeled_makespan),
        ]);
        csv.push_str(&format!(
            "dense_threshold,{thr},{:.6},{:.6}\n",
            r.numeric_seconds, r.modeled_makespan
        ));
    }

    println!("\nAblation 2 — Algorithm 3 constants (ASIC analogue, 4 workers)");
    let tp = TablePrinter::new(
        &["step", "max_num", "blocks", "block-nnz CV", "numeric(s)"],
        &[6, 8, 8, 13, 11],
    );
    for (step, max_num) in [(1, 3), (2, 1), (2, 3), (2, 6), (4, 3)] {
        let mut opts = SolveOptions::ours(4);
        opts.irregular = IrregularParams { step, max_num, ..Default::default() };
        let mut solver = Solver::new(opts);
        let r = solver
            .factorize(bbd)
            .map_err(|e| anyhow::anyhow!("step {step} max {max_num}: {e}"))?
            .report;
        tp.row(&[
            &step.to_string(),
            &max_num.to_string(),
            &r.num_blocks.to_string(),
            &format!("{:.3}", r.balance.block_summary.cv()),
            &format!("{:.3}", r.numeric_seconds),
        ]);
        csv.push_str(&format!(
            "alg3,step{step}_max{max_num},{:.6},{:.6}\n",
            r.numeric_seconds, r.modeled_makespan
        ));
    }

    println!("\nAblation 3 — process grid shape (ASIC analogue, 4 workers, modeled)");
    let tp = TablePrinter::new(&["grid", "modeled makespan(s)", "modeled imbalance"], &[8, 20, 18]);
    for (label, placement) in [("2x2", Placement { pr: 2, pc: 2 }), ("1x4", Placement { pr: 1, pc: 4 }), ("4x1", Placement { pr: 4, pc: 1 })] {
        let perm = crate::ordering::order(bbd, crate::ordering::OrderingMethod::MinDegree);
        let pa = bbd.permute_sym(perm.as_slice());
        let sym = crate::symbolic::analyze(&pa);
        let ldu = sym.ldu_pattern(&pa).expect("A within its own symbolic pattern");
        let curve = crate::blocking::DiagFeature::from_csc(&ldu).curve();
        let blocking = crate::blocking::irregular_blocking(
            &curve,
            &crate::blocking::IrregularParams::default(),
        );
        let bm = crate::blocking::BlockedMatrix::build(&ldu, blocking);
        let model = crate::gpu_model::CostModel::a100();
        let dag = crate::coordinator::TaskDag::build(
            &bm,
            &crate::numeric::KernelPolicy::default(),
            placement,
            &model,
        );
        let sim = crate::coordinator::simulate(&dag, 4, &model);
        tp.row(&[
            label,
            &format!("{:.4}", sim.makespan),
            &format!("{:.3}", sim.imbalance()),
        ]);
        csv.push_str(&format!("grid,{label},{:.6},{:.6}\n", sim.makespan, sim.imbalance()));
    }
    write_csv(out_dir, "ablations.csv", &csv)
}

/// §5.4: preprocessing (blocking + partitioning + DAG) cost, regular vs
/// irregular, next to the numeric time it buys.
pub fn preprocessing_cost(out_dir: &Path, scale: SuiteScale) -> anyhow::Result<()> {
    println!("§5.4 — preprocessing cost: regular vs irregular blocking");
    let tp = TablePrinter::new(
        &["Matrix", "prep reg(s)", "prep irr(s)", "numeric reg(s)", "numeric irr(s)"],
        &[18, 12, 12, 14, 14],
    );
    let mut csv =
        String::from("matrix,prep_regular_s,prep_irregular_s,numeric_regular_s,numeric_irregular_s\n");
    for m in matrices::paper_suite(scale) {
        let run = |opts: SolveOptions| -> anyhow::Result<(f64, f64)> {
            let mut solver = Solver::new(opts);
            let f = solver
                .factorize(&m.matrix)
                .map_err(|e| anyhow::anyhow!("{}: {e}", m.name))?;
            Ok((f.report.preprocess_seconds, f.report.numeric_seconds))
        };
        let (prep_reg, num_reg) = run(SolveOptions::pangulu(1))?;
        let (prep_irr, num_irr) = run(SolveOptions::ours(1))?;
        tp.row(&[
            m.name,
            &format!("{prep_reg:.4}"),
            &format!("{prep_irr:.4}"),
            &format!("{num_reg:.3}"),
            &format!("{num_irr:.3}"),
        ]);
        csv.push_str(&format!(
            "{},{prep_reg:.6},{prep_irr:.6},{num_reg:.6},{num_irr:.6}\n",
            m.name
        ));
    }
    write_csv(out_dir, "prep_cost.csv", &csv)
}
