//! Plan-construction bench: the **cold-start** scenario behind
//! `repro plan-bench`.
//!
//! A serving shard's first request for an unseen pattern pays the full
//! structure-only pipeline — ordering, symbolic fill, blocking + DAG,
//! scatter map. This bench prices that spike twice per (matrix, worker
//! count): once sequentially ([`FactorPlan::build`]) and once on the
//! persistent executor ([`FactorPlan::build_on`]), asserting the two
//! plans are structurally identical before trusting the timing. The
//! per-phase laps come straight from the plan's own [`PlanReport`], so
//! the breakdown matches what `repro analyze` prints. Results land in
//! `BENCH_plan.json`.

use crate::coordinator::Executor;
use crate::session::{FactorPlan, PlanReport};
use crate::solver::SolveOptions;
use crate::sparse::gen;

/// One (matrix, worker-count) build measurement (best-of-`replays`).
pub struct PlanBenchResult {
    pub name: String,
    pub n: usize,
    pub nnz: usize,
    pub nnz_ldu: usize,
    pub workers: u32,
    /// Best sequential wall-clock build, seconds.
    pub seq_seconds: f64,
    /// Best executor-parallel wall-clock build, seconds.
    pub par_seconds: f64,
    /// Per-phase laps from the best sequential build's [`PlanReport`].
    pub seq_reorder: f64,
    pub seq_symbolic: f64,
    pub seq_preprocess: f64,
    pub seq_extra: f64,
    /// Per-phase laps from the best parallel build's [`PlanReport`].
    pub par_reorder: f64,
    pub par_symbolic: f64,
    pub par_preprocess: f64,
    pub par_extra: f64,
}

impl PlanBenchResult {
    /// Sequential-over-parallel wall-clock ratio (>1 means the executor
    /// built the plan faster).
    pub fn speedup(&self) -> f64 {
        self.seq_seconds / self.par_seconds.max(1e-12)
    }
}

/// The whole plan-bench run.
pub struct PlanBenchReport {
    pub replays: usize,
    pub results: Vec<PlanBenchResult>,
}

impl PlanBenchReport {
    /// `BENCH_plan.json` payload.
    pub fn to_json(&self) -> String {
        let rows: Vec<String> = self
            .results
            .iter()
            .map(|r| {
                format!(
                    concat!(
                        "    {{\"matrix\": \"{}\", \"n\": {}, \"nnz\": {}, ",
                        "\"nnz_ldu\": {}, \"workers\": {}, ",
                        "\"seq_seconds\": {:.6}, \"par_seconds\": {:.6}, ",
                        "\"speedup\": {:.3}, ",
                        "\"seq_reorder\": {:.6}, \"seq_symbolic\": {:.6}, ",
                        "\"seq_preprocess\": {:.6}, \"seq_extra\": {:.6}, ",
                        "\"par_reorder\": {:.6}, \"par_symbolic\": {:.6}, ",
                        "\"par_preprocess\": {:.6}, \"par_extra\": {:.6}}}"
                    ),
                    r.name,
                    r.n,
                    r.nnz,
                    r.nnz_ldu,
                    r.workers,
                    r.seq_seconds,
                    r.par_seconds,
                    r.speedup(),
                    r.seq_reorder,
                    r.seq_symbolic,
                    r.seq_preprocess,
                    r.seq_extra,
                    r.par_reorder,
                    r.par_symbolic,
                    r.par_preprocess,
                    r.par_extra,
                )
            })
            .collect();
        format!(
            "{{\n  \"bench\": \"plan\",\n  \"scenario\": \"plan-construction\",\n  \
             \"replays\": {},\n  \"results\": [\n{}\n  ]\n}}\n",
            self.replays,
            rows.join(",\n")
        )
    }

    /// Human-readable table (shared by the CLI command and CI logs).
    pub fn print(&self) {
        println!("\n--- plan bench: plan-construction (best of {} builds) ---", self.replays);
        for r in &self.results {
            println!(
                "{:22} w={} | seq {:8.4}s -> par {:8.4}s ({:.2}x) | par phases: reorder \
                 {:.4}s, symbolic {:.4}s, blocking {:.4}s, scatter {:.4}s",
                r.name,
                r.workers,
                r.seq_seconds,
                r.par_seconds,
                r.speedup(),
                r.par_reorder,
                r.par_symbolic,
                r.par_preprocess,
                r.par_extra,
            );
        }
    }
}

/// Best-of-`replays` build via `f`, returning the fastest build's
/// wall-clock seconds together with that build's plan.
fn best_of(replays: usize, mut f: impl FnMut() -> FactorPlan) -> (f64, FactorPlan) {
    let mut best_secs = f64::INFINITY;
    let mut best_plan = None;
    for _ in 0..replays {
        let t0 = std::time::Instant::now();
        let plan = f();
        let secs = t0.elapsed().as_secs_f64();
        if secs < best_secs {
            best_secs = secs;
            best_plan = Some(plan);
        }
    }
    (best_secs, best_plan.expect("replays >= 1"))
}

/// Panic unless the two builds produced structurally identical plans —
/// the timing comparison is meaningless otherwise.
fn assert_same_plan(seq: &FactorPlan, par: &FactorPlan) {
    assert_eq!(seq.fingerprint(), par.fingerprint(), "fingerprint diverged");
    assert_eq!(
        seq.structure.blocking.positions(),
        par.structure.blocking.positions(),
        "blocking diverged"
    );
    assert_eq!(seq.report.nnz_ldu, par.report.nnz_ldu, "symbolic fill diverged");
    assert_eq!(seq.dag.tasks.len(), par.dag.tasks.len(), "task DAG diverged");
    assert_eq!(seq.scatter_maps().0, par.scatter_maps().0, "scatter map diverged");
}

fn phases(r: &PlanReport) -> (f64, f64, f64, f64) {
    (r.reorder_seconds, r.symbolic_seconds, r.preprocess_seconds, r.plan_extra_seconds)
}

/// Run the plan-construction suite: `replays` builds per timing (best
/// taken), one measurement per (matrix, worker count).
pub fn run(replays: usize, worker_counts: &[u32]) -> PlanBenchReport {
    assert!(replays >= 1, "need at least 1 build per measurement");
    let suite = [
        ("grid2d-48x48", gen::grid2d_laplacian(48, 48)),
        (
            "circuit-bbd-3000",
            gen::circuit_bbd(gen::CircuitParams { n: 3000, ..Default::default() }),
        ),
    ];
    let mut results = Vec::new();
    for (name, a) in &suite {
        for &workers in worker_counts {
            let opts = SolveOptions::ours(workers);
            let (seq_seconds, seq) =
                best_of(replays, || FactorPlan::build(a, &opts).expect("sequential build"));
            let exec = Executor::shared(workers);
            let (par_seconds, par) =
                best_of(replays, || FactorPlan::build_on(a, &opts, &exec).expect("parallel build"));
            assert_same_plan(&seq, &par);
            let (seq_reorder, seq_symbolic, seq_preprocess, seq_extra) = phases(&seq.report);
            let (par_reorder, par_symbolic, par_preprocess, par_extra) = phases(&par.report);
            results.push(PlanBenchResult {
                name: (*name).to_string(),
                n: a.n_rows(),
                nnz: a.nnz(),
                nnz_ldu: seq.report.nnz_ldu,
                workers,
                seq_seconds,
                par_seconds,
                seq_reorder,
                seq_symbolic,
                seq_preprocess,
                seq_extra,
                par_reorder,
                par_symbolic,
                par_preprocess,
                par_extra,
            });
        }
    }
    PlanBenchReport { replays, results }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn smoke() {
        let report = run(1, &[1, 2]);
        assert_eq!(report.results.len(), 4);
        for r in &report.results {
            assert!(r.seq_seconds > 0.0 && r.par_seconds > 0.0);
            assert!(r.nnz_ldu >= r.nnz);
        }
        let json = report.to_json();
        assert!(json.contains("\"bench\": \"plan\""));
        assert!(json.contains("\"workers\": 2"));
    }
}
