//! Scheduler-health bench: the **refactorize-storm** scenario behind
//! `repro sched-bench` and `cargo bench --bench sched`.
//!
//! The storm replays many tiny full and partial re-factorizations of a
//! small fixed-pattern matrix — the session/serve steady state — under
//! both schedulers:
//!
//! * **spawn** — the pre-executor baseline
//!   ([`crate::coordinator::run_dag_spawn`]): `P` fresh OS threads, one
//!   global ready-queue lock, counters reallocated per call;
//! * **persistent** — the work-stealing [`crate::coordinator::Executor`]
//!   with the session's reusable [`crate::coordinator::RunState`].
//!
//! Both paths produce bit-identical factors (asserted per storm), so the
//! throughput ratio prices pure scheduling overhead. Executor counters
//! (steals, wakeups, parks) are reported as scheduler-health metrics.
//! Results land in `BENCH_sched.json`.

use crate::coordinator::Scheduler;
use crate::session::{ChangeSet, FactorPlan, SolverSession};
use crate::solver::SolveOptions;
use crate::sparse::{gen, Csc};
use std::sync::Arc;
use std::time::Instant;

/// One (matrix, worker-count) storm measurement.
pub struct StormResult {
    pub name: String,
    pub n: usize,
    pub nnz: usize,
    pub workers: u32,
    /// Replays per storm (each scheduler runs the same count).
    pub replays: usize,
    /// Full-refactorize replays per second.
    pub full_spawn_rps: f64,
    pub full_persistent_rps: f64,
    /// Partial (one-entry change set) replays per second.
    pub partial_spawn_rps: f64,
    pub partial_persistent_rps: f64,
    /// DAG tasks per full replay / per pruned partial replay.
    pub tasks_full: usize,
    pub tasks_partial: usize,
    /// Executor-counter deltas over the persistent storms.
    pub steals: u64,
    pub wakeups: u64,
    pub parks: u64,
    /// Pool shape from the [`crate::coordinator::ExecutorStats`]
    /// snapshot after the storms: thread count and how many were idle
    /// at snapshot time (the storm just ended, so normally all of them).
    pub pool_workers: u32,
    pub idle_workers: usize,
}

impl StormResult {
    /// Persistent-over-spawn throughput ratio, full replays.
    pub fn full_speedup(&self) -> f64 {
        self.full_persistent_rps / self.full_spawn_rps.max(1e-12)
    }

    /// Persistent-over-spawn throughput ratio, partial replays.
    pub fn partial_speedup(&self) -> f64 {
        self.partial_persistent_rps / self.partial_spawn_rps.max(1e-12)
    }
}

/// The whole sched-bench run.
pub struct SchedReport {
    pub replays: usize,
    pub results: Vec<StormResult>,
}

impl SchedReport {
    /// `BENCH_sched.json` payload.
    pub fn to_json(&self) -> String {
        let rows: Vec<String> = self
            .results
            .iter()
            .map(|r| {
                format!(
                    concat!(
                        "    {{\"matrix\": \"{}\", \"n\": {}, \"nnz\": {}, ",
                        "\"workers\": {}, \"replays\": {}, ",
                        "\"full_spawn_rps\": {:.3}, \"full_persistent_rps\": {:.3}, ",
                        "\"full_speedup\": {:.3}, ",
                        "\"partial_spawn_rps\": {:.3}, \"partial_persistent_rps\": {:.3}, ",
                        "\"partial_speedup\": {:.3}, ",
                        "\"tasks_full\": {}, \"tasks_partial\": {}, ",
                        "\"steals\": {}, \"wakeups\": {}, \"parks\": {}, ",
                        "\"pool_workers\": {}, \"idle_workers\": {}}}"
                    ),
                    r.name,
                    r.n,
                    r.nnz,
                    r.workers,
                    r.replays,
                    r.full_spawn_rps,
                    r.full_persistent_rps,
                    r.full_speedup(),
                    r.partial_spawn_rps,
                    r.partial_persistent_rps,
                    r.partial_speedup(),
                    r.tasks_full,
                    r.tasks_partial,
                    r.steals,
                    r.wakeups,
                    r.parks,
                    r.pool_workers,
                    r.idle_workers,
                )
            })
            .collect();
        format!(
            "{{\n  \"bench\": \"sched\",\n  \"scenario\": \"refactorize-storm\",\n  \
             \"results\": [\n{}\n  ]\n}}\n",
            rows.join(",\n")
        )
    }

    /// Human-readable table (shared by the CLI command and the bench
    /// binary).
    pub fn print(&self) {
        println!("\n--- sched bench: refactorize-storm ({} replays/storm) ---", self.replays);
        for r in &self.results {
            println!(
                "{:22} w={} | full {:8.1} -> {:8.1} rps ({:.2}x) | partial {:8.1} -> {:8.1} rps \
                 ({:.2}x) | {} steals, {} wakeups, {} parks",
                r.name,
                r.workers,
                r.full_spawn_rps,
                r.full_persistent_rps,
                r.full_speedup(),
                r.partial_spawn_rps,
                r.partial_persistent_rps,
                r.partial_speedup(),
                r.steals,
                r.wakeups,
                r.parks,
            );
        }
    }
}

/// A-value index of a diagonal entry landing in the trailing diagonal
/// block of the plan — the smallest possible dirty closure (the same
/// trick as `benches/refactor.rs`).
fn trailing_diag_index(plan: &FactorPlan, a: &Csc) -> usize {
    let p = plan.permutation().as_slice();
    let positions = plan.structure.blocking.positions();
    let last_lo = positions[plan.structure.nb() - 1];
    let r = (0..a.n_rows())
        .find(|&i| p[i] >= last_lo && a.value_index(i, i).is_some())
        .expect("diagonal entry in the trailing block");
    a.value_index(r, r).unwrap()
}

/// Time `replays` full re-factorizations.
fn full_storm(session: &mut SolverSession<'_>, values: &[f64], replays: usize) -> f64 {
    let t0 = Instant::now();
    for _ in 0..replays {
        session.refactorize(values).expect("storm refactorize");
    }
    t0.elapsed().as_secs_f64()
}

/// Time `replays` one-entry partial re-factorizations (values alternate
/// so every replay does real work). Returns (seconds, tasks per replay).
fn partial_storm(
    session: &mut SolverSession<'_>,
    k: usize,
    base: f64,
    replays: usize,
) -> (f64, usize) {
    let mut flip = 1.0f64;
    let mut tasks = 0usize;
    let t0 = Instant::now();
    for _ in 0..replays {
        flip = -flip;
        let cs = ChangeSet::from_value_indices([(k, base * (1.5 + 0.1 * flip))]);
        let rep = session.refactorize_partial(&cs).expect("storm partial refactorize");
        tasks = rep.tasks_executed;
    }
    (t0.elapsed().as_secs_f64(), tasks)
}

/// Run the refactorize-storm suite: `replays` replays per storm, one
/// storm per (matrix, worker count).
pub fn run(replays: usize, worker_counts: &[u32]) -> SchedReport {
    assert!(replays >= 2, "need at least 2 replays per storm");
    let suite = [
        (
            "tiny-bbd",
            gen::circuit_bbd(gen::CircuitParams { n: 400, ..Default::default() }),
        ),
        ("small-grid2d", gen::grid2d_laplacian(24, 24)),
    ];
    let warmup = (replays / 4).max(1);
    let mut results = Vec::new();
    for (name, a) in &suite {
        for &workers in worker_counts {
            let opts = SolveOptions::ours(workers);
            let plan = Arc::new(FactorPlan::build(a, &opts).unwrap());
            let tasks_full = plan.dag.tasks.len();
            let mut session = SolverSession::from_plan(plan.clone());
            session.refactorize(&a.values).expect("seed refactorize");
            let k = trailing_diag_index(&plan, a);
            let base = a.values[k];

            // spawn-per-call baseline first
            session.set_scheduler(Scheduler::SpawnPerCall);
            full_storm(&mut session, &a.values, warmup);
            let full_spawn_s = full_storm(&mut session, &a.values, replays);
            let (partial_spawn_s, _) = partial_storm(&mut session, k, base, replays);
            // snapshot the spawn path's final FACTORS (not inputs) for
            // the cross-scheduler bit-match check below
            let nblocks = plan.structure.blocks.len();
            let spawn_blocks: Vec<Vec<f64>> =
                (0..nblocks).map(|id| session.numeric().block_values(id as u32)).collect();

            // persistent executor, same session, same work
            session.set_scheduler(Scheduler::Persistent);
            full_storm(&mut session, &a.values, warmup);
            let stats0 = session.executor().stats();
            let full_pers_s = full_storm(&mut session, &a.values, replays);
            let (partial_pers_s, tasks_partial) = partial_storm(&mut session, k, base, replays);
            let stats1 = session.executor().stats();

            // both schedulers ended on the same final change set — their
            // factors must agree bitwise (the differential harness covers
            // this exhaustively; this is the bench's own sanity check)
            for (id, spawn) in spawn_blocks.iter().enumerate() {
                assert_eq!(
                    &session.numeric().block_values(id as u32),
                    spawn,
                    "block {id} diverged between schedulers ({name}, w={workers})"
                );
            }

            results.push(StormResult {
                name: name.to_string(),
                n: a.n_rows(),
                nnz: a.nnz(),
                workers,
                replays,
                full_spawn_rps: replays as f64 / full_spawn_s.max(1e-12),
                full_persistent_rps: replays as f64 / full_pers_s.max(1e-12),
                partial_spawn_rps: replays as f64 / partial_spawn_s.max(1e-12),
                partial_persistent_rps: replays as f64 / partial_pers_s.max(1e-12),
                tasks_full,
                tasks_partial,
                steals: stats1.steals - stats0.steals,
                wakeups: stats1.wakeups - stats0.wakeups,
                parks: stats1.parks - stats0.parks,
                pool_workers: stats1.workers,
                idle_workers: stats1.idle_workers,
            });
        }
    }
    SchedReport { replays, results }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn storm_runs_and_reports_all_combinations() {
        let report = run(3, &[1, 2]);
        assert_eq!(report.results.len(), 4, "2 matrices x 2 worker counts");
        for r in &report.results {
            assert!(r.full_spawn_rps > 0.0);
            assert!(r.full_persistent_rps > 0.0);
            assert!(r.partial_persistent_rps > 0.0);
            assert!(r.tasks_partial <= r.tasks_full);
            assert_eq!(r.pool_workers, r.workers, "stats snapshot reports the pool shape");
            assert!(r.idle_workers <= r.pool_workers as usize);
        }
        let json = report.to_json();
        assert!(json.contains("\"bench\": \"sched\""));
        assert!(json.contains("refactorize-storm"));
        assert!(json.contains("\"steals\""));
        assert!(json.contains("\"pool_workers\""));
    }
}
