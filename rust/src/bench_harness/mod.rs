//! Bench harness: regenerates every table and figure of the paper's
//! evaluation (§5) — see DESIGN.md §4 for the experiment index.
//!
//! Each experiment prints a paper-shaped table/series to stdout and writes
//! CSV files under the output directory. Run via the CLI:
//!
//! ```text
//! repro bench table4 --out results
//! repro bench all    --out results
//! ```

pub mod chaos;
pub mod figures;
pub mod kernels;
pub mod matrices;
pub mod plan;
pub mod sched;
pub mod sweeps;
pub mod tables;
pub mod trace;

pub use matrices::{paper_suite, SuiteMatrix, SuiteScale};

use std::io::Write;
use std::path::Path;

/// All experiment names in paper order.
pub const EXPERIMENTS: &[&str] = &[
    "fig1", "fig2", "fig4", "fig5", "fig7", "fig8", "fig9", "table3", "table4", "fig10",
    "table5", "fig11", "fig12", "prep", "ablate",
];

/// Run one experiment (or `all`) writing CSVs into `out_dir`.
pub fn run(experiment: &str, out_dir: &Path, scale: SuiteScale) -> anyhow::Result<()> {
    std::fs::create_dir_all(out_dir)?;
    match experiment {
        "fig1" => figures::fig1_phase_breakdown(out_dir, scale),
        "fig2" => figures::fig2_fill_in(out_dir),
        "fig4" => figures::fig4_block_size_sweep(out_dir, scale),
        "fig5" => figures::fig5_balance(out_dir, scale),
        "fig7" => figures::fig7_archetype_curves(out_dir),
        "fig8" => figures::fig8_local_curves(out_dir),
        "fig9" => figures::fig9_blocking_example(out_dir),
        "table3" => tables::table3_suite_stats(out_dir, scale),
        "table4" => tables::table4_single_gpu(out_dir, scale),
        "table5" => tables::table5_four_gpus(out_dir, scale),
        "fig10" => sweeps::fig10_pangulu_best(out_dir, scale, 1),
        "fig12" => sweeps::fig12_pangulu_best(out_dir, scale, 4),
        "fig11" => figures::fig11_distributions(out_dir, scale),
        "prep" => sweeps::preprocessing_cost(out_dir, scale),
        "ablate" => sweeps::ablations(out_dir, scale),
        "all" => {
            for e in EXPERIMENTS {
                println!("\n======== {e} ========");
                run(e, out_dir, scale)?;
            }
            Ok(())
        }
        other => anyhow::bail!("unknown experiment {other:?}; options: {EXPERIMENTS:?} or all"),
    }
}

/// Write a CSV file (creating the directory if needed).
pub(crate) fn write_csv(out_dir: &Path, name: &str, content: &str) -> anyhow::Result<()> {
    std::fs::create_dir_all(out_dir)?;
    let path = out_dir.join(name);
    let mut f = std::fs::File::create(&path)?;
    f.write_all(content.as_bytes())?;
    println!("  -> wrote {}", path.display());
    Ok(())
}

/// Fixed-width table printer.
pub(crate) struct TablePrinter {
    widths: Vec<usize>,
}

impl TablePrinter {
    pub fn new(headers: &[&str], widths: &[usize]) -> Self {
        let tp = Self { widths: widths.to_vec() };
        tp.row(headers);
        println!("{}", "-".repeat(widths.iter().sum::<usize>() + widths.len()));
        tp
    }

    pub fn row(&self, cells: &[&str]) {
        let mut line = String::new();
        for (cell, w) in cells.iter().zip(&self.widths) {
            line.push_str(&format!("{cell:>w$} ", w = w));
        }
        println!("{line}");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unknown_experiment_errors() {
        let tmp = std::env::temp_dir().join("sparselu_bench_test");
        assert!(run("nope", &tmp, SuiteScale::Small).is_err());
    }

    #[test]
    fn experiment_list_is_complete() {
        assert!(EXPERIMENTS.contains(&"table4"));
        assert!(EXPERIMENTS.contains(&"fig12"));
        assert!(EXPERIMENTS.contains(&"ablate"));
        assert_eq!(EXPERIMENTS.len(), 15);
    }
}
