//! Figure reproductions: Fig 1 (phase breakdown), Fig 2 (fill-in), Fig 4
//! (regular-block-size sensitivity), Fig 5 (balance under regular
//! blocking), Figs 7/8/11 (feature curves), Fig 9 (worked blocking
//! example).

use super::{matrices, write_csv, SuiteScale, TablePrinter};
use crate::blocking::{
    irregular_blocking, regular_blocking, BalanceReport, BlockedMatrix, DiagFeature,
    IrregularParams,
};
use crate::solver::{SolveOptions, Solver};
use crate::sparse::gen;
use crate::symbolic;
use std::path::Path;

/// Fig 1: time share of reordering / symbolic / numeric per matrix
/// (the paper reports numeric at 50–95%).
pub fn fig1_phase_breakdown(out_dir: &Path, scale: SuiteScale) -> anyhow::Result<()> {
    println!("Fig 1 — phase time breakdown (numeric share should dominate)");
    let tp = TablePrinter::new(
        &["Matrix", "reorder(s)", "symbolic(s)", "numeric(s)", "numeric %"],
        &[18, 11, 12, 11, 10],
    );
    let mut csv = String::from("matrix,reorder_s,symbolic_s,preprocess_s,numeric_s,numeric_share\n");
    for m in matrices::paper_suite(scale) {
        let mut solver = Solver::new(SolveOptions::pangulu(1));
        let f = solver
            .factorize(&m.matrix)
            .map_err(|e| anyhow::anyhow!("{}: {e}", m.name))?;
        let r = &f.report;
        tp.row(&[
            m.name,
            &format!("{:.3}", r.reorder_seconds),
            &format!("{:.3}", r.symbolic_seconds),
            &format!("{:.3}", r.numeric_seconds),
            &format!("{:.0}%", r.numeric_share() * 100.0),
        ]);
        csv.push_str(&format!(
            "{},{:.6},{:.6},{:.6},{:.6},{:.4}\n",
            m.name,
            r.reorder_seconds,
            r.symbolic_seconds,
            r.preprocess_seconds,
            r.numeric_seconds,
            r.numeric_share()
        ));
    }
    write_csv(out_dir, "fig1.csv", &csv)
}

/// Fig 2: ordering decides fill — arrow-up fills completely, arrow-down
/// (same graph, optimal order) not at all; min-degree repairs arrow-up.
pub fn fig2_fill_in(out_dir: &Path) -> anyhow::Result<()> {
    println!("Fig 2 — structure determines fill-in (arrow matrix, n=2000)");
    let n = 2000;
    let up = gen::arrow_up(n);
    let down = gen::arrow_down(n);
    let sym_up = symbolic::analyze(&up);
    let sym_down = symbolic::analyze(&down);
    let md = crate::ordering::order(&up, crate::ordering::OrderingMethod::MinDegree);
    let sym_fixed = symbolic::analyze(&up.permute_sym(md.as_slice()));
    let tp = TablePrinter::new(&["Ordering", "nnz(A)", "nnz(L+U)", "fill ratio"], &[24, 10, 14, 11]);
    let rows = [
        ("arrow-up (natural)", up.nnz(), sym_up.nnz_ldu()),
        ("arrow-down (natural)", down.nnz(), sym_down.nnz_ldu()),
        ("arrow-up + min-degree", up.nnz(), sym_fixed.nnz_ldu()),
    ];
    let mut csv = String::from("config,nnz_a,nnz_ldu,fill_ratio\n");
    for (name, nnz_a, nnz_ldu) in rows {
        tp.row(&[
            name,
            &nnz_a.to_string(),
            &nnz_ldu.to_string(),
            &format!("{:.1}x", nnz_ldu as f64 / nnz_a as f64),
        ]);
        csv.push_str(&format!(
            "{name},{nnz_a},{nnz_ldu},{:.3}\n",
            nnz_ldu as f64 / nnz_a as f64
        ));
    }
    assert_eq!(sym_up.nnz_ldu(), n * n, "arrow-up must fill fully");
    assert_eq!(sym_down.nnz_ldu(), 3 * n - 2, "arrow-down must not fill");
    write_csv(out_dir, "fig2.csv", &csv)
}

/// Fig 4: numeric time across regular block sizes vs what the selection
/// tree picks vs irregular blocking (offshore analogue).
pub fn fig4_block_size_sweep(out_dir: &Path, scale: SuiteScale) -> anyhow::Result<()> {
    let m = matrices::offshore(scale);
    println!(
        "Fig 4 — numeric time vs regular block size ({} analogue, n={})",
        m.name,
        m.matrix.n_rows()
    );
    let n = m.matrix.n_rows();
    let options = crate::blocking::selection::scaled_options(n);
    let tp = TablePrinter::new(&["Config", "block size", "measured(s)", "modeled(s)"], &[16, 12, 12, 12]);
    let mut csv = String::from("config,block_size,measured_s,modeled_s\n");
    let run = |label: &str, opts: SolveOptions| -> anyhow::Result<(f64, f64)> {
        let mut solver = Solver::new(opts);
        let f = solver
            .factorize(&m.matrix)
            .map_err(|e| anyhow::anyhow!("{label}: {e}"))?;
        Ok((f.report.numeric_seconds, f.report.modeled_makespan))
    };
    for &bs in &options {
        let (meas, modeled) = run(&format!("regular {bs}"), SolveOptions::pangulu_with_size(1, bs))?;
        tp.row(&["regular", &bs.to_string(), &format!("{meas:.3}"), &format!("{modeled:.4}")]);
        csv.push_str(&format!("regular,{bs},{meas:.6},{modeled:.6}\n"));
    }
    // what the selection tree would pick
    let (meas_sel, mod_sel) = run("selected", SolveOptions::pangulu(1))?;
    tp.row(&["sel.tree", "-", &format!("{meas_sel:.3}"), &format!("{mod_sel:.4}")]);
    csv.push_str(&format!("selected,,{meas_sel:.6},{mod_sel:.6}\n"));
    let (meas_irr, mod_irr) = run("irregular", SolveOptions::ours(1))?;
    tp.row(&["irregular", "-", &format!("{meas_irr:.3}"), &format!("{mod_irr:.4}")]);
    csv.push_str(&format!("irregular,,{meas_irr:.6},{mod_irr:.6}\n"));
    write_csv(out_dir, "fig4.csv", &csv)
}

/// Fig 5: nnz imbalance across blocks and dependency levels under regular
/// vs irregular blocking on the BBD (ASIC-like) matrix.
pub fn fig5_balance(out_dir: &Path, scale: SuiteScale) -> anyhow::Result<()> {
    println!("Fig 5 — per-block / per-level nnz balance (ASIC_680k analogue)");
    let suite = matrices::paper_suite(scale);
    let m = suite.iter().find(|m| m.name == "ASIC_680k").unwrap();
    let perm = crate::ordering::order(&m.matrix, crate::ordering::OrderingMethod::MinDegree);
    let pa = m.matrix.permute_sym(perm.as_slice());
    let sym = symbolic::analyze(&pa);
    let ldu = sym.ldu_pattern(&pa).expect("A within its own symbolic pattern");
    let n = ldu.n_cols();
    let curve = DiagFeature::from_csc(&ldu).curve();
    let irr = irregular_blocking(&curve, &IrregularParams::default());
    let reg = regular_blocking(n, n / irr.num_blocks().max(1));

    let mut csv = String::from("blocking,block_cv,within_level_cv,last_level_share,num_blocks\n");
    let tp = TablePrinter::new(
        &["Blocking", "blocks", "block nnz CV", "within-level CV", "last-level share"],
        &[12, 8, 13, 16, 17],
    );
    for (label, blocking) in [("regular", reg), ("irregular", irr)] {
        let bm = BlockedMatrix::build(&ldu, blocking);
        let rep = BalanceReport::of(&bm);
        tp.row(&[
            label,
            &bm.nb().to_string(),
            &format!("{:.3}", rep.block_summary.cv()),
            &format!("{:.3}", rep.within_level_cv),
            &format!("{:.1}%", rep.last_level_share() * 100.0),
        ]);
        csv.push_str(&format!(
            "{label},{:.4},{:.4},{:.4},{}\n",
            rep.block_summary.cv(),
            rep.within_level_cv,
            rep.last_level_share(),
            bm.nb()
        ));
    }
    write_csv(out_dir, "fig5.csv", &csv)
}

/// Figs 7(c,d): feature curves of the linear and uniform archetypes.
pub fn fig7_archetype_curves(out_dir: &Path) -> anyhow::Result<()> {
    println!("Fig 7 — diagonal-pointer percentage curves: linear vs uniform");
    let lin = gen::tridiagonal(4000);
    let uni = gen::uniform_random(2000, 0.01, 0x71).plus_transpose_pattern();
    let c_lin = DiagFeature::from_csc(&lin).curve();
    let c_uni = DiagFeature::from_csc(&uni).curve();
    println!(
        "  linear matrix quadratic-score {:+.4} (≈0 ⇒ linear curve)",
        c_lin.quadratic_score()
    );
    println!(
        "  uniform matrix quadratic-score {:+.4} (<0 ⇒ quadratic curve)",
        c_uni.quadratic_score()
    );
    write_csv(out_dir, "fig7_linear.csv", &c_lin.to_csv(1000))?;
    write_csv(out_dir, "fig7_uniform.csv", &c_uni.to_csv(1000))
}

/// Figs 8(c,d): curves with local dense regions and dense rows/cols.
pub fn fig8_local_curves(out_dir: &Path) -> anyhow::Result<()> {
    println!("Fig 8 — feature curves exposing local structure");
    let blocks = gen::local_dense_blocks(3000, &[(600, 250), (1900, 300)], 2, 0x81);
    let rows = gen::dense_rows_cols(3000, &[700, 1500, 2400], 2, 0x82);
    let c_blocks = DiagFeature::from_csc(&blocks.plus_transpose_pattern()).curve();
    let c_rows = DiagFeature::from_csc(&rows.plus_transpose_pattern()).curve();
    println!("  local-dense max jump {:.4}", c_blocks.max_jump());
    println!("  dense-rows  max jump {:.4} (jumps mark dense rows/cols)", c_rows.max_jump());
    write_csv(out_dir, "fig8_local_dense.csv", &c_blocks.to_csv(1000))?;
    write_csv(out_dir, "fig8_dense_rows.csv", &c_rows.to_csv(1000))
}

/// Fig 9: worked example — the blocking positions Algorithm 3 emits on a
/// small matrix with one dense region.
pub fn fig9_blocking_example(out_dir: &Path) -> anyhow::Result<()> {
    println!("Fig 9 — irregular blocking worked example");
    let a = gen::local_dense_blocks(1200, &[(800, 250)], 2, 0x91);
    let sym = symbolic::analyze(&a);
    let ldu = sym.ldu_pattern(&a).expect("A within its own symbolic pattern");
    let curve = DiagFeature::from_csc(&ldu).curve();
    let params = IrregularParams { sample_points: 24, min_block: 16, ..Default::default() };
    let blocking = irregular_blocking(&curve, &params);
    println!("  positions: {:?}", blocking.positions());
    println!("  sizes    : {:?}", blocking.sizes());
    let mut csv = String::from("position\n");
    for p in blocking.positions() {
        csv.push_str(&format!("{p}\n"));
    }
    write_csv(out_dir, "fig9_positions.csv", &csv)
}

/// Fig 11: post-symbolic nonzero distributions of the ASIC_680k and
/// ecology1 analogues.
pub fn fig11_distributions(out_dir: &Path, scale: SuiteScale) -> anyhow::Result<()> {
    println!("Fig 11 — nnz distribution: ASIC_680k vs ecology1 analogues");
    let suite = matrices::paper_suite(scale);
    for name in ["ASIC_680k", "ecology1"] {
        let m = suite.iter().find(|m| m.name == name).unwrap();
        // ecology1 is shown in its natural banded form (the paper's Fig 11
        // right is linear — a bandwidth-preserving ordering keeps it so;
        // min-degree would push fill to the bottom-right even here).
        let method = if name == "ecology1" {
            crate::ordering::OrderingMethod::Rcm
        } else {
            crate::ordering::OrderingMethod::MinDegree
        };
        let perm = crate::ordering::order(&m.matrix, method);
        let pa = m.matrix.permute_sym(perm.as_slice());
        let sym = symbolic::analyze(&pa);
        let ldu = sym.ldu_pattern(&pa).expect("A within its own symbolic pattern");
        let curve = DiagFeature::from_csc(&ldu).curve();
        // paper: ASIC bottom-right-heavy (98% in last region), ecology linear
        let last_20pct = 1.0 - curve.pct[(ldu.n_cols() as f64 * 0.8) as usize];
        println!(
            "  {name:18} quadratic-score {:+.4}  nnz share in last 20% of diag: {:.0}%",
            curve.quadratic_score(),
            last_20pct * 100.0
        );
        write_csv(out_dir, &format!("fig11_{name}.csv"), &curve.to_csv(1000))?;
    }
    Ok(())
}

/// Used by the CLI `analyze` command too.
pub fn describe_curve(a: &crate::sparse::Csc) -> (f64, f64) {
    let curve = DiagFeature::from_csc(&a.plus_transpose_pattern()).curve();
    (curve.quadratic_score(), curve.max_jump())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig2_invariants_hold() {
        let tmp = std::env::temp_dir().join("sparselu_fig2");
        fig2_fill_in(&tmp).unwrap();
        assert!(tmp.join("fig2.csv").exists());
    }

    #[test]
    fn fig7_writes_curves() {
        let tmp = std::env::temp_dir().join("sparselu_fig7");
        fig7_archetype_curves(&tmp).unwrap();
        let csv = std::fs::read_to_string(tmp.join("fig7_linear.csv")).unwrap();
        assert_eq!(csv.lines().count(), 1002);
    }

    #[test]
    fn fig9_emits_valid_positions() {
        let tmp = std::env::temp_dir().join("sparselu_fig9");
        fig9_blocking_example(&tmp).unwrap();
    }

}
