//! Chaos bench: serving availability, latency and recovery under
//! deterministic fault injection — `repro chaos-bench`, results in
//! `BENCH_chaos.json`.
//!
//! A 4-tenant [`Router`] serves a fixed refactorize + solve script
//! while a seeded [`FaultPlan`] injects kernel panics, NaN/Inf
//! poisoning, forced zero pivots and task stalls at increasing rates
//! (see [`crate::fault`]). Three numbers summarize how well the
//! containment machinery holds:
//!
//! * **availability** — completed requests / attempted requests per
//!   sweep point. The `one-shot` point (exactly one injected panic,
//!   one injected stall over the whole script) is the release gate:
//!   [`run`] asserts its availability stays ≥
//!   [`AVAILABILITY_GATE_PCT`], i.e. one real kernel panic costs at
//!   most the batch it rode in, never the process;
//! * **p50/p99 latency** — served-request latency per point, showing
//!   what stalls and retries cost the survivors;
//! * **recovery** — a NaN-poisoned refactorize trips the tenant
//!   quarantine ([`crate::serve::TenantHealth::quarantined`]); the
//!   bench measures wall time until the background pool rebuild
//!   revives the tenant and a clean refactorize + solve round-trips,
//!   then checks the post-recovery solution is **bit-identical** to a
//!   fault-free oracle session on the same plan.
//!
//! The run's registry (fault counters, per-tenant quarantine/degraded
//! series, router counters) is rendered into
//! [`ChaosReport::metrics_text`] so CI can gate the exposition with
//! `repro metrics-dump --file BENCH_chaos_metrics.txt --check`.

use crate::fault::{self, FaultPlan};
use crate::obs::Registry;
use crate::serve::{Request, Router, RouterConfig, ServeError, TenantId};
use crate::session::SolverSession;
use crate::solver::SolveOptions;
use crate::sparse::{gen, Csc};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Availability floor (percent) the `one-shot` sweep point must hold.
pub const AVAILABILITY_GATE_PCT: f64 = 99.0;

/// One fault-rate sweep point.
pub struct PointResult {
    pub label: &'static str,
    /// Per-event rate of each erroring fault kind (0 for the one-shot
    /// point, whose schedule is exact triggers instead).
    pub fault_rate: f64,
    /// Submit attempts (accepted or rejected).
    pub requests: usize,
    /// Requests that completed successfully.
    pub completed: usize,
    /// Requests that came back as a typed per-request error.
    pub errored: usize,
    /// Completed requests served degraded (partial→full retry etc.).
    pub degraded: usize,
    pub availability_pct: f64,
    /// Server-side latency (queue + execution) of completed requests.
    pub p50_s: f64,
    pub p99_s: f64,
    /// Faults fired during the point that must surface as errors or
    /// counted recoveries (panics + NaNs + zero pivots).
    pub injected_erroring: u64,
    /// Stalls fired (delay-only — they move latency, never errors).
    pub injected_stalls: u64,
}

/// The quarantine-recovery measurement.
pub struct RecoveryResult {
    /// Quarantine trips observed across the run (from
    /// [`crate::serve::TenantHealth`]).
    pub quarantines: usize,
    /// Background pool rebuilds that lifted a quarantine.
    pub revivals: usize,
    /// Wall seconds from the poisoned drain until a clean refactorize
    /// + solve served end-to-end again.
    pub recovery_seconds: f64,
    /// Post-recovery solution is bitwise equal to a fault-free oracle
    /// session on the same plan.
    pub post_recovery_bit_identical: bool,
}

/// The whole chaos-bench run.
pub struct ChaosReport {
    pub tenants: usize,
    pub rounds: usize,
    pub solves_per_round: usize,
    pub points: Vec<PointResult>,
    pub recovery: RecoveryResult,
    /// Rendered metrics exposition of the run's registry, for
    /// `repro metrics-dump --file ... --check`.
    pub metrics_text: String,
}

impl ChaosReport {
    /// `BENCH_chaos.json` payload.
    pub fn to_json(&self) -> String {
        let rows: Vec<String> = self
            .points
            .iter()
            .map(|p| {
                format!(
                    concat!(
                        "    {{\"label\": \"{}\", \"fault_rate\": {:.6}, ",
                        "\"requests\": {}, \"completed\": {}, \"errored\": {}, ",
                        "\"degraded\": {},\n",
                        "     \"availability_pct\": {:.4}, ",
                        "\"p50_s\": {:.9}, \"p99_s\": {:.9}, ",
                        "\"injected_erroring\": {}, \"injected_stalls\": {}}}"
                    ),
                    p.label,
                    p.fault_rate,
                    p.requests,
                    p.completed,
                    p.errored,
                    p.degraded,
                    p.availability_pct,
                    p.p50_s,
                    p.p99_s,
                    p.injected_erroring,
                    p.injected_stalls,
                )
            })
            .collect();
        format!(
            concat!(
                "{{\n",
                "  \"bench\": \"chaos\",\n",
                "  \"tenants\": {}, \"rounds\": {}, \"solves_per_round\": {},\n",
                "  \"availability_gate_pct\": {:.1},\n",
                "  \"points\": [\n{}\n  ],\n",
                "  \"recovery\": {{\"quarantines\": {}, \"revivals\": {}, ",
                "\"recovery_seconds\": {:.6}, ",
                "\"post_recovery_bit_identical\": {}}}\n",
                "}}\n"
            ),
            self.tenants,
            self.rounds,
            self.solves_per_round,
            AVAILABILITY_GATE_PCT,
            rows.join(",\n"),
            self.recovery.quarantines,
            self.recovery.revivals,
            self.recovery.recovery_seconds,
            self.recovery.post_recovery_bit_identical,
        )
    }

    /// Human-readable summary.
    pub fn print(&self) {
        println!("\n--- chaos bench ({} tenants) ---", self.tenants);
        for p in &self.points {
            println!(
                "  {:10} rate {:7.4}  avail {:7.3}%  ({}/{} ok, {} degraded)  \
                 p50 {:.5}s p99 {:.5}s  injected {} erroring / {} stalls",
                p.label,
                p.fault_rate,
                p.availability_pct,
                p.completed,
                p.requests,
                p.degraded,
                p.p50_s,
                p.p99_s,
                p.injected_erroring,
                p.injected_stalls,
            );
        }
        println!(
            "  recovery: {} quarantine(s), {} revival(s), served clean again in {:.4}s, \
             bit-identical to oracle: {}",
            self.recovery.quarantines,
            self.recovery.revivals,
            self.recovery.recovery_seconds,
            self.recovery.post_recovery_bit_identical,
        );
    }
}

/// Accumulator for one sweep point's traffic.
#[derive(Default)]
struct PointStats {
    requests: usize,
    completed: usize,
    errored: usize,
    degraded: usize,
    latencies: Vec<f64>,
}

impl PointStats {
    fn percentile(&self, sorted: &[f64], p: f64) -> f64 {
        if sorted.is_empty() {
            return 0.0;
        }
        let idx = ((sorted.len() as f64 * p) as usize).min(sorted.len() - 1);
        sorted[idx]
    }

    fn into_point(
        mut self,
        label: &'static str,
        fault_rate: f64,
        injected: fault::FaultCounters,
    ) -> PointResult {
        self.latencies.sort_by(|a, b| a.total_cmp(b));
        let availability_pct = if self.requests == 0 {
            100.0
        } else {
            self.completed as f64 / self.requests as f64 * 100.0
        };
        PointResult {
            label,
            fault_rate,
            requests: self.requests,
            completed: self.completed,
            errored: self.errored,
            degraded: self.degraded,
            availability_pct,
            p50_s: self.percentile(&self.latencies, 0.50),
            p99_s: self.percentile(&self.latencies, 0.99),
            injected_erroring: injected.erroring(),
            injected_stalls: injected.stalls,
        }
    }
}

/// Submit one request, counting the attempt; a rejected submit (full
/// queue, quarantined tenant) is an errored request from the client's
/// point of view.
fn submit_counted(router: &Router, tenant: TenantId, request: Request, stats: &mut PointStats) {
    stats.requests += 1;
    if router.submit(tenant, request).is_err() {
        stats.errored += 1;
    }
}

/// One scripted round over every tenant: a refactorize plus
/// `solves` solve requests each, then a concurrent drain.
fn drive_round(
    router: &Router,
    tenants: &[(TenantId, Csc)],
    solves: usize,
    stats: &mut PointStats,
) {
    for (tenant, a) in tenants {
        submit_counted(router, *tenant, Request::Refactorize { values: a.values.clone() }, stats);
        let rhs = vec![1.0; a.n_rows()];
        for _ in 0..solves {
            submit_counted(router, *tenant, Request::Solve { rhs: rhs.clone() }, stats);
        }
    }
    for (_, outcomes) in router.drain_all(2) {
        for outcome in outcomes {
            match outcome {
                Ok(rep) => {
                    stats.completed += 1;
                    stats.latencies.push(rep.queue_seconds + rep.exec_seconds);
                    if rep.degraded {
                        stats.degraded += 1;
                    }
                }
                Err(_) => stats.errored += 1,
            }
        }
    }
}

/// Wait (bounded) until no tenant is quarantined — the background
/// rebuild lifts the flag on its own, no drain required.
fn await_revival(router: &Router, limit: Duration) {
    let start = Instant::now();
    while router.health().iter().any(|h| h.quarantined) {
        if start.elapsed() > limit {
            return;
        }
        std::thread::sleep(Duration::from_micros(200));
    }
}

/// One uncounted clean round: restore every tenant to a factored,
/// unquarantined state so sweep points stay independent.
fn clean_round(router: &Router, tenants: &[(TenantId, Csc)]) {
    await_revival(router, Duration::from_secs(5));
    for (tenant, a) in tenants {
        for _ in 0..50 {
            match router.submit(*tenant, Request::Refactorize { values: a.values.clone() }) {
                Ok(()) => break,
                Err(ServeError::TenantQuarantined { .. }) => {
                    std::thread::sleep(Duration::from_micros(200));
                }
                Err(_) => break,
            }
        }
    }
    let _ = router.drain_all(2);
}

/// Run the chaos bench: `rounds` scripted rounds per sweep point, each
/// round issuing one refactorize plus `solves_per_round` solves per
/// tenant. Asserts the one-shot point's availability gate.
pub fn run(rounds: usize, solves_per_round: usize, seed: u64) -> ChaosReport {
    assert!(rounds > 0 && solves_per_round > 0, "empty chaos script");
    let registry = Arc::new(Registry::new());
    fault::register_metrics(&registry);
    let router = Router::new(
        SolveOptions::ours(2),
        RouterConfig {
            max_shards: 4,
            plan_cache_capacity: 8,
            shard_queue: 4 * (1 + solves_per_round) * 2,
            checkout_timeout: Some(Duration::from_millis(500)),
            registry: Some(registry.clone()),
            ..RouterConfig::default()
        },
    );
    let mats: Vec<Csc> = vec![
        gen::grid2d_laplacian(8, 8),
        gen::grid2d_laplacian(8, 9),
        gen::grid2d_laplacian(9, 9),
        gen::grid2d_laplacian(9, 10),
    ];
    let tenants: Vec<(TenantId, Csc)> = mats
        .into_iter()
        .map(|a| {
            let t = router.admit(&a).expect("admit chaos tenant");
            (t, a)
        })
        .collect();
    clean_round(&router, &tenants);

    // the sweep: exact one-shot triggers first (the gated point), then
    // rate-based storms for the latency/availability curve
    let sweep: Vec<(&'static str, f64, FaultPlan)> = vec![
        ("baseline", 0.0, FaultPlan::seeded(seed)),
        ("one-shot", 0.0, FaultPlan::seeded(seed).panic_at_task(5).stall_at_task(9)),
        (
            "storm-low",
            0.001,
            FaultPlan::seeded(seed ^ 0x10)
                .panic_rate(0.001)
                .nan_rate(0.001)
                .zero_pivot_rate(0.001)
                .stall_rate(0.01, 100),
        ),
        (
            "storm-high",
            0.01,
            FaultPlan::seeded(seed ^ 0x20)
                .panic_rate(0.01)
                .nan_rate(0.01)
                .zero_pivot_rate(0.01)
                .stall_rate(0.05, 200),
        ),
    ];

    let mut points = Vec::with_capacity(sweep.len());
    for (label, rate, plan) in sweep {
        let _guard = fault::FaultGuard::new(plan);
        let mut stats = PointStats::default();
        for _ in 0..rounds {
            drive_round(&router, &tenants, solves_per_round, &mut stats);
        }
        let injected = fault::counters();
        drop(_guard);
        clean_round(&router, &tenants);
        points.push(stats.into_point(label, rate, injected));
    }

    let gated = points.iter().find(|p| p.label == "one-shot").expect("one-shot point ran");
    assert!(
        gated.availability_pct >= AVAILABILITY_GATE_PCT,
        "availability gate: one injected panic cost {:.3}% availability (gate {:.1}%, \
         {}/{} completed)",
        100.0 - gated.availability_pct,
        AVAILABILITY_GATE_PCT,
        gated.completed,
        gated.requests,
    );

    let recovery = measure_recovery(&router, &tenants[0], seed);
    let quarantines: usize = router.health().iter().map(|h| h.quarantines).sum();
    let revivals: usize = router.health().iter().map(|h| h.quarantine_revivals).sum();

    ChaosReport {
        tenants: tenants.len(),
        rounds,
        solves_per_round,
        points,
        recovery: RecoveryResult { quarantines, revivals, ..recovery },
        metrics_text: registry.render(),
    }
}

/// Poison one tenant's refactorize, ride out the quarantine, and time
/// the round-trip back to clean serving; then check bit-identity
/// against a fault-free oracle session.
fn measure_recovery(router: &Router, tenant: &(TenantId, Csc), seed: u64) -> RecoveryResult {
    let (t, a) = tenant;
    let rhs = vec![1.0; a.n_rows()];
    // the very first kernel dispatch of the next refactorize poisons
    // its target block -> post-factor scan -> NonFinite -> quarantine
    fault::install(FaultPlan::seeded(seed ^ 0x7E).nan_at_kernel(0));
    router.submit(*t, Request::Refactorize { values: a.values.clone() }).expect("seed poison");
    let start = Instant::now();
    let poisoned = router.drain_tenant(*t).expect("drain poisoned tenant");
    fault::clear();
    assert!(
        poisoned.iter().any(|o| o.is_err()),
        "NaN-poisoned refactorize must surface as an error"
    );
    // recovery: retry until the revived shard serves a clean
    // refactorize + solve end-to-end
    let mut solution: Option<Vec<f64>> = None;
    while start.elapsed() < Duration::from_secs(10) {
        match router.submit(*t, Request::Refactorize { values: a.values.clone() }) {
            Ok(()) => {}
            Err(ServeError::TenantQuarantined { .. }) => {
                std::thread::sleep(Duration::from_micros(200));
                continue;
            }
            Err(e) => panic!("unexpected submit failure during recovery: {e}"),
        }
        router.submit(*t, Request::Solve { rhs: rhs.clone() }).expect("solve after revival");
        let outcomes = router.drain_tenant(*t).expect("drain revived tenant");
        if outcomes.iter().all(|o| o.is_ok()) {
            solution = outcomes.into_iter().flatten().find_map(|rep| rep.solution);
            break;
        }
    }
    let recovery_seconds = start.elapsed().as_secs_f64();
    let solution = solution.expect("tenant recovered within the deadline");

    // oracle: a fresh fault-free session over the same plan must agree
    // bit-for-bit with the post-recovery serving path
    let plan = router.plan_of(*t).expect("plan of recovered tenant");
    let mut oracle = SolverSession::from_plan(plan);
    oracle.refactorize(&a.values).expect("oracle refactorize");
    let expect = oracle.solve(&rhs);
    let identical = expect.len() == solution.len()
        && expect.iter().zip(&solution).all(|(x, y)| x.to_bits() == y.to_bits());
    RecoveryResult {
        quarantines: 0, // filled by the caller from TenantHealth
        revivals: 0,
        recovery_seconds,
        post_recovery_bit_identical: identical,
    }
}
