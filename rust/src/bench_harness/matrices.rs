//! The benchmark suite: synthetic analogues of the paper's Table 3
//! matrices (SuiteSparse is not available offline; DESIGN.md §2 documents
//! the substitution). Dimensions are scaled ~100× down so the whole table
//! regenerates in minutes on CPU; the *relative* structure (nonzero
//! distribution archetype, fill behaviour, density class) follows the
//! original of each kind.
//!
//! Real SuiteSparse `.mtx` files can be dropped in via
//! `repro solve --matrix file.mtx` unchanged.

use crate::sparse::{gen, Csc};

/// One suite entry.
pub struct SuiteMatrix {
    /// Paper matrix this stands in for.
    pub name: &'static str,
    /// SuiteSparse kind string (Table 3 column).
    pub kind: &'static str,
    pub matrix: Csc,
}

/// Scale factor presets for the suite.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SuiteScale {
    /// Tiny — CI-speed smoke (seconds).
    Small,
    /// The default bench scale (table regeneration in minutes).
    Medium,
}

/// Build the full Table 3/4/5 suite.
pub fn paper_suite(scale: SuiteScale) -> Vec<SuiteMatrix> {
    let s = match scale {
        SuiteScale::Small => 1usize,
        SuiteScale::Medium => 2usize,
    };
    let m = |name: &'static str, kind: &'static str, matrix: Csc| SuiteMatrix {
        name,
        kind,
        matrix,
    };
    vec![
        m(
            "apache2",
            "Structural Problem",
            gen::grid3d_laplacian(10 * s, 10 * s, 9 * s),
        ),
        m(
            "ASIC_680k",
            "Circuit Simulation Problem",
            gen::circuit_bbd(gen::CircuitParams {
                n: 3400 * s,
                border_frac: 0.05,
                border_density: 0.35,
                interior_deg: 2,
                seed: 0x680F,
            }),
        ),
        m("cage12", "Directed Weighted Graph", gen::directed_graph(1300 * s, 8, 0xCA6E)),
        m(
            "CoupCons3D",
            "Structural Problem",
            gen::banded_fem(2100 * s, &[1, 2, 3, 40, 41, 80], 0.85, 0xC0C0),
        ),
        m(
            "dielFilterV3real",
            "Electromagnetics Problem",
            gen::electromagnetics_like(2750 * s, 24, 2, 0xD1E1),
        ),
        m("ecology1", "2D/3D Problem", gen::grid2d_laplacian(50 * s, 50 * s)),
        m("G3_circuit", "Circuit Simulation Problem", gen::grid2d_laplacian(63 * s, 63 * s)),
        m(
            "inline_1",
            "Structural Problem",
            gen::banded_fem(2500 * s, &[1, 2, 3, 12, 13], 0.9, 0x111E),
        ),
        m("language", "Directed Weighted Graph", gen::directed_graph(2000 * s, 3, 0x1A26)),
        m(
            "boneS10",
            "Model Reduction Problem",
            gen::banded_fem(2250 * s, &[1, 2, 3, 30, 60, 61], 0.8, 0xB0E5),
        ),
    ]
}

/// The offshore analogue (used by Fig 4's block-size sweep).
pub fn offshore(scale: SuiteScale) -> SuiteMatrix {
    let s = match scale {
        SuiteScale::Small => 1usize,
        SuiteScale::Medium => 2usize,
    };
    SuiteMatrix {
        name: "offshore",
        kind: "Electromagnetics Problem",
        matrix: gen::electromagnetics_like(1300 * s, 12, 2, 0x0F5E),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn suite_has_ten_table4_matrices() {
        let suite = paper_suite(SuiteScale::Small);
        assert_eq!(suite.len(), 10);
        let names: Vec<&str> = suite.iter().map(|m| m.name).collect();
        for expect in [
            "apache2",
            "ASIC_680k",
            "cage12",
            "CoupCons3D",
            "dielFilterV3real",
            "ecology1",
            "G3_circuit",
            "inline_1",
            "language",
            "boneS10",
        ] {
            assert!(names.contains(&expect), "{expect} missing");
        }
    }

    #[test]
    fn all_matrices_valid_and_diag_full() {
        for m in paper_suite(SuiteScale::Small) {
            m.matrix.validate().unwrap_or_else(|e| panic!("{}: {e}", m.name));
            assert!(m.matrix.has_full_diagonal(), "{}", m.name);
            assert!(m.matrix.n_rows() >= 900, "{} too small", m.name);
        }
    }

    #[test]
    fn asic_like_is_border_heavy() {
        let suite = paper_suite(SuiteScale::Small);
        let asic = suite.iter().find(|m| m.name == "ASIC_680k").unwrap();
        // feature curve of A itself already shows the right-bottom skew
        let sym = asic.matrix.plus_transpose_pattern();
        let f = crate::blocking::DiagFeature::from_csc(&sym).curve();
        assert!(
            f.quadratic_score() < -0.02,
            "ASIC analogue must be bottom-right heavy, score {}",
            f.quadratic_score()
        );
    }

    #[test]
    fn ecology_like_is_linear() {
        let suite = paper_suite(SuiteScale::Small);
        let eco = suite.iter().find(|m| m.name == "ecology1").unwrap();
        let sym = eco.matrix.plus_transpose_pattern();
        let f = crate::blocking::DiagFeature::from_csc(&sym).curve();
        assert!(f.quadratic_score().abs() < 0.02, "score {}", f.quadratic_score());
    }
}
