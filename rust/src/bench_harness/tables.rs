//! Table 3 (suite statistics), Table 4 (single-GPU numeric factorization)
//! and Table 5 (4-GPU) reproductions.

use super::{matrices, write_csv, SuiteScale, TablePrinter};
use crate::solver::{SolveOptions, Solver};
use crate::symbolic;
use crate::util::stats::geomean;
use std::path::Path;

/// Table 3: n, nnz(A), nnz(L+U), FLOPs, kind for every suite matrix.
pub fn table3_suite_stats(out_dir: &Path, scale: SuiteScale) -> anyhow::Result<()> {
    println!("Table 3 — benchmark suite statistics (synthetic analogues)");
    let tp = TablePrinter::new(
        &["Matrix", "n", "nnz(A)", "nnz(L+U)", "FLOPs", "Kind"],
        &[18, 8, 10, 12, 12, 30],
    );
    let mut csv = String::from("matrix,n,nnz_a,nnz_ldu,flops,kind\n");
    for m in matrices::paper_suite(scale) {
        // fill statistics under the production ordering (min degree)
        let perm = crate::ordering::order(&m.matrix, crate::ordering::OrderingMethod::MinDegree);
        let pa = m.matrix.permute_sym(perm.as_slice());
        let sym = symbolic::analyze(&pa);
        tp.row(&[
            m.name,
            &m.matrix.n_rows().to_string(),
            &m.matrix.nnz().to_string(),
            &sym.nnz_ldu().to_string(),
            &format!("{:.3e}", sym.flops()),
            m.kind,
        ]);
        csv.push_str(&format!(
            "{},{},{},{},{:.6e},{}\n",
            m.name,
            m.matrix.n_rows(),
            m.matrix.nnz(),
            sym.nnz_ldu(),
            sym.flops(),
            m.kind
        ));
    }
    write_csv(out_dir, "table3.csv", &csv)
}

/// One comparison row of Table 4/5.
struct Row {
    name: String,
    superlu: f64,
    pangulu: f64,
    ours: f64,
    superlu_modeled: f64,
    pangulu_modeled: f64,
    ours_modeled: f64,
}

fn run_one(matrix: &crate::sparse::Csc, opts: SolveOptions) -> anyhow::Result<(f64, f64)> {
    let mut solver = Solver::new(opts);
    let f = solver
        .factorize(matrix)
        .map_err(|e| anyhow::anyhow!("factorization failed: {e}"))?;
    Ok((f.report.numeric_seconds, f.report.modeled_makespan))
}

fn comparison_table(
    out_dir: &Path,
    scale: SuiteScale,
    workers: u32,
    title: &str,
    csv_name: &str,
) -> anyhow::Result<()> {
    println!("{title}");
    println!("(measured CPU seconds on {workers} worker(s) | modeled A100 seconds in brackets)");
    let tp = TablePrinter::new(
        &["Matrix", "SuperLU-like", "PanguLU-like", "Ours", "vs SuperLU", "vs PanguLU"],
        &[18, 16, 16, 16, 11, 11],
    );
    let mut csv = String::from(
        "matrix,superlu_s,pangulu_s,ours_s,superlu_modeled_s,pangulu_modeled_s,ours_modeled_s,\
         speedup_vs_superlu,speedup_vs_pangulu,modeled_speedup_vs_superlu,modeled_speedup_vs_pangulu\n",
    );
    let mut rows = Vec::new();
    for m in matrices::paper_suite(scale) {
        let (superlu, superlu_m) = run_one(&m.matrix, SolveOptions::superlu_like(workers))?;
        let (pangulu, pangulu_m) = run_one(&m.matrix, SolveOptions::pangulu(workers))?;
        let (ours, ours_m) = run_one(&m.matrix, SolveOptions::ours(workers))?;
        let row = Row {
            name: m.name.to_string(),
            superlu,
            pangulu,
            ours,
            superlu_modeled: superlu_m,
            pangulu_modeled: pangulu_m,
            ours_modeled: ours_m,
        };
        tp.row(&[
            &row.name,
            &format!("{:.3} [{:.3}]", row.superlu, row.superlu_modeled),
            &format!("{:.3} [{:.3}]", row.pangulu, row.pangulu_modeled),
            &format!("{:.3} [{:.3}]", row.ours, row.ours_modeled),
            &format!("{:.2}x", row.superlu / row.ours),
            &format!("{:.2}x", row.pangulu / row.ours),
        ]);
        csv.push_str(&format!(
            "{},{:.6},{:.6},{:.6},{:.6e},{:.6e},{:.6e},{:.3},{:.3},{:.3},{:.3}\n",
            row.name,
            row.superlu,
            row.pangulu,
            row.ours,
            row.superlu_modeled,
            row.pangulu_modeled,
            row.ours_modeled,
            row.superlu / row.ours,
            row.pangulu / row.ours,
            row.superlu_modeled / row.ours_modeled,
            row.pangulu_modeled / row.ours_modeled,
        ));
        rows.push(row);
    }
    let g_superlu = geomean(&rows.iter().map(|r| r.superlu / r.ours).collect::<Vec<_>>());
    let g_pangulu = geomean(&rows.iter().map(|r| r.pangulu / r.ours).collect::<Vec<_>>());
    let gm_superlu = geomean(
        &rows
            .iter()
            .map(|r| r.superlu_modeled / r.ours_modeled)
            .collect::<Vec<_>>(),
    );
    let gm_pangulu = geomean(
        &rows
            .iter()
            .map(|r| r.pangulu_modeled / r.ours_modeled)
            .collect::<Vec<_>>(),
    );
    tp.row(&[
        "GEOMEAN",
        "",
        "",
        "",
        &format!("{g_superlu:.2}x"),
        &format!("{g_pangulu:.2}x"),
    ]);
    println!(
        "GEOMEAN (modeled A100): vs SuperLU-like {gm_superlu:.2}x | vs PanguLU-like {gm_pangulu:.2}x"
    );
    println!(
        "paper reference      : vs SuperLU {}x | vs PanguLU {}x",
        if workers == 1 { "3.32" } else { "3.84" },
        if workers == 1 { "1.50" } else { "1.40" },
    );
    csv.push_str(&format!(
        "GEOMEAN,,,,,,,{g_superlu:.3},{g_pangulu:.3},{gm_superlu:.3},{gm_pangulu:.3}\n"
    ));
    write_csv(out_dir, csv_name, &csv)
}

/// Table 4: numeric factorization on one device.
pub fn table4_single_gpu(out_dir: &Path, scale: SuiteScale) -> anyhow::Result<()> {
    comparison_table(
        out_dir,
        scale,
        1,
        "Table 4 — numeric factorization, 1 device (paper: 1×A100)",
        "table4.csv",
    )
}

/// Table 5: numeric factorization on 4 devices.
pub fn table5_four_gpus(out_dir: &Path, scale: SuiteScale) -> anyhow::Result<()> {
    comparison_table(
        out_dir,
        scale,
        4,
        "Table 5 — numeric factorization, 4 devices (paper: 4×A100)",
        "table5.csv",
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table3_runs_at_small_scale() {
        let tmp = std::env::temp_dir().join("sparselu_t3");
        table3_suite_stats(&tmp, SuiteScale::Small).unwrap();
        assert!(tmp.join("table3.csv").exists());
        let csv = std::fs::read_to_string(tmp.join("table3.csv")).unwrap();
        assert_eq!(csv.lines().count(), 11); // header + 10 matrices
    }
}
