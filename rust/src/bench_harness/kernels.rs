//! Raw-speed pass on the dense block kernels — `repro kernel-bench`.
//!
//! For every kernel × block shape × fill density the bench times the
//! scalar oracle ([`crate::numeric::dense`]) against the register-blocked
//! tiled fast path ([`crate::numeric::tiled`]) on identical inputs, and
//! **asserts bitwise identity of the two outputs in-bench** before any
//! timing is reported — a BENCH_kernels.json that exists at all proves
//! the fast path kept the order-preservation contract on this machine.
//!
//! Density is the operand fill fraction ([`gen::dense_dd_density`] /
//! [`gen::dense_uniform_density`]); both paths are skip-free, so timing
//! is density-*independent* by design — the sweep exists to prove exactly
//! that (a density-sensitive timing would mean a value-dependent branch
//! snuck in) and to label the dense-region rows (≥64 in every dimension,
//! density ≥ 0.5) where the tiled speedup is the headline number.
//! Results land in `BENCH_kernels.json`.

use crate::numeric::kernels::flops;
use crate::numeric::{dense, tiled};
use crate::sparse::gen;
use std::time::Instant;

/// One (kernel, shape, density) measurement.
pub struct KernelResult {
    /// `getrf` | `trsm_lower` | `trsm_upper` | `gemm`.
    pub kernel: &'static str,
    /// Shape in the gemm convention: GETRF is `n×n` (m=k=n), GESSM is a
    /// unit-lower `m×m` applied to `m×n` (k=m), TSTRF is `m×k` times a
    /// `k×k` U (n=k), SSSSM is `C[m×n] -= A[m×k]·B[k×n]`.
    pub m: usize,
    pub k: usize,
    pub n: usize,
    /// Fill density requested from the generator…
    pub requested_density: f64,
    /// …and the fraction of nonzeros actually materialized.
    pub density: f64,
    /// Exact per-call flop count (closed forms of
    /// [`crate::numeric::kernels::flops`] — exact because both paths are
    /// skip-free).
    pub flops: f64,
    /// Best-of-reps seconds per call.
    pub scalar_s: f64,
    pub tiled_s: f64,
    /// The acceptance slice: every dimension ≥ 64 and density ≥ 0.5.
    pub dense_region: bool,
}

impl KernelResult {
    /// Tiled-over-scalar speedup (>1 means the fast path is faster).
    pub fn speedup(&self) -> f64 {
        self.scalar_s / self.tiled_s.max(1e-12)
    }

    /// Achieved Gflop/s of the tiled path.
    pub fn tiled_gflops(&self) -> f64 {
        self.flops / self.tiled_s.max(1e-12) / 1e9
    }
}

/// The whole kernel-bench run. Constructing one via [`run`] has already
/// asserted scalar/tiled bitwise identity for every row.
pub struct KernelReport {
    pub reps: usize,
    pub results: Vec<KernelResult>,
}

impl KernelReport {
    /// Smallest tiled-over-scalar speedup across the dense-region rows —
    /// the number the perf pass is graded on (≥ 2x on real hardware).
    pub fn dense_region_min_speedup(&self) -> f64 {
        self.results
            .iter()
            .filter(|r| r.dense_region)
            .map(KernelResult::speedup)
            .fold(f64::INFINITY, f64::min)
    }

    /// `BENCH_kernels.json` payload.
    pub fn to_json(&self) -> String {
        let rows: Vec<String> = self
            .results
            .iter()
            .map(|r| {
                format!(
                    concat!(
                        "    {{\"kernel\": \"{}\", \"m\": {}, \"k\": {}, \"n\": {}, ",
                        "\"requested_density\": {:.2}, \"density\": {:.4}, ",
                        "\"flops\": {:.0}, ",
                        "\"scalar_s\": {:.9}, \"tiled_s\": {:.9}, ",
                        "\"speedup\": {:.3}, \"tiled_gflops\": {:.3}, ",
                        "\"dense_region\": {}}}"
                    ),
                    r.kernel,
                    r.m,
                    r.k,
                    r.n,
                    r.requested_density,
                    r.density,
                    r.flops,
                    r.scalar_s,
                    r.tiled_s,
                    r.speedup(),
                    r.tiled_gflops(),
                    r.dense_region,
                )
            })
            .collect();
        format!(
            concat!(
                "{{\n",
                "  \"bench\": \"kernels\",\n",
                "  \"identity\": \"bitwise scalar==tiled asserted in-bench\",\n",
                "  \"reps\": {}, \"dense_region_min_speedup\": {:.3},\n",
                "  \"results\": [\n{}\n  ]\n",
                "}}\n"
            ),
            self.reps,
            self.dense_region_min_speedup(),
            rows.join(",\n")
        )
    }

    /// Human-readable table (shared by the CLI command and tests).
    pub fn print(&self) {
        println!(
            "\n--- kernel bench: scalar oracle vs tiled fast path ({} reps, best-of) ---",
            self.reps
        );
        for r in &self.results {
            println!(
                "{:10} {:>3}x{:<3}x{:<3} d={:.2} | scalar {:>9.3}us  tiled {:>9.3}us  \
                 ({:.2}x, {:.2} Gflop/s){}",
                r.kernel,
                r.m,
                r.k,
                r.n,
                r.density,
                r.scalar_s * 1e6,
                r.tiled_s * 1e6,
                r.speedup(),
                r.tiled_gflops(),
                if r.dense_region { "  [dense region]" } else { "" },
            );
        }
        println!(
            "dense-region min speedup: {:.2}x (identity: bitwise, asserted per row)",
            self.dense_region_min_speedup()
        );
    }
}

/// Best-of-`reps` seconds for one kernel call. `src` is restored into the
/// scratch buffer before every call, outside the timed window, so only
/// the kernel itself is measured.
fn time_per_call(reps: usize, src: &[f64], mut run: impl FnMut(&mut [f64])) -> f64 {
    let mut buf = src.to_vec();
    run(&mut buf); // warmup
    let mut best = f64::INFINITY;
    for _ in 0..reps {
        buf.copy_from_slice(src);
        let t = Instant::now();
        run(&mut buf);
        best = best.min(t.elapsed().as_secs_f64());
        std::hint::black_box(&buf);
    }
    best
}

/// The identity gate: one scalar call and one tiled call from the same
/// input must agree to the bit, else the whole bench aborts.
fn assert_bitwise(kernel: &str, shape: (usize, usize, usize), d: f64, a: &[f64], b: &[f64]) {
    assert_eq!(a.len(), b.len());
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert!(
            x.to_bits() == y.to_bits(),
            "{kernel} {shape:?} density {d}: scalar and tiled diverge at flat index {i} \
             ({x:e} vs {y:e}) — the order-preservation contract is broken"
        );
    }
}

const DENSITIES: &[f64] = &[0.5, 1.0];

fn dense_region(m: usize, k: usize, n: usize, density: f64) -> bool {
    m >= 64 && k >= 64 && n >= 64 && density >= 0.5
}

/// Run the sweep: `reps` timed calls per (kernel, shape, density) row,
/// best-of reported, bitwise identity asserted per row.
pub fn run(reps: usize) -> KernelReport {
    assert!(reps >= 1, "need at least one timed rep");
    let mut results = Vec::new();
    let mut seed = 0x4E31u64;
    let mut next_seed = || {
        seed = seed.wrapping_mul(6364136223846793005).wrapping_add(1);
        seed
    };

    // GETRF: n×n in-place LU
    for &n in &[16usize, 32, 64, 96, 128] {
        for &d in DENSITIES {
            let a = gen::dense_dd_density(n, d, next_seed());
            let mut s_out = a.clone();
            dense::getrf_in_place(&mut s_out, n).unwrap();
            let mut t_out = a.clone();
            tiled::getrf_in_place(&mut t_out, n).unwrap();
            assert_bitwise("getrf", (n, n, n), d, &s_out, &t_out);
            let scalar_s =
                time_per_call(reps, &a, |buf| dense::getrf_in_place(buf, n).unwrap());
            let tiled_s =
                time_per_call(reps, &a, |buf| tiled::getrf_in_place(buf, n).unwrap());
            results.push(KernelResult {
                kernel: "getrf",
                m: n,
                k: n,
                n,
                requested_density: d,
                density: gen::buffer_density(&a),
                flops: flops::getrf_dense(n),
                scalar_s,
                tiled_s,
                dense_region: dense_region(n, n, n, d),
            });
        }
    }

    // GESSM / trsm_lower_unit: unit-lower m×m applied to an m×n panel
    for &(m, n) in &[(64usize, 64usize), (128, 128), (128, 32)] {
        let mut lu = gen::dense_dd(m, next_seed());
        dense::getrf_in_place(&mut lu, m).unwrap();
        for &d in DENSITIES {
            let b = gen::dense_uniform_density(m, n, d, next_seed());
            let mut s_out = b.clone();
            dense::trsm_lower_unit(&lu, m, &mut s_out, n);
            let mut t_out = b.clone();
            tiled::trsm_lower_unit(&lu, m, &mut t_out, n);
            assert_bitwise("trsm_lower", (m, m, n), d, &s_out, &t_out);
            let scalar_s =
                time_per_call(reps, &b, |buf| dense::trsm_lower_unit(&lu, m, buf, n));
            let tiled_s =
                time_per_call(reps, &b, |buf| tiled::trsm_lower_unit(&lu, m, buf, n));
            results.push(KernelResult {
                kernel: "trsm_lower",
                m,
                k: m,
                n,
                requested_density: d,
                density: gen::buffer_density(&b),
                flops: flops::gessm_dense(m, n),
                scalar_s,
                tiled_s,
                dense_region: dense_region(m, m, n, d),
            });
        }
    }

    // TSTRF / trsm_upper_right: m×k panel times U⁻¹ of a k×k factor
    for &(m, k) in &[(64usize, 64usize), (128, 128), (32, 128)] {
        let mut lu = gen::dense_dd(k, next_seed());
        dense::getrf_in_place(&mut lu, k).unwrap();
        for &d in DENSITIES {
            let b = gen::dense_uniform_density(m, k, d, next_seed());
            let mut s_out = b.clone();
            dense::trsm_upper_right(&lu, k, &mut s_out, m);
            let mut t_out = b.clone();
            tiled::trsm_upper_right(&lu, k, &mut t_out, m);
            assert_bitwise("trsm_upper", (m, k, k), d, &s_out, &t_out);
            let scalar_s =
                time_per_call(reps, &b, |buf| dense::trsm_upper_right(&lu, k, buf, m));
            let tiled_s =
                time_per_call(reps, &b, |buf| tiled::trsm_upper_right(&lu, k, buf, m));
            results.push(KernelResult {
                kernel: "trsm_upper",
                m,
                k,
                n: k,
                requested_density: d,
                density: gen::buffer_density(&b),
                flops: flops::tstrf_dense(m, k),
                scalar_s,
                tiled_s,
                dense_region: dense_region(m, k, k, d),
            });
        }
    }

    // SSSSM / gemm_update: C[m×n] -= A[m×k]·B[k×n] — the Schur hot spot
    for &(m, k, n) in &[(64usize, 64usize, 64usize), (128, 128, 128), (96, 32, 96)] {
        for &d in DENSITIES {
            let a = gen::dense_uniform_density(m, k, d, next_seed());
            let b = gen::dense_uniform_density(k, n, d, next_seed());
            let c = gen::dense_uniform(m, n, next_seed());
            let mut s_out = c.clone();
            dense::gemm_update(&mut s_out, &a, &b, m, k, n);
            let mut t_out = c.clone();
            tiled::gemm_update(&mut t_out, &a, &b, m, k, n);
            assert_bitwise("gemm", (m, k, n), d, &s_out, &t_out);
            let scalar_s =
                time_per_call(reps, &c, |buf| dense::gemm_update(buf, &a, &b, m, k, n));
            let tiled_s =
                time_per_call(reps, &c, |buf| tiled::gemm_update(buf, &a, &b, m, k, n));
            results.push(KernelResult {
                kernel: "gemm",
                m,
                k,
                n,
                requested_density: d,
                // operand density: the A/B fill fraction (C is dense)
                density: gen::buffer_density(&a).min(gen::buffer_density(&b)),
                flops: flops::ssssm_dense(m, k, n),
                scalar_s,
                tiled_s,
                dense_region: dense_region(m, k, n, d),
            });
        }
    }

    KernelReport { reps, results }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kernel_bench_sweeps_and_gates_identity() {
        // run() asserts scalar==tiled bitwise per row; reaching the
        // report at all means the gate passed on every combination
        let report = run(2);
        assert_eq!(
            report.results.len(),
            5 * 2 + 3 * 2 + 3 * 2 + 3 * 2,
            "getrf sizes + trsm_lower shapes + trsm_upper shapes + gemm shapes, 2 densities"
        );
        assert!(report.results.iter().any(|r| r.dense_region), "acceptance slice present");
        for r in &report.results {
            assert!(r.scalar_s > 0.0 && r.tiled_s > 0.0);
            assert!(r.flops > 0.0);
            assert!(r.speedup().is_finite());
            assert!(
                (r.density - r.requested_density).abs() < 0.1,
                "{}: achieved {} vs requested {}",
                r.kernel,
                r.density,
                r.requested_density
            );
            if r.dense_region {
                assert!(r.m >= 64 && r.k >= 64 && r.n >= 64 && r.density >= 0.45);
            }
        }
        let json = report.to_json();
        assert!(json.contains("\"bench\": \"kernels\""));
        assert!(json.contains("\"dense_region_min_speedup\""));
        assert!(json.contains("\"kernel\": \"gemm\""));
        assert!(json.contains("\"dense_region\": true"));
        assert!(report.dense_region_min_speedup() > 0.0);
    }
}
