//! Trace bench: the measured critical-path / balance scenario behind
//! `repro trace-bench`.
//!
//! For each (matrix, worker count, blocking) combination the bench runs
//! traced re-factorizations through a [`crate::session::SolverSession`]
//! and distills the recording ([`crate::obs::trace`]) into the numbers
//! the paper's balance claim is about:
//!
//! * **scheduling efficiency** — measured critical path over achieved
//!   makespan ([`trace::analyze_run`]), plus the top-k straggler tasks;
//! * **per-level balance** — nonzeros and measured seconds per target
//!   block per DAG level ([`trace::level_balance`]), with the worst
//!   within-level and the across-level max/mean imbalance factors,
//!   reported for the paper's irregular blocking (`ours`) next to the
//!   regular/PanguLU-style baseline on the same matrix.
//!
//! Results land in `BENCH_trace.json`; the last scenario's raw recording
//! is exported as a Chrome-trace sample so CI always uploads one
//! Perfetto-loadable artifact. The bench asserts its own sanity gate
//! inline: `critical path <= makespan <= total task seconds` (up to a
//! small timing slack), so a CI run that completes has already validated
//! the profiler's invariants.

use crate::obs::trace;
use crate::session::{FactorPlan, SolverSession};
use crate::solver::SolveOptions;
use crate::sparse::gen;
use std::sync::Arc;

/// One traced (matrix, workers, blocking) measurement.
pub struct TraceScenario {
    /// Matrix name.
    pub name: String,
    /// `"irregular"` (the paper's `ours`) or `"regular"` (PanguLU-style
    /// regular blocking).
    pub blocking: String,
    /// Matrix order.
    pub n: usize,
    /// Input nonzeros.
    pub nnz: usize,
    /// Pool size the DAG ran on.
    pub workers: u32,
    /// DAG tasks executed by the analyzed run.
    pub tasks: usize,
    /// DAG levels with at least one recorded task.
    pub levels: usize,
    /// Measured schedule quality of the analyzed run.
    pub analysis: trace::RunAnalysis,
    /// Per-level balance rows, ascending level.
    pub per_level: Vec<trace::LevelBalance>,
    /// Worst within-level `nnz_max / nnz_mean` across levels.
    pub worst_nnz_imbalance: f64,
    /// Worst within-level `seconds_max / seconds_mean` across levels.
    pub worst_time_imbalance: f64,
    /// Across-level max/mean of per-level nonzero totals.
    pub nnz_imbalance_across: f64,
    /// Across-level max/mean of per-level measured seconds.
    pub time_imbalance_across: f64,
    /// Ring-overflow losses over the scenario's recording window.
    pub dropped_events: u64,
}

/// The whole trace-bench run.
pub struct TraceReport {
    /// Traced replays per scenario (the last one is analyzed).
    pub replays: usize,
    /// All scenario measurements.
    pub results: Vec<TraceScenario>,
    /// Chrome-trace JSON of the last scenario's recording — the sample
    /// artifact `repro trace-bench --trace-out` writes.
    pub sample_trace: String,
}

impl TraceReport {
    /// `BENCH_trace.json` payload.
    pub fn to_json(&self) -> String {
        let rows: Vec<String> = self
            .results
            .iter()
            .map(|r| {
                let stragglers: Vec<String> = r
                    .analysis
                    .stragglers
                    .iter()
                    .map(|s| {
                        format!(
                            concat!(
                                "        {{\"task\": {}, \"op\": \"{}\", ",
                                "\"bi\": {}, \"bj\": {}, \"level\": {}, ",
                                "\"worker\": {}, \"seconds\": {:.9}}}"
                            ),
                            s.task, s.op, s.target.0, s.target.1, s.level, s.worker, s.seconds,
                        )
                    })
                    .collect();
                let levels: Vec<String> = r
                    .per_level
                    .iter()
                    .map(|l| {
                        format!(
                            concat!(
                                "        {{\"level\": {}, \"tasks\": {}, \"blocks\": {}, ",
                                "\"nnz_total\": {}, \"nnz_max\": {}, \"nnz_mean\": {:.3}, ",
                                "\"nnz_imbalance\": {:.4}, ",
                                "\"seconds_total\": {:.9}, \"seconds_max\": {:.9}, ",
                                "\"time_imbalance\": {:.4}}}"
                            ),
                            l.level,
                            l.tasks,
                            l.blocks,
                            l.nnz_total,
                            l.nnz_max,
                            l.nnz_mean,
                            l.nnz_imbalance,
                            l.seconds_total,
                            l.seconds_max,
                            l.time_imbalance,
                        )
                    })
                    .collect();
                format!(
                    concat!(
                        "    {{\"matrix\": \"{}\", \"blocking\": \"{}\", ",
                        "\"n\": {}, \"nnz\": {}, \"workers\": {}, ",
                        "\"tasks\": {}, \"levels\": {}, ",
                        "\"makespan_seconds\": {:.9}, ",
                        "\"critical_path_seconds\": {:.9}, ",
                        "\"total_task_seconds\": {:.9}, ",
                        "\"scheduling_efficiency\": {:.4}, ",
                        "\"worst_nnz_imbalance\": {:.4}, ",
                        "\"worst_time_imbalance\": {:.4}, ",
                        "\"nnz_imbalance_across\": {:.4}, ",
                        "\"time_imbalance_across\": {:.4}, ",
                        "\"dropped_events\": {},\n",
                        "      \"stragglers\": [\n{}\n      ],\n",
                        "      \"per_level\": [\n{}\n      ]}}"
                    ),
                    r.name,
                    r.blocking,
                    r.n,
                    r.nnz,
                    r.workers,
                    r.tasks,
                    r.levels,
                    r.analysis.makespan_seconds,
                    r.analysis.critical_path_seconds,
                    r.analysis.total_task_seconds,
                    r.analysis.scheduling_efficiency,
                    r.worst_nnz_imbalance,
                    r.worst_time_imbalance,
                    r.nnz_imbalance_across,
                    r.time_imbalance_across,
                    r.dropped_events,
                    stragglers.join(",\n"),
                    levels.join(",\n"),
                )
            })
            .collect();
        format!(
            "{{\n  \"bench\": \"trace\",\n  \"scenario\": \"traced-refactorize\",\n  \
             \"replays\": {},\n  \"results\": [\n{}\n  ]\n}}\n",
            self.replays,
            rows.join(",\n")
        )
    }

    /// Human-readable table (shared by the CLI command and
    /// `--trace-summary`-style inspection).
    pub fn print(&self) {
        println!("\n--- trace bench: traced-refactorize ({} replays/scenario) ---", self.replays);
        for r in &self.results {
            println!(
                "{:14} {:9} w={} | {:4} tasks / {:2} levels | eff {:.2} (crit {:.3}ms / span \
                 {:.3}ms) | within nnz {:.2}x time {:.2}x | across nnz {:.2}x time {:.2}x",
                r.name,
                r.blocking,
                r.workers,
                r.tasks,
                r.levels,
                r.analysis.scheduling_efficiency,
                r.analysis.critical_path_seconds * 1e3,
                r.analysis.makespan_seconds * 1e3,
                r.worst_nnz_imbalance,
                r.worst_time_imbalance,
                r.nnz_imbalance_across,
                r.time_imbalance_across,
            );
            if let Some(s) = r.analysis.stragglers.first() {
                println!(
                    "{:14} {:9}     | top straggler: {}({},{}) level {} worker {} {:.3}ms",
                    "",
                    "",
                    s.op,
                    s.target.0,
                    s.target.1,
                    s.level,
                    s.worker,
                    s.seconds * 1e3,
                );
            }
        }
    }
}

/// Run the traced-refactorize suite: `replays` traced full replays per
/// scenario (the last replay's run is analyzed), one scenario per
/// (matrix, worker count, blocking). Restores the tracing switch to its
/// prior state before returning.
pub fn run(replays: usize, worker_counts: &[u32]) -> TraceReport {
    assert!(replays >= 1, "need at least 1 replay per scenario");
    let suite = [
        ("tiny-bbd", gen::circuit_bbd(gen::CircuitParams { n: 400, ..Default::default() })),
        ("small-grid2d", gen::grid2d_laplacian(24, 24)),
    ];
    let was_on = trace::enabled();
    trace::set_enabled(true);
    let mut results = Vec::new();
    let mut sample_trace = String::new();
    for (name, a) in &suite {
        for &workers in worker_counts {
            for (blocking, opts) in [
                ("irregular", SolveOptions::ours(workers)),
                ("regular", SolveOptions::pangulu(workers)),
            ] {
                let plan = Arc::new(FactorPlan::build(a, &opts).expect("plan build"));
                let mut session = SolverSession::from_plan(plan.clone());
                session.refactorize(&a.values).expect("warmup refactorize");

                // fresh recording window + a scenario-unique trace id, so
                // the analysis below cannot pick up another run's events
                trace::clear();
                let tid = trace::next_trace_id();
                session.set_trace_id(tid);
                for _ in 0..replays {
                    session.refactorize(&a.values).expect("traced refactorize");
                }

                let snap = trace::snapshot();
                let events = snap.all_events();
                // each replay is one DAG run; analyze the last (highest
                // run id) — with `replays` runs in the rings, any
                // overflow evicts older runs first, never the newest
                let run_id = events
                    .iter()
                    .filter(|e| e.kind == trace::EventKind::Task && e.trace_id == tid)
                    .map(|e| e.run_id)
                    .max()
                    .expect("traced refactorize recorded task events");
                let analysis = trace::analyze_run(&plan.dag, &events, run_id, 5)
                    .expect("analysis of a recorded run");
                let per_level = trace::level_balance(&plan.structure, &events, run_id);
                let (nnz_across, time_across) = trace::imbalance_across(&per_level);

                // the profiler's own invariants, gated in-bench so a CI
                // run that completes has verified them: the measured
                // critical chain can never exceed the achieved makespan,
                // and one run's makespan can never exceed the summed task
                // time by more than scheduling gaps (slack covers timer
                // jitter and the inline path's inter-task bookkeeping)
                let slack = 0.05 * analysis.makespan_seconds + 1e-3;
                assert!(
                    analysis.critical_path_seconds <= analysis.makespan_seconds + slack,
                    "critical path {} > makespan {} ({name}/{blocking}, w={workers})",
                    analysis.critical_path_seconds,
                    analysis.makespan_seconds,
                );
                assert!(
                    analysis.makespan_seconds <= analysis.total_task_seconds + slack,
                    "makespan {} > total task seconds {} ({name}/{blocking}, w={workers})",
                    analysis.makespan_seconds,
                    analysis.total_task_seconds,
                );

                sample_trace = trace::chrome_trace_of(&snap);
                results.push(TraceScenario {
                    name: name.to_string(),
                    blocking: blocking.to_string(),
                    n: a.n_rows(),
                    nnz: a.nnz(),
                    workers,
                    tasks: analysis.tasks,
                    levels: per_level.len(),
                    worst_nnz_imbalance: per_level
                        .iter()
                        .map(|l| l.nnz_imbalance)
                        .fold(1.0f64, f64::max),
                    worst_time_imbalance: per_level
                        .iter()
                        .map(|l| l.time_imbalance)
                        .fold(1.0f64, f64::max),
                    nnz_imbalance_across: nnz_across,
                    time_imbalance_across: time_across,
                    dropped_events: snap.dropped_events,
                    analysis,
                    per_level,
                });
            }
        }
    }
    trace::set_enabled(was_on);
    TraceReport { replays, results, sample_trace }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trace_bench_runs_and_reports_all_scenarios() {
        let report = run(2, &[1, 2]);
        assert_eq!(report.results.len(), 8, "2 matrices x 2 worker counts x 2 blockings");
        for r in &report.results {
            assert!(r.tasks > 0, "{}/{}", r.name, r.blocking);
            assert!(r.levels > 0);
            assert_eq!(r.tasks, r.analysis.tasks);
            assert!(r.analysis.scheduling_efficiency > 0.0);
            assert!(r.analysis.critical_path_seconds <= r.analysis.makespan_seconds + 1e-3);
            assert!(r.worst_nnz_imbalance >= 1.0);
            assert!(r.worst_time_imbalance >= 1.0);
            assert!(r.nnz_imbalance_across >= 1.0);
            // the level rows cover every analyzed task exactly once
            assert_eq!(r.per_level.iter().map(|l| l.tasks).sum::<usize>(), r.tasks);
        }
        let json = report.to_json();
        assert!(json.contains("\"bench\": \"trace\""));
        assert!(json.contains("\"scheduling_efficiency\""));
        assert!(json.contains("\"per_level\""));
        assert!(json.contains("\"blocking\": \"irregular\""));
        assert!(json.contains("\"blocking\": \"regular\""));
        trace::parse_json(&json).expect("BENCH_trace.json parses");
        // the sample artifact is valid Chrome-trace JSON with events
        let sample = trace::parse_json(&report.sample_trace).expect("sample trace parses");
        let events = sample.get("traceEvents").unwrap().as_arr().unwrap();
        assert!(!events.is_empty());
    }
}
