//! Seeded, deterministic fault injection — the chaos layer behind
//! `repro chaos-bench` and `rust/tests/chaos.rs`.
//!
//! The serving stack promises containment: a kernel panic becomes one
//! [`crate::numeric::FactorError::TaskPanic`], a non-finite factor
//! quarantines one tenant, a corrupt plan file is skipped at warm-up.
//! Those paths are worthless untested, and real faults are too rare and
//! too irreproducible to test against. This module injects them on
//! demand, *deterministically*: a [`FaultPlan`] derives every decision
//! from a seed and a monotone per-site sequence number, so a failing
//! chaos run replays bit-for-bit.
//!
//! ## Cost model
//!
//! Injection is always compiled and **free when off** in the same sense
//! as [`crate::obs::trace`]: every hook starts with one `Relaxed` load
//! of a static `AtomicBool` and returns immediately when no plan is
//! installed. No sequence counters tick, no locks are taken.
//!
//! ## Fault sites
//!
//! | hook                  | boundary          | injected fault                          |
//! |-----------------------|-------------------|-----------------------------------------|
//! | [`on_task`]           | executor job      | panic at the Nth task; artificial stall |
//! | [`poison_value`]      | kernel dispatch   | NaN/Inf written into the target block   |
//! | [`force_zero_pivot`]  | kernel dispatch   | zeroed pivot entry before GETRF         |
//! | [`corrupt_persist`]   | persist encode    | byte flip / truncation of the plan file |
//!
//! Each site has its own sequence counter (reset by [`install`]), so a
//! one-shot trigger like `panic_at_task(3)` means "the 4th task executed
//! *after install*" regardless of what other sites observed.
//!
//! ## Accounting
//!
//! Every fired injection increments a per-kind counter readable via
//! [`counters`]. The chaos suite's balance invariant — every injected
//! fault surfaces as exactly one typed per-request error or one counted
//! transparent recovery — is checked against these totals, and
//! [`register_metrics`] mirrors them into an [`crate::obs::Registry`]
//! as `sparselu_faults_injected_total{kind=...}`.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Global on/off switch; a static so the fault-off check is one
/// `Relaxed` load and never touches the plan mutex.
static ENABLED: AtomicBool = AtomicBool::new(false);

/// The installed plan. Locked only on the fault-on path; hooks clone the
/// `Arc` out so injection decisions never hold the lock while sleeping
/// or panicking.
static PLAN: Mutex<Option<Arc<FaultPlan>>> = Mutex::new(None);

// Per-site sequence counters (reset by `install`). Sequence numbers are
// allocated only while a plan is installed, so one-shot trigger indices
// are stable offsets from the install point.
static TASK_SEQ: AtomicU64 = AtomicU64::new(0);
static KERNEL_SEQ: AtomicU64 = AtomicU64::new(0);
static GETRF_SEQ: AtomicU64 = AtomicU64::new(0);
static PERSIST_SEQ: AtomicU64 = AtomicU64::new(0);

// Fired-injection counters, one per fault kind.
static INJ_PANICS: AtomicU64 = AtomicU64::new(0);
static INJ_STALLS: AtomicU64 = AtomicU64::new(0);
static INJ_NANS: AtomicU64 = AtomicU64::new(0);
static INJ_ZERO_PIVOTS: AtomicU64 = AtomicU64::new(0);
static INJ_PERSIST: AtomicU64 = AtomicU64::new(0);

/// Is fault injection armed? One `Relaxed` atomic load — the entire
/// cost of the fault-off path.
#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// A deterministic fault schedule. Build with [`FaultPlan::seeded`] and
/// the `*_at` / `*_rate` builders, then arm with [`install`].
///
/// Two trigger styles compose:
///
/// * **one-shot** (`panic_at_task(n)`, ...): fires exactly once, at the
///   `n`th post-install event of that site — the style the invariant
///   tests use, because each firing maps to one observable outcome;
/// * **rate-based** (`panic_rate(p)`, ...): each event fires
///   independently with probability `p`, decided by hashing
///   `(seed, site, sequence)` — the style `repro chaos-bench` sweeps.
#[derive(Clone, Debug, Default)]
pub struct FaultPlan {
    /// Seed for every rate decision and poison-value choice.
    pub seed: u64,
    /// One-shot executor-task sequence numbers that panic.
    pub panic_at: Vec<u64>,
    /// Per-task panic probability in `[0, 1]`.
    pub panic_rate: f64,
    /// One-shot executor-task sequence numbers that stall.
    pub stall_at: Vec<u64>,
    /// Per-task stall probability in `[0, 1]`.
    pub stall_rate: f64,
    /// Stall duration; zero means the 200µs default.
    pub stall_micros: u64,
    /// One-shot kernel-dispatch sequence numbers that poison the
    /// dispatched op's target block with NaN/Inf.
    pub nan_at: Vec<u64>,
    /// Per-dispatch poison probability in `[0, 1]`.
    pub nan_rate: f64,
    /// One-shot GETRF-dispatch sequence numbers whose pivot is zeroed.
    pub zero_pivot_at: Vec<u64>,
    /// Per-GETRF zero-pivot probability in `[0, 1]`.
    pub zero_pivot_rate: f64,
    /// One-shot `save_plan` call sequence numbers whose encoded bytes
    /// are corrupted.
    pub corrupt_persist_at: Vec<u64>,
    /// Per-save corruption probability in `[0, 1]`.
    pub corrupt_persist_rate: f64,
    /// Corrupt by truncating the file instead of flipping a byte.
    pub truncate_persist: bool,
}

impl FaultPlan {
    /// An empty plan (injects nothing) carrying `seed`.
    pub fn seeded(seed: u64) -> Self {
        Self { seed, ..Self::default() }
    }

    /// Panic at the `n`th executor task after install.
    pub fn panic_at_task(mut self, n: u64) -> Self {
        self.panic_at.push(n);
        self
    }

    /// Panic each executor task independently with probability `p`.
    pub fn panic_rate(mut self, p: f64) -> Self {
        self.panic_rate = p;
        self
    }

    /// Stall the `n`th executor task after install.
    pub fn stall_at_task(mut self, n: u64) -> Self {
        self.stall_at.push(n);
        self
    }

    /// Stall each executor task independently with probability `p`,
    /// sleeping `micros` each time.
    pub fn stall_rate(mut self, p: f64, micros: u64) -> Self {
        self.stall_rate = p;
        self.stall_micros = micros;
        self
    }

    /// Poison the target block of the `n`th kernel dispatch after
    /// install with a NaN or Inf (seed-chosen).
    pub fn nan_at_kernel(mut self, n: u64) -> Self {
        self.nan_at.push(n);
        self
    }

    /// Poison each kernel dispatch independently with probability `p`.
    pub fn nan_rate(mut self, p: f64) -> Self {
        self.nan_rate = p;
        self
    }

    /// Zero the pivot of the `n`th GETRF dispatch after install.
    pub fn zero_pivot_at_getrf(mut self, n: u64) -> Self {
        self.zero_pivot_at.push(n);
        self
    }

    /// Zero each GETRF pivot independently with probability `p`.
    pub fn zero_pivot_rate(mut self, p: f64) -> Self {
        self.zero_pivot_rate = p;
        self
    }

    /// Corrupt the bytes of the `n`th `save_plan` call after install.
    pub fn corrupt_persist_at(mut self, n: u64) -> Self {
        self.corrupt_persist_at.push(n);
        self
    }

    /// Truncate instead of byte-flipping when persist corruption fires.
    pub fn truncate_persist(mut self) -> Self {
        self.truncate_persist = true;
        self
    }

    /// Does this plan inject anything at all?
    pub fn is_active(&self) -> bool {
        !self.panic_at.is_empty()
            || !self.stall_at.is_empty()
            || !self.nan_at.is_empty()
            || !self.zero_pivot_at.is_empty()
            || !self.corrupt_persist_at.is_empty()
            || self.panic_rate > 0.0
            || self.stall_rate > 0.0
            || self.nan_rate > 0.0
            || self.zero_pivot_rate > 0.0
            || self.corrupt_persist_rate > 0.0
    }
}

/// Arm `plan` process-wide, resetting all sequence and injection
/// counters so one-shot trigger indices count from this instant.
pub fn install(plan: FaultPlan) {
    let mut slot = PLAN.lock().unwrap_or_else(|p| p.into_inner());
    for c in [
        &TASK_SEQ,
        &KERNEL_SEQ,
        &GETRF_SEQ,
        &PERSIST_SEQ,
        &INJ_PANICS,
        &INJ_STALLS,
        &INJ_NANS,
        &INJ_ZERO_PIVOTS,
        &INJ_PERSIST,
    ] {
        c.store(0, Ordering::Relaxed);
    }
    *slot = Some(Arc::new(plan));
    ENABLED.store(true, Ordering::Relaxed);
}

/// Disarm fault injection. Counters keep their totals until the next
/// [`install`] so post-mortem accounting can still read them.
pub fn clear() {
    ENABLED.store(false, Ordering::Relaxed);
    *PLAN.lock().unwrap_or_else(|p| p.into_inner()) = None;
}

/// RAII arming: [`install`] on construction, [`clear`] on drop — keeps
/// a panicking test from leaking an armed plan into its neighbors.
pub struct FaultGuard(());

impl FaultGuard {
    /// Arm `plan` for the lifetime of the returned guard.
    pub fn new(plan: FaultPlan) -> Self {
        install(plan);
        FaultGuard(())
    }
}

impl Drop for FaultGuard {
    fn drop(&mut self) {
        clear();
    }
}

/// Snapshot of fired injections since the last [`install`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct FaultCounters {
    /// Kernel panics raised inside executor tasks.
    pub panics: u64,
    /// Artificial stalls slept inside executor tasks.
    pub stalls: u64,
    /// Blocks poisoned with NaN/Inf after a kernel dispatch.
    pub nans: u64,
    /// GETRF pivots zeroed before dispatch.
    pub zero_pivots: u64,
    /// Persisted plan encodings corrupted or truncated.
    pub persist: u64,
}

impl FaultCounters {
    /// All fired injections.
    pub fn total(&self) -> u64 {
        self.panics + self.stalls + self.nans + self.zero_pivots + self.persist
    }

    /// Injections that must each surface as exactly one per-request
    /// error or one counted transparent recovery (stalls only delay).
    pub fn erroring(&self) -> u64 {
        self.panics + self.nans + self.zero_pivots
    }
}

/// Read the fired-injection counters.
pub fn counters() -> FaultCounters {
    FaultCounters {
        panics: INJ_PANICS.load(Ordering::Relaxed),
        stalls: INJ_STALLS.load(Ordering::Relaxed),
        nans: INJ_NANS.load(Ordering::Relaxed),
        zero_pivots: INJ_ZERO_PIVOTS.load(Ordering::Relaxed),
        persist: INJ_PERSIST.load(Ordering::Relaxed),
    }
}

fn plan() -> Option<Arc<FaultPlan>> {
    PLAN.lock().unwrap_or_else(|p| p.into_inner()).clone()
}

/// SplitMix64 finalizer — the per-event hash behind every rate decision.
fn mix(x: u64) -> u64 {
    let mut z = x.wrapping_add(0x9E3779B97F4A7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// Deterministic Bernoulli: hash `(seed, site, seq)` against `rate`.
fn roll(seed: u64, site: u64, seq: u64, rate: f64) -> bool {
    if rate <= 0.0 {
        return false;
    }
    if rate >= 1.0 {
        return true;
    }
    let h = mix(seed ^ site.wrapping_mul(0xA24BAED4963EE407) ^ seq);
    ((h >> 11) as f64) * (1.0 / (1u64 << 53) as f64) < rate
}

/// Executor-job boundary hook: called once per task execution, inside
/// the scheduler's `catch_unwind`. May sleep (artificial stall) and may
/// panic (injected kernel panic — contained by the executor exactly
/// like a real kernel bug and surfaced as `FactorError::TaskPanic`).
#[inline]
pub fn on_task() {
    if !enabled() {
        return;
    }
    on_task_slow();
}

#[cold]
fn on_task_slow() {
    let Some(plan) = plan() else { return };
    let seq = TASK_SEQ.fetch_add(1, Ordering::Relaxed);
    if plan.stall_at.contains(&seq) || roll(plan.seed, 0x57A11, seq, plan.stall_rate) {
        INJ_STALLS.fetch_add(1, Ordering::Relaxed);
        let micros = if plan.stall_micros == 0 { 200 } else { plan.stall_micros };
        std::thread::sleep(Duration::from_micros(micros));
    }
    if plan.panic_at.contains(&seq) || roll(plan.seed, 0x9A21C, seq, plan.panic_rate) {
        INJ_PANICS.fetch_add(1, Ordering::Relaxed);
        panic!("fault-injected kernel panic (task seq {seq})");
    }
}

/// Kernel-dispatch boundary hook: should this dispatch's target block
/// be poisoned, and with what value? Called once per dispatched op;
/// returns the NaN/Inf to write (seed decides which) or `None`.
#[inline]
pub fn poison_value() -> Option<f64> {
    if !enabled() {
        return None;
    }
    poison_value_slow()
}

#[cold]
fn poison_value_slow() -> Option<f64> {
    let plan = plan()?;
    let seq = KERNEL_SEQ.fetch_add(1, Ordering::Relaxed);
    if plan.nan_at.contains(&seq) || roll(plan.seed, 0xDEAD1, seq, plan.nan_rate) {
        INJ_NANS.fetch_add(1, Ordering::Relaxed);
        let v = if mix(plan.seed ^ seq) & 1 == 0 { f64::NAN } else { f64::INFINITY };
        return Some(v);
    }
    None
}

/// Kernel-dispatch boundary hook: should this GETRF's pivot entry be
/// zeroed before the kernel runs? Called once per GETRF dispatch.
#[inline]
pub fn force_zero_pivot() -> bool {
    if !enabled() {
        return false;
    }
    force_zero_pivot_slow()
}

#[cold]
fn force_zero_pivot_slow() -> bool {
    let Some(plan) = plan() else { return false };
    let seq = GETRF_SEQ.fetch_add(1, Ordering::Relaxed);
    if plan.zero_pivot_at.contains(&seq) || roll(plan.seed, 0x21607, seq, plan.zero_pivot_rate) {
        INJ_ZERO_PIVOTS.fetch_add(1, Ordering::Relaxed);
        return true;
    }
    false
}

/// Persist boundary hook: corrupt the encoded plan bytes in place
/// (deterministic byte flip, or truncation when the plan asks for it).
/// Returns whether corruption fired.
#[inline]
pub fn corrupt_persist(bytes: &mut Vec<u8>) -> bool {
    if !enabled() {
        return false;
    }
    corrupt_persist_slow(bytes)
}

#[cold]
fn corrupt_persist_slow(bytes: &mut Vec<u8>) -> bool {
    let Some(plan) = plan() else { return false };
    let seq = PERSIST_SEQ.fetch_add(1, Ordering::Relaxed);
    let fire = plan.corrupt_persist_at.contains(&seq)
        || roll(plan.seed, 0xC0DE5, seq, plan.corrupt_persist_rate);
    if !fire || bytes.is_empty() {
        return false;
    }
    INJ_PERSIST.fetch_add(1, Ordering::Relaxed);
    if plan.truncate_persist {
        let keep = bytes.len() / 2;
        bytes.truncate(keep);
    } else {
        let idx = (mix(plan.seed ^ seq) as usize) % bytes.len();
        bytes[idx] ^= 0x40;
    }
    true
}

/// Mirror the fired-injection counters into `registry` as
/// `sparselu_faults_injected_total{kind=...}`, refreshed at scrape time
/// (same snapshot-mirror pattern as [`crate::obs::register_executor`]).
pub fn register_metrics(registry: &std::sync::Arc<crate::obs::Registry>) {
    const HELP: &str = "Faults fired by the installed FaultPlan, by kind.";
    let panics = registry.counter("sparselu_faults_injected_total", HELP, &[("kind", "panic")]);
    let stalls = registry.counter("sparselu_faults_injected_total", HELP, &[("kind", "stall")]);
    let nans = registry.counter("sparselu_faults_injected_total", HELP, &[("kind", "nan")]);
    let pivots =
        registry.counter("sparselu_faults_injected_total", HELP, &[("kind", "zero_pivot")]);
    let persist =
        registry.counter("sparselu_faults_injected_total", HELP, &[("kind", "persist")]);
    registry.register_refresher("fault-injection", move || {
        let c = counters();
        panics.mirror(c.panics);
        stalls.mirror(c.stalls);
        nans.mirror(c.nans);
        pivots.mirror(c.zero_pivots);
        persist.mirror(c.persist);
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    // Fault state is process-global; every test that installs a plan
    // must hold this lock (the integration chaos suite does the same).
    static LOCK: Mutex<()> = Mutex::new(());

    #[test]
    fn disabled_hooks_are_inert() {
        let _l = LOCK.lock().unwrap_or_else(|p| p.into_inner());
        clear();
        assert!(!enabled());
        on_task();
        assert_eq!(poison_value(), None);
        assert!(!force_zero_pivot());
        let mut b = vec![1u8, 2, 3];
        assert!(!corrupt_persist(&mut b));
        assert_eq!(b, vec![1, 2, 3]);
    }

    #[test]
    fn one_shot_triggers_fire_once_and_count() {
        let _l = LOCK.lock().unwrap_or_else(|p| p.into_inner());
        let _g = FaultGuard::new(
            FaultPlan::seeded(7).nan_at_kernel(1).zero_pivot_at_getrf(0),
        );
        assert_eq!(poison_value(), None); // seq 0
        let p = poison_value(); // seq 1 fires
        assert!(p.is_some_and(|v| !v.is_finite()));
        assert_eq!(poison_value(), None); // seq 2
        assert!(force_zero_pivot()); // getrf seq 0 fires
        assert!(!force_zero_pivot());
        let c = counters();
        assert_eq!((c.nans, c.zero_pivots), (1, 1));
        assert_eq!(c.total(), 2);
    }

    #[test]
    fn rate_decisions_are_deterministic_in_seed_and_seq() {
        let _l = LOCK.lock().unwrap_or_else(|p| p.into_inner());
        let fired: Vec<bool> = (0..256).map(|s| roll(42, 0xDEAD1, s, 0.25)).collect();
        let again: Vec<bool> = (0..256).map(|s| roll(42, 0xDEAD1, s, 0.25)).collect();
        assert_eq!(fired, again);
        let hits = fired.iter().filter(|&&b| b).count();
        assert!((32..96).contains(&hits), "rate 0.25 fired {hits}/256");
        assert!(!roll(42, 0xDEAD1, 0, 0.0));
        assert!(roll(42, 0xDEAD1, 0, 1.0));
    }

    #[test]
    fn persist_corruption_flips_and_truncates() {
        let _l = LOCK.lock().unwrap_or_else(|p| p.into_inner());
        {
            let _g = FaultGuard::new(FaultPlan::seeded(3).corrupt_persist_at(0));
            let orig = vec![0u8; 64];
            let mut b = orig.clone();
            assert!(corrupt_persist(&mut b));
            assert_eq!(b.len(), 64);
            assert_ne!(b, orig);
        }
        {
            let _g =
                FaultGuard::new(FaultPlan::seeded(3).corrupt_persist_at(0).truncate_persist());
            let mut b = vec![0u8; 64];
            assert!(corrupt_persist(&mut b));
            assert_eq!(b.len(), 32);
            assert_eq!(counters().persist, 1);
        }
        assert!(!enabled());
    }

    #[test]
    fn panic_injection_panics_inside_task_hook() {
        let _l = LOCK.lock().unwrap_or_else(|p| p.into_inner());
        let _g = FaultGuard::new(FaultPlan::seeded(1).panic_at_task(0));
        let r = std::panic::catch_unwind(on_task);
        assert!(r.is_err());
        assert_eq!(counters().panics, 1);
        // the one-shot already fired; later tasks run clean
        on_task();
        assert_eq!(counters().panics, 1);
    }
}
