//! `repro` — CLI for the sparselu reproduction.
//!
//! ```text
//! repro solve --matrix gen:bbd=4000 --workers 4 --blocking irregular
//! repro solve --matrix path/to/suitesparse.mtx --pjrt
//! repro analyze --matrix gen:grid2d=100x100
//! repro bench table4 --out results
//! repro bench all --out results --scale medium
//! repro serve-bench --matrix gen:bbd=2000 --clients 8 --mix 1,6,3
//! repro artifacts-check
//! ```
//!
//! (No clap offline — small hand-rolled parser.)

use anyhow::{bail, Context, Result};
use sparselu::bench_harness::{self, SuiteScale};
use sparselu::numeric::Precision;
use sparselu::obs;
use sparselu::ordering::OrderingMethod;
use sparselu::runtime::PjrtDense;
use sparselu::serve::{loadgen, persist, RouterConfig, ScenarioMix};
use sparselu::session::{FactorPlan, PlanCache, SolverSession};
use sparselu::solver::{SolveOptions, Solver};
use sparselu::sparse::{gen, io, residual, Csc};
use sparselu::util::timer::timed;
use std::collections::HashMap;
use std::sync::Arc;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = args.first() else {
        print_help();
        return Ok(());
    };
    let flags = parse_flags(&args[1..]);
    match cmd.as_str() {
        "solve" => cmd_solve(&flags),
        "analyze" => cmd_analyze(&flags),
        "bench" => {
            let exp = args
                .get(1)
                .filter(|a| !a.starts_with("--"))
                .context("bench needs an experiment name (or `all`)")?;
            let out = flags.get("out").cloned().unwrap_or_else(|| "results".into());
            let scale = match flags.get("scale").map(String::as_str) {
                Some("small") => SuiteScale::Small,
                _ => SuiteScale::Medium,
            };
            bench_harness::run(exp, std::path::Path::new(&out), scale)
        }
        "serve-bench" => cmd_serve_bench(&flags),
        "chaos-bench" => cmd_chaos_bench(&flags),
        "kernel-bench" => cmd_kernel_bench(&flags),
        "sched-bench" => cmd_sched_bench(&flags),
        "plan-bench" => cmd_plan_bench(&flags),
        "trace" => cmd_trace(&flags),
        "trace-bench" => cmd_trace_bench(&flags),
        "metrics-dump" => cmd_metrics_dump(&flags),
        "artifacts-check" => cmd_artifacts_check(&flags),
        "help" | "--help" | "-h" => {
            print_help();
            Ok(())
        }
        other => bail!("unknown command {other:?} — try `repro help`"),
    }
}

fn print_help() {
    println!(
        "repro — structure-aware irregular blocking for sparse LU (CS.DC 2025 reproduction)

USAGE:
  repro solve   --matrix <SPEC> [--workers N] [--blocking B] [--ordering O] [--pjrt]
  repro analyze --matrix <SPEC>
  repro bench   <EXPERIMENT|all> [--out DIR] [--scale small|medium]
  repro serve-bench [--matrix SPEC] [--clients K] [--requests N] [--sessions S]
                    [--mix F,S,V] [--tenants M] [--plan-dir DIR] [--out FILE]
                    [--workers N] [--blocking B] [--precision full|mixed]
                    [--metrics-addr HOST:PORT] [--metrics-out FILE] [--autoscale]
  repro chaos-bench [--rounds N] [--solves N] [--seed S] [--out FILE] [--metrics-out FILE]
  repro kernel-bench [--reps N] [--out FILE]
  repro sched-bench [--replays N] [--worker-counts 1,2,4] [--out FILE]
  repro plan-bench  [--replays N] [--worker-counts 2,8] [--out FILE]
  repro trace       [--matrix SPEC] [--workers N] [--blocking B] [--replays N] [--out FILE]
  repro trace-bench [--replays N] [--worker-counts 1,4] [--out FILE] [--trace-out FILE]
  repro metrics-dump (--addr HOST:PORT | --file PATH | --trace-summary FILE) [--check]
  repro artifacts-check [--dir artifacts]

CHAOS-BENCH (the fault-injection availability bench):
  A 4-tenant router serves a fixed refactorize+solve script while a
  seeded FaultPlan injects kernel panics, NaN/Inf poisoning, forced
  zero pivots and stalls at increasing rates. Per sweep point the
  bench reports availability and p50/p99 latency; it then poisons
  one tenant into quarantine and times the background-rebuild
  recovery, checking the post-recovery solution is bit-identical to a
  fault-free oracle. The one-shot point (exactly one injected panic)
  must keep availability >= 99 percent — the bench asserts it, so a
  failing gate fails the run. Results go to --out (default BENCH_chaos.json);
  the run's metric exposition (fault/quarantine/degraded counters) is
  written to --metrics-out (default BENCH_chaos_metrics.txt) for
  `repro metrics-dump --file ... --check`.

KERNEL-BENCH (the dense-kernel raw-speed bench):
  Scalar oracle vs register-blocked tiled fast path, per kernel (GETRF /
  TRSM-lower / TRSM-upper / GEMM) x block shape x fill density, best of
  --reps calls (default 200). The bench asserts bitwise scalar==tiled
  identity on every row before timing anything — a written
  BENCH_kernels.json is itself the differential gate passing. Dense-
  region rows (every dim >= 64, density >= 0.5) carry the headline
  speedup; results go to --out (default BENCH_kernels.json).

SCHED-BENCH (the scheduler bench):
  Refactorize-storm: many tiny full + partial re-factorizations of small
  fixed-pattern matrices, run under the spawn-per-call baseline and the
  persistent work-stealing executor. Per-storm throughput, the
  persistent/spawn speedup, and the executor's steal/wakeup/park
  counters are written to --out (default BENCH_sched.json).

PLAN-BENCH (the plan-construction bench):
  Cold-start: build the full FactorPlan (ordering + symbolic + blocking
  + DAG + scatter map) for each suite matrix, sequentially and on the
  persistent executor, asserting both builds produce identical plans.
  Best-of-N wall clock, the parallel/sequential speedup, and the
  per-phase breakdown are written to --out (default BENCH_plan.json).

SERVE-BENCH (the serving-layer load generator):
  K closed-loop client threads drive a shared-plan session pool over a
  full-refactorize / device-stamp / solve-only scenario mix (--mix
  weights, default 1,6,3) and the run's throughput + p50/p99 latency per
  scenario is written to --out (default BENCH_serve.json). With
  --plan-dir the FactorPlan is persisted there and warm-loaded on the
  next run (cold start = one disk read, no symbolic/blocking). With
  --tenants M >= 2 (default 3) a second, multi-tenant scenario also
  runs: K clients spread over M distinct sparsity patterns, routed by
  pattern fingerprint through serve::Router to per-tenant shards that
  drain concurrently — per-tenant throughput and p50/p99 land in the
  same JSON under "multi_tenant". --tenants 1 skips it.

  --precision mixed stores factors in f32 (halving factor bandwidth in
  the refactorize storm) and answers solve requests by f32 triangular
  solves plus f64 iterative refinement to full accuracy; shards then
  accept SolveMixed requests and reject plain solves. Default: full
  (f64 factors, plain solves).

  With --metrics-addr a Prometheus-style scrape endpoint (GET /metrics,
  text exposition 0.0.4, plus /healthz) serves the run's per-tenant
  queue/latency/batch histograms, session-pool occupancy, plan-cache and
  executor counters while the load runs; at the end the bench
  self-scrapes, validates the exposition format, and writes the text to
  --metrics-out (default BENCH_metrics.txt). --autoscale additionally
  runs the SLO-driven controller during the multi-tenant phase (pool
  resize + queue rebound + low-priority shedding).

TRACE (task-level tracing):
  Record every executed DAG task (kernel kind, target block, level,
  worker, steal attribution) of a few traced re-factorizations and write
  Chrome-trace JSON to --out (default trace.json), loadable in Perfetto
  or chrome://tracing. A serving process exposes the same export live on
  GET /trace next to /metrics. Tracing is always compiled in; when off
  the executor pays one atomic load per run.

TRACE-BENCH (the profiler bench):
  Traced re-factorizations of the small suite under both the paper's
  irregular blocking (`ours`) and the regular/PanguLU-style baseline:
  measured critical path vs achieved makespan (scheduling efficiency),
  top straggler tasks, and per-level nonzero/time imbalance — the
  paper's balance claim, measured instead of modeled. Results go to
  --out (default BENCH_trace.json); the last scenario's raw recording to
  --trace-out (default BENCH_trace.sample.trace.json). The bench gates
  its own sanity inline: critical path <= makespan <= summed task time.

METRICS-DUMP (exposition inspection):
  Fetch /metrics from a live endpoint (--addr) or read a scraped file
  (--file), validate the exposition format strictly, and print the text
  (--check prints only the family/series/sample summary). Exits nonzero
  on any format violation. With --trace-summary FILE instead, read a
  BENCH_trace.json and print scheduling efficiency, the top stragglers
  and the per-level imbalance of every scenario.

MATRIX SPEC:
  path/to/file.mtx             MatrixMarket file (SuiteSparse downloads work)
  gen:grid2d=100x100           2D Laplacian          (ecology1-like)
  gen:grid3d=20x20x18          3D Laplacian          (apache2-like)
  gen:bbd=4000                 circuit w/ dense border (ASIC_680k-like)
  gen:graph=2000,4             directed weighted graph (cage/language-like)
  gen:fem=3000                 banded FEM            (boneS10-like)
  gen:em=2500                  electromagnetics      (offshore-like)
  gen:tridiag=5000             tridiagonal           (linear archetype)
  gen:uniform=1500,0.01        uniform random        (quadratic archetype)

BLOCKING (--blocking):
  irregular (default) | pangulu | regular:SIZE | superlu

EXPERIMENTS: {}",
        bench_harness::EXPERIMENTS.join(" ")
    );
}

fn parse_flags(args: &[String]) -> HashMap<String, String> {
    let mut flags = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        if let Some(name) = args[i].strip_prefix("--") {
            match args.get(i + 1) {
                Some(v) if !v.starts_with("--") => {
                    flags.insert(name.to_string(), v.clone());
                    i += 2;
                }
                _ => {
                    flags.insert(name.to_string(), "true".into());
                    i += 1;
                }
            }
        } else {
            i += 1;
        }
    }
    flags
}

fn load_matrix(spec: &str) -> Result<Csc> {
    if let Some(gen_spec) = spec.strip_prefix("gen:") {
        let (kind, param) = gen_spec
            .split_once('=')
            .context("generator spec must be gen:kind=params")?;
        let dims: Vec<&str> = param.split(['x', ',']).collect();
        let num = |i: usize| -> Result<usize> {
            dims.get(i)
                .context("missing dimension")?
                .parse::<usize>()
                .context("bad dimension")
        };
        Ok(match kind {
            "grid2d" => gen::grid2d_laplacian(num(0)?, num(1)?),
            "grid3d" => gen::grid3d_laplacian(num(0)?, num(1)?, num(2)?),
            "bbd" => gen::circuit_bbd(gen::CircuitParams { n: num(0)?, ..Default::default() }),
            "graph" => gen::directed_graph(num(0)?, num(1).unwrap_or(4), 0xBEEF),
            "fem" => gen::banded_fem(num(0)?, &[1, 2, 3, 40, 41], 0.85, 0xFE3),
            "em" => gen::electromagnetics_like(num(0)?, 16, 2, 0xE3),
            "tridiag" => gen::tridiagonal(num(0)?),
            "uniform" => {
                let d: f64 = dims.get(1).unwrap_or(&"0.01").parse()?;
                gen::uniform_random(num(0)?, d, 0x07)
            }
            other => bail!("unknown generator {other:?}"),
        })
    } else {
        io::read_matrix_market(spec).with_context(|| format!("reading {spec}"))
    }
}

fn options_from_flags(flags: &HashMap<String, String>) -> Result<SolveOptions> {
    let workers: u32 = flags.get("workers").map(|s| s.parse()).transpose()?.unwrap_or(1);
    let mut opts = match flags.get("blocking").map(String::as_str) {
        None | Some("irregular") => SolveOptions::ours(workers),
        Some("pangulu") => SolveOptions::pangulu(workers),
        Some("superlu") => SolveOptions::superlu_like(workers),
        Some(s) if s.starts_with("regular:") => {
            let size: usize = s["regular:".len()..].parse().context("regular:SIZE")?;
            SolveOptions::pangulu_with_size(workers, size)
        }
        Some(other) => bail!("unknown blocking {other:?}"),
    };
    if let Some(ord) = flags.get("ordering") {
        opts.ordering = ord.parse::<OrderingMethod>().map_err(|e| anyhow::anyhow!(e))?;
    }
    Ok(opts)
}

fn cmd_solve(flags: &HashMap<String, String>) -> Result<()> {
    let spec = flags.get("matrix").context("--matrix required")?;
    let a = load_matrix(spec)?;
    println!("matrix: {} n={} nnz={}", spec, a.n_rows(), a.nnz());
    let opts = options_from_flags(flags)?;

    let pjrt;
    let mut solver = if flags.contains_key("pjrt") {
        let dir = flags.get("artifacts").cloned().unwrap_or_else(|| "artifacts".into());
        pjrt = PjrtDense::load(&dir).context("loading PJRT artifacts (run `make artifacts`)")?;
        println!("PJRT backend: {} artifacts loaded", pjrt.num_artifacts());
        Solver::with_backend(opts, &pjrt)
    } else {
        Solver::new(opts)
    };

    let f = solver
        .factorize(&a)
        .map_err(|e| anyhow::anyhow!("factorization failed: {e}"))?;
    let r = &f.report;
    println!("\n--- pipeline report ---");
    println!("n                : {}", r.n);
    println!(
        "nnz(A)           : {}  nnz(L+U): {}  (fill {:.2}x)",
        r.nnz_a,
        r.nnz_ldu,
        r.nnz_ldu as f64 / r.nnz_a as f64
    );
    println!("flops            : {:.3e}", r.flops);
    println!("reorder          : {:.4}s", r.reorder_seconds);
    println!("symbolic         : {:.4}s", r.symbolic_seconds);
    println!("preprocess       : {:.4}s", r.preprocess_seconds);
    println!(
        "numeric          : {:.4}s ({:.0}% of total)",
        r.numeric_seconds,
        r.numeric_share() * 100.0
    );
    println!("blocks           : {} ({} nonempty)", r.num_blocks, r.nonempty_blocks);
    println!("tasks            : {} in {} DAG levels", r.tasks, r.dag_levels);
    println!("block nnz CV     : {:.3}", r.balance.block_summary.cv());
    println!(
        "modeled A100     : makespan {:.4}s on {} device(s)",
        r.modeled_makespan,
        r.measured_busy.len()
    );
    if r.measured_busy.len() > 1 {
        println!(
            "measured busy    : {:?}",
            r.measured_busy.iter().map(|b| format!("{b:.3}s")).collect::<Vec<_>>()
        );
    }

    // verify with a solve
    let b: Vec<f64> = (0..a.n_rows()).map(|i| 1.0 + (i % 10) as f64).collect();
    let x = f.solve(&b);
    let res = residual(&a, &x, &b);
    println!("residual         : {res:.3e}");
    if res > 1e-6 {
        bail!("residual too large — numeric factorization suspect");
    }
    Ok(())
}

fn cmd_analyze(flags: &HashMap<String, String>) -> Result<()> {
    let spec = flags.get("matrix").context("--matrix required")?;
    let a = load_matrix(spec)?;
    println!("matrix: {} n={} nnz={}", spec, a.n_rows(), a.nnz());

    let perm = sparselu::ordering::order(&a, OrderingMethod::MinDegree);
    let pa = a.permute_sym(perm.as_slice());
    let sym = sparselu::symbolic::analyze(&pa);
    let ldu = sym.ldu_pattern(&pa).map_err(|e| anyhow::anyhow!("{e}"))?;
    println!("after min-degree + symbolic:");
    println!("  nnz(L+U) = {} (fill {:.2}x)", sym.nnz_ldu(), sym.fill_ratio(&a));
    println!("  flops    = {:.3e}", sym.flops());

    let feature = sparselu::blocking::DiagFeature::from_csc(&ldu);
    let curve = feature.curve();
    println!("diagonal block-based feature (Algorithm 2):");
    println!(
        "  quadratic score : {:+.4}  (≈0 linear, <0 bottom-right-heavy)",
        curve.quadratic_score()
    );
    println!("  max jump        : {:.4}   (large ⇒ dense rows/cols)", curve.max_jump());

    let blocking = sparselu::blocking::irregular_blocking(
        &curve,
        &sparselu::blocking::IrregularParams::default(),
    );
    let sizes = blocking.sizes();
    let summary =
        sparselu::util::Summary::of(&sizes.iter().map(|&s| s as f64).collect::<Vec<_>>());
    println!("irregular blocking (Algorithm 3):");
    println!(
        "  {} blocks, sizes min/mean/max = {}/{:.0}/{}",
        blocking.num_blocks(),
        summary.min,
        summary.mean,
        summary.max
    );
    let options = sparselu::blocking::selection::scaled_options(a.n_cols());
    let sel = sparselu::blocking::selection::select_from(a.n_cols(), ldu.nnz(), &options);
    println!("PanguLU selection tree would pick: {sel} (from {options:?})");
    Ok(())
}

fn cmd_serve_bench(flags: &HashMap<String, String>) -> Result<()> {
    let spec = flags.get("matrix").cloned().unwrap_or_else(|| "gen:bbd=2000".into());
    let a = load_matrix(&spec)?;
    let opts = options_from_flags(flags)?;
    let clients: usize = flags.get("clients").map(|s| s.parse()).transpose()?.unwrap_or(8);
    let requests: usize = flags.get("requests").map(|s| s.parse()).transpose()?.unwrap_or(40);
    let sessions: usize = flags
        .get("sessions")
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or_else(|| clients.clamp(1, 4));
    if clients == 0 || requests == 0 || sessions == 0 {
        bail!("--clients, --requests and --sessions must all be >= 1");
    }
    let mix = match flags.get("mix") {
        Some(s) => {
            let weights: Vec<u32> = s
                .split(',')
                .map(|p| p.trim().parse::<u32>())
                .collect::<Result<_, _>>()
                .context("--mix F,S,V (three integer weights)")?;
            if weights.len() != 3 {
                bail!("--mix needs exactly three weights: full,stamp,solve");
            }
            ScenarioMix { full: weights[0], stamp: weights[1], solve: weights[2] }
        }
        None => ScenarioMix::default(),
    };
    if mix.full + mix.stamp + mix.solve == 0 {
        bail!("--mix needs at least one positive weight");
    }
    let precision = match flags.get("precision").map(String::as_str) {
        None | Some("full") => Precision::Full,
        Some("mixed") => Precision::Mixed,
        Some(other) => bail!("unknown --precision {other:?} (expected full or mixed)"),
    };
    let out = flags.get("out").cloned().unwrap_or_else(|| "BENCH_serve.json".into());
    println!("matrix: {} n={} nnz={}", spec, a.n_rows(), a.nnz());

    // a bench-scoped registry (not Registry::global) so the scrape shows
    // exactly this run; served live while the load runs when requested
    let registry = Arc::new(obs::Registry::new());
    let metrics_server = match flags.get("metrics-addr") {
        Some(addr) => {
            let server = obs::MetricsServer::serve(addr, registry.clone())
                .with_context(|| format!("binding metrics endpoint on {addr}"))?;
            println!("metrics: http://{}/metrics", server.local_addr());
            Some(server)
        }
        None => None,
    };

    // plan acquisition — through the persistence layer when --plan-dir
    // is given, so repeat runs take the serving restart's warm path
    let plan = match flags.get("plan-dir") {
        Some(dir) => {
            let dir = std::path::Path::new(dir);
            std::fs::create_dir_all(dir)?;
            let mut cache = PlanCache::new(4);
            let warm = cache.warm_from_dir(dir).map_err(|e| anyhow::anyhow!("{e}"))?;
            for (path, err) in &warm.skipped {
                eprintln!("warning: skipped plan file {}: {err}", path.display());
            }
            let (plan, acquire_seconds) = timed(|| cache.get_or_build(&a, &opts));
            let plan = plan.map_err(|e| anyhow::anyhow!("{e}"))?;
            let how = if cache.misses() == 0 { "warm-loaded from disk" } else { "built cold" };
            println!(
                "plan {how} in {acquire_seconds:.4}s ({} file(s) warmed from {})",
                warm.loaded,
                dir.display()
            );
            persist::save_plan_to_dir(&plan, dir).map_err(|e| anyhow::anyhow!("{e}"))?;
            plan
        }
        None => {
            let (plan, build_seconds) = timed(|| FactorPlan::build(&a, &opts));
            let plan = Arc::new(plan.map_err(|e| anyhow::anyhow!("{e}"))?);
            println!(
                "plan built in {build_seconds:.4}s (pass --plan-dir DIR to persist/warm it)"
            );
            plan
        }
    };

    let cfg = loadgen::LoadgenConfig {
        clients,
        requests_per_client: requests,
        pool_sessions: sessions,
        mix,
        seed: 0x5E27E,
        precision,
    };
    println!(
        "load: {clients} clients x {requests} requests, pool cap {sessions}, \
         mix full:{} stamp:{} solve:{}, precision {}",
        mix.full,
        mix.stamp,
        mix.solve,
        if precision == Precision::Mixed { "mixed (f32 + refinement)" } else { "full (f64)" }
    );
    let report = loadgen::run(&a, plan, &cfg);

    // the multi-tenant scenario: the same client count spread over M
    // distinct sparsity patterns, routed through serve::Router
    let tenants: usize = flags.get("tenants").map(|s| s.parse()).transpose()?.unwrap_or(3);
    let multi = if tenants >= 2 {
        let tenant_mats = tenant_matrices(tenants);
        let mcfg = loadgen::MultiTenantConfig {
            clients,
            requests_per_client: requests,
            burst: 4,
            mix,
            seed: 0x3E2A17,
            router: RouterConfig {
                sessions_per_shard: 1,
                plan_dir: flags.get("plan-dir").map(std::path::PathBuf::from),
                registry: Some(registry.clone()),
                precision,
                ..RouterConfig::default()
            },
            autoscale: flags.contains_key("autoscale").then(obs::SloPolicy::default),
        };
        println!(
            "multi-tenant: {clients} clients over {tenants} patterns ({})",
            tenant_mats.iter().map(|(n, _)| n.as_str()).collect::<Vec<_>>().join(", ")
        );
        Some(loadgen::run_multi(&tenant_mats, &opts, &mcfg))
    } else {
        None
    };

    println!("\n--- serve bench ---");
    println!("requests         : {} in {:.3}s", report.total_requests, report.wall_seconds);
    println!("throughput       : {:.1} req/s", report.throughput_rps);
    println!(
        "sessions created : {} of {} allowed (lazy growth)",
        report.sessions_created, cfg.pool_sessions
    );
    println!(
        "tasks            : {} executed, {} skipped by reachability pruning",
        report.tasks_executed, report.tasks_skipped
    );
    println!(
        "latency          : p50 {:.5}s  p99 {:.5}s  max {:.5}s",
        report.overall.p50_s, report.overall.p99_s, report.overall.max_s
    );
    for (name, s) in &report.per_scenario {
        if s.count == 0 {
            continue;
        }
        println!(
            "  {name:6} x{:<5} p50 {:.5}s  p99 {:.5}s  max {:.5}s",
            s.count, s.p50_s, s.p99_s, s.max_s
        );
    }

    if let Some(multi) = &multi {
        println!("\n--- multi-tenant serve bench ---");
        println!(
            "requests         : {} in {:.3}s ({:.1} req/s across {} tenants)",
            multi.total_requests, multi.wall_seconds, multi.throughput_rps, multi.tenants
        );
        println!(
            "router           : {} spin-ups, {} evictions, {} revivals, \
             cache {}h/{}m",
            multi.router.spin_ups,
            multi.router.evictions,
            multi.router.revivals,
            multi.router.cache_hits,
            multi.router.cache_misses
        );
        for t in &multi.per_tenant {
            println!(
                "  {:18} x{:<5} {:.1} req/s  p50 {:.5}s  p99 {:.5}s  \
                 ({} rejections)",
                t.name, t.completed, t.throughput_rps, t.latency.p50_s, t.latency.p99_s,
                t.rejections
            );
        }
    }

    let json = match &multi {
        None => report.to_json(&spec, a.n_rows(), a.nnz()),
        Some(multi) => format!(
            "{{\n\"bench\": \"serve-combined\",\n\"single\": {},\n\"multi_tenant\": {}\n}}\n",
            report.to_json(&spec, a.n_rows(), a.nnz()).trim_end(),
            multi.to_json().trim_end()
        ),
    };
    std::fs::write(&out, json).with_context(|| format!("writing {out}"))?;
    println!("\nwrote {out}");

    if let Some(server) = &metrics_server {
        let text = obs::scrape(server.local_addr(), "/metrics")
            .context("self-scraping the metrics endpoint")?;
        let summary = obs::validate(&text)
            .map_err(|e| anyhow::anyhow!("metrics exposition invalid: {e}"))?;
        let metrics_out =
            flags.get("metrics-out").cloned().unwrap_or_else(|| "BENCH_metrics.txt".into());
        std::fs::write(&metrics_out, &text).with_context(|| format!("writing {metrics_out}"))?;
        println!(
            "metrics: {} families, {} series, {} samples (exposition valid) -> {metrics_out}",
            summary.families,
            summary.series.len(),
            summary.samples
        );
    }
    Ok(())
}

fn cmd_metrics_dump(flags: &HashMap<String, String>) -> Result<()> {
    if let Some(path) = flags.get("trace-summary") {
        return cmd_trace_summary(path);
    }
    let (text, source) = match (flags.get("addr"), flags.get("file")) {
        (Some(addr), None) => (
            obs::scrape(addr.as_str(), "/metrics").with_context(|| format!("scraping {addr}"))?,
            addr.clone(),
        ),
        (None, Some(path)) => (
            std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?,
            path.clone(),
        ),
        _ => bail!("metrics-dump needs exactly one of --addr HOST:PORT or --file PATH"),
    };
    let summary = obs::validate(&text)
        .map_err(|e| anyhow::anyhow!("{source}: exposition format error: {e}"))?;
    if flags.contains_key("check") {
        println!(
            "OK {source}: {} families, {} series, {} samples",
            summary.families,
            summary.series.len(),
            summary.samples
        );
    } else {
        print!("{text}");
    }
    Ok(())
}

/// `repro metrics-dump --trace-summary`: read a `BENCH_trace.json`
/// written by `repro trace-bench` and print the profiler's digest —
/// scheduling efficiency, top stragglers and per-level imbalance.
fn cmd_trace_summary(path: &str) -> Result<()> {
    let text = std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
    let doc = obs::trace::parse_json(&text)
        .map_err(|e| anyhow::anyhow!("{path}: invalid JSON: {e}"))?;
    let results = doc
        .get("results")
        .and_then(|r| r.as_arr())
        .context("no `results` array — is this a BENCH_trace.json?")?;
    fn num(v: &obs::trace::Json, k: &str) -> f64 {
        v.get(k).and_then(|x| x.as_f64()).unwrap_or(f64::NAN)
    }
    fn text_of<'a>(v: &'a obs::trace::Json, k: &str) -> &'a str {
        v.get(k).and_then(|x| x.as_str()).unwrap_or("?")
    }
    println!("--- trace summary: {path} ({} scenarios) ---", results.len());
    for r in results {
        println!(
            "\n{} {} w={} | {} tasks / {} levels | efficiency {:.2} (critical {:.3}ms / \
             makespan {:.3}ms) | across-level imbalance nnz {:.2}x time {:.2}x",
            text_of(r, "matrix"),
            text_of(r, "blocking"),
            num(r, "workers"),
            num(r, "tasks"),
            num(r, "levels"),
            num(r, "scheduling_efficiency"),
            num(r, "critical_path_seconds") * 1e3,
            num(r, "makespan_seconds") * 1e3,
            num(r, "nnz_imbalance_across"),
            num(r, "time_imbalance_across"),
        );
        if let Some(stragglers) = r.get("stragglers").and_then(|s| s.as_arr()) {
            println!("  top stragglers:");
            for s in stragglers.iter().take(5) {
                println!(
                    "    {}({},{}) level {} worker {} {:.3}ms",
                    text_of(s, "op"),
                    num(s, "bi"),
                    num(s, "bj"),
                    num(s, "level"),
                    num(s, "worker"),
                    num(s, "seconds") * 1e3,
                );
            }
        }
        if let Some(levels) = r.get("per_level").and_then(|l| l.as_arr()) {
            println!("  per-level balance:");
            for l in levels {
                println!(
                    "    level {:3}: {:4} blocks | nnz {:8} (imbalance {:.2}x) | {:.3}ms \
                     (imbalance {:.2}x)",
                    num(l, "level"),
                    num(l, "blocks"),
                    num(l, "nnz_total"),
                    num(l, "nnz_imbalance"),
                    num(l, "seconds_total") * 1e3,
                    num(l, "time_imbalance"),
                );
            }
        }
    }
    Ok(())
}

/// Deterministic family of distinct sparsity patterns for the
/// multi-tenant scenario: alternating circuit-BBD and 2D-grid tenants of
/// staggered sizes (every pattern fingerprint is distinct).
fn tenant_matrices(count: usize) -> Vec<(String, Csc)> {
    (0..count)
        .map(|i| {
            if i % 2 == 0 {
                let n = 500 + 123 * i;
                (
                    format!("bbd-{n}"),
                    gen::circuit_bbd(gen::CircuitParams { n, ..Default::default() }),
                )
            } else {
                let side = 20 + 2 * i;
                (format!("grid-{side}x{side}"), gen::grid2d_laplacian(side, side))
            }
        })
        .collect()
}

fn cmd_chaos_bench(flags: &HashMap<String, String>) -> Result<()> {
    let rounds: usize = flags.get("rounds").map(|s| s.parse()).transpose()?.unwrap_or(32);
    let solves: usize = flags.get("solves").map(|s| s.parse()).transpose()?.unwrap_or(7);
    let seed: u64 = flags.get("seed").map(|s| s.parse()).transpose()?.unwrap_or(0xC4A05);
    if rounds == 0 || solves == 0 {
        bail!("--rounds and --solves must be >= 1");
    }
    let out = flags.get("out").cloned().unwrap_or_else(|| "BENCH_chaos.json".into());
    let metrics_out =
        flags.get("metrics-out").cloned().unwrap_or_else(|| "BENCH_chaos_metrics.txt".into());
    println!(
        "chaos: 4 tenants x {rounds} rounds x (1 refactorize + {solves} solves), \
         sweep baseline / one-shot / storm-low / storm-high (seed {seed:#x})"
    );
    let report = bench_harness::chaos::run(rounds, solves, seed);
    report.print();
    std::fs::write(&out, report.to_json()).with_context(|| format!("writing {out}"))?;
    let summary = obs::validate(&report.metrics_text)
        .map_err(|e| anyhow::anyhow!("chaos metrics exposition invalid: {e}"))?;
    std::fs::write(&metrics_out, &report.metrics_text)
        .with_context(|| format!("writing {metrics_out}"))?;
    println!(
        "\nwrote {out} and {metrics_out} ({} families, {} series, exposition valid)",
        summary.families,
        summary.series.len()
    );
    Ok(())
}

fn cmd_kernel_bench(flags: &HashMap<String, String>) -> Result<()> {
    let reps: usize = flags.get("reps").map(|s| s.parse()).transpose()?.unwrap_or(200);
    if reps < 1 {
        bail!("--reps must be >= 1");
    }
    let out = flags.get("out").cloned().unwrap_or_else(|| "BENCH_kernels.json".into());
    println!(
        "kernel raw-speed pass: scalar oracle vs tiled fast path, best of {reps} reps \
         (bitwise identity asserted per row)"
    );
    let report = bench_harness::kernels::run(reps);
    report.print();
    std::fs::write(&out, report.to_json()).with_context(|| format!("writing {out}"))?;
    println!("\nwrote {out}");
    Ok(())
}

fn cmd_sched_bench(flags: &HashMap<String, String>) -> Result<()> {
    let replays: usize = flags.get("replays").map(|s| s.parse()).transpose()?.unwrap_or(40);
    if replays < 2 {
        bail!("--replays must be >= 2");
    }
    let worker_counts: Vec<u32> = match flags.get("worker-counts") {
        Some(s) => s
            .split(',')
            .map(|p| p.trim().parse::<u32>())
            .collect::<Result<_, _>>()
            .context("--worker-counts N,N,... (positive integers)")?,
        None => vec![1, 2, 4],
    };
    if worker_counts.is_empty() || worker_counts.contains(&0) {
        bail!("--worker-counts needs at least one positive worker count");
    }
    let out = flags.get("out").cloned().unwrap_or_else(|| "BENCH_sched.json".into());
    println!(
        "refactorize-storm: {replays} replays/storm over worker counts {worker_counts:?} \
         (spawn-per-call vs persistent executor)"
    );
    let report = bench_harness::sched::run(replays, &worker_counts);
    report.print();
    std::fs::write(&out, report.to_json()).with_context(|| format!("writing {out}"))?;
    println!("\nwrote {out}");
    Ok(())
}

fn cmd_plan_bench(flags: &HashMap<String, String>) -> Result<()> {
    let replays: usize = flags.get("replays").map(|s| s.parse()).transpose()?.unwrap_or(5);
    if replays < 1 {
        bail!("--replays must be >= 1");
    }
    let worker_counts: Vec<u32> = match flags.get("worker-counts") {
        Some(s) => s
            .split(',')
            .map(|p| p.trim().parse::<u32>())
            .collect::<Result<_, _>>()
            .context("--worker-counts N,N,... (positive integers)")?,
        None => vec![2, 8],
    };
    if worker_counts.is_empty() || worker_counts.contains(&0) {
        bail!("--worker-counts needs at least one positive worker count");
    }
    let out = flags.get("out").cloned().unwrap_or_else(|| "BENCH_plan.json".into());
    println!(
        "plan-construction: best of {replays} builds over worker counts {worker_counts:?} \
         (sequential vs persistent executor)"
    );
    let report = bench_harness::plan::run(replays, &worker_counts);
    report.print();
    std::fs::write(&out, report.to_json()).with_context(|| format!("writing {out}"))?;
    println!("\nwrote {out}");
    Ok(())
}

fn cmd_trace(flags: &HashMap<String, String>) -> Result<()> {
    let spec = flags.get("matrix").cloned().unwrap_or_else(|| "gen:grid2d=40x40".into());
    let a = load_matrix(&spec)?;
    let opts = options_from_flags(flags)?;
    let replays: usize = flags.get("replays").map(|s| s.parse()).transpose()?.unwrap_or(3);
    if replays < 1 {
        bail!("--replays must be >= 1");
    }
    let out = flags.get("out").cloned().unwrap_or_else(|| "trace.json".into());
    println!("matrix: {} n={} nnz={}", spec, a.n_rows(), a.nnz());

    obs::trace::set_enabled(true);
    let plan = Arc::new(FactorPlan::build(&a, &opts).map_err(|e| anyhow::anyhow!("{e}"))?);
    let mut session = SolverSession::from_plan(plan.clone());
    let tid = obs::trace::next_trace_id();
    session.set_trace_id(tid);
    for _ in 0..replays {
        session.refactorize(&a.values).map_err(|e| anyhow::anyhow!("{e}"))?;
    }
    obs::trace::set_enabled(false);

    let snap = obs::trace::snapshot();
    let events = snap.all_events();
    let run_id = events
        .iter()
        .filter(|e| e.kind == obs::trace::EventKind::Task && e.trace_id == tid)
        .map(|e| e.run_id)
        .max()
        .context("no task events recorded")?;
    if let Some(an) = obs::trace::analyze_run(&plan.dag, &events, run_id, 5) {
        println!(
            "last run: {} tasks, makespan {:.3}ms, critical path {:.3}ms, efficiency {:.2}",
            an.tasks,
            an.makespan_seconds * 1e3,
            an.critical_path_seconds * 1e3,
            an.scheduling_efficiency
        );
        for s in &an.stragglers {
            println!(
                "  straggler: {}({},{}) level {} worker {} {:.3}ms",
                s.op,
                s.target.0,
                s.target.1,
                s.level,
                s.worker,
                s.seconds * 1e3
            );
        }
    }
    std::fs::write(&out, obs::trace::chrome_trace_of(&snap))
        .with_context(|| format!("writing {out}"))?;
    println!(
        "wrote {out} ({} lanes, {} dropped events) — load it in Perfetto or chrome://tracing",
        snap.lanes.len(),
        snap.dropped_events
    );
    Ok(())
}

fn cmd_trace_bench(flags: &HashMap<String, String>) -> Result<()> {
    let replays: usize = flags.get("replays").map(|s| s.parse()).transpose()?.unwrap_or(5);
    if replays < 1 {
        bail!("--replays must be >= 1");
    }
    let worker_counts: Vec<u32> = match flags.get("worker-counts") {
        Some(s) => s
            .split(',')
            .map(|p| p.trim().parse::<u32>())
            .collect::<Result<_, _>>()
            .context("--worker-counts N,N,... (positive integers)")?,
        None => vec![1, 4],
    };
    if worker_counts.is_empty() || worker_counts.contains(&0) {
        bail!("--worker-counts needs at least one positive worker count");
    }
    let out = flags.get("out").cloned().unwrap_or_else(|| "BENCH_trace.json".into());
    let trace_out = flags
        .get("trace-out")
        .cloned()
        .unwrap_or_else(|| "BENCH_trace.sample.trace.json".into());
    println!(
        "traced-refactorize: {replays} replays/scenario over worker counts {worker_counts:?} \
         (irregular vs regular blocking)"
    );
    let report = bench_harness::trace::run(replays, &worker_counts);
    report.print();
    std::fs::write(&out, report.to_json()).with_context(|| format!("writing {out}"))?;
    std::fs::write(&trace_out, &report.sample_trace)
        .with_context(|| format!("writing {trace_out}"))?;
    println!("\nwrote {out} and {trace_out}");
    Ok(())
}

fn cmd_artifacts_check(flags: &HashMap<String, String>) -> Result<()> {
    let dir = flags.get("dir").cloned().unwrap_or_else(|| "artifacts".into());
    let pjrt = PjrtDense::load(&dir)?;
    println!("loaded {} artifacts from {dir}", pjrt.num_artifacts());
    println!("tile sizes: up to {}", pjrt.max_tile());
    // smoke execution
    use sparselu::numeric::factor::DenseBackend;
    let n = 8;
    let mut a = vec![0.0; n * n];
    for i in 0..n {
        a[i * n + i] = 4.0;
        if i + 1 < n {
            a[i * n + i + 1] = -1.0;
            a[(i + 1) * n + i] = -1.0;
        }
    }
    pjrt.getrf(&mut a, n).map_err(|e| anyhow::anyhow!("{e}"))?;
    println!("smoke GETRF on 8x8 tridiagonal: OK (pivot[0] = {})", a[0]);
    println!("executions dispatched: {}", pjrt.executions());
    Ok(())
}
