//! Sparse block kernels: GETRF / GESSM / TSTRF / SSSSM on the fixed fill
//! pattern.
//!
//! All four kernels use the classic *scatter–compute–gather* scheme: a
//! block column is scattered into a dense workspace vector, updated with
//! sparse AXPYs, and gathered back into the (pre-computed, fill-complete)
//! pattern. Correctness relies on the symbolic closure property: any value
//! produced by `L[·,k]·U[k,·]` products lands on a position the symbolic
//! phase already allocated — asserted in debug builds.

use crate::blocking::partition::Block;

/// Reusable scratch space for the sparse kernels (one per worker thread).
#[derive(Debug, Default)]
pub struct Workspace {
    /// Dense accumulator, sized to the largest block dimension.
    w: Vec<f64>,
    /// Dirty indices of `w` — debug builds only, used to assert the
    /// symbolic-closure property in SSSSM.
    #[cfg_attr(not(debug_assertions), allow(dead_code))]
    touched: Vec<u32>,
}

impl Workspace {
    pub fn with_capacity(max_dim: usize) -> Self {
        Self { w: vec![0.0; max_dim], touched: Vec::with_capacity(max_dim) }
    }

    #[inline]
    fn ensure(&mut self, dim: usize) {
        if self.w.len() < dim {
            self.w.resize(dim, 0.0);
        }
    }
}

/// Numerical failure modes of the no-pivot factorization.
#[derive(Clone, Debug, PartialEq)]
pub enum KernelError {
    /// A pivot underflowed the stability floor.
    ZeroPivot { block: (u32, u32), local_col: usize, value: f64 },
}

impl std::fmt::Display for KernelError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            KernelError::ZeroPivot { block, local_col, value } => write!(
                f,
                "zero/tiny pivot {value:.3e} at local column {local_col} of diagonal block {block:?}"
            ),
        }
    }
}

impl std::error::Error for KernelError {}

/// Pivot magnitude below which the factorization aborts (the paper's
/// setting delegates stability to reordering / diagonal dominance).
pub const PIVOT_FLOOR: f64 = 1e-300;

/// GETRF: factor the diagonal block in place, `vals ← {L\U}` (left-looking
/// within the block; L gets a unit diagonal stored implicitly).
pub fn getrf(pat: &Block, vals: &mut [f64], ws: &mut Workspace) -> Result<(), KernelError> {
    debug_assert_eq!(pat.bi, pat.bj, "GETRF runs on diagonal blocks");
    let n = pat.n_cols as usize;
    ws.ensure(pat.n_rows as usize);
    let w = &mut ws.w;
    for c in 0..n {
        let (start, end) = (pat.col_ptr[c] as usize, pat.col_ptr[c + 1] as usize);
        let rows = &pat.row_idx[start..end];
        // scatter column c
        for (k, &r) in rows.iter().enumerate() {
            w[r as usize] = vals[start + k];
        }
        // eliminate with every factored column k < c present in the pattern
        let diag_pos = start + pat.diag_pos[c] as usize;
        for &r in rows {
            let k = r as usize;
            if k >= c {
                break; // rows sorted: U-part first
            }
            let alpha = w[k];
            if alpha == 0.0 {
                continue;
            }
            // w -= alpha * L[:,k]  (strictly-below-diagonal part of col k)
            let (ks, ke) = (pat.col_ptr[k] as usize, pat.col_ptr[k + 1] as usize);
            let lo = ks + pat.diag_pos[k] as usize + 1;
            for (&s, &lv) in pat.row_idx[lo..ke].iter().zip(&vals[lo..ke]) {
                w[s as usize] -= alpha * lv;
            }
        }
        // pivot + scale
        let pivot = w[c];
        if pivot.abs() < PIVOT_FLOOR {
            return Err(KernelError::ZeroPivot {
                block: (pat.bi, pat.bj),
                local_col: c,
                value: pivot,
            });
        }
        let diag_idx_in_rows = diag_pos - start;
        for (k, &r) in rows.iter().enumerate() {
            let ri = r as usize;
            if k <= diag_idx_in_rows {
                vals[start + k] = w[ri]; // U part + pivot
            } else {
                vals[start + k] = w[ri] / pivot; // L part, scaled
            }
            w[ri] = 0.0;
        }
    }
    Ok(())
}

/// GESSM: U-panel update `B ← L_kk⁻¹ B` where `diag` holds the factored
/// `{L\U}_kk` and `pat/vals` is block `(k, j)`, `j > k`.
pub fn gessm(
    pat: &Block,
    vals: &mut [f64],
    diag_pat: &Block,
    diag_vals: &[f64],
    ws: &mut Workspace,
) {
    debug_assert_eq!(pat.n_rows, diag_pat.n_cols);
    ws.ensure(pat.n_rows as usize);
    let w = &mut ws.w;
    for c in 0..pat.n_cols as usize {
        let (start, end) = (pat.col_ptr[c] as usize, pat.col_ptr[c + 1] as usize);
        let rows = &pat.row_idx[start..end];
        if rows.is_empty() {
            continue;
        }
        for (k, &r) in rows.iter().enumerate() {
            w[r as usize] = vals[start + k];
        }
        // forward substitution with unit-lower L_kk, sparse driver:
        // pattern rows of this column are exactly the reachable set.
        for &r in rows {
            let k = r as usize;
            let alpha = w[k];
            if alpha == 0.0 {
                continue;
            }
            let (ks, ke) = (diag_pat.col_ptr[k] as usize, diag_pat.col_ptr[k + 1] as usize);
            let lo = ks + diag_pat.diag_pos[k] as usize + 1;
            for (&s, &lv) in diag_pat.row_idx[lo..ke].iter().zip(&diag_vals[lo..ke]) {
                w[s as usize] -= alpha * lv;
            }
        }
        for (k, &r) in rows.iter().enumerate() {
            let ri = r as usize;
            vals[start + k] = w[ri];
            w[ri] = 0.0;
        }
    }
}

/// TSTRF: L-panel update `B ← B U_kk⁻¹` where `diag` holds `{L\U}_kk` and
/// `pat/vals` is block `(i, k)`, `i > k`. Column-oriented: columns of the
/// result depend on previously-computed columns.
pub fn tstrf(
    pat: &Block,
    vals: &mut [f64],
    diag_pat: &Block,
    diag_vals: &[f64],
    ws: &mut Workspace,
) {
    debug_assert_eq!(pat.n_cols, diag_pat.n_rows);
    ws.ensure(pat.n_rows as usize);
    let w = &mut ws.w;
    for c in 0..pat.n_cols as usize {
        let (start, end) = (pat.col_ptr[c] as usize, pat.col_ptr[c + 1] as usize);
        let rows = &pat.row_idx[start..end];
        if rows.is_empty() {
            continue;
        }
        for (k, &r) in rows.iter().enumerate() {
            w[r as usize] = vals[start + k];
        }
        // w -= X[:,k] * U[k,c] for U entries k < c of diag col c
        let ds = diag_pat.col_ptr[c] as usize;
        let dpos = diag_pat.diag_pos[c] as usize;
        for t in ds..(ds + dpos) {
            let k = diag_pat.row_idx[t] as usize;
            let ukc = diag_vals[t];
            if ukc == 0.0 {
                continue;
            }
            let (xs, xe) = (pat.col_ptr[k] as usize, pat.col_ptr[k + 1] as usize);
            for (&s, &xv) in pat.row_idx[xs..xe].iter().zip(&vals[xs..xe]) {
                w[s as usize] -= xv * ukc;
            }
        }
        let pivot = diag_vals[ds + dpos];
        let inv = 1.0 / pivot;
        for (k, &r) in rows.iter().enumerate() {
            let ri = r as usize;
            vals[start + k] = w[ri] * inv;
            w[ri] = 0.0;
        }
    }
}

/// SSSSM: Schur-complement update `C ← C − A·B` where `A` is block `(i,k)`
/// (L panel), `B` is block `(k,j)` (U panel), `C` is block `(i,j)`.
///
/// The flop hot-spot of the whole factorization (Alg. 1 line 10).
pub fn ssssm(
    c_pat: &Block,
    c_vals: &mut [f64],
    a_pat: &Block,
    a_vals: &[f64],
    b_pat: &Block,
    b_vals: &[f64],
    ws: &mut Workspace,
) {
    debug_assert_eq!(a_pat.n_cols, b_pat.n_rows);
    debug_assert_eq!(c_pat.n_rows, a_pat.n_rows);
    debug_assert_eq!(c_pat.n_cols, b_pat.n_cols);
    ws.ensure(c_pat.n_rows as usize);
    let w = &mut ws.w;
    for c in 0..b_pat.n_cols as usize {
        let (bs, be) = (b_pat.col_ptr[c] as usize, b_pat.col_ptr[c + 1] as usize);
        if bs == be {
            continue;
        }
        // track touched rows only in debug builds — in release, the
        // symbolic-closure property guarantees every accumulated position
        // lies inside C's pattern, so the gather loop below fully resets
        // `w` and the branch + push per FMA can be elided from the hot
        // loop (EXPERIMENTS.md §Perf L3 opt-1).
        #[cfg(debug_assertions)]
        let touched = {
            ws.touched.clear();
            &mut ws.touched
        };
        let mut any = false;
        // w += A[:, r] * B[r, c] accumulated over B's column entries
        for t in bs..be {
            let r = b_pat.row_idx[t] as usize;
            let bv = b_vals[t];
            if bv == 0.0 {
                continue;
            }
            let (as_, ae) = (a_pat.col_ptr[r] as usize, a_pat.col_ptr[r + 1] as usize);
            any |= as_ != ae;
            // zipped slices: one bounds check per slice, not per element
            for (&s, &av) in a_pat.row_idx[as_..ae].iter().zip(&a_vals[as_..ae]) {
                let si = s as usize;
                #[cfg(debug_assertions)]
                if w[si] == 0.0 {
                    touched.push(s);
                }
                w[si] += av * bv;
            }
        }
        if !any {
            continue;
        }
        // gather: subtract at C's pattern positions (resetting w)
        let (cs, ce) = (c_pat.col_ptr[c] as usize, c_pat.col_ptr[c + 1] as usize);
        for t in cs..ce {
            let ri = c_pat.row_idx[t] as usize;
            let acc = w[ri];
            if acc != 0.0 {
                c_vals[t] -= acc;
                w[ri] = 0.0;
            }
        }
        // symbolic-closure guard: every accumulated position must have
        // been inside C's pattern (w already reset there).
        #[cfg(debug_assertions)]
        for &s in ws.touched.iter() {
            debug_assert!(
                w[s as usize] == 0.0,
                "SSSSM produced value outside symbolic pattern at local row {s}"
            );
        }
    }
}

/// Flop cost of each kernel given the participating block patterns —
/// consumed by the GPU cost model and the bench harness.
pub mod cost {
    use crate::blocking::partition::Block;

    /// GETRF flops on the sparse pattern: for each column c, each U-entry
    /// k<c triggers an AXPY of length |L(:,k)|.
    pub fn getrf(pat: &Block) -> f64 {
        let n = pat.n_cols as usize;
        // approximation: Σ_c Σ_{k<c in pat(c)} |L(:,k)| ≈ use column sizes
        let mut below = vec![0usize; n];
        for c in 0..n {
            let rows = pat.col_rows(c);
            let d = rows.partition_point(|&r| (r as usize) < c);
            below[c] = rows.len() - d - 1; // strictly below diagonal
        }
        let mut fl = 0.0;
        for c in 0..n {
            let rows = pat.col_rows(c);
            for &r in rows {
                let k = r as usize;
                if k >= c {
                    break;
                }
                fl += 2.0 * below[k] as f64;
            }
            fl += below[c] as f64; // the division
        }
        fl
    }

    /// GESSM flops: per target column, Σ over its entries k of |L_kk(:,k)|.
    pub fn gessm(pat: &Block, diag: &Block) -> f64 {
        let mut below = vec![0usize; diag.n_cols as usize];
        for c in 0..diag.n_cols as usize {
            let rows = diag.col_rows(c);
            let d = rows.partition_point(|&r| (r as usize) <= c);
            below[c] = rows.len() - d;
        }
        let mut fl = 0.0;
        for c in 0..pat.n_cols as usize {
            for &r in pat.col_rows(c) {
                fl += 2.0 * below[r as usize] as f64;
            }
        }
        fl
    }

    /// TSTRF flops: per column c, Σ over U entries k<c of |X(:,k)| + division.
    pub fn tstrf(pat: &Block, diag: &Block) -> f64 {
        let mut xcol = vec![0usize; pat.n_cols as usize];
        for c in 0..pat.n_cols as usize {
            xcol[c] = pat.col_rows(c).len();
        }
        let mut fl = 0.0;
        for c in 0..pat.n_cols as usize {
            for &dr in diag.col_rows(c) {
                let k = dr as usize;
                if k >= c {
                    break;
                }
                fl += 2.0 * xcol[k] as f64;
            }
            fl += xcol[c] as f64;
        }
        fl
    }

    /// SSSSM flops: Σ over B entries (r,c) of 2·|A(:,r)|.
    pub fn ssssm(a: &Block, b: &Block) -> f64 {
        let mut acol = vec![0usize; a.n_cols as usize];
        for c in 0..a.n_cols as usize {
            acol[c] = a.col_rows(c).len();
        }
        let mut fl = 0.0;
        for c in 0..b.n_cols as usize {
            for &r in b.col_rows(c) {
                fl += 2.0 * acol[r as usize] as f64;
            }
        }
        fl
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blocking::{regular_blocking, BlockedMatrix};
    use crate::numeric::dense;
    use crate::sparse::gen;
    use crate::symbolic;

    /// Factor a small matrix with one giant block and compare {L\U}
    /// against the dense no-pivot LU.
    #[test]
    fn getrf_matches_dense_lu_single_block() {
        let a = gen::uniform_random(24, 0.2, 42);
        let sym = symbolic::analyze(&a);
        let ldu = sym.ldu_pattern(&a).unwrap();
        let bm = BlockedMatrix::build(&ldu, regular_blocking(24, 24));
        let id = bm.block_id(0, 0).unwrap();
        let pat = bm.block(id);
        let mut vals = pat.values.clone();
        let mut ws = Workspace::with_capacity(24);
        getrf(pat, &mut vals, &mut ws).unwrap();

        // dense reference
        let mut d = vec![0.0; 24 * 24];
        for j in 0..24 {
            for (i, v) in a.col(j) {
                d[j * 24 + i] = v;
            }
        }
        dense::getrf_in_place(&mut d, 24).unwrap();
        for c in 0..24usize {
            for (k, &r) in pat.col_rows(c).iter().enumerate() {
                let got = vals[pat.col_ptr[c] as usize + k];
                let want = d[c * 24 + r as usize];
                assert!(
                    (got - want).abs() < 1e-9 * want.abs().max(1.0),
                    "mismatch at ({r},{c}): {got} vs {want}"
                );
            }
        }
    }

    #[test]
    fn getrf_detects_zero_pivot() {
        // 2x2 with exact cancellation: [[1,1],[1,1]] -> pivot 0 at col 1
        let mut coo = crate::sparse::Coo::new(2, 2);
        for i in 0..2 {
            for j in 0..2 {
                coo.push(i, j, 1.0);
            }
        }
        let a = coo.to_csc();
        let sym = symbolic::analyze(&a);
        let ldu = sym.ldu_pattern(&a).unwrap();
        let bm = BlockedMatrix::build(&ldu, regular_blocking(2, 2));
        let id = bm.block_id(0, 0).unwrap();
        let pat = bm.block(id);
        let mut vals = pat.values.clone();
        let mut ws = Workspace::default();
        let err = getrf(pat, &mut vals, &mut ws);
        assert!(matches!(err, Err(KernelError::ZeroPivot { local_col: 1, .. })));
    }

    /// Full blocked factorization on a 2x2 block grid, every kernel
    /// exercised, verified against dense LU of the whole matrix.
    fn blocked_vs_dense(a: &crate::sparse::Csc, bs: usize) {
        let n = a.n_cols();
        let sym = symbolic::analyze(a);
        let ldu = sym.ldu_pattern(a).unwrap();
        let bm = BlockedMatrix::build(&ldu, regular_blocking(n, bs));
        let nb = bm.nb();
        let mut vals: Vec<Vec<f64>> = bm.blocks.iter().map(|b| b.values.clone()).collect();
        let mut ws = Workspace::with_capacity(n);
        for k in 0..nb {
            let diag_id = bm.block_id(k, k).expect("diagonal block must exist") as usize;
            {
                let pat = &bm.blocks[diag_id];
                let mut v = std::mem::take(&mut vals[diag_id]);
                getrf(pat, &mut v, &mut ws).unwrap();
                vals[diag_id] = v;
            }
            let diag_pat = &bm.blocks[diag_id];
            let diag_vals = vals[diag_id].clone();
            // panels
            for &id in &bm.by_col[k] {
                let b = bm.block(id);
                if (b.bi as usize) > k {
                    let mut v = std::mem::take(&mut vals[id as usize]);
                    tstrf(b, &mut v, diag_pat, &diag_vals, &mut ws);
                    vals[id as usize] = v;
                }
            }
            for &id in &bm.by_row[k] {
                let b = bm.block(id);
                if (b.bj as usize) > k {
                    let mut v = std::mem::take(&mut vals[id as usize]);
                    gessm(b, &mut v, diag_pat, &diag_vals, &mut ws);
                    vals[id as usize] = v;
                }
            }
            // updates
            let lids: Vec<u32> = bm.by_col[k]
                .iter()
                .copied()
                .filter(|&id| (bm.block(id).bi as usize) > k)
                .collect();
            let uids: Vec<u32> = bm.by_row[k]
                .iter()
                .copied()
                .filter(|&id| (bm.block(id).bj as usize) > k)
                .collect();
            for &lid in &lids {
                for &uid in &uids {
                    let (bi, bj) = (bm.block(lid).bi as usize, bm.block(uid).bj as usize);
                    if let Some(cid) = bm.block_id(bi, bj) {
                        let mut v = std::mem::take(&mut vals[cid as usize]);
                        ssssm(
                            bm.block(cid),
                            &mut v,
                            bm.block(lid),
                            &vals[lid as usize],
                            bm.block(uid),
                            &vals[uid as usize],
                            &mut ws,
                        );
                        vals[cid as usize] = v;
                    }
                }
            }
        }
        // dense reference on the whole matrix
        let mut d = vec![0.0; n * n];
        for j in 0..n {
            for (i, v) in a.col(j) {
                d[j * n + i] = v;
            }
        }
        dense::getrf_in_place(&mut d, n).unwrap();
        let positions = bm.blocking.positions();
        for (idx, b) in bm.blocks.iter().enumerate() {
            let (rlo, clo) = (positions[b.bi as usize], positions[b.bj as usize]);
            for c in 0..b.n_cols as usize {
                for (t, &r) in b.col_rows(c).iter().enumerate() {
                    let got = vals[idx][b.col_ptr[c] as usize + t];
                    let want = d[(clo + c) * n + rlo + r as usize];
                    assert!(
                        (got - want).abs() < 1e-8 * want.abs().max(1.0),
                        "block ({},{}) local ({r},{c}): {got} vs {want}",
                        b.bi,
                        b.bj
                    );
                }
            }
        }
    }

    #[test]
    fn blocked_factorization_matches_dense_on_grid() {
        blocked_vs_dense(&gen::grid2d_laplacian(6, 5), 8);
    }

    #[test]
    fn blocked_factorization_matches_dense_on_unsymmetric() {
        blocked_vs_dense(&gen::directed_graph(40, 3, 11), 11);
    }

    #[test]
    fn blocked_factorization_matches_dense_on_bbd() {
        let a = gen::circuit_bbd(gen::CircuitParams {
            n: 60,
            border_frac: 0.15,
            border_density: 0.5,
            interior_deg: 2,
            seed: 5,
        });
        blocked_vs_dense(&a, 13);
    }

    #[test]
    fn blocked_factorization_matches_dense_on_arrow() {
        blocked_vs_dense(&gen::arrow_up(30), 7);
        blocked_vs_dense(&gen::arrow_down(30), 7);
    }

    #[test]
    fn cost_model_positive_and_scales() {
        let a = gen::grid2d_laplacian(8, 8);
        let sym = symbolic::analyze(&a);
        let ldu = sym.ldu_pattern(&a).unwrap();
        let bm = BlockedMatrix::build(&ldu, regular_blocking(64, 16));
        let id = bm.block_id(0, 0).unwrap();
        let c1 = cost::getrf(bm.block(id));
        assert!(c1 > 0.0);
        if let (Some(l), Some(u)) = (bm.block_id(1, 0), bm.block_id(0, 1)) {
            let fl = cost::ssssm(bm.block(l), bm.block(u));
            assert!(fl > 0.0);
            let fl_t = cost::tstrf(bm.block(l), bm.block(id));
            let fl_g = cost::gessm(bm.block(u), bm.block(id));
            assert!(fl_t > 0.0 && fl_g > 0.0);
        }
    }
}
