//! Sparse block kernels: GETRF / GESSM / TSTRF / SSSSM on the fixed fill
//! pattern.
//!
//! All four kernels use the classic *scatter–compute–gather* scheme: a
//! block column is scattered into a dense workspace vector, updated with
//! sparse AXPYs, and gathered back into the (pre-computed, fill-complete)
//! pattern. Correctness relies on the symbolic closure property: any value
//! produced by `L[·,k]·U[k,·]` products lands on a position the symbolic
//! phase already allocated — asserted in debug builds.
//!
//! The kernels are generic over [`Real`] (`f64`/`f32`); both the
//! [`crate::numeric::KernelImpl::Scalar`] and
//! [`crate::numeric::KernelImpl::Tiled`] dense paths share these sparse
//! implementations unchanged, so sparse block ops are trivially
//! bit-identical across implementations.

use super::real::Real;
use crate::blocking::partition::Block;

/// Reusable scratch space for the sparse kernels (one per worker thread).
#[derive(Debug, Default)]
pub struct Workspace {
    /// Dense f64 accumulator, sized to the largest block dimension.
    w: Vec<f64>,
    /// Dense f32 accumulator for mixed-precision runs (allocated lazily —
    /// full-precision sessions never touch it).
    w32: Vec<f32>,
    /// Dirty indices of the active accumulator — debug builds only, used
    /// to assert the symbolic-closure property in SSSSM.
    #[cfg_attr(not(debug_assertions), allow(dead_code))]
    touched: Vec<u32>,
}

impl Workspace {
    pub fn with_capacity(max_dim: usize) -> Self {
        Self {
            w: vec![0.0; max_dim],
            w32: Vec::new(),
            touched: Vec::with_capacity(max_dim),
        }
    }
}

/// Selects the per-type accumulator inside a [`Workspace`] — glue so the
/// generic kernels stay free of `match`es on the scalar type. Sealed by
/// construction: only `f64` and `f32` implement it (there is no third
/// accumulator in [`Workspace`]).
pub trait WsBuf: Real {
    #[doc(hidden)]
    fn buf(ws: &mut Workspace) -> (&mut Vec<Self>, &mut Vec<u32>);
}

impl WsBuf for f64 {
    #[inline]
    fn buf(ws: &mut Workspace) -> (&mut Vec<Self>, &mut Vec<u32>) {
        (&mut ws.w, &mut ws.touched)
    }
}

impl WsBuf for f32 {
    #[inline]
    fn buf(ws: &mut Workspace) -> (&mut Vec<Self>, &mut Vec<u32>) {
        (&mut ws.w32, &mut ws.touched)
    }
}

#[inline]
fn scratch<T: WsBuf>(ws: &mut Workspace, dim: usize) -> (&mut Vec<T>, &mut Vec<u32>) {
    let (w, touched) = T::buf(ws);
    if w.len() < dim {
        w.resize(dim, T::ZERO);
    }
    (w, touched)
}

/// Numerical failure modes of the no-pivot factorization.
#[derive(Clone, Debug, PartialEq)]
pub enum KernelError {
    /// A pivot underflowed the stability floor.
    ZeroPivot { block: (u32, u32), local_col: usize, value: f64 },
}

impl std::fmt::Display for KernelError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            KernelError::ZeroPivot { block, local_col, value } => write!(
                f,
                "zero/tiny pivot {value:.3e} at local column {local_col} of diagonal block {block:?}"
            ),
        }
    }
}

impl std::error::Error for KernelError {}

/// Pivot magnitude below which the f64 factorization aborts (the paper's
/// setting delegates stability to reordering / diagonal dominance). The
/// f32 instantiation uses [`Real::PIVOT_FLOOR`] = `1e-30`.
pub const PIVOT_FLOOR: f64 = <f64 as Real>::PIVOT_FLOOR;

/// GETRF: factor the diagonal block in place, `vals ← {L\U}` (left-looking
/// within the block; L gets a unit diagonal stored implicitly).
pub fn getrf<T: Real>(pat: &Block, vals: &mut [T], ws: &mut Workspace) -> Result<(), KernelError>
where
    T: WsBuf,
{
    debug_assert_eq!(pat.bi, pat.bj, "GETRF runs on diagonal blocks");
    let n = pat.n_cols as usize;
    let (w, _) = scratch::<T>(ws, pat.n_rows as usize);
    for c in 0..n {
        let (start, end) = (pat.col_ptr[c] as usize, pat.col_ptr[c + 1] as usize);
        let rows = &pat.row_idx[start..end];
        // scatter column c
        for (k, &r) in rows.iter().enumerate() {
            w[r as usize] = vals[start + k];
        }
        // eliminate with every factored column k < c present in the pattern
        let diag_pos = start + pat.diag_pos[c] as usize;
        for &r in rows {
            let k = r as usize;
            if k >= c {
                break; // rows sorted: U-part first
            }
            let alpha = w[k];
            if alpha == T::ZERO {
                continue;
            }
            // w -= alpha * L[:,k]  (strictly-below-diagonal part of col k)
            let (ks, ke) = (pat.col_ptr[k] as usize, pat.col_ptr[k + 1] as usize);
            let lo = ks + pat.diag_pos[k] as usize + 1;
            for (&s, &lv) in pat.row_idx[lo..ke].iter().zip(&vals[lo..ke]) {
                w[s as usize] -= alpha * lv;
            }
        }
        // pivot + scale
        let pivot = w[c];
        if pivot.abs() < T::PIVOT_FLOOR {
            return Err(KernelError::ZeroPivot {
                block: (pat.bi, pat.bj),
                local_col: c,
                value: pivot.to_f64(),
            });
        }
        let diag_idx_in_rows = diag_pos - start;
        for (k, &r) in rows.iter().enumerate() {
            let ri = r as usize;
            if k <= diag_idx_in_rows {
                vals[start + k] = w[ri]; // U part + pivot
            } else {
                vals[start + k] = w[ri] / pivot; // L part, scaled
            }
            w[ri] = T::ZERO;
        }
    }
    Ok(())
}

/// GESSM: U-panel update `B ← L_kk⁻¹ B` where `diag` holds the factored
/// `{L\U}_kk` and `pat/vals` is block `(k, j)`, `j > k`.
pub fn gessm<T: Real>(
    pat: &Block,
    vals: &mut [T],
    diag_pat: &Block,
    diag_vals: &[T],
    ws: &mut Workspace,
) where
    T: WsBuf,
{
    debug_assert_eq!(pat.n_rows, diag_pat.n_cols);
    let (w, _) = scratch::<T>(ws, pat.n_rows as usize);
    for c in 0..pat.n_cols as usize {
        let (start, end) = (pat.col_ptr[c] as usize, pat.col_ptr[c + 1] as usize);
        let rows = &pat.row_idx[start..end];
        if rows.is_empty() {
            continue;
        }
        for (k, &r) in rows.iter().enumerate() {
            w[r as usize] = vals[start + k];
        }
        // forward substitution with unit-lower L_kk, sparse driver:
        // pattern rows of this column are exactly the reachable set.
        for &r in rows {
            let k = r as usize;
            let alpha = w[k];
            if alpha == T::ZERO {
                continue;
            }
            let (ks, ke) = (diag_pat.col_ptr[k] as usize, diag_pat.col_ptr[k + 1] as usize);
            let lo = ks + diag_pat.diag_pos[k] as usize + 1;
            for (&s, &lv) in diag_pat.row_idx[lo..ke].iter().zip(&diag_vals[lo..ke]) {
                w[s as usize] -= alpha * lv;
            }
        }
        for (k, &r) in rows.iter().enumerate() {
            let ri = r as usize;
            vals[start + k] = w[ri];
            w[ri] = T::ZERO;
        }
    }
}

/// TSTRF: L-panel update `B ← B U_kk⁻¹` where `diag` holds `{L\U}_kk` and
/// `pat/vals` is block `(i, k)`, `i > k`. Column-oriented: columns of the
/// result depend on previously-computed columns.
pub fn tstrf<T: Real>(
    pat: &Block,
    vals: &mut [T],
    diag_pat: &Block,
    diag_vals: &[T],
    ws: &mut Workspace,
) where
    T: WsBuf,
{
    debug_assert_eq!(pat.n_cols, diag_pat.n_rows);
    let (w, _) = scratch::<T>(ws, pat.n_rows as usize);
    for c in 0..pat.n_cols as usize {
        let (start, end) = (pat.col_ptr[c] as usize, pat.col_ptr[c + 1] as usize);
        let rows = &pat.row_idx[start..end];
        if rows.is_empty() {
            continue;
        }
        for (k, &r) in rows.iter().enumerate() {
            w[r as usize] = vals[start + k];
        }
        // w -= X[:,k] * U[k,c] for U entries k < c of diag col c
        let ds = diag_pat.col_ptr[c] as usize;
        let dpos = diag_pat.diag_pos[c] as usize;
        for t in ds..(ds + dpos) {
            let k = diag_pat.row_idx[t] as usize;
            let ukc = diag_vals[t];
            if ukc == T::ZERO {
                continue;
            }
            let (xs, xe) = (pat.col_ptr[k] as usize, pat.col_ptr[k + 1] as usize);
            for (&s, &xv) in pat.row_idx[xs..xe].iter().zip(&vals[xs..xe]) {
                w[s as usize] -= xv * ukc;
            }
        }
        let pivot = diag_vals[ds + dpos];
        let inv = T::ONE / pivot;
        for (k, &r) in rows.iter().enumerate() {
            let ri = r as usize;
            vals[start + k] = w[ri] * inv;
            w[ri] = T::ZERO;
        }
    }
}

/// SSSSM: Schur-complement update `C ← C − A·B` where `A` is block `(i,k)`
/// (L panel), `B` is block `(k,j)` (U panel), `C` is block `(i,j)`.
///
/// The flop hot-spot of the whole factorization (Alg. 1 line 10).
pub fn ssssm<T: Real>(
    c_pat: &Block,
    c_vals: &mut [T],
    a_pat: &Block,
    a_vals: &[T],
    b_pat: &Block,
    b_vals: &[T],
    ws: &mut Workspace,
) where
    T: WsBuf,
{
    debug_assert_eq!(a_pat.n_cols, b_pat.n_rows);
    debug_assert_eq!(c_pat.n_rows, a_pat.n_rows);
    debug_assert_eq!(c_pat.n_cols, b_pat.n_cols);
    let (w, ws_touched) = scratch::<T>(ws, c_pat.n_rows as usize);
    #[cfg(not(debug_assertions))]
    let _ = ws_touched;
    for c in 0..b_pat.n_cols as usize {
        let (bs, be) = (b_pat.col_ptr[c] as usize, b_pat.col_ptr[c + 1] as usize);
        if bs == be {
            continue;
        }
        // track touched rows only in debug builds — in release, the
        // symbolic-closure property guarantees every accumulated position
        // lies inside C's pattern, so the gather loop below fully resets
        // `w` and the branch + push per FMA can be elided from the hot
        // loop (EXPERIMENTS.md §Perf L3 opt-1).
        #[cfg(debug_assertions)]
        let touched = {
            ws_touched.clear();
            &mut *ws_touched
        };
        let mut any = false;
        // w += A[:, r] * B[r, c] accumulated over B's column entries
        for t in bs..be {
            let r = b_pat.row_idx[t] as usize;
            let bv = b_vals[t];
            if bv == T::ZERO {
                continue;
            }
            let (as_, ae) = (a_pat.col_ptr[r] as usize, a_pat.col_ptr[r + 1] as usize);
            any |= as_ != ae;
            // zipped slices: one bounds check per slice, not per element
            for (&s, &av) in a_pat.row_idx[as_..ae].iter().zip(&a_vals[as_..ae]) {
                let si = s as usize;
                #[cfg(debug_assertions)]
                if w[si] == T::ZERO {
                    touched.push(s);
                }
                w[si] += av * bv;
            }
        }
        if !any {
            continue;
        }
        // gather: subtract at C's pattern positions (resetting w)
        let (cs, ce) = (c_pat.col_ptr[c] as usize, c_pat.col_ptr[c + 1] as usize);
        for t in cs..ce {
            let ri = c_pat.row_idx[t] as usize;
            let acc = w[ri];
            if acc != T::ZERO {
                c_vals[t] -= acc;
                w[ri] = T::ZERO;
            }
        }
        // symbolic-closure guard: every accumulated position must have
        // been inside C's pattern (w already reset there).
        #[cfg(debug_assertions)]
        for &s in touched.iter() {
            debug_assert!(
                w[s as usize] == T::ZERO,
                "SSSSM produced value outside symbolic pattern at local row {s}"
            );
        }
    }
}

/// Flop cost of each kernel given the participating block patterns —
/// consumed by the DAG cost model ([`crate::coordinator`]'s
/// `estimate_partial` routing) and the bench harness.
///
/// Two families: the `*` functions count the **sparse-path** operations
/// exactly from the patterns (assuming stored values are numerically
/// nonzero, i.e. the value-dependent `== 0` skips don't fire — the
/// worst-case the scheduler must budget for), and the `*_dense` functions
/// count the **dense/tiled-path** operations in closed form. The dense
/// counts are exact for the skip-free scalar and tiled kernels (which
/// execute the same multiset of operations — see
/// [`crate::numeric::tiled`]), pinned against hand-computed small-block
/// values in the unit tests below.
pub mod flops {
    use crate::blocking::partition::Block;

    /// Sparse GETRF: for each column c, each U-entry k<c triggers an AXPY
    /// of length |L(:,k)| (2 flops per element), plus |L(:,c)| pivot
    /// divisions.
    pub fn getrf(pat: &Block) -> f64 {
        let n = pat.n_cols as usize;
        let mut below = vec![0usize; n];
        for c in 0..n {
            let rows = pat.col_rows(c);
            let d = rows.partition_point(|&r| (r as usize) < c);
            below[c] = rows.len() - d - 1; // strictly below diagonal
        }
        let mut fl = 0.0;
        for c in 0..n {
            let rows = pat.col_rows(c);
            for &r in rows {
                let k = r as usize;
                if k >= c {
                    break;
                }
                fl += 2.0 * below[k] as f64;
            }
            fl += below[c] as f64; // the divisions
        }
        fl
    }

    /// Sparse GESSM: per target column, Σ over its entries k of
    /// 2·|L_kk(:,k)| (strictly-below-diagonal AXPY).
    pub fn gessm(pat: &Block, diag: &Block) -> f64 {
        let mut below = vec![0usize; diag.n_cols as usize];
        for c in 0..diag.n_cols as usize {
            let rows = diag.col_rows(c);
            let d = rows.partition_point(|&r| (r as usize) <= c);
            below[c] = rows.len() - d;
        }
        let mut fl = 0.0;
        for c in 0..pat.n_cols as usize {
            for &r in pat.col_rows(c) {
                fl += 2.0 * below[r as usize] as f64;
            }
        }
        fl
    }

    /// Sparse TSTRF: per column c, Σ over U entries k<c of 2·|X(:,k)|,
    /// plus |X(:,c)| multiplies by the pivot reciprocal.
    pub fn tstrf(pat: &Block, diag: &Block) -> f64 {
        let mut xcol = vec![0usize; pat.n_cols as usize];
        for c in 0..pat.n_cols as usize {
            xcol[c] = pat.col_rows(c).len();
        }
        let mut fl = 0.0;
        for c in 0..pat.n_cols as usize {
            for &dr in diag.col_rows(c) {
                let k = dr as usize;
                if k >= c {
                    break;
                }
                fl += 2.0 * xcol[k] as f64;
            }
            fl += xcol[c] as f64;
        }
        fl
    }

    /// Sparse SSSSM: Σ over B entries (r,c) of 2·|A(:,r)| accumulate
    /// flops, plus one gather subtract per C-pattern entry of every
    /// column whose B column contributes (the term the old estimator
    /// dropped — for hypersparse panels the gather dominates).
    pub fn ssssm(a: &Block, b: &Block, c: &Block) -> f64 {
        let mut acol = vec![0usize; a.n_cols as usize];
        for ci in 0..a.n_cols as usize {
            acol[ci] = a.col_rows(ci).len();
        }
        let mut fl = 0.0;
        for ci in 0..b.n_cols as usize {
            let mut any = false;
            for &r in b.col_rows(ci) {
                let len = acol[r as usize];
                any |= len > 0;
                fl += 2.0 * len as f64;
            }
            if any {
                fl += c.col_rows(ci).len() as f64;
            }
        }
        fl
    }

    /// Dense GETRF on an `n×n` block: per step k one reciprocal, `n-1-k`
    /// scale multiplies and a `(n-1-k)²` rank-1 update (2 flops/element).
    /// `= n + n(n-1)/2 + n(n-1)(2n-1)/3`.
    pub fn getrf_dense(n: usize) -> f64 {
        let n = n as f64;
        n + n * (n - 1.0) / 2.0 + n * (n - 1.0) * (2.0 * n - 1.0) / 3.0
    }

    /// Dense GESSM (`trsm_lower_unit`, unit-lower `m×m` applied to `m×n`):
    /// per column Σ_r 2(m-1-r) `= n·m(m-1)` (skip-free).
    pub fn gessm_dense(m: usize, n: usize) -> f64 {
        (n * m * m.saturating_sub(1)) as f64
    }

    /// Dense TSTRF (`trsm_upper_right`, `m×k` times `U⁻¹` of `k×k`): per
    /// column c, 2m·c update flops + one reciprocal + m scale multiplies
    /// `= m·k² + k`.
    pub fn tstrf_dense(m: usize, k: usize) -> f64 {
        (m * k * k + k) as f64
    }

    /// Dense SSSSM (`gemm_update`): `2·m·k·n` exactly.
    pub fn ssssm_dense(m: usize, k: usize, n: usize) -> f64 {
        2.0 * m as f64 * k as f64 * n as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blocking::{regular_blocking, BlockedMatrix};
    use crate::numeric::dense;
    use crate::sparse::gen;
    use crate::symbolic;

    /// Factor a small matrix with one giant block and compare {L\U}
    /// against the dense no-pivot LU.
    #[test]
    fn getrf_matches_dense_lu_single_block() {
        let a = gen::uniform_random(24, 0.2, 42);
        let sym = symbolic::analyze(&a);
        let ldu = sym.ldu_pattern(&a).unwrap();
        let bm = BlockedMatrix::build(&ldu, regular_blocking(24, 24));
        let id = bm.block_id(0, 0).unwrap();
        let pat = bm.block(id);
        let mut vals = pat.values.clone();
        let mut ws = Workspace::with_capacity(24);
        getrf(pat, &mut vals, &mut ws).unwrap();

        // dense reference
        let mut d = vec![0.0; 24 * 24];
        for j in 0..24 {
            for (i, v) in a.col(j) {
                d[j * 24 + i] = v;
            }
        }
        dense::getrf_in_place(&mut d, 24).unwrap();
        for c in 0..24usize {
            for (k, &r) in pat.col_rows(c).iter().enumerate() {
                let got = vals[pat.col_ptr[c] as usize + k];
                let want = d[c * 24 + r as usize];
                assert!(
                    (got - want).abs() < 1e-9 * want.abs().max(1.0),
                    "mismatch at ({r},{c}): {got} vs {want}"
                );
            }
        }
    }

    #[test]
    fn getrf_detects_zero_pivot() {
        // 2x2 with exact cancellation: [[1,1],[1,1]] -> pivot 0 at col 1
        let mut coo = crate::sparse::Coo::new(2, 2);
        for i in 0..2 {
            for j in 0..2 {
                coo.push(i, j, 1.0);
            }
        }
        let a = coo.to_csc();
        let sym = symbolic::analyze(&a);
        let ldu = sym.ldu_pattern(&a).unwrap();
        let bm = BlockedMatrix::build(&ldu, regular_blocking(2, 2));
        let id = bm.block_id(0, 0).unwrap();
        let pat = bm.block(id);
        let mut vals = pat.values.clone();
        let mut ws = Workspace::default();
        let err = getrf(pat, &mut vals, &mut ws);
        assert!(matches!(err, Err(KernelError::ZeroPivot { local_col: 1, .. })));
    }

    #[test]
    fn f32_kernels_track_f64_within_single_precision() {
        // the f32 instantiation of every sparse kernel must approximate
        // the f64 result to f32 accuracy on a well-conditioned block
        let a = gen::grid2d_laplacian(5, 5);
        let sym = symbolic::analyze(&a);
        let ldu = sym.ldu_pattern(&a).unwrap();
        let bm = BlockedMatrix::build(&ldu, regular_blocking(25, 25));
        let id = bm.block_id(0, 0).unwrap();
        let pat = bm.block(id);
        let mut ws = Workspace::with_capacity(25);
        let mut v64 = pat.values.clone();
        getrf(pat, &mut v64, &mut ws).unwrap();
        let mut v32: Vec<f32> = pat.values.iter().map(|&v| v as f32).collect();
        getrf(pat, &mut v32, &mut ws).unwrap();
        for (a, b) in v64.iter().zip(&v32) {
            assert!(
                (a - *b as f64).abs() < 1e-4 * a.abs().max(1.0),
                "f32 kernel drifted: {a} vs {b}"
            );
        }
    }

    /// Full blocked factorization on a 2x2 block grid, every kernel
    /// exercised, verified against dense LU of the whole matrix.
    fn blocked_vs_dense(a: &crate::sparse::Csc, bs: usize) {
        let n = a.n_cols();
        let sym = symbolic::analyze(a);
        let ldu = sym.ldu_pattern(a).unwrap();
        let bm = BlockedMatrix::build(&ldu, regular_blocking(n, bs));
        let nb = bm.nb();
        let mut vals: Vec<Vec<f64>> = bm.blocks.iter().map(|b| b.values.clone()).collect();
        let mut ws = Workspace::with_capacity(n);
        for k in 0..nb {
            let diag_id = bm.block_id(k, k).expect("diagonal block must exist") as usize;
            {
                let pat = &bm.blocks[diag_id];
                let mut v = std::mem::take(&mut vals[diag_id]);
                getrf(pat, &mut v, &mut ws).unwrap();
                vals[diag_id] = v;
            }
            let diag_pat = &bm.blocks[diag_id];
            let diag_vals = vals[diag_id].clone();
            // panels
            for &id in &bm.by_col[k] {
                let b = bm.block(id);
                if (b.bi as usize) > k {
                    let mut v = std::mem::take(&mut vals[id as usize]);
                    tstrf(b, &mut v, diag_pat, &diag_vals, &mut ws);
                    vals[id as usize] = v;
                }
            }
            for &id in &bm.by_row[k] {
                let b = bm.block(id);
                if (b.bj as usize) > k {
                    let mut v = std::mem::take(&mut vals[id as usize]);
                    gessm(b, &mut v, diag_pat, &diag_vals, &mut ws);
                    vals[id as usize] = v;
                }
            }
            // updates
            let lids: Vec<u32> = bm.by_col[k]
                .iter()
                .copied()
                .filter(|&id| (bm.block(id).bi as usize) > k)
                .collect();
            let uids: Vec<u32> = bm.by_row[k]
                .iter()
                .copied()
                .filter(|&id| (bm.block(id).bj as usize) > k)
                .collect();
            for &lid in &lids {
                for &uid in &uids {
                    let (bi, bj) = (bm.block(lid).bi as usize, bm.block(uid).bj as usize);
                    if let Some(cid) = bm.block_id(bi, bj) {
                        let mut v = std::mem::take(&mut vals[cid as usize]);
                        ssssm(
                            bm.block(cid),
                            &mut v,
                            bm.block(lid),
                            &vals[lid as usize],
                            bm.block(uid),
                            &vals[uid as usize],
                            &mut ws,
                        );
                        vals[cid as usize] = v;
                    }
                }
            }
        }
        // dense reference on the whole matrix
        let mut d = vec![0.0; n * n];
        for j in 0..n {
            for (i, v) in a.col(j) {
                d[j * n + i] = v;
            }
        }
        dense::getrf_in_place(&mut d, n).unwrap();
        let positions = bm.blocking.positions();
        for (idx, b) in bm.blocks.iter().enumerate() {
            let (rlo, clo) = (positions[b.bi as usize], positions[b.bj as usize]);
            for c in 0..b.n_cols as usize {
                for (t, &r) in b.col_rows(c).iter().enumerate() {
                    let got = vals[idx][b.col_ptr[c] as usize + t];
                    let want = d[(clo + c) * n + rlo + r as usize];
                    assert!(
                        (got - want).abs() < 1e-8 * want.abs().max(1.0),
                        "block ({},{}) local ({r},{c}): {got} vs {want}",
                        b.bi,
                        b.bj
                    );
                }
            }
        }
    }

    #[test]
    fn blocked_factorization_matches_dense_on_grid() {
        blocked_vs_dense(&gen::grid2d_laplacian(6, 5), 8);
    }

    #[test]
    fn blocked_factorization_matches_dense_on_unsymmetric() {
        blocked_vs_dense(&gen::directed_graph(40, 3, 11), 11);
    }

    #[test]
    fn blocked_factorization_matches_dense_on_bbd() {
        let a = gen::circuit_bbd(gen::CircuitParams {
            n: 60,
            border_frac: 0.15,
            border_density: 0.5,
            interior_deg: 2,
            seed: 5,
        });
        blocked_vs_dense(&a, 13);
    }

    #[test]
    fn blocked_factorization_matches_dense_on_arrow() {
        blocked_vs_dense(&gen::arrow_up(30), 7);
        blocked_vs_dense(&gen::arrow_down(30), 7);
    }

    #[test]
    fn cost_model_positive_and_scales() {
        let a = gen::grid2d_laplacian(8, 8);
        let sym = symbolic::analyze(&a);
        let ldu = sym.ldu_pattern(&a).unwrap();
        let bm = BlockedMatrix::build(&ldu, regular_blocking(64, 16));
        let id = bm.block_id(0, 0).unwrap();
        let c1 = flops::getrf(bm.block(id));
        assert!(c1 > 0.0);
        if let (Some(l), Some(u)) = (bm.block_id(1, 0), bm.block_id(0, 1)) {
            if let Some(c) = bm.block_id(1, 1) {
                let fl = flops::ssssm(bm.block(l), bm.block(u), bm.block(c));
                assert!(fl > 0.0);
            }
            let fl_t = flops::tstrf(bm.block(l), bm.block(id));
            let fl_g = flops::gessm(bm.block(u), bm.block(id));
            assert!(fl_t > 0.0 && fl_g > 0.0);
        }
    }

    /// Build a fully-dense `n×n` diagonal block (every pattern position
    /// stored) for hand-pinning the estimators.
    fn full_block(n: usize) -> Block {
        let mut col_ptr = vec![0u32; n + 1];
        let mut row_idx = Vec::with_capacity(n * n);
        for c in 0..n {
            col_ptr[c + 1] = ((c + 1) * n) as u32;
            for r in 0..n {
                row_idx.push(r as u32);
            }
        }
        Block {
            bi: 0,
            bj: 0,
            n_rows: n as u32,
            n_cols: n as u32,
            col_ptr,
            row_idx,
            values: vec![1.0; n * n],
            diag_pos: (0..n as u32).collect(),
        }
    }

    /// Off-diagonal `m×n` block with every position stored.
    fn full_panel(m: usize, n: usize, bi: u32, bj: u32) -> Block {
        let mut col_ptr = vec![0u32; n + 1];
        let mut row_idx = Vec::with_capacity(m * n);
        for c in 0..n {
            col_ptr[c + 1] = ((c + 1) * m) as u32;
            for r in 0..m {
                row_idx.push(r as u32);
            }
        }
        Block {
            bi,
            bj,
            n_rows: m as u32,
            n_cols: n as u32,
            col_ptr,
            row_idx,
            values: vec![1.0; m * n],
            diag_pos: Vec::new(),
        }
    }

    /// Hand-computed pins for the sparse estimators on fully-dense
    /// patterns (where the AXPY structure is easy to count by hand).
    #[test]
    fn flops_pinned_against_hand_counts_sparse() {
        // GETRF on a full 3×3: below = [2,1,0].
        //   c=0: 2 divisions                                    = 2
        //   c=1: k=0 AXPY 2·2 + 1 division                      = 5
        //   c=2: k=0 AXPY 2·2, k=1 AXPY 2·1, 0 divisions        = 6
        let d3 = full_block(3);
        assert_eq!(flops::getrf(&d3), 13.0);

        // GESSM: full 3×3 diag (strictly-below sizes [2,1,0]) applied to
        // a full 3×2 panel: per column 2·(2+1+0) = 6, two columns = 12.
        let u = full_panel(3, 2, 0, 1);
        assert_eq!(flops::gessm(&u, &d3), 12.0);

        // TSTRF: full 2×3 panel (|X(:,c)| = 2) against full 3×3 diag:
        //   c=0: 0 updates + 2 scale muls          = 2
        //   c=1: k=0: 2·2 + 2                      = 6
        //   c=2: k=0,1: 2·(2+2) + 2                = 10
        let l = full_panel(2, 3, 1, 0);
        assert_eq!(flops::tstrf(&l, &d3), 18.0);

        // SSSSM: A full 2×3, B full 3×2, C full 2×2: per C column,
        // 3 B-entries × AXPY 2·2 = 12 accumulates + 2 gather subtracts;
        // 2 columns = 28.
        let a = full_panel(2, 3, 1, 0);
        let b = full_panel(3, 2, 0, 1);
        let c = full_panel(2, 2, 1, 1);
        assert_eq!(flops::ssssm(&a, &b, &c), 28.0);
    }

    /// Dense closed forms pinned against tiny hand counts.
    #[test]
    fn flops_pinned_against_hand_counts_dense() {
        // n=1: one reciprocal. n=2: k=0: 1 div + 1 scale + 2-flop
        // rank-1; k=1: 1 div → 5. n=3: 3 + 3 + 2·(4+1) = hand: k=0:
        // 1+2+2·4=11, k=1: 1+1+2·1=4, k=2: 1 → 16.
        assert_eq!(flops::getrf_dense(1), 1.0);
        assert_eq!(flops::getrf_dense(2), 5.0);
        assert_eq!(flops::getrf_dense(3), 16.0);
        // unit-lower 3×3 onto one column: r=0: 2·2, r=1: 2·1, r=2: 0 → 6
        assert_eq!(flops::gessm_dense(3, 1), 6.0);
        assert_eq!(flops::gessm_dense(3, 2), 12.0);
        // m=2, k=3: c=0: 2 muls (+recip), c=1: 2·2+2, c=2: 2·4+2 → 18+3
        assert_eq!(flops::tstrf_dense(2, 3), 21.0);
        assert_eq!(flops::ssssm_dense(2, 3, 4), 48.0);
    }

    /// The dense estimators match the sparse estimators' structure-driven
    /// counts on fully-dense patterns (up to the skip-free accounting:
    /// dense GETRF counts the reciprocal per column and the dense SSSSM
    /// counts every multiply where the sparse gather counts one subtract
    /// per output).
    #[test]
    fn dense_estimators_bound_sparse_on_full_patterns() {
        for n in [1usize, 2, 5, 8] {
            let blk = full_block(n);
            let sparse = flops::getrf(&blk);
            let dense = flops::getrf_dense(n);
            assert!(
                dense >= sparse,
                "dense count {dense} must dominate sparse {sparse} at n={n}"
            );
            // the gap is exactly the scale multiplies + reciprocals the
            // sparse kernel folds into its gather division
            assert_eq!(dense - sparse, n as f64 + n as f64 * (n as f64 - 1.0) / 2.0);
        }
    }
}
