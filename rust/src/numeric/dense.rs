//! Dense block kernels on column-major buffers — the portable **scalar
//! reference** implementation ([`super::KernelImpl::Scalar`]).
//!
//! Three roles: (1) CPU implementation of the dense path PanguLU would run
//! through cuBLAS — selected by [`super::KernelPolicy`] for dense blocks;
//! (2) correctness oracle for both the sparse kernels and the tiled fast
//! path ([`super::tiled`], checked bit-for-bit by
//! `tests/kernel_differential.rs`); (3) the same operations the AOT
//! Pallas/XLA artifacts implement, so [`crate::runtime`] can swap them in
//! 1:1 (`getrf_in_place` ↔ `artifacts/getrf_*.hlo.txt`, …).
//!
//! **Skip-free contract.** These kernels deliberately contain no
//! value-dependent `== 0` skip branches: every kernel executes the same
//! fixed multiset of operations for a given shape, in a fixed order
//! (ascending-`k` rank-1 updates, one subtract of one product at a time).
//! That makes the scalar path (a) bit-identical to the tiled path, which
//! executes the identical operation sequence per output element, and
//! (b) an honest flop baseline for the bench harness (the closed-form
//! counts in [`super::kernels::flops`] are exact). Zero-skipping belongs
//! to the *sparse* kernels, where the pattern — not a runtime branch —
//! encodes the zeros.

use super::kernels::KernelError;
use super::real::Real;

/// In-place no-pivot LU of a dense `n×n` column-major matrix: on return
/// the buffer holds `{L\U}` with L's unit diagonal implicit.
pub fn getrf_in_place<T: Real>(a: &mut [T], n: usize) -> Result<(), KernelError> {
    debug_assert_eq!(a.len(), n * n);
    for k in 0..n {
        let pivot = a[k * n + k];
        if pivot.abs() < T::PIVOT_FLOOR {
            return Err(KernelError::ZeroPivot {
                block: (0, 0),
                local_col: k,
                value: pivot.to_f64(),
            });
        }
        let inv = T::ONE / pivot;
        for i in (k + 1)..n {
            a[k * n + i] *= inv;
        }
        // rank-1 update of the trailing submatrix
        for j in (k + 1)..n {
            let ukj = a[j * n + k];
            let (lcol, tcol) = {
                let (lo, hi) = a.split_at_mut(j * n);
                (&lo[k * n..k * n + n], &mut hi[..n])
            };
            for i in (k + 1)..n {
                tcol[i] -= lcol[i] * ukj;
            }
        }
    }
    Ok(())
}

/// `B ← L⁻¹ B` with unit-lower `L` stored in `{L\U}` form (`lu`, `m×m`),
/// `B` column-major `m×k`. The dense counterpart of GESSM.
pub fn trsm_lower_unit<T: Real>(lu: &[T], m: usize, b: &mut [T], k: usize) {
    debug_assert_eq!(lu.len(), m * m);
    debug_assert_eq!(b.len(), m * k);
    for c in 0..k {
        let col = &mut b[c * m..(c + 1) * m];
        for r in 0..m {
            let alpha = col[r];
            for i in (r + 1)..m {
                col[i] -= alpha * lu[r * m + i];
            }
        }
    }
}

/// `B ← B U⁻¹` with upper `U` stored in `{L\U}` form (`lu`, `k×k`),
/// `B` column-major `m×k`. The dense counterpart of TSTRF.
pub fn trsm_upper_right<T: Real>(lu: &[T], k: usize, b: &mut [T], m: usize) {
    debug_assert_eq!(lu.len(), k * k);
    debug_assert_eq!(b.len(), m * k);
    for c in 0..k {
        // subtract contributions of previous columns
        for p in 0..c {
            let upc = lu[c * k + p];
            let (prev, cur) = {
                let (lo, hi) = b.split_at_mut(c * m);
                (&lo[p * m..p * m + m], &mut hi[..m])
            };
            for i in 0..m {
                cur[i] -= prev[i] * upc;
            }
        }
        let inv = T::ONE / lu[c * k + c];
        for i in 0..m {
            b[c * m + i] *= inv;
        }
    }
}

/// `C ← C − A·B`, all column-major: `A` is `m×k`, `B` is `k×n`, `C` is
/// `m×n`. The dense counterpart of SSSSM (and the MXU hot-spot on TPU).
pub fn gemm_update<T: Real>(c: &mut [T], a: &[T], b: &[T], m: usize, k: usize, n: usize) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    for j in 0..n {
        let ccol = &mut c[j * m..(j + 1) * m];
        for p in 0..k {
            let bpj = b[j * k + p];
            let acol = &a[p * m..(p + 1) * m];
            for i in 0..m {
                ccol[i] -= acol[i] * bpj;
            }
        }
    }
}

/// Multiply `{L\U}` back into `A = L·U` (test helper).
pub fn lu_multiply(lu: &[f64], n: usize) -> Vec<f64> {
    let mut out = vec![0.0; n * n];
    for j in 0..n {
        for i in 0..n {
            let mut s = 0.0;
            let kmax = i.min(j);
            for k in 0..=kmax {
                let l = if i == k { 1.0 } else if i > k { lu[k * n + i] } else { 0.0 };
                let u = if k <= j { lu[j * n + k] } else { 0.0 };
                s += l * u;
            }
            out[j * n + i] = s;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::gen;
    use crate::util::Prng;

    #[test]
    fn getrf_reconstructs_a() {
        let n = 17;
        let a = gen::dense_dd(n, 1);
        let mut lu = a.clone();
        getrf_in_place(&mut lu, n).unwrap();
        let back = lu_multiply(&lu, n);
        for p in 0..n * n {
            assert!((back[p] - a[p]).abs() < 1e-9, "at {p}: {} vs {}", back[p], a[p]);
        }
    }

    #[test]
    fn getrf_rejects_singular() {
        let mut a = vec![1.0, 1.0, 1.0, 1.0]; // singular 2x2
        assert!(getrf_in_place(&mut a, 2).is_err());
    }

    #[test]
    fn trsm_lower_solves() {
        let n = 9;
        let a = gen::dense_dd(n, 2);
        let mut lu = a.clone();
        getrf_in_place(&mut lu, n).unwrap();
        let mut rng = Prng::new(3);
        let x: Vec<f64> = (0..n * 2).map(|_| rng.signed_unit()).collect();
        // b = L x
        let mut b = vec![0.0; n * 2];
        for c in 0..2 {
            for i in 0..n {
                let mut s = x[c * n + i];
                for k in 0..i {
                    s += lu[k * n + i] * x[c * n + k];
                }
                b[c * n + i] = s;
            }
        }
        trsm_lower_unit(&lu, n, &mut b, 2);
        for p in 0..n * 2 {
            assert!((b[p] - x[p]).abs() < 1e-9);
        }
    }

    #[test]
    fn trsm_upper_right_solves() {
        let k = 8;
        let m = 5;
        let a = gen::dense_dd(k, 4);
        let mut lu = a.clone();
        getrf_in_place(&mut lu, k).unwrap();
        let x = gen::dense_uniform(m, k, 5);
        // b = X U  (b[i,c] = Σ_p x[i,p] u[p,c])
        let mut b = vec![0.0; m * k];
        for c in 0..k {
            for i in 0..m {
                let mut s = 0.0;
                for p in 0..=c {
                    s += x[p * m + i] * lu[c * k + p];
                }
                b[c * m + i] = s;
            }
        }
        trsm_upper_right(&lu, k, &mut b, m);
        for p in 0..m * k {
            assert!((b[p] - x[p]).abs() < 1e-9, "at {p}: {} vs {}", b[p], x[p]);
        }
    }

    #[test]
    fn gemm_update_matches_naive() {
        let (m, k, n) = (6, 4, 5);
        let a = gen::dense_uniform(m, k, 6);
        let b = gen::dense_uniform(k, n, 7);
        let c0 = gen::dense_uniform(m, n, 8);
        let mut c = c0.clone();
        gemm_update(&mut c, &a, &b, m, k, n);
        for j in 0..n {
            for i in 0..m {
                let mut want = c0[j * m + i];
                for p in 0..k {
                    want -= a[p * m + i] * b[j * k + p];
                }
                assert!((c[j * m + i] - want).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn dense_kernels_compose_into_block_lu() {
        // 2x2 block dense LU via the four kernels == full dense LU
        let n = 12;
        let h = 7; // uneven split
        let a = gen::dense_dd(n, 7);
        let mut full = a.clone();
        getrf_in_place(&mut full, n).unwrap();

        // extract blocks (column-major)
        let sub = |r0: usize, r1: usize, c0: usize, c1: usize| -> Vec<f64> {
            let mut out = vec![0.0; (r1 - r0) * (c1 - c0)];
            for (cc, c) in (c0..c1).enumerate() {
                for (rr, r) in (r0..r1).enumerate() {
                    out[cc * (r1 - r0) + rr] = a[c * n + r];
                }
            }
            out
        };
        let mut a11 = sub(0, h, 0, h);
        let mut a21 = sub(h, n, 0, h);
        let mut a12 = sub(0, h, h, n);
        let mut a22 = sub(h, n, h, n);
        getrf_in_place(&mut a11, h).unwrap();
        trsm_lower_unit(&a11, h, &mut a12, n - h);
        trsm_upper_right(&a11, h, &mut a21, n - h);
        gemm_update(&mut a22, &a21, &a12, n - h, h, n - h);
        getrf_in_place(&mut a22, n - h).unwrap();

        let check = |blk: &[f64], r0: usize, c0: usize, nr: usize, nc: usize| {
            for c in 0..nc {
                for r in 0..nr {
                    let got = blk[c * nr + r];
                    let want = full[(c0 + c) * n + r0 + r];
                    assert!(
                        (got - want).abs() < 1e-9,
                        "block entry ({r},{c}) {got} vs {want}"
                    );
                }
            }
        };
        check(&a11, 0, 0, h, h);
        check(&a12, 0, h, h, n - h);
        check(&a21, h, 0, n - h, h);
        check(&a22, h, h, n - h, n - h);
    }

    #[test]
    fn f32_instantiation_compiles_and_solves() {
        let n = 10;
        let a64 = gen::dense_dd(n, 9);
        let mut lu32: Vec<f32> = a64.iter().map(|&v| v as f32).collect();
        getrf_in_place(&mut lu32, n).unwrap();
        let mut lu64 = a64.clone();
        getrf_in_place(&mut lu64, n).unwrap();
        for (g, w) in lu32.iter().zip(&lu64) {
            assert!((*g as f64 - w).abs() < 1e-4 * w.abs().max(1.0));
        }
    }
}
