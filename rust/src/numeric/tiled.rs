//! Register-blocked, cache-tiled dense kernels — the
//! [`super::KernelImpl::Tiled`] fast path.
//!
//! # The order-preservation contract
//!
//! Every kernel here executes, **per output element**, exactly the same
//! sequence of IEEE-754 operations as its scalar counterpart in
//! [`super::dense`]: rank-`k` contributions arrive in ascending `k`, one
//! `x ← x − a·b` subtract of one product at a time, with pivot
//! reciprocals/scales applied at the same sequence point. The speedup
//! comes purely from *where* the intermediate values live (registers
//! instead of a memory round-trip per update) and *which* elements are
//! interleaved (a register tile of independent outputs instead of one
//! column) — both invisible to IEEE semantics. rustc performs no
//! floating-point contraction by default, so `acc - av*b` never fuses
//! into an FMA the scalar path didn't execute. Consequence: Scalar and
//! Tiled are **bit-identical**, for f64 and f32 alike — enforced by the
//! unit tests below, `tests/kernel_differential.rs`, and the in-bench
//! identity gate of `repro kernel-bench`.
//!
//! # Microkernel layout
//!
//! ```text
//!            NR=4 columns of B/C
//!           ┌────┬────┬────┬────┐          acc[t][r]: NR×MR accumulator
//!   MR=8 ┌──┤ c₀ │ c₁ │ c₂ │ c₃ │          block held in registers for
//!   rows │A │    │    │    │    │          the whole p-loop; each A
//!        └──┴────┴────┴────┴────┘          column load is reused NR×.
//!         ▲ p ascending (k-loop) — the order the scalar kernel uses
//! ```
//!
//! `gemm_panel` is the one microkernel; the three level-3 solves
//! (`trsm_lower_unit`, `trsm_upper_right`) and the blocked LU
//! (`getrf_in_place`) reduce their off-panel work to it, packing the
//! small operand into scratch when it would alias the output buffer.
//! Panel width 32 keeps the active panel + accumulators inside L1/L2 for
//! the block sizes the irregular blocking produces (§5.2 dense regions).

use super::kernels::KernelError;
use super::real::Real;

/// Register tile height (rows of C per accumulator block).
pub const MR: usize = 8;
/// Register tile width (columns of C per accumulator block).
pub const NR: usize = 4;
/// Cache panel width for the blocked TRSM/LU drivers.
pub const PANEL: usize = 32;

/// `C ← C − A·B` on column-major sub-matrices with independent leading
/// dimensions: `C` is `m×n` (ld `ldc`), `A` is `m×k` (ld `lda`), `B` is
/// `k×n` (ld `ldb`). Per output element the `p`-loop ascends exactly like
/// [`super::dense::gemm_update`]'s.
pub fn gemm_panel<T: Real>(
    c: &mut [T],
    ldc: usize,
    a: &[T],
    lda: usize,
    b: &[T],
    ldb: usize,
    m: usize,
    k: usize,
    n: usize,
) {
    let mut j0 = 0;
    // main column tiles: NR columns of C updated together
    while j0 + NR <= n {
        let bcol: [&[T]; NR] =
            core::array::from_fn(|t| &b[(j0 + t) * ldb..(j0 + t) * ldb + k]);
        let mut i0 = 0;
        while i0 + MR <= m {
            // load the MR×NR accumulator tile
            let mut acc = [[T::ZERO; MR]; NR];
            for t in 0..NR {
                let cc = &c[(j0 + t) * ldc + i0..(j0 + t) * ldc + i0 + MR];
                for r in 0..MR {
                    acc[t][r] = cc[r];
                }
            }
            for p in 0..k {
                let av_s = &a[p * lda + i0..p * lda + i0 + MR];
                let mut av = [T::ZERO; MR];
                for r in 0..MR {
                    av[r] = av_s[r];
                }
                for t in 0..NR {
                    let bpj = bcol[t][p];
                    for r in 0..MR {
                        acc[t][r] = acc[t][r] - av[r] * bpj;
                    }
                }
            }
            for t in 0..NR {
                let cc = &mut c[(j0 + t) * ldc + i0..(j0 + t) * ldc + i0 + MR];
                for r in 0..MR {
                    cc[r] = acc[t][r];
                }
            }
            i0 += MR;
        }
        // row remainder of the full-width column tile: scalar register
        // accumulation, p still ascending per element
        for t in 0..NR {
            let bc = bcol[t];
            for i in i0..m {
                let mut acc = c[(j0 + t) * ldc + i];
                for p in 0..k {
                    acc = acc - a[p * lda + i] * bc[p];
                }
                c[(j0 + t) * ldc + i] = acc;
            }
        }
        j0 += NR;
    }
    // column remainder: one column at a time, p ascending per element
    for j in j0..n {
        let bc = &b[j * ldb..j * ldb + k];
        for i in 0..m {
            let mut acc = c[j * ldc + i];
            for p in 0..k {
                acc = acc - a[p * lda + i] * bc[p];
            }
            c[j * ldc + i] = acc;
        }
    }
}

/// `C ← C − A·B` on whole column-major buffers — drop-in (bit-identical)
/// replacement for [`super::dense::gemm_update`].
pub fn gemm_update<T: Real>(c: &mut [T], a: &[T], b: &[T], m: usize, k: usize, n: usize) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    gemm_panel(c, m, a, m, b, k, m, k, n);
}

/// Blocked `B ← L⁻¹ B` (unit-lower `{L\U}` `lu`, `m×m`; `B` `m×k`) —
/// bit-identical to [`super::dense::trsm_lower_unit`].
///
/// Row panels of width [`PANEL`]: the triangular part of each panel runs
/// scalar (it is O(PANEL²·k) work), then everything below the panel is a
/// rank-PANEL [`gemm_panel`] — with the solved panel rows packed into
/// scratch, because B is both the gemm's right operand and its output.
pub fn trsm_lower_unit<T: Real>(lu: &[T], m: usize, b: &mut [T], k: usize) {
    debug_assert_eq!(lu.len(), m * m);
    debug_assert_eq!(b.len(), m * k);
    let mut pack: Vec<T> = Vec::new();
    let mut r0 = 0;
    while r0 < m {
        let r1 = (r0 + PANEL).min(m);
        // triangular solve inside the panel (updates from rows < r0
        // already applied by earlier panels' gemm)
        for c in 0..k {
            let col = &mut b[c * m..(c + 1) * m];
            for r in r0..r1 {
                let alpha = col[r];
                for i in (r + 1)..r1 {
                    col[i] -= alpha * lu[r * m + i];
                }
            }
        }
        if r1 < m {
            let rb = r1 - r0;
            // pack solved panel rows (gemm right operand) out of B
            pack.clear();
            pack.resize(rb * k, T::ZERO);
            for c in 0..k {
                for t in 0..rb {
                    pack[c * rb + t] = b[c * m + r0 + t];
                }
            }
            // rows below the panel: B[r1.., :] −= L[r1.., r0..r1]·pack
            let a_sub = &lu[r0 * m + r1..];
            let c_sub = &mut b[r1..];
            gemm_panel(c_sub, m, a_sub, m, &pack, rb, m - r1, rb, k);
        }
        r0 = r1;
    }
}

/// Blocked `B ← B U⁻¹` (upper `{L\U}` `lu`, `k×k`; `B` `m×k`) —
/// bit-identical to [`super::dense::trsm_upper_right`].
///
/// Column panels of width [`PANEL`]: contributions of all columns before
/// the panel arrive via one [`gemm_panel`] (`split_at_mut` separates the
/// finished columns from the panel, U block read straight out of `lu`
/// with `ldb = k`), then the intra-panel dependencies run scalar.
pub fn trsm_upper_right<T: Real>(lu: &[T], k: usize, b: &mut [T], m: usize) {
    debug_assert_eq!(lu.len(), k * k);
    debug_assert_eq!(b.len(), m * k);
    let mut c0 = 0;
    while c0 < k {
        let c1 = (c0 + PANEL).min(k);
        if c0 > 0 {
            // panel −= B[:, 0..c0] · U[0..c0, c0..c1]
            let (prev, rest) = b.split_at_mut(c0 * m);
            let c_sub = &mut rest[..(c1 - c0) * m];
            let b_sub = &lu[c0 * k..];
            gemm_panel(c_sub, m, prev, m, b_sub, k, m, c0, c1 - c0);
        }
        for c in c0..c1 {
            for p in c0..c {
                let upc = lu[c * k + p];
                let (lo, hi) = b.split_at_mut(c * m);
                let prev = &lo[p * m..p * m + m];
                let cur = &mut hi[..m];
                for i in 0..m {
                    cur[i] -= prev[i] * upc;
                }
            }
            let inv = T::ONE / lu[c * k + c];
            for i in 0..m {
                b[c * m + i] *= inv;
            }
        }
        c0 = c1;
    }
}

/// Blocked in-place no-pivot LU of a dense `n×n` column-major matrix —
/// bit-identical to [`super::dense::getrf_in_place`], including which
/// column a [`KernelError::ZeroPivot`] is reported for.
///
/// LAPACK-style right-looking panels of width [`PANEL`]:
/// 1. factor the panel columns against each other (scalar rank-1s, full
///    column height — pivots checked in ascending column order, exactly
///    where the scalar kernel checks them);
/// 2. finish the U rows of the trailing columns (scalar small-triangular
///    solve against the panel's unit-lower part);
/// 3. one rank-PANEL [`gemm_panel`] for the Schur complement, with the
///    freshly-solved U panel packed to scratch (it lives in the same
///    columns as the gemm output) and `split_at_mut` at the panel/
///    trailing column boundary separating the L operand from the output.
pub fn getrf_in_place<T: Real>(a: &mut [T], n: usize) -> Result<(), KernelError> {
    debug_assert_eq!(a.len(), n * n);
    let mut upack: Vec<T> = Vec::new();
    let mut k0 = 0;
    while k0 < n {
        let k1 = (k0 + PANEL).min(n);
        // 1. panel factorization (columns k0..k1, rows k0..n)
        for kk in k0..k1 {
            let pivot = a[kk * n + kk];
            if pivot.abs() < T::PIVOT_FLOOR {
                return Err(KernelError::ZeroPivot {
                    block: (0, 0),
                    local_col: kk,
                    value: pivot.to_f64(),
                });
            }
            let inv = T::ONE / pivot;
            for i in (kk + 1)..n {
                a[kk * n + i] *= inv;
            }
            for j in (kk + 1)..k1 {
                let ukj = a[j * n + kk];
                let (lo, hi) = a.split_at_mut(j * n);
                let lcol = &lo[kk * n..kk * n + n];
                let tcol = &mut hi[..n];
                for i in (kk + 1)..n {
                    tcol[i] -= lcol[i] * ukj;
                }
            }
        }
        if k1 < n {
            let nb = k1 - k0;
            // 2. U rows of the trailing columns: unit-lower solve against
            // the panel (rows r in k0..k1, ascending — the order the
            // scalar rank-1 cascade applies them)
            for j in k1..n {
                for r in k0..k1 {
                    let ujr = a[j * n + r];
                    let (lo, hi) = a.split_at_mut(j * n);
                    let lcol = &lo[r * n..r * n + n];
                    let col = &mut hi[..n];
                    for i in (r + 1)..k1 {
                        col[i] -= lcol[i] * ujr;
                    }
                }
            }
            // 3. Schur complement of the trailing matrix
            upack.clear();
            upack.resize(nb * (n - k1), T::ZERO);
            for jj in 0..(n - k1) {
                for t in 0..nb {
                    upack[jj * nb + t] = a[(k1 + jj) * n + k0 + t];
                }
            }
            let (lo, hi) = a.split_at_mut(k1 * n);
            let a_sub = &lo[k0 * n + k1..];
            let c_sub = &mut hi[k1..];
            gemm_panel(c_sub, n, a_sub, n, &upack, nb, n - k1, nb, n - k1);
        }
        k0 = k1;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::numeric::dense;
    use crate::sparse::gen;

    /// Exercises edge tiles: below/above MR, NR, PANEL, and non-multiples.
    const SIZES: &[usize] = &[1, 2, 3, 5, 8, 13, 17, 31, 32, 33, 64, 70];

    fn assert_bits_eq(got: &[f64], want: &[f64], what: &str) {
        assert_eq!(got.len(), want.len());
        for (p, (g, w)) in got.iter().zip(want).enumerate() {
            assert_eq!(
                g.to_bits(),
                w.to_bits(),
                "{what}: bit mismatch at {p}: {g:?} vs {w:?}"
            );
        }
    }

    #[test]
    fn gemm_bitwise_matches_scalar() {
        for &m in SIZES {
            for &(k, n) in &[(m, m), (7, 11), (1, 1), (33, 5)] {
                let a = gen::dense_uniform(m, k, 100 + m as u64);
                let b = gen::dense_uniform(k, n, 200 + m as u64);
                let c0 = gen::dense_uniform(m, n, 300 + m as u64);
                let mut c_t = c0.clone();
                let mut c_s = c0;
                gemm_update(&mut c_t, &a, &b, m, k, n);
                dense::gemm_update(&mut c_s, &a, &b, m, k, n);
                assert_bits_eq(&c_t, &c_s, &format!("gemm {m}x{k}x{n}"));
            }
        }
    }

    #[test]
    fn getrf_bitwise_matches_scalar() {
        for &n in SIZES {
            let a = gen::dense_dd(n, 40 + n as u64);
            let mut lu_t = a.clone();
            let mut lu_s = a;
            getrf_in_place(&mut lu_t, n).unwrap();
            dense::getrf_in_place(&mut lu_s, n).unwrap();
            assert_bits_eq(&lu_t, &lu_s, &format!("getrf n={n}"));
        }
    }

    #[test]
    fn trsm_lower_bitwise_matches_scalar() {
        for &m in SIZES {
            let mut lu = gen::dense_dd(m, 50 + m as u64);
            dense::getrf_in_place(&mut lu, m).unwrap();
            for &k in &[1usize, 3, 16, 40] {
                let b0 = gen::dense_uniform(m, k, 60 + (m * k) as u64);
                let mut b_t = b0.clone();
                let mut b_s = b0;
                trsm_lower_unit(&lu, m, &mut b_t, k);
                dense::trsm_lower_unit(&lu, m, &mut b_s, k);
                assert_bits_eq(&b_t, &b_s, &format!("trsm_lower m={m} k={k}"));
            }
        }
    }

    #[test]
    fn trsm_upper_bitwise_matches_scalar() {
        for &k in SIZES {
            let mut lu = gen::dense_dd(k, 70 + k as u64);
            dense::getrf_in_place(&mut lu, k).unwrap();
            for &m in &[1usize, 5, 24, 40] {
                let b0 = gen::dense_uniform(m, k, 80 + (m * k) as u64);
                let mut b_t = b0.clone();
                let mut b_s = b0;
                trsm_upper_right(&lu, k, &mut b_t, m);
                dense::trsm_upper_right(&lu, k, &mut b_s, m);
                assert_bits_eq(&b_t, &b_s, &format!("trsm_upper m={m} k={k}"));
            }
        }
    }

    #[test]
    fn getrf_reports_same_pivot_failure_as_scalar() {
        // singular leading 2x2 inside a larger matrix: both paths must
        // fail at the same local column
        let n = 40;
        let mut a = gen::dense_dd(n, 90);
        // force exact cancellation at column 1
        for i in 0..n {
            a[n + i] = a[i]; // col 1 := col 0
        }
        let mut a_t = a.clone();
        let err_t = getrf_in_place(&mut a_t, n).unwrap_err();
        let err_s = dense::getrf_in_place(&mut a, n).unwrap_err();
        assert_eq!(err_t, err_s);
    }

    #[test]
    fn f32_bitwise_matches_scalar_f32() {
        let n = 48;
        let a: Vec<f32> = gen::dense_dd(n, 91).iter().map(|&v| v as f32).collect();
        let mut lu_t = a.clone();
        let mut lu_s = a;
        getrf_in_place(&mut lu_t, n).unwrap();
        dense::getrf_in_place(&mut lu_s, n).unwrap();
        for (g, w) in lu_t.iter().zip(&lu_s) {
            assert_eq!(g.to_bits(), w.to_bits());
        }
    }
}
