//! The scalar abstraction behind the mixed-precision kernels: every block
//! kernel (sparse and dense, scalar and tiled) is generic over [`Real`],
//! instantiated at `f64` (the default, bit-exactness-bearing path) and
//! `f32` (the bandwidth-saving replay path behind
//! [`crate::numeric::Precision::Mixed`]).
//!
//! The trait is deliberately tiny — constants, `abs`, and f64 conversion
//! — so the kernel bodies read exactly like their former f64-only selves
//! and the monomorphized f64 code is instruction-identical to what the
//! hand-written kernels compiled to.

use core::fmt::{Debug, Display};
use core::ops::{Add, AddAssign, Div, Mul, MulAssign, Neg, Sub, SubAssign};

/// IEEE-754 scalar the numeric kernels are generic over (`f64` / `f32`).
pub trait Real:
    Copy
    + Default
    + PartialEq
    + PartialOrd
    + Debug
    + Display
    + Send
    + Sync
    + 'static
    + Add<Output = Self>
    + Sub<Output = Self>
    + Mul<Output = Self>
    + Div<Output = Self>
    + Neg<Output = Self>
    + AddAssign
    + SubAssign
    + MulAssign
{
    const ZERO: Self;
    const ONE: Self;
    /// Pivot magnitude below which the no-pivot factorization aborts —
    /// scaled to the type's range (`1e-300` for f64, `1e-30` for f32: an
    /// f32 pivot below that is indistinguishable from a cancelled zero).
    const PIVOT_FLOOR: Self;
    fn abs(self) -> Self;
    fn to_f64(self) -> f64;
    fn from_f64(v: f64) -> Self;
}

impl Real for f64 {
    const ZERO: Self = 0.0;
    const ONE: Self = 1.0;
    const PIVOT_FLOOR: Self = 1e-300;
    #[inline(always)]
    fn abs(self) -> Self {
        f64::abs(self)
    }
    #[inline(always)]
    fn to_f64(self) -> f64 {
        self
    }
    #[inline(always)]
    fn from_f64(v: f64) -> Self {
        v
    }
}

impl Real for f32 {
    const ZERO: Self = 0.0;
    const ONE: Self = 1.0;
    const PIVOT_FLOOR: Self = 1e-30;
    #[inline(always)]
    fn abs(self) -> Self {
        f32::abs(self)
    }
    #[inline(always)]
    fn to_f64(self) -> f64 {
        self as f64
    }
    #[inline(always)]
    fn from_f64(v: f64) -> Self {
        v as f32
    }
}
