//! The right-looking blocked LU driver (paper Algorithm 1) and the shared
//! per-operation executor used by both the sequential path and the
//! multi-worker coordinator.

use super::dense;
use super::kernels::{self, KernelError, Workspace, WsBuf};
use super::real::Real;
use super::tiled;
use super::{KernelImpl, KernelKind, KernelPolicy, Precision};
use crate::blocking::partition::{Block, BlockedMatrix};
use std::sync::{Arc, OnceLock, PoisonError, RwLock, RwLockReadGuard, RwLockWriteGuard};

/// One block operation of Algorithm 1.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum BlockOp {
    /// Factor diagonal block `k` (line 3).
    Getrf { k: usize },
    /// U-panel `B_kj ← L_kk⁻¹ B_kj` (line 5).
    Gessm { k: usize, j: usize },
    /// L-panel `B_ik ← B_ik U_kk⁻¹` (line 6).
    Tstrf { i: usize, k: usize },
    /// Schur update `B_ij ← B_ij − B_ik B_kj` (line 10).
    Ssssm { i: usize, j: usize, k: usize },
}

impl BlockOp {
    /// Grid coordinates of the block this op writes.
    pub fn target(&self) -> (usize, usize) {
        match *self {
            BlockOp::Getrf { k } => (k, k),
            BlockOp::Gessm { k, j } => (k, j),
            BlockOp::Tstrf { i, k } => (i, k),
            BlockOp::Ssssm { i, j, .. } => (i, j),
        }
    }

    /// Elimination step this op belongs to.
    pub fn step(&self) -> usize {
        match *self {
            BlockOp::Getrf { k }
            | BlockOp::Gessm { k, .. }
            | BlockOp::Tstrf { k, .. }
            | BlockOp::Ssssm { k, .. } => k,
        }
    }
}

/// Pluggable dense-kernel backend: pure-rust CPU ([`CpuDense`]) or the
/// AOT PJRT artifacts ([`crate::runtime::PjrtDense`]).
///
/// The `*_tiled` methods carry the [`KernelImpl::Tiled`] fast path;
/// their defaults delegate to the base methods, so a backend whose dense
/// kernels are opaque accelerator artifacts (where the scalar/tiled
/// distinction is meaningless) implements four methods and ignores the
/// split. [`CpuDense`] overrides them with [`super::tiled`].
pub trait DenseBackend: Sync {
    fn getrf(&self, a: &mut [f64], n: usize) -> Result<(), KernelError>;
    fn trsm_lower(&self, lu: &[f64], m: usize, b: &mut [f64], k: usize);
    fn trsm_upper(&self, lu: &[f64], k: usize, b: &mut [f64], m: usize);
    fn gemm(&self, c: &mut [f64], a: &[f64], b: &[f64], m: usize, k: usize, n: usize);

    fn getrf_tiled(&self, a: &mut [f64], n: usize) -> Result<(), KernelError> {
        self.getrf(a, n)
    }
    fn trsm_lower_tiled(&self, lu: &[f64], m: usize, b: &mut [f64], k: usize) {
        self.trsm_lower(lu, m, b, k);
    }
    fn trsm_upper_tiled(&self, lu: &[f64], k: usize, b: &mut [f64], m: usize) {
        self.trsm_upper(lu, k, b, m);
    }
    fn gemm_tiled(&self, c: &mut [f64], a: &[f64], b: &[f64], m: usize, k: usize, n: usize) {
        self.gemm(c, a, b, m, k, n);
    }
}

/// Pure-rust dense backend: scalar reference kernels ([`dense`]) as the
/// base methods, register-blocked microkernels ([`tiled`]) as the tiled
/// fast path. The default / oracle.
pub struct CpuDense;

impl DenseBackend for CpuDense {
    fn getrf(&self, a: &mut [f64], n: usize) -> Result<(), KernelError> {
        dense::getrf_in_place(a, n)
    }
    fn trsm_lower(&self, lu: &[f64], m: usize, b: &mut [f64], k: usize) {
        dense::trsm_lower_unit(lu, m, b, k);
    }
    fn trsm_upper(&self, lu: &[f64], k: usize, b: &mut [f64], m: usize) {
        dense::trsm_upper_right(lu, k, b, m);
    }
    fn gemm(&self, c: &mut [f64], a: &[f64], b: &[f64], m: usize, k: usize, n: usize) {
        dense::gemm_update(c, a, b, m, k, n);
    }
    fn getrf_tiled(&self, a: &mut [f64], n: usize) -> Result<(), KernelError> {
        tiled::getrf_in_place(a, n)
    }
    fn trsm_lower_tiled(&self, lu: &[f64], m: usize, b: &mut [f64], k: usize) {
        tiled::trsm_lower_unit(lu, m, b, k);
    }
    fn trsm_upper_tiled(&self, lu: &[f64], k: usize, b: &mut [f64], m: usize) {
        tiled::trsm_upper_right(lu, k, b, m);
    }
    fn gemm_tiled(&self, c: &mut [f64], a: &[f64], b: &[f64], m: usize, k: usize, n: usize) {
        tiled::gemm_update(c, a, b, m, k, n);
    }
}

/// Dense dispatch seen by the generic executor: picks scalar vs tiled per
/// [`KernelImpl`] at a given scalar type.
trait DenseDispatch<T: Real> {
    fn getrf(&self, imp: KernelImpl, a: &mut [T], n: usize) -> Result<(), KernelError>;
    fn trsm_lower(&self, imp: KernelImpl, lu: &[T], m: usize, b: &mut [T], k: usize);
    fn trsm_upper(&self, imp: KernelImpl, lu: &[T], k: usize, b: &mut [T], m: usize);
    fn gemm(&self, imp: KernelImpl, c: &mut [T], a: &[T], b: &[T], m: usize, k: usize, n: usize);
}

/// f64 dispatch through the pluggable [`DenseBackend`] (runtime artifacts
/// eligible).
struct BackendDispatch<'a>(&'a dyn DenseBackend);

impl DenseDispatch<f64> for BackendDispatch<'_> {
    fn getrf(&self, imp: KernelImpl, a: &mut [f64], n: usize) -> Result<(), KernelError> {
        match imp {
            KernelImpl::Scalar => self.0.getrf(a, n),
            KernelImpl::Tiled => self.0.getrf_tiled(a, n),
        }
    }
    fn trsm_lower(&self, imp: KernelImpl, lu: &[f64], m: usize, b: &mut [f64], k: usize) {
        match imp {
            KernelImpl::Scalar => self.0.trsm_lower(lu, m, b, k),
            KernelImpl::Tiled => self.0.trsm_lower_tiled(lu, m, b, k),
        }
    }
    fn trsm_upper(&self, imp: KernelImpl, lu: &[f64], k: usize, b: &mut [f64], m: usize) {
        match imp {
            KernelImpl::Scalar => self.0.trsm_upper(lu, k, b, m),
            KernelImpl::Tiled => self.0.trsm_upper_tiled(lu, k, b, m),
        }
    }
    fn gemm(&self, imp: KernelImpl, c: &mut [f64], a: &[f64], b: &[f64], m: usize, k: usize, n: usize) {
        match imp {
            KernelImpl::Scalar => self.0.gemm(c, a, b, m, k, n),
            KernelImpl::Tiled => self.0.gemm_tiled(c, a, b, m, k, n),
        }
    }
}

/// Generic CPU dispatch — the mixed-precision (f32) path. The f32 block
/// kernels are CPU-only by design: the [`DenseBackend`] trait is f64
/// (matching the AOT artifact ABI), and the bandwidth win that motivates
/// mixed precision is a host-memory property anyway.
struct CpuDispatch;

impl<T: Real> DenseDispatch<T> for CpuDispatch {
    fn getrf(&self, imp: KernelImpl, a: &mut [T], n: usize) -> Result<(), KernelError> {
        match imp {
            KernelImpl::Scalar => dense::getrf_in_place(a, n),
            KernelImpl::Tiled => tiled::getrf_in_place(a, n),
        }
    }
    fn trsm_lower(&self, imp: KernelImpl, lu: &[T], m: usize, b: &mut [T], k: usize) {
        match imp {
            KernelImpl::Scalar => dense::trsm_lower_unit(lu, m, b, k),
            KernelImpl::Tiled => tiled::trsm_lower_unit(lu, m, b, k),
        }
    }
    fn trsm_upper(&self, imp: KernelImpl, lu: &[T], k: usize, b: &mut [T], m: usize) {
        match imp {
            KernelImpl::Scalar => dense::trsm_upper_right(lu, k, b, m),
            KernelImpl::Tiled => tiled::trsm_upper_right(lu, k, b, m),
        }
    }
    fn gemm(&self, imp: KernelImpl, c: &mut [T], a: &[T], b: &[T], m: usize, k: usize, n: usize) {
        match imp {
            KernelImpl::Scalar => dense::gemm_update(c, a, b, m, k, n),
            KernelImpl::Tiled => tiled::gemm_update(c, a, b, m, k, n),
        }
    }
}

/// Numeric state: the immutable blocked structure plus per-block value
/// vectors behind `RwLock`s so independent tasks can run concurrently
/// (the task DAG guarantees writer exclusivity; the locks make it sound).
///
/// Under [`Precision::Mixed`] the factorization runs entirely in the f32
/// shadow storage (`values32`, allocated on first demotion); the f64
/// storage then holds whatever the last full-precision pass left and is
/// not consulted — the f64 accuracy comes back through iterative
/// refinement in [`super::trisolve`].
pub struct NumericMatrix {
    pub structure: Arc<BlockedMatrix>,
    pub values: Vec<RwLock<Vec<f64>>>,
    /// f32 shadow of `values` for [`Precision::Mixed`] — lazily allocated
    /// so full-precision sessions never pay the +50% value memory.
    values32: OnceLock<Vec<RwLock<Vec<f32>>>>,
    /// Which storage the *factorization* reads and writes.
    pub precision: Precision,
    /// Largest block dimension (workspace sizing).
    pub max_dim: usize,
}

/// Factorization failure.
///
/// `Clone` so a serving layer can report one failed execution to every
/// request of a coalesced batch (see [`crate::serve::Batcher`]).
#[derive(Clone, Debug, PartialEq)]
pub enum FactorError {
    Kernel(KernelError),
    /// A diagonal block of the grid is structurally empty.
    MissingDiagonal(usize),
    /// A coordinate addressed an entry outside the sparsity pattern the
    /// structure was built for — e.g. a device stamp at a position `A`
    /// has no nonzero at. Changing the *pattern* needs a fresh symbolic
    /// analysis / [`crate::session::FactorPlan`], not a value update, and
    /// a serving path must reject such client input instead of aborting.
    OutOfPattern { row: usize, col: usize },
    /// A matrix whose dimension does not match the analyzed structure.
    DimensionMismatch { got: usize, want: usize },
    /// The submitted pattern has a structurally zero diagonal entry at
    /// `row` (original, pre-permutation index). Sparse LU without
    /// numerical pivoting needs every `(i,i)` present in the pattern; a
    /// tenant submitting such a matrix gets this error back instead of
    /// panicking the plan-construction path (and with it, the shard).
    StructurallySingular { row: usize },
    /// A worker panicked while executing a block task — a bug, not a
    /// numeric failure. The executor cancels the run and survives (see
    /// [`crate::coordinator::Executor`]); callers observe an `Err`
    /// instead of a hung pool.
    TaskPanic,
    /// The post-factor scan found a NaN/Inf in block `block`'s factored
    /// values — overflow, a poisoned input, or an injected fault
    /// ([`crate::fault`]). The factors are unusable: a triangular solve
    /// would silently return garbage, so the session refuses to mark
    /// itself factored and a serving router quarantines the tenant
    /// until a clean rebuild (see [`crate::serve::Router`]).
    NonFinite { block: usize },
}

impl std::fmt::Display for FactorError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FactorError::Kernel(e) => write!(f, "kernel failure: {e}"),
            FactorError::MissingDiagonal(k) => {
                write!(f, "diagonal block {k} structurally empty (singular pattern)")
            }
            FactorError::OutOfPattern { row, col } => {
                write!(f, "entry ({row},{col}) is outside the sparsity pattern")
            }
            FactorError::DimensionMismatch { got, want } => {
                write!(f, "matrix has dimension {got}, analyzed structure expects {want}")
            }
            FactorError::StructurallySingular { row } => {
                write!(
                    f,
                    "matrix is structurally singular: diagonal entry ({row},{row}) \
                     is absent from the sparsity pattern"
                )
            }
            FactorError::TaskPanic => {
                write!(f, "a worker panicked while executing a block task")
            }
            FactorError::NonFinite { block } => {
                write!(f, "factored values of block {block} are non-finite (NaN/Inf)")
            }
        }
    }
}

impl std::error::Error for FactorError {}

impl From<KernelError> for FactorError {
    fn from(e: KernelError) -> Self {
        FactorError::Kernel(e)
    }
}

/// Acquire a block's values for reading, shrugging off lock poisoning: a
/// kernel panic (caught by the executor and surfaced as
/// [`FactorError::TaskPanic`]) leaves the block's `RwLock` poisoned, but
/// the failed run is already discarded by the `Err` contract — a later
/// successful refactorize overwrites every block — so poisoning carries
/// no signal a later reader should die on.
pub(crate) fn read_vals<T>(lock: &RwLock<Vec<T>>) -> RwLockReadGuard<'_, Vec<T>> {
    lock.read().unwrap_or_else(PoisonError::into_inner)
}

/// Writer counterpart of [`read_vals`].
pub(crate) fn write_vals<T>(lock: &RwLock<Vec<T>>) -> RwLockWriteGuard<'_, Vec<T>> {
    lock.write().unwrap_or_else(PoisonError::into_inner)
}

impl NumericMatrix {
    fn max_dim_of(bm: &BlockedMatrix) -> usize {
        bm.blocks
            .iter()
            .map(|b| b.n_rows.max(b.n_cols) as usize)
            .max()
            .unwrap_or(0)
    }

    /// Clone values out of a freshly-built blocked matrix.
    pub fn from_blocked(bm: Arc<BlockedMatrix>) -> Self {
        let values = bm
            .blocks
            .iter()
            .map(|b| RwLock::new(b.values.clone()))
            .collect();
        let max_dim = Self::max_dim_of(&bm);
        Self {
            structure: bm,
            values,
            values32: OnceLock::new(),
            precision: Precision::Full,
            max_dim,
        }
    }

    /// Like [`Self::from_blocked`] but with zero-filled value storage —
    /// for sessions, whose first `refactorize` overwrites every value
    /// anyway, this skips the O(nnz) copy of the builder's stale values.
    pub fn from_blocked_zeroed(bm: Arc<BlockedMatrix>) -> Self {
        let values = bm
            .blocks
            .iter()
            .map(|b| RwLock::new(vec![0.0; b.nnz()]))
            .collect();
        let max_dim = Self::max_dim_of(&bm);
        Self {
            structure: bm,
            values,
            values32: OnceLock::new(),
            precision: Precision::Full,
            max_dim,
        }
    }

    /// Switch the storage the factorization runs in. Entering
    /// [`Precision::Mixed`] allocates the f32 shadow on first use;
    /// leaving it keeps the (cheap, already-allocated) shadow around for
    /// the next demotion.
    pub fn set_precision(&mut self, p: Precision) {
        self.precision = p;
        if p == Precision::Mixed {
            let structure = &self.structure;
            self.values32.get_or_init(|| {
                structure
                    .blocks
                    .iter()
                    .map(|b| RwLock::new(vec![0.0f32; b.nnz()]))
                    .collect()
            });
        }
    }

    /// The f32 shadow storage. Panics if the matrix was never demoted —
    /// callers reach this only behind a [`Precision::Mixed`] check.
    pub(crate) fn values32(&self) -> &[RwLock<Vec<f32>>] {
        self.values32
            .get()
            .expect("mixed-precision storage requires set_precision(Precision::Mixed) first")
    }

    /// Zero every stored value — the first step of a numeric-only
    /// re-factorization (new values are then scattered in through the
    /// plan's scatter map). Takes `&mut self`, so no locks are acquired
    /// and no storage is allocated or freed. Precision-aware: zeroes the
    /// storage the current precision factors into.
    pub fn zero_values(&mut self) {
        match self.precision {
            Precision::Full => {
                for v in &mut self.values {
                    v.get_mut().unwrap_or_else(PoisonError::into_inner).fill(0.0);
                }
            }
            Precision::Mixed => {
                for v in self.values32.get_mut().expect("mixed storage initialized") {
                    v.get_mut().unwrap_or_else(PoisonError::into_inner).fill(0.0);
                }
            }
        }
    }

    /// Lock-free mutable access to one block's values (exclusive access
    /// to the whole numeric matrix guarantees soundness).
    pub fn values_mut(&mut self, id: u32) -> &mut [f64] {
        self.values[id as usize].get_mut().unwrap_or_else(PoisonError::into_inner)
    }

    /// f32 counterpart of [`Self::values_mut`].
    pub(crate) fn values32_mut(&mut self, id: u32) -> &mut [f32] {
        self.values32.get_mut().expect("mixed storage initialized")[id as usize]
            .get_mut()
            .unwrap_or_else(PoisonError::into_inner)
    }

    /// Zero one block's stored values — the block-granular reset used by
    /// incremental re-factorization, which re-initializes only the blocks
    /// whose tasks re-execute and leaves every other block's factored
    /// values untouched. Precision-aware like [`Self::zero_values`].
    pub fn zero_block(&mut self, id: u32) {
        match self.precision {
            Precision::Full => self.values_mut(id).fill(0.0),
            Precision::Mixed => self.values32_mut(id).fill(0.0),
        }
    }

    /// Execute one block operation with the given policy/backend.
    ///
    /// Lock discipline: sources acquired as readers before the writer
    /// target. The op DAG keeps conflicting writers apart; locks only make
    /// the (safe) concurrency explicit to the compiler.
    ///
    /// [`Precision::Full`] runs f64 through the pluggable backend;
    /// [`Precision::Mixed`] runs f32 through the CPU kernels directly
    /// (the backend ABI is f64 — see [`CpuDispatch`]).
    pub fn execute(
        &self,
        op: BlockOp,
        policy: &KernelPolicy,
        backend: &dyn DenseBackend,
        ws: &mut Workspace,
    ) -> Result<(), FactorError> {
        // kernel-dispatch fault boundary: one relaxed load when injection
        // is disarmed (see `crate::fault`)
        if crate::fault::enabled() {
            self.pre_dispatch_fault(op);
        }
        let res = match self.precision {
            Precision::Full => {
                self.execute_in(&self.values, op, policy, &BackendDispatch(backend), ws)
            }
            Precision::Mixed => self.execute_in(self.values32(), op, policy, &CpuDispatch, ws),
        };
        if res.is_ok() && crate::fault::enabled() {
            self.post_dispatch_fault(op);
        }
        res
    }

    /// Fault injection before a kernel runs: a forced zero pivot wipes
    /// the diagonal block, so GETRF's stability floor trips with a real
    /// [`KernelError::ZeroPivot`] — the same error path a numerically
    /// singular input takes.
    #[cold]
    fn pre_dispatch_fault(&self, op: BlockOp) {
        if let BlockOp::Getrf { k } = op {
            if crate::fault::force_zero_pivot() {
                if let Some(id) = self.structure.block_id(k, k) {
                    match self.precision {
                        Precision::Full => {
                            write_vals(&self.values[id as usize]).fill(0.0);
                        }
                        Precision::Mixed => {
                            write_vals(&self.values32()[id as usize]).fill(0.0);
                        }
                    }
                }
            }
        }
    }

    /// Fault injection after a kernel succeeds: NaN/Inf poisoning of the
    /// op's target block, caught later by [`Self::scan_non_finite`].
    #[cold]
    fn post_dispatch_fault(&self, op: BlockOp) {
        if let Some(poison) = crate::fault::poison_value() {
            let (i, j) = op.target();
            if let Some(id) = self.structure.block_id(i, j) {
                match self.precision {
                    Precision::Full => {
                        if let Some(v) = write_vals(&self.values[id as usize]).first_mut() {
                            *v = poison;
                        }
                    }
                    Precision::Mixed => {
                        if let Some(v) = write_vals(&self.values32()[id as usize]).first_mut() {
                            *v = poison as f32;
                        }
                    }
                }
            }
        }
    }

    /// Post-factor non-finite scan: the first block whose
    /// active-precision factored values contain a NaN/Inf, or `None`
    /// when the factors are clean. One linear pass over the stored
    /// factor values — noise next to the factorization's flop count —
    /// run after every (re)factorization so unusable factors surface as
    /// [`FactorError::NonFinite`] instead of garbage solutions.
    pub fn scan_non_finite(&self) -> Option<usize> {
        match self.precision {
            Precision::Full => self
                .values
                .iter()
                .position(|l| read_vals(l).iter().any(|v| !v.is_finite())),
            Precision::Mixed => self
                .values32()
                .iter()
                .position(|l| read_vals(l).iter().any(|v| !v.is_finite())),
        }
    }

    fn execute_in<T, D>(
        &self,
        store: &[RwLock<Vec<T>>],
        op: BlockOp,
        policy: &KernelPolicy,
        disp: &D,
        ws: &mut Workspace,
    ) -> Result<(), FactorError>
    where
        T: WsBuf,
        D: DenseDispatch<T>,
    {
        let bm = &*self.structure;
        match op {
            BlockOp::Getrf { k } => {
                let id = bm.block_id(k, k).ok_or(FactorError::MissingDiagonal(k))?;
                let pat = bm.block(id);
                let mut vals = write_vals(&store[id as usize]);
                match policy.choose(pat.density()) {
                    KernelKind::Sparse => kernels::getrf(pat, &mut vals, ws)?,
                    KernelKind::Dense => {
                        let mut d = dense_of(pat, &vals);
                        disp.getrf(policy.imp, &mut d, pat.n_rows as usize)
                            .map_err(|e| relabel(e, pat))?;
                        scatter_into(pat, &mut vals, &d);
                    }
                }
            }
            BlockOp::Gessm { k, j } => {
                let did = bm.block_id(k, k).ok_or(FactorError::MissingDiagonal(k))?;
                let tid = bm.block_id(k, j).expect("GESSM target missing");
                let dpat = bm.block(did);
                let tpat = bm.block(tid);
                let dvals = read_vals(&store[did as usize]);
                let mut tvals = write_vals(&store[tid as usize]);
                match policy.choose(dpat.density().max(tpat.density())) {
                    KernelKind::Sparse => kernels::gessm(tpat, &mut tvals, dpat, &dvals, ws),
                    KernelKind::Dense => {
                        let lu = dense_of(dpat, &dvals);
                        let mut b = dense_of(tpat, &tvals);
                        disp.trsm_lower(
                            policy.imp,
                            &lu,
                            dpat.n_rows as usize,
                            &mut b,
                            tpat.n_cols as usize,
                        );
                        scatter_into(tpat, &mut tvals, &b);
                    }
                }
            }
            BlockOp::Tstrf { i, k } => {
                let did = bm.block_id(k, k).ok_or(FactorError::MissingDiagonal(k))?;
                let tid = bm.block_id(i, k).expect("TSTRF target missing");
                let dpat = bm.block(did);
                let tpat = bm.block(tid);
                let dvals = read_vals(&store[did as usize]);
                let mut tvals = write_vals(&store[tid as usize]);
                match policy.choose(dpat.density().max(tpat.density())) {
                    KernelKind::Sparse => kernels::tstrf(tpat, &mut tvals, dpat, &dvals, ws),
                    KernelKind::Dense => {
                        let lu = dense_of(dpat, &dvals);
                        let mut b = dense_of(tpat, &tvals);
                        disp.trsm_upper(
                            policy.imp,
                            &lu,
                            dpat.n_cols as usize,
                            &mut b,
                            tpat.n_rows as usize,
                        );
                        scatter_into(tpat, &mut tvals, &b);
                    }
                }
            }
            BlockOp::Ssssm { i, j, k } => {
                let aid = bm.block_id(i, k).expect("SSSSM A-source missing");
                let bid = bm.block_id(k, j).expect("SSSSM B-source missing");
                let Some(cid) = bm.block_id(i, j) else {
                    // No structural overlap (symbolic guarantees no fill
                    // lands here) — nothing to do.
                    return Ok(());
                };
                let apat = bm.block(aid);
                let bpat = bm.block(bid);
                let cpat = bm.block(cid);
                let avals = read_vals(&store[aid as usize]);
                let bvals = read_vals(&store[bid as usize]);
                let mut cvals = write_vals(&store[cid as usize]);
                let dens = apat.density().max(bpat.density()).max(cpat.density());
                match policy.choose(dens) {
                    KernelKind::Sparse => kernels::ssssm(
                        cpat, &mut cvals, apat, &avals, bpat, &bvals, ws,
                    ),
                    KernelKind::Dense => {
                        let a = dense_of(apat, &avals);
                        let b = dense_of(bpat, &bvals);
                        let mut c = dense_of(cpat, &cvals);
                        disp.gemm(
                            policy.imp,
                            &mut c,
                            &a,
                            &b,
                            apat.n_rows as usize,
                            apat.n_cols as usize,
                            bpat.n_cols as usize,
                        );
                        scatter_into(cpat, &mut cvals, &c);
                    }
                }
            }
        }
        Ok(())
    }

    /// Snapshot values of a block (tests / assembly).
    pub fn block_values(&self, id: u32) -> Vec<f64> {
        read_vals(&self.values[id as usize]).clone()
    }
}

fn relabel(e: KernelError, pat: &Block) -> KernelError {
    match e {
        KernelError::ZeroPivot { local_col, value, .. } => KernelError::ZeroPivot {
            block: (pat.bi, pat.bj),
            local_col,
            value,
        },
    }
}

fn dense_of<T: Real>(pat: &Block, vals: &[T]) -> Vec<T> {
    let (nr, nc) = (pat.n_rows as usize, pat.n_cols as usize);
    let mut d = vec![T::ZERO; nr * nc];
    for c in 0..nc {
        for t in pat.col_ptr[c] as usize..pat.col_ptr[c + 1] as usize {
            d[c * nr + pat.row_idx[t] as usize] = vals[t];
        }
    }
    d
}

fn scatter_into<T: Real>(pat: &Block, vals: &mut [T], d: &[T]) {
    let nr = pat.n_rows as usize;
    for c in 0..pat.n_cols as usize {
        for t in pat.col_ptr[c] as usize..pat.col_ptr[c + 1] as usize {
            vals[t] = d[c * nr + pat.row_idx[t] as usize];
        }
    }
}

/// The factored matrix: structure + `{L\U}` values per block.
pub struct Factors {
    pub numeric: NumericMatrix,
    /// Per-op kernel counts (sparse, dense) — reporting.
    pub sparse_ops: usize,
    pub dense_ops: usize,
}

impl Factors {
    /// Solve `L U x = b` using the blocked factors (no permutation —
    /// callers in [`crate::solver`] handle the reordering wrap).
    pub fn solve(&self, b: &[f64]) -> Vec<f64> {
        super::trisolve::solve(&self.numeric, b)
    }

    /// Solve `(L U)ᵀ x = b` (transpose system).
    pub fn solve_transpose(&self, b: &[f64]) -> Vec<f64> {
        super::trisolve_t::solve_transpose(&self.numeric, b)
    }

    /// Reassemble `{L\U}` into a global CSC (diagnostics).
    pub fn to_csc(&self) -> crate::sparse::Csc {
        let bm = &*self.numeric.structure;
        let n = bm.blocking.n();
        let positions = bm.blocking.positions();
        let mut coo = crate::sparse::Coo::with_capacity(n, n, bm.nnz());
        for (idx, blk) in bm.blocks.iter().enumerate() {
            let vals = read_vals(&self.numeric.values[idx]);
            let (rlo, clo) = (positions[blk.bi as usize], positions[blk.bj as usize]);
            for c in 0..blk.n_cols as usize {
                for t in blk.col_ptr[c] as usize..blk.col_ptr[c + 1] as usize {
                    coo.push(rlo + blk.row_idx[t] as usize, clo + c, vals[t]);
                }
            }
        }
        coo.to_csc()
    }
}

/// Algorithm 1, sequential: the reference executor (the coordinator runs
/// the same ops through its dependency DAG).
pub fn factorize_sequential(
    bm: Arc<BlockedMatrix>,
    policy: &KernelPolicy,
    backend: &dyn DenseBackend,
) -> Result<Factors, FactorError> {
    let nm = NumericMatrix::from_blocked(bm);
    let mut ws = Workspace::with_capacity(nm.max_dim);
    let (mut sparse_ops, mut dense_ops) = (0usize, 0usize);
    let bm = nm.structure.clone();
    let nb = bm.nb();
    for k in 0..nb {
        let mut run = |op: BlockOp, nm: &NumericMatrix| -> Result<(), FactorError> {
            // count kernel kinds for reporting
            match op {
                BlockOp::Getrf { .. } | BlockOp::Gessm { .. } | BlockOp::Tstrf { .. }
                | BlockOp::Ssssm { .. } => {
                    if policy.force_dense {
                        dense_ops += 1;
                    } else {
                        sparse_ops += 1;
                    }
                }
            }
            nm.execute(op, policy, backend, &mut ws)
        };
        run(BlockOp::Getrf { k }, &nm)?;
        let lids: Vec<usize> = bm.by_col[k]
            .iter()
            .map(|&id| bm.block(id).bi as usize)
            .filter(|&i| i > k)
            .collect();
        let uids: Vec<usize> = bm.by_row[k]
            .iter()
            .map(|&id| bm.block(id).bj as usize)
            .filter(|&j| j > k)
            .collect();
        for &i in &lids {
            run(BlockOp::Tstrf { i, k }, &nm)?;
        }
        for &j in &uids {
            run(BlockOp::Gessm { k, j }, &nm)?;
        }
        for &i in &lids {
            for &j in &uids {
                run(BlockOp::Ssssm { i, j, k }, &nm)?;
            }
        }
    }
    Ok(Factors { numeric: nm, sparse_ops, dense_ops })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blocking::{regular_blocking, BlockedMatrix};
    use crate::sparse::{gen, residual};
    use crate::symbolic;

    fn factor(a: &crate::sparse::Csc, bs: usize, policy: &KernelPolicy) -> Factors {
        let sym = symbolic::analyze(a);
        let ldu = sym.ldu_pattern(a).unwrap();
        let bm = Arc::new(BlockedMatrix::build(&ldu, regular_blocking(a.n_cols(), bs)));
        factorize_sequential(bm, policy, &CpuDense).unwrap()
    }

    fn check_solve(a: &crate::sparse::Csc, bs: usize, policy: &KernelPolicy, tol: f64) {
        let f = factor(a, bs, policy);
        let n = a.n_cols();
        let b: Vec<f64> = (0..n).map(|i| 1.0 + (i % 7) as f64).collect();
        let x = f.solve(&b);
        let r = residual(a, &x, &b);
        assert!(r < tol, "residual {r}");
    }

    #[test]
    fn sparse_policy_solves_grid() {
        check_solve(&gen::grid2d_laplacian(9, 9), 16, &KernelPolicy::default(), 1e-10);
    }

    #[test]
    fn sparse_policy_solves_unsymmetric() {
        check_solve(&gen::directed_graph(120, 4, 3), 25, &KernelPolicy::default(), 1e-10);
    }

    #[test]
    fn dense_policy_matches_sparse() {
        let a = gen::circuit_bbd(gen::CircuitParams { n: 150, ..Default::default() });
        let fs = factor(&a, 30, &KernelPolicy::default());
        let fd = factor(
            &a,
            30,
            &KernelPolicy { force_dense: true, ..Default::default() },
        );
        let cs = fs.to_csc();
        let cd = fd.to_csc();
        assert_eq!(cs.nnz(), cd.nnz());
        for j in 0..150 {
            let (vs, vd) = (cs.col_values(j), cd.col_values(j));
            for (x, y) in vs.iter().zip(vd) {
                assert!((x - y).abs() < 1e-8 * y.abs().max(1.0));
            }
        }
    }

    /// The acceptance-bearing identity: a full force-dense factorization
    /// under `KernelImpl::Scalar` and `KernelImpl::Tiled` produces
    /// bit-identical factors (every kernel hit through the real driver,
    /// not just in isolation).
    #[test]
    fn tiled_factors_bit_identical_to_scalar() {
        let a = gen::circuit_bbd(gen::CircuitParams { n: 180, ..Default::default() });
        let f_s = factor(
            &a,
            37,
            &KernelPolicy { force_dense: true, imp: KernelImpl::Scalar, ..Default::default() },
        );
        let f_t = factor(
            &a,
            37,
            &KernelPolicy { force_dense: true, imp: KernelImpl::Tiled, ..Default::default() },
        );
        for (idx, _) in f_s.numeric.structure.blocks.iter().enumerate() {
            let vs = f_s.numeric.block_values(idx as u32);
            let vt = f_t.numeric.block_values(idx as u32);
            for (s, t) in vs.iter().zip(&vt) {
                assert_eq!(s.to_bits(), t.to_bits(), "block {idx} diverged");
            }
        }
    }

    #[test]
    fn mixed_precision_factors_track_full() {
        let a = gen::grid2d_laplacian(12, 12);
        let sym = symbolic::analyze(&a);
        let ldu = sym.ldu_pattern(&a).unwrap();
        let bm = Arc::new(BlockedMatrix::build(&ldu, regular_blocking(144, 24)));
        // full-precision reference
        let full = factorize_sequential(bm.clone(), &KernelPolicy::default(), &CpuDense).unwrap();
        // mixed: demote, copy values in, run the same op schedule
        let mut nm = NumericMatrix::from_blocked(bm.clone());
        nm.set_precision(Precision::Mixed);
        for (id, b) in bm.blocks.iter().enumerate() {
            let dst = nm.values32_mut(id as u32);
            for (d, &v) in dst.iter_mut().zip(&b.values) {
                *d = v as f32;
            }
        }
        let policy = KernelPolicy::default();
        let mut ws = Workspace::with_capacity(nm.max_dim);
        let nb = bm.nb();
        for k in 0..nb {
            nm.execute(BlockOp::Getrf { k }, &policy, &CpuDense, &mut ws).unwrap();
            let lids: Vec<usize> = bm.by_col[k]
                .iter()
                .map(|&id| bm.block(id).bi as usize)
                .filter(|&i| i > k)
                .collect();
            let uids: Vec<usize> = bm.by_row[k]
                .iter()
                .map(|&id| bm.block(id).bj as usize)
                .filter(|&j| j > k)
                .collect();
            for &i in &lids {
                nm.execute(BlockOp::Tstrf { i, k }, &policy, &CpuDense, &mut ws).unwrap();
            }
            for &j in &uids {
                nm.execute(BlockOp::Gessm { k, j }, &policy, &CpuDense, &mut ws).unwrap();
            }
            for &i in &lids {
                for &j in &uids {
                    nm.execute(BlockOp::Ssssm { i, j, k }, &policy, &CpuDense, &mut ws).unwrap();
                }
            }
        }
        for (id, _) in bm.blocks.iter().enumerate() {
            let want = full.numeric.block_values(id as u32);
            let got = read_vals(&nm.values32()[id]);
            for (w, g) in want.iter().zip(got.iter()) {
                assert!(
                    (w - *g as f64).abs() < 1e-3 * w.abs().max(1.0),
                    "block {id}: f32 factor drifted: {g} vs {w}"
                );
            }
        }
    }

    #[test]
    fn mixed_policy_solves() {
        let a = gen::electromagnetics_like(200, 10, 2, 9);
        check_solve(&a, 32, &KernelPolicy { dense_threshold: 0.15, ..Default::default() }, 1e-9);
    }

    #[test]
    fn block_size_one_degenerates_to_scalar_lu() {
        check_solve(&gen::tridiagonal(50), 1, &KernelPolicy::default(), 1e-12);
    }

    #[test]
    fn single_block_covers_whole_matrix() {
        check_solve(&gen::grid2d_laplacian(7, 7), 49, &KernelPolicy::default(), 1e-10);
    }

    #[test]
    fn irregular_blocking_factorizes_too() {
        let a = gen::circuit_bbd(gen::CircuitParams { n: 400, ..Default::default() });
        let sym = symbolic::analyze(&a);
        let ldu = sym.ldu_pattern(&a).unwrap();
        let curve = crate::blocking::DiagFeature::from_csc(&ldu).curve();
        let blocking = crate::blocking::irregular_blocking(
            &curve,
            &crate::blocking::IrregularParams::default(),
        );
        let bm = Arc::new(BlockedMatrix::build(&ldu, blocking));
        let f = factorize_sequential(bm, &KernelPolicy::default(), &CpuDense).unwrap();
        let b: Vec<f64> = (0..400).map(|i| (i % 5) as f64 - 2.0).collect();
        let x = f.solve(&b);
        assert!(residual(&a, &x, &b) < 1e-9);
    }
}
