//! Blocked triangular solves: `L y = b` (forward) and `U x = y` (backward)
//! over the factored `{L\U}` blocks — the final step of `Ax = b`.

use super::factor::{read_vals, NumericMatrix};

/// Solve `L U x = b` with the blocked factors (unit-lower L).
pub fn solve(nm: &NumericMatrix, b: &[f64]) -> Vec<f64> {
    let bm = &*nm.structure;
    let n = bm.blocking.n();
    assert_eq!(b.len(), n);
    let positions = bm.blocking.positions();
    let nb = bm.nb();
    let mut x = b.to_vec();

    // ---- forward: L y = b ----
    for k in 0..nb {
        let (lo, hi) = (positions[k], positions[k + 1]);
        let did = bm.block_id(k, k).expect("diagonal block");
        let dpat = bm.block(did);
        let dvals = read_vals(&nm.values[did as usize]);
        // in-place unit-lower forward substitution within the diagonal block
        for c in 0..(hi - lo) {
            let alpha = x[lo + c];
            if alpha == 0.0 {
                continue;
            }
            let (s, e) = (dpat.col_ptr[c] as usize, dpat.col_ptr[c + 1] as usize);
            let rows = &dpat.row_idx[s..e];
            let dstart = dpat.diag_pos[c] as usize + 1;
            for t in dstart..rows.len() {
                x[lo + rows[t] as usize] -= alpha * dvals[s + t];
            }
        }
        drop(dvals);
        // propagate to below block-rows: b_i -= L_ik * y_k
        for &id in &bm.by_col[k] {
            let blk = bm.block(id);
            let i = blk.bi as usize;
            if i <= k {
                continue;
            }
            let rlo = positions[i];
            let vals = read_vals(&nm.values[id as usize]);
            for c in 0..blk.n_cols as usize {
                let alpha = x[lo + c];
                if alpha == 0.0 {
                    continue;
                }
                for t in blk.col_ptr[c] as usize..blk.col_ptr[c + 1] as usize {
                    x[rlo + blk.row_idx[t] as usize] -= alpha * vals[t];
                }
            }
        }
    }

    // ---- backward: U x = y ----
    for k in (0..nb).rev() {
        let (lo, hi) = (positions[k], positions[k + 1]);
        let did = bm.block_id(k, k).expect("diagonal block");
        let dpat = bm.block(did);
        let dvals = read_vals(&nm.values[did as usize]);
        // backward substitution within the diagonal block
        for c in (0..(hi - lo)).rev() {
            let (s, e) = (dpat.col_ptr[c] as usize, dpat.col_ptr[c + 1] as usize);
            let rows = &dpat.row_idx[s..e];
            let dpos = dpat.diag_pos[c] as usize;
            let xc = x[lo + c] / dvals[s + dpos];
            x[lo + c] = xc;
            if xc == 0.0 {
                continue;
            }
            for t in 0..dpos {
                x[lo + rows[t] as usize] -= xc * dvals[s + t];
            }
        }
        drop(dvals);
        // propagate to above block-rows: y_i -= U_ik * x_k
        for &id in &bm.by_col[k] {
            let blk = bm.block(id);
            let i = blk.bi as usize;
            if i >= k {
                continue;
            }
            let rlo = positions[i];
            let vals = read_vals(&nm.values[id as usize]);
            for c in 0..blk.n_cols as usize {
                let xc = x[lo + c];
                if xc == 0.0 {
                    continue;
                }
                for t in blk.col_ptr[c] as usize..blk.col_ptr[c + 1] as usize {
                    x[rlo + blk.row_idx[t] as usize] -= xc * vals[t];
                }
            }
        }
    }
    x
}

/// Solve `L U x = b` against **single-precision** factors — the
/// correction solve of mixed-precision iterative refinement.
///
/// `nm` must have been demoted with
/// [`NumericMatrix::set_precision`]`(Mixed)` and factorized since; the
/// f32 factor values are promoted to f64 at the point of use, so the
/// substitution arithmetic itself runs in f64 (only the factors carry
/// single-precision error). Traversal and entry-level operation order
/// match [`solve`] exactly.
pub fn solve_mixed(nm: &NumericMatrix, b: &[f64]) -> Vec<f64> {
    let bm = &*nm.structure;
    let n = bm.blocking.n();
    assert_eq!(b.len(), n);
    let store = nm.values32();
    let positions = bm.blocking.positions();
    let nb = bm.nb();
    let mut x = b.to_vec();

    // ---- forward: L y = b ----
    for k in 0..nb {
        let (lo, hi) = (positions[k], positions[k + 1]);
        let did = bm.block_id(k, k).expect("diagonal block");
        let dpat = bm.block(did);
        let dvals = read_vals(&store[did as usize]);
        for c in 0..(hi - lo) {
            let alpha = x[lo + c];
            if alpha == 0.0 {
                continue;
            }
            let (s, e) = (dpat.col_ptr[c] as usize, dpat.col_ptr[c + 1] as usize);
            let rows = &dpat.row_idx[s..e];
            let dstart = dpat.diag_pos[c] as usize + 1;
            for t in dstart..rows.len() {
                x[lo + rows[t] as usize] -= alpha * dvals[s + t] as f64;
            }
        }
        drop(dvals);
        for &id in &bm.by_col[k] {
            let blk = bm.block(id);
            let i = blk.bi as usize;
            if i <= k {
                continue;
            }
            let rlo = positions[i];
            let vals = read_vals(&store[id as usize]);
            for c in 0..blk.n_cols as usize {
                let alpha = x[lo + c];
                if alpha == 0.0 {
                    continue;
                }
                for t in blk.col_ptr[c] as usize..blk.col_ptr[c + 1] as usize {
                    x[rlo + blk.row_idx[t] as usize] -= alpha * vals[t] as f64;
                }
            }
        }
    }

    // ---- backward: U x = y ----
    for k in (0..nb).rev() {
        let (lo, hi) = (positions[k], positions[k + 1]);
        let did = bm.block_id(k, k).expect("diagonal block");
        let dpat = bm.block(did);
        let dvals = read_vals(&store[did as usize]);
        for c in (0..(hi - lo)).rev() {
            let (s, e) = (dpat.col_ptr[c] as usize, dpat.col_ptr[c + 1] as usize);
            let rows = &dpat.row_idx[s..e];
            let dpos = dpat.diag_pos[c] as usize;
            let xc = x[lo + c] / dvals[s + dpos] as f64;
            x[lo + c] = xc;
            if xc == 0.0 {
                continue;
            }
            for t in 0..dpos {
                x[lo + rows[t] as usize] -= xc * dvals[s + t] as f64;
            }
        }
        drop(dvals);
        for &id in &bm.by_col[k] {
            let blk = bm.block(id);
            let i = blk.bi as usize;
            if i >= k {
                continue;
            }
            let rlo = positions[i];
            let vals = read_vals(&store[id as usize]);
            for c in 0..blk.n_cols as usize {
                let xc = x[lo + c];
                if xc == 0.0 {
                    continue;
                }
                for t in blk.col_ptr[c] as usize..blk.col_ptr[c + 1] as usize {
                    x[rlo + blk.row_idx[t] as usize] -= xc * vals[t] as f64;
                }
            }
        }
    }
    x
}

/// Solve `L U X = B` for several right-hand sides in one blocked sweep.
///
/// The factor blocks are traversed **once per block column** instead of
/// once per right-hand side: every visited block updates all RHS columns
/// before the sweep moves on, amortizing the pattern walk and keeping the
/// block values hot in cache — the batched path behind
/// [`crate::session::SolverSession::solve_many`]. Per RHS the entry-level
/// operation order matches [`solve`] exactly, so results are bit-identical
/// to repeated single-RHS solves.
pub fn solve_multi(nm: &NumericMatrix, bs: &[Vec<f64>]) -> Vec<Vec<f64>> {
    let bm = &*nm.structure;
    let n = bm.blocking.n();
    let nrhs = bs.len();
    if nrhs == 0 {
        return Vec::new();
    }
    // pack row-major: x[i * nrhs + s] — one cache line serves all RHS of a row
    let mut x = vec![0.0f64; n * nrhs];
    for (s, b) in bs.iter().enumerate() {
        assert_eq!(b.len(), n, "rhs {s} has wrong length");
        for (i, &v) in b.iter().enumerate() {
            x[i * nrhs + s] = v;
        }
    }
    let positions = bm.blocking.positions();
    let nb = bm.nb();
    let mut alpha = vec![0.0f64; nrhs]; // per-column scratch (allocated once)

    // ---- forward: L Y = B ----
    for k in 0..nb {
        let (lo, hi) = (positions[k], positions[k + 1]);
        let did = bm.block_id(k, k).expect("diagonal block");
        let dpat = bm.block(did);
        let dvals = read_vals(&nm.values[did as usize]);
        for c in 0..(hi - lo) {
            alpha.copy_from_slice(&x[(lo + c) * nrhs..(lo + c + 1) * nrhs]);
            if alpha.iter().all(|&a| a == 0.0) {
                continue;
            }
            let (cs, ce) = (dpat.col_ptr[c] as usize, dpat.col_ptr[c + 1] as usize);
            let rows = &dpat.row_idx[cs..ce];
            let dstart = dpat.diag_pos[c] as usize + 1;
            for t in dstart..rows.len() {
                let v = dvals[cs + t];
                let r = lo + rows[t] as usize;
                for s in 0..nrhs {
                    x[r * nrhs + s] -= alpha[s] * v;
                }
            }
        }
        drop(dvals);
        for &id in &bm.by_col[k] {
            let blk = bm.block(id);
            let i = blk.bi as usize;
            if i <= k {
                continue;
            }
            let rlo = positions[i];
            let vals = read_vals(&nm.values[id as usize]);
            for c in 0..blk.n_cols as usize {
                alpha.copy_from_slice(&x[(lo + c) * nrhs..(lo + c + 1) * nrhs]);
                if alpha.iter().all(|&a| a == 0.0) {
                    continue;
                }
                for t in blk.col_ptr[c] as usize..blk.col_ptr[c + 1] as usize {
                    let v = vals[t];
                    let r = rlo + blk.row_idx[t] as usize;
                    for s in 0..nrhs {
                        x[r * nrhs + s] -= alpha[s] * v;
                    }
                }
            }
        }
    }

    // ---- backward: U X = Y ----
    for k in (0..nb).rev() {
        let (lo, hi) = (positions[k], positions[k + 1]);
        let did = bm.block_id(k, k).expect("diagonal block");
        let dpat = bm.block(did);
        let dvals = read_vals(&nm.values[did as usize]);
        for c in (0..(hi - lo)).rev() {
            let (cs, ce) = (dpat.col_ptr[c] as usize, dpat.col_ptr[c + 1] as usize);
            let rows = &dpat.row_idx[cs..ce];
            let dpos = dpat.diag_pos[c] as usize;
            let piv = dvals[cs + dpos];
            for s in 0..nrhs {
                let xc = x[(lo + c) * nrhs + s] / piv;
                x[(lo + c) * nrhs + s] = xc;
                alpha[s] = xc;
            }
            if alpha.iter().all(|&a| a == 0.0) {
                continue;
            }
            for t in 0..dpos {
                let v = dvals[cs + t];
                let r = lo + rows[t] as usize;
                for s in 0..nrhs {
                    x[r * nrhs + s] -= alpha[s] * v;
                }
            }
        }
        drop(dvals);
        for &id in &bm.by_col[k] {
            let blk = bm.block(id);
            let i = blk.bi as usize;
            if i >= k {
                continue;
            }
            let rlo = positions[i];
            let vals = read_vals(&nm.values[id as usize]);
            for c in 0..blk.n_cols as usize {
                alpha.copy_from_slice(&x[(lo + c) * nrhs..(lo + c + 1) * nrhs]);
                if alpha.iter().all(|&a| a == 0.0) {
                    continue;
                }
                for t in blk.col_ptr[c] as usize..blk.col_ptr[c + 1] as usize {
                    let v = vals[t];
                    let r = rlo + blk.row_idx[t] as usize;
                    for s in 0..nrhs {
                        x[r * nrhs + s] -= alpha[s] * v;
                    }
                }
            }
        }
    }

    // unpack
    (0..nrhs)
        .map(|s| (0..n).map(|i| x[i * nrhs + s]).collect())
        .collect()
}

#[cfg(test)]
mod tests {
    use crate::blocking::{regular_blocking, BlockedMatrix};
    use crate::numeric::factor::{factorize_sequential, CpuDense};
    use crate::numeric::KernelPolicy;
    use crate::sparse::{gen, residual};
    use crate::symbolic;
    use std::sync::Arc;

    fn solve_check(a: &crate::sparse::Csc, bs: usize) {
        let sym = symbolic::analyze(a);
        let ldu = sym.ldu_pattern(a).unwrap();
        let bm = Arc::new(BlockedMatrix::build(&ldu, regular_blocking(a.n_cols(), bs)));
        let f = factorize_sequential(bm, &KernelPolicy::default(), &CpuDense).unwrap();
        let n = a.n_cols();
        // several right-hand sides
        for seed in 0..3u64 {
            let mut rng = crate::util::Prng::new(seed);
            let b: Vec<f64> = (0..n).map(|_| rng.signed_unit() * 10.0).collect();
            let x = f.solve(&b);
            let r = residual(a, &x, &b);
            assert!(r < 1e-9, "seed {seed}: residual {r}");
        }
    }

    #[test]
    fn solve_on_various_structures() {
        solve_check(&gen::tridiagonal(64), 9);
        solve_check(&gen::grid2d_laplacian(8, 8), 10);
        solve_check(&gen::banded_fem(90, &[1, 7], 0.9, 2), 14);
    }

    #[test]
    fn solve_with_zero_rhs_gives_zero() {
        let a = gen::grid2d_laplacian(6, 6);
        let sym = symbolic::analyze(&a);
        let ldu = sym.ldu_pattern(&a).unwrap();
        let bm = Arc::new(BlockedMatrix::build(&ldu, regular_blocking(36, 6)));
        let f = factorize_sequential(bm, &KernelPolicy::default(), &CpuDense).unwrap();
        let x = f.solve(&vec![0.0; 36]);
        assert!(x.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn solve_multi_matches_single_bitwise() {
        let a = gen::banded_fem(80, &[1, 5], 0.9, 3);
        let sym = symbolic::analyze(&a);
        let ldu = sym.ldu_pattern(&a).unwrap();
        let bm = Arc::new(BlockedMatrix::build(&ldu, regular_blocking(80, 13)));
        let f = factorize_sequential(bm, &KernelPolicy::default(), &CpuDense).unwrap();
        let mut rng = crate::util::Prng::new(99);
        let bs: Vec<Vec<f64>> = (0..5)
            .map(|_| (0..80).map(|_| rng.signed_unit() * 4.0).collect())
            .collect();
        let batched = super::solve_multi(&f.numeric, &bs);
        assert_eq!(batched.len(), 5);
        for (b, x) in bs.iter().zip(&batched) {
            assert_eq!(x, &f.solve(b), "batched solve must be bit-identical");
            assert!(residual(&a, x, b) < 1e-9);
        }
        assert!(super::solve_multi(&f.numeric, &[]).is_empty());
    }

    #[test]
    fn solve_identity_returns_rhs() {
        let a = crate::sparse::Csc::identity(20);
        let sym = symbolic::analyze(&a);
        let ldu = sym.ldu_pattern(&a).unwrap();
        let bm = Arc::new(BlockedMatrix::build(&ldu, regular_blocking(20, 4)));
        let f = factorize_sequential(bm, &KernelPolicy::default(), &CpuDense).unwrap();
        let b: Vec<f64> = (0..20).map(|i| i as f64).collect();
        assert_eq!(f.solve(&b), b);
    }
}
