//! Numeric factorization (the paper's phase 3 — 50–95% of total time,
//! Fig 1) — right-looking blocked LU over a [`crate::blocking::BlockedMatrix`].
//!
//! The engine mirrors PanguLU's four block kernels:
//!
//! | op      | effect                                   | paper Alg. 1 line |
//! |---------|------------------------------------------|-------------------|
//! | GETRF   | `B_kk → L_kk·U_kk` (in-place)            | 3                 |
//! | GESSM   | `B_kj ← L_kk⁻¹·B_kj` (U panel)           | 5                 |
//! | TSTRF   | `B_ik ← B_ik·U_kk⁻¹` (L panel)           | 6                 |
//! | SSSSM   | `B_ij ← B_ij − B_ik·B_kj` (Schur update) | 10                |
//!
//! Each kernel has a **sparse** implementation ([`kernels`]) operating on
//! the fixed fill pattern with a dense scatter workspace, and a **dense**
//! implementation used when block density crosses the policy threshold
//! (PanguLU's sparse/dense kernel selection). The dense implementation
//! itself comes in two flavors selected by [`KernelImpl`]: the portable
//! scalar reference ([`dense`], the oracle) and the register-blocked,
//! cache-tiled fast path ([`tiled`]) — order-preserving by construction,
//! so both produce **bit-identical** f64 results (proved continuously by
//! `tests/kernel_differential.rs`). On real hardware the dense path is
//! the AOT-compiled Pallas/XLA artifact executed through
//! [`crate::runtime`]; the pure-rust versions here are the CPU fallback
//! and the correctness oracle.
//!
//! All kernels are generic over [`Real`] (`f64`/`f32`): the f32
//! instantiation backs the opt-in mixed-precision replay mode
//! ([`Precision::Mixed`] — f32 block factorization, f64 iterative
//! refinement in [`trisolve`]).

pub mod dense;
pub mod factor;
pub mod kernels;
pub mod real;
pub mod tiled;
pub mod trisolve;
pub mod trisolve_t;

pub use factor::{factorize_sequential, FactorError, Factors, NumericMatrix};
pub use kernels::Workspace;
pub use real::Real;

/// Which kernel implementation a block operation should use.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KernelKind {
    Sparse,
    Dense,
}

/// Which *dense-path* implementation executes a dense block op.
///
/// Both produce bit-identical f64 results: the tiled kernels preserve the
/// scalar kernels' per-element operation order exactly (ascending-`k`
/// rank-1 updates, one subtract of one product at a time, scaling at the
/// same sequence point) — the speedup comes from register/cache reuse,
/// not from reassociation. The scalar path survives as the oracle the
/// differential rig checks the fast path against.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum KernelImpl {
    /// Portable scalar reference kernels ([`dense`]).
    Scalar,
    /// Register-blocked, cache-tiled microkernels ([`tiled`]) — the
    /// default fast path.
    #[default]
    Tiled,
}

/// Numeric precision the block factorization runs in.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Precision {
    /// f64 storage end to end (the default, bit-exactness-bearing path).
    #[default]
    Full,
    /// f32 block factorization (half the factor-storage bandwidth — the
    /// replay-storm saver) with f64 iterative refinement in the solves.
    /// Opt-in via [`crate::session::SolverSession::set_precision`] or the
    /// serve layer's precision routing.
    Mixed,
}

/// Sparse-vs-dense kernel selection policy (PanguLU's kernel selection):
/// blocks denser than `dense_threshold` use dense kernels.
#[derive(Clone, Copy, Debug)]
pub struct KernelPolicy {
    /// Density at/above which a block op goes to the dense kernel.
    pub dense_threshold: f64,
    /// Force everything dense (the SuperLU_DIST-like baseline, which
    /// computes supernodal panels with dense BLAS regardless of sparsity).
    pub force_dense: bool,
    /// Route dense ops through the PJRT runtime artifacts when loaded.
    pub use_runtime: bool,
    /// Scalar reference vs tiled fast path for the dense kernels.
    pub imp: KernelImpl,
}

impl Default for KernelPolicy {
    fn default() -> Self {
        Self {
            dense_threshold: 0.30,
            force_dense: false,
            use_runtime: false,
            imp: KernelImpl::default(),
        }
    }
}

impl KernelPolicy {
    /// Decide the kernel for an op whose participating blocks have the
    /// given maximum density.
    pub fn choose(&self, density: f64) -> KernelKind {
        if self.force_dense || density >= self.dense_threshold {
            KernelKind::Dense
        } else {
            KernelKind::Sparse
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn policy_thresholds() {
        let p = KernelPolicy::default();
        assert_eq!(p.choose(0.05), KernelKind::Sparse);
        assert_eq!(p.choose(0.95), KernelKind::Dense);
        let f = KernelPolicy { force_dense: true, ..Default::default() };
        assert_eq!(f.choose(0.0), KernelKind::Dense);
    }

    #[test]
    fn tiled_is_the_default_dense_impl() {
        assert_eq!(KernelPolicy::default().imp, KernelImpl::Tiled);
        assert_eq!(Precision::default(), Precision::Full);
    }
}
