//! Numeric factorization (the paper's phase 3 — 50–95% of total time,
//! Fig 1) — right-looking blocked LU over a [`crate::blocking::BlockedMatrix`].
//!
//! The engine mirrors PanguLU's four block kernels:
//!
//! | op      | effect                                   | paper Alg. 1 line |
//! |---------|------------------------------------------|-------------------|
//! | GETRF   | `B_kk → L_kk·U_kk` (in-place)            | 3                 |
//! | GESSM   | `B_kj ← L_kk⁻¹·B_kj` (U panel)           | 5                 |
//! | TSTRF   | `B_ik ← B_ik·U_kk⁻¹` (L panel)           | 6                 |
//! | SSSSM   | `B_ij ← B_ij − B_ik·B_kj` (Schur update) | 10                |
//!
//! Each kernel has a **sparse** implementation ([`kernels`]) operating on
//! the fixed fill pattern with a dense scatter workspace, and a **dense**
//! implementation ([`dense`]) used when block density crosses the policy
//! threshold (PanguLU's sparse/dense kernel selection) — on real hardware
//! the dense path is the AOT-compiled Pallas/XLA artifact executed through
//! [`crate::runtime`]; the pure-rust versions here are the CPU fallback and
//! the correctness oracle.

pub mod dense;
pub mod factor;
pub mod kernels;
pub mod trisolve;
pub mod trisolve_t;

pub use factor::{factorize_sequential, FactorError, Factors, NumericMatrix};
pub use kernels::Workspace;

/// Which kernel implementation a block operation should use.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KernelKind {
    Sparse,
    Dense,
}

/// Sparse-vs-dense kernel selection policy (PanguLU's kernel selection):
/// blocks denser than `dense_threshold` use dense kernels.
#[derive(Clone, Copy, Debug)]
pub struct KernelPolicy {
    /// Density at/above which a block op goes to the dense kernel.
    pub dense_threshold: f64,
    /// Force everything dense (the SuperLU_DIST-like baseline, which
    /// computes supernodal panels with dense BLAS regardless of sparsity).
    pub force_dense: bool,
    /// Route dense ops through the PJRT runtime artifacts when loaded.
    pub use_runtime: bool,
}

impl Default for KernelPolicy {
    fn default() -> Self {
        Self { dense_threshold: 0.30, force_dense: false, use_runtime: false }
    }
}

impl KernelPolicy {
    /// Decide the kernel for an op whose participating blocks have the
    /// given maximum density.
    pub fn choose(&self, density: f64) -> KernelKind {
        if self.force_dense || density >= self.dense_threshold {
            KernelKind::Dense
        } else {
            KernelKind::Sparse
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn policy_thresholds() {
        let p = KernelPolicy::default();
        assert_eq!(p.choose(0.05), KernelKind::Sparse);
        assert_eq!(p.choose(0.95), KernelKind::Dense);
        let f = KernelPolicy { force_dense: true, ..Default::default() };
        assert_eq!(f.choose(0.0), KernelKind::Dense);
    }
}
