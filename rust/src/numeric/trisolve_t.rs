//! Transpose triangular solves: `(LU)ᵀ x = b`, i.e. `Uᵀ y = b` (forward)
//! then `Lᵀ x = y` (backward), over the same blocked `{L\U}` storage.
//! Needed for `Aᵀx = b` — adjoint solves in sensitivity analysis and
//! transistor-level circuit simulation (the paper's application domain).

use super::factor::{read_vals, NumericMatrix};

/// Solve `Uᵀ Lᵀ x = b` with the blocked factors (unit-lower L).
pub fn solve_transpose(nm: &NumericMatrix, b: &[f64]) -> Vec<f64> {
    let bm = &*nm.structure;
    let n = bm.blocking.n();
    assert_eq!(b.len(), n);
    let positions = bm.blocking.positions();
    let nb = bm.nb();
    let mut x = b.to_vec();

    // ---- forward: Uᵀ y = b (Uᵀ is lower triangular) ----
    // y[c] = (b[c] - Σ_{r<c} U[r,c]·y[r]) / U[c,c]  — a *gather* over the
    // CSC column, so transpose solves need no transposed storage.
    for k in 0..nb {
        let (lo, _hi) = (positions[k], positions[k + 1]);
        // contributions from above block-rows already applied (see below);
        // solve within diagonal block
        let did = bm.block_id(k, k).expect("diagonal block");
        let dpat = bm.block(did);
        let dvals = read_vals(&nm.values[did as usize]);
        for c in 0..dpat.n_cols as usize {
            let (s, _e) = (dpat.col_ptr[c] as usize, dpat.col_ptr[c + 1] as usize);
            let dpos = dpat.diag_pos[c] as usize;
            let mut acc = x[lo + c];
            for t in s..(s + dpos) {
                acc -= dvals[t] * x[lo + dpat.row_idx[t] as usize];
            }
            x[lo + c] = acc / dvals[s + dpos];
        }
        drop(dvals);
        // propagate to the right block-columns: blocks (k, j), j > k hold
        // U_kj; Uᵀ couples y_j ← y_k
        for &id in &bm.by_row[k] {
            let blk = bm.block(id);
            let j = blk.bj as usize;
            if j <= k {
                continue;
            }
            let clo = positions[j];
            let vals = read_vals(&nm.values[id as usize]);
            for c in 0..blk.n_cols as usize {
                let mut acc = 0.0;
                for t in blk.col_ptr[c] as usize..blk.col_ptr[c + 1] as usize {
                    acc += vals[t] * x[lo + blk.row_idx[t] as usize];
                }
                x[clo + c] -= acc;
            }
        }
    }

    // ---- backward: Lᵀ x = y (Lᵀ is unit upper triangular) ----
    // x[c] = y[c] - Σ_{r>c} L[r,c]·x[r] — gather over the L part.
    for k in (0..nb).rev() {
        let (lo, _hi) = (positions[k], positions[k + 1]);
        // contributions from below block-rows: blocks (i, k), i > k hold
        // L_ik; Lᵀ couples x_k ← x_i
        let did = bm.block_id(k, k).expect("diagonal block");
        for &id in &bm.by_col[k] {
            let blk = bm.block(id);
            let i = blk.bi as usize;
            if i <= k {
                continue;
            }
            let rlo = positions[i];
            let vals = read_vals(&nm.values[id as usize]);
            for c in 0..blk.n_cols as usize {
                let mut acc = 0.0;
                for t in blk.col_ptr[c] as usize..blk.col_ptr[c + 1] as usize {
                    acc += vals[t] * x[rlo + blk.row_idx[t] as usize];
                }
                x[lo + c] -= acc;
            }
        }
        // within diagonal block, columns descending
        let dpat = bm.block(did);
        let dvals = read_vals(&nm.values[did as usize]);
        for c in (0..dpat.n_cols as usize).rev() {
            let (s, e) = (dpat.col_ptr[c] as usize, dpat.col_ptr[c + 1] as usize);
            let dpos = dpat.diag_pos[c] as usize;
            let mut acc = x[lo + c];
            for t in (s + dpos + 1)..e {
                acc -= dvals[t] * x[lo + dpat.row_idx[t] as usize];
            }
            x[lo + c] = acc; // unit diagonal
        }
    }
    x
}

#[cfg(test)]
mod tests {
    use crate::blocking::{regular_blocking, BlockedMatrix};
    use crate::numeric::factor::{factorize_sequential, CpuDense};
    use crate::numeric::KernelPolicy;
    use crate::sparse::gen;
    use crate::symbolic;
    use crate::util::Prng;
    use std::sync::Arc;

    fn check_transpose_solve(a: &crate::sparse::Csc, bs: usize) {
        let sym = symbolic::analyze(a);
        let ldu = sym.ldu_pattern(a).unwrap();
        let bm = Arc::new(BlockedMatrix::build(&ldu, regular_blocking(a.n_cols(), bs)));
        let f = factorize_sequential(bm, &KernelPolicy::default(), &CpuDense).unwrap();
        let n = a.n_cols();
        let mut rng = Prng::new(0xAD);
        let x_true: Vec<f64> = (0..n).map(|_| rng.signed_unit()).collect();
        // b = Aᵀ x_true
        let b = a.transpose().mul_vec(&x_true);
        let x = super::solve_transpose(&f.numeric, &b);
        for (got, want) in x.iter().zip(&x_true) {
            assert!((got - want).abs() < 1e-8, "{got} vs {want}");
        }
    }

    #[test]
    fn transpose_solve_grid() {
        check_transpose_solve(&gen::grid2d_laplacian(8, 8), 12);
    }

    #[test]
    fn transpose_solve_unsymmetric() {
        check_transpose_solve(&gen::directed_graph(150, 4, 9), 30);
    }

    #[test]
    fn transpose_solve_bbd() {
        let a = gen::circuit_bbd(gen::CircuitParams { n: 200, ..Default::default() });
        check_transpose_solve(&a, 35);
    }

    #[test]
    fn transpose_solve_identity() {
        let a = crate::sparse::Csc::identity(10);
        check_transpose_solve(&a, 3);
    }
}
