//! Fill-reducing orderings (the paper's phase 1, "reordering").
//!
//! The paper relies on reordering to (a) reduce fill-in and (b) push the
//! remaining nonzeros toward the diagonal / bottom-right BBD shape that the
//! irregular blocking method then exploits. We implement:
//!
//! * [`amd::min_degree`] — quotient-graph minimum degree with AMD-style
//!   approximate external degrees (the default, like PanguLU's use of
//!   MC64+METIS/AMD pipelines);
//! * [`rcm::rcm`] — reverse Cuthill–McKee (bandwidth-reducing baseline);
//! * natural ordering (identity).

pub mod amd;
pub mod btf;
pub mod perm;
pub mod rcm;

pub use btf::{btf, Btf};
pub use perm::Permutation;

use crate::sparse::Csc;

/// Ordering algorithm selector.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OrderingMethod {
    /// Identity permutation.
    Natural,
    /// Reverse Cuthill–McKee on the pattern of A+Aᵀ.
    Rcm,
    /// Approximate minimum degree on the pattern of A+Aᵀ.
    MinDegree,
}

impl std::str::FromStr for OrderingMethod {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "natural" | "none" => Ok(Self::Natural),
            "rcm" => Ok(Self::Rcm),
            "amd" | "mindegree" | "md" => Ok(Self::MinDegree),
            other => Err(format!("unknown ordering {other:?}")),
        }
    }
}

/// Compute the fill-reducing permutation for `a` with the chosen method.
/// The permutation maps old index → new index.
pub fn order(a: &Csc, method: OrderingMethod) -> Permutation {
    match method {
        OrderingMethod::Natural => Permutation::identity(a.n_cols()),
        OrderingMethod::Rcm => rcm::rcm(&a.plus_transpose_pattern()),
        OrderingMethod::MinDegree => amd::min_degree(&a.plus_transpose_pattern()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::gen;

    #[test]
    fn order_natural_is_identity() {
        let a = gen::tridiagonal(10);
        let p = order(&a, OrderingMethod::Natural);
        assert_eq!(p.as_slice(), (0..10).collect::<Vec<_>>().as_slice());
    }

    #[test]
    fn all_methods_return_valid_permutations() {
        let a = gen::grid2d_laplacian(8, 8);
        for m in [OrderingMethod::Natural, OrderingMethod::Rcm, OrderingMethod::MinDegree] {
            let p = order(&a, m);
            assert!(p.is_valid(), "{m:?}");
            assert_eq!(p.len(), 64);
        }
    }

    #[test]
    fn method_parses_from_str() {
        assert_eq!("amd".parse::<OrderingMethod>().unwrap(), OrderingMethod::MinDegree);
        assert_eq!("rcm".parse::<OrderingMethod>().unwrap(), OrderingMethod::Rcm);
        assert_eq!("natural".parse::<OrderingMethod>().unwrap(), OrderingMethod::Natural);
        assert!("bogus".parse::<OrderingMethod>().is_err());
    }
}
